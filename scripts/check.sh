#!/usr/bin/env bash
# Pre-merge gate: lint, compile sanity, tier-1 tests, serving smoke bench,
# and the benchmark baseline-regression comparison — the same steps CI runs
# (.github/workflows/ci.yml), so local green means CI green.
# Usage: scripts/check.sh
set -euo pipefail
cd "$(dirname "$0")/.."

if command -v ruff >/dev/null 2>&1; then
  echo "== ruff lint =="
  ruff check .
  echo "== ruff format check (serving + core + kernels + launch + corpus) =="
  ruff format --check src/repro/serving src/repro/core src/repro/kernels \
    src/repro/launch src/repro/corpus benchmarks/compare_baseline.py
else
  echo "== ruff not installed; skipping lint (CI runs it) =="
fi

echo "== compileall =="
python -m compileall -q src benchmarks

echo "== tier-1 pytest =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

echo "== serving smoke bench =="
smoke_json="$(mktemp /tmp/serve_smoke.XXXXXX.json)"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.serve_bench --smoke --json "$smoke_json"

echo "== benchmark baseline comparison =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.compare_baseline \
  benchmarks/baseline_smoke.json "$smoke_json"
rm -f "$smoke_json"

echo "== OK =="

#!/usr/bin/env bash
# Pre-merge gate: compile sanity, tier-1 tests, serving smoke bench.
# Usage: scripts/check.sh
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== compileall =="
python -m compileall -q src benchmarks

echo "== tier-1 pytest =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

echo "== serving smoke bench =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.serve_bench --smoke

echo "== OK =="

#!/usr/bin/env bash
# Pre-merge gate: lint, compile sanity, tier-1 tests, serving smoke bench,
# and the benchmark baseline-regression comparison — the same steps CI runs
# (.github/workflows/ci.yml), so local green means CI green.
# Usage: scripts/check.sh
set -euo pipefail
cd "$(dirname "$0")/.."

if command -v ruff >/dev/null 2>&1; then
  echo "== ruff lint =="
  ruff check .
  echo "== ruff format check (src + tests + benchmarks) =="
  ruff format --check src/repro/serving src/repro/core src/repro/kernels \
    src/repro/launch src/repro/corpus src/repro/obs tests benchmarks
else
  echo "== ruff not installed; skipping lint (CI runs it) =="
fi

echo "== compileall =="
python -m compileall -q src benchmarks

echo "== tier-1 pytest =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

echo "== serving smoke bench =="
smoke_json="$(mktemp /tmp/serve_smoke.XXXXXX.json)"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.serve_bench --smoke --json "$smoke_json"

echo "== benchmark baseline comparison =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.compare_baseline \
  benchmarks/baseline_smoke.json "$smoke_json"
rm -f "$smoke_json"

echo "== telemetry smoke serve + trace validation =="
tel_dir="$(mktemp -d /tmp/serve_telemetry.XXXXXX)"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.launch.serve \
  --n-docs 800 --n-terms 300 --queries 96 --batch 8 --pool-size 24 \
  --arrival poisson --rate-qps 400 --workers 2 --coalesce \
  --algorithm auto --no-recall \
  --trace-out "$tel_dir/trace.json" --metrics-out "$tel_dir/metrics.prom" \
  --audit-out "$tel_dir/audit.jsonl" --events-out "$tel_dir/events.jsonl"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.obs.validate "$tel_dir/trace.json"
rm -rf "$tel_dir"

echo "== OK =="

"""Train a small LM end-to-end with checkpointing + fault injection.

Reduced smollm-family config by default (single CPU container); the same
code path drives the full configs on a real mesh via launch/train.py.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.data.lm import LMDataConfig, lm_batch
from repro.models.transformer import TransformerConfig, loss_fn
from repro.train.loop import LoopConfig, make_train_step, run
from repro.train.optimizer import OptimizerConfig, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--simulate-failure", type=int, default=None)
    args = ap.parse_args()

    cfg = TransformerConfig(
        name="smollm-nano", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=384, vocab=2048, attn_chunk=64, tie_embeddings=True,
        compute_dtype=jnp.float32,
    )
    print(f"model: {cfg.n_params()/1e6:.2f}M params")
    opt = OptimizerConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    dc = LMDataConfig(vocab=cfg.vocab, seq_len=args.seq_len, global_batch=args.batch)
    step_fn = make_train_step(lambda p, b: loss_fn(cfg, p, b), opt)

    def init_state():
        p = cfg.init(jax.random.key(0))
        return p, init_opt_state(opt, p)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        loop = LoopConfig(
            total_steps=args.steps, ckpt_every=50, ckpt_dir=ckpt_dir,
            log_every=max(args.steps // 20, 1),
            simulate_failure_at=args.simulate_failure,
        )
        _, _, hist = run(loop, step_fn, init_state, lambda s: lm_batch(dc, s))
    first, last = hist[0][1], hist[-1][1]
    print(f"\nloss {first:.3f} -> {last:.3f} ({'OK: learning' if last < first else 'WARN'})")


if __name__ == "__main__":
    main()

"""Quickstart: build a small geo search engine and run queries.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import GeoSearchEngine, QueryBatch, QueryBudgets
from repro.corpus import make_corpus, make_query_trace
import jax.numpy as jnp


def main():
    # 1. a synthetic "national crawl": 2000 docs, 400-term vocabulary,
    #    footprints around power-law cities
    corpus = make_corpus(n_docs=2000, n_terms=400, seed=0)

    # 2. build the engine: inverted index + Morton toe-print store +
    #    1024-tile grid (paper §IV)
    engine = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=32,
        budgets=QueryBudgets(
            max_candidates=2048, max_tiles=1024, k_sweeps=4,
            sweep_budget=1024, top_k=5,
        ),
    )

    # 3. a hand-written query: two terms taken from a real document, with a
    #    footprint around that document's own area ("yoga Tambaram")
    doc_id = 17
    t = sorted(set(int(x) for x in corpus.doc_terms[doc_id]))[:2]
    dr = corpus.doc_rects[doc_id, 0]
    cx, cy = (dr[0] + dr[2]) / 2, (dr[1] + dr[3]) / 2
    w = 0.08
    query = QueryBatch(
        terms=jnp.array([[t[0], t[1] if len(t) > 1 else -1, -1, -1]], jnp.int32),
        rects=jnp.array([[[cx - w, cy - w, cx + w, cy + w],
                          [1.0, 1.0, 0.0, 0.0]]], jnp.float32),
        amps=jnp.array([[1.0, 0.0]], jnp.float32),
    )
    for algo in ["text_first", "geo_first", "k_sweep"]:
        res = engine.query(query, algo)
        ids = np.asarray(res.ids)[0]
        scores = np.asarray(res.scores)[0]
        hits = [(int(i), round(float(s), 4)) for i, s in zip(ids, scores) if i >= 0]
        print(f"{algo:12s} top-5: {hits}")

    # 4. a realistic trace + recall vs the exact oracle
    trace = make_query_trace(corpus, n_queries=32, seed=1)
    for algo in ["text_first", "geo_first", "k_sweep"]:
        print(f"{algo:12s} recall@5 vs oracle: {engine.recall_at_k(trace, algo):.3f}")


if __name__ == "__main__":
    main()

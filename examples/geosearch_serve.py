"""END-TO-END DRIVER (the paper is a serving paper): build a corpus, then
serve batched geo-query traffic through all three algorithms, reporting
QPS, latency, recall and the per-stage I/O counters the paper optimizes —
including the paper's own Table-1 style comparison under the 2010 disk cost
model and the TPU-HBM cost model.

    PYTHONPATH=src python examples/geosearch_serve.py [--n-docs 20000]
"""
import argparse
import time

import jax
import numpy as np

from repro.core import GeoSearchEngine, QueryBudgets
from repro.corpus import make_corpus, make_query_trace

SEEK_S, DISK_BW = 8e-3, 100e6
HBM_BW, EFF_SEQ, EFF_RAND = 819e9, 0.9, 0.15


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=20000)
    ap.add_argument("--n-queries", type=int, default=512)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--use-pallas", action="store_true")
    args = ap.parse_args()

    print(f"[build] corpus: {args.n_docs} docs …")
    t0 = time.perf_counter()
    corpus = make_corpus(args.n_docs, 2000, seed=0)
    budgets = QueryBudgets(
        max_candidates=4096, max_tiles=2048, k_sweeps=8,
        sweep_budget=max(args.n_docs // 3, 512), top_k=10,
    )
    eng = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=64, budgets=budgets,
    )
    print(f"[build] done in {time.perf_counter()-t0:.1f}s "
          f"({eng.index.spatial.n_toeprints} toe prints, "
          f"{eng.index.text.n_postings} postings)")

    trace = make_query_trace(corpus, n_queries=args.n_queries, seed=1)
    kw = {}
    if args.use_pallas:
        from repro.kernels.geo_score.ops import geo_score_toeprints
        kw["tp_scorer"] = geo_score_toeprints

    print(f"\n{'algorithm':12s} {'QPS':>8s} {'ms/q':>7s} {'recall':>7s} "
          f"{'t_disk2010':>11s} {'t_hbm_v5e':>10s}")
    for algo in ["text_first", "geo_first", "k_sweep"]:
        akw = kw if algo == "k_sweep" else {}
        nb = args.n_queries // args.batch
        sub0 = jax.tree.map(lambda x: x[: args.batch], trace)
        eng.query(sub0, algo, **akw)  # warm/compile
        t0 = time.perf_counter()
        seeks = b_seq = b_rand = 0.0
        for i in range(nb):
            sub = jax.tree.map(
                lambda x: x[i * args.batch : (i + 1) * args.batch], trace
            )
            res = eng.query(sub, algo, **akw)
            seeks += float(np.asarray(res.stats["seeks"]).sum())
            b_seq += float(np.asarray(res.stats["bytes_seq"]).sum())
            b_rand += float(np.asarray(res.stats["bytes_random"]).sum())
        jax.block_until_ready(res.scores)
        dt = time.perf_counter() - t0
        n = nb * args.batch
        t_disk = (seeks * SEEK_S + (b_seq + b_rand) / DISK_BW) / n
        t_hbm = (b_seq / (HBM_BW * EFF_SEQ) + b_rand / (HBM_BW * EFF_RAND)) / n
        rec = eng.recall_at_k(sub0, algo)
        print(f"{algo:12s} {n/dt:8.1f} {dt/n*1e3:7.3f} {rec:7.3f} "
              f"{t_disk*1e3:9.1f}ms {t_hbm*1e6:8.2f}us")

    print("\npaper Table 1 reference: old 0.65 s -> proposed 0.34 s (1.91x)")


if __name__ == "__main__":
    main()

"""Geo-constrained two-tower retrieval — the paper's ranking function with a
learned text score (DESIGN.md §6): train a small two-tower model with
in-batch sampled softmax, then score a candidate corpus with
dot-product + geo_score (Pallas kernel) and compare plain vs
geo-constrained top-k.

    PYTHONPATH=src python examples/recsys_retrieval.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.recsys import two_tower_batch
from repro.models.recsys import (
    TwoTowerConfig, two_tower_loss, two_tower_score_candidates,
)
from repro.train.loop import make_train_step
from repro.train.optimizer import OptimizerConfig, init_opt_state


def main():
    cfg = TwoTowerConfig(
        name="two-tower-mini", embed_dim=32, tower_dims=(128, 64),
        n_users=5000, n_items=2000, n_user_fields=2, n_item_fields=2,
        field_vocab=200, hist_len=8, feat_dim=16,
    )
    params = cfg.init(jax.random.key(0))
    opt = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    step = make_train_step(lambda p, b: two_tower_loss(cfg, p, b), opt)
    state = init_opt_state(opt, params)
    print("training two-tower with in-batch sampled softmax …")
    for s in range(100):
        batch = two_tower_batch(64, cfg.n_users, cfg.n_items, cfg.n_user_fields,
                                cfg.n_item_fields, cfg.field_vocab, cfg.hist_len,
                                seed=0, step=s)
        params, state, m = step(params, state, batch)
        if s % 25 == 0:
            print(f"  step {s:3d} loss {float(m['loss']):.4f}")

    # candidate corpus with geographic footprints
    rng = np.random.default_rng(1)
    Nc = 1024
    cand_ids = jnp.arange(Nc, dtype=jnp.int32) % cfg.n_items
    cand_fields = jnp.asarray(rng.integers(0, cfg.field_vocab, (Nc, 2)), jnp.int32)
    lo = rng.uniform(0, 0.9, (Nc, 1, 2)).astype(np.float32)
    cand_rects = jnp.asarray(np.concatenate([lo, lo + 0.08], axis=2))
    cand_amps = jnp.ones((Nc, 1))

    user = two_tower_batch(1, cfg.n_users, cfg.n_items, cfg.n_user_fields,
                           cfg.n_item_fields, cfg.field_vocab, cfg.hist_len,
                           seed=9, step=0)
    plain_s, plain_i = two_tower_score_candidates(
        cfg, params, user, cand_ids, cand_fields, top_k=10
    )
    geo = {
        "cand_rects": cand_rects, "cand_amps": cand_amps,
        "q_rects": jnp.asarray([[0.3, 0.3, 0.5, 0.5]], dtype=jnp.float32),
        "q_amps": jnp.ones((1,)), "weight": 5.0,
    }
    geo_s, geo_i = two_tower_score_candidates(
        cfg, params, user, cand_ids, cand_fields, top_k=10, geo=geo
    )
    print("\nplain top-10 candidates:   ", list(np.asarray(plain_i)[0]))
    print("geo-constrained top-10:    ", list(np.asarray(geo_i)[0]))
    inside = [
        int(i) for i in np.asarray(geo_i)[0]
        if float(cand_rects[i, 0, 0]) < 0.5 and float(cand_rects[i, 0, 2]) > 0.3
        and float(cand_rects[i, 0, 1]) < 0.5 and float(cand_rects[i, 0, 3]) > 0.3
    ]
    print(f"geo-constrained results overlapping query area: {len(inside)}/10")


if __name__ == "__main__":
    main()

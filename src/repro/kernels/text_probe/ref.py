"""Pure-jnp oracle for the fused text_probe kernel.

``text_probe_pruned_ref`` mirrors ``ops.text_probe_pruned`` operation for
operation — same window bounds, same one-θ-per-tile skip rule, same cyclic
partial top-C buffer, same astype-then-affine decode of the stored impact
plane — so the skip *decisions* agree with the Pallas kernel exactly, not
just approximately.  It is both the kernel's test oracle and the traversal
behind ``text_first(prune=True, fused=False)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(
    jax.jit, static_argnames=("max_candidates", "max_term_blocks", "monotone")
)
def text_probe_pruned_ref(
    imp_plane: jax.Array,  # [NB, LANES] stored-dtype plane (impact_planes)
    blk_max_impact: jax.Array,  # f32[NB]
    blk_len: jax.Array,  # i32[NB]
    b0: jax.Array,  # i32 scalar: driver term's first block
    nb: jax.Array,  # i32 scalar: driver term's block count
    w_text: jax.Array,  # f32 scalar
    rest_ub: jax.Array,  # f32 scalar
    floor: jax.Array | float = 0.0,
    max_candidates: int = 1024,
    max_term_blocks: int = 1,
    monotone: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Block-max pruned text-probe oracle; same contract as
    ``ops.text_probe_pruned`` (opt, valid, streamed, blocks_scored,
    blocks_active).  ``monotone=True`` carries the kernel's early-exit cut
    flag through the scan — same per-tile cut semantics (the flag set by
    tile t masks tiles > t; within a tile a failing bound implies every
    later bound fails too, since bounds are non-increasing), so skip
    decisions stay bit-identical to the kernel."""
    from repro.kernels.text_probe.kernel import (
        BLOCK_ROWS,
        LANES,
        TILE,
        slot_theta,
    )
    from repro.kernels.text_probe.ops import window_size, window_term_bounds

    n_win = window_size(max_term_blocks)
    n_tiles = n_win // BLOCK_ROWS
    cb = max(1, -(-max_candidates // TILE))
    c_sel = max(1, min(max_candidates, n_win * LANES))

    ub, lens, active = window_term_bounds(
        blk_max_impact, blk_len, b0, nb, w_text, rest_ub, n_win
    )
    floor_c = jnp.maximum(jnp.asarray(floor, jnp.float32).reshape(()), 0.0)

    # all window optimistic scores on the kernel's block lattice, kernel
    # decode order (stored dtype → astype f32 → × w_text + rest_ub)
    NB = imp_plane.shape[0]
    bid = jnp.clip(b0 + jnp.arange(n_win, dtype=jnp.int32), 0, NB - 1)
    opt_all = (
        imp_plane[bid].astype(jnp.float32)
        * jnp.asarray(w_text, jnp.float32)
        + jnp.asarray(rest_ub, jnp.float32)
    )  # [n_win, LANES]
    lane_ok = jnp.arange(LANES, dtype=jnp.int32)[None, :] < lens[:, None]

    # sequential tile walk: one θ per tile (all BLOCK_ROWS decisions of a
    # tile see the θ from before any of the tile's folds — matching the
    # kernel, which reads min(buf) once per grid step), cyclic fold after
    flat_ub = ub.reshape(n_tiles, BLOCK_ROWS)
    flat_opt = opt_all.reshape(n_tiles, BLOCK_ROWS, LANES)
    flat_ok = lane_ok.reshape(n_tiles, BLOCK_ROWS, LANES)
    slots = jnp.arange(n_tiles, dtype=jnp.int32) % cb

    def step(carry, xs):
        buf, cut = carry
        ub_t, opt_t, ok_t, slot = xs
        # same C-th-largest-slot θ read as the kernel (slot_theta)
        theta = slot_theta(buf, floor_c, c_sel)
        raw = ub_t > theta  # [BLOCK_ROWS]
        scored = raw & jnp.logical_not(cut) if monotone else raw
        sc = jnp.where(scored[:, None] & ok_t, opt_t, 0.0)
        buf = buf.at[slot].set(jnp.maximum(buf[slot], sc))
        if monotone:
            cut = cut | jnp.any(jnp.logical_not(raw))
        return (buf, cut), (scored, sc)

    _, (scored, sc) = jax.lax.scan(
        step,
        (
            jnp.full((cb, BLOCK_ROWS, LANES), floor_c, jnp.float32),
            jnp.zeros((), bool),
        ),
        (flat_ub, flat_opt, flat_ok, slots),
    )
    scored_blk = scored.reshape(n_win)
    valid = active[:, None] & lane_ok
    streamed = jnp.repeat(scored_blk, LANES)
    blocks_scored = jnp.sum((scored_blk & active).astype(jnp.int32))
    blocks_active = jnp.sum(active.astype(jnp.int32))
    return (
        sc.reshape(n_win * LANES),
        valid.reshape(n_win * LANES),
        streamed,
        blocks_scored,
        blocks_active,
    )

"""jit'd wrapper for the fused text_probe kernel.

Handles: the block-major impact plane (one planar row per 128-posting
block, query-independent — built once per index and closed over by the
vmapped query fn), the per-window upper bounds / lengths that drive the
in-kernel skip test, and the re-flattening of the kernel's tile outputs
into the per-position (opt, valid, streamed) contract that
``core/algorithms.text_first`` consumes.  The bound/length prologue is
shared with ``ref.py`` so the skip decisions stay bit-identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.text_probe.kernel import (
    BLOCK_ROWS,
    LANES,
    TILE,
    text_probe_pruned_planar,
)

# plain int (not a jnp scalar): this module is imported lazily from inside
# jit-traced code, and creating a jax array at import time would leak a tracer
INVALID = 2**31 - 1


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def window_size(max_term_blocks: int) -> int:
    """Static window-block count: max blocks of any term, whole tiles."""
    mtb = max(max_term_blocks, 1)
    return -(-mtb // BLOCK_ROWS) * BLOCK_ROWS


def impact_planes(
    impacts: jax.Array,  # [P] stored dtype (f32 or f16)
    blk_pos: jax.Array,  # i32[NB]
    blk_len: jax.Array,  # i32[NB]
) -> jax.Array:
    """Block-major impact plane [NB, LANES] in the STORED dtype.

    Row b holds block b's impacts (``impacts[blk_pos[b] : +blk_len[b]]``)
    zero-padded past ``blk_len`` — query-independent, so callers hoist it
    out of the per-query vmap.  The kernel streams these stored bytes and
    decodes in-register (astype f32, then the optimistic affine).
    """
    NB = blk_pos.shape[0]
    P = impacts.shape[0]
    if P == 0:
        return jnp.zeros((NB, LANES), impacts.dtype)
    j = jnp.arange(LANES, dtype=jnp.int32)
    ap = jnp.clip(blk_pos[:, None] + j[None, :], 0, P - 1)
    v = impacts[ap]
    return jnp.where(j[None, :] < blk_len[:, None], v, jnp.zeros((), v.dtype))


def window_term_bounds(
    blk_max_impact: jax.Array,  # f32[NB]
    blk_len: jax.Array,  # i32[NB]
    b0: jax.Array,  # i32 scalar: driver term's first block
    nb: jax.Array,  # i32 scalar: driver term's block count
    w_text: jax.Array,  # f32 scalar
    rest_ub: jax.Array,  # f32 scalar (≥ 0)
    n_win: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Shared prologue (used by ops AND ref so skip decisions stay
    bit-identical): per-window-block upper bounds ``w_text·blk_max + rest``
    (-inf past the driver's ``nb`` blocks, so they can never beat θ ≥ 0
    and move zero bytes), valid lengths, and the active-block mask —
    what an *unpruned* traversal would stream, the baseline for the
    skipped-block counters."""
    NB = blk_max_impact.shape[0]
    w = jnp.arange(n_win, dtype=jnp.int32)
    active = w < nb
    bid = jnp.clip(b0 + w, 0, NB - 1)
    ub = jnp.where(
        active,
        w_text * blk_max_impact[bid] + rest_ub,
        -jnp.inf,
    )
    lens = jnp.where(active, blk_len[bid], 0)
    return ub, lens.astype(jnp.int32), active


@functools.partial(
    jax.jit,
    static_argnames=("max_candidates", "max_term_blocks", "interpret", "monotone"),
)
def text_probe_pruned(
    imp_plane: jax.Array,  # [NB, LANES] stored-dtype plane (impact_planes)
    blk_max_impact: jax.Array,  # f32[NB]
    blk_len: jax.Array,  # i32[NB]
    b0: jax.Array,  # i32 scalar: driver term's first block
    nb: jax.Array,  # i32 scalar: driver term's block count
    w_text: jax.Array,  # f32 scalar
    rest_ub: jax.Array,  # f32 scalar: query-constant remainder bound
    floor: jax.Array | float = 0.0,  # select-stage score floor (scalar)
    max_candidates: int = 1024,  # C of the partial top-C threshold buffer
    max_term_blocks: int = 1,  # static window bound (TextIndex field)
    interpret: bool | None = None,
    monotone: bool = False,  # non-increasing bounds → early-exit cut
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused probe+score+select over the driver term's posting blocks.

    Returns ``(opt f32[n_win*LANES], valid bool[n_win*LANES], streamed
    bool[n_win*LANES], blocks_scored i32, blocks_active i32)``: ``opt`` is
    each streamed posting's optimistic score (0 where skipped/invalid),
    ``valid`` marks genuine driver postings, ``streamed`` positions whose
    block was actually fetched (candidates are ``valid & streamed`` — on
    hardware the per-block DMA is simply not issued for skipped blocks),
    and the block counters feed ``text_blocks_skipped`` stats.

    ``monotone=True`` asserts the driver's bounds are non-increasing along
    its block run (layout="impact"'s suffix-max envelope): the kernel then
    early-exits the term at the first failing bound (see kernel docstring).
    """
    if interpret is None:
        interpret = _default_interpret()
    n_win = window_size(max_term_blocks)
    ub, lens, active = window_term_bounds(
        blk_max_impact, blk_len, b0, nb, w_text, rest_ub, n_win
    )
    floor_c = jnp.maximum(jnp.asarray(floor, jnp.float32), 0.0)
    wb = jnp.stack(
        [
            jnp.asarray(w_text, jnp.float32),
            jnp.asarray(rest_ub, jnp.float32),
        ]
    )
    opt, scored = text_probe_pruned_planar(
        jnp.asarray(b0, jnp.int32).reshape(1),
        ub,
        lens,
        wb,
        floor_c.reshape(1),
        imp_plane,
        n_win=n_win,
        max_candidates=max_candidates,
        interpret=interpret,
        monotone=monotone,
    )
    scored_blk = scored.reshape(n_win) > 0
    lane_ok = (
        jnp.arange(LANES, dtype=jnp.int32)[None, :] < lens[:, None]
    )  # [n_win, LANES]
    valid = active[:, None] & lane_ok
    streamed = jnp.repeat(scored_blk, LANES)
    blocks_scored = jnp.sum((scored_blk & active).astype(jnp.int32))
    blocks_active = jnp.sum(active.astype(jnp.int32))
    return (
        opt.reshape(n_win * LANES),
        valid.reshape(n_win * LANES),
        streamed,
        blocks_scored,
        blocks_active,
    )


__all__ = [
    "BLOCK_ROWS",
    "LANES",
    "TILE",
    "INVALID",
    "impact_planes",
    "text_probe_pruned",
    "window_size",
    "window_term_bounds",
]

from repro.kernels.text_probe.ops import (  # noqa: F401
    impact_planes,
    text_probe_pruned,
)

"""Pallas TPU kernel: block-max pruned text probe (probe → score → select).

The text-side twin of ``kernels/sweep_score``'s pruned sweep.  TEXT-FIRST
walks the driver term's posting list; unpruned it streams every posting.
This kernel walks the driver's 128-posting *blocks* and tests each block's
precomputed score upper bound

    ub[b] = w_text · blk_max_impact[b] + rest_ub

(``rest_ub`` = the query-constant bound on everything a posting's final
score can gain beyond its own impact: the other query terms' max impacts,
the geo contribution, and pagerank) against a running threshold θ.  Blocks
that cannot beat θ are *skipped before their bytes move*: the impact plane
stays in ``ANY`` memory space and the kernel issues one manual
``make_async_copy`` per surviving block under ``pl.when``, so a skipped
block truly streams zero bytes — the same DMA-elision discipline as the
spatial pruned sweep.

θ approximates the partial top-``max_candidates`` optimistic score: a
persistent VMEM scratch buffer of ``cb·TILE ≥ max_candidates`` slots, each
holding the max over a disjoint cyclically-assigned subset of the streamed
candidates (seeded with the select floor).  The θ read (``slot_theta``)
takes the C-th largest slot value — attained by C distinct candidates,
so provably ≤ the true C-th largest streamed optimistic score: a skipped
block can never contain a candidate the top-C select stage would keep.

One planar row = one posting block (LANES = 128 postings), so the DMA
unit is a single ``[1, 128]`` row and no tile alignment of the driver's
first block is needed.  Grid = (n_win // BLOCK_ROWS,) walked sequentially;
under ``vmap`` the batch axis becomes the outer grid dimension and the
``j == 0`` re-init gives every query a fresh θ.

``monotone=True`` (the impact-ordered layout, whose ``blk_max_impact`` is
a per-term suffix-max envelope — non-increasing along the block run)
additionally keeps an early-exit *cut flag* in SMEM across grid steps:
the first block whose bound fails θ proves every later block fails too
(θ only ever rises), so the rest of the term is cut without testing —
and, as always, a skipped block issues no DMA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128  # postings per block = one planar row
BLOCK_ROWS = 8  # blocks fetched per grid step
TILE = BLOCK_ROWS * LANES


def slot_theta(bv, floor, c_sel: int):
    """θ = the C-th largest slot value of the partial top-C buffer.

    Each slot holds the max over a disjoint subset of the streamed
    candidates (or its floor seed, if no candidate ever folded there).
    The top-C slot values are attained by C *distinct* candidates (one
    per slot; a floor seed among them collapses θ to the floor, which is
    always sound), so the C-th largest slot value can never exceed the
    C-th largest streamed optimistic score: a block skipped against it
    cannot contain a candidate the top-C select stage would keep.

    This is the tightest sound threshold the slot lattice offers.  A
    plain ``min(buffer)`` (the previous rule) is badly loose at both
    ends: slots no candidate ever reaches (lanes past a ragged block's
    length, rows past a short driver's block count) pin the min at the
    floor forever, while for C ≪ 1024 streamed-heavy buffers approximate
    the stream *minimum* rather than the C-th best.  Shared by the
    kernel and ``ref.py`` so skip decisions stay bit-identical.
    """
    vals = jax.lax.top_k(bv.reshape(-1), c_sel)[0]
    return jnp.maximum(vals[c_sel - 1], floor)


def _pruned_kernel(
    start_ref,  # scalar prefetch: i32[1] driver's first block (plane row)
    ub_ref,  # SMEM f32[n_win] per-window-block optimistic upper bounds
    len_ref,  # SMEM i32[n_win] valid postings per window block
    wb_ref,  # SMEM f32[2]: (w_text, rest_ub) — the optimistic-score affine
    floor_ref,  # SMEM f32[1]: select-stage score floor
    imp_hbm,  # ANY-space impact plane [rows, LANES] (stored dtype)
    out_ref,  # VMEM f32[BLOCK_ROWS, LANES] tile of optimistic scores
    scored_ref,  # SMEM i32[1, BLOCK_ROWS] per-block scored flags
    buf_ref,  # VMEM scratch f32[cb*BLOCK_ROWS, LANES]: partial top-C heap
    imp_s,  # VMEM scratch [BLOCK_ROWS, LANES] stored dtype: fetched rows
    copy_sem,  # DMA semaphore for the per-block copies
    cut_ref,  # SMEM scratch i32[1]: early-exit cut flag (monotone only)
    *,
    cb: int,
    c_sel: int,
    monotone: bool,
):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        # seed every slot with the selection floor: θ never drops below it,
        # so blocks whose bound cannot clear the floor are skipped — their
        # candidates would be dropped by the select stage regardless
        buf_ref[...] = jnp.full_like(buf_ref, floor_ref[0])
        cut_ref[0] = jnp.int32(0)

    theta = slot_theta(buf_ref[...], floor_ref[0], c_sel)
    # under a monotone (non-increasing) bound run the first failing block
    # proves every later block fails too (θ only ever rises): once the cut
    # flag is set, the whole remainder of the term is skipped without even
    # testing its bounds — zero DMA after the cut
    cut = cut_ref[0] > 0 if monotone else False
    rows = jax.lax.broadcasted_iota(jnp.int32, (BLOCK_ROWS, LANES), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (BLOCK_ROWS, LANES), 1)
    mask = jnp.zeros((BLOCK_ROWS, LANES), dtype=bool)
    any_scored = False
    any_fail = False
    for b in range(BLOCK_ROWS):  # static unroll over the tile's blocks
        w = j * BLOCK_ROWS + b
        sb = ub_ref[w] > theta  # -inf beyond the driver's blocks
        any_fail = jnp.logical_not(sb) | any_fail
        if monotone:
            sb = sb & jnp.logical_not(cut)
        scored_ref[0, b] = sb.astype(jnp.int32)
        mask = mask | (sb & (rows == b) & (cols < len_ref[w]))
        any_scored = sb | any_scored

        # a θ-skipped block issues NO copy: zero bytes move for it.  Its
        # scratch row keeps stale data, which is safe — everything below
        # selects through ``mask``, so garbage cannot propagate.
        @pl.when(sb)
        def _fetch(b=b, w=w):
            cp = pltpu.make_async_copy(
                imp_hbm.at[pl.ds(start_ref[0] + w, 1), :],
                imp_s.at[pl.ds(b, 1), :],
                copy_sem,
            )
            cp.start()
            cp.wait()

    @pl.when(any_scored)
    def _score():
        # in-register decode of the stored dtype, then the optimistic
        # affine: every posting's best possible final score
        opt = imp_s[...].astype(jnp.float32) * wb_ref[0] + wb_ref[1]
        sc = jnp.where(mask, opt, 0.0)
        out_ref[...] = sc
        # cyclic top-C approximation: fold this tile into its buffer slice
        r0 = (j % cb) * BLOCK_ROWS
        sl = buf_ref[pl.ds(r0, BLOCK_ROWS), :]
        buf_ref[pl.ds(r0, BLOCK_ROWS), :] = jnp.maximum(sl, sc)

    @pl.when(jnp.logical_not(any_scored))
    def _skip():
        out_ref[...] = jnp.zeros_like(out_ref)

    if monotone:
        cut_ref[0] = jnp.where(any_fail | cut, 1, 0).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("n_win", "max_candidates", "interpret", "monotone")
)
def text_probe_pruned_planar(
    start: jax.Array,  # i32[1] driver's first block (plane row)
    ub: jax.Array,  # f32[n_win] per-window-block bounds (-inf padded)
    lens: jax.Array,  # i32[n_win] valid postings per window block
    wb: jax.Array,  # f32[2]: (w_text, rest_ub)
    floor: jax.Array,  # f32[1] select-stage score floor
    imp_plane: jax.Array,  # [rows, LANES] impact plane in its stored dtype
    n_win: int,  # window blocks; multiple of BLOCK_ROWS
    max_candidates: int,  # C of the partial top-C threshold buffer
    interpret: bool = True,
    monotone: bool = False,  # bounds non-increasing → early-exit cut flag
) -> tuple[jax.Array, jax.Array]:
    """Pruned driver-block walk: (opt f32[n_tiles, BLOCK_ROWS, LANES],
    scored i32[n_tiles, BLOCK_ROWS] per-block flags)."""
    assert n_win % BLOCK_ROWS == 0
    n_tiles = n_win // BLOCK_ROWS
    # C rounded up to whole tiles: θ is the c_sel-th largest slot value
    # of the buffer, and each slot max is attained by a distinct
    # candidate, so any buffer ≥ C slots yields a sound (under-) estimate
    cb = max(1, -(-max_candidates // TILE))
    # the select stage can keep at most the whole window; the θ read
    # must use the same effective C
    c_sel = max(1, min(max_candidates, n_win * LANES))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((n_win,), lambda j, s: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((n_win,), lambda j, s: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((2,), lambda j, s: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda j, s: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),  # impact plane
        ],
        out_specs=[
            pl.BlockSpec((1, BLOCK_ROWS, LANES), lambda j, s: (j, 0, 0)),
            pl.BlockSpec(
                (1, BLOCK_ROWS), lambda j, s: (j, 0), memory_space=pltpu.SMEM
            ),
        ],
        scratch_shapes=[
            pltpu.VMEM((cb * BLOCK_ROWS, LANES), jnp.float32),
            pltpu.VMEM((BLOCK_ROWS, LANES), imp_plane.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SMEM((1,), jnp.int32),
        ],
    )
    kernel = functools.partial(
        _pruned_kernel, cb=cb, c_sel=c_sel, monotone=monotone
    )
    opt, scored = pl.pallas_call(
        lambda s_ref, ub_r, ln_r, wb_r, fl_r, plane, o, f, buf, sc_, sem, cut: kernel(
            s_ref, ub_r, ln_r, wb_r, fl_r, plane, o.at[0], f, buf, sc_, sem, cut
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, BLOCK_ROWS, LANES), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles, BLOCK_ROWS), jnp.int32),
        ],
        interpret=interpret,
    )(start, ub, lens, wb, floor, imp_plane)
    return opt, scored

"""Pallas TPU kernel: block-max pruned text probe (probe → score → select).

The text-side twin of ``kernels/sweep_score``'s pruned sweep.  TEXT-FIRST
walks the driver term's posting list; unpruned it streams every posting.
This kernel walks the driver's 128-posting *blocks* and tests each block's
precomputed score upper bound

    ub[b] = w_text · blk_max_impact[b] + rest_ub

(``rest_ub`` = the query-constant bound on everything a posting's final
score can gain beyond its own impact: the other query terms' max impacts,
the geo contribution, and pagerank) against a running threshold θ.  Blocks
that cannot beat θ are *skipped before their bytes move*: the impact plane
stays in ``ANY`` memory space and the kernel issues one manual
``make_async_copy`` per surviving block under ``pl.when``, so a skipped
block truly streams zero bytes — the same DMA-elision discipline as the
spatial pruned sweep.

θ approximates the partial top-``max_candidates`` optimistic score: a
persistent VMEM scratch buffer of ``cb·TILE ≥ max_candidates`` slots, each
holding the max over a disjoint cyclically-assigned subset of the streamed
candidates (seeded with the select floor), with θ = min(buffer).  min over
disjoint-subset maxima never exceeds the true C-th largest optimistic
score, so a skipped block cannot contain a candidate the top-C select
stage would keep (above the floor).

One planar row = one posting block (LANES = 128 postings), so the DMA
unit is a single ``[1, 128]`` row and no tile alignment of the driver's
first block is needed.  Grid = (n_win // BLOCK_ROWS,) walked sequentially;
under ``vmap`` the batch axis becomes the outer grid dimension and the
``j == 0`` re-init gives every query a fresh θ.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128  # postings per block = one planar row
BLOCK_ROWS = 8  # blocks fetched per grid step
TILE = BLOCK_ROWS * LANES


def _pruned_kernel(
    start_ref,  # scalar prefetch: i32[1] driver's first block (plane row)
    ub_ref,  # SMEM f32[n_win] per-window-block optimistic upper bounds
    len_ref,  # SMEM i32[n_win] valid postings per window block
    wb_ref,  # SMEM f32[2]: (w_text, rest_ub) — the optimistic-score affine
    floor_ref,  # SMEM f32[1]: select-stage score floor
    imp_hbm,  # ANY-space impact plane [rows, LANES] (stored dtype)
    out_ref,  # VMEM f32[BLOCK_ROWS, LANES] tile of optimistic scores
    scored_ref,  # SMEM i32[1, BLOCK_ROWS] per-block scored flags
    buf_ref,  # VMEM scratch f32[cb*BLOCK_ROWS, LANES]: partial top-C heap
    imp_s,  # VMEM scratch [BLOCK_ROWS, LANES] stored dtype: fetched rows
    copy_sem,  # DMA semaphore for the per-block copies
    *,
    cb: int,
):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        # seed every slot with the selection floor: θ never drops below it,
        # so blocks whose bound cannot clear the floor are skipped — their
        # candidates would be dropped by the select stage regardless
        buf_ref[...] = jnp.full_like(buf_ref, floor_ref[0])

    theta = jnp.min(buf_ref[...])
    rows = jax.lax.broadcasted_iota(jnp.int32, (BLOCK_ROWS, LANES), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (BLOCK_ROWS, LANES), 1)
    mask = jnp.zeros((BLOCK_ROWS, LANES), dtype=bool)
    any_scored = False
    for b in range(BLOCK_ROWS):  # static unroll over the tile's blocks
        w = j * BLOCK_ROWS + b
        sb = ub_ref[w] > theta  # -inf beyond the driver's blocks
        scored_ref[0, b] = sb.astype(jnp.int32)
        mask = mask | (sb & (rows == b) & (cols < len_ref[w]))
        any_scored = sb | any_scored

        # a θ-skipped block issues NO copy: zero bytes move for it.  Its
        # scratch row keeps stale data, which is safe — everything below
        # selects through ``mask``, so garbage cannot propagate.
        @pl.when(sb)
        def _fetch(b=b, w=w):
            cp = pltpu.make_async_copy(
                imp_hbm.at[pl.ds(start_ref[0] + w, 1), :],
                imp_s.at[pl.ds(b, 1), :],
                copy_sem,
            )
            cp.start()
            cp.wait()

    @pl.when(any_scored)
    def _score():
        # in-register decode of the stored dtype, then the optimistic
        # affine: every posting's best possible final score
        opt = imp_s[...].astype(jnp.float32) * wb_ref[0] + wb_ref[1]
        sc = jnp.where(mask, opt, 0.0)
        out_ref[...] = sc
        # cyclic top-C approximation: fold this tile into its buffer slice
        r0 = (j % cb) * BLOCK_ROWS
        sl = buf_ref[pl.ds(r0, BLOCK_ROWS), :]
        buf_ref[pl.ds(r0, BLOCK_ROWS), :] = jnp.maximum(sl, sc)

    @pl.when(jnp.logical_not(any_scored))
    def _skip():
        out_ref[...] = jnp.zeros_like(out_ref)


@functools.partial(
    jax.jit, static_argnames=("n_win", "max_candidates", "interpret")
)
def text_probe_pruned_planar(
    start: jax.Array,  # i32[1] driver's first block (plane row)
    ub: jax.Array,  # f32[n_win] per-window-block bounds (-inf padded)
    lens: jax.Array,  # i32[n_win] valid postings per window block
    wb: jax.Array,  # f32[2]: (w_text, rest_ub)
    floor: jax.Array,  # f32[1] select-stage score floor
    imp_plane: jax.Array,  # [rows, LANES] impact plane in its stored dtype
    n_win: int,  # window blocks; multiple of BLOCK_ROWS
    max_candidates: int,  # C of the partial top-C threshold buffer
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Pruned driver-block walk: (opt f32[n_tiles, BLOCK_ROWS, LANES],
    scored i32[n_tiles, BLOCK_ROWS] per-block flags)."""
    assert n_win % BLOCK_ROWS == 0
    n_tiles = n_win // BLOCK_ROWS
    # C rounded up to whole tiles: a larger buffer only lowers θ (safer)
    cb = max(1, -(-max_candidates // TILE))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((n_win,), lambda j, s: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((n_win,), lambda j, s: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((2,), lambda j, s: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda j, s: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),  # impact plane
        ],
        out_specs=[
            pl.BlockSpec((1, BLOCK_ROWS, LANES), lambda j, s: (j, 0, 0)),
            pl.BlockSpec(
                (1, BLOCK_ROWS), lambda j, s: (j, 0), memory_space=pltpu.SMEM
            ),
        ],
        scratch_shapes=[
            pltpu.VMEM((cb * BLOCK_ROWS, LANES), jnp.float32),
            pltpu.VMEM((BLOCK_ROWS, LANES), imp_plane.dtype),
            pltpu.SemaphoreType.DMA,
        ],
    )
    kernel = functools.partial(_pruned_kernel, cb=cb)
    opt, scored = pl.pallas_call(
        lambda s_ref, ub_r, ln_r, wb_r, fl_r, plane, o, f, buf, sc_, sem: kernel(
            s_ref, ub_r, ln_r, wb_r, fl_r, plane, o.at[0], f, buf, sc_, sem
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, BLOCK_ROWS, LANES), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles, BLOCK_ROWS), jnp.int32),
        ],
        interpret=interpret,
    )(start, ub, lens, wb, floor, imp_plane)
    return opt, scored

"""Pure-jnp oracle for the bitmap_filter kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bitmap_and_popcount_ref(bitmaps: jax.Array) -> tuple[jax.Array, jax.Array]:
    """bitmaps u32[d, W] → (anded u32[W], counts i32[W])."""
    anded = bitmaps[0]
    for i in range(1, bitmaps.shape[0]):
        anded = anded & bitmaps[i]
    counts = jax.lax.population_count(anded).astype(jnp.int32)
    return anded, counts

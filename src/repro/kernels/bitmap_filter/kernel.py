"""Pallas TPU kernel: block-bitmap conjunction (AND + popcount).

The TPU-idiomatic replacement for DAAT list intersection on frequent terms
(DESIGN.md §2, beyond-paper feature 3): given the packed u32 bitmaps of the
d query terms over ``ceil(N/32)`` words, compute

    anded[w]  = AND_i bitmaps[i, w]           (documents containing ALL terms)
    counts[w] = popcount(anded[w])            (survivor count per word)

Layout: bitmaps arrive as u32[d, rows, 128] (ops.py pads/reshapes); the term
dimension d is small and static → unrolled; each grid step ANDs a
[BLOCK_ROWS, 128] tile per term and popcounts with the SWAR bit trick —
pure VPU integer ops, no MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 8


def _popcount_u32(v: jax.Array) -> jax.Array:
    """SWAR popcount on uint32 lanes."""
    v = v - ((v >> jnp.uint32(1)) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> jnp.uint32(2)) & jnp.uint32(0x33333333))
    v = (v + (v >> jnp.uint32(4))) & jnp.uint32(0x0F0F0F0F)
    return ((v * jnp.uint32(0x01010101)) >> jnp.uint32(24)).astype(jnp.int32)


def _make_kernel(d: int):
    def kernel(bm_ref, anded_ref, count_ref):
        acc = bm_ref[0]
        for i in range(1, d):  # static unroll over query terms
            acc = acc & bm_ref[i]
        anded_ref[...] = acc
        count_ref[...] = _popcount_u32(acc)

    return kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitmap_and_popcount_planar(
    bitmaps: jax.Array,  # u32[d, rows, 128]
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    d, rows, lanes = bitmaps.shape
    assert lanes == LANES and rows % BLOCK_ROWS == 0
    grid = (rows // BLOCK_ROWS,)
    out_plane = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _make_kernel(d),
        grid=grid,
        in_specs=[pl.BlockSpec((d, BLOCK_ROWS, LANES), lambda i: (0, i, 0))],
        out_specs=(out_plane, out_plane),
        out_shape=(
            jax.ShapeDtypeStruct((rows, LANES), jnp.uint32),
            jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        ),
        interpret=interpret,
    )(bitmaps)

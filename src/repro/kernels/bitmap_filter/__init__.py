from repro.kernels.bitmap_filter.ops import *  # noqa: F401,F403

"""jit'd public wrappers for the bitmap_filter Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bitmap_filter.kernel import (
    BLOCK_ROWS,
    LANES,
    bitmap_and_popcount_planar,
)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitmap_and_popcount(
    bitmaps: jax.Array,  # u32[d, W]
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """AND the d term bitmaps and popcount survivors. Returns (u32[W], i32[W])."""
    if interpret is None:
        interpret = _default_interpret()
    d, W = bitmaps.shape
    tile = BLOCK_ROWS * LANES
    Wp = (W + tile - 1) // tile * tile
    bm = jnp.pad(bitmaps, ((0, 0), (0, Wp - W)))
    bm = bm.reshape(d, Wp // LANES, LANES)
    anded, counts = bitmap_and_popcount_planar(bm, interpret=interpret)
    return anded.reshape(Wp)[:W], counts.reshape(Wp)[:W]


@functools.partial(jax.jit, static_argnames=("interpret",))
def conjunction_block_prefilter(
    term_bitmaps: jax.Array,  # u32[d, W] (gathered rows for the query terms)
    interpret: bool | None = None,
) -> jax.Array:
    """Survivor-document count of the conjunction (scalar i64)."""
    _, counts = bitmap_and_popcount(term_bitmaps, interpret=interpret)
    return counts.sum()

"""Pallas TPU kernels: fused k-sweep fetch + geo scoring (+ block-max prune).

The K-SWEEP hot path does two HBM passes in the reference implementation:
(1) ``dynamic_slice`` the toe-print store for each sweep, (2) score the
fetched toe prints against the query footprint.  ``sweep_score_planar``
FUSES them: the grid walks ``(sweep, block-within-sweep)`` and the input
BlockSpec index_map is driven by the **scalar-prefetched sweep starts** —
each grid step DMAs the next VMEM tile of the Morton-ordered store directly
from the sweep's dynamic offset and scores it in-register.  The fetched toe
prints never round-trip through HBM.

``sweep_score_pruned_planar`` extends the fused pipeline into
sweep → score → *select*: each VMEM tile is divided into its metadata
blocks (``core/spatial_index.py``; 128–1024 toe prints, i.e. whole lane
rows), and every block's precomputed upper bound (block MBR ∩ query ×
max amp) is tested against a running threshold θ — blocks that cannot
beat θ are masked out of scoring and flagged skipped, WAND-style adaptive
feedback.  θ is maintained in a persistent VMEM scratch buffer
approximating the partial top-``max_candidates`` heap: the buffer holds
``C`` slots, every tile folds its surviving masked scores elementwise-max
into a cyclically-assigned slice, and θ = min(buffer).  Each slot is then
the max of a disjoint subset of the candidate scores seen so far, so min
over the ``C`` slots never exceeds the true C-th largest candidate score —
pruning against it is *safe*: a skipped block cannot contain a top-C
candidate.  The buffer is *seeded* with the select stage's score floor
(``prune_eps`` × query mass), so blocks below the floor are skipped even
before C candidates have streamed — provably without changing the final
selection.  Per-block ``scored`` flags are emitted so the caller can
count skipped blocks and charge only the bytes actually streamed.

Layout mirrors kernels/geo_score: planar coordinate arrays with the lane
dimension along toe prints ([rows, 128] f32 tiles), query rects unrolled
from VMEM scalars.  Sweep starts are block-aligned by ops.py (rounded down
to the 1024-element tile); masking against the true [start, end) range
happens in ops.py for the unpruned kernel, and in-kernel (positions derived
from the prefetched starts) for the pruned one, whose θ updates must see
only genuine candidates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
BLOCK_ROWS = 8
TILE = BLOCK_ROWS * LANES  # toe prints per grid step
Q_MAX = 8


def _kernel(
    starts_ref, qr_ref, qa_ref, x0_ref, y0_ref, x1_ref, y1_ref, amp_ref, sc_ref,
    out_ref,
):
    # starts_ref is scalar-prefetch (used only by the index maps).  The
    # planes arrive in their STORED dtype (f32/f16 coords, f32/f16/int8
    # amps) and are decoded in-register: astype f32, then × the per-row
    # amp scale (all-ones for non-int8 stores — ×1.0 is bitwise exact).
    x0 = x0_ref[...].astype(jnp.float32)
    y0 = y0_ref[...].astype(jnp.float32)
    x1 = x1_ref[...].astype(jnp.float32)
    y1 = y1_ref[...].astype(jnp.float32)
    amp = amp_ref[...].astype(jnp.float32) * sc_ref[...]
    acc = jnp.zeros_like(x0)
    for j in range(Q_MAX):  # static unroll over query rects
        qx0 = qr_ref[j, 0]
        qy0 = qr_ref[j, 1]
        qx1 = qr_ref[j, 2]
        qy1 = qr_ref[j, 3]
        w = jnp.maximum(jnp.minimum(x1, qx1) - jnp.maximum(x0, qx0), 0.0)
        h = jnp.maximum(jnp.minimum(y1, qy1) - jnp.maximum(y0, qy0), 0.0)
        acc = acc + (w * h) * qa_ref[j]
    out_ref[...] = acc * amp


@functools.partial(jax.jit, static_argnames=("n_sweeps", "budget", "interpret"))
def sweep_score_planar(
    block_starts: jax.Array,  # i32[k] sweep starts in BLOCK units (rows/BLOCK_ROWS)
    q_rects: jax.Array,  # f32[Q_MAX, 4]
    q_amps: jax.Array,  # f32[Q_MAX]
    x0: jax.Array,  # [rows, 128] — the ENTIRE toe-print store, planar,
    y0: jax.Array,  # in its stored dtype (f32/f16 coords, f32/f16/int8 amps)
    x1: jax.Array,
    y1: jax.Array,
    amp: jax.Array,
    scale: jax.Array,  # f32[rows, 1] per-row amp scale (ones unless int8)
    n_sweeps: int,
    budget: int,  # toe prints fetched per sweep; multiple of TILE
    interpret: bool = True,
) -> jax.Array:
    """Returns per-sweep scores f32[k, budget // LANES, 128].

    grid = (k, budget/TILE); block (i, j) reads store rows
    ``block_starts[i] + j*BLOCK_ROWS`` — a streaming DMA from the sweep
    offset, fused with scoring.
    """
    assert budget % TILE == 0
    rows = x0.shape[0]
    n_blocks = budget // TILE

    def in_map(i, j, starts):
        # starts[i] is in BLOCK units (TILE-aligned rows / BLOCK_ROWS)
        return (starts[i] + j, 0)

    plane = pl.BlockSpec((BLOCK_ROWS, LANES), in_map)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_sweeps, n_blocks),
        in_specs=[
            pl.BlockSpec((Q_MAX, 4), lambda i, j, s: (0, 0)),
            pl.BlockSpec((Q_MAX,), lambda i, j, s: (0,)),
            plane, plane, plane, plane, plane,
            pl.BlockSpec((BLOCK_ROWS, 1), in_map),
        ],
        out_specs=pl.BlockSpec(
            (1, BLOCK_ROWS, LANES), lambda i, j, s: (i, j, 0)
        ),
    )
    out = pl.pallas_call(
        lambda s_ref, qr, qa, a, b, c, d, e, sc, o: _kernel(
            s_ref, qr, qa, a, b, c, d, e, sc, o.at[0]
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (n_sweeps, budget // LANES, LANES), jnp.float32
        ),
        interpret=interpret,
    )(block_starts, q_rects, q_amps, x0, y0, x1, y1, amp, scale)
    return out


def _pruned_kernel(
    starts_ref,  # scalar prefetch: i32[k] sweep starts in TILE units
    bounds_ref,  # SMEM i32[k, 2]: exact [start, end) element offsets
    floor_ref,  # SMEM f32[1]: select-stage score floor (prune_eps × mass)
    ub_ref,  # SMEM f32[k, n_tiles*bpt]: per-metadata-block upper bounds
    qr_ref,
    qa_ref,
    x0_hbm,  # ANY-space planar store (full arrays; copied per block below)
    y0_hbm,
    x1_hbm,
    y1_hbm,
    amp_hbm,
    sc_hbm,
    out_ref,  # VMEM f32[BLOCK_ROWS, LANES] tile of the score output
    scored_ref,  # SMEM i32[1, bpt] per-metadata-block scored flags
    buf_ref,  # VMEM scratch f32[cb*BLOCK_ROWS, LANES]: partial top-C heap
    x0_s,  # VMEM scratch [BLOCK_ROWS, LANES] in the store dtypes: the
    y0_s,  # manually-DMA'd tile (only scored blocks' rows are copied in)
    x1_s,
    y1_s,
    amp_s,
    sc_s,  # VMEM scratch f32[BLOCK_ROWS, 1]
    copy_sem,  # DMA semaphore for the per-block copies
    *,
    n_tiles: int,
    cb: int,
    bpt: int,  # metadata blocks per tile
):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        # seed every slot with the selection floor: θ never drops below it,
        # so blocks whose bound cannot clear the floor are skipped — their
        # candidates would be dropped by the select stage regardless
        buf_ref[...] = jnp.full_like(buf_ref, floor_ref[0])

    theta = jnp.min(buf_ref[...])
    rows_per_block = (BLOCK_ROWS + bpt - 1) // bpt  # bpt divides BLOCK_ROWS
    rows = jax.lax.broadcasted_iota(jnp.int32, (BLOCK_ROWS, LANES), 0)
    row0 = (starts_ref[i] + j) * BLOCK_ROWS  # planar row of this tile
    # per-row scored mask assembled from the bpt per-block decisions
    mask = jnp.zeros((BLOCK_ROWS, LANES), dtype=bool)
    any_scored = False
    for b in range(bpt):  # static unroll over the tile's metadata blocks
        sb = ub_ref[i, j * bpt + b] > theta
        scored_ref[0, b] = sb.astype(jnp.int32)
        mask = mask | (sb & (rows // rows_per_block == b))
        any_scored = sb | any_scored

        # a θ-skipped block issues NO copy: zero bytes move for it.  Its
        # scratch rows keep stale data from earlier tiles, which is safe —
        # every consumer below selects through ``mask`` (jnp.where), so
        # garbage (even NaN) in never-copied rows cannot propagate.
        @pl.when(sb)
        def _fetch(b=b):
            src_row = row0 + b * rows_per_block
            dst_row = b * rows_per_block
            for src, dst in (
                (x0_hbm, x0_s),
                (y0_hbm, y0_s),
                (x1_hbm, x1_s),
                (y1_hbm, y1_s),
                (amp_hbm, amp_s),
                (sc_hbm, sc_s),
            ):
                cp = pltpu.make_async_copy(
                    src.at[pl.ds(src_row, rows_per_block), :],
                    dst.at[pl.ds(dst_row, rows_per_block), :],
                    copy_sem,
                )
                cp.start()
                cp.wait()

    @pl.when(any_scored)
    def _score():
        # in-register decode of the stored dtypes (see _kernel)
        x0 = x0_s[...].astype(jnp.float32)
        y0 = y0_s[...].astype(jnp.float32)
        x1 = x1_s[...].astype(jnp.float32)
        y1 = y1_s[...].astype(jnp.float32)
        amp = amp_s[...].astype(jnp.float32) * sc_s[...]
        acc = jnp.zeros_like(x0)
        for q in range(Q_MAX):  # static unroll over query rects
            qx0 = qr_ref[q, 0]
            qy0 = qr_ref[q, 1]
            qx1 = qr_ref[q, 2]
            qy1 = qr_ref[q, 3]
            w = jnp.maximum(jnp.minimum(x1, qx1) - jnp.maximum(x0, qx0), 0.0)
            h = jnp.maximum(jnp.minimum(y1, qy1) - jnp.maximum(y0, qy0), 0.0)
            acc = acc + (w * h) * qa_ref[q]
        sc = jnp.where(mask, acc * amp, 0.0)
        out_ref[...] = sc
        # absolute toe-print positions of this tile, for the validity mask —
        # only genuine [start, end) candidates may feed the θ buffer
        cols = jax.lax.broadcasted_iota(jnp.int32, sc.shape, 1)
        pos = (starts_ref[i] + j) * TILE + rows * LANES + cols
        okm = (pos >= bounds_ref[i, 0]) & (pos < bounds_ref[i, 1])
        masked = jnp.where(okm, sc, 0.0)
        # cyclic top-C approximation: fold this tile into its buffer slice
        r0 = ((i * n_tiles + j) % cb) * BLOCK_ROWS
        sl = buf_ref[pl.ds(r0, BLOCK_ROWS), :]
        buf_ref[pl.ds(r0, BLOCK_ROWS), :] = jnp.maximum(sl, masked)

    @pl.when(jnp.logical_not(any_scored))
    def _skip():
        out_ref[...] = jnp.zeros_like(out_ref)


@functools.partial(
    jax.jit,
    static_argnames=("n_sweeps", "budget", "max_candidates", "bpt", "interpret"),
)
def sweep_score_pruned_planar(
    block_starts: jax.Array,  # i32[k] sweep starts in TILE units
    bounds: jax.Array,  # i32[k, 2] exact [start, end) element offsets
    floor: jax.Array,  # f32[1] select-stage score floor
    block_ub: jax.Array,  # f32[k, (budget // TILE) * bpt] per-block bounds
    q_rects: jax.Array,  # f32[Q_MAX, 4]
    q_amps: jax.Array,  # f32[Q_MAX]
    x0: jax.Array,  # [rows, 128] — the ENTIRE toe-print store, planar,
    y0: jax.Array,  # in its stored dtype (f32/f16 coords, f32/f16/int8 amps)
    x1: jax.Array,
    y1: jax.Array,
    amp: jax.Array,
    scale: jax.Array,  # f32[rows, 1] per-row amp scale (ones unless int8)
    n_sweeps: int,
    budget: int,  # toe prints fetched per sweep; multiple of TILE
    max_candidates: int,  # C of the partial top-C threshold buffer
    bpt: int,  # metadata blocks per TILE (1, 2, 4 or 8)
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Pruned fused sweep: (scores f32[k, budget//LANES, 128],
    scored i32[k, (budget//TILE)*bpt] per-metadata-block flags).

    Grid = (k, budget/TILE), walked sequentially, so the θ scratch carries
    across all tiles of all sweeps of one query; under ``vmap`` the batch
    axis becomes the outermost grid dimension and the (0, 0) re-init gives
    every query a fresh threshold.

    Unlike the unpruned kernel, the store planes are NOT auto-DMA'd by a
    BlockSpec: they stay in ``ANY`` memory space and the kernel issues a
    manual ``make_async_copy`` per *metadata block* that survives the θ
    test, so a skipped block truly moves zero bytes (the PR 4 caveat —
    previously the whole tile streamed and skipped blocks were only
    masked after the fetch).
    """
    assert budget % TILE == 0
    assert BLOCK_ROWS % bpt == 0
    n_tiles = budget // TILE
    # C rounded up to whole tiles: a larger buffer only lowers θ (safer)
    cb = max(1, -(-max_candidates // TILE))

    # store planes: full arrays, manually copied block-wise in-kernel
    plane = pl.BlockSpec(memory_space=pltpu.ANY)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_sweeps, n_tiles),
        in_specs=[
            pl.BlockSpec(
                (n_sweeps, 2), lambda i, j, s: (0, 0), memory_space=pltpu.SMEM
            ),
            pl.BlockSpec((1,), lambda i, j, s: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec(
                (n_sweeps, n_tiles * bpt),
                lambda i, j, s: (0, 0),
                memory_space=pltpu.SMEM,
            ),
            pl.BlockSpec((Q_MAX, 4), lambda i, j, s: (0, 0)),
            pl.BlockSpec((Q_MAX,), lambda i, j, s: (0,)),
            plane,
            plane,
            plane,
            plane,
            plane,
            plane,
        ],
        out_specs=[
            pl.BlockSpec((1, BLOCK_ROWS, LANES), lambda i, j, s: (i, j, 0)),
            pl.BlockSpec((1, bpt), lambda i, j, s: (i, j), memory_space=pltpu.SMEM),
        ],
        scratch_shapes=[
            pltpu.VMEM((cb * BLOCK_ROWS, LANES), jnp.float32),
            pltpu.VMEM((BLOCK_ROWS, LANES), x0.dtype),
            pltpu.VMEM((BLOCK_ROWS, LANES), y0.dtype),
            pltpu.VMEM((BLOCK_ROWS, LANES), x1.dtype),
            pltpu.VMEM((BLOCK_ROWS, LANES), y1.dtype),
            pltpu.VMEM((BLOCK_ROWS, LANES), amp.dtype),
            pltpu.VMEM((BLOCK_ROWS, 1), jnp.float32),
            pltpu.SemaphoreType.DMA,
        ],
    )
    kernel = functools.partial(_pruned_kernel, n_tiles=n_tiles, cb=cb, bpt=bpt)
    scores, scored = pl.pallas_call(
        lambda s_ref, bd, fl, ub, qr, qa, a, b, c, d, e, g, o, f, buf, sa, sb, sc_, sd, se, sg, sem: kernel(
            s_ref, bd, fl, ub, qr, qa, a, b, c, d, e, g,
            o.at[0], f, buf, sa, sb, sc_, sd, se, sg, sem
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_sweeps, budget // LANES, LANES), jnp.float32),
            jax.ShapeDtypeStruct((n_sweeps, n_tiles * bpt), jnp.int32),
        ],
        interpret=interpret,
    )(
        block_starts, bounds, floor, block_ub, q_rects, q_amps,
        x0, y0, x1, y1, amp, scale,
    )
    return scores, scored

"""Pallas TPU kernel: fused k-sweep fetch + geo scoring.

The K-SWEEP hot path does two HBM passes in the reference implementation:
(1) ``dynamic_slice`` the toe-print store for each sweep, (2) score the
fetched toe prints against the query footprint.  This kernel FUSES them:
the grid walks ``(sweep, block-within-sweep)`` and the input BlockSpec
index_map is driven by the **scalar-prefetched sweep starts** — each grid
step DMAs the next VMEM tile of the Morton-ordered store directly from the
sweep's dynamic offset and scores it in-register.  The fetched toe prints
never round-trip through HBM.

Layout mirrors kernels/geo_score: planar coordinate arrays with the lane
dimension along toe prints ([rows, 128] f32 tiles), query rects unrolled
from VMEM scalars.  Sweep starts are block-aligned by ops.py (rounded down
to the 1024-element tile); masking against the true [start, end) range
happens in ops.py where absolute positions are known.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
BLOCK_ROWS = 8
TILE = BLOCK_ROWS * LANES  # toe prints per grid step
Q_MAX = 8


def _kernel(starts_ref, qr_ref, qa_ref, x0_ref, y0_ref, x1_ref, y1_ref, amp_ref, out_ref):
    # starts_ref is scalar-prefetch (used only by the index maps)
    x0 = x0_ref[...]
    y0 = y0_ref[...]
    x1 = x1_ref[...]
    y1 = y1_ref[...]
    acc = jnp.zeros_like(x0)
    for j in range(Q_MAX):  # static unroll over query rects
        qx0 = qr_ref[j, 0]
        qy0 = qr_ref[j, 1]
        qx1 = qr_ref[j, 2]
        qy1 = qr_ref[j, 3]
        w = jnp.maximum(jnp.minimum(x1, qx1) - jnp.maximum(x0, qx0), 0.0)
        h = jnp.maximum(jnp.minimum(y1, qy1) - jnp.maximum(y0, qy0), 0.0)
        acc = acc + (w * h) * qa_ref[j]
    out_ref[...] = acc * amp_ref[...]


@functools.partial(jax.jit, static_argnames=("n_sweeps", "budget", "interpret"))
def sweep_score_planar(
    block_starts: jax.Array,  # i32[k] sweep starts in BLOCK units (rows/BLOCK_ROWS)
    q_rects: jax.Array,  # f32[Q_MAX, 4]
    q_amps: jax.Array,  # f32[Q_MAX]
    x0: jax.Array,  # f32[rows, 128] — the ENTIRE toe-print store, planar
    y0: jax.Array,
    x1: jax.Array,
    y1: jax.Array,
    amp: jax.Array,
    n_sweeps: int,
    budget: int,  # toe prints fetched per sweep; multiple of TILE
    interpret: bool = True,
) -> jax.Array:
    """Returns per-sweep scores f32[k, budget // LANES, 128].

    grid = (k, budget/TILE); block (i, j) reads store rows
    ``block_starts[i] + j*BLOCK_ROWS`` — a streaming DMA from the sweep
    offset, fused with scoring.
    """
    assert budget % TILE == 0
    rows = x0.shape[0]
    n_blocks = budget // TILE

    def in_map(i, j, starts):
        # starts[i] is in BLOCK units (TILE-aligned rows / BLOCK_ROWS)
        return (starts[i] + j, 0)

    plane = pl.BlockSpec((BLOCK_ROWS, LANES), in_map)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_sweeps, n_blocks),
        in_specs=[
            pl.BlockSpec((Q_MAX, 4), lambda i, j, s: (0, 0)),
            pl.BlockSpec((Q_MAX,), lambda i, j, s: (0,)),
            plane, plane, plane, plane, plane,
        ],
        out_specs=pl.BlockSpec(
            (1, BLOCK_ROWS, LANES), lambda i, j, s: (i, j, 0)
        ),
    )
    out = pl.pallas_call(
        lambda s_ref, qr, qa, a, b, c, d, e, o: _kernel(
            s_ref, qr, qa, a, b, c, d, e, o.at[0]
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (n_sweeps, budget // LANES, LANES), jnp.float32
        ),
        interpret=interpret,
    )(block_starts, q_rects, q_amps, x0, y0, x1, y1, amp)
    return out

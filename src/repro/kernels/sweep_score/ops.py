"""jit'd wrappers for the fused sweep_score kernels.

Handles: planarization of the toe-print store, block alignment of sweep
starts (the kernels DMA TILE-aligned blocks; we align the window down and
enlarge the in-kernel budget by one tile so the true [start, end) range is
always covered), masking back to exact sweep bounds, and — for the pruned
variant — computing the per-tile block-max upper bounds that drive the
in-kernel skip test from the ``SpatialIndex`` block columns.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.spatial_index import SCALE_BLOCK
from repro.kernels.sweep_score.kernel import (
    LANES,
    Q_MAX,
    TILE,
    sweep_score_planar,
    sweep_score_pruned_planar,
)

# plain int (not a jnp scalar): this module is imported lazily from inside
# jit-traced code, and creating a jax array at import time would leak a tracer
INVALID = 2**31 - 1


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _planarize(tp_rects, tp_amps, tp_amp_scale, budget):
    """Planar [rows, 128] views of the store in its STORED dtype, padded
    for alignment slop, plus a per-row f32 amp-scale plane [rows, 1].

    Compressed stores keep their narrow dtypes here — the kernels stream
    the stored bytes and decode in-register (astype f32, × row scale).
    One planar row is exactly one amp-scale block (SCALE_BLOCK == LANES);
    stores without a scale column get an all-ones plane, and ×1.0 keeps
    the uncompressed path bit-identical.

    Returns (planes, pad_budget): 6 planes (x0, y0, x1, y1, amp, scale)
    and the per-sweep in-kernel budget (the requested budget rounded up to
    whole tiles plus one tile of alignment slop).
    """
    assert SCALE_BLOCK == LANES
    T = tp_rects.shape[0]
    pad_budget = (budget + TILE - 1) // TILE * TILE + TILE
    Tp = (T + TILE - 1) // TILE * TILE + pad_budget  # tail room for last sweep
    rows = Tp // LANES

    def plane(v, fill):
        v = jnp.pad(v, (0, Tp - T), constant_values=fill)
        return v.reshape(rows, LANES)

    ns = tp_amp_scale.shape[0] if tp_amp_scale is not None else 0
    scale = jnp.ones((rows, 1), jnp.float32)
    if ns:
        scale = scale.at[:ns, 0].set(tp_amp_scale.astype(jnp.float32))
    planes = (
        plane(tp_rects[:, 0], 1.0),  # empty-rect padding
        plane(tp_rects[:, 1], 1.0),
        plane(tp_rects[:, 2], 0.0),
        plane(tp_rects[:, 3], 0.0),
        plane(tp_amps, 0),
        scale,
    )
    return planes, pad_budget


def _pad_query(q_rects, q_amps):
    Q = q_rects.shape[0]
    assert Q <= Q_MAX
    qr = jnp.zeros((Q_MAX, 4), jnp.float32).at[:Q].set(q_rects.astype(jnp.float32))
    qa = jnp.zeros((Q_MAX,), jnp.float32).at[:Q].set(q_amps.astype(jnp.float32))
    return qr, qa


def sweep_window_offsets(sweep_starts, sweep_ends, T):
    """Shared pruned-sweep window prologue (used by ops AND ref so their
    skip decisions stay bit-identical): INVALID-safe starts, TILE-aligned
    window origins (in elements and TILE units), and the exact candidate
    [start, end) bounds clamped to the store."""
    safe = jnp.where(sweep_starts == INVALID, 0, sweep_starts)
    aligned = (safe // TILE) * TILE
    block_starts = (aligned // TILE).astype(jnp.int32)
    ends = jnp.where(sweep_starts == INVALID, 0, jnp.minimum(sweep_ends, jnp.int32(T)))
    bounds = jnp.stack([safe, ends], axis=1)
    return safe, aligned, block_starts, bounds


def rewindow_outputs(
    flat, scored, safe, aligned, sweep_starts, sweep_ends, T, budget, block_size
):
    """Shared pruned-sweep epilogue: re-window the padded per-tile outputs
    to exactly [start, start+budget), rebuild the valid mask, and gather
    the per-position streamed (block-scored) mask."""
    offs = safe - aligned  # [k] in [0, TILE)
    idx = offs[:, None] + jnp.arange(budget, dtype=jnp.int32)[None, :]
    scores = jnp.take_along_axis(flat, idx, axis=1)
    pos = safe[:, None] + jnp.arange(budget, dtype=jnp.int32)[None, :]
    valid = (
        (sweep_starts[:, None] != INVALID)
        & (pos >= sweep_starts[:, None])
        & (pos < sweep_ends[:, None])
        & (pos < T)
    )
    streamed = jnp.take_along_axis(scored.astype(bool), idx // block_size, axis=1)
    return jnp.where(valid & streamed, scores, 0.0), valid, streamed


def block_upper_bounds(
    blk_mbr: jax.Array,  # f32[NB, 4]
    blk_max_amp: jax.Array,  # f32[NB]
    blk_max_mass: jax.Array,  # f32[NB]
    q_rects: jax.Array,  # [Q, 4]
    q_amps: jax.Array,  # [Q]
) -> jax.Array:
    """Safe per-block upper bound on any toe print's partial geo score.

    ``score_t = amp_t * Σ_q area(t ∩ q) · amp_q`` is bounded by both
    ``blk_max_amp · Σ_q area(blk_mbr ∩ q) · amp_q`` (every toe print lies
    inside the block MBR) and ``blk_max_mass · Σ_q amp_q`` (the
    intersection never exceeds the toe print's own area).  Returns the
    min of the two, f32[NB]; exactly 0 for blocks disjoint from the query.
    """
    qr = q_rects.astype(jnp.float32)
    qa = q_amps.astype(jnp.float32)
    w = jnp.maximum(
        jnp.minimum(blk_mbr[:, None, 2], qr[None, :, 2])
        - jnp.maximum(blk_mbr[:, None, 0], qr[None, :, 0]),
        0.0,
    )
    h = jnp.maximum(
        jnp.minimum(blk_mbr[:, None, 3], qr[None, :, 3])
        - jnp.maximum(blk_mbr[:, None, 1], qr[None, :, 1]),
        0.0,
    )
    bound_mbr = blk_max_amp * jnp.sum(w * h * qa[None, :], axis=1)
    bound_mass = blk_max_mass * jnp.sum(qa)
    return jnp.minimum(bound_mbr, bound_mass)


def window_block_bounds(
    ub_blocks: jax.Array,  # f32[NB] per-metadata-block bounds
    block_starts: jax.Array,  # i32[k] aligned sweep starts in TILE units
    bounds: jax.Array,  # i32[k, 2] exact [start, end) element offsets
    n_tiles: int,
    block_size: int,
) -> tuple[jax.Array, jax.Array]:
    """Per (sweep, window-block) upper bound and overlap mask, both
    f32/bool[k, n_tiles * (TILE // block_size)].

    The bound is zeroed for blocks with no overlap with the sweep's exact
    [start, end) range (they hold no candidates, so scoring them could
    only pollute the θ buffer).  ``overlap`` marks the blocks an
    *unpruned* sweep would stream — the baseline for the skipped-block
    counters."""
    nb = ub_blocks.shape[0]
    bpt = TILE // block_size
    w = jnp.arange(n_tiles * bpt, dtype=jnp.int32)
    b0 = (
        block_starts[:, None] * bpt + w[None, :]
    )  # metadata-block id per window slot
    ub = jnp.where(b0 < nb, ub_blocks[jnp.clip(b0, 0, nb - 1)], 0.0)
    e0 = b0 * block_size  # element offset of the block
    overlap = (e0 + block_size > bounds[:, None, 0]) & (e0 < bounds[:, None, 1])
    return jnp.where(overlap, ub, 0.0), overlap


@functools.partial(jax.jit, static_argnames=("budget", "interpret"))
def sweep_score(
    tp_rects: jax.Array,  # [T, 4] toe-print store (any float dtype)
    tp_amps: jax.Array,  # [T]
    sweep_starts: jax.Array,  # i32[k] element offsets (INVALID padded)
    sweep_ends: jax.Array,  # i32[k]
    q_rects: jax.Array,  # [Q, 4], Q <= Q_MAX
    q_amps: jax.Array,  # [Q]
    budget: int,
    interpret: bool | None = None,
    tp_amp_scale: jax.Array | None = None,  # f32[ceil(T/SCALE_BLOCK)] (int8 store)
) -> tuple[jax.Array, jax.Array]:
    """Fused fetch+score: (scores f32[k, budget], valid bool[k, budget])."""
    if interpret is None:
        interpret = _default_interpret()
    T = tp_rects.shape[0]
    k = sweep_starts.shape[0]
    qr, qa = _pad_query(q_rects, q_amps)
    (x0, y0, x1, y1, am, sc), pad_budget = _planarize(
        tp_rects, tp_amps, tp_amp_scale, budget
    )

    safe = jnp.where(sweep_starts == INVALID, 0, sweep_starts)
    aligned = (safe // TILE) * TILE  # align down to tile
    block_starts = (aligned // TILE).astype(jnp.int32)  # TILE units

    out = sweep_score_planar(
        block_starts,
        qr,
        qa,
        x0,
        y0,
        x1,
        y1,
        am,
        sc,
        n_sweeps=k,
        budget=pad_budget,
        interpret=interpret,
    )  # [k, pad_budget/LANES, LANES]
    flat = out.reshape(k, pad_budget)
    # re-window to exactly [start, start+budget) and mask to [start, end)
    offs = safe - aligned  # [k] in [0, TILE)
    idx = offs[:, None] + jnp.arange(budget, dtype=jnp.int32)[None, :]
    scores = jnp.take_along_axis(flat, idx, axis=1)
    pos = safe[:, None] + jnp.arange(budget, dtype=jnp.int32)[None, :]
    valid = (
        (sweep_starts[:, None] != INVALID)
        & (pos >= sweep_starts[:, None])
        & (pos < sweep_ends[:, None])
        & (pos < T)
    )
    return jnp.where(valid, scores, 0.0), valid


@functools.partial(
    jax.jit, static_argnames=("budget", "max_candidates", "block_size", "interpret")
)
def sweep_score_pruned(
    tp_rects: jax.Array,  # [T, 4] toe-print store (any float dtype)
    tp_amps: jax.Array,  # [T]
    blk_mbr: jax.Array,  # f32[NB, 4] block-max metadata columns
    blk_max_amp: jax.Array,  # f32[NB]
    blk_max_mass: jax.Array,  # f32[NB]
    sweep_starts: jax.Array,  # i32[k] element offsets (INVALID padded)
    sweep_ends: jax.Array,  # i32[k]
    q_rects: jax.Array,  # [Q, 4], Q <= Q_MAX
    q_amps: jax.Array,  # [Q]
    budget: int,
    max_candidates: int,
    block_size: int,
    floor: jax.Array | float = 0.0,  # select-stage score floor (scalar)
    interpret: bool | None = None,
    tp_amp_scale: jax.Array | None = None,  # f32[ceil(T/SCALE_BLOCK)] (int8 store)
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused fetch+score+select with block-max pruning.

    Returns ``(scores f32[k, budget], valid bool[k, budget], streamed
    bool[k, budget], blocks_scored i32, blocks_active i32)``: ``streamed``
    marks window positions whose metadata block was actually scored (the
    pruned path's streamed-bytes accounting — on hardware the per-block
    DMA is simply not issued for skipped blocks), candidates are
    ``valid & streamed``, and the block counters feed the
    ``blocks_skipped`` stats (``blocks_active`` counts blocks overlapping
    a live [start, end) range — what an unpruned sweep would stream).
    """
    if interpret is None:
        interpret = _default_interpret()
    T = tp_rects.shape[0]
    k = sweep_starts.shape[0]
    bpt = TILE // block_size
    qr, qa = _pad_query(q_rects, q_amps)
    (x0, y0, x1, y1, am, sc), pad_budget = _planarize(
        tp_rects, tp_amps, tp_amp_scale, budget
    )
    n_tiles = pad_budget // TILE

    safe, aligned, block_starts, bounds = sweep_window_offsets(
        sweep_starts, sweep_ends, T
    )
    ub_blocks = block_upper_bounds(blk_mbr, blk_max_amp, blk_max_mass, q_rects, q_amps)
    win_ub, overlap = window_block_bounds(
        ub_blocks, block_starts, bounds, n_tiles, block_size
    )

    out, scored = sweep_score_pruned_planar(
        block_starts,
        bounds.astype(jnp.int32),
        jnp.maximum(jnp.asarray(floor, jnp.float32), 0.0).reshape(1),
        win_ub,
        qr,
        qa,
        x0,
        y0,
        x1,
        y1,
        am,
        sc,
        n_sweeps=k,
        budget=pad_budget,
        max_candidates=max_candidates,
        bpt=bpt,
        interpret=interpret,
    )
    flat = out.reshape(k, pad_budget)
    scores, valid, streamed = rewindow_outputs(
        flat, scored, safe, aligned, sweep_starts, sweep_ends, T, budget, block_size
    )
    blocks_scored = jnp.sum((scored > 0) & overlap)
    blocks_active = jnp.sum(overlap)
    return (
        scores,
        valid,
        streamed,
        blocks_scored.astype(jnp.int32),
        blocks_active.astype(jnp.int32),
    )

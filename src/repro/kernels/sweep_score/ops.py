"""jit'd wrapper for the fused sweep_score kernel.

Handles: planarization of the toe-print store, block alignment of sweep
starts (the kernel DMAs TILE-aligned blocks; we align the window down and
enlarge the in-kernel budget by one tile so the true [start, end) range is
always covered), and masking back to exact sweep bounds.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sweep_score.kernel import (
    BLOCK_ROWS, LANES, Q_MAX, TILE, sweep_score_planar,
)

INVALID = jnp.int32(2**31 - 1)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("budget", "interpret"))
def sweep_score(
    tp_rects: jax.Array,  # [T, 4] toe-print store (any float dtype)
    tp_amps: jax.Array,  # [T]
    sweep_starts: jax.Array,  # i32[k] element offsets (INVALID padded)
    sweep_ends: jax.Array,  # i32[k]
    q_rects: jax.Array,  # [Q, 4], Q <= Q_MAX
    q_amps: jax.Array,  # [Q]
    budget: int,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused fetch+score: (scores f32[k, budget], valid bool[k, budget])."""
    if interpret is None:
        interpret = _default_interpret()
    T = tp_rects.shape[0]
    k = sweep_starts.shape[0]
    Q = q_rects.shape[0]
    assert Q <= Q_MAX

    qr = jnp.zeros((Q_MAX, 4), jnp.float32).at[:Q].set(q_rects.astype(jnp.float32))
    qa = jnp.zeros((Q_MAX,), jnp.float32).at[:Q].set(q_amps.astype(jnp.float32))

    # planarize the store, padded to a tile multiple
    pad_budget = (budget + TILE - 1) // TILE * TILE + TILE  # +1 tile: alignment slop
    Tp = (T + TILE - 1) // TILE * TILE + pad_budget  # tail room for last sweep

    def plane(v, fill):
        v = jnp.pad(v.astype(jnp.float32), (0, Tp - T), constant_values=fill)
        return v.reshape(Tp // LANES, LANES)

    x0 = plane(tp_rects[:, 0], 1.0)  # empty-rect padding
    y0 = plane(tp_rects[:, 1], 1.0)
    x1 = plane(tp_rects[:, 2], 0.0)
    y1 = plane(tp_rects[:, 3], 0.0)
    am = plane(tp_amps, 0.0)

    safe = jnp.where(sweep_starts == INVALID, 0, sweep_starts)
    aligned = (safe // TILE) * TILE  # align down to tile
    block_starts = (aligned // TILE).astype(jnp.int32)  # BLOCK units

    out = sweep_score_planar(
        block_starts, qr, qa, x0, y0, x1, y1, am,
        n_sweeps=k, budget=pad_budget, interpret=interpret,
    )  # [k, pad_budget/LANES, LANES]
    flat = out.reshape(k, pad_budget)
    # re-window to exactly [start, start+budget) and mask to [start, end)
    offs = safe - aligned  # [k] in [0, TILE)
    idx = offs[:, None] + jnp.arange(budget, dtype=jnp.int32)[None, :]
    scores = jnp.take_along_axis(flat, idx, axis=1)
    pos = safe[:, None] + jnp.arange(budget, dtype=jnp.int32)[None, :]
    valid = (
        (sweep_starts[:, None] != INVALID)
        & (pos >= sweep_starts[:, None])
        & (pos < sweep_ends[:, None])
        & (pos < T)
    )
    return jnp.where(valid, scores, 0.0), valid

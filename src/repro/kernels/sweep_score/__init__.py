from repro.kernels.sweep_score.ops import sweep_score  # noqa: F401

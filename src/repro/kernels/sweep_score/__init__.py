from repro.kernels.sweep_score.ops import (  # noqa: F401
    sweep_score,
    sweep_score_pruned,
)

"""Pure-jnp oracles for the fused sweep_score kernels.

``sweep_score_pruned_ref`` mirrors ``ops.sweep_score_pruned`` operation for
operation — same TILE-aligned windows, same per-tile upper bounds, same
cyclic partial top-C buffer and θ = min(buffer) skip rule, same sequential
accumulation order over query rects — so the skip *decisions* agree with
the Pallas kernel exactly, not just approximately.  It is both the kernel's
test oracle and the scorer behind ``k_sweep(prune=True, fused=False)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def sweep_score_ref(
    tp_rects: jax.Array,  # f32[T, 4] (Morton-ordered store)
    tp_amps: jax.Array,  # f32[T]
    sweep_starts: jax.Array,  # i32[k] element offsets (may be unaligned)
    sweep_ends: jax.Array,  # i32[k]
    q_rects: jax.Array,  # f32[Q, 4]
    q_amps: jax.Array,  # f32[Q]
    budget: int,
    tp_amp_scale: jax.Array | None = None,  # f32[ceil(T/128)] (int8 store)
) -> tuple[jax.Array, jax.Array]:
    """Fetch-then-score reference: returns (scores f32[k, budget],
    valid bool[k, budget]) for each sweep's [start, start+budget) window,
    masked to [start, end)."""
    from repro.core.spatial_index import SCALE_BLOCK

    T = tp_rects.shape[0]
    has_scale = tp_amp_scale is not None and tp_amp_scale.shape[0] > 0

    def one(s, e):
        start = jnp.where(s == jnp.int32(2**31 - 1), 0, s)
        pos = start + jnp.arange(budget, dtype=jnp.int32)
        safe = jnp.clip(pos, 0, T - 1)
        r = tp_rects[safe].astype(jnp.float32)
        a = tp_amps[safe].astype(jnp.float32)
        if has_scale:  # same astype-then-multiply order as the kernel decode
            a = a * tp_amp_scale[safe // SCALE_BLOCK]
        ok = (s != jnp.int32(2**31 - 1)) & (pos >= s) & (pos < e) & (pos < T)
        ix0 = jnp.maximum(r[:, None, 0], q_rects[None, :, 0])
        iy0 = jnp.maximum(r[:, None, 1], q_rects[None, :, 1])
        ix1 = jnp.minimum(r[:, None, 2], q_rects[None, :, 2])
        iy1 = jnp.minimum(r[:, None, 3], q_rects[None, :, 3])
        area = jnp.maximum(ix1 - ix0, 0.0) * jnp.maximum(iy1 - iy0, 0.0)
        sc = a * jnp.sum(area * q_amps[None, :], axis=1)
        return jnp.where(ok, sc, 0.0), ok

    return jax.vmap(one)(sweep_starts, sweep_ends)


@functools.partial(
    jax.jit, static_argnames=("budget", "max_candidates", "block_size")
)
def sweep_score_pruned_ref(
    tp_rects: jax.Array,  # [T, 4] toe-print store (any float dtype)
    tp_amps: jax.Array,  # [T]
    blk_mbr: jax.Array,  # f32[NB, 4] block-max metadata columns
    blk_max_amp: jax.Array,  # f32[NB]
    blk_max_mass: jax.Array,  # f32[NB]
    sweep_starts: jax.Array,  # i32[k] element offsets (INVALID padded)
    sweep_ends: jax.Array,  # i32[k]
    q_rects: jax.Array,  # [Q, 4]
    q_amps: jax.Array,  # [Q]
    budget: int,
    max_candidates: int,
    block_size: int,
    floor: jax.Array | float = 0.0,
    tp_amp_scale: jax.Array | None = None,  # f32[ceil(T/128)] (int8 store)
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Block-max pruned sweep oracle; same contract as
    ``ops.sweep_score_pruned`` (scores, valid, streamed, blocks_scored,
    blocks_active)."""
    from repro.core.spatial_index import SCALE_BLOCK
    from repro.kernels.sweep_score.kernel import Q_MAX, TILE
    from repro.kernels.sweep_score.ops import (
        block_upper_bounds,
        rewindow_outputs,
        sweep_window_offsets,
        window_block_bounds,
    )

    T = tp_rects.shape[0]
    k = sweep_starts.shape[0]
    Q = q_rects.shape[0]
    bpt = TILE // block_size
    pad_budget = (budget + TILE - 1) // TILE * TILE + TILE
    n_tiles = pad_budget // TILE
    cb = max(1, -(-max_candidates // TILE))

    safe, aligned, block_starts, bounds = sweep_window_offsets(
        sweep_starts, sweep_ends, T
    )
    ub_blocks = block_upper_bounds(blk_mbr, blk_max_amp, blk_max_mass, q_rects, q_amps)
    win_ub, overlap = window_block_bounds(
        ub_blocks, block_starts, bounds, n_tiles, block_size
    )

    # all window scores, kernel accumulation order (sequential over Q_MAX
    # slots; missing slots contribute exactly 0), on the kernel's padded
    # position lattice
    pos = (
        aligned[:, None, None]
        + (jnp.arange(n_tiles, dtype=jnp.int32) * TILE)[None, :, None]
        + jnp.arange(TILE, dtype=jnp.int32)[None, None, :]
    )  # [k, n_tiles, TILE]
    gp = jnp.clip(pos, 0, max(T - 1, 0))
    in_store = pos < T
    r = tp_rects[gp].astype(jnp.float32)
    # out-of-store positions see the kernel's empty-rect/zero-amp padding
    x0 = jnp.where(in_store, r[..., 0], 1.0)
    y0 = jnp.where(in_store, r[..., 1], 1.0)
    x1 = jnp.where(in_store, r[..., 2], 0.0)
    y1 = jnp.where(in_store, r[..., 3], 0.0)
    a_dec = tp_amps[gp].astype(jnp.float32)
    if tp_amp_scale is not None and tp_amp_scale.shape[0] > 0:
        # same astype-then-multiply order as the in-kernel decode
        a_dec = a_dec * tp_amp_scale[gp // SCALE_BLOCK]
    a = jnp.where(in_store, a_dec, 0.0)
    qr = q_rects.astype(jnp.float32)
    qa = q_amps.astype(jnp.float32)
    acc = jnp.zeros_like(x0)
    for q in range(Q_MAX):
        if q >= Q:
            break
        w = jnp.maximum(jnp.minimum(x1, qr[q, 2]) - jnp.maximum(x0, qr[q, 0]), 0.0)
        h = jnp.maximum(jnp.minimum(y1, qr[q, 3]) - jnp.maximum(y0, qr[q, 1]), 0.0)
        acc = acc + (w * h) * qa[q]
    sc_all = acc * a  # [k, n_tiles, TILE]
    okm_all = (pos >= bounds[:, None, None, 0]) & (pos < bounds[:, None, None, 1])

    # sequential tile walk: per-metadata-block skip decisions against the
    # cyclic partial top-C threshold buffer (seeded with the select floor)
    flat_ub = win_ub.reshape(k * n_tiles, bpt)
    flat_sc = sc_all.reshape(k * n_tiles, bpt, block_size)
    flat_ok = okm_all.reshape(k * n_tiles, bpt, block_size)
    slots = jnp.arange(k * n_tiles, dtype=jnp.int32) % cb
    theta0 = jnp.maximum(jnp.asarray(floor, jnp.float32).reshape(()), 0.0)

    def step(buf, xs):
        ub, sc, okm, slot = xs
        theta = jnp.min(buf)
        scored = ub > theta  # [bpt]
        masked = jnp.where(scored[:, None] & okm, sc, 0.0).reshape(TILE)
        buf = buf.at[slot].set(jnp.maximum(buf[slot], masked))
        return buf, scored

    _, scored = jax.lax.scan(
        step,
        jnp.full((cb, TILE), theta0, jnp.float32),
        (flat_ub, flat_sc, flat_ok, slots),
    )
    scored = scored.reshape(k, n_tiles * bpt)

    flat = jnp.where(
        scored.reshape(k, n_tiles, bpt, 1),
        sc_all.reshape(k, n_tiles, bpt, block_size),
        0.0,
    ).reshape(k, pad_budget)
    scores, valid, streamed = rewindow_outputs(
        flat, scored, safe, aligned, sweep_starts, sweep_ends, T, budget, block_size
    )
    blocks_scored = jnp.sum(scored & overlap)
    blocks_active = jnp.sum(overlap)
    return (
        scores,
        valid,
        streamed,
        blocks_scored.astype(jnp.int32),
        blocks_active.astype(jnp.int32),
    )

"""Pure-jnp oracle for the fused sweep_score kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sweep_score_ref(
    tp_rects: jax.Array,  # f32[T, 4] (Morton-ordered store)
    tp_amps: jax.Array,  # f32[T]
    sweep_starts: jax.Array,  # i32[k] element offsets (may be unaligned)
    sweep_ends: jax.Array,  # i32[k]
    q_rects: jax.Array,  # f32[Q, 4]
    q_amps: jax.Array,  # f32[Q]
    budget: int,
) -> tuple[jax.Array, jax.Array]:
    """Fetch-then-score reference: returns (scores f32[k, budget],
    valid bool[k, budget]) for each sweep's [start, start+budget) window,
    masked to [start, end)."""
    T = tp_rects.shape[0]

    def one(s, e):
        start = jnp.where(s == jnp.int32(2**31 - 1), 0, s)
        pos = start + jnp.arange(budget, dtype=jnp.int32)
        safe = jnp.clip(pos, 0, T - 1)
        r = tp_rects[safe].astype(jnp.float32)
        a = tp_amps[safe].astype(jnp.float32)
        ok = (s != jnp.int32(2**31 - 1)) & (pos >= s) & (pos < e) & (pos < T)
        ix0 = jnp.maximum(r[:, None, 0], q_rects[None, :, 0])
        iy0 = jnp.maximum(r[:, None, 1], q_rects[None, :, 1])
        ix1 = jnp.minimum(r[:, None, 2], q_rects[None, :, 2])
        iy1 = jnp.minimum(r[:, None, 3], q_rects[None, :, 3])
        area = jnp.maximum(ix1 - ix0, 0.0) * jnp.maximum(iy1 - iy0, 0.0)
        sc = a * jnp.sum(area * q_amps[None, :], axis=1)
        return jnp.where(ok, sc, 0.0), ok

    return jax.vmap(one)(sweep_starts, sweep_ends)

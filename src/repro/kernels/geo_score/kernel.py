"""Pallas TPU kernel: per-toe-print geographic scores.

The FLOP hot spot of the paper's pipeline (precise geo scoring, §IV):
for a tile of toe prints and a small set of query rectangles compute

    out[t] = amp[t] * Σ_j area(rect[t] ∩ qrect[j]) * qamp[j]

Layout decisions (TPU-native, DESIGN.md §2):

* Toe-print rect components arrive as four planar f32 arrays shaped
  ``[rows, 128]`` (ops.py transposes/pads) — lane dimension = toe prints, so
  every min/max/mul is a full-width VPU op.  The packed ``[T, 4]`` layout
  would put the 4 coordinates in lanes and waste 124/128 of the vector unit.
* The query footprint (≤ Q_MAX rects) is tiny: it sits unblocked in VMEM and
  the kernel unrolls a static Python loop over its rows — each iteration is
  a scalar-broadcast VPU multiply-accumulate over the [BLOCK_ROWS, 128] tile.
* Block shape (BLOCK_ROWS × 128) f32 = 8 sublanes × 128 lanes per input
  plane — the native VREG tile; 5 input planes + 1 output plane per block =
  24 KiB of VMEM per grid step at the default BLOCK_ROWS=8, leaving VMEM for
  double buffering at any practical grid size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 8  # sublane-aligned f32 tile
Q_MAX = 8  # max query rects supported by a single kernel pass


def _geo_score_kernel(qr_ref, qa_ref, x0_ref, y0_ref, x1_ref, y1_ref, amp_ref, out_ref):
    x0 = x0_ref[...]
    y0 = y0_ref[...]
    x1 = x1_ref[...]
    y1 = y1_ref[...]
    acc = jnp.zeros_like(x0)
    for j in range(Q_MAX):  # static unroll over query rects
        qx0 = qr_ref[j, 0]
        qy0 = qr_ref[j, 1]
        qx1 = qr_ref[j, 2]
        qy1 = qr_ref[j, 3]
        w = jnp.maximum(jnp.minimum(x1, qx1) - jnp.maximum(x0, qx0), 0.0)
        h = jnp.maximum(jnp.minimum(y1, qy1) - jnp.maximum(y0, qy0), 0.0)
        acc = acc + (w * h) * qa_ref[j]
    out_ref[...] = acc * amp_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def geo_score_planar(
    q_rects: jax.Array,  # f32[Q_MAX, 4]
    q_amps: jax.Array,  # f32[Q_MAX]
    x0: jax.Array,  # f32[rows, 128]
    y0: jax.Array,
    x1: jax.Array,
    y1: jax.Array,
    amp: jax.Array,
    interpret: bool = True,
) -> jax.Array:
    """Raw pallas_call on pre-planarized inputs. Prefer ops.geo_score_toeprints."""
    rows = x0.shape[0]
    assert rows % BLOCK_ROWS == 0, rows
    assert q_rects.shape == (Q_MAX, 4) and q_amps.shape == (Q_MAX,)
    grid = (rows // BLOCK_ROWS,)
    plane = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _geo_score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Q_MAX, 4), lambda i: (0, 0)),  # query rects: whole, VMEM
            pl.BlockSpec((Q_MAX,), lambda i: (0,)),
            plane, plane, plane, plane, plane,
        ],
        out_specs=plane,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=interpret,
    )(q_rects, q_amps, x0, y0, x1, y1, amp)

"""Pure-jnp oracle for the geo_score kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def geo_score_toeprints_ref(
    rects: jax.Array,  # f32[T, 4]
    amps: jax.Array,  # f32[T]
    q_rects: jax.Array,  # f32[Q, 4]
    q_amps: jax.Array,  # f32[Q]
) -> jax.Array:
    """out[t] = amp[t] * Σ_j area(rect[t] ∩ qrect[j]) * qamp[j]  (f32[T])."""
    ix0 = jnp.maximum(rects[:, None, 0], q_rects[None, :, 0])
    iy0 = jnp.maximum(rects[:, None, 1], q_rects[None, :, 1])
    ix1 = jnp.minimum(rects[:, None, 2], q_rects[None, :, 2])
    iy1 = jnp.minimum(rects[:, None, 3], q_rects[None, :, 3])
    area = jnp.maximum(ix1 - ix0, 0.0) * jnp.maximum(iy1 - iy0, 0.0)
    return amps * jnp.sum(area * q_amps[None, :], axis=1)

from repro.kernels.geo_score.ops import *  # noqa: F401,F403

"""jit'd public wrappers for the geo_score Pallas kernel.

Handles layout adaptation (packed [T,4] rects → planar [rows,128] components),
padding, and backend selection (interpret mode off-TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.geo_score.kernel import BLOCK_ROWS, LANES, Q_MAX, geo_score_planar


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def geo_score_toeprints(
    rects: jax.Array,  # f32[T, 4]
    amps: jax.Array,  # f32[T]
    q_rects: jax.Array,  # f32[Q, 4], Q <= Q_MAX
    q_amps: jax.Array,  # f32[Q]
    interpret: bool | None = None,
) -> jax.Array:
    """Per-toe-print geo scores, f32[T]. Drop-in for the k_sweep tp_scorer."""
    if interpret is None:
        interpret = _default_interpret()
    T = rects.shape[0]
    Q = q_rects.shape[0]
    assert Q <= Q_MAX, f"at most {Q_MAX} query rects per pass, got {Q}"

    # pad query to Q_MAX with zero-amp empty rects
    qr = jnp.zeros((Q_MAX, 4), jnp.float32).at[:Q].set(q_rects.astype(jnp.float32))
    qa = jnp.zeros((Q_MAX,), jnp.float32).at[:Q].set(q_amps.astype(jnp.float32))

    # planarize: [T,4] -> four [rows,128] planes (pad T up to tile multiple)
    tile = BLOCK_ROWS * LANES
    Tp = (T + tile - 1) // tile * tile
    pad = Tp - T

    def plane(v, fill):
        v = jnp.pad(v.astype(jnp.float32), (0, pad), constant_values=fill)
        return v.reshape(Tp // LANES, LANES)

    out = geo_score_planar(
        qr, qa,
        plane(rects[:, 0], 1.0),  # empty-rect padding (x1 < x0 => area 0)
        plane(rects[:, 1], 1.0),
        plane(rects[:, 2], 0.0),
        plane(rects[:, 3], 0.0),
        plane(amps, 0.0),
        interpret=interpret,
    )
    return out.reshape(Tp)[:T]


@functools.partial(jax.jit, static_argnames=("interpret",))
def geo_score_docs(
    doc_rects: jax.Array,  # f32[C, R, 4]
    doc_amps: jax.Array,  # f32[C, R]
    q_rects: jax.Array,  # f32[Q, 4]
    q_amps: jax.Array,  # f32[Q]
    interpret: bool | None = None,
) -> jax.Array:
    """Per-document geo scores f32[C]: kernel over the flattened rect set."""
    C, R, _ = doc_rects.shape
    flat = geo_score_toeprints(
        doc_rects.reshape(C * R, 4),
        doc_amps.reshape(C * R),
        q_rects,
        q_amps,
        interpret=interpret,
    )
    return flat.reshape(C, R).sum(axis=1)

"""Pallas TPU kernels for the paper's query-processing hot spots.

geo_score      -- per-toe-print rectangle-intersection scoring (precise geo scores)
bitmap_filter  -- block-bitmap conjunction: u32 AND + SWAR popcount
sweep_score    -- FUSED k-sweep fetch + scoring: scalar-prefetch-driven
                  BlockSpecs stream each sweep through VMEM and score
                  in-register (the K-SWEEP hot path as one kernel); the
                  pruned variant adds block-max upper-bound skip tests
                  against a running top-C threshold held in VMEM scratch
                  (sweep -> score -> select, WAND-style)

Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrappers),
ref.py (pure-jnp oracle).
"""

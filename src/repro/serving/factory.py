"""One construction path for the three executor variants.

``launch/serve.py``, the benchmarks, and the tests used to hand-build
:class:`SingleDeviceExecutor` / :class:`ShardedExecutor` /
:class:`MeshExecutor` with three diverging keyword sets (and a stringly
``partition=`` flag).  :func:`make_executor` is the single front door:
pick a ``kind``, hand it the corpus, and configure partitioning/routing
through the :class:`~repro.core.distributed.Partitioner` API.

    from repro.core.distributed import RegionRangePartitioner
    ex = make_executor(
        "sharded", corpus, n_shards=8,
        partitioner=RegionRangePartitioner(), routing="footprint",
    )

The corpus argument is duck-typed: anything with ``doc_terms``,
``doc_rects``, ``doc_amps``, ``pagerank`` and ``n_terms`` attributes
(:class:`repro.corpus.SynthCorpus` in practice).
"""
from __future__ import annotations

from repro.core import algorithms as alg
from repro.core import ranking
from repro.core.distributed import Partitioner
from repro.core.engine import GeoSearchEngine
from repro.serving.executor import (
    MeshExecutor,
    ShardedExecutor,
    SingleDeviceExecutor,
    _check_routing,
)

EXECUTOR_KINDS = ("single", "sharded", "mesh")


def make_executor(
    kind: str,
    corpus,
    *,
    algorithm: str = "k_sweep",
    budgets: alg.QueryBudgets | None = None,
    weights: ranking.RankWeights | None = None,
    partitioner: Partitioner | None = None,
    routing: str = "broadcast",
    n_shards: int = 1,
    mesh=None,
    grid: int = 64,
    m_intervals: int = 2,
    fused: bool = False,
    use_pallas: bool = False,
    compress: "bool | str" = False,
    layout: str = "docid",
    telemetry=None,
):
    """Build an executor of ``kind`` over ``corpus``; see module docstring.

    * ``kind="single"``  — one engine, one device.  Partitioning/routing
      options do not apply and raise ``ValueError`` if set.
    * ``kind="sharded"`` — host scatter-gather over ``n_shards`` per-shard
      engines, split by ``partitioner`` (default Morton).
    * ``kind="mesh"``    — SPMD ``shard_map`` step over ``mesh`` (required);
      the shard count comes from the mesh's doc axes, not ``n_shards``.

    ``routing="footprint"`` (sharded/mesh) skips/masks shards no query
    footprint touches; ``compress`` selects the index storage mode
    (``"none"``/``"f16"``/``"int8"``, bool accepted for compatibility);
    ``layout`` selects the posting order (``"docid"``/``"impact"``, see
    :mod:`repro.core.text_index`); ``telemetry`` is attached before
    returning.
    """
    if kind not in EXECUTOR_KINDS:
        raise ValueError(f"kind must be one of {EXECUTOR_KINDS}, got {kind!r}")
    _check_routing(routing)
    if partitioner is not None and not isinstance(partitioner, Partitioner):
        raise TypeError(
            "partitioner must be a Partitioner instance; resolve strings at "
            "the CLI boundary with repro.core.distributed.resolve_partitioner"
        )
    budgets = budgets or alg.QueryBudgets()

    kw = {}
    if use_pallas:
        if kind == "mesh":
            raise ValueError(
                "use_pallas applies to host executors only (the mesh step "
                "selects kernels via fused=)"
            )
        if algorithm == "k_sweep":
            from repro.kernels.geo_score.ops import geo_score_toeprints

            kw["tp_scorer"] = geo_score_toeprints
    if (
        fused
        and kind != "mesh"
        and (
            algorithm in ("k_sweep", "auto")
            or (algorithm == "text_first" and budgets.prune)
        )
    ):
        kw["fused"] = True

    if kind == "single":
        if partitioner is not None or routing != "broadcast" or n_shards != 1:
            raise ValueError(
                "partitioner/routing/n_shards only apply to kind='sharded' "
                "or kind='mesh'"
            )
        eng = GeoSearchEngine.build(
            corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
            pagerank=corpus.pagerank, grid=grid, m_intervals=m_intervals,
            budgets=budgets, weights=weights, compress=compress,
            layout=layout,
        )
        executor = SingleDeviceExecutor(eng, algorithm, **kw)
    elif kind == "sharded":
        executor = ShardedExecutor.build(
            corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
            pagerank=corpus.pagerank, n_shards=n_shards,
            partitioner=partitioner, grid=grid, budgets=budgets,
            weights=weights, algorithm=algorithm, routing=routing,
            compress=compress, layout=layout, **kw,
        )
    else:  # mesh
        if mesh is None:
            raise ValueError("kind='mesh' requires mesh=")
        executor = MeshExecutor.build(
            corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
            pagerank=corpus.pagerank, mesh=mesh, partitioner=partitioner,
            grid=grid, budgets=budgets, weights=weights, algorithm=algorithm,
            fused=fused, routing=routing, compress=compress, layout=layout,
        )
    if telemetry is not None:
        executor.attach_telemetry(telemetry)
    return executor

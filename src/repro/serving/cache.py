"""Query-result caches: LRU and cost-aware Landlord eviction.

A geo search trace is Zipf-skewed — a few head queries repeat constantly —
so a result cache in front of the engine converts the bulk of traffic into
O(1) lookups.  Two policies:

* :class:`LRUCache` — classic recency eviction.  Optimal when every miss
  costs the same.
* :class:`LandlordCache` — the Landlord algorithm (Young 1998; the
  weighted-caching generalization of LRU/FIFO/GreedyDual).  Every entry is
  admitted with credit ``cost / size``; on pressure the minimum remaining
  credit is charged as "rent" to all entries (lazily, via a virtual clock)
  and a zero-credit entry is evicted; a hit restores the entry's credit.
  Expensive-to-recompute results (deep sweeps, many probes) therefore
  outlive cheap ones even when they recur less often — the right policy
  when miss costs vary by orders of magnitude, as the paper's per-query
  byte counters show they do.

Both caches track hits / misses / evictions and expose ``hit_rate``.
"""
from __future__ import annotations

import heapq
from collections import OrderedDict
from typing import Any, Hashable


class _CacheStats:
    hits: int
    misses: int
    evictions: int

    def __init__(self) -> None:
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        n = self.lookups
        return self.hits / n if n else 0.0


class LRUCache(_CacheStats):
    """Least-recently-used result cache with a fixed entry capacity."""

    def __init__(self, capacity: int):
        super().__init__()
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._data: OrderedDict[Hashable, Any] = OrderedDict()

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._data

    def get(self, key: Hashable):
        if key in self._data:
            self._data.move_to_end(key)
            self.hits += 1
            return self._data[key]
        self.misses += 1
        return None

    def put(
        self, key: Hashable, value: Any, cost: float = 1.0, size: float = 1.0
    ) -> None:
        if key in self._data:
            self._data.move_to_end(key)
            self._data[key] = value
            return
        while len(self._data) >= self.capacity:
            self._data.popitem(last=False)
            self.evictions += 1
        self._data[key] = value

    def fresh_clone(self) -> "LRUCache":
        """Empty cache with the same configuration (for shape prediction)."""
        return LRUCache(self.capacity)


class LandlordCache(_CacheStats):
    """Cost-aware cache (Landlord / GreedyDual-Size with lazy rent).

    Rent is charged through a virtual clock ``L``: an entry stored at clock
    value ``L0`` with credit ``cost/size`` expires at ``L0 + cost/size``.
    Eviction pops the minimum-expiry entry and advances ``L`` to its expiry
    (equivalent to subtracting the minimum credit from everyone).  A hit
    re-credits the entry: its expiry becomes ``L + cost/size`` again.

    **Size-aware admission**: with a ``max_bytes`` budget, ``size`` is the
    entry's payload bytes (the server passes the top-k arrays' ``nbytes``)
    and eviction also runs while the byte budget is exceeded, so many small
    results can coexist with few large ones under one memory ceiling — the
    GreedyDual-*Size* half of the algorithm.  An entry larger than the whole
    budget is never admitted (admitting it would evict everything for a
    result too big to keep).  Without ``max_bytes`` the cache is count-
    bounded only and ``size`` just scales credit, as before.

    **Exact byte accounting**: entry sizes are whole bytes (``int(size)``,
    floored at 1) and ``bytes_used`` is an integer — the running total is
    ``sum(entry sizes)`` exactly, through any sequence of admissions,
    replacements and eviction storms.  (The accounting used to accumulate
    float residue and paper over it with a reset-to-zero-when-empty hack;
    only the *credit* math ``cost / size`` is float now.)
    """

    def __init__(self, capacity: int, max_bytes: float | None = None):
        super().__init__()
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if max_bytes is not None and max_bytes <= 0:
            raise ValueError("max_bytes must be > 0 (or None for unbounded)")
        self.capacity = capacity
        self.max_bytes = max_bytes
        self.bytes_used = 0
        self.rejected = 0  # oversized entries refused admission
        self.clock = 0.0
        # key -> [value, cost, size, expiry, generation]
        self._data: dict[Hashable, list] = {}
        self._heap: list[tuple[float, int, int, Hashable]] = []  # lazy-deleted
        self._gen = 0

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._data

    def _push(self, key: Hashable, entry: list) -> None:
        self._gen += 1
        entry[4] = self._gen
        heapq.heappush(self._heap, (entry[3], self._gen, id(entry), key))
        # lazy deletion leaves stale records behind on every renewal; on
        # hit-heavy workloads (the cache's target regime) that is O(hits)
        # growth for a fixed-capacity cache — compact when it gets silly
        if len(self._heap) > 4 * self.capacity + 64:
            self._heap = [(e[3], e[4], id(e), k) for k, e in self._data.items()]
            heapq.heapify(self._heap)

    def get(self, key: Hashable):
        entry = self._data.get(key)
        if entry is None:
            self.misses += 1
            return None
        self.hits += 1
        # renew: restore full credit relative to the current clock
        entry[3] = self.clock + entry[1] / entry[2]
        self._push(key, entry)
        return entry[0]

    def put(
        self, key: Hashable, value: Any, cost: float = 1.0, size: float = 1.0
    ) -> None:
        cost = max(float(cost), 1e-12)
        size = max(int(size), 1)  # whole bytes: accounting stays exact
        if self.max_bytes is not None and size > self.max_bytes:
            self.rejected += 1
            return
        if key in self._data:
            entry = self._data[key]
            self.bytes_used += size - entry[2]
            entry[0], entry[1], entry[2] = value, cost, size
            entry[3] = self.clock + cost / size
            self._push(key, entry)
        else:
            while len(self._data) >= self.capacity:
                self._evict_one()
            entry = [value, cost, size, self.clock + cost / size, 0]
            self._data[key] = entry
            self.bytes_used += size
            self._push(key, entry)
        if self.max_bytes is not None:
            # may evict the entry just admitted if its credit is the minimum
            while self._data and self.bytes_used > self.max_bytes:
                self._evict_one()

    def _evict_one(self) -> None:
        while self._heap:
            expiry, gen, _, key = heapq.heappop(self._heap)
            entry = self._data.get(key)
            if entry is None or entry[4] != gen:
                continue  # stale heap record (renewed or replaced)
            self.clock = max(self.clock, expiry)  # charge rent = min credit
            del self._data[key]
            self.bytes_used -= entry[2]
            self.evictions += 1
            return
        raise RuntimeError("landlord heap empty while cache non-empty")

    def fresh_clone(self) -> "LandlordCache":
        """Empty cache with the same configuration (for shape prediction)."""
        return LandlordCache(self.capacity, max_bytes=self.max_bytes)


def make_cache(policy: str, capacity: int, max_bytes: float | None = None):
    """Factory: ``none`` | ``lru`` | ``landlord``.

    ``max_bytes`` (Landlord only) adds a result-payload byte budget on top
    of the entry-count capacity; combining it with another policy is an
    error rather than a silent no-op.
    """
    if policy != "landlord" and max_bytes is not None:
        raise ValueError(f"max_bytes is only supported by landlord, not {policy!r}")
    if policy == "none":
        return None
    if policy == "lru":
        return LRUCache(capacity)
    if policy == "landlord":
        return LandlordCache(capacity, max_bytes=max_bytes)
    raise ValueError(f"unknown cache policy {policy!r}")

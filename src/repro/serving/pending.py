"""In-flight request coalescing: the pending-result table.

A live serving tier sees the same popular query many times in a short
window.  The result cache only helps once the first execution *finishes* —
until then every duplicate would re-enter the batcher and burn executor
time recomputing an answer that is already on its way.  The
:class:`PendingTable` closes that window: it maps a query fingerprint to
the **in-flight** execution of that fingerprint (still waiting in a
batcher bucket, queued for a worker, or executing), so a duplicate can
*subscribe* to the pending result instead of re-enqueueing.

Lifecycle of an entry (driven by :class:`~repro.serving.server.GeoServer`):

1. ``register(key, qid)`` — a cache miss enqueued into the batcher becomes
   the *owner* of its fingerprint.
2. ``lookup(key, now)`` — a later miss with the same fingerprint finds the
   entry; the server appends it to ``subscribers`` (owner still batched,
   completion time unknown) or records it immediately (owner dispatched,
   timing known).
3. ``dispatched(key, qid, …)`` — the owner's batch is flushed and placed
   on a worker: the entry learns its ``flush_t``/``start_t``/``done_t``
   timeline and the owner's result row; deferred subscribers are resolved
   by the server at this point.
4. The entry stays coalescible until virtual time passes ``done_t`` (the
   result is then in the result cache, if any); ``expire(now)`` garbage-
   collects it.

The table never stores un-fingerprinted queries and is policy-free: all
latency accounting stays in the server so batch-wait + queue-wait +
service continues to sum exactly to total latency for coalesced queries.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field


@dataclass
class PendingEntry:
    """One in-flight fingerprint: its owner query and (once known) timing."""

    owner_qid: int
    # virtual timeline of the owner's batch; None until dispatched
    flush_t: float | None = None
    start_t: float | None = None
    done_t: float | None = None
    value: object | None = None  # owner's QueryResult row, set at dispatch
    plan_label: str | None = None  # plan that served the owner's batch
    # (arrival_s, trace index) of duplicates that subscribed while the
    # owner was still in a batcher bucket (timing unknown at subscribe time)
    subscribers: list[tuple[float, int]] = field(default_factory=list)

    @property
    def dispatched(self) -> bool:
        return self.done_t is not None


class PendingTable:
    """fingerprint key → in-flight :class:`PendingEntry`."""

    def __init__(self) -> None:
        self._by_key: dict = {}
        # (done_t, seq, key, qid) min-heap — with several workers, dispatch
        # order is not completion order, so expiry must pop by done time
        self._done_heap: list[tuple[float, int, object, int]] = []
        self._seq = itertools.count()

    def __len__(self) -> int:
        return len(self._by_key)

    def clear(self) -> None:
        self._by_key.clear()
        self._done_heap.clear()

    # ------------------------------------------------------------------
    def register(self, key, qid: int) -> PendingEntry:
        """A freshly-enqueued miss becomes the owner of its fingerprint."""
        entry = PendingEntry(owner_qid=qid)
        self._by_key[key] = entry
        return entry

    def lookup(self, key, now: float) -> PendingEntry | None:
        """The entry a duplicate arriving at ``now`` may coalesce onto.

        An entry whose batch already completed (``done_t <= now``) is not
        returned: its result has moved to the result cache (or is gone),
        so the duplicate must take the normal cache/batcher path.
        """
        entry = self._by_key.get(key)
        if entry is None:
            return None
        if entry.done_t is not None and entry.done_t <= now:
            return None
        return entry

    def on_dispatch(
        self, key, qid: int, flush_t: float, start_t: float, done_t: float, value
    ) -> PendingEntry | None:
        """Record the owner's batch timeline; returns the entry if owned.

        Returns ``None`` when ``qid`` no longer owns the fingerprint (a
        later miss re-registered after this entry expired) — nothing to
        resolve in that case.
        """
        entry = self._by_key.get(key)
        if entry is None or entry.owner_qid != qid:
            return None
        entry.flush_t, entry.start_t, entry.done_t = flush_t, start_t, done_t
        entry.value = value
        heapq.heappush(self._done_heap, (done_t, next(self._seq), key, qid))
        return entry

    def resolve(self, key, qid: int) -> PendingEntry | None:
        """Pop the entry outright (closed-loop: completion is in the past
        the moment the wall-clock executor returns)."""
        entry = self._by_key.get(key)
        if entry is None or entry.owner_qid != qid:
            return None
        del self._by_key[key]
        return entry

    def expire(self, now: float) -> int:
        """Drop entries whose batch completed by virtual ``now``; returns
        the number of coalesce windows closed (telemetry counter)."""
        heap = self._done_heap
        n = 0
        while heap and heap[0][0] <= now:
            _, _, key, qid = heapq.heappop(heap)
            entry = self._by_key.get(key)
            if entry is not None and entry.owner_qid == qid:
                del self._by_key[key]
                n += 1
        return n

    # ------------------------------------------------------------------
    def unresolved_subscribers(self) -> int:
        """Deferred subscribers still waiting on a dispatch (0 after a
        fully drained run — asserted by the server)."""
        return sum(len(e.subscribers) for e in self._by_key.values())

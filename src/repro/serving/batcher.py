"""Shape-bucketed dynamic micro-batcher.

The engine's query pipeline is jit-compiled per static shape
``(batch, terms_per_query, rects_per_query)``.  A naive dynamic batcher
would emit a fresh shape — and a fresh XLA compile — for every mix of
query widths in flight.  This batcher instead *registers a small lattice
of static shapes up front* (power-of-two term/rect capacities × power-of-
two batch sizes) and pads every incoming query up to the nearest bucket:

* the number of distinct compiled programs is bounded by
  ``len(term_buckets) · len(rect_buckets) · log2(max_batch)+1`` regardless
  of trace length;
* padding waste is *measured*, not hidden — ``pad_slots`` (whole dummy
  queries emitted to round a batch up) and ``pad_elements`` (padded term /
  rect cells inside real queries) feed the serving report's
  ``padding_overhead`` column.

Buckets are additionally *plan-homogeneous*: when the serving layer runs a
cost-based planner (``--algo auto``), each query carries its chosen
:class:`~repro.core.planner.QueryPlan` and the plan joins the bucket key —
a flushed batch holds one plan only, so the executor compiles once per
plan × shape and runs every row under its own chosen algorithm.  Fixed-
algorithm serving leaves ``plan`` as ``None`` and behaves bit-identically
to the pre-planner batcher.

Invariants (unit-tested): every emitted batch's shape is in the registered
set, every submitted query appears in exactly one emitted batch, and every
query in an emitted batch shares the batch's plan.
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields

import numpy as np


@dataclass(frozen=True)
class BucketShape:
    """One registered static shape: capacities, not actual occupancy."""

    batch: int
    d_terms: int
    q_rects: int


@dataclass
class PendingQuery:
    qid: int
    terms: np.ndarray  # i32[d]  (no padding)
    rects: np.ndarray  # f32[r, 4]
    amps: np.ndarray  # f32[r]
    plan: object = None  # QueryPlan chosen by the planner (None = fixed)


@dataclass
class RawBatch:
    """A padded batch ready for the executor (host-side numpy)."""

    shape: BucketShape
    qids: list[int]  # real queries, len <= shape.batch
    terms: np.ndarray  # i32[B, d]
    rects: np.ndarray  # f32[B, r, 4]
    amps: np.ndarray  # f32[B, r]
    plan: object = None  # the plan every query in this batch shares
    # filled post-execution by footprint-routed executors: per-batch shard
    # fan-out {"shards_touched": f64[n_real], "shards_visited": float}
    routing: dict | None = None

    @property
    def n_real(self) -> int:
        return len(self.qids)


def _pow2_buckets(max_value: int) -> list[int]:
    out, v = [], 1
    while v < max_value:
        out.append(v)
        v *= 2
    out.append(max_value)
    return out


@dataclass
class ShapeBucketedBatcher:
    """Groups queries by (term, rect) bucket; flushes full or on demand."""

    max_batch: int = 32
    max_terms: int = 8
    max_rects: int = 4
    # filled in __post_init__
    term_buckets: list[int] = field(default_factory=list)
    rect_buckets: list[int] = field(default_factory=list)
    batch_sizes: list[int] = field(default_factory=list)

    def __post_init__(self) -> None:
        self.term_buckets = self.term_buckets or _pow2_buckets(self.max_terms)
        self.rect_buckets = self.rect_buckets or _pow2_buckets(self.max_rects)
        self.batch_sizes = self.batch_sizes or _pow2_buckets(self.max_batch)
        self._pending: dict[tuple, list[PendingQuery]] = {}
        # padding accounting
        self.pad_slots = 0  # dummy whole-query rows
        self.real_slots = 0
        self.pad_elements = 0  # padded term/rect cells in real queries
        self.real_elements = 0
        self.emitted_shapes: set[BucketShape] = set()

    # ------------------------------------------------------------------
    def clone_empty(self) -> "ShapeBucketedBatcher":
        """A fresh batcher with identical configuration and no state.

        Works for subclasses too (all their config lives in dataclass
        fields) — the server uses this to replay batching decisions
        host-side for shape prediction/warmup.
        """
        kw = {f.name: getattr(self, f.name) for f in fields(self)}
        for k in ("term_buckets", "rect_buckets", "batch_sizes"):
            kw[k] = list(kw[k])
        return type(self)(**kw)

    @property
    def registered_shapes(self) -> set[BucketShape]:
        return {
            BucketShape(b, d, r)
            for b in self.batch_sizes
            for d in self.term_buckets
            for r in self.rect_buckets
        }

    def _bucket_of(self, n: int, buckets: list[int]) -> int:
        for b in buckets:
            if n <= b:
                return b
        raise ValueError(f"query dimension {n} exceeds largest bucket {buckets[-1]}")

    def _key_of(self, q: PendingQuery) -> tuple:
        """The (plan, term, rect) bucket a query lands in.

        The plan leads the key so buckets are plan-homogeneous: one flushed
        batch = one compiled plan × shape.
        """
        return (
            q.plan,
            self._bucket_of(max(len(q.terms), 1), self.term_buckets),
            self._bucket_of(max(len(q.rects), 1), self.rect_buckets),
        )

    # ------------------------------------------------------------------
    def add(self, q: PendingQuery) -> list[RawBatch]:
        """Enqueue one query; returns any batch made full by it."""
        key = self._key_of(q)
        self._pending.setdefault(key, []).append(q)
        if len(self._pending[key]) >= self.max_batch:
            return [self._emit(key, self._pending.pop(key))]
        return []

    def flush(self) -> list[RawBatch]:
        """Emit everything still pending (end of trace / wait timeout)."""
        out = [self._emit(k, qs) for k, qs in self._pending.items()]
        self._pending.clear()
        return out

    # ------------------------------------------------------------------
    def _emit(self, key: tuple, qs: list[PendingQuery]) -> RawBatch:
        plan, d, r = key
        B = self._bucket_of(len(qs), self.batch_sizes)
        shape = BucketShape(B, d, r)
        terms = np.full((B, d), -1, dtype=np.int32)
        rects = np.zeros((B, r, 4), dtype=np.float32)
        rects[:, :, 0] = 1.0  # empty-rect padding (x1 < x0)
        rects[:, :, 1] = 1.0
        amps = np.zeros((B, r), dtype=np.float32)
        for i, q in enumerate(qs):
            nt, nr = len(q.terms), len(q.rects)
            terms[i, :nt] = q.terms
            rects[i, :nr] = q.rects
            amps[i, :nr] = q.amps
            self.pad_elements += (d - nt) + (r - nr)
            self.real_elements += nt + nr
        self.pad_slots += B - len(qs)
        self.real_slots += len(qs)
        self.emitted_shapes.add(shape)
        return RawBatch(shape, [q.qid for q in qs], terms, rects, amps, plan)

    # ------------------------------------------------------------------
    @property
    def padding_overhead(self) -> float:
        """Fraction of emitted batch slots that were padding."""
        total = self.pad_slots + self.real_slots
        return self.pad_slots / total if total else 0.0

    @property
    def element_padding_overhead(self) -> float:
        """Fraction of term/rect cells inside real rows that were padding."""
        total = self.pad_elements + self.real_elements
        return self.pad_elements / total if total else 0.0


@dataclass
class DeadlineBatcher(ShapeBucketedBatcher):
    """Clock-aware batcher: flush on full **or** on the oldest query's deadline.

    Each bucket remembers when its oldest pending query was enqueued; that
    query's deadline is ``enqueue_time + max_wait_s``.  The serve loop asks
    :meth:`next_deadline` for the earliest deadline across buckets (its next
    timer event) and :meth:`due` for every bucket whose deadline has passed,
    in deadline order — so a half-full bucket never holds a query hostage
    for longer than ``max_wait_s``.

    Two edge cases pin the semantics (unit-tested):

    * ``max_wait_s = 0``   — every query flushes immediately in a batch of
      one: minimum latency, maximum padding.
    * ``max_wait_s = inf`` — deadlines never fire; behavior is bit-identical
      to the count-only :class:`ShapeBucketedBatcher` (PR 1).

    The clock is whatever the caller passes as ``now`` — wall seconds in a
    live server, virtual seconds in simulation/tests — which is what makes
    deadline behavior deterministic under test.
    """

    max_wait_s: float = float("inf")

    def __post_init__(self) -> None:
        super().__post_init__()
        if self.max_wait_s < 0:
            raise ValueError("max_wait_s must be >= 0 (inf = count-only)")
        self._oldest: dict[tuple, float] = {}

    # ------------------------------------------------------------------
    def add(self, q: PendingQuery, now: float = 0.0) -> list[RawBatch]:
        """Enqueue at time ``now``; returns any batch made full by it."""
        key = self._key_of(q)
        out = super().add(q)
        if out:
            self._oldest.pop(key, None)
        else:
            self._oldest.setdefault(key, now)
        return out

    # ------------------------------------------------------------------
    def next_deadline(self) -> float | None:
        """Earliest pending deadline, or ``None`` if nothing can expire."""
        if not self._oldest or self.max_wait_s == float("inf"):
            return None
        return min(self._oldest.values()) + self.max_wait_s

    def due(self, now: float) -> list[RawBatch]:
        """Flush every bucket whose oldest query expired by ``now``.

        Batches come back in deadline order (oldest expiry first), so a
        replay loop draining multiple overdue buckets services them in the
        order their queries would have timed out.
        """
        if self.max_wait_s == float("inf"):
            return []
        # key=t only: bucket keys lead with a QueryPlan (unorderable), so a
        # tied deadline must fall back to stable insertion order, not key
        # comparison
        ripe = sorted(
            ((t, k) for k, t in self._oldest.items() if t + self.max_wait_s <= now),
            key=lambda tk: tk[0],
        )
        out = []
        for _, key in ripe:
            del self._oldest[key]
            out.append(self._emit(key, self._pending.pop(key)))
        return out

    def flush(self) -> list[RawBatch]:
        self._oldest.clear()
        return super().flush()

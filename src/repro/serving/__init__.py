"""repro.serving — the production serving layer around the geo engine.

The paper evaluates query processing against *real query traces*: skewed,
bursty traffic where most of the end-to-end cost is decided by the layer
around the index, not the index alone.  This package is that layer:

    trace ──► fingerprint ──► result cache ──► shape-bucketed batcher
                                  │                      │
                                  │ hit                  ▼ miss batches
                                  ▼              sharded executor
                               response ◄──── scatter-gather top-k merge

* :mod:`repro.serving.fingerprint` — normalized query keys (sorted terms +
  quantized footprint rects) so geographically-near duplicates collide.
* :mod:`repro.serving.cache`       — LRU and cost-aware Landlord caches
  (entry-count capacity + optional result-payload byte budget).
* :mod:`repro.serving.batcher`     — dynamic micro-batcher over a small
  registry of padded static shapes (bounded jit recompiles); the
  :class:`DeadlineBatcher` variant also flushes a bucket when its oldest
  query's ``max_wait_s`` deadline expires.
* :mod:`repro.serving.executor`    — single-device and doc-sharded
  scatter-gather execution of query batches.
* :mod:`repro.serving.pending`     — the in-flight pending-result table:
  a miss whose fingerprint is already queued or executing subscribes to
  that batch's result instead of re-enqueueing (request coalescing).
* :mod:`repro.serving.server`      — the serve loop (closed-loop wall-clock
  replay or event-driven open-loop replay over stamped arrival times, with
  ``n_workers`` parallel executor slots draining a FIFO dispatch queue)
  plus QPS / latency-decomposition / hit-rate / padding / SLO metrics.
"""
from repro.serving.batcher import BucketShape, DeadlineBatcher, ShapeBucketedBatcher
from repro.serving.cache import LandlordCache, LRUCache, make_cache
from repro.serving.executor import MeshExecutor, ShardedExecutor, SingleDeviceExecutor
from repro.serving.factory import EXECUTOR_KINDS, make_executor
from repro.serving.fingerprint import query_fingerprint
from repro.serving.pending import PendingEntry, PendingTable
from repro.serving.server import BatchEvent, GeoServer, ServeReport

__all__ = [
    "BucketShape",
    "DeadlineBatcher",
    "ShapeBucketedBatcher",
    "LRUCache",
    "LandlordCache",
    "make_cache",
    "SingleDeviceExecutor",
    "ShardedExecutor",
    "MeshExecutor",
    "EXECUTOR_KINDS",
    "make_executor",
    "query_fingerprint",
    "PendingEntry",
    "PendingTable",
    "BatchEvent",
    "GeoServer",
    "ServeReport",
]

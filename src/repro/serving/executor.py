"""Batch executors: single-device, doc-sharded scatter-gather, SPMD mesh.

The executor is the serving layer's view of the engine: it takes a padded
:class:`~repro.core.algorithms.QueryBatch` and returns a
:class:`~repro.core.algorithms.TopKResult` with *global* doc ids.

* :class:`SingleDeviceExecutor` wraps one :class:`GeoSearchEngine`.
* :class:`ShardedExecutor` partitions the corpus doc-wise into ``S`` shards
  with a :class:`~repro.core.distributed.Partitioner` strategy object
  (hash round-robin, Morton-contiguous, or KD region ranges), builds one
  engine per shard, **scatters** each batch to the shards it can reach,
  and **gathers** the per-shard local top-k lists into a global top-k by
  a k-way merge.  Per-query merge traffic is O(k · S), independent of
  corpus size — the property that lets the architecture scale out.
* :class:`MeshExecutor` is the SPMD twin: one ``shard_map`` serve step per
  plan, with the per-stage byte counters *measured inside the step* and
  psum-reduced over the doc axes.

Footprint routing (``routing="footprint"``): each shard carries a
coverage-grid SAT of its toe prints (:mod:`repro.core.distributed`).
:meth:`ShardedExecutor.route_batch` tests every query footprint against
every shard's SAT; ``run`` then *skips* shards no query touches — result-
preserving because ``require_geo`` ranking scores a doc −inf when its geo
score is 0, so an unreachable shard can only return empty lists.  The mesh
executor gets the same semantics from ``make_serve_fn(with_routing=True)``,
which masks untouched shards inside the jit'd step.  Both report
``shards_touched`` (per query) and ``shards_visited`` (per batch) stats in
footprint mode; ``routing="broadcast"`` (the default) keeps the original
visit-everything behaviour and stat keys.

Plan-driven execution: every executor accepts ``run(batch, plan=...)``
with a :class:`~repro.core.planner.QueryPlan`, and ``algorithm="auto"``
builds a cost-based planner over the executor's corpus so the serving
layer can ask :meth:`plan_query` for each query's cheapest pipeline
before batching (plan-homogeneous buckets → one compile per plan×shape).
Fixed-algorithm executors return ``None`` from :meth:`plan_query` and run
exactly as before.

Telemetry: every executor exposes :meth:`attach_telemetry` (the server
calls it when built with a :class:`~repro.obs.Telemetry` handle).  With a
tracer attached, executors record **wall-clock** spans on the trace's
executor process — the engine call for :class:`SingleDeviceExecutor`, one
span per shard of :class:`ShardedExecutor`'s sequential scatter-gather
loop, and the mesh step for :class:`MeshExecutor` — and route their
engines' compile counters / the planner's probe counters into the metrics
registry.  ``telemetry=None`` (the default) leaves ``run`` untouched.
"""
from __future__ import annotations

from dataclasses import replace

import jax
import numpy as np

from repro.core import algorithms as alg
from repro.core import ranking
from repro.core.distributed import (
    MortonPartitioner,
    Partitioner,
    _require_partitioner,
    _valid_rects_np,
    coverage_grid_np,
    coverage_sat_np,
    footprint_touch_np,
)
from repro.core.engine import GeoSearchEngine
from repro.core.planner import CostModel, Planner, QueryPlan
from repro.core.text_index import global_idf_np

ROUTINGS = ("broadcast", "footprint")


def _check_routing(routing: str) -> str:
    if routing not in ROUTINGS:
        raise ValueError(f"routing must be one of {ROUTINGS}, got {routing!r}")
    return routing


def _reject_partition_kwarg(kw: dict) -> None:
    """The ``partition="hash"|"geo"`` string flag is gone — fail loudly
    instead of letting the stale kwarg leak into engine query kwargs."""
    if "partition" in kw:
        raise TypeError(
            "partition= strings were replaced by the Partitioner API: pass "
            "partitioner=HashPartitioner() / MortonPartitioner() / "
            "RegionRangePartitioner() (strings resolve only at the CLI "
            "boundary via repro.core.distributed.resolve_partitioner)"
        )


class SingleDeviceExecutor:
    """Run batches through one engine; the trivial executor."""

    def __init__(self, engine: GeoSearchEngine, algorithm: str = "k_sweep", **kw):
        self.engine = engine
        self.algorithm = algorithm
        self.kw = kw
        self.telemetry = None
        self.planner: Planner | None = None
        if algorithm == "auto":
            self.planner = Planner.from_engine(
                engine, fused=bool(kw.get("fused", False))
            )

    @property
    def top_k(self) -> int:
        return self.engine.budgets.top_k

    def attach_telemetry(self, telemetry) -> None:
        self.telemetry = telemetry
        if telemetry and telemetry.metrics is not None:
            self.engine.metrics = telemetry.metrics
            if self.planner is not None:
                self.planner.model.metrics = telemetry.metrics

    def plan_query(self, terms, rects, amps) -> QueryPlan | None:
        """Cheapest plan for one query; ``None`` when the algorithm is fixed."""
        if self.planner is None:
            return None
        return self.planner.plan_query(terms, rects, amps)

    def run(
        self, batch: alg.QueryBatch, plan: QueryPlan | None = None
    ) -> alg.TopKResult:
        tracer = self.telemetry.tracer if self.telemetry else None
        t0 = tracer.wall_now() if tracer is not None else 0.0
        if plan is not None:
            res = self.engine.query(batch, plan=plan, **self.kw)
        else:
            res = self.engine.query(batch, self.algorithm, **self.kw)
        if tracer is not None:
            label = plan.label if plan is not None else self.algorithm
            tracer.span(
                "engine", f"query[{label}]", t0, tracer.wall_now(),
                args={"batch": int(batch.terms.shape[0])},
            )
        return res


class ShardedExecutor:
    """Doc-sharded scatter-gather execution over per-shard engines.

    Shard dispatch is *overlapped* by default: every routed shard's query
    is submitted back-to-back (jax dispatch is asynchronous, so the device
    work for shard ``s+1`` starts while shard ``s`` still computes) and the
    host synchronizes exactly once, when the merge pulls the per-shard
    top-k lists.  ``overlap=False`` restores the strictly sequential loop
    (each shard runs to completion before the next is dispatched) — the
    two paths are bit-identical in results and per-stage counters, which
    ``tests/test_serving.py`` pins.
    """

    def __init__(
        self,
        engines,
        global_ids,
        algorithm: str = "k_sweep",
        routing: str = "broadcast",
        overlap: bool = True,
        **kw,
    ):
        _reject_partition_kwarg(kw)
        self.engines: list[GeoSearchEngine] = engines
        self.global_ids: list[np.ndarray] = global_ids  # per shard: local → global
        self.algorithm = algorithm
        self.routing = _check_routing(routing)
        self.overlap = overlap
        self._coverage_sats: np.ndarray | None = None  # lazy f32[S, G+1, G+1]
        self.kw = kw
        self.telemetry = None
        self.planner: Planner | None = None
        if algorithm == "auto":
            # corpus-global features: df and tile coverage summed over the
            # shards, block metadata concatenated
            model = CostModel.from_shards(
                [e.index for e in engines], engines[0].budgets
            )
            self.planner = Planner(
                model=model,
                candidates=Planner.make_candidates(
                    engines[0].budgets, fused=bool(kw.get("fused", False))
                ),
            )

    @property
    def n_shards(self) -> int:
        return len(self.engines)

    @property
    def top_k(self) -> int:
        return self.engines[0].budgets.top_k

    def attach_telemetry(self, telemetry) -> None:
        self.telemetry = telemetry
        if telemetry and telemetry.metrics is not None:
            for eng in self.engines:
                eng.metrics = telemetry.metrics
            if self.planner is not None:
                self.planner.model.metrics = telemetry.metrics

    def plan_query(self, terms, rects, amps) -> QueryPlan | None:
        if self.planner is None:
            return None
        return self.planner.plan_query(terms, rects, amps)

    # ------------------------------------------------------------------
    @staticmethod
    def build(
        doc_terms: list[np.ndarray],
        doc_rects: np.ndarray,
        doc_amps: np.ndarray,
        n_terms: int,
        pagerank: np.ndarray,
        n_shards: int,
        partitioner: Partitioner | None = None,
        grid: int = 64,
        budgets: alg.QueryBudgets | None = None,
        weights: ranking.RankWeights | None = None,
        algorithm: str = "k_sweep",
        routing: str = "broadcast",
        compress: "bool | str" = False,
        layout: str = "docid",
        overlap: bool = True,
        **kw,
    ) -> "ShardedExecutor":
        _reject_partition_kwarg(kw)
        budgets = budgets or alg.QueryBudgets()
        partitioner = _require_partitioner(partitioner, default=MortonPartitioner)
        shard_ids = np.asarray(partitioner.assign(doc_rects, n_shards))
        idf_global = global_idf_np(doc_terms, n_terms)
        engines, gids = [], []
        for s in range(n_shards):
            # ascending global ids in-shard: local tie-breaks match global
            sel = np.flatnonzero(shard_ids == s)
            # global IDF built in directly: impacts round to f32 once from
            # partition-independent statistics, so per-doc scores are
            # bit-identical across shard layouts (routing equivalence gate)
            eng = GeoSearchEngine.build(
                [doc_terms[i] for i in sel],
                doc_rects[sel],
                doc_amps[sel],
                n_terms,
                pagerank=pagerank[sel],
                grid=grid,
                budgets=budgets,
                weights=weights,
                idf=idf_global,
                compress=compress,
                layout=layout,
            )
            engines.append(eng)
            gids.append(sel.astype(np.int32))
        return ShardedExecutor(
            engines, gids, algorithm, routing=routing, overlap=overlap, **kw
        )

    # ------------------------------------------------------------------
    def _coverage(self) -> np.ndarray:
        """Stacked per-shard coverage SATs ``f32[S, G+1, G+1]`` (lazy)."""
        if self._coverage_sats is None:
            from repro.core.spatial_index import SCALE_BLOCK

            sats = []
            for eng in self.engines:
                sp = eng.index.spatial
                amps = np.asarray(sp.tp_amps).astype(np.float32)
                if sp.tp_amp_scale.shape[0]:  # decode int8 amp stores
                    sc = np.asarray(sp.tp_amp_scale)
                    amps = amps * np.repeat(sc, SCALE_BLOCK)[: amps.shape[0]]
                sats.append(
                    coverage_sat_np(
                        coverage_grid_np(
                            np.asarray(sp.tp_rects).astype(np.float32), amps
                        )
                    )
                )
            self._coverage_sats = np.stack(sats)
        return self._coverage_sats

    def route_batch(self, batch: alg.QueryBatch) -> tuple[np.ndarray, np.ndarray]:
        """Footprint-routing decision for a batch.

        Returns ``(visit bool[S], touched f64[B])``: which shards to
        scatter the batch to (any query's footprints reach them) and how
        many shards each query's own footprints touch.
        """
        touch = footprint_touch_np(
            self._coverage(), np.asarray(batch.rects), np.asarray(batch.amps)
        )  # [S, B]
        return touch.any(axis=1), touch.sum(axis=0, dtype=np.float64)

    def run(
        self, batch: alg.QueryBatch, plan: QueryPlan | None = None
    ) -> alg.TopKResult:
        """Scatter the batch to the routed shards; gather + merge top-k."""
        all_ids, all_scores = [], []
        stats_acc: dict[str, np.ndarray] = {}
        visit = np.ones(self.n_shards, dtype=bool)
        if self.routing == "footprint":
            visit, touched = self.route_batch(batch)
            if not _valid_rects_np(batch.rects, batch.amps).any():
                # all-padding batch (server warmup): broadcast so every
                # shard engine still compiles during the warmup pass
                visit[:] = True
            stats_acc["shards_touched"] = touched
            stats_acc["shards_visited"] = np.float64(visit.sum())
            if not visit.any():
                b, k = batch.terms.shape[0], self.top_k
                return alg.TopKResult(
                    ids=np.full((b, k), -1, dtype=np.int32),
                    scores=np.full((b, k), -np.inf, dtype=np.float32),
                    stats=stats_acc,
                )
        tracer = self.telemetry.tracer if self.telemetry else None
        label = plan.label if plan is not None else self.algorithm
        # phase 1 — scatter: dispatch every routed shard's query.  jax
        # dispatch is asynchronous, so with overlap the device work of all
        # shards is in flight before any result is pulled to host
        pending = []
        for shard, (eng, gid) in enumerate(zip(self.engines, self.global_ids)):
            if not visit[shard]:
                continue
            t0 = tracer.wall_now() if tracer is not None else 0.0
            if plan is not None:
                # each shard engine re-clamps the plan's sweep budget to
                # its own toe-print store inside _compiled
                res = eng.query(batch, plan=plan, **self.kw)
            else:
                res = eng.query(batch, self.algorithm, **self.kw)
            if not self.overlap:
                # sequential reference path: shard s completes before
                # shard s+1 dispatches
                jax.block_until_ready((res.ids, res.scores))
            pending.append((shard, gid, res, t0))
        # phase 2 — gather: the single host sync point per shard result
        for shard, gid, res, t0 in pending:
            ids = np.asarray(res.ids)
            scores = np.asarray(res.scores).copy()
            valid = ids >= 0
            g = np.where(valid, gid[np.clip(ids, 0, len(gid) - 1)], -1)
            scores[~valid] = -np.inf
            all_ids.append(g)
            all_scores.append(scores)
            for key, v in res.stats.items():
                v = np.asarray(v, dtype=np.float64)
                stats_acc[key] = stats_acc.get(key, 0.0) + v
            if tracer is not None:
                # span runs from this shard's dispatch to its host pull —
                # under overlap, shard spans legitimately overlap in time
                tracer.span(
                    f"shard {shard}", f"query[{label}]", t0, tracer.wall_now(),
                    args={"batch": int(batch.terms.shape[0])},
                )
        k = all_ids[0].shape[-1]
        ids = np.concatenate(all_ids, axis=-1)  # [B, S*k]
        scores = np.concatenate(all_scores, axis=-1)
        # gather: global top-k, ties broken by lower global docID
        order = np.lexsort((ids, -scores), axis=-1)[:, :k]
        m_ids = np.take_along_axis(ids, order, axis=-1)
        m_scores = np.take_along_axis(scores, order, axis=-1)
        m_ids = np.where(np.isfinite(m_scores), m_ids, -1)
        return alg.TopKResult(ids=m_ids, scores=m_scores, stats=stats_acc)


class MeshExecutor:
    """SPMD executor: one ``shard_map`` serve step per plan over a mesh.

    The mesh-parallel twin of :class:`ShardedExecutor` — the same doc-wise
    partitioning, but all shards execute concurrently on their own devices
    and the top-k merge runs as ``all_gather`` collectives inside the jit'd
    step (:func:`repro.core.distributed.make_serve_fn`).  The doc/query
    mesh axes are resolved from the logical sharding rules
    (:mod:`repro.sharding.specs`: ``docs`` → ('pod','data'), ``queries`` →
    ('model',)), so the same code follows whatever mesh topology is in use.

    Requires a multi-device runtime (or ``XLA_FLAGS=
    --xla_force_host_platform_device_count=N``); exercised by the
    subprocess tests in ``tests/test_distributed.py``.

    Per-stage byte counters are **measured inside the step**: each shard's
    per-query stats vectors are psum-reduced over the doc axes and ride
    back with the ids/scores (``make_serve_fn(with_stats=True)``), so mesh
    serving reports exact traffic — the same numbers the host-side
    executors measure, asserted equal in ``tests/test_serving.py``.

    Serve steps are compiled lazily per plan: the fixed-algorithm step at
    construction, and one step per distinct :class:`QueryPlan` the planner
    selects under ``algorithm="auto"``.
    """

    def __init__(
        self,
        mesh,
        serve_fn,
        sharded_index,
        top_k: int,
        budgets: alg.QueryBudgets | None = None,
        algorithm: str = "k_sweep",
        n_rect_slots: int = 4,
        block_size: int = 128,
        weights: ranking.RankWeights | None = None,
        doc_axes: tuple[str, ...] = ("data",),
        query_axis: str = "model",
        fused: bool = False,
        routing: str = "broadcast",
    ):
        self.mesh = mesh
        self._index = sharded_index
        self.top_k = top_k
        self.budgets = budgets or alg.QueryBudgets(top_k=top_k)
        self.algorithm = algorithm
        self.n_rect_slots = n_rect_slots  # doc footprint slots (R)
        self.block_size = block_size  # block-max metadata granularity
        self.weights = weights or ranking.RankWeights()
        self.doc_axes = doc_axes
        self.query_axis = query_axis
        self.fused = fused
        self.routing = _check_routing(routing)
        # plan (or None = the construction-time fixed config) → serve step
        self._serve_fns: dict = {None: serve_fn}
        self.telemetry = None
        self.planner: Planner | None = None
        if algorithm == "auto":
            self.planner = Planner(
                model=CostModel.from_sharded_index(sharded_index, self.budgets),
                candidates=Planner.make_candidates(self.budgets, fused=fused),
            )

    @staticmethod
    def build(
        doc_terms: list[np.ndarray],
        doc_rects: np.ndarray,
        doc_amps: np.ndarray,
        n_terms: int,
        pagerank: np.ndarray,
        mesh,
        partitioner: Partitioner | None = None,
        grid: int = 64,
        budgets: alg.QueryBudgets | None = None,
        weights: ranking.RankWeights | None = None,
        algorithm: str = "k_sweep",
        fused: bool = False,
        routing: str = "broadcast",
        compress: "bool | str" = False,
        layout: str = "docid",
        **kw,
    ) -> "MeshExecutor":
        from repro.core.distributed import make_serve_fn, shard_corpus_np
        from repro.sharding.specs import DEFAULT_RULES

        _reject_partition_kwarg(kw)
        if kw:
            raise TypeError(f"unexpected keyword arguments: {sorted(kw)}")
        budgets = budgets or alg.QueryBudgets()
        partitioner = _require_partitioner(partitioner, default=MortonPartitioner)
        doc_axes = tuple(a for a in DEFAULT_RULES["docs"] if a in mesh.axis_names)
        query_axis = next(a for a in DEFAULT_RULES["queries"] if a in mesh.axis_names)
        n_shards = 1
        for a in doc_axes:
            n_shards *= mesh.shape[a]
        sharded = shard_corpus_np(
            doc_terms, doc_rects, doc_amps, pagerank, n_terms,
            n_shards, partitioner, grid=grid, compress=compress,
            layout=layout,
        )
        # sweeps cannot exceed a shard's toe-print store (same clamp as
        # GeoSearchEngine.build applies for the single-index case)
        budgets = replace(
            budgets,
            sweep_budget=min(budgets.sweep_budget, sharded.tp_rects.shape[1]),
        )
        weights = weights or ranking.RankWeights()
        serve_algorithm = "k_sweep" if algorithm == "auto" else algorithm
        serve = make_serve_fn(
            mesh, budgets, weights,
            doc_axes=doc_axes, query_axis=query_axis,
            algorithm=serve_algorithm, grid=grid, n_terms=n_terms,
            fused=fused, block_size=sharded.block_size,
            with_stats=True, with_routing=routing == "footprint",
            max_term_blocks=sharded.max_term_blocks,
            layout=sharded.layout,
            max_term_segments=sharded.max_term_segments,
        )
        return MeshExecutor(
            mesh, serve, sharded, budgets.top_k,
            budgets=budgets, algorithm=algorithm,
            n_rect_slots=doc_rects.shape[1],
            block_size=sharded.block_size,
            weights=weights, doc_axes=doc_axes, query_axis=query_axis,
            fused=fused, routing=routing,
        )

    @property
    def n_shards(self) -> int:
        return self._index.n_shards

    def attach_telemetry(self, telemetry) -> None:
        self.telemetry = telemetry
        if telemetry and telemetry.metrics is not None:
            if self.planner is not None:
                self.planner.model.metrics = telemetry.metrics

    def plan_query(self, terms, rects, amps) -> QueryPlan | None:
        if self.planner is None:
            return None
        return self.planner.plan_query(terms, rects, amps)

    def _serve_for(self, plan: QueryPlan | None):
        """The (lazily compiled) shard_map serve step for a plan."""
        if plan in self._serve_fns:
            return self._serve_fns[plan]
        if self.telemetry and self.telemetry.metrics is not None:
            self.telemetry.metrics.inc("engine.compiled_fns_total")
        from repro.core.distributed import make_serve_fn

        budgets = replace(
            plan.budgets,
            sweep_budget=min(
                plan.budgets.sweep_budget, self._index.tp_rects.shape[1]
            ),
        )
        serve = make_serve_fn(
            self.mesh, budgets, self.weights,
            doc_axes=self.doc_axes, query_axis=self.query_axis,
            algorithm=plan.algorithm, grid=self._index.grid,
            n_terms=self._index.n_terms, fused=plan.fused,
            block_size=self._index.block_size, with_stats=True,
            with_routing=self.routing == "footprint",
            max_term_blocks=self._index.max_term_blocks,
            layout=self._index.layout,
            max_term_segments=self._index.max_term_segments,
        )
        self._serve_fns[plan] = serve
        return serve

    def run(
        self, batch: alg.QueryBatch, plan: QueryPlan | None = None
    ) -> alg.TopKResult:
        serve = self._serve_for(plan)
        tracer = self.telemetry.tracer if self.telemetry else None
        t0 = tracer.wall_now() if tracer is not None else 0.0
        with self.mesh:
            out = serve(self._index, batch)
        if tracer is not None:
            label = plan.label if plan is not None else self.algorithm
            tracer.span(
                "mesh step", f"serve[{label}]", t0, tracer.wall_now(),
                args={"batch": int(batch.terms.shape[0])},
            )
        if len(out) == 3:
            ids, scores, stats = out
        else:  # hand-built executor around a stats-less make_serve_fn
            (ids, scores), stats = out, {}
        return alg.TopKResult(
            ids=ids,
            scores=scores,
            stats={k: np.asarray(v) for k, v in stats.items()},
        )

"""Batch executors: single-device and doc-sharded scatter-gather.

The executor is the serving layer's view of the engine: it takes a padded
:class:`~repro.core.algorithms.QueryBatch` and returns a
:class:`~repro.core.algorithms.TopKResult` with *global* doc ids.

* :class:`SingleDeviceExecutor` wraps one :class:`GeoSearchEngine`.
* :class:`ShardedExecutor` partitions the corpus doc-wise into ``S`` shards
  (``hash`` round-robin or ``geo`` Morton-contiguous, the same policies as
  :mod:`repro.core.distributed`), builds one engine per shard, **scatters**
  each batch to every shard, and **gathers** the per-shard local top-k
  lists into a global top-k by a k-way merge.  Per-query merge traffic is
  O(k · S), independent of corpus size — the property that lets the
  architecture scale out.

  On a multi-device runtime each shard's engine naturally lands on its own
  device; on a single host the scatter loop degrades gracefully to a
  sequential sweep over shards (the mesh-parallel ``shard_map`` variant
  lives in :func:`repro.core.distributed.make_serve_fn`).  Either way the
  merged results are equivalent to a single-device engine over the full
  corpus — unit-tested in ``tests/test_serving.py``.
"""
from __future__ import annotations

from dataclasses import replace

import numpy as np

from repro.core import algorithms as alg
from repro.core import ranking
from repro.core.distributed import partition_order
from repro.core.engine import GeoSearchEngine
from repro.core.text_index import global_idf_np, rescale_impacts_to_global


class SingleDeviceExecutor:
    """Run batches through one engine; the trivial executor."""

    def __init__(self, engine: GeoSearchEngine, algorithm: str = "k_sweep", **kw):
        self.engine = engine
        self.algorithm = algorithm
        self.kw = kw

    @property
    def top_k(self) -> int:
        return self.engine.budgets.top_k

    def run(self, batch: alg.QueryBatch) -> alg.TopKResult:
        return self.engine.query(batch, self.algorithm, **self.kw)


class ShardedExecutor:
    """Doc-sharded scatter-gather execution over per-shard engines."""

    def __init__(self, engines, global_ids, algorithm: str = "k_sweep", **kw):
        self.engines: list[GeoSearchEngine] = engines
        self.global_ids: list[np.ndarray] = global_ids  # per shard: local → global
        self.algorithm = algorithm
        self.kw = kw

    @property
    def n_shards(self) -> int:
        return len(self.engines)

    @property
    def top_k(self) -> int:
        return self.engines[0].budgets.top_k

    # ------------------------------------------------------------------
    @staticmethod
    def build(
        doc_terms: list[np.ndarray],
        doc_rects: np.ndarray,
        doc_amps: np.ndarray,
        n_terms: int,
        pagerank: np.ndarray,
        n_shards: int,
        partition: str = "geo",
        grid: int = 64,
        budgets: alg.QueryBudgets | None = None,
        weights: ranking.RankWeights | None = None,
        algorithm: str = "k_sweep",
        **kw,
    ) -> "ShardedExecutor":
        budgets = budgets or alg.QueryBudgets()
        order = partition_order(doc_rects, n_shards, partition)
        idf_global = global_idf_np(doc_terms, n_terms)
        per = (len(doc_terms) + n_shards - 1) // n_shards
        engines, gids = [], []
        for s in range(n_shards):
            sel = order[s * per : (s + 1) * per]
            eng = GeoSearchEngine.build(
                [doc_terms[i] for i in sel],
                doc_rects[sel],
                doc_amps[sel],
                n_terms,
                pagerank=pagerank[sel],
                grid=grid,
                budgets=budgets,
                weights=weights,
            )
            # broadcast global term statistics to the shard (global IDF)
            eng.index = replace(
                eng.index,
                text=rescale_impacts_to_global(eng.index.text, idf_global),
            )
            engines.append(eng)
            gids.append(sel.astype(np.int32))
        return ShardedExecutor(engines, gids, algorithm, **kw)

    # ------------------------------------------------------------------
    def run(self, batch: alg.QueryBatch) -> alg.TopKResult:
        """Scatter the batch to all shards; gather + merge local top-k."""
        all_ids, all_scores = [], []
        stats_acc: dict[str, np.ndarray] = {}
        for eng, gid in zip(self.engines, self.global_ids):
            res = eng.query(batch, self.algorithm, **self.kw)
            ids = np.asarray(res.ids)
            scores = np.asarray(res.scores).copy()
            valid = ids >= 0
            g = np.where(valid, gid[np.clip(ids, 0, len(gid) - 1)], -1)
            scores[~valid] = -np.inf
            all_ids.append(g)
            all_scores.append(scores)
            for key, v in res.stats.items():
                v = np.asarray(v, dtype=np.float64)
                stats_acc[key] = stats_acc.get(key, 0.0) + v
        k = all_ids[0].shape[-1]
        ids = np.concatenate(all_ids, axis=-1)  # [B, S*k]
        scores = np.concatenate(all_scores, axis=-1)
        # gather: global top-k, ties broken by lower global docID
        order = np.lexsort((ids, -scores), axis=-1)[:, :k]
        m_ids = np.take_along_axis(ids, order, axis=-1)
        m_scores = np.take_along_axis(scores, order, axis=-1)
        m_ids = np.where(np.isfinite(m_scores), m_ids, -1)
        return alg.TopKResult(ids=m_ids, scores=m_scores, stats=stats_acc)


class MeshExecutor:
    """SPMD executor: one ``shard_map`` serve step over a device mesh.

    The mesh-parallel twin of :class:`ShardedExecutor` — the same doc-wise
    partitioning, but all shards execute concurrently on their own devices
    and the top-k merge runs as ``all_gather`` collectives inside the jit'd
    step (:func:`repro.core.distributed.make_serve_fn`).  The doc/query
    mesh axes are resolved from the logical sharding rules
    (:mod:`repro.sharding.specs`: ``docs`` → ('pod','data'), ``queries`` →
    ('model',)), so the same code follows whatever mesh topology is in use.

    Requires a multi-device runtime (or ``XLA_FLAGS=
    --xla_force_host_platform_device_count=N``); exercised by the
    subprocess tests in ``tests/test_distributed.py``.

    Per-stage byte counters: the jit'd ``shard_map`` step only returns
    ``(ids, scores)`` — hauling the data-dependent stats arrays through the
    collectives would put host bookkeeping on the hot path.  Instead
    ``run`` models the counters host-side from the batch shape and the
    per-shard capacity budgets (every sweep reads its full
    ``sweep_budget``, every candidate slot probes), using the same keys as
    :class:`ShardedExecutor`'s measured stats.  The model is a per-shard
    *capacity upper bound* of the measured counters — asserted against the
    other executors in ``tests/test_serving.py``.
    """

    def __init__(
        self,
        mesh,
        serve_fn,
        sharded_index,
        top_k: int,
        budgets: alg.QueryBudgets | None = None,
        algorithm: str = "k_sweep",
        n_rect_slots: int = 4,
        block_size: int = 128,
    ):
        self.mesh = mesh
        self._serve = serve_fn
        self._index = sharded_index
        self.top_k = top_k
        self.budgets = budgets or alg.QueryBudgets(top_k=top_k)
        self.algorithm = algorithm
        self.n_rect_slots = n_rect_slots  # doc footprint slots (R)
        self.block_size = block_size  # block-max metadata granularity

    @staticmethod
    def build(
        doc_terms: list[np.ndarray],
        doc_rects: np.ndarray,
        doc_amps: np.ndarray,
        n_terms: int,
        pagerank: np.ndarray,
        mesh,
        partition: str = "geo",
        grid: int = 64,
        budgets: alg.QueryBudgets | None = None,
        weights: ranking.RankWeights | None = None,
        algorithm: str = "k_sweep",
        fused: bool = False,
    ) -> "MeshExecutor":
        from repro.core.distributed import make_serve_fn, shard_corpus_np
        from repro.sharding.specs import DEFAULT_RULES

        budgets = budgets or alg.QueryBudgets()
        doc_axes = tuple(a for a in DEFAULT_RULES["docs"] if a in mesh.axis_names)
        query_axis = next(a for a in DEFAULT_RULES["queries"] if a in mesh.axis_names)
        n_shards = 1
        for a in doc_axes:
            n_shards *= mesh.shape[a]
        sharded = shard_corpus_np(
            doc_terms, doc_rects, doc_amps, pagerank, n_terms,
            n_shards, partition, grid=grid,
        )
        # sweeps cannot exceed a shard's toe-print store (same clamp as
        # GeoSearchEngine.build applies for the single-index case)
        budgets = replace(
            budgets,
            sweep_budget=min(budgets.sweep_budget, sharded.tp_rects.shape[1]),
        )
        serve = make_serve_fn(
            mesh, budgets, weights or ranking.RankWeights(),
            doc_axes=doc_axes, query_axis=query_axis,
            algorithm=algorithm, grid=grid, n_terms=n_terms,
            fused=fused, block_size=sharded.block_size,
        )
        return MeshExecutor(
            mesh, serve, sharded, budgets.top_k,
            budgets=budgets, algorithm=algorithm,
            n_rect_slots=doc_rects.shape[1],
            block_size=sharded.block_size,
        )

    @property
    def n_shards(self) -> int:
        return self._index.n_shards

    @property
    def n_postings(self) -> int:
        """Per-shard posting-store length (padded to the largest shard)."""
        return int(self._index.postings.shape[1])

    def _model_stats(self, batch: alg.QueryBatch) -> dict[str, np.ndarray]:
        """Host-side per-query byte counters (capacity model, per shard × S).

        Mirrors the stats keys of :mod:`repro.core.algorithms` for the
        configured algorithm.  Data-dependent quantities (sweeps fetched,
        unique candidates) are replaced by their budget capacities —
        ``k_sweeps`` full sweeps, ``max_candidates`` candidate slots —
        which is what each device's fixed-shape pipeline actually streams
        through memory; only the real term count per query is measured
        from the batch itself.  Every query executes against all ``S``
        doc shards, so the per-shard model is scaled by ``n_shards``.
        """
        terms = np.asarray(batch.terms)
        B = terms.shape[0]
        n_terms_real = (terms >= 0).sum(axis=-1).astype(np.float64)  # [B]
        S = float(self.n_shards)
        bud = self.budgets
        R = self.n_rect_slots
        logp = float(np.ceil(np.log2(max(self.n_postings, 2))))
        if self.algorithm == "k_sweep":
            sweeps = np.full(B, float(bud.k_sweeps))
            fetched = sweeps * bud.sweep_budget
            # early termination / pruning cap the candidate set before text
            # probing; without them every fetched toe print may probe
            select = bud.early_termination or bud.prune
            n_uniq = (
                np.minimum(fetched, float(bud.max_candidates))
                if select
                else fetched
            )
            # streamed-block capacity: whole TILE-aligned windows (+1 tile
            # of alignment slop on the pruned/fused path), in metadata-block
            # units; data-dependent skips are modeled as zero savings
            from repro.kernels.sweep_score.kernel import TILE as tile

            pad_budget = -(-bud.sweep_budget // tile) * tile + tile
            blocks_total = float(bud.k_sweeps * (pad_budget // self.block_size))
            stats = {
                "candidates": fetched,
                "sweeps": sweeps,
                "bytes_spatial": fetched * alg.TP_BYTES,
                "sweep_slack": np.zeros(B),
                "bytes_scored": n_uniq * alg.TP_BYTES,
                "blocks_total": np.full(B, blocks_total),
                "blocks_skipped": np.zeros(B),
                "probes_saved": np.zeros(B),
                "bytes_postings": n_uniq * logp * alg.POSTING_BYTES,
                "seeks": sweeps + n_terms_real,
                "n_probes": n_uniq * n_terms_real,
                "bytes_seq": fetched * alg.TP_BYTES,
                "bytes_random": n_uniq * n_terms_real * 32,
            }
        elif self.algorithm == "text_first":
            n_c = np.full(B, float(bud.max_candidates))
            n_probes = n_c * np.maximum(n_terms_real - 1, 0.0)
            stats = {
                "candidates": n_c,
                "bytes_spatial": n_c * R * (16 + 4),
                "bytes_postings": n_c * alg.POSTING_BYTES
                + bud.max_candidates * alg.POSTING_BYTES,
                "fetch_runs": n_c,
                "seeks": n_c + n_terms_real,
                "n_probes": n_probes,
                "bytes_seq": np.full(B, float(bud.max_candidates))
                * alg.POSTING_BYTES,
                "bytes_random": n_c * R * (16 + 4) + n_probes * 32,
            }
        else:  # geo_first
            n_c = np.full(B, float(bud.max_candidates))
            stats = {
                "candidates": n_c,
                "bytes_spatial": n_c * 4 + n_c * R * (16 + 4),
                "bytes_postings": n_c * logp * alg.POSTING_BYTES,
                "seeks": 2 * n_c,
                "n_probes": n_c * n_terms_real,
                "bytes_seq": np.zeros(B),
                "bytes_random": n_c * 4 + n_c * R * (16 + 4)
                + n_c * n_terms_real * 32,
            }
        return {k: v * S for k, v in stats.items()}

    def run(self, batch: alg.QueryBatch) -> alg.TopKResult:
        with self.mesh:
            ids, scores = self._serve(self._index, batch)
        return alg.TopKResult(ids=ids, scores=scores, stats=self._model_stats(batch))

"""GeoServer: the trace-driven serve loop.

One query's life:

1. **fingerprint** — the raw (terms, rects, amps) triple is normalized
   (:mod:`repro.serving.fingerprint`); near-duplicate searches collide.
2. **cache lookup** — a hit returns the cached top-k immediately; its
   latency is just the lookup.
3. **batcher** — misses queue in their (terms, rects) shape bucket; a full
   bucket flushes as one padded static-shape batch.
4. **executor** — the batch runs on the engine (single device or sharded
   scatter-gather); per-query rows are scattered back to their submitters,
   latency = completion − arrival (so queue wait inside a bucket counts).
5. **cache fill** — each executed query's result is inserted with its
   *cost* (its share of the batch's measured execution time), which is
   what the Landlord policy spends as eviction credit.

``run_trace`` drives a whole trace through this loop and returns a
:class:`ServeReport` with QPS, p50/p99 latency, cache hit rate, padding
overhead, and the paper's per-stage byte counters (summed over executed
batches — cache hits move no bytes, which is the point).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg
from repro.corpus.synth import TraceQuery
from repro.serving.batcher import PendingQuery, RawBatch, ShapeBucketedBatcher
from repro.serving.fingerprint import query_fingerprint


@dataclass
class QueryResult:
    ids: np.ndarray  # i32[k]
    scores: np.ndarray  # f32[k]


@dataclass
class ServeReport:
    n_queries: int = 0
    wall_s: float = 0.0
    latencies_s: list[float] = field(default_factory=list)
    cache_hits: int = 0
    cache_misses: int = 0
    n_batches: int = 0
    pad_slots: int = 0
    real_slots: int = 0
    element_padding_overhead: float = 0.0
    n_compiled_shapes: int = 0
    stats: dict[str, float] = field(default_factory=dict)  # summed byte counters
    shapes_used: set = field(default_factory=set)  # distinct shapes this run

    @property
    def qps(self) -> float:
        return self.n_queries / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def hit_rate(self) -> float:
        n = self.cache_hits + self.cache_misses
        return self.cache_hits / n if n else 0.0

    @property
    def padding_overhead(self) -> float:
        total = self.pad_slots + self.real_slots
        return self.pad_slots / total if total else 0.0

    def percentile_ms(self, p: float) -> float:
        if not self.latencies_s:
            return 0.0
        return float(np.percentile(np.asarray(self.latencies_s), p) * 1e3)

    def summary(self) -> str:
        per_q = {
            k: v / max(self.n_queries, 1)
            for k, v in sorted(self.stats.items())
            if k.startswith("bytes_") or k in ("seeks", "n_probes", "candidates")
        }
        return (
            f"queries={self.n_queries}  qps={self.qps:,.1f}  "
            f"p50={self.percentile_ms(50):.3f}ms  p99={self.percentile_ms(99):.3f}ms  "
            f"hit_rate={self.hit_rate:.3f}  batches={self.n_batches}  "
            f"padding={self.padding_overhead:.3f}  "
            f"elem_padding={self.element_padding_overhead:.3f}  "
            f"shapes={self.n_compiled_shapes}\n"
            + "  ".join(f"{k}/q={v:,.0f}" for k, v in per_q.items())
        )


class GeoServer:
    """Cache → shape-bucketed batcher → executor, over a query trace."""

    def __init__(
        self,
        executor,
        cache=None,
        batcher: ShapeBucketedBatcher | None = None,
        fingerprint_quant: int = 128,
    ):
        self.executor = executor
        self.cache = cache
        self.batcher = batcher or ShapeBucketedBatcher()
        self.fingerprint_quant = fingerprint_quant
        # qid → (fingerprint key, arrival time)
        self._inflight: dict[int, tuple[tuple, float]] = {}
        self._next_qid = 0

    # ------------------------------------------------------------------
    def run_trace(self, trace: list[TraceQuery], warmup: bool = True) -> ServeReport:
        """Serve a whole trace closed-loop; returns the metrics report.

        ``warmup=True`` pre-compiles the batch shapes the trace will emit
        (predicted by replaying the cache/batcher decisions host-side)
        before the timed loop, so latency percentiles measure serving, not
        XLA compilation.
        """
        report = ServeReport()
        if warmup and trace:
            self._warmup(trace)
        # snapshot cumulative batcher counters so the report is per-run
        b = self.batcher
        base = (b.pad_slots, b.real_slots, b.pad_elements, b.real_elements)
        t_start = time.perf_counter()
        for q in trace:
            t_arr = time.perf_counter()
            if self.cache is not None:
                key = query_fingerprint(
                    q.terms, q.rects, q.amps, quant=self.fingerprint_quant
                )
                hit = self.cache.get(key)
                if hit is not None:
                    report.cache_hits += 1
                    report.latencies_s.append(time.perf_counter() - t_arr)
                    report.n_queries += 1
                    continue
            else:
                key = None  # no cache → fingerprinting is pure overhead
            report.cache_misses += 1
            qid = self._next_qid
            self._next_qid += 1
            self._inflight[qid] = (key, t_arr)
            for batch in self.batcher.add(PendingQuery(qid, q.terms, q.rects, q.amps)):
                self._execute(batch, report)
            report.n_queries += 1
        for batch in self.batcher.flush():
            self._execute(batch, report)
        report.wall_s = time.perf_counter() - t_start
        report.pad_slots = b.pad_slots - base[0]
        report.real_slots = b.real_slots - base[1]
        pad_el, real_el = b.pad_elements - base[2], b.real_elements - base[3]
        report.element_padding_overhead = (
            pad_el / (pad_el + real_el) if pad_el + real_el else 0.0
        )
        report.n_compiled_shapes = len(report.shapes_used)
        assert not self._inflight, "batcher dropped in-flight queries"
        return report

    # ------------------------------------------------------------------
    def _fresh_batcher(self) -> ShapeBucketedBatcher:
        return ShapeBucketedBatcher(
            max_batch=self.batcher.max_batch,
            max_terms=self.batcher.max_terms,
            max_rects=self.batcher.max_rects,
            term_buckets=list(self.batcher.term_buckets),
            rect_buckets=list(self.batcher.rect_buckets),
            batch_sizes=list(self.batcher.batch_sizes),
        )

    def _predict_shapes(self, trace: list[TraceQuery]) -> set:
        """Replay cache + batcher decisions (no execution) → emitted shapes.

        Exact for LRU and for Landlord without eviction pressure; under
        pressure Landlord's cost-dependent evictions may diverge, in which
        case an unpredicted shape simply compiles inside the timed loop.
        """
        cache = (
            type(self.cache)(self.cache.capacity) if self.cache is not None else None
        )
        batcher = self._fresh_batcher()
        pending: dict[int, tuple] = {}
        shapes: set = set()

        def emit(raws):
            for raw in raws:
                shapes.add(raw.shape)
                if cache is not None:
                    for qid in raw.qids:
                        cache.put(pending.pop(qid), True)

        qid = 0
        for q in trace:
            key = query_fingerprint(
                q.terms, q.rects, q.amps, quant=self.fingerprint_quant
            )
            if cache is not None and cache.get(key) is not None:
                continue
            pending[qid] = key
            emit(batcher.add(PendingQuery(qid, q.terms, q.rects, q.amps)))
            qid += 1
        emit(batcher.flush())
        return shapes

    def _warmup(self, trace: list[TraceQuery]) -> None:
        """Pre-compile every predicted batch shape with an inert batch."""
        for shape in sorted(
            self._predict_shapes(trace), key=lambda s: (s.batch, s.d_terms, s.q_rects)
        ):
            terms = np.full((shape.batch, shape.d_terms), -1, dtype=np.int32)
            rects = np.zeros((shape.batch, shape.q_rects, 4), dtype=np.float32)
            rects[:, :, 0] = 1.0
            rects[:, :, 1] = 1.0
            amps = np.zeros((shape.batch, shape.q_rects), dtype=np.float32)
            res = self.executor.run(
                alg.QueryBatch(
                    terms=jnp.asarray(terms),
                    rects=jnp.asarray(rects),
                    amps=jnp.asarray(amps),
                )
            )
            jax.block_until_ready(res.scores)

    @staticmethod
    def _to_query_batch(raw: RawBatch) -> alg.QueryBatch:
        return alg.QueryBatch(
            terms=jnp.asarray(raw.terms),
            rects=jnp.asarray(raw.rects),
            amps=jnp.asarray(raw.amps),
        )

    def _execute(self, raw: RawBatch, report: ServeReport) -> None:
        t0 = time.perf_counter()
        res = self.executor.run(self._to_query_batch(raw))
        ids = np.asarray(res.ids)
        scores = np.asarray(res.scores)
        t_done = time.perf_counter()
        report.n_batches += 1
        report.shapes_used.add(raw.shape)
        # batch cost shared equally by its real queries (Landlord credit)
        cost = (t_done - t0) / max(raw.n_real, 1)
        for row, qid in enumerate(raw.qids):
            key, t_arr = self._inflight.pop(qid)
            report.latencies_s.append(t_done - t_arr)
            if self.cache is not None:
                self.cache.put(
                    key, QueryResult(ids[row].copy(), scores[row].copy()), cost=cost
                )
        for key, v in res.stats.items():
            # only the real rows' work is attributable to served queries,
            # but padded rows burn real bytes too — count everything
            report.stats[key] = report.stats.get(key, 0.0) + float(
                np.asarray(v, dtype=np.float64).sum()
            )

"""GeoServer: the trace-driven serve loop (closed- and open-loop).

One query's life:

1. **fingerprint** — the raw (terms, rects, amps) triple is normalized
   (:mod:`repro.serving.fingerprint`); near-duplicate searches collide.
2. **cache lookup** — a hit returns the cached top-k immediately; its
   latency is just the lookup.
3. **coalesce check** (optional) — a miss whose fingerprint is already in
   a queued or executing batch *subscribes* to that batch's pending result
   (:mod:`repro.serving.pending`) instead of re-enqueueing.
4. **planner** (optional) — when the executor runs ``algorithm="auto"``,
   the miss is routed through the cost-based planner
   (:mod:`repro.core.planner`): cheap host-side features pick the
   cheapest :class:`QueryPlan` (text-first / geo-first / K-SWEEP) for
   *this* query.  Fixed-algorithm executors skip this stage (plan
   ``None``), bit-identically to the pre-planner server.
5. **batcher** — remaining misses queue in their (plan, terms, rects)
   bucket — buckets are *plan-homogeneous*, so a flushed batch compiles
   and runs one plan only; the bucket flushes when it fills *or* when its
   oldest query's deadline (``max_wait_s``) expires
   (:class:`~repro.serving.batcher.DeadlineBatcher`).
6. **dispatch queue → workers** — flushed batches enter a FIFO dispatch
   queue; each of ``n_workers`` executor slots picks up the next batch
   when free, so sharded/mesh executor batches can overlap.
7. **executor** — the batch runs on the engine (single device or sharded
   scatter-gather) under the batch's plan; per-query rows are scattered
   back to their submitters and to any coalesced subscribers, and the
   batch's byte counters / latencies are attributed to its plan in the
   report's per-plan breakdown.
8. **cache fill** — each executed query's result is inserted with its
   *cost* (its share of the batch's measured execution time — the Landlord
   eviction credit) and its *size* (the top-k payload bytes — the Landlord
   byte-budget admission input).

``run_trace`` supports two replay disciplines:

* **closed-loop** (``arrival="closed"``, PR 1 behavior): the next query is
  released as soon as the previous one is handled; wall-clock timing; the
  worker pool degenerates to the one real executor (``n_workers`` must be
  1 — there is only one wall clock).
* **open-loop** (any other ``arrival`` label): queries are released at the
  ``arrival_s`` stamps on the trace regardless of server progress, as an
  event-driven discrete-event simulation over a virtual clock.  Service
  durations are *measured* on the real executor (or supplied via
  ``service_time`` for deterministic tests) and charged to the earliest-
  free of ``n_workers`` parallel worker timelines (``n_workers=1`` is the
  single-busy-server model of PR 2, bit-identically), so queueing delay
  under burst is modeled, not hidden.  Per-query latency is decomposed
  exactly into **batch-wait** (arrival → bucket flush) + **queue-wait**
  (flush → a worker frees up) + **service** (batch execution); coalesced
  queries are charged the same three stages against their twin batch's
  timeline, clamped at their own arrival, so the decomposition still sums
  exactly to total latency for every query.
"""
from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg
from repro.corpus.synth import TraceQuery
from repro.serving.batcher import (
    DeadlineBatcher,
    PendingQuery,
    RawBatch,
    ShapeBucketedBatcher,
)
from repro.serving.fingerprint import query_fingerprint
from repro.serving.pending import PendingTable


@dataclass
class QueryResult:
    ids: np.ndarray  # i32[k]
    scores: np.ndarray  # f32[k]


@dataclass
class BatchEvent:
    """One executed batch on the (virtual or wall) timeline."""

    flush_t: float  # batcher emitted the batch (enters dispatch queue)
    start_t: float  # a worker picked it up
    done_t: float  # execution finished
    worker: int  # worker slot that ran it
    n_real: int  # real (non-padding) queries in the batch


@dataclass
class ServeReport:
    n_queries: int = 0
    wall_s: float = 0.0
    latencies_s: list[float] = field(default_factory=list)
    cache_hits: int = 0
    cache_misses: int = 0
    coalesced: int = 0  # misses served by subscribing to an in-flight twin
    n_batches: int = 0
    n_workers: int = 1
    pad_slots: int = 0
    real_slots: int = 0
    element_padding_overhead: float = 0.0
    n_compiled_shapes: int = 0
    stats: dict[str, float] = field(default_factory=dict)  # summed byte counters
    shapes_used: set = field(default_factory=set)  # distinct shapes this run
    # latency decomposition (one entry per query, same order as latencies_s)
    batch_wait_s: list[float] = field(default_factory=list)
    queue_wait_s: list[float] = field(default_factory=list)
    service_s: list[float] = field(default_factory=list)
    # dispatch timeline, one entry per executed batch in dispatch order
    batch_events: list[BatchEvent] = field(default_factory=list)
    # per-plan attribution: executed/coalesced query counts, latencies and
    # summed byte counters keyed by plan label (fixed-algorithm serving
    # attributes everything to the executor's single algorithm)
    plan_queries: dict = field(default_factory=dict)  # label -> int
    plan_latencies_s: dict = field(default_factory=dict)  # label -> [float]
    plan_stats: dict = field(default_factory=dict)  # label -> {ctr: float}
    # shard fan-out per plan (footprint-routed executors only): label ->
    # {"queries", "shards_touched", "batches", "shards_visited"} — the
    # per-query mean shards-touched is the routing win the paper argues for
    routing: dict = field(default_factory=dict)
    # per-trace-position results (run_trace(collect_results=True) only)
    results: list | None = None
    arrival: str = "closed"
    slo_ms: float | None = None

    @property
    def qps(self) -> float:
        return self.n_queries / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def hit_rate(self) -> float:
        n = self.cache_hits + self.cache_misses
        return self.cache_hits / n if n else 0.0

    @property
    def padding_overhead(self) -> float:
        total = self.pad_slots + self.real_slots
        return self.pad_slots / total if total else 0.0

    @property
    def slo_attainment(self) -> float:
        """Fraction of queries whose end-to-end latency met ``slo_ms``."""
        if self.slo_ms is None or not self.latencies_s:
            return 1.0
        lat = np.asarray(self.latencies_s)
        return float((lat <= self.slo_ms * 1e-3).mean())

    def percentile_ms(self, p: float) -> float:
        if not self.latencies_s:
            return 0.0
        return float(np.percentile(np.asarray(self.latencies_s), p) * 1e3)

    def stage_percentile_ms(self, stage: str, p: float) -> float:
        """Percentile of one latency component: batch_wait|queue_wait|service.

        NaN when the stage has no samples — "never ran" must be
        distinguishable from "ran in 0ms" on a dashboard.
        """
        xs = getattr(self, f"{stage}_s")
        if not xs:
            return float("nan")
        return float(np.percentile(np.asarray(xs), p) * 1e3)

    def plan_percentile_ms(self, label: str, p: float) -> float:
        """Latency percentile of the queries served under one plan; NaN
        when no query ran under ``label`` (same contract as
        :meth:`stage_percentile_ms`)."""
        xs = self.plan_latencies_s.get(label)
        if not xs:
            return float("nan")
        return float(np.percentile(np.asarray(xs), p) * 1e3)

    def _record_plan(self, label: str, latency_s: float) -> None:
        self.plan_queries[label] = self.plan_queries.get(label, 0) + 1
        self.plan_latencies_s.setdefault(label, []).append(latency_s)

    def routing_mean(self, label: str) -> float:
        """Mean shards-touched per executed query under one plan; NaN when
        no routed batch ran under ``label`` (same contract as
        :meth:`plan_percentile_ms`)."""
        r = self.routing.get(label)
        if not r or not r["queries"]:
            return float("nan")
        return r["shards_touched"] / r["queries"]

    def summary(self) -> str:
        per_q = {
            k: v / max(self.n_queries, 1)
            for k, v in sorted(self.stats.items())
            if k.startswith("bytes_") or k in ("seeks", "n_probes", "candidates")
        }
        lines = [
            f"queries={self.n_queries}  qps={self.qps:,.1f}  "
            f"p50={self.percentile_ms(50):.3f}ms  p99={self.percentile_ms(99):.3f}ms  "
            f"hit_rate={self.hit_rate:.3f}  batches={self.n_batches}  "
            f"padding={self.padding_overhead:.3f}  "
            f"elem_padding={self.element_padding_overhead:.3f}  "
            f"shapes={self.n_compiled_shapes}"
        ]
        if len(self.plan_queries) > 1:
            # NaN percentile = no latency samples under that plan: omit
            # the p50/p99 parenthetical, keep the count
            mix = "  ".join(
                f"{label}={n} (p50/p99="
                f"{self.plan_percentile_ms(label, 50):.3f}/"
                f"{self.plan_percentile_ms(label, 99):.3f}ms)"
                if self.plan_latencies_s.get(label)
                else f"{label}={n}"
                for label, n in sorted(self.plan_queries.items())
            )
            lines.append(f"plans: {mix}")
        if self.routing:
            fan = "  ".join(
                f"{label}: shards/q={self.routing_mean(label):.2f} "
                f"visited/batch="
                f"{r['shards_visited'] / max(r['batches'], 1):.2f}"
                for label, r in sorted(self.routing.items())
            )
            lines.append(f"routing: {fan}")
        if self.batch_wait_s:
            decomp = "  ".join(
                f"{stage}_p50/p99={self.stage_percentile_ms(stage, 50):.3f}/"
                f"{self.stage_percentile_ms(stage, 99):.3f}ms"
                for stage in ("batch_wait", "queue_wait", "service")
                if getattr(self, f"{stage}_s")
            )
            slo = (
                f"  slo_{self.slo_ms:g}ms={self.slo_attainment:.3f}"
                if self.slo_ms is not None
                else ""
            )
            lines.append(
                f"arrival={self.arrival}  workers={self.n_workers}  "
                f"coalesced={self.coalesced}  {decomp}{slo}"
            )
        if self.stats.get("text_blocks_total"):
            # pruned TEXT-FIRST only: share of driver posting blocks whose
            # bytes never streamed (θ-skipped, incl. monotone tail cuts)
            skipped = self.stats.get("text_blocks_skipped", 0.0)
            total = self.stats["text_blocks_total"]
            lines.append(
                f"text block skip rate={skipped / total:.3f} "
                f"({skipped:,.0f}/{total:,.0f} blocks)"
            )
        lines.append("  ".join(f"{k}/q={v:,.0f}" for k, v in per_q.items()))
        return "\n".join(lines)


class GeoServer:
    """Cache → coalesce → deadline batcher → worker pool, over a query trace."""

    def __init__(
        self,
        executor,
        cache=None,
        batcher: ShapeBucketedBatcher | None = None,
        fingerprint_quant: int = 128,
        n_workers: int = 1,
        coalesce: bool = False,
        telemetry=None,
    ):
        if n_workers < 1:
            raise ValueError("n_workers must be >= 1")
        self.executor = executor
        self.cache = cache
        self.batcher = batcher or DeadlineBatcher()
        self.fingerprint_quant = fingerprint_quant
        self.n_workers = n_workers
        self.coalesce = coalesce
        # repro.obs.Telemetry handle, or None: every telemetry branch in
        # the serve loop is behind a single `if self.telemetry` check, so a
        # server built without one runs the pre-telemetry code path
        self.telemetry = telemetry
        if telemetry:
            attach = getattr(executor, "attach_telemetry", None)
            if attach is not None:  # test doubles need no telemetry surface
                attach(telemetry)
        # qid → (fingerprint key, arrival time, trace position)
        self._inflight: dict[int, tuple[tuple, float, int]] = {}
        # id(TraceQuery) → QueryPlan, per run_trace: the warmup's shape
        # prediction and the live loop plan the same objects, and zipf
        # traces repeat pool entries — plan each object once
        self._plan_cache: dict[int, object] = {}
        self._next_qid = 0
        # per-worker busy-until times (virtual seconds, open loop)
        self._workers: list[float] = [0.0] * n_workers
        # open-loop cache fills deferred to their batch's virtual completion:
        # a (done_time, seq, key, value, cost) min-heap — dispatch order is
        # NOT completion order once workers overlap, so a fast batch behind
        # a slow one must still become visible at its own done time
        self._pending_fills: list[tuple[float, int, tuple, QueryResult, float]] = []
        self._fill_seq = itertools.count()
        # fingerprint → in-flight batch subscription (coalescing)
        self._pending = PendingTable() if coalesce else None

    # ------------------------------------------------------------------
    def run_trace(
        self,
        trace: list[TraceQuery],
        warmup: bool = True,
        arrival: str = "closed",
        slo_ms: float | None = None,
        service_time=None,
        collect_results: bool = False,
    ) -> ServeReport:
        """Serve a whole trace; returns the metrics report.

        ``arrival="closed"`` replays back-to-back on the wall clock (PR 1).
        Any other label replays **open-loop**: queries enter at their
        ``arrival_s`` stamps on a virtual clock and queue when the worker
        pool falls behind.  ``service_time`` (optional, ``RawBatch ->
        seconds``) replaces measured execution time in the virtual
        timeline, making open-loop replay fully deterministic for tests;
        cache-hit lookup latency is likewise pinned to zero when it is
        supplied.

        ``collect_results=True`` additionally stores every query's top-k
        (:class:`QueryResult`) in ``report.results``, aligned with the
        input ``trace`` positions — hits get the cached value, executed
        misses their batch row, coalesced misses their twin's row.

        ``warmup=True`` pre-compiles the batch shapes the trace will emit
        (predicted by replaying the cache/batcher decisions host-side)
        before the timed loop, so latency percentiles measure serving, not
        XLA compilation.
        """
        open_loop = arrival != "closed"
        if open_loop and not isinstance(self.batcher, DeadlineBatcher):
            raise ValueError("open-loop replay requires a DeadlineBatcher")
        if not open_loop and self.n_workers != 1:
            raise ValueError(
                "closed-loop replay times one real executor on the wall clock; "
                "n_workers > 1 requires open-loop arrivals"
            )
        report = ServeReport(arrival=arrival, slo_ms=slo_ms)
        report.n_workers = self.n_workers
        self._plan_cache.clear()  # trace objects may be reused across runs
        if collect_results:
            report.results = [None] * len(trace)
        if warmup and trace:
            self._warmup(trace, open_loop)
        # snapshot cumulative batcher counters so the report is per-run
        b = self.batcher
        base = (b.pad_slots, b.real_slots, b.pad_elements, b.real_elements)
        if open_loop:
            self._run_open(trace, report, service_time)
        else:
            self._run_closed(trace, report)
        report.pad_slots = b.pad_slots - base[0]
        report.real_slots = b.real_slots - base[1]
        pad_el, real_el = b.pad_elements - base[2], b.real_elements - base[3]
        report.element_padding_overhead = (
            pad_el / (pad_el + real_el) if pad_el + real_el else 0.0
        )
        report.n_compiled_shapes = len(report.shapes_used)
        if self.telemetry and self.telemetry.metrics is not None:
            m = self.telemetry.metrics
            m.set("batcher.pad_slots", report.pad_slots)
            m.set("batcher.real_slots", report.real_slots)
        assert not self._inflight, "batcher dropped in-flight queries"
        if self._pending is not None:
            n_left = self._pending.unresolved_subscribers()
            assert n_left == 0, "coalesced queries left unresolved"
        return report

    # ------------------------------------------------------------------
    def _lookup(self, q: TraceQuery):
        if self.cache is None and not self.coalesce:
            return None, None  # no consumer → fingerprinting is pure overhead
        key = query_fingerprint(q.terms, q.rects, q.amps, quant=self.fingerprint_quant)
        hit = self.cache.get(key) if self.cache is not None else None
        return key, hit

    def _plan_for(self, q: TraceQuery):
        """Ask the executor's planner for this query's plan (None = fixed).

        Memoized by trace-object identity for the current ``run_trace`` —
        the warmup replay and the live loop see the same objects (and zipf
        traces repeat them), so each query is planned exactly once.
        """
        plan_fn = getattr(self.executor, "plan_query", None)
        if plan_fn is None:
            return None
        key = id(q)
        if key not in self._plan_cache:
            self._plan_cache[key] = plan_fn(q.terms, q.rects, q.amps)
        return self._plan_cache[key]

    def _plan_label(self, raw: RawBatch) -> str:
        if raw.plan is not None:
            return raw.plan.label
        return getattr(self.executor, "algorithm", "fixed")

    @staticmethod
    def _set_result(report: ServeReport, idx: int, value) -> None:
        if report.results is not None:
            report.results[idx] = value

    def _run_closed(self, trace: list[TraceQuery], report: ServeReport) -> None:
        """PR 1 wall-clock loop + deadline flushes discovered between queries."""
        deadline_aware = isinstance(self.batcher, DeadlineBatcher)
        if self._pending is not None:
            self._pending.clear()
        t_start = time.perf_counter()
        for idx, q in enumerate(trace):
            t_arr = time.perf_counter() - t_start
            if deadline_aware:
                dl = self.batcher.next_deadline()
                if dl is not None and dl <= t_arr:
                    for raw in self.batcher.due(t_arr):
                        self._execute(
                            raw, report, flush_t=t_arr, t0=t_start,
                            reason="deadline",
                        )
            key, hit = self._lookup(q)
            if hit is not None:
                report.cache_hits += 1
                self._count("server.cache_hits_total")
                lookup_s = time.perf_counter() - t_start - t_arr
                self._record(
                    report, lookup_s, 0.0, 0.0, lookup_s,
                    t_arr=t_arr, idx=idx, kind="hit",
                )
                self._set_result(report, idx, hit)
                report.n_queries += 1
                continue
            report.cache_misses += 1
            self._count("server.cache_misses_total")
            # coalesce: the twin is still waiting in a batcher bucket
            # (closed-loop has no post-flush window — execution is
            # synchronous with the flush on the wall clock)
            if self._pending is not None:
                entry = self._pending.lookup(key, t_arr)
                if entry is not None:
                    report.coalesced += 1
                    self._coalesce_event(t_arr, entry.owner_qid, idx)
                    entry.subscribers.append((t_arr, idx))
                    report.n_queries += 1
                    continue
            qid = self._next_qid
            self._next_qid += 1
            self._inflight[qid] = (key, t_arr, idx)
            if self._pending is not None:
                self._pending.register(key, qid)
            plan = self._plan_for(q)
            self._audit_plan(qid, idx, q, plan, t_arr)
            pending = PendingQuery(qid, q.terms, q.rects, q.amps, plan)
            raws = (
                self.batcher.add(pending, t_arr)
                if deadline_aware
                else self.batcher.add(pending)
            )
            for raw in raws:
                self._execute(
                    raw, report, flush_t=t_arr, t0=t_start, reason="fill"
                )
            report.n_queries += 1
        t_end = time.perf_counter() - t_start
        for raw in self.batcher.flush():
            self._execute(raw, report, flush_t=t_end, t0=t_start, reason="drain")
        report.wall_s = time.perf_counter() - t_start

    def _run_open(self, trace, report: ServeReport, service_time) -> None:
        """Discrete-event open-loop replay over the trace's arrival stamps.

        Flushed batches enter a FIFO dispatch queue; each of ``n_workers``
        executor slots picks up the next batch the moment it frees up
        (equivalently: a batch's start time is ``max(flush_t, earliest
        worker-free time)`` in flush order — work-conserving by
        construction, property-tested in ``tests/test_multiworker_serving``).
        """
        b: DeadlineBatcher = self.batcher
        order = sorted(range(len(trace)), key=lambda i: trace[i].arrival_s)
        self._workers = [0.0] * self.n_workers
        self._pending_fills.clear()
        if self._pending is not None:
            self._pending.clear()
        t_first = trace[order[0]].arrival_s if trace else 0.0
        t_last = trace[order[-1]].arrival_s if trace else 0.0
        for idx in order:
            q = trace[idx]
            now = q.arrival_s
            # fire every deadline timer that expires before this arrival
            while True:
                dl = b.next_deadline()
                if dl is None or dl > now:
                    break
                for raw in b.due(dl):
                    self._execute_open(
                        raw, report, flush_t=dl, service_time=service_time,
                        reason="deadline",
                    )
            # apply fills AFTER the deadline loop: a deadline batch that
            # completed before `now` must be visible to this very lookup
            # (it triggered the lazy flush), as it would be on a live server
            self._apply_fills(now)
            if self._pending is not None:
                self._expire_pending(now)
            t_lk = time.perf_counter()
            key, hit = self._lookup(q)
            if hit is not None:
                report.cache_hits += 1
                self._count("server.cache_hits_total")
                # a hit's latency is just the (real, measured) lookup; zero
                # under an injected service model so tests are deterministic
                lookup_s = (
                    0.0 if service_time is not None else time.perf_counter() - t_lk
                )
                self._record(
                    report, lookup_s, 0.0, 0.0, lookup_s,
                    t_arr=now, idx=idx, kind="hit",
                )
                self._set_result(report, idx, hit)
                report.n_queries += 1
                continue
            report.cache_misses += 1
            self._count("server.cache_misses_total")
            # coalesce: subscribe to an in-flight twin (queued in a bucket,
            # waiting for a worker, or executing) instead of re-enqueueing
            if self._pending is not None:
                entry = self._pending.lookup(key, now)
                if entry is not None:
                    report.coalesced += 1
                    self._coalesce_event(now, entry.owner_qid, idx)
                    if entry.dispatched:
                        self._record_coalesced(report, entry, now, idx)
                    else:
                        entry.subscribers.append((now, idx))
                    report.n_queries += 1
                    continue
            qid = self._next_qid
            self._next_qid += 1
            self._inflight[qid] = (key, now, idx)
            if self._pending is not None:
                self._pending.register(key, qid)
            plan = self._plan_for(q)
            self._audit_plan(qid, idx, q, plan, now)
            pq = PendingQuery(qid, q.terms, q.rects, q.amps, plan)
            for raw in b.add(pq, now):
                self._execute_open(
                    raw, report, flush_t=now, service_time=service_time,
                    reason="fill",
                )
            report.n_queries += 1
        # drain: fire remaining finite deadlines in order, then the
        # infinite-wait leftovers at the end of the stream
        while True:
            dl = b.next_deadline()
            if dl is None:
                break
            for raw in b.due(dl):
                self._execute_open(
                    raw, report, flush_t=dl, service_time=service_time,
                    reason="deadline",
                )
        for raw in b.flush():
            flush_t = max(t_last, min(self._workers))
            self._execute_open(
                raw, report, flush_t=flush_t, service_time=service_time,
                reason="drain",
            )
        self._apply_fills(float("inf"))  # a later run_trace sees the full cache
        if self._pending is not None:
            self._expire_pending(float("inf"))
        report.wall_s = max(max(self._workers), t_last) - t_first

    # ------------------------------------------------------------------
    def _record(
        self,
        report,
        latency,
        batch_wait,
        queue_wait,
        service,
        *,
        t_arr: float = 0.0,
        qid: int = -1,
        idx: int = -1,
        kind: str = "executed",
        label: str | None = None,
    ) -> None:
        """Every served query's latency decomposition funnels through here —
        report lists, metrics histograms, and the query's trace span are all
        appended in the same order from the same numbers, so the span-derived
        percentiles are the report's percentiles by construction."""
        report.latencies_s.append(latency)
        report.batch_wait_s.append(batch_wait)
        report.queue_wait_s.append(queue_wait)
        report.service_s.append(service)
        tel = self.telemetry
        if tel:
            if tel.metrics is not None:
                m = tel.metrics
                m.inc("server.queries_total")
                m.observe("server.latency_ms", latency * 1e3)
                m.observe("server.batch_wait_ms", batch_wait * 1e3)
                m.observe("server.queue_wait_ms", queue_wait * 1e3)
                m.observe("server.service_ms", service * 1e3)
            if tel.tracer is not None:
                tel.tracer.query(
                    qid, idx, kind, label, t_arr,
                    latency, batch_wait, queue_wait, service,
                )

    def _record_coalesced(self, report, entry, t_arr: float, idx: int) -> None:
        """Charge a coalesced query against its twin batch's timeline.

        Each stage is clamped at the subscriber's own arrival — it cannot
        wait for a phase that ended before it arrived — so the three
        components still sum exactly to ``done - t_arr``:

        * arrived before the flush: full batch-wait tail + queue-wait +
          service;
        * arrived while the batch sat in the dispatch queue: queue-wait
          tail + service;
        * arrived mid-execution: the remaining service time only.
        """
        batch_wait = max(entry.flush_t - t_arr, 0.0)
        queue_wait = max(entry.start_t - max(t_arr, entry.flush_t), 0.0)
        service = entry.done_t - max(t_arr, entry.start_t)
        self._record(
            report, entry.done_t - t_arr, batch_wait, queue_wait, service,
            t_arr=t_arr, idx=idx, kind="coalesced", label=entry.plan_label,
        )
        if entry.plan_label is not None:
            report._record_plan(entry.plan_label, entry.done_t - t_arr)
        self._set_result(report, idx, entry.value)

    # ------------------------------------------------------------------
    # telemetry helpers (each a no-op without the matching sink)
    # ------------------------------------------------------------------
    def _count(self, name: str, amount: float = 1.0, **labels) -> None:
        tel = self.telemetry
        if tel and tel.metrics is not None:
            tel.metrics.inc(name, amount, **labels)

    def _coalesce_event(self, now: float, owner_qid: int, idx: int) -> None:
        tel = self.telemetry
        if tel:
            if tel.metrics is not None:
                tel.metrics.inc("server.coalesced_total")
            if tel.events is not None:
                tel.events.emit(now, "coalesce", qid=owner_qid, idx=idx)

    def _expire_pending(self, now: float) -> None:
        n = self._pending.expire(now)
        tel = self.telemetry
        if n and tel:
            if tel.metrics is not None:
                tel.metrics.inc("pending.expired_total", n)
            if tel.events is not None:
                tel.events.emit(now, "expire", n=n)

    def _audit_plan(self, qid: int, idx: int, q, plan, now: float) -> None:
        """Record a planned miss's features + candidate costs for the audit.

        Runs :meth:`~repro.core.planner.Planner.explain` — a second feature
        pass over the query — so the audit costs nothing unless enabled.
        Recorded at live enqueue (not in ``_plan_for``) so the warmup's
        shape-prediction replay never pollutes the log.
        """
        tel = self.telemetry
        if plan is None or not tel or tel.audit is None:
            return
        planner = getattr(self.executor, "planner", None)
        if planner is None:
            return
        ex = planner.explain(q.terms, q.rects, q.amps)
        tel.audit.record(
            qid, idx, ex["features"], ex["candidates"], ex["chosen"], now
        )

    def _batch_telemetry(
        self, raw: RawBatch, label: str, reason: str,
        flush_t: float, start_t: float, done_t: float, worker: int,
    ) -> None:
        """Per-executed-batch flush/dispatch/complete events + batch span."""
        tel = self.telemetry
        if not tel:
            return
        shape = (raw.shape.batch, raw.shape.d_terms, raw.shape.q_rects)
        if tel.metrics is not None:
            m = tel.metrics
            m.inc("batcher.flush_total", reason=reason)
            m.observe("batcher.batch_real_queries", float(raw.n_real))
            m.inc("executor.batches_total", plan=label)
        if tel.tracer is not None:
            tel.tracer.batch(
                worker, flush_t, start_t, done_t, label, raw.n_real, shape
            )
        if tel.events is not None:
            shape = list(shape)
            tel.events.emit(
                flush_t, "flush", reason=reason, plan=label,
                n_real=raw.n_real, shape=shape,
            )
            tel.events.emit(
                start_t, "dispatch", worker=worker, plan=label,
                n_real=raw.n_real,
            )
            tel.events.emit(
                done_t, "complete", worker=worker, plan=label,
                n_real=raw.n_real, service_s=done_t - start_t,
            )

    def _put_cache(self, key, value, cost: float, now: float) -> None:
        """Cache insert + eviction accounting (Landlord may evict many)."""
        ev0 = self.cache.evictions
        self.cache.put(
            key, value, cost=cost, size=value.ids.nbytes + value.scores.nbytes
        )
        n_ev = self.cache.evictions - ev0
        tel = self.telemetry
        if n_ev and tel:
            if tel.metrics is not None:
                tel.metrics.inc("cache.evictions_total", n_ev)
            if tel.events is not None:
                tel.events.emit(now, "evict", n=n_ev)

    def _predict_shapes(self, trace: list[TraceQuery], open_loop: bool) -> set:
        """Replay cache + batcher decisions (no execution) → emitted
        (plan, shape) pairs — the compile units of a planned server.

        Exact for LRU and for Landlord without eviction pressure; under
        pressure Landlord's cost/size-dependent evictions may diverge, and
        in open-loop mode the real loop fills the cache at *completion*
        time rather than emission time, so a duplicate arriving while its
        twin is still queued may hit here and miss there.  Coalescing is
        approximated the same way: a duplicate of a not-yet-emitted query
        is skipped (its in-flight window is closed at emission here, at
        batch completion in the real loop).  Closed-loop prediction is
        time-blind: with a finite ``max_wait_s`` the real loop's
        wall-clock deadline flushes can emit smaller batch shapes than
        predicted (open-loop replay is the intended home of finite
        deadlines).  Either way an unpredicted shape simply compiles
        inside the timed loop.
        """
        cache = self.cache.fresh_clone() if self.cache is not None else None
        batcher = self.batcher.clone_empty()
        deadline_aware = isinstance(batcher, DeadlineBatcher)
        pending: dict[int, tuple] = {}
        inflight_keys: set = set()  # coalesce window approximation
        shapes: set = set()

        def emit(raws):
            for raw in raws:
                shapes.add((raw.plan, raw.shape))
                for qid in raw.qids:
                    key = pending.pop(qid)
                    inflight_keys.discard(key)
                    if cache is not None:
                        cache.put(key, True)

        qid = 0

        def admit(q: TraceQuery, now: float) -> None:
            nonlocal qid
            if cache is None and not self.coalesce:
                key = None
            else:
                key = query_fingerprint(
                    q.terms, q.rects, q.amps, quant=self.fingerprint_quant
                )
            if cache is not None and cache.get(key) is not None:
                return
            if self.coalesce and key in inflight_keys:
                return
            pending[qid] = key
            inflight_keys.add(key)
            p = PendingQuery(qid, q.terms, q.rects, q.amps, self._plan_for(q))
            emit(batcher.add(p, now) if deadline_aware else batcher.add(p))
            qid += 1

        if open_loop:
            for q in sorted(trace, key=lambda q: q.arrival_s):
                while True:
                    dl = batcher.next_deadline()
                    if dl is None or dl > q.arrival_s:
                        break
                    emit(batcher.due(dl))
                admit(q, q.arrival_s)
            while True:
                dl = batcher.next_deadline()
                if dl is None:
                    break
                emit(batcher.due(dl))
        else:
            for q in trace:
                admit(q, 0.0)
        emit(batcher.flush())
        return shapes

    def _warmup(self, trace: list[TraceQuery], open_loop: bool = False) -> None:
        """Pre-compile every predicted (plan, shape) with an inert batch."""
        for plan, shape in sorted(
            self._predict_shapes(trace, open_loop),
            key=lambda ps: (repr(ps[0]), ps[1].batch, ps[1].d_terms, ps[1].q_rects),
        ):
            terms = np.full((shape.batch, shape.d_terms), -1, dtype=np.int32)
            rects = np.zeros((shape.batch, shape.q_rects, 4), dtype=np.float32)
            rects[:, :, 0] = 1.0
            rects[:, :, 1] = 1.0
            amps = np.zeros((shape.batch, shape.q_rects), dtype=np.float32)
            batch = alg.QueryBatch(
                terms=jnp.asarray(terms),
                rects=jnp.asarray(rects),
                amps=jnp.asarray(amps),
            )
            res = (
                self.executor.run(batch, plan=plan)
                if plan is not None
                else self.executor.run(batch)
            )
            jax.block_until_ready(res.scores)

    @staticmethod
    def routing_acc(report: ServeReport, label: str) -> dict:
        return report.routing.setdefault(
            label,
            {
                "queries": 0,
                "shards_touched": 0.0,
                "batches": 0,
                "shards_visited": 0.0,
            },
        )

    @staticmethod
    def _to_query_batch(raw: RawBatch) -> alg.QueryBatch:
        return alg.QueryBatch(
            terms=jnp.asarray(raw.terms),
            rects=jnp.asarray(raw.rects),
            amps=jnp.asarray(raw.amps),
        )

    # ------------------------------------------------------------------
    def _finish_batch(self, raw: RawBatch, report: ServeReport):
        """Run the executor under the batch's plan; return host results."""
        if raw.plan is not None:
            res = self.executor.run(self._to_query_batch(raw), plan=raw.plan)
        else:
            res = self.executor.run(self._to_query_batch(raw))
        ids = np.asarray(res.ids)
        scores = np.asarray(res.scores)
        report.n_batches += 1
        report.shapes_used.add(raw.shape)
        label = self._plan_label(raw)
        tel = self.telemetry
        metrics = tel.metrics if tel else None
        pstats = report.plan_stats.setdefault(label, {})
        per_row: dict[str, np.ndarray] = {}
        for key, v in res.stats.items():
            # only the real rows' work is attributable to served queries,
            # but padded rows burn real bytes too — count everything
            arr = np.asarray(v, dtype=np.float64)
            total = float(arr.sum())
            report.stats[key] = report.stats.get(key, 0.0) + total
            pstats[key] = pstats.get(key, 0.0) + total
            if metrics is not None:
                metrics.inc(f"executor.{key}_total", total, plan=label)
            if arr.ndim >= 1 and arr.shape[0] == raw.shape.batch:
                per_row[key] = arr.reshape(arr.shape[0], -1).sum(axis=1)
        if "shards_touched" in per_row:
            # footprint-routed executor: fold this batch's fan-out into the
            # per-plan routing summary (real rows only — padding rows touch
            # no shard a served query can be charged for)
            touched = per_row["shards_touched"][: raw.n_real]
            raw.routing = {
                "shards_touched": touched,
                "shards_visited": float(
                    np.asarray(res.stats.get("shards_visited", 0.0)).sum()
                ),
            }
            r = self.routing_acc(report, label)
            r["queries"] += raw.n_real
            r["shards_touched"] += float(touched.sum())
            r["batches"] += 1
            r["shards_visited"] += raw.routing["shards_visited"]
            if metrics is not None:
                for v in touched:
                    metrics.observe(
                        "executor.shards_touched", float(v), plan=label
                    )
        if tel and tel.audit is not None and raw.plan is not None:
            # join each planned row's measured counters back onto its
            # audit record — prediction vs ground truth, per query
            for row, qid in enumerate(raw.qids):
                tel.audit.join(
                    qid, {k: float(a[row]) for k, a in per_row.items()}
                )
        return ids, scores

    def _execute(
        self,
        raw: RawBatch,
        report: ServeReport,
        flush_t: float,
        t0: float,
        reason: str = "fill",
    ) -> None:
        """Closed-loop execution: wall-clock timing relative to ``t0``.

        Service is measured per batch (``t_exec → t_done``), so when one
        flush event drains several batches (end-of-trace, overdue-deadline
        bursts) the later batches' wait behind the earlier ones lands in
        queue-wait, not in their service time or Landlord cost.
        """
        t_exec = time.perf_counter() - t0
        ids, scores = self._finish_batch(raw, report)
        t_done = time.perf_counter() - t0
        # batch cost shared equally by its real queries (Landlord credit)
        service = t_done - t_exec
        cost = service / max(raw.n_real, 1)
        report.batch_events.append(
            BatchEvent(flush_t, t_exec, t_done, 0, raw.n_real)
        )
        label = self._plan_label(raw)
        self._batch_telemetry(raw, label, reason, flush_t, t_exec, t_done, 0)
        for row, qid in enumerate(raw.qids):
            key, t_arr, idx = self._inflight.pop(qid)
            self._record(
                report, t_done - t_arr, flush_t - t_arr, t_exec - flush_t, service,
                t_arr=t_arr, qid=qid, idx=idx, kind="executed", label=label,
            )
            report._record_plan(label, t_done - t_arr)
            need_value = (
                report.results is not None
                or self.cache is not None
                or self._pending is not None
            )
            value = (
                QueryResult(ids[row].copy(), scores[row].copy())
                if need_value
                else None
            )
            self._set_result(report, idx, value)
            if self.cache is not None:
                self._put_cache(key, value, cost, t_done)
            if self._pending is not None:
                entry = self._pending.resolve(key, qid)
                if entry is not None:
                    for t_sub, sub_idx in entry.subscribers:
                        self._record(
                            report,
                            t_done - t_sub,
                            flush_t - t_sub,
                            t_exec - flush_t,
                            service,
                            t_arr=t_sub, idx=sub_idx, kind="coalesced",
                            label=label,
                        )
                        report._record_plan(label, t_done - t_sub)
                        self._set_result(report, sub_idx, value)
                    entry.subscribers.clear()

    def _apply_fills(self, now: float) -> None:
        """Insert deferred results whose batch completed by virtual ``now``.

        Open-loop cache fills become visible only at their batch's virtual
        completion — a duplicate arriving while its twin is still queued or
        executing misses the cache, exactly as it would in a live server
        (with coalescing on, that duplicate subscribes to the in-flight
        twin instead).
        """
        fills = self._pending_fills
        while fills and fills[0][0] <= now:
            done, _, key, value, cost = heapq.heappop(fills)
            self._put_cache(key, value, cost, done)

    def _execute_open(
        self,
        raw: RawBatch,
        report: ServeReport,
        flush_t: float,
        service_time,
        reason: str = "fill",
    ) -> None:
        """Open-loop execution: dispatch to the earliest-free worker slot.

        The batch starts when a worker frees up (``max(flush_t,
        min(worker-free times))`` — FIFO dispatch, work-conserving) and its
        measured (or injected) duration is charged to that worker's
        timeline; with one worker this is exactly the single busy-server
        recurrence of PR 2.
        """
        t0 = time.perf_counter()
        ids, scores = self._finish_batch(raw, report)
        if service_time is not None:
            dt = float(service_time(raw))
        else:
            dt = time.perf_counter() - t0
        w = min(range(self.n_workers), key=lambda i: self._workers[i])
        start = max(flush_t, self._workers[w])
        done = start + dt
        self._workers[w] = done
        report.batch_events.append(BatchEvent(flush_t, start, done, w, raw.n_real))
        cost = dt / max(raw.n_real, 1)
        label = self._plan_label(raw)
        self._batch_telemetry(raw, label, reason, flush_t, start, done, w)
        for row, qid in enumerate(raw.qids):
            key, t_arr, idx = self._inflight.pop(qid)
            self._record(
                report, done - t_arr, flush_t - t_arr, start - flush_t, dt,
                t_arr=t_arr, qid=qid, idx=idx, kind="executed", label=label,
            )
            report._record_plan(label, done - t_arr)
            need_value = (
                report.results is not None
                or self.cache is not None
                or self._pending is not None
            )
            value = (
                QueryResult(ids[row].copy(), scores[row].copy())
                if need_value
                else None
            )
            self._set_result(report, idx, value)
            if self.cache is not None:
                heapq.heappush(
                    self._pending_fills,
                    (done, next(self._fill_seq), key, value, cost),
                )
            if self._pending is not None:
                entry = self._pending.on_dispatch(
                    key, qid, flush_t, start, done, value
                )
                if entry is not None:
                    entry.plan_label = label
                    # resolve duplicates that subscribed while this query
                    # sat in its batcher bucket; later duplicates (arriving
                    # before `done`) are recorded directly at lookup time
                    for t_sub, sub_idx in entry.subscribers:
                        self._record_coalesced(report, entry, t_sub, sub_idx)
                    entry.subscribers.clear()

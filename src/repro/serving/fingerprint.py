"""Normalized query fingerprints for result caching.

Two queries should share a cache entry when they are *semantically* the
same search: the same conjunction of terms over (almost) the same
footprint.  Real traces are full of such near-duplicates — the same "pizza
new york" issued from slightly different map viewports.  The fingerprint
therefore normalizes away the noise:

* **terms** — deduplicated, sorted, padding (−1) dropped: term order never
  changes a conjunction;
* **rects** — coordinates quantized onto a ``quant × quant`` lattice, empty
  rects dropped, rects sorted: footprints that differ by less than one
  lattice cell collide;
* **amps**  — quantized to ``amp_levels`` buckets.

The key is a flat tuple of ints — hashable, cheap to compare, and stable
across processes (no float bit patterns).
"""
from __future__ import annotations

import numpy as np

Fingerprint = tuple

def query_fingerprint(
    terms: np.ndarray,
    rects: np.ndarray,
    amps: np.ndarray,
    quant: int = 128,
    amp_levels: int = 8,
) -> Fingerprint:
    """Normalize one query → hashable key.

    terms: i32[d] (−1 padded) · rects: f32[r, 4] · amps: f32[r].
    """
    t = np.unique(np.asarray(terms, dtype=np.int64))
    t = t[t >= 0]

    r = np.asarray(rects, dtype=np.float64).reshape(-1, 4)
    a = np.asarray(amps, dtype=np.float64).reshape(-1)
    # validity is judged on the raw floats; quantization must never *create*
    # or *destroy* a rect (a sub-cell rect still identifies a location)
    valid = (r[:, 2] > r[:, 0]) & (r[:, 3] > r[:, 1]) & (a > 0)
    r = r[valid]
    # floor the low edge, ceil the high edge, min one lattice cell: nearby
    # rects collide, but tiny rects in different cells stay distinct
    lo = np.clip(np.floor(r[:, :2] * quant), 0, quant - 1).astype(np.int64)
    hi = np.clip(np.ceil(r[:, 2:] * quant), 0, quant).astype(np.int64)
    hi = np.maximum(hi, lo + 1)
    qa = np.clip((a[valid] * amp_levels).astype(np.int64), 0, amp_levels)
    rows = np.concatenate([lo, hi, qa[:, None]], axis=1)
    # canonical order so rect permutations collide
    order = np.lexsort(rows.T[::-1])
    rows = rows[order]
    return (len(t), *t.tolist(), *rows.reshape(-1).tolist())

"""GeoSearchEngine: build / hold indexes, execute batched geo queries.

This is the public API of the paper's system.  It owns

* a ``TextIndex`` (CSR inverted index + impacts + optional block bitmaps),
* a ``SpatialIndex`` (Morton toe-print store + tile-interval grid + doc-major
  footprint mirror),
* per-document global scores (PageRank),
* query ``Budgets`` and ranking weights,

and exposes ``query(batch, algorithm=...)`` — a jit-compiled, batched query
pipeline — plus ``oracle`` for exact evaluation.

Execution is *plan-driven*: every call resolves to a
:class:`~repro.core.planner.QueryPlan` (algorithm + budgets + kernel knobs)
and the compiled-function cache is keyed by plan, so callers can hold
several pipeline variants against one index without recompiling or mutating
engine state.  ``algorithm="auto"`` routes through the engine's cost-based
:class:`~repro.core.planner.Planner`, which picks the cheapest plan per
query from posting-list lengths and footprint coverage estimates.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg
from repro.core import ranking
from repro.core.planner import Planner, QueryPlan
from repro.core.spatial_index import SpatialIndex, build_spatial_index_np
from repro.core.text_index import TextIndex, build_text_index_np


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class GeoIndex:
    """The full index state — a single pytree, shardable under pjit/shard_map."""

    text: TextIndex
    spatial: SpatialIndex
    pagerank: jax.Array  # f32[N]


@dataclass
class GeoSearchEngine:
    index: GeoIndex
    budgets: alg.QueryBudgets
    weights: ranking.RankWeights

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @staticmethod
    def build(
        doc_terms: list[np.ndarray],
        doc_rects: np.ndarray,
        doc_amps: np.ndarray,
        n_terms: int,
        pagerank: np.ndarray | None = None,
        grid: int = 64,
        m_intervals: int = 2,
        n_bitmap_terms: int = 0,
        budgets: alg.QueryBudgets | None = None,
        weights: ranking.RankWeights | None = None,
        compress: "bool | str" = False,
        block_size: int = 128,
        idf: np.ndarray | None = None,
        layout: str = "docid",
    ) -> "GeoSearchEngine":
        # idf: corpus-global IDF override for shard engines (see
        # build_text_index_np — keeps impacts partition-independent)
        # layout: posting order — "docid" (reference) or "impact"
        # (descending-impact segments; see text_index module docstring)
        from repro.core.spatial_index import normalize_compress

        mode = normalize_compress(compress)
        # one compression entry point: the builder quantizes impacts (f16
        # under any compressed mode) BEFORE computing blk_max_impact, so
        # pruning bounds are taken over the stored values
        text = build_text_index_np(
            doc_terms, n_terms, n_bitmap_terms, idf=idf,
            compress=(mode != "none"),
            impact_dtype=(np.float16 if mode != "none" else None),
            layout=layout,
        )
        spatial = build_spatial_index_np(
            doc_rects, doc_amps, grid, m_intervals, compress=mode,
            block_size=block_size,
        )
        n = len(doc_terms)
        if pagerank is None:
            pagerank = np.full((n,), 0.1, dtype=np.float32)
        budgets = budgets or alg.QueryBudgets()
        # sweeps cannot exceed the store
        budgets = replace(
            budgets, sweep_budget=min(budgets.sweep_budget, spatial.n_toeprints)
        )
        return GeoSearchEngine(
            index=GeoIndex(text=text, spatial=spatial, pagerank=jnp.asarray(pagerank)),
            budgets=budgets,
            weights=weights or ranking.RankWeights(),
        )

    # ------------------------------------------------------------------
    # query execution
    # ------------------------------------------------------------------
    def query(
        self,
        batch: alg.QueryBatch,
        algorithm: str = "k_sweep",
        plan: QueryPlan | None = None,
        **kw,
    ) -> alg.TopKResult:
        """Run one batch under a plan.

        ``plan=None`` builds the default plan for ``algorithm`` from the
        engine's own budgets (bit-identical to the pre-plan API).
        ``algorithm="auto"`` asks the engine's planner for a per-query plan
        and gathers each row's result from its assigned plan's run.
        """
        if plan is None:
            if algorithm == "auto":
                return self._query_auto(batch, **kw)
            plan = QueryPlan(
                algorithm, self.budgets, fused=bool(kw.pop("fused", False))
            )
        else:
            kw.pop("fused", None)  # the plan owns the fused flag
        fn = self._compiled(plan, tuple(sorted(kw.items())))
        return fn(self.index, batch)

    @property
    def planner(self) -> Planner:
        """Lazily-built cost-based planner over this engine's index."""
        p = self.__dict__.get("_planner")
        if p is None:
            p = Planner.from_engine(self)
            self.__dict__["_planner"] = p
        return p

    def _query_auto(self, batch: alg.QueryBatch, **kw) -> alg.TopKResult:
        """Per-query plan dispatch at the engine level.

        The serving layer dispatches plan-homogeneous batches (one compile
        and one execution per plan × shape); here, against a single padded
        batch, we emulate that: each *distinct* chosen plan runs on the
        whole batch and every row's ids/scores/stats are gathered from its
        assigned plan's run — so the per-query counters are exactly what
        per-query dispatch would have measured, at the price of executing
        each selected pipeline over the full batch.
        """
        fused = bool(kw.pop("fused", False))
        plans = self.planner.plan_rows(batch)
        if fused:  # route rows with a fused Pallas pipeline through it
            plans = [
                replace(p, fused=True)
                if p.algorithm == "k_sweep"
                or (p.algorithm == "text_first" and p.budgets.prune)
                else p
                for p in plans
            ]
        uniq: list[QueryPlan] = []
        for p in plans:
            if p not in uniq:
                uniq.append(p)
        if len(uniq) == 1:
            return self.query(batch, plan=uniq[0], **kw)
        results = {p: self.query(batch, plan=p, **kw) for p in uniq}
        rows = [np.asarray([plan == p for plan in plans]) for p in uniq]
        ids = np.zeros_like(np.asarray(results[uniq[0]].ids))
        scores = np.zeros_like(np.asarray(results[uniq[0]].scores))
        keys = sorted({k for r in results.values() for k in r.stats})
        B = batch.batch
        stats = {k: np.zeros((B,), np.float64) for k in keys}
        for p, sel in zip(uniq, rows):
            res = results[p]
            ids[sel] = np.asarray(res.ids)[sel]
            scores[sel] = np.asarray(res.scores)[sel]
            for k in keys:  # absent counters contribute 0 for this plan
                if k in res.stats:
                    v = np.asarray(res.stats[k], np.float64)
                    stats[k][sel] = v[sel] if v.ndim else v
        return alg.TopKResult(
            ids=jnp.asarray(ids),
            scores=jnp.asarray(scores),
            stats={k: jnp.asarray(v) for k, v in stats.items()},
        )

    def oracle(self, batch: alg.QueryBatch, k: int | None = None) -> alg.TopKResult:
        k = k or self.budgets.top_k
        return jax.jit(
            lambda idx, b: alg.oracle(
                idx.text, idx.spatial, idx.pagerank, b, k, self.weights
            )
        )(self.index, batch)

    def _compiled(self, plan: QueryPlan, kw_key) -> Callable:
        """Plan-keyed compiled-function cache (one jit program per plan)."""
        cache = self.__dict__.setdefault("_fn_cache", {})
        key = (plan, kw_key)
        if key not in cache:
            # metrics registry is attached by the serving layer's
            # attach_telemetry; each distinct plan x kw jit program counts
            m = getattr(self, "metrics", None)
            if m is not None:
                m.inc("engine.compiled_fns_total")
            fn = alg.get_algorithm(plan.algorithm)
            kw = {**plan.engine_kw(), **dict(kw_key)}
            # a plan's budgets may come from another shard's engine: sweeps
            # can never exceed THIS index's toe-print store
            budgets = replace(
                plan.budgets,
                sweep_budget=min(
                    plan.budgets.sweep_budget, self.index.spatial.n_toeprints
                ),
            )

            @jax.jit
            def run(index: GeoIndex, batch: alg.QueryBatch):
                return fn(
                    index.text,
                    index.spatial,
                    index.pagerank,
                    batch,
                    budgets,
                    self.weights,
                    **kw,
                )

            cache[key] = run
        return cache[key]

    # ------------------------------------------------------------------
    # evaluation
    # ------------------------------------------------------------------
    def recall_at_k(
        self,
        batch: alg.QueryBatch,
        algorithm: str = "k_sweep",
        k: int | None = None,
        **kw,
    ) -> float:
        """Recall@k of an algorithm vs the exact oracle (``kw`` forwarded
        to the algorithm, e.g. ``fused=True``)."""
        k = k or self.budgets.top_k
        got = self.query(batch, algorithm, **kw)
        want = self.oracle(batch, k)
        return ranking.topk_recall_np(want.ids, got.ids)

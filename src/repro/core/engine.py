"""GeoSearchEngine: build / hold indexes, execute batched geo queries.

This is the public API of the paper's system.  It owns

* a ``TextIndex`` (CSR inverted index + impacts + optional block bitmaps),
* a ``SpatialIndex`` (Morton toe-print store + tile-interval grid + doc-major
  footprint mirror),
* per-document global scores (PageRank),
* query ``Budgets`` and ranking weights,

and exposes ``query(batch, algorithm=...)`` — a jit-compiled, batched query
pipeline — plus ``oracle`` for exact evaluation.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg
from repro.core import ranking
from repro.core.spatial_index import SpatialIndex, build_spatial_index_np
from repro.core.text_index import TextIndex, build_text_index_np


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class GeoIndex:
    """The full index state — a single pytree, shardable under pjit/shard_map."""

    text: TextIndex
    spatial: SpatialIndex
    pagerank: jax.Array  # f32[N]


@dataclass
class GeoSearchEngine:
    index: GeoIndex
    budgets: alg.QueryBudgets
    weights: ranking.RankWeights

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @staticmethod
    def build(
        doc_terms: list[np.ndarray],
        doc_rects: np.ndarray,
        doc_amps: np.ndarray,
        n_terms: int,
        pagerank: np.ndarray | None = None,
        grid: int = 64,
        m_intervals: int = 2,
        n_bitmap_terms: int = 0,
        budgets: alg.QueryBudgets | None = None,
        weights: ranking.RankWeights | None = None,
        compress: bool = False,
        block_size: int = 128,
    ) -> "GeoSearchEngine":
        text = build_text_index_np(doc_terms, n_terms, n_bitmap_terms)
        spatial = build_spatial_index_np(
            doc_rects, doc_amps, grid, m_intervals, compress=compress,
            block_size=block_size,
        )
        if compress:
            from repro.core.text_index import quantize_impacts

            text = quantize_impacts(text, jnp.float16)
        n = len(doc_terms)
        if pagerank is None:
            pagerank = np.full((n,), 0.1, dtype=np.float32)
        budgets = budgets or alg.QueryBudgets()
        # sweeps cannot exceed the store
        budgets = replace(
            budgets, sweep_budget=min(budgets.sweep_budget, spatial.n_toeprints)
        )
        return GeoSearchEngine(
            index=GeoIndex(text=text, spatial=spatial, pagerank=jnp.asarray(pagerank)),
            budgets=budgets,
            weights=weights or ranking.RankWeights(),
        )

    # ------------------------------------------------------------------
    # query execution
    # ------------------------------------------------------------------
    def query(
        self,
        batch: alg.QueryBatch,
        algorithm: str = "k_sweep",
        **kw,
    ) -> alg.TopKResult:
        fn = self._compiled(algorithm, tuple(sorted(kw.items())))
        return fn(self.index, batch)

    def oracle(self, batch: alg.QueryBatch, k: int | None = None) -> alg.TopKResult:
        k = k or self.budgets.top_k
        return jax.jit(
            lambda idx, b: alg.oracle(
                idx.text, idx.spatial, idx.pagerank, b, k, self.weights
            )
        )(self.index, batch)

    def _compiled(self, algorithm: str, kw_key) -> Callable:
        cache = self.__dict__.setdefault("_fn_cache", {})
        key = (algorithm, kw_key)
        if key not in cache:
            fn = alg.ALGORITHMS[algorithm]
            kw = dict(kw_key)

            @jax.jit
            def run(index: GeoIndex, batch: alg.QueryBatch):
                return fn(
                    index.text,
                    index.spatial,
                    index.pagerank,
                    batch,
                    self.budgets,
                    self.weights,
                    **kw,
                )

            cache[key] = run
        return cache[key]

    # ------------------------------------------------------------------
    # evaluation
    # ------------------------------------------------------------------
    def recall_at_k(
        self,
        batch: alg.QueryBatch,
        algorithm: str = "k_sweep",
        k: int | None = None,
        **kw,
    ) -> float:
        """Recall@k of an algorithm vs the exact oracle (``kw`` forwarded
        to the algorithm, e.g. ``fused=True``)."""
        k = k or self.budgets.top_k
        got = self.query(batch, algorithm, **kw)
        want = self.oracle(batch, k)
        got_ids = np.asarray(got.ids)
        want_ids = np.asarray(want.ids)
        # vectorized membership: want[b, i] found anywhere in got[b, :]
        want_valid = want_ids >= 0
        got_valid = got_ids >= 0
        found = (
            (want_ids[:, :, None] == got_ids[:, None, :])
            & want_valid[:, :, None]
            & got_valid[:, None, :]
        ).any(axis=-1)
        total = int(want_valid.sum())
        if total == 0:
            return 1.0  # vacuous: no query has any valid result
        return float(found.sum()) / total

"""Inverted text index: CSR posting arrays + impact scores + block bitmaps.

Layout (paper §II.B, adapted to HBM-resident fixed-shape arrays):

* ``postings i32[P]``  — docIDs, ascending within each term's slice.
* ``impacts  f32[P]``  — precomputed per-posting *impact* score: the term's
  full contribution to the lnc.ltc cosine of eq. (3),
  ``ln(1 + n/f_t) * (1 + ln f_{D,t}) / sqrt(|D|)``, so query-time text
  scoring is a pure gather+sum (quantizable to f16/int8; see ``quantize``).
* ``offsets  i32[M+1]`` — CSR slices: term w owns postings[offsets[w]:offsets[w+1]].
* block bitmaps: for the ``n_bitmap_terms`` most frequent terms, a packed
  u32 bitmap over ceil(N/128)*4 words marking which 128-doc *blocks*'
  documents contain the term — the TPU-idiomatic conjunction prefilter
  (AND + popcount; see kernels/bitmap_filter).

Membership probing at query time is a vectorized binary search
(``searchsorted``) into the term slice — the TPU analogue of DAAT list
merging.

Compressed posting storage (paper §II.B: "compressed index formats")
--------------------------------------------------------------------

``build_text_index_np(compress=True)`` replaces the raw ``postings i32[P]``
column with a delta + bit-packed store cut into 128-posting blocks that
never straddle a term slice:

* ``post_packed u32[W]`` — little-endian bit-packed doc-id deltas; each
  block is word-aligned and stores its deltas at a per-block *base* width
  ``blk_bits[b]`` (PForDelta framing, below), followed by
  ``blk_n_exc[b]`` exception words.
* ``blk_first/blk_bits/blk_len/blk_word_off/blk_pos i32[NB]`` — per-block
  first doc id, base bit width, valid count, start word, and absolute CSR
  position of the block's first posting (impacts stay CSR-addressed).
* ``blk_n_exc i32[NB]`` — PForDelta exception words per block.
* ``blk_term_off i32[M+1]`` — CSR of blocks per term.

PForDelta exception framing
---------------------------

Instead of one bit width per block sized by the *largest* delta (one
outlier gap inflates all 128 slots), each block picks the base width
minimizing total words: ``ceil(len·bits/32)`` base words (tail-trimmed)
plus one patch word per delta that does not fit.  A patch word packs
``slot | high_bits << 8`` — the slot index (< 128, 8 bits) and the bits
above the base width (≤ 24, enforced by ``bits ≥ bit_length(max) − 24``).
Decode extracts the base bits as before, then replays the patch list
(:func:`decode_posting_blocks`).  In practice the chosen base width covers
~90% of deltas and the outliers ride in the exception list.

Posting layouts (``build_text_index_np(layout=)``)
--------------------------------------------------

* ``"docid"`` (default) — postings ascend by doc id within each term
  slice; ``blk_max_impact`` is the exact per-block max.  This is the
  bit-identical correctness reference.
* ``"impact"`` — each term's postings are grouped into descending
  quantized-impact *segments* (:data:`IMPACT_LEVELS` global geometric
  levels), docID-ascending *within* a segment so delta + bit-packing
  still applies; blocks never straddle segments.  The segment CSR
  (``seg_term_off i32[M+1]``, ``seg_pos/seg_len i32[NS]``) drives the
  segment-aware membership probes.  ``blk_max_impact`` is the per-term
  *suffix-max envelope* of the exact block maxima — monotone
  non-increasing along each term's block run, so the pruned traversal
  (kernels/text_probe, ``monotone=True``) can early-exit a term the
  first time a block's bound drops below θ.  Scores are unchanged (same
  stored impacts, different order): top-k ids and scores match the
  docID layout exactly.

The *logical* 128-posting framing (``blk_term_off``/``blk_pos``/``blk_len``)
plus the block-max metadata ``blk_max_impact f32[NB]`` are built in BOTH
storage modes: they are the skip unit of the WAND-style pruned traversal
(kernels/text_probe), which is independent of how doc ids are stored.

Query-time probes binary-search the block heads (``blk_first``) and decode
exactly one block per key (shift/mask + prefix sum + exception patch) —
the compressed words are the only doc-id bytes the query path touches, so
the modeled ``posting_bytes`` (see the property) is what actually streams.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 128  # docs per bitmap block
WORDS_PER_BLOCK = BLOCK // 32
POSTING_BLOCK = 128  # postings per delta/bit-pack compression block
# PForDelta patch word: slot (8 bits, block slots < 128) | high_bits << 8
PFOR_SLOT_BITS = 8
PFOR_HIGH_BITS = 32 - PFOR_SLOT_BITS
# impact-ordered layout: global geometric quantization into this many
# descending levels; each level spans a RATIO-wide band of stored impacts.
# The ratio sets the pruning granularity — a θ cut can only drop whole
# trailing levels of a term, so levels must be fine enough that one term's
# impact spread (typically ~4×: the tf and length-norm factors) covers
# several of them.  1.2 gives ~8 levels across a 4× spread; 32 levels
# (~340× total dynamic range) covers the cross-term idf spread.
IMPACT_LEVELS = 32
IMPACT_LEVEL_RATIO = 1.2


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class TextIndex:
    """HBM-resident inverted index (a pytree of arrays)."""

    postings: jax.Array  # i32[P] docIDs ([0] when compressed — see post_packed)
    impacts: jax.Array  # f32[P] precomputed impact scores
    offsets: jax.Array  # i32[M+1]
    bitmaps: jax.Array  # u32[n_bitmap_terms, n_words]  (may be [0, n_words])
    bitmap_term_ids: jax.Array  # i32[n_bitmap_terms] term id per bitmap row
    # --- delta + bit-packed doc-id store ([0] when uncompressed) ---
    post_packed: jax.Array  # u32[W] packed deltas, word-aligned blocks
    blk_first: jax.Array  # i32[NB] first doc id per block
    blk_bits: jax.Array  # i32[NB] delta bit width per block
    # --- logical 128-posting block addressing (BOTH layouts: blocks never
    # straddle terms, so compressed and uncompressed share one framing) ---
    blk_len: jax.Array  # i32[NB] valid postings per block (≤ POSTING_BLOCK)
    blk_word_off: jax.Array  # i32[NB] start word in post_packed ([0] raw)
    blk_pos: jax.Array  # i32[NB] absolute CSR position of block's 1st posting
    blk_term_off: jax.Array  # i32[M+1] CSR of blocks per term
    # block-max impact metadata (both layouts; see block_max_impacts_np):
    # per-block max of the *stored* impacts, decoded to f32 — computed
    # post-quantization so WAND-style upper bounds stay safe under f16;
    # under layout="impact" this is the per-term suffix-max envelope
    # (monotone non-increasing along each term's block run)
    blk_max_impact: jax.Array  # f32[NB]
    # PForDelta exception words per block ([0] when uncompressed)
    blk_n_exc: jax.Array  # i32[NB]
    # impact-ordered segment CSR (degenerate under layout="docid": the
    # probes never read it, so it stays one zero entry)
    seg_term_off: jax.Array  # i32[M+1] CSR of impact segments per term
    seg_pos: jax.Array  # i32[NS] absolute CSR position of segment start
    seg_len: jax.Array  # i32[NS] postings per segment
    n_docs: int = field(metadata=dict(static=True))
    n_terms: int = field(metadata=dict(static=True))
    # max blocks owned by any single term (static: sizes the pruned-probe
    # kernel's per-query block lattice)
    max_term_blocks: int = field(default=1, metadata=dict(static=True))
    # posting order: "docid" (ascending doc ids per term) or "impact"
    # (descending quantized-impact segments per term)
    layout: str = field(default="docid", metadata=dict(static=True))
    # max segments owned by any single term (static: bounds the
    # segment-aware probe loop; 1 under layout="docid")
    max_term_segments: int = field(default=1, metadata=dict(static=True))

    @property
    def n_postings(self) -> int:
        # impacts stay CSR-addressed in both layouts, so P comes from them
        return self.impacts.shape[0]

    @property
    def is_compressed(self) -> bool:
        return self.blk_first.shape[0] > 0

    @property
    def posting_bytes(self) -> float:
        """Modeled bytes per posting: doc id (+ block metadata) + impact.

        Uncompressed this is the classic ``4 + impact_itemsize`` (= 8 at
        f32); compressed it is the bit-packed words (base + PForDelta
        exception words) plus the 20 B/block of metadata (incl.
        ``blk_n_exc``) plus the (possibly quantized) impact, amortized per
        posting.  The impact layout additionally pays 8 B per segment for
        the ``seg_pos``/``seg_len`` prefixes.  The planner and the
        per-query ``bytes_postings`` counters both read this property, so
        compressed bytes are what the cost model optimizes end to end.
        """
        P = max(self.n_postings, 1)
        imp = self.impacts.dtype.itemsize
        seg = 8 * self.seg_pos.shape[0] if self.layout == "impact" else 0
        if self.is_compressed:
            packed = 4 * self.post_packed.shape[0] + 20 * self.blk_first.shape[0]
            return (packed + seg) / P + imp
        return (4.0 * P + seg) / P + imp


def logical_posting_blocks_np(
    offsets: np.ndarray,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """128-posting block framing of a CSR posting store.

    Returns ``(blk_term_off i32[M+1], blk_pos i32[NB], blk_len i32[NB])``
    with blocks that never straddle a term slice — the exact framing
    :func:`pack_postings_np` uses, so compressed and uncompressed indexes
    address the same logical blocks (the pruned traversal's skip unit).
    An all-empty store yields one degenerate empty block (matching the
    packed layout's sentinel) so block columns are never zero-width.
    """
    M = len(offsets) - 1
    counts = np.diff(offsets.astype(np.int64))
    nb = (counts + POSTING_BLOCK - 1) // POSTING_BLOCK
    blk_term_off = np.zeros((M + 1,), np.int32)
    blk_term_off[1:] = np.cumsum(nb).astype(np.int32)
    NB = int(blk_term_off[-1])
    if NB == 0:
        return blk_term_off, np.zeros((1,), np.int32), np.zeros((1,), np.int32)
    term_of_blk = np.repeat(np.arange(M), nb)
    k = np.arange(NB, dtype=np.int64) - np.repeat(blk_term_off[:-1], nb)
    poss = offsets[term_of_blk].astype(np.int64) + k * POSTING_BLOCK
    lens = np.minimum(counts[term_of_blk] - k * POSTING_BLOCK, POSTING_BLOCK)
    return blk_term_off, poss.astype(np.int32), lens.astype(np.int32)


def block_max_impacts_np(
    impacts: np.ndarray, blk_pos: np.ndarray, blk_len: np.ndarray
) -> np.ndarray:
    """Per-block max of the *stored* impacts, decoded to f32 — f32[NB].

    Computed from the stored (possibly f16-quantized) values so the bound
    stays an upper bound after lossy compression: round-to-nearest can
    round a value *up*, so a max taken pre-quantization would be unsafe.
    Empty blocks get 0.0 (vacuous — no posting ever reads their bound).
    """
    NB = blk_pos.shape[0]
    out = np.zeros((NB,), np.float32)
    P = int(np.sum(blk_len))
    if P > 0:
        # blocks tile the CSR contiguously and in order in both layouts,
        # so posting p belongs to the block repeated at position p
        bid = np.repeat(np.arange(NB), blk_len)
        np.maximum.at(out, bid, np.asarray(impacts[:P]).astype(np.float32))
    return out


def _empty_pack(offsets: np.ndarray) -> dict[str, np.ndarray]:
    """Uncompressed layout: zero-width packed columns + logical blocks."""
    z = np.zeros((0,), np.int32)
    blk_term_off, blk_pos, blk_len = logical_posting_blocks_np(offsets)
    return dict(
        post_packed=np.zeros((0,), np.uint32), blk_first=z, blk_bits=z,
        blk_len=blk_len, blk_word_off=z, blk_pos=blk_pos,
        blk_term_off=blk_term_off, blk_n_exc=z,
    )


def _pfor_width_np(real_deltas: np.ndarray) -> tuple[int, int]:
    """Pick a block's PForDelta base width — ``(bits, n_exc)``.

    Minimizes total stored words: ``ceil(len·bits/32)`` tail-trimmed base
    words plus one exception word per delta exceeding the base width.
    The floor ``bits ≥ bit_length(max) − PFOR_HIGH_BITS`` keeps every
    exception's high bits inside one 24-bit patch field; ties break
    toward the wider base (fewer exceptions → cheaper decode).
    """
    n = len(real_deltas)
    maxbits = max(int(real_deltas.max(initial=0)).bit_length(), 1)
    best_bits, best_exc, best_words = maxbits, 0, max(-(-n * maxbits // 32), 1)
    for width in range(max(1, maxbits - PFOR_HIGH_BITS), maxbits):
        n_exc = int(np.count_nonzero(real_deltas >> width))
        words = max(-(-n * width // 32), 1) + n_exc
        if words < best_words:
            best_bits, best_exc, best_words = width, n_exc, words
    return best_bits, best_exc


def pack_postings_np(
    postings: np.ndarray,
    offsets: np.ndarray,
    impacts: np.ndarray | None = None,
) -> dict[str, np.ndarray]:
    """Delta + bit-pack each term's posting slice into 128-posting blocks.

    Blocks never straddle terms; within a block the first element stores
    delta 0 (its doc id lives in ``blk_first``) and subsequent deltas are
    strictly ≥ 1 (postings are sorted unique doc ids within a term).
    Framing is PForDelta: each block picks the total-word-minimizing base
    width (:func:`_pfor_width_np`) and stores ``ceil(len·bits/32)``
    tail-trimmed base words holding every delta's low ``bits`` bits,
    followed by one patch word per delta that overflows the base width —
    ``slot | high_bits << PFOR_SLOT_BITS``.  The tail padding a ragged
    last block would need is not materialized (``blk_word_off`` is
    explicit, so blocks are variable-width), which is what makes short
    posting lists actually compress.  Decoded slots past ``blk_len`` are
    therefore garbage (they read into the exception words or the next
    block) and every consumer masks them before trusting membership.

    When ``impacts`` is given (the *stored*, possibly quantized, values)
    the dict additionally carries ``blk_max_impact`` — the per-block score
    upper bound driving the pruned traversal (see
    :func:`block_max_impacts_np` for why it must be computed
    post-quantization).
    """
    M = len(offsets) - 1
    blk_term_off = np.zeros((M + 1,), np.int32)
    firsts: list[int] = []
    bits_l: list[int] = []
    lens: list[int] = []
    poss: list[int] = []
    word_off: list[int] = []
    n_exc_l: list[int] = []
    chunks: list[np.ndarray] = []
    w = 0
    j64 = np.arange(POSTING_BLOCK, dtype=np.int64)
    for t in range(M):
        lo, hi = int(offsets[t]), int(offsets[t + 1])
        nb = (hi - lo + POSTING_BLOCK - 1) // POSTING_BLOCK
        blk_term_off[t + 1] = blk_term_off[t] + nb
        for b in range(nb):
            s = lo + b * POSTING_BLOCK
            e = min(s + POSTING_BLOCK, hi)
            ids = postings[s:e].astype(np.int64)
            deltas = np.ones((POSTING_BLOCK,), np.int64)
            deltas[0] = 0
            deltas[1:e - s] = np.diff(ids)
            real = deltas[: e - s]
            bits, n_exc = _pfor_width_np(real)
            low = deltas & ((np.int64(1) << bits) - 1)
            nw = (POSTING_BLOCK * bits) // 32  # 128·bits/32 = 4·bits exactly
            buf = np.zeros((nw,), np.uint64)
            bitpos = j64 * bits
            wi = bitpos >> 5
            off = (bitpos & 31).astype(np.uint64)
            lo64 = low.astype(np.uint64) << off
            np.bitwise_or.at(buf, wi, lo64 & np.uint64(0xFFFFFFFF))
            spill = lo64 >> np.uint64(32)
            # a nonzero spill always lands inside the block (the last delta
            # ends exactly at the block's word boundary), so the clamp only
            # ever redirects zero-valued ORs
            np.bitwise_or.at(buf, np.minimum(wi + 1, nw - 1), spill)
            # store only the words real postings reach: a ragged last block
            # keeps ceil(len·bits/32) words instead of the full 4·bits
            nw_t = max(-(-(e - s) * bits // 32), 1)
            words = buf[:nw_t].astype(np.uint32)
            if n_exc:
                slots = np.flatnonzero(real >> bits).astype(np.uint32)
                high = (real[slots] >> bits).astype(np.uint32)
                words = np.concatenate(
                    [words, slots | (high << np.uint32(PFOR_SLOT_BITS))]
                )
            chunks.append(words)
            firsts.append(int(ids[0]))
            bits_l.append(bits)
            lens.append(e - s)
            poss.append(s)
            word_off.append(w)
            n_exc_l.append(n_exc)
            w += nw_t + n_exc
    if not firsts:  # empty posting store: one degenerate empty block
        chunks.append(np.zeros((4,), np.uint32))
        firsts, bits_l, lens, poss, word_off = [0], [1], [0], [0], [0]
        n_exc_l = [0]
    out = dict(
        post_packed=np.concatenate(chunks),
        blk_first=np.asarray(firsts, np.int32),
        blk_bits=np.asarray(bits_l, np.int32),
        blk_len=np.asarray(lens, np.int32),
        blk_word_off=np.asarray(word_off, np.int32),
        blk_pos=np.asarray(poss, np.int32),
        blk_term_off=blk_term_off,
        blk_n_exc=np.asarray(n_exc_l, np.int32),
    )
    if impacts is not None:
        out["blk_max_impact"] = block_max_impacts_np(
            impacts, out["blk_pos"], out["blk_len"]
        )
    return out


def impact_levels_np(impacts: np.ndarray) -> np.ndarray:
    """Global geometric impact level per posting — i32, 0 = highest.

    Level ``l`` covers stored impacts in ``(vmax/r^(l+1), vmax/r^l]`` with
    ``r = IMPACT_LEVEL_RATIO``; everything below the last boundary folds
    into level ``IMPACT_LEVELS - 1``.  Computed from the *stored* (possibly
    quantized) values so segment order matches what queries actually score.
    """
    v = np.asarray(impacts, np.float32).astype(np.float64)
    vmax = float(v.max(initial=0.0))
    if vmax <= 0.0:
        return np.zeros(v.shape, np.int32)
    lvl = np.floor(
        np.log(vmax / np.maximum(v, vmax * 1e-12))
        / np.log(IMPACT_LEVEL_RATIO)
    )
    return np.clip(lvl, 0, IMPACT_LEVELS - 1).astype(np.int32)


def _impact_order_np(
    postings: np.ndarray, impacts: np.ndarray, offsets: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Reorder each term's slice into descending-impact-level segments.

    Returns ``(postings, impacts, seg_term_off, seg_pos, seg_len)`` — the
    reordered columns plus the segment CSR.  Within each segment doc ids
    ascend (sort key ``(level, docid)``), so delta coding still applies;
    segments tile each term's CSR slice contiguously.
    """
    lvl = impact_levels_np(impacts)
    M = len(offsets) - 1
    post2 = postings.copy()
    imp2 = impacts.copy()
    seg_term_off = np.zeros((M + 1,), np.int32)
    seg_pos_l: list[int] = []
    seg_len_l: list[int] = []
    for t in range(M):
        lo, hi = int(offsets[t]), int(offsets[t + 1])
        ns = 0
        if hi > lo:
            order = np.lexsort((postings[lo:hi], lvl[lo:hi]))
            post2[lo:hi] = postings[lo:hi][order]
            imp2[lo:hi] = impacts[lo:hi][order]
            lv = lvl[lo:hi][order]
            starts = np.flatnonzero(np.r_[True, lv[1:] != lv[:-1]])
            ends = np.r_[starts[1:], hi - lo]
            for a, b in zip(starts, ends):
                seg_pos_l.append(lo + int(a))
                seg_len_l.append(int(b - a))
            ns = len(starts)
        seg_term_off[t + 1] = seg_term_off[t] + ns
    if not seg_pos_l:  # empty store: one degenerate empty segment
        seg_pos_l, seg_len_l = [0], [0]
    return (
        post2, imp2, seg_term_off,
        np.asarray(seg_pos_l, np.int32), np.asarray(seg_len_l, np.int32),
    )


def _suffix_max_per_term_np(
    blk_max: np.ndarray, blk_term_off: np.ndarray
) -> np.ndarray:
    """Per-term suffix-max envelope of block maxima — f32[NB].

    ``out[b] = max(blk_max[b : term_end])`` within each term's block run:
    a safe upper bound for block ``b`` that is monotone non-increasing
    along the run, which is what lets the pruned kernel early-exit.
    """
    out = np.asarray(blk_max, np.float32).copy()
    for t in range(len(blk_term_off) - 1):
        b0, b1 = int(blk_term_off[t]), int(blk_term_off[t + 1])
        if b1 > b0:
            out[b0:b1] = np.maximum.accumulate(out[b0:b1][::-1])[::-1]
    return out


def _trivial_segments_np(M: int) -> dict[str, np.ndarray]:
    """Degenerate segment columns for layout="docid" (never probed)."""
    return dict(
        seg_term_off=np.zeros((M + 1,), np.int32),
        seg_pos=np.zeros((1,), np.int32),
        seg_len=np.zeros((1,), np.int32),
    )


def build_text_index_np(
    doc_terms: list[np.ndarray],
    n_terms: int,
    n_bitmap_terms: int = 0,
    idf: np.ndarray | None = None,
    compress: bool = False,
    impact_dtype: np.dtype | str | None = None,
    layout: str = "docid",
) -> TextIndex:
    """Build from per-doc term-id arrays (with repetitions = frequencies).

    Pure-numpy index construction (host side, analogous to the paper's
    offline index build).  ``idf`` overrides the collection IDF — shard
    builders pass the *corpus-global* IDF (:func:`global_idf_np`) so each
    posting's impact is rounded to f32 exactly once from statistics that
    do not depend on the partitioning, making per-doc scores bit-identical
    across shard layouts (the routing equivalence gate relies on this).

    ``impact_dtype`` lossy-compresses the impact column at build time (the
    one compression entry point — ``normalize_compress`` modes pass f16
    here), so ``blk_max_impact`` is computed from the values that are
    actually stored and the pruning bound survives quantization.

    ``layout`` selects the posting order: ``"docid"`` (ascending doc ids,
    the bit-identical reference) or ``"impact"`` (descending
    quantized-impact segments per term — see the module docstring).
    Impact ordering happens *after* quantization so segments group the
    stored values, and block framing restarts at segment boundaries.
    """
    if layout not in ("docid", "impact"):
        raise ValueError(f"unknown posting layout: {layout!r}")
    n_docs = len(doc_terms)
    # term frequencies per doc, collection document frequencies
    doc_ids_per_term: list[list[int]] = [[] for _ in range(n_terms)]
    freq_per_term: list[list[int]] = [[] for _ in range(n_terms)]
    doc_len = np.zeros((n_docs,), dtype=np.float64)
    for d, terms in enumerate(doc_terms):
        doc_len[d] = max(len(terms), 1)
        uniq, counts = np.unique(terms, return_counts=True)
        for w, c in zip(uniq, counts):
            doc_ids_per_term[int(w)].append(d)
            freq_per_term[int(w)].append(int(c))

    df = np.array([len(x) for x in doc_ids_per_term], dtype=np.float64)
    if idf is None:
        idf = np.log(1.0 + n_docs / np.maximum(df, 1.0))

    offsets = np.zeros((n_terms + 1,), dtype=np.int32)
    offsets[1:] = np.cumsum([len(x) for x in doc_ids_per_term])
    P = int(offsets[-1])
    postings = np.zeros((P,), dtype=np.int32)
    impacts = np.zeros((P,), dtype=np.float32)
    for w in range(n_terms):
        lo, hi = offsets[w], offsets[w + 1]
        if hi == lo:
            continue
        ids = np.asarray(doc_ids_per_term[w], dtype=np.int32)
        fr = np.asarray(freq_per_term[w], dtype=np.float64)
        order = np.argsort(ids)
        postings[lo:hi] = ids[order]
        imp = idf[w] * (1.0 + np.log(fr[order])) / np.sqrt(doc_len[ids[order]])
        impacts[lo:hi] = imp.astype(np.float32)

    # block bitmaps for the most frequent terms
    n_blocks = (n_docs + BLOCK - 1) // BLOCK
    n_words = n_blocks * WORDS_PER_BLOCK
    if n_bitmap_terms > 0:
        top_terms = np.argsort(-df)[:n_bitmap_terms].astype(np.int32)
        bitmaps = np.zeros((n_bitmap_terms, n_words), dtype=np.uint32)
        for row, w in enumerate(top_terms):
            lo, hi = offsets[w], offsets[w + 1]
            ids = postings[lo:hi]
            words = ids // 32
            bits = (ids % 32).astype(np.uint32)
            np.bitwise_or.at(bitmaps[row], words, np.uint32(1) << bits)
    else:
        top_terms = np.zeros((0,), dtype=np.int32)
        bitmaps = np.zeros((0, n_words), dtype=np.uint32)

    if impact_dtype is not None:
        impacts = impacts.astype(impact_dtype)
    if layout == "impact":
        postings, impacts, seg_term_off, seg_pos, seg_len = _impact_order_np(
            postings, impacts, offsets
        )
        seg = dict(seg_term_off=seg_term_off, seg_pos=seg_pos, seg_len=seg_len)
        # frame blocks over *segments* (blocks never straddle a segment):
        # segments tile each term's CSR slice contiguously and in order,
        # so segment ends are a valid CSR over the whole posting store
        NS = int(seg_term_off[-1])
        frame_off = np.zeros((NS + 1,), np.int64)
        frame_off[1:] = (seg_pos[:NS] + seg_len[:NS]).astype(np.int64)
    else:
        seg = _trivial_segments_np(n_terms)
        frame_off = offsets
    if compress:
        pack = pack_postings_np(postings, frame_off, impacts=impacts)
        postings = np.zeros((0,), np.int32)  # packed words are the store
    else:
        pack = _empty_pack(frame_off)
        pack["blk_max_impact"] = block_max_impacts_np(
            impacts, pack["blk_pos"], pack["blk_len"]
        )
    if layout == "impact":
        # collapse the per-segment block CSR back to per-term, and widen
        # the exact block maxima into the per-term suffix-max envelope —
        # the monotone bound the early-exiting pruned traversal needs
        pack["blk_term_off"] = pack["blk_term_off"][seg["seg_term_off"]]
        pack["blk_max_impact"] = _suffix_max_per_term_np(
            pack["blk_max_impact"], pack["blk_term_off"]
        )
    term_blocks = np.diff(pack["blk_term_off"])
    term_segments = np.diff(seg["seg_term_off"])
    return TextIndex(
        postings=jnp.asarray(postings),
        impacts=jnp.asarray(impacts),
        offsets=jnp.asarray(offsets),
        bitmaps=jnp.asarray(bitmaps),
        bitmap_term_ids=jnp.asarray(top_terms),
        **{k: jnp.asarray(v) for k, v in pack.items()},
        **{k: jnp.asarray(v) for k, v in seg.items()},
        n_docs=n_docs,
        n_terms=n_terms,
        max_term_blocks=int(max(term_blocks.max(initial=0), 1)),
        layout=layout,
        max_term_segments=int(max(term_segments.max(initial=0), 1)),
    )


def _with_impacts(index: TextIndex, impacts: jax.Array) -> TextIndex:
    """Replace the impact column and refresh ``blk_max_impact`` to match.

    Under layout="impact" the refreshed maxima are re-enveloped per term —
    per-term rescaling preserves within-term order, so the suffix-max
    stays both a safe bound and monotone along each block run.
    """
    bm = block_max_impacts_np(
        np.asarray(impacts), np.asarray(index.blk_pos), np.asarray(index.blk_len)
    )
    if index.layout == "impact":
        bm = _suffix_max_per_term_np(bm, np.asarray(index.blk_term_off))
    return dataclasses.replace(
        index, impacts=impacts, blk_max_impact=jnp.asarray(bm)
    )


def quantize_impacts(index: TextIndex, dtype=jnp.float16) -> TextIndex:
    """Deprecated shim: quantize impacts post-build.

    Prefer ``build_text_index_np(..., impact_dtype=...)`` — the one
    compression entry point (engine builders route every ``compress`` mode
    through it).  Kept for callers holding an already-built index; it
    refreshes ``blk_max_impact`` so pruning bounds stay safe.
    """
    return _with_impacts(index, index.impacts.astype(dtype))


def global_idf_np(doc_terms: list[np.ndarray], n_terms: int) -> np.ndarray:
    """Corpus-wide IDF, matching ``build_text_index_np``'s formula."""
    df = np.zeros((n_terms,), dtype=np.float64)
    for terms in doc_terms:
        np.add.at(df, np.unique(terms), 1.0)
    return np.log(1.0 + len(doc_terms) / np.maximum(df, 1.0))


def rescale_impacts_to_global(index: TextIndex, idf_global: np.ndarray) -> TextIndex:
    """Swap a shard-local index's IDF for the corpus-global one.

    Text impacts are ``idf · (1+log tf) / sqrt(doc_len)``; tf and doc_len
    are per-document, but idf is a *collection* statistic — a shard scoring
    with its local idf would rank differently from the whole corpus.  Real
    distributed engines broadcast global term stats to every shard; we do
    the same by rescaling each posting's impact by ``idf_global/idf_local``.
    """
    offsets = np.asarray(index.offsets)
    counts = np.diff(offsets)
    idf_local = np.log(1.0 + index.n_docs / np.maximum(counts.astype(np.float64), 1.0))
    ratio = np.where(counts > 0, idf_global / idf_local, 1.0)
    impacts = np.asarray(index.impacts) * np.repeat(ratio, counts).astype(np.float32)
    return _with_impacts(index, jnp.asarray(impacts))


# ---------------------------------------------------------------------------
# Query-time primitives (jit-safe)
# ---------------------------------------------------------------------------

def term_slice(index: TextIndex, term: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(start, length) of a term's posting slice."""
    lo = index.offsets[term]
    hi = index.offsets[term + 1]
    return lo, hi - lo


def decode_posting_blocks(index: TextIndex, blocks: jax.Array) -> jax.Array:
    """Decode compressed blocks to doc ids — i32[..., POSTING_BLOCK].

    Pure shift/mask extraction of each block's 128 base-width deltas from
    the packed words, then a replay of the block's PForDelta patch list
    (each patch word restores one delta's high bits), then a prefix sum
    from ``blk_first``.  Slots past ``blk_len`` are garbage — blocks are
    stored tail-trimmed, so those reads fall into the exception words or
    the next block; mask with ``blk_len`` before trusting membership.
    """
    bits = index.blk_bits[blocks]  # [...]
    w0 = index.blk_word_off[blocks]
    j = jnp.arange(POSTING_BLOCK, dtype=jnp.int32)
    bitpos = j * bits[..., None]  # [..., 128]
    word = w0[..., None] + (bitpos >> 5)
    off = (bitpos & 31).astype(jnp.uint32)
    W = max(index.post_packed.shape[0], 1)
    lo_w = index.post_packed[jnp.clip(word, 0, W - 1)]
    hi_w = index.post_packed[jnp.clip(word + 1, 0, W - 1)]
    # two-word extraction; the hi shift amount stays < 32 via the mask and
    # the off == 0 case (where 32 - off would be 32) selects 0 anyway
    hi_part = jnp.where(
        off > 0, hi_w << ((jnp.uint32(32) - off) & jnp.uint32(31)), jnp.uint32(0)
    )
    mask = (jnp.uint32(1) << bits[..., None].astype(jnp.uint32)) - 1  # bits ≤ 31
    delta = (((lo_w >> off) | hi_part) & mask).astype(jnp.int32)
    delta = jnp.where(j == 0, 0, delta)
    # PForDelta patch replay: exception words live right after the block's
    # tail-trimmed base words; each restores one slot's high bits.  The
    # loop bound is the batch-wide max patch count (traced — fori_loop
    # lowers to a while_loop), so exception-free batches decode as before.
    n_exc = index.blk_n_exc[blocks]  # [...]
    base_words = jnp.maximum(
        (index.blk_len[blocks] * bits + 31) >> 5, 1
    )
    ew0 = w0 + base_words

    def _patch(e, d):
        pw = index.post_packed[jnp.clip(ew0 + e, 0, W - 1)]  # [...]
        slot = (pw & jnp.uint32((1 << PFOR_SLOT_BITS) - 1)).astype(jnp.int32)
        high = (pw >> jnp.uint32(PFOR_SLOT_BITS)).astype(jnp.int32)
        add = jnp.where(e < n_exc, high << bits, 0)  # [...]
        return d + jnp.where(j == slot[..., None], add[..., None], 0)

    delta = jax.lax.fori_loop(0, jnp.max(n_exc), _patch, delta)
    return index.blk_first[blocks][..., None] + jnp.cumsum(delta, axis=-1)


def _probe_term_packed(
    index: TextIndex, term: jax.Array, doc_ids: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Compressed-layout probe: block-head binary search + one-block decode."""
    b0 = index.blk_term_off[term]
    nb = index.blk_term_off[term + 1] - b0
    NB = index.blk_first.shape[0]
    # containing block = last block whose first doc id is ≤ the key
    pos = _searchsorted_slice(index.blk_first, b0, nb, doc_ids)
    exact = (pos < b0 + nb) & (
        index.blk_first[jnp.clip(pos, 0, NB - 1)] == doc_ids
    )
    blk = jnp.where(exact, pos, pos - 1)
    in_range = (blk >= b0) & (blk < b0 + nb) & (nb > 0)
    blk_s = jnp.clip(blk, 0, NB - 1)
    decoded = decode_posting_blocks(index, blk_s)  # [..., 128]
    j = jnp.arange(POSTING_BLOCK, dtype=jnp.int32)
    hit = (decoded == doc_ids[..., None]) & (j < index.blk_len[blk_s][..., None])
    member = in_range & hit.any(axis=-1)
    jpos = jnp.argmax(hit, axis=-1).astype(jnp.int32)
    apos = jnp.clip(index.blk_pos[blk_s] + jpos, 0, index.n_postings - 1)
    impact = jnp.where(member, index.impacts[apos].astype(jnp.float32), 0.0)
    return member, impact


def _probe_term_segmented(
    index: TextIndex, term: jax.Array, doc_ids: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Impact-layout probe: binary search within each of the term's segments.

    Impact ordering breaks the global docID-ascending invariant the plain
    probes rely on, but doc ids still ascend *within* each segment — so
    membership is an OR over ``max_term_segments`` per-segment searches
    (a doc occurs at most once per term, so segment hits are disjoint and
    the impact sum picks up exactly the one stored value).
    """
    s0 = index.seg_term_off[term]
    ns = index.seg_term_off[term + 1] - s0
    NS = index.seg_pos.shape[0]
    P = index.n_postings
    member0 = jnp.zeros(doc_ids.shape, bool)
    impact0 = jnp.zeros(doc_ids.shape, jnp.float32)
    if index.is_compressed:
        NB = index.blk_first.shape[0]
        j = jnp.arange(POSTING_BLOCK, dtype=jnp.int32)

        def seg_one(i, carry):
            member, impact, b_off = carry
            s = jnp.clip(s0 + i, 0, NS - 1)
            live = i < ns
            # segments tile the term's block run contiguously, so the
            # running block offset carried across iterations addresses
            # this segment's ceil(len/128) blocks directly
            nb_s = jnp.where(
                live, -(-index.seg_len[s] // POSTING_BLOCK), 0
            )
            pos = _searchsorted_slice(index.blk_first, b_off, nb_s, doc_ids)
            exact = (pos < b_off + nb_s) & (
                index.blk_first[jnp.clip(pos, 0, NB - 1)] == doc_ids
            )
            blk = jnp.where(exact, pos, pos - 1)
            in_range = (blk >= b_off) & (blk < b_off + nb_s)
            blk_s = jnp.clip(blk, 0, NB - 1)
            decoded = decode_posting_blocks(index, blk_s)
            hit = (decoded == doc_ids[..., None]) & (
                j < index.blk_len[blk_s][..., None]
            )
            m = in_range & hit.any(axis=-1)
            jpos = jnp.argmax(hit, axis=-1).astype(jnp.int32)
            apos = jnp.clip(index.blk_pos[blk_s] + jpos, 0, P - 1)
            imp = jnp.where(m, index.impacts[apos].astype(jnp.float32), 0.0)
            return member | m, impact + imp, b_off + nb_s

        member, impact, _ = jax.lax.fori_loop(
            0, index.max_term_segments, seg_one,
            (member0, impact0, index.blk_term_off[term]),
        )
        return member, impact

    def seg_one(i, carry):
        member, impact = carry
        s = jnp.clip(s0 + i, 0, NS - 1)
        live = i < ns
        lo = index.seg_pos[s]
        n = jnp.where(live, index.seg_len[s], 0)
        pos = _searchsorted_slice(index.postings, lo, n, doc_ids)
        found = index.postings[jnp.clip(pos, 0, P - 1)]
        m = (pos < lo + n) & (found == doc_ids) & (n > 0)
        imp = jnp.where(
            m, index.impacts[jnp.clip(pos, 0, P - 1)].astype(jnp.float32), 0.0
        )
        return member | m, impact + imp

    member, impact = jax.lax.fori_loop(
        0, index.max_term_segments, seg_one, (member0, impact0)
    )
    return member, impact


def probe_term(
    index: TextIndex, term: jax.Array, doc_ids: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Membership + impact of ``doc_ids`` in one term's posting list.

    Vectorized binary search over the whole posting array restricted to the
    term slice.  Returns (member bool[...], impact f32[...]).  The impact
    layout dispatches to the segment-aware probe (doc ids only ascend
    within a segment there); the docid layout keeps the single-slice fast
    path, bit-identical to what it always did.
    """
    if index.layout == "impact":
        return _probe_term_segmented(index, term, doc_ids)
    if index.is_compressed:
        return _probe_term_packed(index, term, doc_ids)
    lo, n = term_slice(index, term)
    # searchsorted over the full array with translated bounds: postings within
    # a slice are sorted, and slices are disjoint, so search the slice via
    # index arithmetic on a gathered window — instead do searchsorted on the
    # full array bounded to [lo, lo+n) by clamping.
    pos = _searchsorted_slice(index.postings, lo, n, doc_ids)
    found_id = index.postings[jnp.clip(pos, 0, index.n_postings - 1)]
    member = (pos < lo + n) & (found_id == doc_ids) & (n > 0)
    safe_pos = jnp.clip(pos, 0, index.n_postings - 1)
    impact = jnp.where(member, index.impacts[safe_pos].astype(jnp.float32), 0.0)
    return member, impact


def _searchsorted_slice(
    arr: jax.Array, lo: jax.Array, n: jax.Array, keys: jax.Array
) -> jax.Array:
    """Branchless binary search of ``keys`` in ``arr[lo:lo+n)`` (left).

    Works for traced (dynamic) lo/n: a fixed ``ceil(log2(P))+1``-step bisection.
    Returns absolute positions in [lo, lo+n].
    """
    P = arr.shape[0]
    steps = max(int(np.ceil(np.log2(max(P, 2)))) + 1, 1)
    lo_ = jnp.broadcast_to(lo, keys.shape).astype(jnp.int32)
    hi_ = jnp.broadcast_to(lo + n, keys.shape).astype(jnp.int32)

    def body(_, lh):
        l, h = lh
        active = l < h
        # overflow-safe midpoint: l + h wraps int32 once the posting store
        # passes 2^30 entries (production-scale shards); l + (h-l)//2 is
        # value-identical for 0 <= l <= h and never overflows
        mid = l + (h - l) // 2
        v = arr[jnp.clip(mid, 0, P - 1)]
        go_right = v < keys
        l = jnp.where(active & go_right, mid + 1, l)
        h = jnp.where(active & ~go_right, mid, h)
        return l, h

    l, _ = jax.lax.fori_loop(0, steps, body, (lo_, hi_))
    return l


def conjunction_candidates(
    index: TextIndex,
    terms: jax.Array,  # i32[d] (padded with -1)
    max_candidates: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """TEXT-FIRST driver: intersect posting lists of ``terms``.

    Uses the *first valid* term's posting list as the driver (capped at
    ``max_candidates`` postings, an early-termination budget) and probes the
    remaining terms by binary search.  Returns

      cand_ids  i32[max_candidates]   (docIDs; ascending among valid under
                                       layout="docid", impact-segment
                                       order under layout="impact")
      valid     bool[max_candidates]
      text_score f32[max_candidates]  (sum of impacts over query terms)
    """
    d = terms.shape[0]
    # Classic optimization: drive the intersection with the *shortest* list.
    safe_terms = jnp.maximum(terms, 0)
    lens = index.offsets[safe_terms + 1] - index.offsets[safe_terms]
    lens = jnp.where(terms >= 0, lens, jnp.int32(2**31 - 1))
    driver = jnp.argmin(lens).astype(jnp.int32)
    t0 = safe_terms[driver]
    any_real = terms[0] >= 0  # terms are packed left; term 0 real iff query nonempty

    lo, n = term_slice(index, t0)
    n = jnp.minimum(n, max_candidates)
    idx = jnp.arange(max_candidates, dtype=jnp.int32)
    valid = (idx < n) & any_real
    if index.is_compressed:
        # stream the driver's blocks: decode ceil(mc/128) consecutive blocks
        # once and flatten, instead of per-element block decodes
        NB = index.blk_first.shape[0]
        nbd = (max_candidates + POSTING_BLOCK - 1) // POSTING_BLOCK
        blocks = jnp.clip(
            index.blk_term_off[t0] + jnp.arange(nbd, dtype=jnp.int32), 0, NB - 1
        )
        decoded = decode_posting_blocks(index, blocks)
        if index.layout == "impact":
            # segment-restarted framing leaves ragged blocks *mid-run*
            # (each segment's tail), so a plain flatten would interleave
            # garbage slots: map each CSR offset through the blocks' valid
            # lengths instead.  The docid layout keeps the plain flatten
            # (only its last block is ragged — past n is masked anyway).
            cl = jnp.cumsum(index.blk_len[blocks])
            bi = jnp.searchsorted(cl, idx, side="right")
            bi_s = jnp.clip(bi, 0, nbd - 1)
            lane = idx - jnp.where(bi > 0, cl[jnp.maximum(bi - 1, 0)], 0)
            cand = decoded[bi_s, jnp.clip(lane, 0, POSTING_BLOCK - 1)]
            # blocks tile the CSR contiguously, so the driver's i-th
            # posting lives at CSR position lo + i in both layouts
            apos = jnp.clip(lo + idx, 0, index.n_postings - 1)
        else:
            cand = decoded.reshape(-1)[:max_candidates]
            apos = jnp.clip(
                index.blk_pos[blocks][:, None]
                + jnp.arange(POSTING_BLOCK, dtype=jnp.int32)[None, :],
                0,
                index.n_postings - 1,
            ).reshape(-1)[:max_candidates]
        imp = index.impacts[apos].astype(jnp.float32)
    else:
        pos = lo + idx
        cand = index.postings[jnp.clip(pos, 0, index.n_postings - 1)]
        imp = index.impacts[jnp.clip(pos, 0, index.n_postings - 1)].astype(
            jnp.float32
        )
    cand = jnp.where(valid, cand, jnp.int32(2**31 - 1))
    score = jnp.where(valid, imp, 0.0)

    def probe_one(i, carry):
        valid, score = carry
        t = terms[i]
        is_real = (t >= 0) & (i != driver)
        member, imp = probe_term(index, jnp.maximum(t, 0), cand)
        valid = valid & (member | ~is_real)
        score = score + jnp.where(is_real, imp, 0.0)
        return valid, score

    valid, score = jax.lax.fori_loop(0, d, probe_one, (valid, score))
    cand = jnp.where(valid, cand, jnp.int32(2**31 - 1))
    score = jnp.where(valid, score, 0.0)
    return cand, valid, score


def text_score_of_docs(
    index: TextIndex,
    terms: jax.Array,  # i32[d] padded with -1
    doc_ids: jax.Array,  # i32[C]
) -> tuple[jax.Array, jax.Array]:
    """AND-semantics text score for arbitrary candidate docs.

    Returns (match bool[C], score f32[C]); ``match`` requires every valid
    query term to occur in the doc.
    """
    d = terms.shape[0]

    def probe_one(i, carry):
        match, score = carry
        t = terms[i]
        is_real = t >= 0
        member, imp = probe_term(index, jnp.maximum(t, 0), doc_ids)
        match = match & (member | ~is_real)
        score = score + jnp.where(is_real, imp, 0.0)
        return match, score

    match0 = jnp.ones(doc_ids.shape, dtype=bool)
    score0 = jnp.zeros(doc_ids.shape, dtype=jnp.float32)
    match, score = jax.lax.fori_loop(0, d, probe_one, (match0, score0))
    return match, score


def text_score_of_docs_counted(
    index: TextIndex,
    terms: jax.Array,  # i32[d] padded with -1
    doc_ids: jax.Array,  # i32[C]
    valid: jax.Array,  # bool[C] — candidates that are live before term 0
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``text_score_of_docs`` plus an honest probe counter.

    Same match/score math (bit-identical outputs), but additionally counts
    the probes a term-at-a-time short-circuiting evaluator would issue:
    before each term only the candidates still matching every earlier term
    are probed, so the count shrinks as terms eliminate candidates.
    Returns (match bool[C], score f32[C], probes i32 scalar).
    """
    d = terms.shape[0]

    def probe_one(i, carry):
        match, score, probes = carry
        t = terms[i]
        is_real = t >= 0
        live = match & valid
        probes = probes + jnp.where(
            is_real, jnp.sum(live.astype(jnp.int32)), 0
        )
        member, imp = probe_term(index, jnp.maximum(t, 0), doc_ids)
        match = match & (member | ~is_real)
        score = score + jnp.where(is_real, imp, 0.0)
        return match, score, probes

    match0 = jnp.ones(doc_ids.shape, dtype=bool)
    score0 = jnp.zeros(doc_ids.shape, dtype=jnp.float32)
    match, score, probes = jax.lax.fori_loop(
        0, d, probe_one, (match0, score0, jnp.int32(0))
    )
    return match, score, probes

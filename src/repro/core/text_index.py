"""Inverted text index: CSR posting arrays + impact scores + block bitmaps.

Layout (paper §II.B, adapted to HBM-resident fixed-shape arrays):

* ``postings i32[P]``  — docIDs, ascending within each term's slice.
* ``impacts  f32[P]``  — precomputed per-posting *impact* score: the term's
  full contribution to the lnc.ltc cosine of eq. (3),
  ``ln(1 + n/f_t) * (1 + ln f_{D,t}) / sqrt(|D|)``, so query-time text
  scoring is a pure gather+sum (quantizable to f16/int8; see ``quantize``).
* ``offsets  i32[M+1]`` — CSR slices: term w owns postings[offsets[w]:offsets[w+1]].
* block bitmaps: for the ``n_bitmap_terms`` most frequent terms, a packed
  u32 bitmap over ceil(N/128)*4 words marking which 128-doc *blocks*'
  documents contain the term — the TPU-idiomatic conjunction prefilter
  (AND + popcount; see kernels/bitmap_filter).

Membership probing at query time is a vectorized binary search
(``searchsorted``) into the term slice — the TPU analogue of DAAT list
merging.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 128  # docs per bitmap block
WORDS_PER_BLOCK = BLOCK // 32


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class TextIndex:
    """HBM-resident inverted index (a pytree of arrays)."""

    postings: jax.Array  # i32[P] docIDs
    impacts: jax.Array  # f32[P] precomputed impact scores
    offsets: jax.Array  # i32[M+1]
    bitmaps: jax.Array  # u32[n_bitmap_terms, n_words]  (may be [0, n_words])
    bitmap_term_ids: jax.Array  # i32[n_bitmap_terms] term id per bitmap row
    n_docs: int = field(metadata=dict(static=True))
    n_terms: int = field(metadata=dict(static=True))

    @property
    def n_postings(self) -> int:
        return self.postings.shape[0]


def build_text_index_np(
    doc_terms: list[np.ndarray],
    n_terms: int,
    n_bitmap_terms: int = 0,
    idf: np.ndarray | None = None,
) -> TextIndex:
    """Build from per-doc term-id arrays (with repetitions = frequencies).

    Pure-numpy index construction (host side, analogous to the paper's
    offline index build).  ``idf`` overrides the collection IDF — shard
    builders pass the *corpus-global* IDF (:func:`global_idf_np`) so each
    posting's impact is rounded to f32 exactly once from statistics that
    do not depend on the partitioning, making per-doc scores bit-identical
    across shard layouts (the routing equivalence gate relies on this).
    """
    n_docs = len(doc_terms)
    # term frequencies per doc, collection document frequencies
    doc_ids_per_term: list[list[int]] = [[] for _ in range(n_terms)]
    freq_per_term: list[list[int]] = [[] for _ in range(n_terms)]
    doc_len = np.zeros((n_docs,), dtype=np.float64)
    for d, terms in enumerate(doc_terms):
        doc_len[d] = max(len(terms), 1)
        uniq, counts = np.unique(terms, return_counts=True)
        for w, c in zip(uniq, counts):
            doc_ids_per_term[int(w)].append(d)
            freq_per_term[int(w)].append(int(c))

    df = np.array([len(x) for x in doc_ids_per_term], dtype=np.float64)
    if idf is None:
        idf = np.log(1.0 + n_docs / np.maximum(df, 1.0))

    offsets = np.zeros((n_terms + 1,), dtype=np.int32)
    offsets[1:] = np.cumsum([len(x) for x in doc_ids_per_term])
    P = int(offsets[-1])
    postings = np.zeros((P,), dtype=np.int32)
    impacts = np.zeros((P,), dtype=np.float32)
    for w in range(n_terms):
        lo, hi = offsets[w], offsets[w + 1]
        if hi == lo:
            continue
        ids = np.asarray(doc_ids_per_term[w], dtype=np.int32)
        fr = np.asarray(freq_per_term[w], dtype=np.float64)
        order = np.argsort(ids)
        postings[lo:hi] = ids[order]
        imp = idf[w] * (1.0 + np.log(fr[order])) / np.sqrt(doc_len[ids[order]])
        impacts[lo:hi] = imp.astype(np.float32)

    # block bitmaps for the most frequent terms
    n_blocks = (n_docs + BLOCK - 1) // BLOCK
    n_words = n_blocks * WORDS_PER_BLOCK
    if n_bitmap_terms > 0:
        top_terms = np.argsort(-df)[:n_bitmap_terms].astype(np.int32)
        bitmaps = np.zeros((n_bitmap_terms, n_words), dtype=np.uint32)
        for row, w in enumerate(top_terms):
            lo, hi = offsets[w], offsets[w + 1]
            ids = postings[lo:hi]
            words = ids // 32
            bits = (ids % 32).astype(np.uint32)
            np.bitwise_or.at(bitmaps[row], words, np.uint32(1) << bits)
    else:
        top_terms = np.zeros((0,), dtype=np.int32)
        bitmaps = np.zeros((0, n_words), dtype=np.uint32)

    return TextIndex(
        postings=jnp.asarray(postings),
        impacts=jnp.asarray(impacts),
        offsets=jnp.asarray(offsets),
        bitmaps=jnp.asarray(bitmaps),
        bitmap_term_ids=jnp.asarray(top_terms),
        n_docs=n_docs,
        n_terms=n_terms,
    )


def quantize_impacts(index: TextIndex, dtype=jnp.float16) -> TextIndex:
    """Lossy-compress impact scores (paper: compressed index formats)."""
    return TextIndex(
        postings=index.postings,
        impacts=index.impacts.astype(dtype),
        offsets=index.offsets,
        bitmaps=index.bitmaps,
        bitmap_term_ids=index.bitmap_term_ids,
        n_docs=index.n_docs,
        n_terms=index.n_terms,
    )


def global_idf_np(doc_terms: list[np.ndarray], n_terms: int) -> np.ndarray:
    """Corpus-wide IDF, matching ``build_text_index_np``'s formula."""
    df = np.zeros((n_terms,), dtype=np.float64)
    for terms in doc_terms:
        np.add.at(df, np.unique(terms), 1.0)
    return np.log(1.0 + len(doc_terms) / np.maximum(df, 1.0))


def rescale_impacts_to_global(index: TextIndex, idf_global: np.ndarray) -> TextIndex:
    """Swap a shard-local index's IDF for the corpus-global one.

    Text impacts are ``idf · (1+log tf) / sqrt(doc_len)``; tf and doc_len
    are per-document, but idf is a *collection* statistic — a shard scoring
    with its local idf would rank differently from the whole corpus.  Real
    distributed engines broadcast global term stats to every shard; we do
    the same by rescaling each posting's impact by ``idf_global/idf_local``.
    """
    offsets = np.asarray(index.offsets)
    counts = np.diff(offsets)
    idf_local = np.log(1.0 + index.n_docs / np.maximum(counts.astype(np.float64), 1.0))
    ratio = np.where(counts > 0, idf_global / idf_local, 1.0)
    impacts = np.asarray(index.impacts) * np.repeat(ratio, counts).astype(np.float32)
    return TextIndex(
        postings=index.postings,
        impacts=jnp.asarray(impacts),
        offsets=index.offsets,
        bitmaps=index.bitmaps,
        bitmap_term_ids=index.bitmap_term_ids,
        n_docs=index.n_docs,
        n_terms=index.n_terms,
    )


# ---------------------------------------------------------------------------
# Query-time primitives (jit-safe)
# ---------------------------------------------------------------------------

def term_slice(index: TextIndex, term: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(start, length) of a term's posting slice."""
    lo = index.offsets[term]
    hi = index.offsets[term + 1]
    return lo, hi - lo


def probe_term(
    index: TextIndex, term: jax.Array, doc_ids: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Membership + impact of ``doc_ids`` in one term's posting list.

    Vectorized binary search over the whole posting array restricted to the
    term slice.  Returns (member bool[...], impact f32[...]).
    """
    lo, n = term_slice(index, term)
    # searchsorted over the full array with translated bounds: postings within
    # a slice are sorted, and slices are disjoint, so search the slice via
    # index arithmetic on a gathered window — instead do searchsorted on the
    # full array bounded to [lo, lo+n) by clamping.
    pos = _searchsorted_slice(index.postings, lo, n, doc_ids)
    found_id = index.postings[jnp.clip(pos, 0, index.n_postings - 1)]
    member = (pos < lo + n) & (found_id == doc_ids) & (n > 0)
    safe_pos = jnp.clip(pos, 0, index.n_postings - 1)
    impact = jnp.where(member, index.impacts[safe_pos].astype(jnp.float32), 0.0)
    return member, impact


def _searchsorted_slice(
    arr: jax.Array, lo: jax.Array, n: jax.Array, keys: jax.Array
) -> jax.Array:
    """Branchless binary search of ``keys`` in ``arr[lo:lo+n)`` (left).

    Works for traced (dynamic) lo/n: a fixed ``ceil(log2(P))+1``-step bisection.
    Returns absolute positions in [lo, lo+n].
    """
    P = arr.shape[0]
    steps = max(int(np.ceil(np.log2(max(P, 2)))) + 1, 1)
    lo_ = jnp.broadcast_to(lo, keys.shape).astype(jnp.int32)
    hi_ = jnp.broadcast_to(lo + n, keys.shape).astype(jnp.int32)

    def body(_, lh):
        l, h = lh
        active = l < h
        # overflow-safe midpoint: l + h wraps int32 once the posting store
        # passes 2^30 entries (production-scale shards); l + (h-l)//2 is
        # value-identical for 0 <= l <= h and never overflows
        mid = l + (h - l) // 2
        v = arr[jnp.clip(mid, 0, P - 1)]
        go_right = v < keys
        l = jnp.where(active & go_right, mid + 1, l)
        h = jnp.where(active & ~go_right, mid, h)
        return l, h

    l, _ = jax.lax.fori_loop(0, steps, body, (lo_, hi_))
    return l


def conjunction_candidates(
    index: TextIndex,
    terms: jax.Array,  # i32[d] (padded with -1)
    max_candidates: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """TEXT-FIRST driver: intersect posting lists of ``terms``.

    Uses the *first valid* term's posting list as the driver (capped at
    ``max_candidates`` postings, an early-termination budget) and probes the
    remaining terms by binary search.  Returns

      cand_ids  i32[max_candidates]   (docIDs, ascending among valid)
      valid     bool[max_candidates]
      text_score f32[max_candidates]  (sum of impacts over query terms)
    """
    d = terms.shape[0]
    # Classic optimization: drive the intersection with the *shortest* list.
    safe_terms = jnp.maximum(terms, 0)
    lens = index.offsets[safe_terms + 1] - index.offsets[safe_terms]
    lens = jnp.where(terms >= 0, lens, jnp.int32(2**31 - 1))
    driver = jnp.argmin(lens).astype(jnp.int32)
    t0 = safe_terms[driver]
    any_real = terms[0] >= 0  # terms are packed left; term 0 real iff query nonempty

    lo, n = term_slice(index, t0)
    n = jnp.minimum(n, max_candidates)
    idx = jnp.arange(max_candidates, dtype=jnp.int32)
    pos = lo + idx
    valid = (idx < n) & any_real
    cand = index.postings[jnp.clip(pos, 0, index.n_postings - 1)]
    cand = jnp.where(valid, cand, jnp.int32(2**31 - 1))
    score = jnp.where(
        valid,
        index.impacts[jnp.clip(pos, 0, index.n_postings - 1)].astype(jnp.float32),
        0.0,
    )

    def probe_one(i, carry):
        valid, score = carry
        t = terms[i]
        is_real = (t >= 0) & (i != driver)
        member, imp = probe_term(index, jnp.maximum(t, 0), cand)
        valid = valid & (member | ~is_real)
        score = score + jnp.where(is_real, imp, 0.0)
        return valid, score

    valid, score = jax.lax.fori_loop(0, d, probe_one, (valid, score))
    cand = jnp.where(valid, cand, jnp.int32(2**31 - 1))
    score = jnp.where(valid, score, 0.0)
    return cand, valid, score


def text_score_of_docs(
    index: TextIndex,
    terms: jax.Array,  # i32[d] padded with -1
    doc_ids: jax.Array,  # i32[C]
) -> tuple[jax.Array, jax.Array]:
    """AND-semantics text score for arbitrary candidate docs.

    Returns (match bool[C], score f32[C]); ``match`` requires every valid
    query term to occur in the doc.
    """
    d = terms.shape[0]

    def probe_one(i, carry):
        match, score = carry
        t = terms[i]
        is_real = t >= 0
        member, imp = probe_term(index, jnp.maximum(t, 0), doc_ids)
        match = match & (member | ~is_real)
        score = score + jnp.where(is_real, imp, 0.0)
        return match, score

    match0 = jnp.ones(doc_ids.shape, dtype=bool)
    score0 = jnp.zeros(doc_ids.shape, dtype=jnp.float32)
    match, score = jax.lax.fori_loop(0, d, probe_one, (match0, score0))
    return match, score

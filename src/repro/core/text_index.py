"""Inverted text index: CSR posting arrays + impact scores + block bitmaps.

Layout (paper §II.B, adapted to HBM-resident fixed-shape arrays):

* ``postings i32[P]``  — docIDs, ascending within each term's slice.
* ``impacts  f32[P]``  — precomputed per-posting *impact* score: the term's
  full contribution to the lnc.ltc cosine of eq. (3),
  ``ln(1 + n/f_t) * (1 + ln f_{D,t}) / sqrt(|D|)``, so query-time text
  scoring is a pure gather+sum (quantizable to f16/int8; see ``quantize``).
* ``offsets  i32[M+1]`` — CSR slices: term w owns postings[offsets[w]:offsets[w+1]].
* block bitmaps: for the ``n_bitmap_terms`` most frequent terms, a packed
  u32 bitmap over ceil(N/128)*4 words marking which 128-doc *blocks*'
  documents contain the term — the TPU-idiomatic conjunction prefilter
  (AND + popcount; see kernels/bitmap_filter).

Membership probing at query time is a vectorized binary search
(``searchsorted``) into the term slice — the TPU analogue of DAAT list
merging.

Compressed posting storage (paper §II.B: "compressed index formats")
--------------------------------------------------------------------

``build_text_index_np(compress=True)`` replaces the raw ``postings i32[P]``
column with a delta + bit-packed store cut into 128-posting blocks that
never straddle a term slice:

* ``post_packed u32[W]`` — little-endian bit-packed doc-id deltas; each
  block is word-aligned and uses a fixed per-block width
  ``blk_bits[b] = max(1, bit_length(max delta))`` (128·bits/32 = 4·bits
  words per block, exactly).
* ``blk_first/blk_bits/blk_len/blk_word_off/blk_pos i32[NB]`` — per-block
  first doc id, bit width, valid count, start word, and absolute CSR
  position of the block's first posting (impacts stay CSR-addressed).
* ``blk_term_off i32[M+1]`` — CSR of blocks per term.

The *logical* 128-posting framing (``blk_term_off``/``blk_pos``/``blk_len``)
plus the block-max metadata ``blk_max_impact f32[NB]`` are built in BOTH
layouts: they are the skip unit of the WAND-style pruned traversal
(kernels/text_probe), which is independent of how doc ids are stored.

Query-time probes binary-search the block heads (``blk_first``) and decode
exactly one block per key (shift/mask + prefix sum) — the compressed words
are the only doc-id bytes the query path touches, so the modeled
``posting_bytes`` (see the property) is what actually streams.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 128  # docs per bitmap block
WORDS_PER_BLOCK = BLOCK // 32
POSTING_BLOCK = 128  # postings per delta/bit-pack compression block


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class TextIndex:
    """HBM-resident inverted index (a pytree of arrays)."""

    postings: jax.Array  # i32[P] docIDs ([0] when compressed — see post_packed)
    impacts: jax.Array  # f32[P] precomputed impact scores
    offsets: jax.Array  # i32[M+1]
    bitmaps: jax.Array  # u32[n_bitmap_terms, n_words]  (may be [0, n_words])
    bitmap_term_ids: jax.Array  # i32[n_bitmap_terms] term id per bitmap row
    # --- delta + bit-packed doc-id store ([0] when uncompressed) ---
    post_packed: jax.Array  # u32[W] packed deltas, word-aligned blocks
    blk_first: jax.Array  # i32[NB] first doc id per block
    blk_bits: jax.Array  # i32[NB] delta bit width per block
    # --- logical 128-posting block addressing (BOTH layouts: blocks never
    # straddle terms, so compressed and uncompressed share one framing) ---
    blk_len: jax.Array  # i32[NB] valid postings per block (≤ POSTING_BLOCK)
    blk_word_off: jax.Array  # i32[NB] start word in post_packed ([0] raw)
    blk_pos: jax.Array  # i32[NB] absolute CSR position of block's 1st posting
    blk_term_off: jax.Array  # i32[M+1] CSR of blocks per term
    # block-max impact metadata (both layouts; see block_max_impacts_np):
    # per-block max of the *stored* impacts, decoded to f32 — computed
    # post-quantization so WAND-style upper bounds stay safe under f16
    blk_max_impact: jax.Array  # f32[NB]
    n_docs: int = field(metadata=dict(static=True))
    n_terms: int = field(metadata=dict(static=True))
    # max blocks owned by any single term (static: sizes the pruned-probe
    # kernel's per-query block lattice)
    max_term_blocks: int = field(default=1, metadata=dict(static=True))

    @property
    def n_postings(self) -> int:
        # impacts stay CSR-addressed in both layouts, so P comes from them
        return self.impacts.shape[0]

    @property
    def is_compressed(self) -> bool:
        return self.blk_first.shape[0] > 0

    @property
    def posting_bytes(self) -> float:
        """Modeled bytes per posting: doc id (+ block metadata) + impact.

        Uncompressed this is the classic ``4 + impact_itemsize`` (= 8 at
        f32); compressed it is the bit-packed words plus the 16 B/block of
        metadata plus the (possibly quantized) impact, amortized per
        posting.  The planner and the per-query ``bytes_postings`` counters
        both read this property, so compressed bytes are what the cost
        model optimizes end to end.
        """
        P = max(self.n_postings, 1)
        imp = self.impacts.dtype.itemsize
        if self.is_compressed:
            packed = 4 * self.post_packed.shape[0] + 16 * self.blk_first.shape[0]
            return packed / P + imp
        return 4.0 + imp


def logical_posting_blocks_np(
    offsets: np.ndarray,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """128-posting block framing of a CSR posting store.

    Returns ``(blk_term_off i32[M+1], blk_pos i32[NB], blk_len i32[NB])``
    with blocks that never straddle a term slice — the exact framing
    :func:`pack_postings_np` uses, so compressed and uncompressed indexes
    address the same logical blocks (the pruned traversal's skip unit).
    An all-empty store yields one degenerate empty block (matching the
    packed layout's sentinel) so block columns are never zero-width.
    """
    M = len(offsets) - 1
    counts = np.diff(offsets.astype(np.int64))
    nb = (counts + POSTING_BLOCK - 1) // POSTING_BLOCK
    blk_term_off = np.zeros((M + 1,), np.int32)
    blk_term_off[1:] = np.cumsum(nb).astype(np.int32)
    NB = int(blk_term_off[-1])
    if NB == 0:
        return blk_term_off, np.zeros((1,), np.int32), np.zeros((1,), np.int32)
    term_of_blk = np.repeat(np.arange(M), nb)
    k = np.arange(NB, dtype=np.int64) - np.repeat(blk_term_off[:-1], nb)
    poss = offsets[term_of_blk].astype(np.int64) + k * POSTING_BLOCK
    lens = np.minimum(counts[term_of_blk] - k * POSTING_BLOCK, POSTING_BLOCK)
    return blk_term_off, poss.astype(np.int32), lens.astype(np.int32)


def block_max_impacts_np(
    impacts: np.ndarray, blk_pos: np.ndarray, blk_len: np.ndarray
) -> np.ndarray:
    """Per-block max of the *stored* impacts, decoded to f32 — f32[NB].

    Computed from the stored (possibly f16-quantized) values so the bound
    stays an upper bound after lossy compression: round-to-nearest can
    round a value *up*, so a max taken pre-quantization would be unsafe.
    Empty blocks get 0.0 (vacuous — no posting ever reads their bound).
    """
    NB = blk_pos.shape[0]
    out = np.zeros((NB,), np.float32)
    P = int(np.sum(blk_len))
    if P > 0:
        # blocks tile the CSR contiguously and in order in both layouts,
        # so posting p belongs to the block repeated at position p
        bid = np.repeat(np.arange(NB), blk_len)
        np.maximum.at(out, bid, np.asarray(impacts[:P]).astype(np.float32))
    return out


def _empty_pack(offsets: np.ndarray) -> dict[str, np.ndarray]:
    """Uncompressed layout: zero-width packed columns + logical blocks."""
    z = np.zeros((0,), np.int32)
    blk_term_off, blk_pos, blk_len = logical_posting_blocks_np(offsets)
    return dict(
        post_packed=np.zeros((0,), np.uint32), blk_first=z, blk_bits=z,
        blk_len=blk_len, blk_word_off=z, blk_pos=blk_pos,
        blk_term_off=blk_term_off,
    )


def pack_postings_np(
    postings: np.ndarray,
    offsets: np.ndarray,
    impacts: np.ndarray | None = None,
) -> dict[str, np.ndarray]:
    """Delta + bit-pack each term's posting slice into 128-posting blocks.

    Blocks never straddle terms; within a block the first element stores
    delta 0 (its doc id lives in ``blk_first``) and subsequent deltas are
    strictly ≥ 1 (postings are sorted unique doc ids within a term).  A
    block stores only ``ceil(len·bits/32)`` words — the tail padding a
    ragged last block would need is not materialized (``blk_word_off`` is
    explicit, so blocks are variable-width), which is what makes short
    posting lists actually compress.  Decoded slots past ``blk_len`` are
    therefore garbage (they read into the next block's words) and every
    consumer masks them before trusting membership.

    When ``impacts`` is given (the *stored*, possibly quantized, values)
    the dict additionally carries ``blk_max_impact`` — the per-block score
    upper bound driving the pruned traversal (see
    :func:`block_max_impacts_np` for why it must be computed
    post-quantization).
    """
    M = len(offsets) - 1
    blk_term_off = np.zeros((M + 1,), np.int32)
    firsts: list[int] = []
    bits_l: list[int] = []
    lens: list[int] = []
    poss: list[int] = []
    word_off: list[int] = []
    chunks: list[np.ndarray] = []
    w = 0
    j64 = np.arange(POSTING_BLOCK, dtype=np.int64)
    for t in range(M):
        lo, hi = int(offsets[t]), int(offsets[t + 1])
        nb = (hi - lo + POSTING_BLOCK - 1) // POSTING_BLOCK
        blk_term_off[t + 1] = blk_term_off[t] + nb
        for b in range(nb):
            s = lo + b * POSTING_BLOCK
            e = min(s + POSTING_BLOCK, hi)
            ids = postings[s:e].astype(np.int64)
            deltas = np.ones((POSTING_BLOCK,), np.int64)
            deltas[0] = 0
            deltas[1:e - s] = np.diff(ids)
            bits = max(int(deltas.max()).bit_length(), 1)
            nw = (POSTING_BLOCK * bits) // 32  # 128·bits/32 = 4·bits exactly
            buf = np.zeros((nw,), np.uint64)
            bitpos = j64 * bits
            wi = bitpos >> 5
            off = (bitpos & 31).astype(np.uint64)
            lo64 = deltas.astype(np.uint64) << off
            np.bitwise_or.at(buf, wi, lo64 & np.uint64(0xFFFFFFFF))
            spill = lo64 >> np.uint64(32)
            # a nonzero spill always lands inside the block (the last delta
            # ends exactly at the block's word boundary), so the clamp only
            # ever redirects zero-valued ORs
            np.bitwise_or.at(buf, np.minimum(wi + 1, nw - 1), spill)
            # store only the words real postings reach: a ragged last block
            # keeps ceil(len·bits/32) words instead of the full 4·bits
            nw_t = max(-(-(e - s) * bits // 32), 1)
            chunks.append(buf[:nw_t].astype(np.uint32))
            firsts.append(int(ids[0]))
            bits_l.append(bits)
            lens.append(e - s)
            poss.append(s)
            word_off.append(w)
            w += nw_t
    if not firsts:  # empty posting store: one degenerate empty block
        chunks.append(np.zeros((4,), np.uint32))
        firsts, bits_l, lens, poss, word_off = [0], [1], [0], [0], [0]
    out = dict(
        post_packed=np.concatenate(chunks),
        blk_first=np.asarray(firsts, np.int32),
        blk_bits=np.asarray(bits_l, np.int32),
        blk_len=np.asarray(lens, np.int32),
        blk_word_off=np.asarray(word_off, np.int32),
        blk_pos=np.asarray(poss, np.int32),
        blk_term_off=blk_term_off,
    )
    if impacts is not None:
        out["blk_max_impact"] = block_max_impacts_np(
            impacts, out["blk_pos"], out["blk_len"]
        )
    return out


def build_text_index_np(
    doc_terms: list[np.ndarray],
    n_terms: int,
    n_bitmap_terms: int = 0,
    idf: np.ndarray | None = None,
    compress: bool = False,
    impact_dtype: np.dtype | str | None = None,
) -> TextIndex:
    """Build from per-doc term-id arrays (with repetitions = frequencies).

    Pure-numpy index construction (host side, analogous to the paper's
    offline index build).  ``idf`` overrides the collection IDF — shard
    builders pass the *corpus-global* IDF (:func:`global_idf_np`) so each
    posting's impact is rounded to f32 exactly once from statistics that
    do not depend on the partitioning, making per-doc scores bit-identical
    across shard layouts (the routing equivalence gate relies on this).

    ``impact_dtype`` lossy-compresses the impact column at build time (the
    one compression entry point — ``normalize_compress`` modes pass f16
    here), so ``blk_max_impact`` is computed from the values that are
    actually stored and the pruning bound survives quantization.
    """
    n_docs = len(doc_terms)
    # term frequencies per doc, collection document frequencies
    doc_ids_per_term: list[list[int]] = [[] for _ in range(n_terms)]
    freq_per_term: list[list[int]] = [[] for _ in range(n_terms)]
    doc_len = np.zeros((n_docs,), dtype=np.float64)
    for d, terms in enumerate(doc_terms):
        doc_len[d] = max(len(terms), 1)
        uniq, counts = np.unique(terms, return_counts=True)
        for w, c in zip(uniq, counts):
            doc_ids_per_term[int(w)].append(d)
            freq_per_term[int(w)].append(int(c))

    df = np.array([len(x) for x in doc_ids_per_term], dtype=np.float64)
    if idf is None:
        idf = np.log(1.0 + n_docs / np.maximum(df, 1.0))

    offsets = np.zeros((n_terms + 1,), dtype=np.int32)
    offsets[1:] = np.cumsum([len(x) for x in doc_ids_per_term])
    P = int(offsets[-1])
    postings = np.zeros((P,), dtype=np.int32)
    impacts = np.zeros((P,), dtype=np.float32)
    for w in range(n_terms):
        lo, hi = offsets[w], offsets[w + 1]
        if hi == lo:
            continue
        ids = np.asarray(doc_ids_per_term[w], dtype=np.int32)
        fr = np.asarray(freq_per_term[w], dtype=np.float64)
        order = np.argsort(ids)
        postings[lo:hi] = ids[order]
        imp = idf[w] * (1.0 + np.log(fr[order])) / np.sqrt(doc_len[ids[order]])
        impacts[lo:hi] = imp.astype(np.float32)

    # block bitmaps for the most frequent terms
    n_blocks = (n_docs + BLOCK - 1) // BLOCK
    n_words = n_blocks * WORDS_PER_BLOCK
    if n_bitmap_terms > 0:
        top_terms = np.argsort(-df)[:n_bitmap_terms].astype(np.int32)
        bitmaps = np.zeros((n_bitmap_terms, n_words), dtype=np.uint32)
        for row, w in enumerate(top_terms):
            lo, hi = offsets[w], offsets[w + 1]
            ids = postings[lo:hi]
            words = ids // 32
            bits = (ids % 32).astype(np.uint32)
            np.bitwise_or.at(bitmaps[row], words, np.uint32(1) << bits)
    else:
        top_terms = np.zeros((0,), dtype=np.int32)
        bitmaps = np.zeros((0, n_words), dtype=np.uint32)

    if impact_dtype is not None:
        impacts = impacts.astype(impact_dtype)
    if compress:
        pack = pack_postings_np(postings, offsets, impacts=impacts)
        postings = np.zeros((0,), np.int32)  # packed words are the store
    else:
        pack = _empty_pack(offsets)
        pack["blk_max_impact"] = block_max_impacts_np(
            impacts, pack["blk_pos"], pack["blk_len"]
        )
    term_blocks = np.diff(pack["blk_term_off"])
    return TextIndex(
        postings=jnp.asarray(postings),
        impacts=jnp.asarray(impacts),
        offsets=jnp.asarray(offsets),
        bitmaps=jnp.asarray(bitmaps),
        bitmap_term_ids=jnp.asarray(top_terms),
        **{k: jnp.asarray(v) for k, v in pack.items()},
        n_docs=n_docs,
        n_terms=n_terms,
        max_term_blocks=int(max(term_blocks.max(initial=0), 1)),
    )


def _with_impacts(index: TextIndex, impacts: jax.Array) -> TextIndex:
    """Replace the impact column and refresh ``blk_max_impact`` to match."""
    bm = block_max_impacts_np(
        np.asarray(impacts), np.asarray(index.blk_pos), np.asarray(index.blk_len)
    )
    return dataclasses.replace(
        index, impacts=impacts, blk_max_impact=jnp.asarray(bm)
    )


def quantize_impacts(index: TextIndex, dtype=jnp.float16) -> TextIndex:
    """Deprecated shim: quantize impacts post-build.

    Prefer ``build_text_index_np(..., impact_dtype=...)`` — the one
    compression entry point (engine builders route every ``compress`` mode
    through it).  Kept for callers holding an already-built index; it
    refreshes ``blk_max_impact`` so pruning bounds stay safe.
    """
    return _with_impacts(index, index.impacts.astype(dtype))


def global_idf_np(doc_terms: list[np.ndarray], n_terms: int) -> np.ndarray:
    """Corpus-wide IDF, matching ``build_text_index_np``'s formula."""
    df = np.zeros((n_terms,), dtype=np.float64)
    for terms in doc_terms:
        np.add.at(df, np.unique(terms), 1.0)
    return np.log(1.0 + len(doc_terms) / np.maximum(df, 1.0))


def rescale_impacts_to_global(index: TextIndex, idf_global: np.ndarray) -> TextIndex:
    """Swap a shard-local index's IDF for the corpus-global one.

    Text impacts are ``idf · (1+log tf) / sqrt(doc_len)``; tf and doc_len
    are per-document, but idf is a *collection* statistic — a shard scoring
    with its local idf would rank differently from the whole corpus.  Real
    distributed engines broadcast global term stats to every shard; we do
    the same by rescaling each posting's impact by ``idf_global/idf_local``.
    """
    offsets = np.asarray(index.offsets)
    counts = np.diff(offsets)
    idf_local = np.log(1.0 + index.n_docs / np.maximum(counts.astype(np.float64), 1.0))
    ratio = np.where(counts > 0, idf_global / idf_local, 1.0)
    impacts = np.asarray(index.impacts) * np.repeat(ratio, counts).astype(np.float32)
    return _with_impacts(index, jnp.asarray(impacts))


# ---------------------------------------------------------------------------
# Query-time primitives (jit-safe)
# ---------------------------------------------------------------------------

def term_slice(index: TextIndex, term: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(start, length) of a term's posting slice."""
    lo = index.offsets[term]
    hi = index.offsets[term + 1]
    return lo, hi - lo


def decode_posting_blocks(index: TextIndex, blocks: jax.Array) -> jax.Array:
    """Decode compressed blocks to doc ids — i32[..., POSTING_BLOCK].

    Pure shift/mask extraction of each block's 128 fixed-width deltas from
    the packed words, then a prefix sum from ``blk_first``.  Slots past
    ``blk_len`` are garbage — blocks are stored tail-trimmed, so those
    reads fall into the next block's words; mask with ``blk_len`` before
    trusting membership.
    """
    bits = index.blk_bits[blocks]  # [...]
    w0 = index.blk_word_off[blocks]
    j = jnp.arange(POSTING_BLOCK, dtype=jnp.int32)
    bitpos = j * bits[..., None]  # [..., 128]
    word = w0[..., None] + (bitpos >> 5)
    off = (bitpos & 31).astype(jnp.uint32)
    W = max(index.post_packed.shape[0], 1)
    lo_w = index.post_packed[jnp.clip(word, 0, W - 1)]
    hi_w = index.post_packed[jnp.clip(word + 1, 0, W - 1)]
    # two-word extraction; the hi shift amount stays < 32 via the mask and
    # the off == 0 case (where 32 - off would be 32) selects 0 anyway
    hi_part = jnp.where(
        off > 0, hi_w << ((jnp.uint32(32) - off) & jnp.uint32(31)), jnp.uint32(0)
    )
    mask = (jnp.uint32(1) << bits[..., None].astype(jnp.uint32)) - 1  # bits ≤ 31
    delta = (((lo_w >> off) | hi_part) & mask).astype(jnp.int32)
    delta = jnp.where(j == 0, 0, delta)
    return index.blk_first[blocks][..., None] + jnp.cumsum(delta, axis=-1)


def _probe_term_packed(
    index: TextIndex, term: jax.Array, doc_ids: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Compressed-layout probe: block-head binary search + one-block decode."""
    b0 = index.blk_term_off[term]
    nb = index.blk_term_off[term + 1] - b0
    NB = index.blk_first.shape[0]
    # containing block = last block whose first doc id is ≤ the key
    pos = _searchsorted_slice(index.blk_first, b0, nb, doc_ids)
    exact = (pos < b0 + nb) & (
        index.blk_first[jnp.clip(pos, 0, NB - 1)] == doc_ids
    )
    blk = jnp.where(exact, pos, pos - 1)
    in_range = (blk >= b0) & (blk < b0 + nb) & (nb > 0)
    blk_s = jnp.clip(blk, 0, NB - 1)
    decoded = decode_posting_blocks(index, blk_s)  # [..., 128]
    j = jnp.arange(POSTING_BLOCK, dtype=jnp.int32)
    hit = (decoded == doc_ids[..., None]) & (j < index.blk_len[blk_s][..., None])
    member = in_range & hit.any(axis=-1)
    jpos = jnp.argmax(hit, axis=-1).astype(jnp.int32)
    apos = jnp.clip(index.blk_pos[blk_s] + jpos, 0, index.n_postings - 1)
    impact = jnp.where(member, index.impacts[apos].astype(jnp.float32), 0.0)
    return member, impact


def probe_term(
    index: TextIndex, term: jax.Array, doc_ids: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Membership + impact of ``doc_ids`` in one term's posting list.

    Vectorized binary search over the whole posting array restricted to the
    term slice.  Returns (member bool[...], impact f32[...]).
    """
    if index.is_compressed:
        return _probe_term_packed(index, term, doc_ids)
    lo, n = term_slice(index, term)
    # searchsorted over the full array with translated bounds: postings within
    # a slice are sorted, and slices are disjoint, so search the slice via
    # index arithmetic on a gathered window — instead do searchsorted on the
    # full array bounded to [lo, lo+n) by clamping.
    pos = _searchsorted_slice(index.postings, lo, n, doc_ids)
    found_id = index.postings[jnp.clip(pos, 0, index.n_postings - 1)]
    member = (pos < lo + n) & (found_id == doc_ids) & (n > 0)
    safe_pos = jnp.clip(pos, 0, index.n_postings - 1)
    impact = jnp.where(member, index.impacts[safe_pos].astype(jnp.float32), 0.0)
    return member, impact


def _searchsorted_slice(
    arr: jax.Array, lo: jax.Array, n: jax.Array, keys: jax.Array
) -> jax.Array:
    """Branchless binary search of ``keys`` in ``arr[lo:lo+n)`` (left).

    Works for traced (dynamic) lo/n: a fixed ``ceil(log2(P))+1``-step bisection.
    Returns absolute positions in [lo, lo+n].
    """
    P = arr.shape[0]
    steps = max(int(np.ceil(np.log2(max(P, 2)))) + 1, 1)
    lo_ = jnp.broadcast_to(lo, keys.shape).astype(jnp.int32)
    hi_ = jnp.broadcast_to(lo + n, keys.shape).astype(jnp.int32)

    def body(_, lh):
        l, h = lh
        active = l < h
        # overflow-safe midpoint: l + h wraps int32 once the posting store
        # passes 2^30 entries (production-scale shards); l + (h-l)//2 is
        # value-identical for 0 <= l <= h and never overflows
        mid = l + (h - l) // 2
        v = arr[jnp.clip(mid, 0, P - 1)]
        go_right = v < keys
        l = jnp.where(active & go_right, mid + 1, l)
        h = jnp.where(active & ~go_right, mid, h)
        return l, h

    l, _ = jax.lax.fori_loop(0, steps, body, (lo_, hi_))
    return l


def conjunction_candidates(
    index: TextIndex,
    terms: jax.Array,  # i32[d] (padded with -1)
    max_candidates: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """TEXT-FIRST driver: intersect posting lists of ``terms``.

    Uses the *first valid* term's posting list as the driver (capped at
    ``max_candidates`` postings, an early-termination budget) and probes the
    remaining terms by binary search.  Returns

      cand_ids  i32[max_candidates]   (docIDs, ascending among valid)
      valid     bool[max_candidates]
      text_score f32[max_candidates]  (sum of impacts over query terms)
    """
    d = terms.shape[0]
    # Classic optimization: drive the intersection with the *shortest* list.
    safe_terms = jnp.maximum(terms, 0)
    lens = index.offsets[safe_terms + 1] - index.offsets[safe_terms]
    lens = jnp.where(terms >= 0, lens, jnp.int32(2**31 - 1))
    driver = jnp.argmin(lens).astype(jnp.int32)
    t0 = safe_terms[driver]
    any_real = terms[0] >= 0  # terms are packed left; term 0 real iff query nonempty

    lo, n = term_slice(index, t0)
    n = jnp.minimum(n, max_candidates)
    idx = jnp.arange(max_candidates, dtype=jnp.int32)
    valid = (idx < n) & any_real
    if index.is_compressed:
        # stream the driver's blocks: decode ceil(mc/128) consecutive blocks
        # once and flatten, instead of per-element block decodes
        NB = index.blk_first.shape[0]
        nbd = (max_candidates + POSTING_BLOCK - 1) // POSTING_BLOCK
        blocks = jnp.clip(
            index.blk_term_off[t0] + jnp.arange(nbd, dtype=jnp.int32), 0, NB - 1
        )
        cand = decode_posting_blocks(index, blocks).reshape(-1)[:max_candidates]
        apos = jnp.clip(
            index.blk_pos[blocks][:, None]
            + jnp.arange(POSTING_BLOCK, dtype=jnp.int32)[None, :],
            0,
            index.n_postings - 1,
        ).reshape(-1)[:max_candidates]
        imp = index.impacts[apos].astype(jnp.float32)
    else:
        pos = lo + idx
        cand = index.postings[jnp.clip(pos, 0, index.n_postings - 1)]
        imp = index.impacts[jnp.clip(pos, 0, index.n_postings - 1)].astype(
            jnp.float32
        )
    cand = jnp.where(valid, cand, jnp.int32(2**31 - 1))
    score = jnp.where(valid, imp, 0.0)

    def probe_one(i, carry):
        valid, score = carry
        t = terms[i]
        is_real = (t >= 0) & (i != driver)
        member, imp = probe_term(index, jnp.maximum(t, 0), cand)
        valid = valid & (member | ~is_real)
        score = score + jnp.where(is_real, imp, 0.0)
        return valid, score

    valid, score = jax.lax.fori_loop(0, d, probe_one, (valid, score))
    cand = jnp.where(valid, cand, jnp.int32(2**31 - 1))
    score = jnp.where(valid, score, 0.0)
    return cand, valid, score


def text_score_of_docs(
    index: TextIndex,
    terms: jax.Array,  # i32[d] padded with -1
    doc_ids: jax.Array,  # i32[C]
) -> tuple[jax.Array, jax.Array]:
    """AND-semantics text score for arbitrary candidate docs.

    Returns (match bool[C], score f32[C]); ``match`` requires every valid
    query term to occur in the doc.
    """
    d = terms.shape[0]

    def probe_one(i, carry):
        match, score = carry
        t = terms[i]
        is_real = t >= 0
        member, imp = probe_term(index, jnp.maximum(t, 0), doc_ids)
        match = match & (member | ~is_real)
        score = score + jnp.where(is_real, imp, 0.0)
        return match, score

    match0 = jnp.ones(doc_ids.shape, dtype=bool)
    score0 = jnp.zeros(doc_ids.shape, dtype=jnp.float32)
    match, score = jax.lax.fori_loop(0, d, probe_one, (match0, score0))
    return match, score


def text_score_of_docs_counted(
    index: TextIndex,
    terms: jax.Array,  # i32[d] padded with -1
    doc_ids: jax.Array,  # i32[C]
    valid: jax.Array,  # bool[C] — candidates that are live before term 0
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``text_score_of_docs`` plus an honest probe counter.

    Same match/score math (bit-identical outputs), but additionally counts
    the probes a term-at-a-time short-circuiting evaluator would issue:
    before each term only the candidates still matching every earlier term
    are probed, so the count shrinks as terms eliminate candidates.
    Returns (match bool[C], score f32[C], probes i32 scalar).
    """
    d = terms.shape[0]

    def probe_one(i, carry):
        match, score, probes = carry
        t = terms[i]
        is_real = t >= 0
        live = match & valid
        probes = probes + jnp.where(
            is_real, jnp.sum(live.astype(jnp.int32)), 0
        )
        member, imp = probe_term(index, jnp.maximum(t, 0), doc_ids)
        match = match & (member | ~is_real)
        score = score + jnp.where(is_real, imp, 0.0)
        return match, score, probes

    match0 = jnp.ones(doc_ids.shape, dtype=bool)
    score0 = jnp.zeros(doc_ids.shape, dtype=jnp.float32)
    match, score, probes = jax.lax.fori_loop(
        0, d, probe_one, (match0, score0, jnp.int32(0))
    )
    return match, score, probes

"""Distributed geo-search serving: doc-sharded index × query-sharded batch.

Topology (DESIGN.md §5): documents are partitioned into ``S`` index shards
laid out over the mesh's doc axes (``('pod','data')`` in production); the
query batch is sharded over the ``'model'`` axis (replica/throughput axis).
One ``shard_map`` serve step:

1. every device runs the full K-SWEEP pipeline against its local index shard
   for its local query slice;
2. local top-k per (query, shard);
3. hierarchical merge: ``all_gather`` along ``'data'`` (intra-pod ICI) +
   re-top-k, then along ``'pod'`` (inter-pod DCI) + final top-k.

Collective volume per query is O(k · n_doc_shards) — independent of corpus
size, the property that makes the architecture scale to thousands of chips.

The ``Partitioner`` protocol (paper §Conclusions future work)
-------------------------------------------------------------
Document partitioning is a first-class strategy object, not a string flag.
A partitioner implements:

* ``name`` — stable identifier (CLI / report label);
* ``assign(doc_rects, n_shards) -> i32[N]`` — shard id per document, given
  the doc footprint rects ``f32[N, R, 4]`` (padded slots: inverted rects);
* ``coverage(rects, amps) -> bool[G, G]`` — the bbox-grid summary of one
  shard's toe prints (shared base implementation; see below).

Shipped strategies:

* :class:`HashPartitioner`   — round-robin ``doc_id % n_shards`` (the
  standard engine layout; every shard sees every region);
* :class:`MortonPartitioner` — docs sorted by the Morton code of their
  footprint center, split into equal contiguous ranges: each shard owns a
  compact curve segment, its tile grid is denser and sweeps are tighter;
* :class:`RegionRangePartitioner` — recursive median (KD) splits of the
  footprint centers: each shard owns an axis-aligned region, the tightest
  per-shard MBRs of the three (the footprint-routing partitioner).

Strings are resolved exactly once, at the CLI boundary, via
:func:`resolve_partitioner`; every core/serving call site takes an
instance (passing a raw string raises ``TypeError``).

Coverage grids and footprint routing
------------------------------------
Each shard's spatial extent is summarized as a ``G×G`` boolean bbox grid
(``G = COVERAGE_GRID``) over its toe-print rects — the same clamped-floor
cell mapping (:func:`repro.core.planner.coarse_cells`, no upper-edge
epsilon) the planner's ``tp_span`` grid uses, so the summary *over-covers*:
any toe print ∩ query-rect intersection shares at least one cell with the
query's cell range.  The grid is stored as its summed-area table
(``coverage_sat f32[G+1, G+1]``, integral image of the 0/1 grid), making
"does this rect touch any covered cell" an O(1) four-corner lookup both
host-side (:func:`footprint_touch_np`) and inside the jit'd serve step.

Because ranking requires footprint overlap (``combine_scores`` scores a
doc −inf when its geo score is 0 — see :mod:`repro.core.ranking`), a shard
whose coverage grid misses every query footprint in a batch can only
produce empty local top-k lists.  Executors exploit this: the host
scatter-gather loop skips such shards outright, and the mesh serve step
(``make_serve_fn(with_routing=True)``) masks them so their counters and
score contributions are zero by construction — bit-identical results at
O(shards-touched) instead of O(S) per-query cost.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import algorithms as alg
from repro.core import ranking
from repro.core.engine import GeoIndex
from repro.core.spatial_index import SpatialIndex, build_spatial_index_np
from repro.core.text_index import (
    TextIndex,
    build_text_index_np,
    global_idf_np as tidx_global_idf,
)
from repro.core import geometry
from repro.core.planner import coarse_cells

#: Side length of the per-shard coverage bbox grid.  Matches the planner's
#: ``tp_span`` grid resolution (``planner._SPAN_GRID``): fine enough that
#: city-sized footprints resolve to a few cells, coarse enough that the
#: [S, G+1, G+1] SAT stack stays negligible next to the index arrays.
COVERAGE_GRID = 16


def _valid_rects_np(rects: np.ndarray, amps: np.ndarray | None = None) -> np.ndarray:
    """bool[...] mask of real (non-padding) rect slots: positive area and,
    when amplitudes are given, positive amplitude."""
    rects = np.asarray(rects)
    v = (rects[..., 2] > rects[..., 0]) & (rects[..., 3] > rects[..., 1])
    if amps is not None:
        v = v & (np.asarray(amps) > 0)
    return v


def coverage_grid_np(
    rects: np.ndarray, amps: np.ndarray | None = None, grid: int = COVERAGE_GRID
) -> np.ndarray:
    """Occupancy grid ``bool[G, G]`` (row = y cell) of the valid rects.

    Cells are claimed through :func:`repro.core.planner.coarse_cells` — the
    shared clamped-floor mapping with no upper-edge epsilon — so the grid
    over-covers: every point of every valid rect lands in a claimed cell.
    """
    occ = np.zeros((grid, grid), dtype=bool)
    r = np.asarray(rects).reshape(-1, 4)
    valid = _valid_rects_np(rects, amps).reshape(-1)
    r = r[valid]
    if r.shape[0] == 0:
        return occ
    ix0, iy0, ix1, iy1 = coarse_cells(r, grid)
    for x0, y0, x1, y1 in zip(ix0, iy0, ix1, iy1):
        occ[y0 : y1 + 1, x0 : x1 + 1] = True
    return occ


def coverage_sat_np(occ: np.ndarray) -> np.ndarray:
    """Summed-area table ``f32[G+1, G+1]`` of a 0/1 occupancy grid."""
    g = occ.shape[0]
    sat = np.zeros((g + 1, g + 1), dtype=np.float32)
    sat[1:, 1:] = np.cumsum(np.cumsum(occ.astype(np.float32), axis=0), axis=1)
    return sat


def footprint_touch_np(
    sats: np.ndarray,
    rects: np.ndarray,
    amps: np.ndarray | None = None,
    grid: int = COVERAGE_GRID,
) -> np.ndarray:
    """Which shards each query's footprints can reach: ``bool[S, B]``.

    ``sats`` is the stacked coverage SAT ``f32[S, G+1, G+1]``; ``rects`` the
    query footprints ``f32[B, R, 4]`` (``amps f32[B, R]`` marks padding).
    A query touches a shard iff any valid rect's coarse-cell range contains
    a covered cell — an O(1) four-corner SAT lookup per (shard, rect).
    Queries with no valid rect touch nothing (scored −inf everywhere by
    ``require_geo`` ranking regardless of routing).
    """
    sats = np.asarray(sats)
    rects = np.asarray(rects)
    valid = _valid_rects_np(rects, amps)  # [B, R]
    ix0, iy0, ix1, iy1 = coarse_cells(rects, grid)  # each [B, R]
    cover = (
        sats[:, iy1 + 1, ix1 + 1]
        - sats[:, iy0, ix1 + 1]
        - sats[:, iy1 + 1, ix0]
        + sats[:, iy0, ix0]
    )  # [S, B, R]
    return np.any((cover > 0) & valid[None], axis=-1)


def _footprint_centers(doc_rects: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Mean footprint center per doc, over valid rect slots (f64[N], f64[N])."""
    r = np.asarray(doc_rects, dtype=np.float64)
    valid = _valid_rects_np(r)  # [N, R]
    w = np.maximum(valid.sum(axis=1), 1)
    cx = np.where(valid, (r[:, :, 0] + r[:, :, 2]) * 0.5, 0.0).sum(axis=1) / w
    cy = np.where(valid, (r[:, :, 1] + r[:, :, 3]) * 0.5, 0.0).sum(axis=1) / w
    return cx, cy


class Partitioner:
    """Document-partitioning strategy (see module docstring).

    Stateless: ``assign`` maps doc footprints to shard ids; ``coverage``
    summarizes one shard's toe prints as the routing occupancy grid (the
    base implementation is shared — strategies only differ in ``assign``).
    """

    name: str = "base"

    def assign(self, doc_rects: np.ndarray, n_shards: int) -> np.ndarray:
        raise NotImplementedError

    def coverage(
        self,
        rects: np.ndarray,
        amps: np.ndarray | None = None,
        grid: int = COVERAGE_GRID,
    ) -> np.ndarray:
        return coverage_grid_np(rects, amps, grid)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"{type(self).__name__}()"


class HashPartitioner(Partitioner):
    """Round-robin ``doc_id % n_shards`` — the geography-blind baseline."""

    name = "hash"

    def assign(self, doc_rects: np.ndarray, n_shards: int) -> np.ndarray:
        n_docs = np.asarray(doc_rects).shape[0]
        return (np.arange(n_docs) % n_shards).astype(np.int32)


class MortonPartitioner(Partitioner):
    """Equal contiguous ranges of the Morton order of footprint centers."""

    name = "morton"

    def assign(self, doc_rects: np.ndarray, n_shards: int) -> np.ndarray:
        n_docs = np.asarray(doc_rects).shape[0]
        cx, cy = _footprint_centers(doc_rects)
        fine = 1 << 15
        code = geometry.morton_encode_np(
            np.clip(cx * fine, 0, fine - 1).astype(np.uint32),
            np.clip(cy * fine, 0, fine - 1).astype(np.uint32),
        )
        order = np.argsort(code, kind="stable")
        per = (n_docs + n_shards - 1) // n_shards
        ids = np.empty(n_docs, dtype=np.int32)
        ids[order] = np.arange(n_docs) // per
        return ids


class RegionRangePartitioner(Partitioner):
    """Recursive median (KD) splits of footprint centers: each shard owns a
    compact axis-aligned region, so coverage grids are the tightest of the
    shipped strategies.  Handles any ``n_shards`` via proportional child
    targets (shard sizes differ by at most one doc)."""

    name = "region"

    def assign(self, doc_rects: np.ndarray, n_shards: int) -> np.ndarray:
        n_docs = np.asarray(doc_rects).shape[0]
        cx, cy = _footprint_centers(doc_rects)
        ids = np.zeros(n_docs, dtype=np.int32)
        next_id = [0]

        def split(sel: np.ndarray, parts: int, depth: int) -> None:
            if parts <= 1:
                ids[sel] = next_id[0]
                next_id[0] += 1
                return
            left = parts // 2
            axis = cx if depth % 2 == 0 else cy
            order = sel[np.argsort(axis[sel], kind="stable")]
            cut = (len(sel) * left + parts - 1) // parts
            split(order[:cut], left, depth + 1)
            split(order[cut:], parts - left, depth + 1)

        split(np.arange(n_docs), n_shards, 0)
        return ids


_PARTITIONERS = {
    "hash": HashPartitioner,
    "morton": MortonPartitioner,
    "region": RegionRangePartitioner,
    # legacy CLI spelling from the string-flag era: Morton order
    "geo": MortonPartitioner,
}


def resolve_partitioner(spec: "str | Partitioner | None") -> Partitioner:
    """CLI-boundary resolution: str → instance (once); instances pass through.

    ``None`` resolves to :class:`MortonPartitioner` (the serving default).
    Everywhere else in core/serving, raw strings are a ``TypeError``.
    """
    if spec is None:
        return MortonPartitioner()
    if isinstance(spec, Partitioner):
        return spec
    if isinstance(spec, str):
        try:
            return _PARTITIONERS[spec]()
        except KeyError:
            raise ValueError(
                f"unknown partitioner {spec!r}; choose from {sorted(_PARTITIONERS)}"
            ) from None
    raise TypeError(f"expected Partitioner instance or name, got {type(spec).__name__}")


def _require_partitioner(
    partitioner: "Partitioner | None", default: type[Partitioner]
) -> Partitioner:
    """Core-API guard: instances only (strings stop at the CLI boundary)."""
    if partitioner is None:
        return default()
    if isinstance(partitioner, Partitioner):
        return partitioner
    raise TypeError(
        "partitioner must be a Partitioner instance (e.g. MortonPartitioner()); "
        "raw strings are only accepted at the CLI boundary via "
        f"resolve_partitioner() — got {partitioner!r}"
    )


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class ShardedGeoIndex:
    """Stacked per-shard index arrays; leading dim = doc shard."""

    # text index
    postings: jax.Array  # i32[S, P]
    impacts: jax.Array  # f32[S, P]
    offsets: jax.Array  # i32[S, M+1]
    # text index: delta + bit-packed doc-id store ([S, 0] when uncompressed)
    post_packed: jax.Array  # u32[S, W]
    blk_first: jax.Array  # i32[S, NBp]
    blk_bits: jax.Array  # i32[S, NBp]
    blk_word_off: jax.Array  # i32[S, NBp]
    blk_n_exc: jax.Array  # i32[S, NBp] PForDelta exception words per block
    # logical 128-posting block framing (both layouts; see text_index.py)
    blk_len: jax.Array  # i32[S, NBt]
    blk_pos: jax.Array  # i32[S, NBt]
    blk_max_impact: jax.Array  # f32[S, NBt] post-quantization block maxima
    blk_term_off: jax.Array  # i32[S, M+1]
    # impact-ordered segment CSR (degenerate under layout="docid")
    seg_term_off: jax.Array  # i32[S, M+1]
    seg_pos: jax.Array  # i32[S, NSp]
    seg_len: jax.Array  # i32[S, NSp]
    # spatial index (stored dtypes: f16/int8/i16 under compressed modes)
    tp_rects: jax.Array  # f32[S, T, 4]
    tp_amps: jax.Array  # f32[S, T]
    tp_doc_ids: jax.Array  # i32[S, T]
    tp_amp_scale: jax.Array  # f32[S, ceil(T/SCALE_BLOCK)] ([S, 0] unless int8)
    tile_starts: jax.Array  # i32[S, G*G, m]
    tile_ends: jax.Array  # i32[S, G*G, m]
    doc_rects: jax.Array  # f32[S, N, R, 4]
    doc_amps: jax.Array  # f32[S, N, R]
    doc_mbr: jax.Array  # f32[S, N, 4]
    doc_mass: jax.Array  # f32[S, N]
    # block-max metadata columns (pruned K-SWEEP; see core/spatial_index.py)
    blk_mbr: jax.Array  # f32[S, NB, 4]
    blk_max_amp: jax.Array  # f32[S, NB]
    blk_max_mass: jax.Array  # f32[S, NB]
    pagerank: jax.Array  # f32[S, N]
    doc_offset: jax.Array  # i32[S]  local→global docID base
    # routing: per-shard coverage-grid summed-area table (module docstring)
    coverage_sat: jax.Array  # f32[S, CG+1, CG+1]
    grid: int = field(metadata=dict(static=True))
    n_terms: int = field(metadata=dict(static=True))
    block_size: int = field(default=128, metadata=dict(static=True))
    coverage_grid: int = field(default=COVERAGE_GRID, metadata=dict(static=True))
    # max posting blocks of any term on any shard (pruned-text window bound)
    max_term_blocks: int = field(default=1, metadata=dict(static=True))
    # posting order of every shard's text index ("docid" | "impact")
    layout: str = field(default="docid", metadata=dict(static=True))
    # max impact segments of any term on any shard (segmented probe bound)
    max_term_segments: int = field(default=1, metadata=dict(static=True))

    @property
    def n_shards(self) -> int:
        return self.postings.shape[0]


def shard_corpus_np(
    doc_terms: list[np.ndarray],
    doc_rects: np.ndarray,
    doc_amps: np.ndarray,
    pagerank: np.ndarray,
    n_terms: int,
    n_shards: int,
    partitioner: "Partitioner | None" = None,
    grid: int = 64,
    m_intervals: int = 2,
    block_size: int = 128,
    compress: "bool | str" = False,
    layout: str = "docid",
) -> ShardedGeoIndex:
    """Partition a corpus with ``partitioner`` (default hash round-robin)
    and build one index per shard (host side), including each shard's
    coverage SAT for footprint routing.  ``compress`` takes the same
    ``{none, f16, int8}`` modes as the single-index builders: every shard
    stores bit-packed postings and quantized toe prints.  ``layout``
    selects every shard's posting order (``"docid"`` | ``"impact"``; see
    :mod:`repro.core.text_index`)."""
    from repro.core.spatial_index import SCALE_BLOCK, normalize_compress

    mode = normalize_compress(compress)
    n_docs = len(doc_terms)
    partitioner = _require_partitioner(partitioner, default=HashPartitioner)
    shard_ids = np.asarray(partitioner.assign(doc_rects, n_shards))
    if shard_ids.shape != (n_docs,):
        raise ValueError(
            f"{partitioner.name}.assign returned shape {shard_ids.shape}, "
            f"expected ({n_docs},)"
        )

    idf_global = tidx_global_idf(doc_terms, n_terms)
    shards = []
    coverage = []
    for s in range(n_shards):
        # ascending global ids within the shard: local tie-breaks (lower
        # local docID wins) then agree with the single-index engine's
        sel = np.flatnonzero(shard_ids == s)
        terms = [doc_terms[i] for i in sel]
        # broadcast global term statistics (IDF) so shards rank like the
        # single-index engine would — built in directly (not rescaled after
        # the fact) so impacts are bit-identical across partitionings
        text = build_text_index_np(
            terms, n_terms, idf=idf_global, compress=(mode != "none"),
            layout=layout,
        )
        spatial = build_spatial_index_np(
            doc_rects[sel], doc_amps[sel], grid, m_intervals,
            block_size=block_size, compress=mode,
        )
        shards.append((text, spatial, pagerank[sel], sel))
        # routing coverage wants decoded f32 amps (int8 stores are scaled)
        cov_amps = np.asarray(spatial.tp_amps).astype(np.float32)
        if spatial.tp_amp_scale.shape[0]:
            sc = np.asarray(spatial.tp_amp_scale)
            cov_amps = cov_amps * np.repeat(sc, SCALE_BLOCK)[: cov_amps.shape[0]]
        occ = partitioner.coverage(
            np.asarray(spatial.tp_rects).astype(np.float32), cov_amps, COVERAGE_GRID
        )
        coverage.append(coverage_sat_np(occ))

    # pad to uniform shapes and stack
    P_max = max(s[0].impacts.shape[0] for s in shards)
    Pp_max = max(s[0].postings.shape[0] for s in shards)  # 0 when compressed
    W_max = max(s[0].post_packed.shape[0] for s in shards)
    NBp_max = max(s[0].blk_first.shape[0] for s in shards)  # 0 uncompressed
    NBt_max = max(s[0].blk_len.shape[0] for s in shards)  # logical framing
    NS_max = max(s[0].seg_pos.shape[0] for s in shards)  # impact segments
    T_max = max(s[1].tp_rects.shape[0] for s in shards)
    SB_max = max(s[1].tp_amp_scale.shape[0] for s in shards)
    N_max = max(len(s[3]) for s in shards)
    R = doc_rects.shape[1]

    def padded(a, n, fill):
        a = np.asarray(a)
        out = np.full((n,) + a.shape[1:], fill, dtype=a.dtype)
        out[: a.shape[0]] = a
        return out

    stacked = {}
    stacked["postings"] = np.stack(
        [padded(s[0].postings, Pp_max, 2**31 - 1) for s in shards]
    )
    stacked["impacts"] = np.stack([padded(s[0].impacts, P_max, 0.0) for s in shards])
    stacked["offsets"] = np.stack([np.asarray(s[0].offsets) for s in shards])
    # packed posting columns (all width-0 when uncompressed); padded blocks
    # are unreachable (every probe is bounded by its term's blk_term_off
    # slice) — bits pad 1 so even an accidental decode stays well-defined
    stacked["post_packed"] = np.stack(
        [padded(s[0].post_packed, W_max, 0) for s in shards]
    )
    stacked["blk_first"] = np.stack([padded(s[0].blk_first, NBp_max, 0) for s in shards])
    stacked["blk_bits"] = np.stack([padded(s[0].blk_bits, NBp_max, 1) for s in shards])
    stacked["blk_word_off"] = np.stack(
        [padded(s[0].blk_word_off, NBp_max, 0) for s in shards]
    )
    stacked["blk_n_exc"] = np.stack(
        [padded(s[0].blk_n_exc, NBp_max, 0) for s in shards]
    )
    # logical framing columns exist in both layouts; padded blocks are
    # empty (len 0) with a zero impact bound, so they can never be probed
    # or beat a pruning threshold
    stacked["blk_len"] = np.stack([padded(s[0].blk_len, NBt_max, 0) for s in shards])
    stacked["blk_pos"] = np.stack([padded(s[0].blk_pos, NBt_max, 0) for s in shards])
    stacked["blk_max_impact"] = np.stack(
        [padded(s[0].blk_max_impact, NBt_max, 0.0) for s in shards]
    )
    stacked["blk_term_off"] = np.stack(
        [np.asarray(s[0].blk_term_off) for s in shards]
    )
    # impact-segment CSR: padded segments are empty (len 0) and every probe
    # is bounded by its term's seg_term_off slice, so padding is unreachable
    stacked["seg_term_off"] = np.stack(
        [np.asarray(s[0].seg_term_off) for s in shards]
    )
    stacked["seg_pos"] = np.stack([padded(s[0].seg_pos, NS_max, 0) for s in shards])
    stacked["seg_len"] = np.stack([padded(s[0].seg_len, NS_max, 0) for s in shards])
    stacked["tp_rects"] = np.stack(
        [
            padded(s[1].tp_rects, T_max, 0.0) for s in shards
        ]
    )
    # make padded toe prints empty rects
    for i, s in enumerate(shards):
        t = s[1].tp_rects.shape[0]
        stacked["tp_rects"][i, t:] = geometry.EMPTY_RECT
    stacked["tp_amps"] = np.stack([padded(s[1].tp_amps, T_max, 0.0) for s in shards])
    stacked["tp_doc_ids"] = np.stack(
        [padded(s[1].tp_doc_ids, T_max, 0) for s in shards]
    )
    # int8 amp scales: pad with 1.0 (decode of zero-padded amps stays 0)
    stacked["tp_amp_scale"] = np.stack(
        [padded(s[1].tp_amp_scale, SB_max, 1.0) for s in shards]
    )
    stacked["tile_starts"] = np.stack([np.asarray(s[1].tile_starts) for s in shards])
    stacked["tile_ends"] = np.stack([np.asarray(s[1].tile_ends) for s in shards])
    stacked["doc_rects"] = np.stack(
        [padded(s[1].doc_rects, N_max, 0.0) for s in shards]
    )
    for i, s in enumerate(shards):
        n = s[1].doc_rects.shape[0]
        stacked["doc_rects"][i, n:] = geometry.EMPTY_RECT
    stacked["doc_amps"] = np.stack([padded(s[1].doc_amps, N_max, 0.0) for s in shards])
    stacked["doc_mbr"] = np.stack([padded(s[1].doc_mbr, N_max, 0.0) for s in shards])
    stacked["doc_mass"] = np.stack([padded(s[1].doc_mass, N_max, 0.0) for s in shards])
    # block-max columns: zero-padded blocks have ub == 0 → always skipped
    NB_max = max(s[1].blk_mbr.shape[0] for s in shards)
    stacked["blk_mbr"] = np.stack([padded(s[1].blk_mbr, NB_max, 0.0) for s in shards])
    stacked["blk_max_amp"] = np.stack(
        [padded(s[1].blk_max_amp, NB_max, 0.0) for s in shards]
    )
    stacked["blk_max_mass"] = np.stack(
        [padded(s[1].blk_max_mass, NB_max, 0.0) for s in shards]
    )
    stacked["pagerank"] = np.stack([padded(s[2], N_max, 0.0) for s in shards])
    # local→global docID translation table
    gid = np.stack([padded(s[3].astype(np.int32), N_max, -1) for s in shards])
    stacked["doc_offset"] = gid  # [S, N] full map (name kept for pytree stability)

    return ShardedGeoIndex(
        postings=jnp.asarray(stacked["postings"]),
        impacts=jnp.asarray(stacked["impacts"]),
        offsets=jnp.asarray(stacked["offsets"]),
        post_packed=jnp.asarray(stacked["post_packed"]),
        blk_first=jnp.asarray(stacked["blk_first"]),
        blk_bits=jnp.asarray(stacked["blk_bits"]),
        blk_word_off=jnp.asarray(stacked["blk_word_off"]),
        blk_n_exc=jnp.asarray(stacked["blk_n_exc"]),
        blk_len=jnp.asarray(stacked["blk_len"]),
        blk_pos=jnp.asarray(stacked["blk_pos"]),
        blk_max_impact=jnp.asarray(stacked["blk_max_impact"]),
        blk_term_off=jnp.asarray(stacked["blk_term_off"]),
        seg_term_off=jnp.asarray(stacked["seg_term_off"]),
        seg_pos=jnp.asarray(stacked["seg_pos"]),
        seg_len=jnp.asarray(stacked["seg_len"]),
        tp_rects=jnp.asarray(stacked["tp_rects"]),
        tp_amps=jnp.asarray(stacked["tp_amps"]),
        tp_doc_ids=jnp.asarray(stacked["tp_doc_ids"]),
        tp_amp_scale=jnp.asarray(stacked["tp_amp_scale"]),
        tile_starts=jnp.asarray(stacked["tile_starts"]),
        tile_ends=jnp.asarray(stacked["tile_ends"]),
        doc_rects=jnp.asarray(stacked["doc_rects"]),
        doc_amps=jnp.asarray(stacked["doc_amps"]),
        doc_mbr=jnp.asarray(stacked["doc_mbr"]),
        doc_mass=jnp.asarray(stacked["doc_mass"]),
        blk_mbr=jnp.asarray(stacked["blk_mbr"]),
        blk_max_amp=jnp.asarray(stacked["blk_max_amp"]),
        blk_max_mass=jnp.asarray(stacked["blk_max_mass"]),
        pagerank=jnp.asarray(stacked["pagerank"]),
        doc_offset=jnp.asarray(gid),
        coverage_sat=jnp.asarray(np.stack(coverage)),
        grid=grid,
        n_terms=n_terms,
        block_size=shards[0][1].block_size,
        coverage_grid=COVERAGE_GRID,
        max_term_blocks=max(s[0].max_term_blocks for s in shards),
        layout=layout,
        max_term_segments=max(s[0].max_term_segments for s in shards),
    )


def sharded_index_specs(
    doc_axes: tuple[str, ...],
    grid: int,
    n_terms: int,
    block_size: int = 128,
    coverage_grid: int = COVERAGE_GRID,
    max_term_blocks: int = 1,
    layout: str = "docid",
    max_term_segments: int = 1,
) -> ShardedGeoIndex:
    """PartitionSpecs for every field (leading dim over the doc axes)."""
    lead = P(doc_axes)
    return ShardedGeoIndex(
        postings=lead, impacts=lead, offsets=lead,
        post_packed=lead, blk_first=lead, blk_bits=lead, blk_len=lead,
        blk_word_off=lead, blk_n_exc=lead, blk_pos=lead, blk_max_impact=lead,
        blk_term_off=lead, seg_term_off=lead, seg_pos=lead, seg_len=lead,
        tp_rects=lead, tp_amps=lead, tp_doc_ids=lead, tp_amp_scale=lead,
        tile_starts=lead, tile_ends=lead,
        doc_rects=lead, doc_amps=lead, doc_mbr=lead, doc_mass=lead,
        blk_mbr=lead, blk_max_amp=lead, blk_max_mass=lead,
        pagerank=lead, doc_offset=lead, coverage_sat=lead,
        grid=grid, n_terms=n_terms, block_size=block_size,
        coverage_grid=coverage_grid, max_term_blocks=max_term_blocks,
        layout=layout, max_term_segments=max_term_segments,
    )


def make_serve_fn(
    mesh: Mesh,
    budgets: alg.QueryBudgets,
    weights: ranking.RankWeights = ranking.RankWeights(),
    doc_axes: tuple[str, ...] = ("data",),
    query_axis: str = "model",
    algorithm: str = "k_sweep",
    grid: int = 64,
    n_terms: int = 0,
    fused: bool = False,
    block_size: int = 128,
    with_stats: bool = False,
    with_routing: bool = False,
    max_term_blocks: int = 1,
    layout: str = "docid",
    max_term_segments: int = 1,
):
    """Build the jit'd distributed serve step for a mesh.

    Returns ``serve(index: ShardedGeoIndex, query: QueryBatch)
    -> (ids i32[B, k], scores f32[B, k])`` with global docIDs.
    ``fused=True`` routes k_sweep through the Pallas fused (and, with
    ``budgets.prune``, block-max pruned) sweep kernel on every shard.

    ``with_stats=True`` additionally returns the per-query byte-counter
    dict *measured inside the step*: each shard's per-stage counters are
    summed over the doc axes with ``psum`` (k·S-independent — one scalar
    vector per query rides the existing collective phase), so serving
    reports see exact mesh traffic instead of a host-side capacity model.

    ``with_routing=True`` (requires ``with_stats``) turns on footprint
    routing inside the step: each shard tests the batch's footprints
    against its coverage SAT; a shard no query touches is *masked* — its
    local results are forced to (−1, −inf) and its counters zeroed before
    the psum, so merged outputs and counters are exactly what a host loop
    that skipped the shard would produce.  Counter masking is batch-level
    (a shard any query touches counts its whole batch, matching the host
    executor's visit accounting); result masking is per-query.  Two stat
    keys are added: ``shards_touched`` (per query — shards its footprints
    reach) and ``shards_visited`` (per batch — shards any query reaches).
    """
    if with_routing and not with_stats:
        raise ValueError("with_routing requires with_stats=True")
    fn = alg.get_algorithm(algorithm)
    if algorithm in ("k_sweep", "text_first") and fused:
        from functools import partial as _partial

        fn = _partial(fn, fused=True)
    idx_specs = sharded_index_specs(
        doc_axes, grid, n_terms, block_size, max_term_blocks=max_term_blocks,
        layout=layout, max_term_segments=max_term_segments,
    )
    q_spec = alg.QueryBatch(
        terms=P(query_axis), rects=P(query_axis), amps=P(query_axis)
    )
    # tree-prefix specs: the trailing P broadcasts over the stats dict
    out_spec = (
        (P(query_axis), P(query_axis), P(query_axis))
        if with_stats
        else (P(query_axis), P(query_axis))
    )

    def local_index(idx: ShardedGeoIndex) -> tuple[GeoIndex, jax.Array]:
        text = TextIndex(
            postings=idx.postings[0], impacts=idx.impacts[0], offsets=idx.offsets[0],
            bitmaps=jnp.zeros((0, 4), jnp.uint32),
            bitmap_term_ids=jnp.zeros((0,), jnp.int32),
            post_packed=idx.post_packed[0], blk_first=idx.blk_first[0],
            blk_bits=idx.blk_bits[0], blk_len=idx.blk_len[0],
            blk_word_off=idx.blk_word_off[0], blk_n_exc=idx.blk_n_exc[0],
            blk_pos=idx.blk_pos[0],
            blk_max_impact=idx.blk_max_impact[0],
            blk_term_off=idx.blk_term_off[0],
            seg_term_off=idx.seg_term_off[0], seg_pos=idx.seg_pos[0],
            seg_len=idx.seg_len[0],
            n_docs=idx.doc_rects.shape[1], n_terms=idx.n_terms,
            max_term_blocks=idx.max_term_blocks,
            layout=idx.layout,
            max_term_segments=idx.max_term_segments,
        )
        spatial = SpatialIndex(
            tp_rects=idx.tp_rects[0], tp_amps=idx.tp_amps[0],
            tp_doc_ids=idx.tp_doc_ids[0], tp_amp_scale=idx.tp_amp_scale[0],
            tile_starts=idx.tile_starts[0], tile_ends=idx.tile_ends[0],
            doc_rects=idx.doc_rects[0], doc_amps=idx.doc_amps[0],
            doc_mbr=idx.doc_mbr[0], doc_mass=idx.doc_mass[0],
            blk_mbr=idx.blk_mbr[0], blk_max_amp=idx.blk_max_amp[0],
            blk_max_mass=idx.blk_max_mass[0],
            grid=idx.grid, n_docs=idx.doc_rects.shape[1],
            block_size=idx.block_size,
        )
        local = GeoIndex(text=text, spatial=spatial, pagerank=idx.pagerank[0])
        return local, idx.doc_offset[0]

    def shard_touch(idx: ShardedGeoIndex, query: alg.QueryBatch) -> jax.Array:
        """Footprint routing test against this shard's coverage SAT: bool[B].

        Mirrors :func:`footprint_touch_np` (same clamped-floor cell mapping
        as :func:`repro.core.planner.coarse_cells`) for one shard in-jit.
        """
        sat = idx.coverage_sat[0]
        cg = idx.coverage_grid
        g = float(cg)
        rects = query.rects
        ix0 = jnp.clip(jnp.floor(rects[..., 0] * g).astype(jnp.int32), 0, cg - 1)
        iy0 = jnp.clip(jnp.floor(rects[..., 1] * g).astype(jnp.int32), 0, cg - 1)
        ix1 = jnp.clip(jnp.floor(rects[..., 2] * g).astype(jnp.int32), 0, cg - 1)
        iy1 = jnp.clip(jnp.floor(rects[..., 3] * g).astype(jnp.int32), 0, cg - 1)
        valid = (
            (rects[..., 2] > rects[..., 0])
            & (rects[..., 3] > rects[..., 1])
            & (query.amps > 0)
        )  # [B, R]
        cover = (
            sat[iy1 + 1, ix1 + 1] - sat[iy0, ix1 + 1] - sat[iy1 + 1, ix0] + sat[iy0, ix0]
        )  # [B, R]
        return jnp.any((cover > 0) & valid, axis=-1)

    def shard_body(idx: ShardedGeoIndex, query: alg.QueryBatch):
        local, gid_map = local_index(idx)
        res = fn(local.text, local.spatial, local.pagerank, query, budgets, weights)
        # local → global docIDs
        k = res.ids.shape[-1]
        safe = jnp.clip(res.ids, 0, gid_map.shape[0] - 1)
        gids = jnp.where(res.ids >= 0, gid_map[safe], -1)
        scores = jnp.where(res.ids >= 0, res.scores, -jnp.inf)
        if with_routing:
            # mask untouched (query, shard) pairs before the merge: their
            # contribution becomes structurally empty (provably it already
            # was — require_geo scores a non-overlapping shard −inf)
            touch = shard_touch(idx, query)  # [B]
            gids = jnp.where(touch[:, None], gids, -1)
            scores = jnp.where(touch[:, None], scores, -jnp.inf)
        # hierarchical top-k merge over doc axes (innermost first = intra-pod)
        for ax in reversed(doc_axes):
            g_ids = jax.lax.all_gather(gids, ax)  # [n_ax, B, k]
            g_scores = jax.lax.all_gather(scores, ax)
            n_ax = g_ids.shape[0]
            g_ids = jnp.moveaxis(g_ids, 0, -2).reshape(*gids.shape[:-1], n_ax * k)
            g_scores = jnp.moveaxis(g_scores, 0, -2).reshape(
                *scores.shape[:-1], n_ax * k
            )
            scores, sel = jax.lax.top_k(g_scores, k)
            gids = jnp.take_along_axis(g_ids, sel, axis=-1)
        if with_stats:
            # exact per-query counters: sum each shard's measured stats
            # over the doc axes (every query executed on every shard)
            raw = res.stats
            if with_routing:
                # batch-level visit accounting: a shard counts its whole
                # batch iff any query touches it — exactly the host loop's
                # skip semantics, so host and mesh counters stay equal
                visited = jnp.any(touch)
                raw = {
                    key: jnp.where(visited, v, jnp.zeros_like(v))
                    for key, v in raw.items()
                }
            stats = {key: jax.lax.psum(v, doc_axes) for key, v in raw.items()}
            if with_routing:
                stats["shards_touched"] = jax.lax.psum(
                    touch.astype(jnp.float32), doc_axes
                )
                # [1] not scalar: stats ride the P(query_axis) out_spec,
                # so each query-shard contributes its own visit count
                stats["shards_visited"] = jax.lax.psum(
                    jnp.any(touch).astype(jnp.float32)[None], doc_axes
                )
            return gids, scores, stats
        return gids, scores

    mapped = shard_map(
        shard_body,
        mesh=mesh,
        in_specs=(idx_specs, q_spec),
        out_specs=out_spec,
        check_rep=False,
    )
    return jax.jit(mapped)

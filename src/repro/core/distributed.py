"""Distributed geo-search serving: doc-sharded index × query-sharded batch.

Topology (DESIGN.md §5): documents are partitioned into ``S`` index shards
laid out over the mesh's doc axes (``('pod','data')`` in production); the
query batch is sharded over the ``'model'`` axis (replica/throughput axis).
One ``shard_map`` serve step:

1. every device runs the full K-SWEEP pipeline against its local index shard
   for its local query slice;
2. local top-k per (query, shard);
3. hierarchical merge: ``all_gather`` along ``'data'`` (intra-pod ICI) +
   re-top-k, then along ``'pod'`` (inter-pod DCI) + final top-k.

Collective volume per query is O(k · n_doc_shards) — independent of corpus
size, the property that makes the architecture scale to thousands of chips.

Partitioning policies (paper §Conclusions future work):
* ``hash`` — docs round-robin over shards (the standard engine layout);
* ``geo``  — docs sorted by the Morton code of their footprint center, then
  split into equal contiguous ranges: each shard owns a compact region, its
  tile grid is denser, sweeps are tighter, and non-overlapping shards
  short-circuit (geo score 0 everywhere → empty local top-k).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import algorithms as alg
from repro.core import ranking
from repro.core.engine import GeoIndex
from repro.core.spatial_index import SpatialIndex, build_spatial_index_np
from repro.core.text_index import (
    TextIndex,
    build_text_index_np,
    global_idf_np as tidx_global_idf,
    rescale_impacts_to_global,
)
from repro.core import geometry


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class ShardedGeoIndex:
    """Stacked per-shard index arrays; leading dim = doc shard."""

    # text index
    postings: jax.Array  # i32[S, P]
    impacts: jax.Array  # f32[S, P]
    offsets: jax.Array  # i32[S, M+1]
    # spatial index
    tp_rects: jax.Array  # f32[S, T, 4]
    tp_amps: jax.Array  # f32[S, T]
    tp_doc_ids: jax.Array  # i32[S, T]
    tile_starts: jax.Array  # i32[S, G*G, m]
    tile_ends: jax.Array  # i32[S, G*G, m]
    doc_rects: jax.Array  # f32[S, N, R, 4]
    doc_amps: jax.Array  # f32[S, N, R]
    doc_mbr: jax.Array  # f32[S, N, 4]
    doc_mass: jax.Array  # f32[S, N]
    # block-max metadata columns (pruned K-SWEEP; see core/spatial_index.py)
    blk_mbr: jax.Array  # f32[S, NB, 4]
    blk_max_amp: jax.Array  # f32[S, NB]
    blk_max_mass: jax.Array  # f32[S, NB]
    pagerank: jax.Array  # f32[S, N]
    doc_offset: jax.Array  # i32[S]  local→global docID base
    grid: int = field(metadata=dict(static=True))
    n_terms: int = field(metadata=dict(static=True))
    block_size: int = field(default=128, metadata=dict(static=True))

    @property
    def n_shards(self) -> int:
        return self.postings.shape[0]


def partition_order(doc_rects: np.ndarray, n_shards: int, partition: str) -> np.ndarray:
    """Doc permutation for sharding: ``hash`` round-robin or ``geo`` Morton."""
    n_docs = doc_rects.shape[0]
    if partition == "geo":
        cx = doc_rects[:, :, [0, 2]].mean(axis=(1, 2))
        cy = doc_rects[:, :, [1, 3]].mean(axis=(1, 2))
        fine = 1 << 15
        code = geometry.morton_encode_np(
            np.clip((cx * fine), 0, fine - 1).astype(np.uint32),
            np.clip((cy * fine), 0, fine - 1).astype(np.uint32),
        )
        return np.argsort(code, kind="stable")
    if partition == "hash":
        return np.argsort(np.arange(n_docs) % n_shards, kind="stable")
    raise ValueError(partition)


def shard_corpus_np(
    doc_terms: list[np.ndarray],
    doc_rects: np.ndarray,
    doc_amps: np.ndarray,
    pagerank: np.ndarray,
    n_terms: int,
    n_shards: int,
    partition: str = "hash",
    grid: int = 64,
    m_intervals: int = 2,
    block_size: int = 128,
) -> ShardedGeoIndex:
    """Partition a corpus and build one index per shard (host side)."""
    n_docs = len(doc_terms)
    order = partition_order(doc_rects, n_shards, partition)

    per = (n_docs + n_shards - 1) // n_shards
    idf_global = tidx_global_idf(doc_terms, n_terms)
    shards = []
    offsets = []
    global_ids = []
    for s in range(n_shards):
        sel = order[s * per : (s + 1) * per]
        offsets.append(0)  # global ids carried via explicit map instead
        global_ids.append(sel)
        terms = [doc_terms[i] for i in sel]
        text = build_text_index_np(terms, n_terms)
        # broadcast global term statistics (IDF) so shards rank like the
        # single-index engine would
        text = rescale_impacts_to_global(text, idf_global)
        spatial = build_spatial_index_np(
            doc_rects[sel], doc_amps[sel], grid, m_intervals, block_size=block_size
        )
        shards.append((text, spatial, pagerank[sel], sel))

    # pad to uniform shapes and stack
    P_max = max(s[0].postings.shape[0] for s in shards)
    T_max = max(s[1].tp_rects.shape[0] for s in shards)
    N_max = max(len(s[3]) for s in shards)
    R = doc_rects.shape[1]

    def padded(a, n, fill):
        a = np.asarray(a)
        out = np.full((n,) + a.shape[1:], fill, dtype=a.dtype)
        out[: a.shape[0]] = a
        return out

    stacked = {}
    stacked["postings"] = np.stack(
        [padded(s[0].postings, P_max, 2**31 - 1) for s in shards]
    )
    stacked["impacts"] = np.stack([padded(s[0].impacts, P_max, 0.0) for s in shards])
    stacked["offsets"] = np.stack([np.asarray(s[0].offsets) for s in shards])
    stacked["tp_rects"] = np.stack(
        [
            padded(s[1].tp_rects, T_max, 0.0) for s in shards
        ]
    )
    # make padded toe prints empty rects
    for i, s in enumerate(shards):
        t = s[1].tp_rects.shape[0]
        stacked["tp_rects"][i, t:] = geometry.EMPTY_RECT
    stacked["tp_amps"] = np.stack([padded(s[1].tp_amps, T_max, 0.0) for s in shards])
    stacked["tp_doc_ids"] = np.stack(
        [padded(s[1].tp_doc_ids, T_max, 0) for s in shards]
    )
    stacked["tile_starts"] = np.stack([np.asarray(s[1].tile_starts) for s in shards])
    stacked["tile_ends"] = np.stack([np.asarray(s[1].tile_ends) for s in shards])
    stacked["doc_rects"] = np.stack(
        [padded(s[1].doc_rects, N_max, 0.0) for s in shards]
    )
    for i, s in enumerate(shards):
        n = s[1].doc_rects.shape[0]
        stacked["doc_rects"][i, n:] = geometry.EMPTY_RECT
    stacked["doc_amps"] = np.stack([padded(s[1].doc_amps, N_max, 0.0) for s in shards])
    stacked["doc_mbr"] = np.stack([padded(s[1].doc_mbr, N_max, 0.0) for s in shards])
    stacked["doc_mass"] = np.stack([padded(s[1].doc_mass, N_max, 0.0) for s in shards])
    # block-max columns: zero-padded blocks have ub == 0 → always skipped
    NB_max = max(s[1].blk_mbr.shape[0] for s in shards)
    stacked["blk_mbr"] = np.stack([padded(s[1].blk_mbr, NB_max, 0.0) for s in shards])
    stacked["blk_max_amp"] = np.stack(
        [padded(s[1].blk_max_amp, NB_max, 0.0) for s in shards]
    )
    stacked["blk_max_mass"] = np.stack(
        [padded(s[1].blk_max_mass, NB_max, 0.0) for s in shards]
    )
    stacked["pagerank"] = np.stack([padded(s[2], N_max, 0.0) for s in shards])
    # local→global docID translation table
    gid = np.stack([padded(s[3].astype(np.int32), N_max, -1) for s in shards])
    stacked["doc_offset"] = gid  # [S, N] full map (name kept for pytree stability)

    return ShardedGeoIndex(
        postings=jnp.asarray(stacked["postings"]),
        impacts=jnp.asarray(stacked["impacts"]),
        offsets=jnp.asarray(stacked["offsets"]),
        tp_rects=jnp.asarray(stacked["tp_rects"]),
        tp_amps=jnp.asarray(stacked["tp_amps"]),
        tp_doc_ids=jnp.asarray(stacked["tp_doc_ids"]),
        tile_starts=jnp.asarray(stacked["tile_starts"]),
        tile_ends=jnp.asarray(stacked["tile_ends"]),
        doc_rects=jnp.asarray(stacked["doc_rects"]),
        doc_amps=jnp.asarray(stacked["doc_amps"]),
        doc_mbr=jnp.asarray(stacked["doc_mbr"]),
        doc_mass=jnp.asarray(stacked["doc_mass"]),
        blk_mbr=jnp.asarray(stacked["blk_mbr"]),
        blk_max_amp=jnp.asarray(stacked["blk_max_amp"]),
        blk_max_mass=jnp.asarray(stacked["blk_max_mass"]),
        pagerank=jnp.asarray(stacked["pagerank"]),
        doc_offset=jnp.asarray(gid),
        grid=grid,
        n_terms=n_terms,
        block_size=shards[0][1].block_size,
    )


def sharded_index_specs(
    doc_axes: tuple[str, ...], grid: int, n_terms: int, block_size: int = 128
) -> ShardedGeoIndex:
    """PartitionSpecs for every field (leading dim over the doc axes)."""
    lead = P(doc_axes)
    return ShardedGeoIndex(
        postings=lead, impacts=lead, offsets=lead,
        tp_rects=lead, tp_amps=lead, tp_doc_ids=lead,
        tile_starts=lead, tile_ends=lead,
        doc_rects=lead, doc_amps=lead, doc_mbr=lead, doc_mass=lead,
        blk_mbr=lead, blk_max_amp=lead, blk_max_mass=lead,
        pagerank=lead, doc_offset=lead,
        grid=grid, n_terms=n_terms, block_size=block_size,
    )


def make_serve_fn(
    mesh: Mesh,
    budgets: alg.QueryBudgets,
    weights: ranking.RankWeights = ranking.RankWeights(),
    doc_axes: tuple[str, ...] = ("data",),
    query_axis: str = "model",
    algorithm: str = "k_sweep",
    grid: int = 64,
    n_terms: int = 0,
    fused: bool = False,
    block_size: int = 128,
    with_stats: bool = False,
):
    """Build the jit'd distributed serve step for a mesh.

    Returns ``serve(index: ShardedGeoIndex, query: QueryBatch)
    -> (ids i32[B, k], scores f32[B, k])`` with global docIDs.
    ``fused=True`` routes k_sweep through the Pallas fused (and, with
    ``budgets.prune``, block-max pruned) sweep kernel on every shard.

    ``with_stats=True`` additionally returns the per-query byte-counter
    dict *measured inside the step*: each shard's per-stage counters are
    summed over the doc axes with ``psum`` (k·S-independent — one scalar
    vector per query rides the existing collective phase), so serving
    reports see exact mesh traffic instead of a host-side capacity model.
    """
    fn = alg.get_algorithm(algorithm)
    if algorithm == "k_sweep" and fused:
        from functools import partial as _partial

        fn = _partial(fn, fused=True)
    idx_specs = sharded_index_specs(doc_axes, grid, n_terms, block_size)
    q_spec = alg.QueryBatch(
        terms=P(query_axis), rects=P(query_axis), amps=P(query_axis)
    )
    # tree-prefix specs: the trailing P broadcasts over the stats dict
    out_spec = (
        (P(query_axis), P(query_axis), P(query_axis))
        if with_stats
        else (P(query_axis), P(query_axis))
    )

    def local_index(idx: ShardedGeoIndex) -> tuple[GeoIndex, jax.Array]:
        text = TextIndex(
            postings=idx.postings[0], impacts=idx.impacts[0], offsets=idx.offsets[0],
            bitmaps=jnp.zeros((0, 4), jnp.uint32),
            bitmap_term_ids=jnp.zeros((0,), jnp.int32),
            n_docs=idx.doc_rects.shape[1], n_terms=idx.n_terms,
        )
        spatial = SpatialIndex(
            tp_rects=idx.tp_rects[0], tp_amps=idx.tp_amps[0],
            tp_doc_ids=idx.tp_doc_ids[0],
            tile_starts=idx.tile_starts[0], tile_ends=idx.tile_ends[0],
            doc_rects=idx.doc_rects[0], doc_amps=idx.doc_amps[0],
            doc_mbr=idx.doc_mbr[0], doc_mass=idx.doc_mass[0],
            blk_mbr=idx.blk_mbr[0], blk_max_amp=idx.blk_max_amp[0],
            blk_max_mass=idx.blk_max_mass[0],
            grid=idx.grid, n_docs=idx.doc_rects.shape[1],
            block_size=idx.block_size,
        )
        local = GeoIndex(text=text, spatial=spatial, pagerank=idx.pagerank[0])
        return local, idx.doc_offset[0]

    def shard_body(idx: ShardedGeoIndex, query: alg.QueryBatch):
        local, gid_map = local_index(idx)
        res = fn(local.text, local.spatial, local.pagerank, query, budgets, weights)
        # local → global docIDs
        k = res.ids.shape[-1]
        safe = jnp.clip(res.ids, 0, gid_map.shape[0] - 1)
        gids = jnp.where(res.ids >= 0, gid_map[safe], -1)
        scores = jnp.where(res.ids >= 0, res.scores, -jnp.inf)
        # hierarchical top-k merge over doc axes (innermost first = intra-pod)
        for ax in reversed(doc_axes):
            g_ids = jax.lax.all_gather(gids, ax)  # [n_ax, B, k]
            g_scores = jax.lax.all_gather(scores, ax)
            n_ax = g_ids.shape[0]
            g_ids = jnp.moveaxis(g_ids, 0, -2).reshape(*gids.shape[:-1], n_ax * k)
            g_scores = jnp.moveaxis(g_scores, 0, -2).reshape(
                *scores.shape[:-1], n_ax * k
            )
            scores, sel = jax.lax.top_k(g_scores, k)
            gids = jnp.take_along_axis(g_ids, sel, axis=-1)
        if with_stats:
            # exact per-query counters: sum each shard's measured stats
            # over the doc axes (every query executed on every shard)
            stats = {
                key: jax.lax.psum(v, doc_axes) for key, v in res.stats.items()
            }
            return gids, scores, stats
        return gids, scores

    mapped = shard_map(
        shard_body,
        mesh=mesh,
        in_specs=(idx_specs, q_spec),
        out_specs=out_spec,
        check_rep=False,
    )
    return jax.jit(mapped)

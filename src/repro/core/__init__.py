"""The paper's primary contribution: geographic search query processing.

Modules:
  geometry       rectangles, Morton codes, tile math
  footprint      amplitude-weighted rect-set footprints + geo scores
  text_index     CSR inverted index + impacts + block bitmaps
  spatial_index  Morton toe-print store + tile-interval grid
  ranking        combined text/geo/pagerank ranking
  algorithms     TEXT-FIRST / GEO-FIRST / K-SWEEP batched pipelines
  planner        cost-based per-query plan selection (QueryPlan / Planner)
  engine         GeoSearchEngine facade
  distributed    doc-sharded serving over a device mesh
"""
from repro.core.engine import GeoIndex, GeoSearchEngine
from repro.core.algorithms import (
    ALGORITHMS,
    QueryBatch,
    QueryBudgets,
    TopKResult,
    get_algorithm,
    register_algorithm,
)
from repro.core.planner import CostModel, Planner, QueryPlan
from repro.core.ranking import RankWeights

__all__ = [
    "GeoIndex", "GeoSearchEngine", "QueryBatch", "QueryBudgets",
    "TopKResult", "ALGORITHMS", "get_algorithm", "register_algorithm",
    "CostModel", "Planner", "QueryPlan", "RankWeights",
]

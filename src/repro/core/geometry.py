"""Rectangle and space-filling-curve geometry for geo query processing.

World coordinates live in the unit square ``[0, 1) x [0, 1)``.  A rectangle
is a length-4 vector ``(x0, y0, x1, y1)`` with ``x0 <= x1``, ``y0 <= y1``.
Degenerate/empty rectangles are encoded with ``x1 < x0`` (e.g. padding).

Everything here has two flavors:

* ``jnp`` functions — jit-safe, used inside query pipelines.
* ``*_np`` functions — numpy, used at index-build time (host side).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

EMPTY_RECT = np.array([1.0, 1.0, 0.0, 0.0], dtype=np.float32)  # x1 < x0 => empty


# ---------------------------------------------------------------------------
# Rectangle math (jit-safe)
# ---------------------------------------------------------------------------

def rect_area(r: jax.Array) -> jax.Array:
    """Area of rectangles ``r[..., 4]``; empty rects give 0."""
    w = jnp.maximum(r[..., 2] - r[..., 0], 0.0)
    h = jnp.maximum(r[..., 3] - r[..., 1], 0.0)
    return w * h


def rect_intersection_area(a: jax.Array, b: jax.Array) -> jax.Array:
    """Intersection area of broadcast rect arrays ``a[..., 4]``, ``b[..., 4]``."""
    x0 = jnp.maximum(a[..., 0], b[..., 0])
    y0 = jnp.maximum(a[..., 1], b[..., 1])
    x1 = jnp.minimum(a[..., 2], b[..., 2])
    y1 = jnp.minimum(a[..., 3], b[..., 3])
    return jnp.maximum(x1 - x0, 0.0) * jnp.maximum(y1 - y0, 0.0)


def rects_intersect(a: jax.Array, b: jax.Array) -> jax.Array:
    """Boolean: do rects overlap (with positive or zero-width touching area)?"""
    return (
        (jnp.maximum(a[..., 0], b[..., 0]) <= jnp.minimum(a[..., 2], b[..., 2]))
        & (jnp.maximum(a[..., 1], b[..., 1]) <= jnp.minimum(a[..., 3], b[..., 3]))
    )


def rect_union_bound(a: jax.Array, b: jax.Array) -> jax.Array:
    """MBR of two rects (broadcasting)."""
    return jnp.stack(
        [
            jnp.minimum(a[..., 0], b[..., 0]),
            jnp.minimum(a[..., 1], b[..., 1]),
            jnp.maximum(a[..., 2], b[..., 2]),
            jnp.maximum(a[..., 3], b[..., 3]),
        ],
        axis=-1,
    )


# ---------------------------------------------------------------------------
# Morton (Z-order) codes
# ---------------------------------------------------------------------------

def _part1by1_u32(v):
    """Spread the low 16 bits of v over even bit positions (u32 math)."""
    v = v & 0x0000FFFF
    v = (v | (v << 8)) & 0x00FF00FF
    v = (v | (v << 4)) & 0x0F0F0F0F
    v = (v | (v << 2)) & 0x33333333
    v = (v | (v << 1)) & 0x55555555
    return v


def morton_encode(ix, iy):
    """Interleave integer coordinates into a Z-order code (jit-safe).

    ``ix``/``iy`` are integer tile/cell coordinates, < 2**16.
    Returns int32 codes (safe for grids up to 2**15 per side; we use <= 2**10).
    """
    ix = jnp.asarray(ix, jnp.uint32)
    iy = jnp.asarray(iy, jnp.uint32)
    code = _part1by1_u32(ix) | (_part1by1_u32(iy) << jnp.uint32(1))
    return code.astype(jnp.int32)


def morton_encode_np(ix: np.ndarray, iy: np.ndarray) -> np.ndarray:
    ix = ix.astype(np.uint32)
    iy = iy.astype(np.uint32)

    def part(v):
        v = v & np.uint32(0x0000FFFF)
        v = (v | (v << 8)) & np.uint32(0x00FF00FF)
        v = (v | (v << 4)) & np.uint32(0x0F0F0F0F)
        v = (v | (v << 2)) & np.uint32(0x33333333)
        v = (v | (v << 1)) & np.uint32(0x55555555)
        return v

    return (part(ix) | (part(iy) << np.uint32(1))).astype(np.int64)


def point_to_cell(x, y, grid: int):
    """Map unit-square points to integer cell coordinates in a grid**2 grid."""
    ix = jnp.clip((x * grid).astype(jnp.int32), 0, grid - 1)
    iy = jnp.clip((y * grid).astype(jnp.int32), 0, grid - 1)
    return ix, iy


def rect_to_cell_range(r: jax.Array, grid: int):
    """Integer cell bounds ``(ix0, iy0, ix1, iy1)`` covered by rect(s) r.

    Inclusive bounds. Empty rects produce an inverted range (ix1 < ix0).
    """
    g = jnp.float32(grid)
    ix0 = jnp.clip(jnp.floor(r[..., 0] * g).astype(jnp.int32), 0, grid - 1)
    iy0 = jnp.clip(jnp.floor(r[..., 1] * g).astype(jnp.int32), 0, grid - 1)
    # Subtract a hair so that an exact upper boundary does not spill into the
    # next tile row/col.
    eps = 0.5 / grid * 1e-3
    ix1 = jnp.clip(jnp.floor((r[..., 2] - eps) * g).astype(jnp.int32), 0, grid - 1)
    iy1 = jnp.clip(jnp.floor((r[..., 3] - eps) * g).astype(jnp.int32), 0, grid - 1)
    empty = (r[..., 2] <= r[..., 0]) | (r[..., 3] <= r[..., 1])
    ix1 = jnp.where(empty, ix0 - 1, ix1)
    return ix0, iy0, ix1, iy1


def rect_cell_bounds_np(rects: np.ndarray, grid: int):
    """Integer cell bounds ``(ix0, iy0, ix1, iy1)`` covered by rects, numpy.

    The host-side twin of :func:`rect_to_cell_range` (same upper-edge eps),
    shared by the index build and the query planner so their rect→tile
    bucketing can never drift apart.  Empty rects yield inverted bounds.
    """
    g = float(grid)
    eps = 0.5 / grid * 1e-3
    ix0 = np.clip(np.floor(rects[..., 0] * g).astype(np.int64), 0, grid - 1)
    iy0 = np.clip(np.floor(rects[..., 1] * g).astype(np.int64), 0, grid - 1)
    ix1 = np.clip(np.floor((rects[..., 2] - eps) * g).astype(np.int64), 0, grid - 1)
    iy1 = np.clip(np.floor((rects[..., 3] - eps) * g).astype(np.int64), 0, grid - 1)
    return ix0, iy0, ix1, iy1


def enumerate_rect_tiles(r: jax.Array, grid: int, max_tiles: int):
    """Tile ids (row-major ``iy*grid+ix``) intersecting rect ``r[4]``.

    Returns ``(tile_ids i32[max_tiles], valid bool[max_tiles])``.  Tiles beyond
    the rect's coverage (or beyond ``max_tiles``) are masked out.  Tiles are
    enumerated row-major inside the covered cell range; if the rect covers
    more than ``max_tiles`` tiles the overflow is dropped (documented budget
    approximation — callers size ``max_tiles`` for the largest supported
    query footprint).
    """
    ix0, iy0, ix1, iy1 = rect_to_cell_range(r, grid)
    nx = jnp.maximum(ix1 - ix0 + 1, 0)
    ny = jnp.maximum(iy1 - iy0 + 1, 0)
    idx = jnp.arange(max_tiles, dtype=jnp.int32)
    # row-major within the covered sub-grid
    rel_y = idx // jnp.maximum(nx, 1)
    rel_x = idx % jnp.maximum(nx, 1)
    valid = (idx < nx * ny) & (nx > 0) & (ny > 0)
    tix = jnp.clip(ix0 + rel_x, 0, grid - 1)
    tiy = jnp.clip(iy0 + rel_y, 0, grid - 1)
    tile_ids = tiy * grid + tix
    return jnp.where(valid, tile_ids, 0), valid


def rect_center(r: jax.Array) -> tuple[jax.Array, jax.Array]:
    return (r[..., 0] + r[..., 2]) * 0.5, (r[..., 1] + r[..., 3]) * 0.5

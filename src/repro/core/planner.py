"""Cost-based per-query planner: pick text-first / geo-first / K-SWEEP per query.

The paper's central claim is that a geo search engine needs *several* query
processing algorithms because no single text/spatial evaluation order wins
across query shapes: a rare term with a country-sized footprint wants the
inverted index to drive (TEXT-FIRST), a hot term with a city-block footprint
wants the spatial structure to drive (GEO-FIRST), and the broad middle is
K-SWEEP territory.  This module makes that choice *per query* from cheap
host-side features instead of a static ``--algo`` flag.

Plan abstraction
----------------
A :class:`QueryPlan` is (algorithm, budgets, fused flag) — everything the
engine needs to compile and run one pipeline variant.  Plans are frozen and
hashable: they key the engine's compiled-function cache, the serving
batcher's buckets (a flushed batch compiles once per plan × shape), and the
``ServeReport`` per-plan attribution.

Cost-model features (cheap host-side numpy per query, no device work)
---------------------------------------------------------------------
* ``df_min`` / ``df_sum`` — posting-list lengths of the query terms from the
  :class:`~repro.core.text_index.TextIndex` CSR offsets (the df table is
  precomputed once at planner build).  ``df_min`` is the TEXT-FIRST driver
  list length — the dominant term of its cost.
* ``tp_est`` — toe prints the query's *tile intervals* cover: per-tile
  interval lengths (what GEO-FIRST / K-SWEEP actually enumerate, coalescing
  slack included) are precomputed into a summed-area table at planner
  build, so each query rect's covered-cell sum is O(1).  This is "query
  footprint area × corpus toe-print density", localized to the tile grid
  the candidate streams really fetch from.
* ``tp_span`` — Morton-store span from the spatial index's *block-max
  metadata* (``blk_mbr`` + per-block occupancy): every block whose MBR
  touches the footprint lies inside the span K-SWEEP's coalesced streams
  must cover, which sizes its streamed volume and its sweep-capacity
  truncation risk.  Block candidates come from a coarse bbox grid built
  once over the block MBRs (cell → block CSR), so the exact MBR test runs
  on the footprint's cells' blocks only, not all NB blocks; the probe
  count is published as the ``planner.tp_span_probe`` metric.

Per-algorithm cost estimates mirror the stats formulas the executors
measure (:mod:`repro.core.algorithms`): predicted ``n_probes``,
``bytes_postings`` and ``bytes_spatial`` per query.  The planner objective
is ``w_probes·n_probes + w_postings·bytes_postings + w_spatial·
bytes_spatial`` (defaults weight the paper's probe + posting traffic, with
a light spatial-stream term to break ties).

Calibration
-----------
The estimates are capacity-shaped upper bounds; real workloads have
conjunction selectivity and sweep slack the closed forms cannot see.
:meth:`CostModel.calibrate` runs each candidate algorithm once on a probe
batch through the real engine, compares the *measured* per-stage counters
(the same ``stats`` dict the executors report) against the predictions, and
stores one multiplicative scale per (algorithm, counter).  Scales are
clipped to [1/16, 16] so a degenerate probe batch cannot invert a
decision's sign.  Calibration is optional — uncalibrated scales are 1.0 and
the feature split alone separates the regimes above.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field

import numpy as np

from repro.core import algorithms as alg
from repro.core import geometry
from repro.core.spatial_index import INVALID

# objective keys: the per-stage counters every algorithm reports
COST_KEYS = ("n_probes", "bytes_postings", "bytes_spatial")
_SCALE_CLIP = 16.0
# coarse bbox-grid resolution for the tp_span candidate lookup
_SPAN_GRID = 16


@dataclass(frozen=True)
class QueryPlan:
    """One executable pipeline choice: algorithm + budgets + kernel knobs.

    Frozen and hashable — used as a compiled-function cache key, a batcher
    bucket-key component, and a serving-report attribution label.
    """

    algorithm: str
    budgets: alg.QueryBudgets
    fused: bool = False

    @property
    def label(self) -> str:
        """Human-readable plan name for reports (``k_sweep+prune+fused``)."""
        out = self.algorithm
        if self.algorithm in ("k_sweep", "text_first") and self.budgets.prune:
            out += "+prune"
        if self.algorithm in ("k_sweep", "text_first") and self.fused:
            out += "+fused"
        return out

    def engine_kw(self) -> dict:
        """Extra keyword args the engine forwards to the algorithm fn."""
        if self.algorithm in ("k_sweep", "text_first") and self.fused:
            return {"fused": True}
        return {}


@dataclass(frozen=True)
class QueryFeatures:
    """Cheap per-query features the cost model consumes."""

    n_terms: int
    df_min: float  # shortest posting list among the query terms
    df_sum: float  # total posting volume of the query terms
    tp_est: float  # estimated toe prints the tile intervals cover
    tp_span: float  # estimated Morton-store span (block metadata hits)
    area: float  # total query footprint area


@dataclass
class CostModel:
    """Per-algorithm per-stage cost estimates from per-query features.

    Feature tables are plain numpy copies of the index's auxiliary
    structures (df table, block metadata) — the model never touches device
    arrays at plan time.
    """

    df: np.ndarray  # f64[M] posting-list length per term
    blk_mbr: np.ndarray  # f32[NB, 4] block MBRs (Morton store)
    blk_count: np.ndarray  # f64[NB] toe prints per block
    tile_sat: np.ndarray  # f64[G+1, G+1] summed-area table of per-tile
    #                       interval coverage (Σ interval lengths per tile)
    grid: int
    n_postings: int
    n_toeprints: int
    n_docs: int
    rect_slots: int  # R of the doc-major footprint mirror
    budgets: alg.QueryBudgets
    # per-record byte sizes of the index actually being served — read from
    # the index properties at build so compressed stores shrink the
    # predicted bytes_* exactly like they shrink the measured counters
    posting_bytes: float = float(alg.POSTING_BYTES)
    tp_bytes: float = float(alg.TP_BYTES)
    doc_bytes: float = 20.0  # doc-major rect + amp slot
    tp_id_bytes: float = 4.0  # toe-print doc-id column entry
    # (algorithm, counter) -> multiplicative calibration scale
    scales: dict = field(default_factory=dict)
    # metrics registry (repro.obs) attached by the serving layer; None =
    # the planner publishes nothing
    metrics: object = None
    # cumulative exact MBR tests performed by the tp_span candidate path
    tp_span_probes: int = 0

    def __post_init__(self) -> None:
        # Coarse bbox grid over the occupied block MBRs: cell -> block-id
        # CSR.  Replaces the O(NB) all-blocks scan in features(): a query
        # rect gathers candidate blocks from its covered coarse cells and
        # runs the exact MBR ∩ rect test on those only.  Exact because the
        # cell mapping is clamped and monotone with NO upper-edge epsilon
        # on either side: any point in MBR ∩ rect lands in a cell covered
        # by both, so candidates are a superset of the true hits (boundary
        # over-coverage only adds candidates, never drops one), and zero-
        # count blocks contribute nothing to the span sum either way.
        G = _SPAN_GRID
        occ = np.flatnonzero(np.asarray(self.blk_count) > 0)
        m = np.asarray(self.blk_mbr, np.float64)
        if len(occ):
            ix0, iy0, ix1, iy1 = coarse_cells(m[occ], G)
            w, h = ix1 - ix0 + 1, iy1 - iy0 + 1
            ok = (w > 0) & (h > 0)  # inverted MBRs (padding) cover nothing
            occ, ix0, iy0, w, h = occ[ok], ix0[ok], iy0[ok], w[ok], h[ok]
        if len(occ):
            reps = w * h
            blocks = np.repeat(occ, reps)
            # per-entry (dx, dy) offset within its block's cell range
            first = np.concatenate(([0], np.cumsum(reps)[:-1]))
            k = np.arange(int(reps.sum())) - np.repeat(first, reps)
            wv = np.repeat(w, reps)
            cells = (np.repeat(iy0, reps) + k // wv) * G + (
                np.repeat(ix0, reps) + k % wv
            )
            order = np.argsort(cells, kind="stable")
            self._span_blocks = blocks[order]
            self._span_offsets = np.zeros(G * G + 1, np.int64)
            np.cumsum(np.bincount(cells, minlength=G * G), out=self._span_offsets[1:])
        else:
            self._span_blocks = np.zeros((0,), np.int64)
            self._span_offsets = np.zeros(G * G + 1, np.int64)

    def _span_candidates(self, r: np.ndarray) -> np.ndarray:
        """Block ids whose coarse cells the query rects touch (superset of
        the blocks whose MBR intersects any rect)."""
        G = _SPAN_GRID
        ix0, iy0, ix1, iy1 = coarse_cells(r, G)
        parts = []
        for j in range(len(r)):
            for cy in range(int(iy0[j]), int(iy1[j]) + 1):
                base = cy * G
                s = self._span_offsets[base + int(ix0[j])]
                e = self._span_offsets[base + int(ix1[j]) + 1]
                if e > s:
                    parts.append(self._span_blocks[s:e])
        if not parts:
            return np.zeros((0,), np.int64)
        return np.unique(np.concatenate(parts))

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @staticmethod
    def from_geo_index(index, budgets: alg.QueryBudgets) -> "CostModel":
        """Build feature tables from a single :class:`GeoIndex`."""
        text, spatial = index.text, index.spatial
        df = np.diff(np.asarray(text.offsets)).astype(np.float64)
        blk_mbr = np.asarray(spatial.blk_mbr)
        blk_count = _block_counts(spatial.n_toeprints, spatial.block_size, blk_mbr)
        return CostModel(
            df=df,
            blk_mbr=blk_mbr,
            blk_count=blk_count,
            tile_sat=_tile_sat(
                np.asarray(spatial.tile_starts),
                np.asarray(spatial.tile_ends),
                spatial.grid,
            ),
            grid=int(spatial.grid),
            n_postings=int(text.n_postings),
            n_toeprints=int(spatial.n_toeprints),
            n_docs=int(spatial.n_docs),
            rect_slots=int(spatial.doc_rects.shape[1]),
            budgets=budgets,
            posting_bytes=float(text.posting_bytes),
            tp_bytes=float(spatial.tp_bytes),
            doc_bytes=float(spatial.doc_bytes),
            tp_id_bytes=float(spatial.tp_doc_ids.dtype.itemsize),
        )

    @staticmethod
    def from_shards(indexes, budgets: alg.QueryBudgets) -> "CostModel":
        """Aggregate feature tables over per-shard :class:`GeoIndex` es.

        df and tile coverage sum across shards (every shard sees every
        query); block metadata concatenates, so the features count the
        whole corpus.
        """
        parts = [CostModel.from_geo_index(ix, budgets) for ix in indexes]
        tot_p = max(sum(p.n_postings for p in parts), 1)
        tot_t = max(sum(p.n_toeprints for p in parts), 1)
        return CostModel(
            df=np.sum([p.df for p in parts], axis=0),
            blk_mbr=np.concatenate([p.blk_mbr for p in parts], axis=0),
            blk_count=np.concatenate([p.blk_count for p in parts], axis=0),
            tile_sat=np.sum([p.tile_sat for p in parts], axis=0),
            grid=parts[0].grid,
            n_postings=sum(p.n_postings for p in parts),
            n_toeprints=sum(p.n_toeprints for p in parts),
            n_docs=sum(p.n_docs for p in parts),
            rect_slots=parts[0].rect_slots,
            budgets=budgets,
            # record sizes are near-identical across shards (same compress
            # mode); weight the amortized per-posting metadata anyway
            posting_bytes=sum(p.posting_bytes * p.n_postings for p in parts) / tot_p,
            tp_bytes=sum(p.tp_bytes * p.n_toeprints for p in parts) / tot_t,
            doc_bytes=parts[0].doc_bytes,
            tp_id_bytes=parts[0].tp_id_bytes,
        )

    @staticmethod
    def from_sharded_index(sharded, budgets: alg.QueryBudgets) -> "CostModel":
        """Build from a stacked :class:`ShardedGeoIndex` (mesh executor)."""
        from repro.core.spatial_index import SCALE_BLOCK

        offsets = np.asarray(sharded.offsets, np.int64)  # [S, M+1]
        df = np.diff(offsets, axis=1).sum(axis=0).astype(np.float64)
        blk_mbr = np.asarray(sharded.blk_mbr).reshape(-1, 4)
        # int8 amp stores keep the sign (positive scales), so the validity
        # count needs no dequantization — just a widening cast
        amps = np.asarray(sharded.tp_amps).astype(np.float32)
        n_tp = int((amps > 0).sum())
        # padded blocks carry zero max-amp → zero occupancy
        blk_amp = np.asarray(sharded.blk_max_amp).reshape(-1)
        bs = int(sharded.block_size)
        blk_count = np.where(blk_amp > 0, float(bs), 0.0)
        n_docs = int((np.asarray(sharded.doc_offset) >= 0).sum())
        grid = int(sharded.grid)
        sat = np.sum(
            [
                _tile_sat(
                    np.asarray(sharded.tile_starts[s]),
                    np.asarray(sharded.tile_ends[s]),
                    grid,
                )
                for s in range(sharded.n_shards)
            ],
            axis=0,
        )
        # record sizes from the stacked stores (cross-shard padding inflates
        # the packed-word count marginally; fine for a cost estimate)
        P_tot = max(int(df.sum()), 1)
        imp_b = sharded.impacts.dtype.itemsize
        if sharded.blk_first.shape[1] > 0:  # compressed posting store
            # 20 B/block metadata (first, bits, word_off, len, n_exc) +
            # 8 B/segment prefixes under the impact layout — in lockstep
            # with TextIndex.posting_bytes
            packed = 4 * sharded.post_packed.size + 20 * sharded.blk_first.size
            if sharded.layout == "impact":
                packed += 8 * sharded.seg_pos.size
            posting_bytes = packed / P_tot + imp_b
        else:
            seg = 8 * sharded.seg_pos.size if sharded.layout == "impact" else 0
            posting_bytes = 4.0 + seg / P_tot + imp_b
        scale_b = 4.0 / SCALE_BLOCK if sharded.tp_amp_scale.shape[1] else 0.0
        plane_b = (
            4 * sharded.tp_rects.dtype.itemsize
            + sharded.tp_amps.dtype.itemsize
            + scale_b
        )
        return CostModel(
            df=df,
            blk_mbr=blk_mbr,
            blk_count=blk_count,
            tile_sat=sat,
            grid=grid,
            n_postings=int(df.sum()),
            n_toeprints=n_tp,
            n_docs=n_docs,
            rect_slots=int(sharded.doc_rects.shape[2]),
            budgets=budgets,
            posting_bytes=float(posting_bytes),
            tp_bytes=float(plane_b + sharded.tp_doc_ids.dtype.itemsize),
            doc_bytes=float(
                4 * sharded.doc_rects.dtype.itemsize
                + sharded.doc_amps.dtype.itemsize
            ),
            tp_id_bytes=float(sharded.tp_doc_ids.dtype.itemsize),
        )

    # ------------------------------------------------------------------
    # features
    # ------------------------------------------------------------------
    def features(self, terms, rects, amps) -> QueryFeatures:
        t = np.unique(np.asarray(terms, np.int64).reshape(-1))
        t = t[(t >= 0) & (t < len(self.df))]
        dfs = self.df[t] if len(t) else np.zeros((0,))
        r = np.asarray(rects, np.float64).reshape(-1, 4)
        a = np.asarray(amps, np.float64).reshape(-1)
        valid = (r[:, 2] > r[:, 0]) & (r[:, 3] > r[:, 1]) & (a > 0)
        r = r[valid]
        area = float(
            np.sum((r[:, 2] - r[:, 0]) * (r[:, 3] - r[:, 1])) if len(r) else 0.0
        )
        tp_est, tp_span = 0.0, 0.0
        if len(r):
            # tile-interval coverage: what GEO-FIRST / K-SWEEP actually
            # enumerate is the tile grid's per-tile intervals (with their
            # coalescing slack), so tp_est sums the precomputed per-tile
            # interval lengths over the touched cell range — O(1) per rect
            # via the summed-area table.  rect_cell_bounds_np is the same
            # bucketing the index build used, so coverage cannot drift.
            x0, y0, x1, y1 = geometry.rect_cell_bounds_np(r, self.grid)
            s = self.tile_sat
            covered = (
                s[y1 + 1, x1 + 1] - s[y0, x1 + 1] - s[y1 + 1, x0] + s[y0, x0]
            )
            tp_est = float(np.minimum(covered.sum(), self.n_toeprints))
        if len(r) and len(self.blk_mbr):
            # Morton-span estimate for K-SWEEP's contiguous streams: every
            # metadata block whose MBR touches the footprint lies inside
            # the span the coalesced sweeps must cover.  The coarse bbox
            # grid narrows the exact MBR test to the blocks sharing a cell
            # with the footprint — same sum as the old all-blocks scan
            # (superset argument in __post_init__), O(candidates) not O(NB)
            cand = self._span_candidates(r)
            self.tp_span_probes += len(cand)
            if self.metrics is not None:
                self.metrics.inc("planner.tp_span_probe", float(len(cand)))
            if len(cand):
                m = self.blk_mbr[cand].astype(np.float64)
                hit = (
                    (np.minimum(m[None, :, 2], r[:, None, 2])
                     >= np.maximum(m[None, :, 0], r[:, None, 0]))
                    & (np.minimum(m[None, :, 3], r[:, None, 3])
                       >= np.maximum(m[None, :, 1], r[:, None, 1]))
                ).any(axis=0)
                tp_span = float(
                    np.minimum((hit * self.blk_count[cand]).sum(), self.n_toeprints)
                )
        return QueryFeatures(
            n_terms=int(len(t)),
            df_min=float(dfs.min()) if len(dfs) else 0.0,
            df_sum=float(dfs.sum()),
            tp_est=tp_est,
            tp_span=max(tp_span, tp_est),
            area=area,
        )

    # ------------------------------------------------------------------
    # per-algorithm estimates
    # ------------------------------------------------------------------
    def estimate(self, plan: QueryPlan, f: QueryFeatures) -> dict[str, float]:
        """Predicted per-query counters for ``plan`` (COST_KEYS)."""
        bud = plan.budgets
        d = max(f.n_terms, 1)
        mc = bud.max_candidates
        logp = float(np.ceil(np.log2(max(self.n_postings, 2))))
        pb, tpb, db = self.posting_bytes, self.tp_bytes, self.doc_bytes
        R = self.rect_slots
        tp_per_doc = max(self.n_toeprints / max(self.n_docs, 1), 1.0)
        if plan.algorithm == "text_first":
            n_c = min(f.df_min, mc)  # driver-list bound on survivors
            if bud.prune:
                # block-max pruned traversal: the whole driver list streams
                # at worst (block skips are modeled as zero, a safe upper
                # bound like K-SWEEP's — calibration learns the skip rate),
                # then the select stage caps candidates at mc, so hot-term
                # queries probe/fetch far fewer docs than they stream
                est = {
                    "n_probes": n_c * max(d - 1, 0),
                    "bytes_postings": f.df_min * pb + n_c * pb,
                    "bytes_spatial": n_c * R * db,
                }
            else:
                est = {
                    "n_probes": n_c * max(d - 1, 0),
                    "bytes_postings": n_c * pb + mc * pb,
                    "bytes_spatial": n_c * R * db,
                }
        elif plan.algorithm == "geo_first":
            n_cand = min(f.tp_est, mc)
            n_uniq = n_cand / tp_per_doc
            keep = n_uniq * min(f.df_min / max(self.n_docs, 1), 1.0)
            est = {
                "n_probes": n_uniq * d,
                "bytes_postings": n_uniq * logp * pb,
                "bytes_spatial": n_cand * self.tp_id_bytes + keep * R * db,
            }
        elif plan.algorithm == "k_sweep":
            # sweeps stream whole sweep_budget chunks over the Morton span
            # the footprint's blocks cover
            n_sweeps = (
                min(-(-f.tp_span // bud.sweep_budget), bud.k_sweeps)
                if f.tp_span > 0
                else 1
            )
            streamed = n_sweeps * bud.sweep_budget
            n_valid = min(f.tp_est, streamed)
            if bud.prune or bud.early_termination:
                n_valid = min(n_valid, mc)
            n_uniq = n_valid / tp_per_doc
            est = {
                "n_probes": n_uniq * d,
                "bytes_postings": n_uniq * logp * pb,
                # pruning is modeled as zero skips (a safe upper bound);
                # calibration learns the workload's actual skip rate
                "bytes_spatial": streamed * tpb,
            }
        else:
            raise ValueError(f"cost model has no estimator for {plan.algorithm!r}")
        key = plan.algorithm
        return {k: v * self.scales.get((key, k), 1.0) for k, v in est.items()}

    def truncation(self, plan: QueryPlan, f: QueryFeatures) -> float:
        """Estimated candidates a plan's budgets would *drop* for this query.

        Each algorithm is exact until a static budget truncates its
        candidate stream (TEXT-FIRST: the driver posting list vs
        ``max_candidates``; GEO-FIRST: footprint toe prints vs
        ``max_candidates``; K-SWEEP: footprint toe prints vs the total
        sweep capacity).  The planner charges dropped candidates far above
        their byte cost — recall, not traffic, is what truncation loses —
        so a plan that covers the query beats a nominally cheaper plan
        that cannot.
        """
        bud = plan.budgets
        if plan.algorithm == "text_first":
            if bud.prune:
                # pruned traversal sees the WHOLE driver list and keeps the
                # best-bound ``max_candidates`` — a score-aware cut, not a
                # blind head-of-list truncation, so no coverage charge
                return 0.0
            return max(0.0, f.df_min - bud.max_candidates)
        if plan.algorithm == "geo_first":
            return max(0.0, f.tp_est - bud.max_candidates)
        if plan.algorithm == "k_sweep":
            return max(0.0, f.tp_span - bud.k_sweeps * bud.sweep_budget)
        return 0.0

    # ------------------------------------------------------------------
    # calibration
    # ------------------------------------------------------------------
    def calibrate(self, engine, batch, plans) -> None:
        """Fit per-(algorithm, counter) scales against measured counters.

        Runs each plan once on ``batch`` through ``engine`` and sets
        ``scales[(algorithm, key)] = mean(measured) / mean(predicted)``,
        clipped to [1/16, 16].  Idempotent: predictions are re-derived from
        the unscaled closed forms each call.
        """
        terms = np.asarray(batch.terms)
        rects = np.asarray(batch.rects)
        amps = np.asarray(batch.amps)
        feats = [
            self.features(terms[b], rects[b], amps[b])
            for b in range(terms.shape[0])
        ]
        for plan in plans:
            res = engine.query(batch, plan=plan)
            for k in COST_KEYS:  # predict unscaled
                self.scales.pop((plan.algorithm, k), None)
            pred = {k: 0.0 for k in COST_KEYS}
            for f in feats:
                for k, v in self.estimate(plan, f).items():
                    pred[k] += v
            for k in COST_KEYS:
                meas = float(np.asarray(res.stats[k], np.float64).sum())
                if pred[k] > 0 and meas > 0:
                    self.scales[(plan.algorithm, k)] = float(
                        np.clip(meas / pred[k], 1.0 / _SCALE_CLIP, _SCALE_CLIP)
                    )


@dataclass
class Planner:
    """Chooses the cheapest :class:`QueryPlan` per query.

    ``candidates`` is the plan menu (one per registered algorithm by
    default; the K-SWEEP entry inherits the engine budgets' ``prune`` /
    ``fused`` configuration).  The objective weights mirror the paper's
    probe + posting-byte traffic, with a light spatial-stream tiebreaker.
    """

    model: CostModel
    candidates: tuple[QueryPlan, ...]
    w_probes: float = 1.0
    w_postings: float = 1.0
    w_spatial: float = 0.1
    # bytes charged per candidate a plan's budget would drop (recall risk:
    # dominates the traffic terms so coverage wins over nominal cheapness)
    w_truncation: float = 2048.0

    # ------------------------------------------------------------------
    @staticmethod
    def make_candidates(
        budgets: alg.QueryBudgets, fused: bool = False
    ) -> tuple[QueryPlan, ...]:
        return (
            # pruned TEXT-FIRST has a fused Pallas pipeline too
            QueryPlan("text_first", budgets, fused=fused and budgets.prune),
            QueryPlan("geo_first", budgets),
            QueryPlan("k_sweep", budgets, fused=fused),
        )

    @staticmethod
    def from_engine(engine, fused: bool = False, calibrate_with=None) -> "Planner":
        model = CostModel.from_geo_index(engine.index, engine.budgets)
        planner = Planner(
            model=model,
            candidates=Planner.make_candidates(engine.budgets, fused=fused),
        )
        if calibrate_with is not None:
            model.calibrate(engine, calibrate_with, planner.candidates)
        return planner

    # ------------------------------------------------------------------
    def cost(self, plan: QueryPlan, f: QueryFeatures) -> float:
        est = self.model.estimate(plan, f)
        return (
            self.w_probes * est["n_probes"]
            + self.w_postings * est["bytes_postings"]
            + self.w_spatial * est["bytes_spatial"]
            + self.w_truncation * self.model.truncation(plan, f)
        )

    def plan_query(self, terms, rects, amps) -> QueryPlan:
        """Cheapest plan for one (un-padded or padded) query."""
        f = self.model.features(terms, rects, amps)
        best, best_cost = None, float("inf")
        for plan in self.candidates:  # stable order breaks exact ties
            c = self.cost(plan, f)
            if c < best_cost:
                best, best_cost = plan, c
        return best

    def explain(self, terms, rects, amps) -> dict:
        """The full planning decision for one query, as plain data.

        Returns ``{"features": {...}, "candidates": {label: {algorithm,
        n_probes, bytes_postings, bytes_spatial, truncation, cost}},
        "chosen": label}`` — the planner-audit record the serving layer
        persists.  The chosen label matches :meth:`plan_query` exactly
        (same costs, same stable tie-break order).
        """
        f = self.model.features(terms, rects, amps)
        candidates: dict[str, dict] = {}
        best, best_cost = None, float("inf")
        for plan in self.candidates:
            est = self.model.estimate(plan, f)
            trunc = self.model.truncation(plan, f)
            c = (
                self.w_probes * est["n_probes"]
                + self.w_postings * est["bytes_postings"]
                + self.w_spatial * est["bytes_spatial"]
                + self.w_truncation * trunc
            )
            candidates[plan.label] = {
                "algorithm": plan.algorithm,
                **est,
                "truncation": trunc,
                "cost": c,
            }
            if c < best_cost:
                best, best_cost = plan.label, c
        return {"features": asdict(f), "candidates": candidates, "chosen": best}

    def plan_rows(self, batch: alg.QueryBatch) -> list[QueryPlan]:
        """One plan per row of a padded :class:`QueryBatch`."""
        terms = np.asarray(batch.terms)
        rects = np.asarray(batch.rects)
        amps = np.asarray(batch.amps)
        return [
            self.plan_query(terms[b], rects[b], amps[b])
            for b in range(terms.shape[0])
        ]


def coarse_cells(rects: np.ndarray, grid: int):
    """Clamped inclusive cell bounds ``(ix0, iy0, ix1, iy1)`` on a coarse
    bbox grid — deliberately WITHOUT :func:`geometry.rect_cell_bounds_np`'s
    upper-edge epsilon, so an edge exactly on a cell boundary also claims
    the next cell.  Over-coverage keeps the candidate set a superset of the
    true MBR hits (the exactness requirement); degenerate (zero-area) block
    MBRs still cover their point's cell, while inverted (padding) MBRs come
    back with ``ix1 < ix0`` and cover nothing.

    Shared machinery: the planner's ``tp_span`` candidate grid and the
    per-shard coverage summaries that drive footprint routing
    (:mod:`repro.core.distributed`) both bucket rects through this exact
    mapping, so a rect intersection can never fall between the cells of
    the two sides (monotone clamped floors on both).
    """
    g = float(grid)
    ix0 = np.clip(np.floor(rects[..., 0] * g).astype(np.int64), 0, grid - 1)
    iy0 = np.clip(np.floor(rects[..., 1] * g).astype(np.int64), 0, grid - 1)
    ix1 = np.clip(np.floor(rects[..., 2] * g).astype(np.int64), 0, grid - 1)
    iy1 = np.clip(np.floor(rects[..., 3] * g).astype(np.int64), 0, grid - 1)
    return ix0, iy0, ix1, iy1


def _block_counts(n_toeprints: int, block_size: int, blk_mbr: np.ndarray):
    """Toe prints per metadata block (tail block is short)."""
    nb = blk_mbr.shape[0]
    counts = np.full((nb,), float(block_size))
    if nb:
        counts[-1] = max(n_toeprints - (nb - 1) * block_size, 0)
    return counts


def _tile_sat(tile_starts, tile_ends, grid: int) -> np.ndarray:
    """Summed-area table of per-tile interval coverage, f64[G+1, G+1].

    ``coverage[iy, ix]`` = Σ interval lengths of tile ``iy·G + ix`` — the
    toe prints (including coalescing slack) a query touching that tile
    enumerates.  The SAT makes any cell-range sum O(1) per query rect.
    """
    starts = np.asarray(tile_starts, np.int64)  # [G*G, m]
    ends = np.asarray(tile_ends, np.int64)
    valid = starts != np.int64(INVALID)
    cover = np.where(valid, ends - starts, 0).sum(axis=1).astype(np.float64)
    sat = np.zeros((grid + 1, grid + 1))
    sat[1:, 1:] = cover.reshape(grid, grid).cumsum(axis=0).cumsum(axis=1)
    return sat

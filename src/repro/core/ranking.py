"""Ranking: F(D, q) = w_g·g(fD, fq) + w_p·pr(D) + w_t·Ftext(D, q).

Text impacts are precomputed into the index (text_index.py), so the
query-time text score is a gather+sum.  The geographic score is normalized
by the query footprint mass so that weights are comparable across queries
(paper: "with appropriate normalization of the three terms").
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class RankWeights:
    w_text: float = field(default=1.0, metadata=dict(static=True))
    w_geo: float = field(default=1.0, metadata=dict(static=True))
    w_pr: float = field(default=0.2, metadata=dict(static=True))


def combine_scores(
    weights: RankWeights,
    text_score: jax.Array,
    geo_score: jax.Array,
    pagerank: jax.Array,
    query_mass: jax.Array,
    require_geo: bool = True,
) -> jax.Array:
    """Combined relevance; −inf for documents with empty footprint overlap.

    The paper's semantics: a result must contain all keywords AND its
    footprint must intersect the query footprint (geo score > 0).

    Exactness contract for the ``require_geo`` gate: callers must pass a
    ``geo_score`` computed *directly* from interval endpoints (e.g.
    ``footprint.geo_score`` over the doc's own rect rows, where a disjoint
    rect pair contributes ``max(min(x1,qx1) - max(x0,qx0), 0) == 0.0``
    exactly) — never a value reconstructed through an associative-scan
    prefix difference.  A cumsum residue of ~1e-10 on a true-zero overlap
    would flip this gate and leak a non-overlapping doc into the top-k
    (the historical pruned-vs-unpruned equivalence leak).  All in-repo
    query paths recompute the final geo score per doc from ``doc_rects``
    (see ``algorithms.k_sweep`` step 6 and ``_sorted_dedupe``), which
    makes the ``> 0.0`` comparison exact and the gate safe without any
    epsilon.
    """
    norm = jnp.maximum(query_mass, 1e-12)
    score = (
        weights.w_text * text_score
        + weights.w_geo * geo_score / norm
        + weights.w_pr * pagerank
    )
    if require_geo:
        score = jnp.where(geo_score > 0.0, score, -jnp.inf)
    return score


def top_k(scores: jax.Array, doc_ids: jax.Array, k: int):
    """Top-k by score; ties broken by lower docID (via epsilon on id)."""
    vals, idx = jax.lax.top_k(scores, k)
    ids = jnp.take_along_axis(doc_ids, idx, axis=-1)
    ids = jnp.where(jnp.isfinite(vals), ids, -1)
    return ids, vals


def topk_recall_np(want_ids, got_ids) -> float:
    """Fraction of valid reference ids found in the candidate top-k lists.

    ``want_ids``/``got_ids`` are ``[B, k]`` id arrays with −1 padding — the
    one definition of recall@k shared by ``GeoSearchEngine.recall_at_k``
    and the benchmark acceptance gates.  Vacuously 1.0 when the reference
    has no valid ids.
    """
    want = np.asarray(want_ids)
    got = np.asarray(got_ids)
    want_valid = want >= 0
    found = (
        (want[:, :, None] == got[:, None, :])
        & want_valid[:, :, None]
        & (got[:, None, :] >= 0)
    ).any(axis=-1)
    total = int(want_valid.sum())
    return float(found.sum()) / total if total else 1.0

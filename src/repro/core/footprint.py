"""Geographic footprints: sets of amplitude-weighted rectangles.

A document footprint is an arbitrary, possibly non-contiguous area with an
amplitude (certainty) per location (paper §III.A).  Following the paper, all
algorithms approximate footprints by sets of bounding rectangles ("toe
prints"); the *precise* geographic score between a query footprint and a
document footprint is a black-box procedure — here the amplitude-weighted
intersection inner product:

    g(fD, fq) = sum_{r in fD} sum_{s in fq} area(r ∩ s) * amp(r) * amp(s)

normalized by the query footprint's own mass so scores are comparable across
queries.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import geometry


@dataclass(frozen=True)
class FootprintBatch:
    """A batch of footprints as padded rect sets.

    rects: f32[..., R, 4]   (padding rows encoded as empty rects)
    amps:  f32[..., R]      (padding rows have amp 0)
    """

    rects: jax.Array
    amps: jax.Array

    @property
    def max_rects(self) -> int:
        return self.rects.shape[-2]


def make_footprint_np(
    rects: np.ndarray, amps: np.ndarray, max_rects: int
) -> tuple[np.ndarray, np.ndarray]:
    """Pad a single footprint's (n,4)/(n,) arrays to (max_rects, …)."""
    n = min(len(rects), max_rects)
    out_r = np.tile(geometry.EMPTY_RECT, (max_rects, 1)).astype(np.float32)
    out_a = np.zeros((max_rects,), dtype=np.float32)
    out_r[:n] = rects[:n]
    out_a[:n] = amps[:n]
    return out_r, out_a


def geo_score(
    doc_rects: jax.Array,
    doc_amps: jax.Array,
    query_rects: jax.Array,
    query_amps: jax.Array,
) -> jax.Array:
    """Amplitude-weighted intersection score.

    doc_rects:   f32[..., R, 4]
    doc_amps:    f32[..., R]
    query_rects: f32[Q, 4]
    query_amps:  f32[Q]
    returns      f32[...]
    """
    inter = geometry.rect_intersection_area(
        doc_rects[..., :, None, :].astype(jnp.float32),
        query_rects[None, :, :].astype(jnp.float32),
    )  # [..., R, Q]
    w = doc_amps[..., :, None].astype(jnp.float32) * query_amps[None, :].astype(
        jnp.float32
    )
    return jnp.sum(inter * w, axis=(-1, -2))


def geo_score_upper_bound(
    doc_mbr: jax.Array,
    doc_mass: jax.Array,
    query_rects: jax.Array,
    query_amps: jax.Array,
) -> jax.Array:
    """Cheap upper bound on ``geo_score`` from the footprint MBR only.

    Used by the lossy-footprint early-termination path (paper future work):
    score <= min(area(mbr ∩ q), mass_D) * amp_q summed over query rects,
    where ``doc_mass = Σ_r area(r)·amp(r)`` is precomputed.

    doc_mbr:  f32[..., 4]
    doc_mass: f32[...]
    """
    inter = geometry.rect_intersection_area(
        doc_mbr[..., None, :], query_rects[None, :, :]
    )  # [..., Q]
    bound = jnp.minimum(inter, doc_mass[..., None]) * query_amps[None, :]
    return jnp.sum(bound, axis=-1)


def query_mass(query_rects: jax.Array, query_amps: jax.Array) -> jax.Array:
    """Σ area·amp of the query footprint (normalizer)."""
    return jnp.sum(geometry.rect_area(query_rects) * query_amps, axis=-1)


def footprint_mbr_np(rects: np.ndarray) -> np.ndarray:
    """MBR over the non-empty rects of ``rects (R,4)``."""
    valid = rects[:, 2] > rects[:, 0]
    if not valid.any():
        return geometry.EMPTY_RECT.copy()
    r = rects[valid]
    return np.array(
        [r[:, 0].min(), r[:, 1].min(), r[:, 2].max(), r[:, 3].max()],
        dtype=np.float32,
    )

"""The paper's three query-processing algorithms, batched & jit-safe.

All three share the signature::

    (text_index, spatial_index, pagerank, query, budgets, weights)
        -> TopKResult(ids [B,k], scores [B,k], stats {str: [B] or scalar})

`stats` counts the observable the paper optimizes — bytes moved per pipeline
stage (disk traffic in 2010 = HBM traffic here) — so benchmarks can report
both wall time and modeled I/O.

Algorithms (paper §IV):

* TEXT-FIRST  — inverted index first, then fetch footprints by docID.
* GEO-FIRST   — spatial structure first (tile grid standing in for the
                memory-resident R*-tree), then filter by text, then fetch.
* K-SWEEP     — tile intervals → ≤ k coalesced sweeps → bulk contiguous
                fetch → docID translation → text filter → precise scoring.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import footprint as fp
from repro.core import ranking, spatial_index as sidx, text_index as tidx
from repro.core.spatial_index import INVALID

# UNCOMPRESSED reference record sizes.  The live byte stats below use the
# per-index properties instead (SpatialIndex.tp_bytes / doc_bytes,
# TextIndex.posting_bytes), which report the *stored* — possibly
# compressed — sizes; these constants remain the fixed uncompressed
# baseline for compression-ratio reporting.
TP_BYTES = 4 * 4 + 4 + 4  # rect + amp + docid per toe print
POSTING_BYTES = 4 + 4  # docid + impact

# ---------------------------------------------------------------------------
# algorithm registry
# ---------------------------------------------------------------------------
# One uniform dispatch surface instead of ad-hoc string→fn maps scattered
# through the engine / distributed / executor layers.  Every registered fn
# shares the module-docstring signature; callers resolve by name via
# ``get_algorithm`` (which raises with the valid menu on a typo) and the
# planner enumerates ``ALGORITHMS`` to build its candidate plans.

ALGORITHMS: dict[str, "object"] = {}


def register_algorithm(name: str):
    """Class-of-service decorator: add a query algorithm to the registry."""

    def deco(fn):
        ALGORITHMS[name] = fn
        return fn

    return deco


def get_algorithm(name: str):
    """Resolve a registered algorithm by name (clear error on a typo)."""
    try:
        return ALGORITHMS[name]
    except KeyError:
        raise ValueError(
            f"unknown algorithm {name!r}; registered: {sorted(ALGORITHMS)} "
            "(plus 'auto' at the engine/serving layer, which routes through "
            "the cost-based planner)"
        ) from None


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class QueryBudgets:
    """Static shape budgets (early-termination style approximations)."""

    max_candidates: int = field(default=1024, metadata=dict(static=True))
    max_tiles: int = field(default=64, metadata=dict(static=True))
    k_sweeps: int = field(default=4, metadata=dict(static=True))
    sweep_budget: int = field(default=2048, metadata=dict(static=True))
    top_k: int = field(default=10, metadata=dict(static=True))
    # geo-score early termination in K-SWEEP (paper future work; lossy —
    # keeps only the max_candidates strongest toe prints before text probing,
    # but only AFTER paying the full stream + score cost)
    early_termination: bool = field(default=False, metadata=dict(static=True))
    # block-max pruned K-SWEEP: skip whole sweep blocks whose precomputed
    # upper bound (SpatialIndex blk_* columns) cannot beat the running
    # partial top-max_candidates threshold θ — the candidates never get
    # scored, probed, or sorted, and bytes_spatial counts only the blocks
    # actually streamed.  Subsumes early_termination (the top-C cut is part
    # of the pruned select stage).
    prune: bool = field(default=False, metadata=dict(static=True))
    # pruned select stage: additionally drop candidates whose partial geo
    # score is ≤ prune_eps × query_mass (their normalized geo contribution
    # is below prune_eps).  0 keeps every positive candidate — lossless for
    # the final top-k whenever max_candidates covers the survivors.
    prune_eps: float = field(default=0.0, metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class QueryBatch:
    """A batch of geo queries (fixed shapes).

    terms:  i32[B, d]   (−1 padded)
    rects:  f32[B, Qr, 4] query footprint rectangles (empty-rect padded)
    amps:   f32[B, Qr]
    """

    terms: jax.Array
    rects: jax.Array
    amps: jax.Array

    @property
    def batch(self) -> int:
        return self.terms.shape[0]


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class TopKResult:
    ids: jax.Array  # i32[B, k], −1 padded
    scores: jax.Array  # f32[B, k]
    stats: dict[str, jax.Array]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _geo_score_docs(spatial, doc_ids, valid, q_rects, q_amps, geo_scorer):
    """Gather doc-major footprints and score them against the query."""
    safe = jnp.where(valid, doc_ids, 0)
    rects = spatial.doc_rects[safe]  # [C, R, 4]
    amps = jnp.where(valid[:, None], spatial.doc_amps[safe], 0.0)
    g = geo_scorer(rects, amps, q_rects, q_amps)
    return jnp.where(valid, g, 0.0)


def _default_doc_scorer(rects, amps, q_rects, q_amps):
    return fp.geo_score(rects, amps, q_rects, q_amps)


def _count_unique(ids: jax.Array, valid: jax.Array) -> jax.Array:
    """Number of distinct ids among the valid positions (fixed shape)."""
    big = jnp.int32(2**31 - 1)
    s = jnp.sort(jnp.where(valid, ids, big))
    nxt = jnp.concatenate([s[1:], jnp.full((1,), -2, jnp.int32)])
    return jnp.sum(((s != nxt) & (s != big)).astype(jnp.int32))


def _sorted_dedupe(ids: jax.Array, valid: jax.Array):
    """Sort ids (invalid → +inf sentinel) and mark the last element of each
    run — a fixed-shape dedupe.

    Deliberately cumsum-free: the old run-sum helper accumulated per-doc
    values through an associative-scan prefix *difference* (``cs - before``),
    whose rounding residue (~1e-10) could leak into docs whose exact total
    was 0 — the documented ``require_geo`` leak.  Both callers only ever
    needed the dedupe, and the final geo score is recomputed exactly from
    each doc's own footprint rows (see step 6 of ``k_sweep``), so no
    prefix-sum ever touches a score that feeds ``require_geo``.

    Returns (sorted_ids, last_of_run & valid).
    """
    big = jnp.int32(2**31 - 1)
    ids_s = jnp.sort(jnp.where(valid, ids, big))
    nxt = jnp.concatenate([ids_s[1:], jnp.full((1,), -2, jnp.int32)])
    last = (ids_s != nxt) & (ids_s != big)
    return ids_s, last


# ---------------------------------------------------------------------------
# TEXT-FIRST (paper §IV.A)
# ---------------------------------------------------------------------------

@register_algorithm("text_first")
def text_first(
    text: tidx.TextIndex,
    spatial: sidx.SpatialIndex,
    pagerank: jax.Array,
    query: QueryBatch,
    budgets: QueryBudgets,
    weights: ranking.RankWeights = ranking.RankWeights(),
    geo_scorer=_default_doc_scorer,
    fused: bool = False,  # Pallas fused probe+score+select (kernels/text_probe)
) -> TopKResult:
    """TEXT-FIRST: drive the intersection with the shortest posting list,
    probe the other terms, fetch footprints for the survivors.

    ``budgets.prune`` switches the driver traversal to the block-max
    pruned probe → score → select pipeline (the text-side twin of the
    pruned K-SWEEP): each 128-posting driver block's upper bound
    ``w_text · blk_max_impact + rest_ub`` (rest = other terms' max
    impacts + geo + pagerank bounds) is tested against a running partial
    top-``max_candidates`` threshold θ, and blocks that cannot beat it
    are skipped before their bytes stream.  ``fused=True`` runs it as one
    Pallas kernel (``kernels/text_probe``) with per-block DMA elision;
    otherwise the bit-matching pure-jnp oracle is used.  The unpruned
    path is kept bit-identical as the correctness reference, with
    ``bytes_postings`` counting only the blocks actually streamed and
    ``text_blocks_skipped`` / ``text_blocks_total`` / ``probes_saved``
    reporting the pruning yield.
    """
    if budgets.prune:
        return _text_first_pruned(
            text, spatial, pagerank, query, budgets, weights, geo_scorer, fused
        )
    R = spatial.doc_rects.shape[1]

    def one(terms, q_rects, q_amps):
        cand, valid, tscore = tidx.conjunction_candidates(
            text, terms, budgets.max_candidates
        )
        g = _geo_score_docs(spatial, cand, valid, q_rects, q_amps, geo_scorer)
        qm = fp.query_mass(q_rects, q_amps)
        score = ranking.combine_scores(
            weights, tscore, g, pagerank[jnp.where(valid, cand, 0)], qm
        )
        score = jnp.where(valid, score, -jnp.inf)
        ids, vals = ranking.top_k(score, cand, budgets.top_k)
        n_c = jnp.sum(valid.astype(jnp.int32))
        n_terms_real = jnp.sum((terms >= 0).astype(jnp.int32))
        # disk/HBM access model: candidate footprints live in the docID-
        # sorted file; nearby candidates coalesce into one run, gaps seek
        # (paper SIV.A "reasonable disk access policy").
        cand_sorted = jnp.sort(jnp.where(valid, cand, jnp.int32(2**31 - 1)))
        gap = cand_sorted[1:] - cand_sorted[:-1]
        new_run = (gap > 64) & (cand_sorted[1:] != jnp.int32(2**31 - 1))
        fetch_runs = jnp.sum(new_run.astype(jnp.int32)) + (n_c > 0).astype(jnp.int32)
        # stored (possibly compressed) record sizes — static per index
        pb = text.posting_bytes
        db = spatial.doc_bytes
        stats = {
            "candidates": n_c,
            # footprints fetched for every textual candidate (doc-major file)
            "bytes_spatial": n_c * jnp.float32(R * db),
            "bytes_postings": n_c * jnp.float32(pb)
            + jnp.float32(budgets.max_candidates * pb),
            "fetch_runs": fetch_runs,
            "seeks": fetch_runs + n_terms_real,  # + one seek per posting list
            "n_probes": n_c * jnp.maximum(n_terms_real - 1, 0),
            # unpruned baseline: the full max_candidates driver window
            # streams, nothing is skipped (pruned-path counterparts)
            "text_blocks_total": jnp.full(
                (),
                -(-budgets.max_candidates // tidx.POSTING_BLOCK),
                jnp.int32,
            ),
            "text_blocks_skipped": jnp.int32(0),
            "probes_saved": jnp.int32(0),
            "bytes_seq": jnp.full((), budgets.max_candidates * pb, jnp.float32),
            "bytes_random": n_c * jnp.float32(R * db)
            + n_c * jnp.maximum(n_terms_real - 1, 0) * 32,
        }
        return ids, vals, stats

    ids, vals, stats = jax.vmap(one)(query.terms, query.rects, query.amps)
    return TopKResult(ids, vals, stats)


def _text_first_pruned(
    text: tidx.TextIndex,
    spatial: sidx.SpatialIndex,
    pagerank: jax.Array,
    query: QueryBatch,
    budgets: QueryBudgets,
    weights: ranking.RankWeights,
    geo_scorer,
    fused: bool,
) -> TopKResult:
    """Block-max pruned TEXT-FIRST (see ``text_first``'s docstring).

    Walks the *whole* driver posting list in 128-posting blocks (not just
    the first ``max_candidates`` postings), skipping blocks whose
    optimistic bound cannot beat the running top-C threshold, then selects
    the top-``max_candidates`` streamed postings by optimistic score —
    so hot-term queries both move fewer bytes and keep better candidates
    than the unpruned head-of-list truncation.
    """
    from repro.kernels.text_probe.ops import impact_planes, window_size

    if fused:
        from repro.kernels.text_probe.ops import text_probe_pruned as _pr
    else:
        from repro.kernels.text_probe.ref import text_probe_pruned_ref as _pr

    R = spatial.doc_rects.shape[1]
    NB = text.blk_pos.shape[0]
    P = text.n_postings
    mtb = text.max_term_blocks
    n_win = window_size(mtb)
    Cs = min(budgets.max_candidates, n_win * tidx.POSTING_BLOCK)
    # query-independent inputs, hoisted out of the per-query vmap: the
    # block-major impact plane and the geo/pagerank remainder bounds.
    # geo: combine_scores adds w_geo·g/max(qm, ε) with g ≤ qm·Σ_r amp_r
    # (area(r ∩ q_s) ≤ area(q_s)), so the normalized term is ≤ w_geo·Σ amps.
    plane = impact_planes(text.impacts, text.blk_pos, text.blk_len)
    amp_sum_max = jnp.max(
        jnp.sum(spatial.doc_amps.astype(jnp.float32), axis=-1), initial=0.0
    )
    const_ub = weights.w_geo * amp_sum_max + weights.w_pr * jnp.max(
        pagerank.astype(jnp.float32), initial=0.0
    )
    w_text = jnp.float32(weights.w_text)

    def one(terms, q_rects, q_amps):
        d = terms.shape[0]
        safe_terms = jnp.maximum(terms, 0)
        tlens = text.offsets[safe_terms + 1] - text.offsets[safe_terms]
        tlens = jnp.where(terms >= 0, tlens, jnp.int32(2**31 - 1))
        driver = jnp.argmin(tlens).astype(jnp.int32)
        t0 = safe_terms[driver]
        any_real = terms[0] >= 0
        # per-term max impact from the block metadata: bounds what the
        # non-driver terms can add to any candidate's text score
        tb0 = text.blk_term_off[safe_terms]
        tnb = text.blk_term_off[safe_terms + 1] - tb0
        wi = jnp.arange(n_win, dtype=jnp.int32)
        bidx = jnp.clip(tb0[:, None] + wi[None, :], 0, NB - 1)
        tmax = jnp.max(
            jnp.where(
                wi[None, :] < tnb[:, None], text.blk_max_impact[bidx], 0.0
            ),
            axis=1,
        )
        others = (terms >= 0) & (jnp.arange(d, dtype=jnp.int32) != driver)
        rest_ub = w_text * jnp.sum(jnp.where(others, tmax, 0.0)) + const_ub
        b0 = text.blk_term_off[t0]
        nb = jnp.where(any_real, text.blk_term_off[t0 + 1] - b0, 0)
        # select floor: prune_eps × the best possible optimistic score —
        # candidates below it are dropped by the select stage, so the θ
        # buffer may be seeded with it (skipping provably unselectable
        # blocks even before C candidates have streamed)
        floor = jnp.maximum(
            jnp.float32(budgets.prune_eps) * (w_text * tmax[driver] + rest_ub),
            0.0,
        )
        opt, valid, streamed, blocks_scored, blocks_active = _pr(
            plane,
            text.blk_max_impact,
            text.blk_len,
            b0,
            nb,
            w_text,
            rest_ub,
            floor,
            max_candidates=budgets.max_candidates,
            max_term_blocks=mtb,
            # impact layout: blk_max_impact is a per-term suffix-max
            # envelope (monotone non-increasing), so the traversal may
            # early-exit the driver at its first failing bound
            monotone=text.layout == "impact",
        )
        # select: partial top-C cut by optimistic score over the streamed
        # survivors (the pruned twin of the unpruned head-of-list cap)
        kept = valid & streamed
        val, sel = jax.lax.top_k(jnp.where(kept, opt, -1.0), Cs)
        ok_c = kept[sel] & (val > floor)
        # translate selected lattice positions → doc ids + driver impacts;
        # only the selected candidates' blocks are decoded
        w_sel = sel // tidx.POSTING_BLOCK
        lane = sel % tidx.POSTING_BLOCK
        gb = jnp.clip(b0 + w_sel, 0, NB - 1)
        apos = jnp.clip(text.blk_pos[gb] + lane, 0, max(P - 1, 0))
        if text.is_compressed:
            dec = tidx.decode_posting_blocks(text, gb)  # [Cs, 128]
            cand = jnp.take_along_axis(dec, lane[:, None], axis=1)[:, 0]
        else:
            cand = text.postings[apos]
        cand = jnp.where(ok_c, cand, jnp.int32(2**31 - 1))
        imp_d = jnp.where(ok_c, text.impacts[apos].astype(jnp.float32), 0.0)

        def probe_one(i, carry):
            valid_c, score = carry
            t = terms[i]
            is_real = (t >= 0) & (i != driver)
            member, imp = tidx.probe_term(text, jnp.maximum(t, 0), cand)
            valid_c = valid_c & (member | ~is_real)
            score = score + jnp.where(is_real, imp, 0.0)
            return valid_c, score

        valid_c, tscore = jax.lax.fori_loop(0, d, probe_one, (ok_c, imp_d))
        cand = jnp.where(valid_c, cand, jnp.int32(2**31 - 1))
        tscore = jnp.where(valid_c, tscore, 0.0)
        g = _geo_score_docs(spatial, cand, valid_c, q_rects, q_amps, geo_scorer)
        qm = fp.query_mass(q_rects, q_amps)
        score = ranking.combine_scores(
            weights, tscore, g, pagerank[jnp.where(valid_c, cand, 0)], qm
        )
        score = jnp.where(valid_c, score, -jnp.inf)
        ids, vals = ranking.top_k(score, cand, budgets.top_k)
        n_sel = jnp.sum(ok_c.astype(jnp.int32))  # candidates probed
        n_c = jnp.sum(valid_c.astype(jnp.int32))  # intersection survivors
        streamed_valid = jnp.sum((valid & streamed).astype(jnp.int32))
        n_terms_real = jnp.sum((terms >= 0).astype(jnp.int32))
        probes_per = jnp.maximum(n_terms_real - 1, 0)
        cand_sorted = jnp.sort(jnp.where(valid_c, cand, jnp.int32(2**31 - 1)))
        gap = cand_sorted[1:] - cand_sorted[:-1]
        new_run = (gap > 64) & (cand_sorted[1:] != jnp.int32(2**31 - 1))
        fetch_runs = jnp.sum(new_run.astype(jnp.int32)) + (n_c > 0).astype(
            jnp.int32
        )
        # stored (possibly compressed) record sizes — static per index
        pb = text.posting_bytes
        db = spatial.doc_bytes
        stats = {
            "candidates": n_c,
            "bytes_spatial": n_c * jnp.float32(R * db),
            # ONLY the streamed driver blocks count (skipped blocks move
            # zero bytes), plus the selected candidates' random reads
            "bytes_postings": streamed_valid * jnp.float32(pb)
            + n_sel * jnp.float32(pb),
            "fetch_runs": fetch_runs,
            "seeks": fetch_runs + n_terms_real,
            "n_probes": n_c * probes_per,
            "text_blocks_total": blocks_active,
            "text_blocks_skipped": blocks_active - blocks_scored,
            # probes avoided by the select stage vs. probing every
            # streamed driver posting
            "probes_saved": jnp.maximum(streamed_valid - n_sel, 0)
            * probes_per,
            "bytes_seq": streamed_valid * jnp.float32(pb),
            "bytes_random": n_c * jnp.float32(R * db)
            + n_c * probes_per * 32
            + n_sel * jnp.float32(pb),
        }
        return ids, vals, stats

    ids, vals, stats = jax.vmap(one)(query.terms, query.rects, query.amps)
    return TopKResult(ids, vals, stats)


# ---------------------------------------------------------------------------
# GEO-FIRST (paper §IV.B)
# ---------------------------------------------------------------------------

@register_algorithm("geo_first")
def geo_first(
    text: tidx.TextIndex,
    spatial: sidx.SpatialIndex,
    pagerank: jax.Array,
    query: QueryBatch,
    budgets: QueryBudgets,
    weights: ranking.RankWeights = ranking.RankWeights(),
    geo_scorer=_default_doc_scorer,
) -> TopKResult:
    R = spatial.doc_rects.shape[1]

    def one(terms, q_rects, q_amps):
        tp_ids, ok = sidx.tile_candidate_toeprints(
            spatial, q_rects, budgets.max_tiles, budgets.max_candidates
        )
        # translate toe prints → doc ids (random access into the id column of
        # the toe-print store; the MBR table of the "R*-tree" is memory
        # resident so we charge only the id translation)
        docs = jnp.where(
            ok, spatial.tp_doc_ids[tp_ids].astype(jnp.int32), jnp.int32(2**31 - 1)
        )
        # dedupe docs (multiple toe prints per doc)
        docs_s, last = _sorted_dedupe(docs, ok)
        dvalid = last
        docs_u = jnp.where(dvalid, docs_s, 0)
        # text filter via binary probes
        match, tscore = tidx.text_score_of_docs(text, terms, docs_u)
        keep = dvalid & match
        # fetch footprints for survivors only (doc-major file)
        g = _geo_score_docs(spatial, docs_u, keep, q_rects, q_amps, geo_scorer)
        qm = fp.query_mass(q_rects, q_amps)
        score = ranking.combine_scores(
            weights, tscore, g, pagerank[jnp.where(keep, docs_u, 0)], qm
        )
        score = jnp.where(keep, score, -jnp.inf)
        ids, vals = ranking.top_k(score, docs_u, budgets.top_k)
        n_cand = jnp.sum(ok.astype(jnp.int32))
        n_uniq = jnp.sum(dvalid.astype(jnp.int32))
        n_keep = jnp.sum(keep.astype(jnp.int32))
        n_terms_real = jnp.sum((terms >= 0).astype(jnp.int32))
        # stored (possibly compressed) record sizes — static per index
        pb = text.posting_bytes
        db = spatial.doc_bytes
        idb = spatial.tp_doc_ids.dtype.itemsize
        stats = {
            "candidates": n_cand,
            "bytes_spatial": n_cand * jnp.float32(idb)  # id translation
            + n_keep * jnp.float32(R * db),  # survivor footprints
            "bytes_postings": n_uniq
            * jnp.ceil(jnp.log2(jnp.maximum(text.n_postings, 2)))
            * jnp.float32(pb),
            # every candidate toe print is fetched INDIVIDUALLY (R*-tree
            # random access), every surviving footprint likewise
            "seeks": n_cand + n_keep,
            "n_probes": n_uniq * n_terms_real,
            "bytes_seq": jnp.float32(0),
            "bytes_random": n_cand * jnp.float32(idb)
            + n_keep * jnp.float32(R * db)
            + n_uniq * n_terms_real * 32,
        }
        return ids, vals, stats

    ids, vals, stats = jax.vmap(one)(query.terms, query.rects, query.amps)
    return TopKResult(ids, vals, stats)


# ---------------------------------------------------------------------------
# K-SWEEP (paper §IV.C — the main algorithm)
# ---------------------------------------------------------------------------

@register_algorithm("k_sweep")
def k_sweep(
    text: tidx.TextIndex,
    spatial: sidx.SpatialIndex,
    pagerank: jax.Array,
    query: QueryBatch,
    budgets: QueryBudgets,
    weights: ranking.RankWeights = ranking.RankWeights(),
    tp_scorer=None,
    fused: bool = False,  # Pallas fused fetch+score (kernels/sweep_score)
) -> TopKResult:
    """K-SWEEP: (1) tile intervals → (2) ≤k sweeps → (3) bulk fetch →
    (4) docID translation + sort → (5) text filter → (6) geo scores → top-k.

    ``tp_scorer(rects [T,4], amps [T], q_rects [Q,4], q_amps [Q]) -> [T]``
    computes per-toe-print partial geo scores; defaults to the pure-jnp
    reference, swappable for the Pallas kernel (kernels/geo_score).

    ``budgets.prune`` switches stage (3+6a) to the block-max pruned
    sweep → score → select pipeline: per-block upper bounds from the
    ``SpatialIndex`` blk_* columns are tested against a running partial
    top-``max_candidates`` threshold θ and whole blocks that cannot beat it
    are skipped before scoring — only the surviving candidates reach the
    sort, the inverted-index probes, and the text filter.  ``fused=True``
    runs it as one Pallas kernel (``kernels/sweep_score``); otherwise the
    bit-matching pure-jnp oracle is used (``tp_scorer`` is ignored on the
    pruned path — the scorer is baked into the select pipeline).  The
    unpruned path is kept bit-identical as the correctness reference.

    Stats report streamed vs. scored traffic separately: ``bytes_spatial``
    counts bytes actually streamed from the store (whole sweeps, or only
    unskipped blocks when pruning), ``bytes_scored`` the toe prints that
    survive to candidate aggregation, plus ``blocks_skipped`` /
    ``blocks_total`` (metadata-block units) and ``probes_saved`` (index
    probes avoided vs. probing every fetched candidate).
    """
    if tp_scorer is None:
        tp_scorer = _default_tp_scorer

    def one(terms, q_rects, q_amps):
        # (1) intervals of all intersecting tiles
        starts, ends = sidx.gather_query_intervals(spatial, q_rects, budgets.max_tiles)
        # (2) coalesce into ≤ k sweeps, re-chunked to the fetch budget
        s_starts, s_ends = sidx.coalesce_k_sweeps(starts, ends, budgets.k_sweeps)
        s_starts, s_ends = sidx.split_sweeps_to_budget(
            s_starts, s_ends, budgets.k_sweeps, budgets.sweep_budget
        )
        n_sweeps = jnp.sum((s_starts != INVALID).astype(jnp.int32))
        total = budgets.k_sweeps * budgets.sweep_budget
        Cmax = min(budgets.max_candidates, total)
        bs = spatial.block_size
        if budgets.prune:
            # (3+6a+5a) PRUNED: block-max upper-bound test + adaptive θ
            # feedback skip whole blocks before they are scored; the fused
            # variant runs in-kernel (kernels/sweep_score), the other one
            # through the bit-matching jnp oracle.  The θ buffer is seeded
            # with the select stage's own score floor, so a skipped block
            # provably holds no candidate the selection would keep.
            if fused:
                from repro.kernels.sweep_score.ops import sweep_score_pruned as _pr
            else:
                from repro.kernels.sweep_score.ref import (
                    sweep_score_pruned_ref as _pr,
                )
            floor = jnp.maximum(
                jnp.float32(budgets.prune_eps) * fp.query_mass(q_rects, q_amps), 0.0
            )
            part2d, ok2d, st2d, blocks_scored, blocks_active = _pr(
                spatial.tp_rects,
                spatial.tp_amps,
                spatial.blk_mbr,
                spatial.blk_max_amp,
                spatial.blk_max_mass,
                s_starts,
                s_ends,
                q_rects,
                q_amps,
                budgets.sweep_budget,
                budgets.max_candidates,
                bs,
                floor,
                tp_amp_scale=(
                    spatial.tp_amp_scale if spatial.tp_amp_scale.shape[0] else None
                ),
            )
            part = part2d.reshape(-1)
            ok = ok2d.reshape(-1)
            kept = ok & st2d.reshape(-1)
            docs = sidx.fetch_sweep_ids(spatial, s_starts, s_ends, budgets.sweep_budget)
            # select: partial top-C cut over the pruned survivors, plus the
            # relative floor prune_eps × query_mass (a candidate below it
            # contributes < prune_eps to the normalized geo score)
            val, sel = jax.lax.top_k(jnp.where(kept, part, -1.0), Cmax)
            docs_c = docs[sel]
            ok_c = kept[sel] & (val > floor)
            streamed_tp = jnp.sum(st2d.astype(jnp.int32))
            blocks_total = blocks_active
            blocks_skipped = blocks_active - blocks_scored
        else:
            if fused:
                # (3+6a) FUSED: the Pallas kernel streams each sweep through
                # VMEM and scores it in-register (kernels/sweep_score); only
                # the i32 doc-id column is fetched separately.
                from repro.kernels.sweep_score.ops import sweep_score as _fused

                part2d, ok2d = _fused(
                    spatial.tp_rects,
                    spatial.tp_amps,
                    s_starts,
                    s_ends,
                    q_rects,
                    q_amps,
                    budgets.sweep_budget,
                    tp_amp_scale=(
                        spatial.tp_amp_scale
                        if spatial.tp_amp_scale.shape[0]
                        else None
                    ),
                )
                part = part2d.reshape(-1)
                ok = ok2d.reshape(-1)
                docs = sidx.fetch_sweep_ids(
                    spatial, s_starts, s_ends, budgets.sweep_budget
                )
            else:
                # (3) bulk contiguous fetch (k dynamic-slice streams)
                rects, amps, docs, ok = sidx.fetch_sweeps(
                    spatial, s_starts, s_ends, budgets.sweep_budget
                )
                # (6a) per-toe-print partial geo scores (the FLOP hot spot)
                part = tp_scorer(rects, jnp.where(ok, amps, 0.0), q_rects, q_amps)
            # (5a) geo-score early termination (paper §Conclusions future
            # work): keep only the strongest max_candidates toe prints
            # before the expensive sort + inverted-index probing.  Lossy,
            # and the full stream + score cost has already been paid —
            # the pruned path above avoids it up front.
            if budgets.early_termination and Cmax < total:
                val, sel = jax.lax.top_k(jnp.where(ok, part, -1.0), Cmax)
                docs_c = docs[sel]
                ok_c = ok[sel] & (val > 0)
            else:
                docs_c, ok_c = docs, ok
            streamed_tp = n_sweeps * budgets.sweep_budget
            blocks_total = n_sweeps * ((budgets.sweep_budget + bs - 1) // bs)
            blocks_skipped = jnp.int32(0)
        # (4) translate to docIDs, sort, dedupe per doc (the partial scores
        # drove selection; they are not the final geo score)
        docs_s, last = _sorted_dedupe(docs_c, ok_c)
        dvalid = last
        docs_u = jnp.where(dvalid, docs_s, 0)
        # (5) filter through the inverted index.  Under pruning the
        # counted variant reports the probes a short-circuiting evaluator
        # issues (earlier terms' misses spare later terms' probes) —
        # same match/score math, outputs bit-identical.
        if budgets.prune:
            match, tscore, text_probes = tidx.text_score_of_docs_counted(
                text, terms, docs_u, dvalid
            )
        else:
            match, tscore = tidx.text_score_of_docs(text, terms, docs_u)
            text_probes = None
        keep = dvalid & match
        # (6) final geo score from each survivor's own footprint slots —
        # the same doc-major scorer as geo_first/oracle, summed in the
        # doc's canonical slot order.  Scoring from doc_rects rows (not
        # the sweep stream's run sums) keeps per-doc scores bit-identical
        # across shard layouts: the stream order, coalescing slack, and
        # cumsum prefix all depend on the partitioning, a doc's own rect
        # row does not (the footprint-routing equivalence gate).
        g_tot = _geo_score_docs(
            spatial, docs_u, keep, q_rects, q_amps, _default_doc_scorer
        )
        qm = fp.query_mass(q_rects, q_amps)
        score = ranking.combine_scores(
            weights, tscore, g_tot, pagerank[jnp.where(keep, docs_u, 0)], qm
        )
        score = jnp.where(keep, score, -jnp.inf)
        ids, vals = ranking.top_k(score, docs_u, budgets.top_k)
        fetched = jnp.sum(ok.astype(jnp.int32))
        n_selected = jnp.sum(ok_c.astype(jnp.int32))
        n_uniq = jnp.sum(dvalid.astype(jnp.int32))
        n_terms_real = jnp.sum((terms >= 0).astype(jnp.int32))
        if budgets.prune or budgets.early_termination:
            # probes the select stage avoided vs. probing every fetched doc
            probes_saved = (_count_unique(docs, ok) - n_uniq) * n_terms_real
        else:
            probes_saved = jnp.int32(0)
        # stored (possibly compressed) record sizes — static per index
        tpb = spatial.tp_bytes
        pb = text.posting_bytes
        stats = {
            "candidates": fetched,
            "sweeps": n_sweeps,
            # bytes actually streamed: ≤k contiguous streams, minus any
            # block-max-skipped blocks on the pruned path
            "bytes_spatial": streamed_tp * jnp.float32(tpb),
            "sweep_slack": n_sweeps * budgets.sweep_budget - fetched,
            # toe prints surviving to candidate aggregation (≠ streamed
            # when early termination or pruning drops candidates)
            "bytes_scored": n_selected * jnp.float32(tpb),
            "blocks_total": blocks_total,
            "blocks_skipped": blocks_skipped,
            "probes_saved": probes_saved,
            "bytes_postings": n_uniq
            * jnp.ceil(jnp.log2(jnp.maximum(text.n_postings, 2)))
            * jnp.float32(pb),
            "seeks": n_sweeps + n_terms_real,
            # honest short-circuit count when the pruned text filter ran
            "n_probes": (
                text_probes if text_probes is not None else n_uniq * n_terms_real
            ),
            "bytes_seq": streamed_tp * jnp.float32(tpb),
            "bytes_random": n_uniq * n_terms_real * 32,
        }
        return ids, vals, stats

    ids, vals, stats = jax.vmap(one)(query.terms, query.rects, query.amps)
    return TopKResult(ids, vals, stats)


def _default_tp_scorer(rects, amps, q_rects, q_amps):
    """Pure-jnp per-toe-print scorer: Σ_q area(tp ∩ q)·amp_tp·amp_q.
    Casts to f32 so it accepts lossy-compressed (f16) toe-print stores."""
    from repro.core import geometry

    inter = geometry.rect_intersection_area(
        rects[:, None, :].astype(jnp.float32), q_rects[None, :, :].astype(jnp.float32)
    )
    return jnp.sum(
        inter * amps[:, None].astype(jnp.float32) * q_amps[None, :].astype(jnp.float32),
        axis=-1,
    )


# ---------------------------------------------------------------------------
# Exact oracle (dense scan) — for recall evaluation in tests/benchmarks
# ---------------------------------------------------------------------------

def oracle(
    text: tidx.TextIndex,
    spatial: sidx.SpatialIndex,
    pagerank: jax.Array,
    query: QueryBatch,
    k: int,
    weights: ranking.RankWeights = ranking.RankWeights(),
) -> TopKResult:
    """Exact top-k by scoring *every* document (no budgets).  O(N) per query."""
    N = spatial.n_docs
    all_docs = jnp.arange(N, dtype=jnp.int32)

    def one(terms, q_rects, q_amps):
        match, tscore = tidx.text_score_of_docs(text, terms, all_docs)
        g = fp.geo_score(spatial.doc_rects, spatial.doc_amps, q_rects, q_amps)
        qm = fp.query_mass(q_rects, q_amps)
        score = ranking.combine_scores(weights, tscore, g, pagerank, qm)
        score = jnp.where(match, score, -jnp.inf)
        return ranking.top_k(score, all_docs, k)

    ids, vals = jax.vmap(one)(query.terms, query.rects, query.amps)
    return TopKResult(ids, vals, {})

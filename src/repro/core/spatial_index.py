"""Spatial index: Morton-ordered toe-print store + tile→interval grid.

This is the paper's K-SWEEP substrate (§IV.C), adapted to HBM:

* Every footprint rectangle of every document is a *toe print*.  Toe prints
  are sorted by the Morton (Z-order) code of their center — the
  space-filling-curve layout that makes spatially-close toe prints adjacent
  in memory ("on disk").
* A ``G×G`` tile grid stores, per tile, up to ``m`` toe-print-ID *intervals*
  covering all toe prints intersecting that tile.  The whole structure is a
  few MB (paper: "the entire auxiliary structure can be stored in a few MB").
* A query unions the intervals of the tiles its footprint touches and
  coalesces them into ≤ ``k`` *sweeps* — contiguous ranges fetched with
  ``dynamic_slice`` streams instead of random gathers.

Also holds the doc-major footprint mirror (``doc_rects``/``doc_amps``) used
by the TEXT-FIRST / GEO-FIRST baselines (the "footprints sorted by docID on
disk" file), and per-doc MBRs for the GEO-FIRST in-memory filter (the
R*-tree stand-in: a memory-resident MBR table probed via the same tile grid).

Block-max metadata (the SEAL-style pruning substrate)
-----------------------------------------------------

The Morton-ordered store is additionally cut into fixed ``block_size``-
toe-print *blocks* (block ``b`` covers toe-print IDs ``[b*block_size,
(b+1)*block_size)``), and three per-block columns are precomputed at build:

* ``blk_mbr     f32[NB, 4]`` — MBR of the block's toe-print rects,
* ``blk_max_amp f32[NB]``    — max amplitude in the block,
* ``blk_max_mass f32[NB]``   — max per-toe-print ``amp * area``.

Together they give a cheap, *safe* upper bound on any toe print's partial
geo score against a query footprint::

    score_t <= min(blk_max_amp * sum_q area(blk_mbr ∩ q) * amp_q,
                   blk_max_mass * sum_q amp_q)

which is what the pruned K-SWEEP path (``budgets.prune``; see
``kernels/sweep_score``) tests against its running threshold θ to skip
scoring whole sweep blocks.  Like the tile grid, the block columns are a
small memory-resident auxiliary structure (``~T/block_size`` rows).  They
are always stored in f32 — computed from the (possibly f16-compressed)
store values actually scored at query time, so the bound stays safe under
lossy compression.  ``block_size`` must divide the Pallas streaming tile
(1024 toe prints) so a VMEM tile always covers whole blocks.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import geometry
from repro.core.footprint import footprint_mbr_np

INVALID = np.int32(2**31 - 1)
SCALE_BLOCK = 128  # toe prints per int8 amplitude-scale block (= kernel lanes)
COMPRESS_MODES = ("none", "f16", "int8")


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class SpatialIndex:
    # --- Morton-sorted toe-print store (the k-sweep "disk file") ---
    # compressed builds store rects/amps in f16 (or amps in int8 with a
    # per-SCALE_BLOCK f32 scale) and doc ids in i16 when they fit — the
    # sweep kernels stream the stored dtypes and decode in-register
    tp_rects: jax.Array  # f32[T, 4] (f16 when compressed)
    tp_amps: jax.Array  # f32[T] (f16 / int8 when compressed)
    tp_doc_ids: jax.Array  # i32[T] (i16 when compressed and n_docs fits)
    tp_amp_scale: jax.Array  # f32[ceil(T/SCALE_BLOCK)] ([0] unless int8)
    # --- tile grid: per tile, m toe-print-ID intervals [start, end) ---
    tile_starts: jax.Array  # i32[G*G, m]
    tile_ends: jax.Array  # i32[G*G, m]
    # --- doc-major mirror (docID-sorted footprint file) ---
    doc_rects: jax.Array  # f32[N, R, 4]
    doc_amps: jax.Array  # f32[N, R]
    doc_mbr: jax.Array  # f32[N, 4]
    doc_mass: jax.Array  # f32[N]  (Σ area·amp, for score upper bounds)
    # --- block-max metadata over the toe-print store (pruned K-SWEEP) ---
    blk_mbr: jax.Array  # f32[NB, 4]
    blk_max_amp: jax.Array  # f32[NB]
    blk_max_mass: jax.Array  # f32[NB]  (max amp·area per block)
    grid: int = field(metadata=dict(static=True))
    n_docs: int = field(metadata=dict(static=True))
    block_size: int = field(default=128, metadata=dict(static=True))

    @property
    def n_toeprints(self) -> int:
        return self.tp_rects.shape[0]

    @property
    def n_blocks(self) -> int:
        return self.blk_mbr.shape[0]

    @property
    def m_intervals(self) -> int:
        return self.tile_starts.shape[1]

    @property
    def plane_bytes(self) -> float:
        """Bytes per toe print the sweep kernels stream (coordinate planes +
        amplitude + amortized scale column, NOT the doc-id column)."""
        scale = 4.0 / SCALE_BLOCK if self.tp_amp_scale.shape[0] else 0.0
        return (
            4 * self.tp_rects.dtype.itemsize
            + self.tp_amps.dtype.itemsize
            + scale
        )

    @property
    def tp_bytes(self) -> float:
        """Modeled bytes per full toe-print record (planes + doc id) — the
        unit behind ``bytes_spatial``/``bytes_scored``.  24 uncompressed."""
        return self.plane_bytes + self.tp_doc_ids.dtype.itemsize

    @property
    def doc_bytes(self) -> float:
        """Bytes per doc-major footprint slot (rect + amp); 20 uncompressed."""
        return 4 * self.doc_rects.dtype.itemsize + self.doc_amps.dtype.itemsize


def normalize_compress(compress) -> str:
    """Accept the legacy bool flag or a mode string; return the mode."""
    if compress is True:
        return "f16"
    if compress is False or compress is None:
        return "none"
    if compress not in COMPRESS_MODES:
        raise ValueError(f"compress must be one of {COMPRESS_MODES}, got {compress!r}")
    return compress


def quantize_amps_np(amps: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric per-SCALE_BLOCK int8 quantization of the amp column.

    Returns (q int8[T], scale f32[ceil(T/SB)]); decode is
    ``q.astype(f32) * scale[t // SCALE_BLOCK]`` — the exact expression the
    kernels and references evaluate, so quantized values round-trip
    bit-identically everywhere.  Handles negative amps (symmetric range)
    and all-zero blocks (scale 1.0, q 0).
    """
    T = amps.shape[0]
    nb = max((T + SCALE_BLOCK - 1) // SCALE_BLOCK, 1)
    pad = nb * SCALE_BLOCK - T
    a = np.concatenate([amps.astype(np.float32), np.zeros((pad,), np.float32)])
    a = a.reshape(nb, SCALE_BLOCK)
    max_abs = np.abs(a).max(axis=1)
    scale = np.where(max_abs > 0, max_abs / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.round(a / scale[:, None]), -127, 127).astype(np.int8)
    return q.reshape(-1)[:T], scale


def _id_dtype(n_docs: int, mode: str):
    return np.int16 if (mode != "none" and n_docs <= np.iinfo(np.int16).max) else np.int32


def build_spatial_index_np(
    doc_rects: np.ndarray,  # f32[N, R, 4] (padded with EMPTY_RECT)
    doc_amps: np.ndarray,  # f32[N, R]
    grid: int = 64,
    m_intervals: int = 2,
    compress: bool | str = False,  # "none"|"f16"|"int8" (paper: lossy compression)
    block_size: int = 128,  # toe prints per block-max metadata block
) -> SpatialIndex:
    """Host-side index build (the paper's offline preprocessing).

    ``compress="f16"`` stores footprint rects/amps in f16; ``"int8"``
    additionally quantizes the toe-print amp column to int8 with a
    per-:data:`SCALE_BLOCK` f32 scale.  Both narrow the streamed doc-id
    column to i16 when ``n_docs`` fits.  Block-max metadata is always
    computed from the decoded (post-quantization) values so the pruning
    bounds stay safe.
    """
    N, R, _ = doc_rects.shape
    valid = doc_rects[:, :, 2] > doc_rects[:, :, 0]
    doc_idx, rect_idx = np.nonzero(valid)
    rects = doc_rects[doc_idx, rect_idx]  # [T, 4]
    amps = doc_amps[doc_idx, rect_idx]

    # Morton order by rect-center cell in a fine 2^15 grid.
    cx = (rects[:, 0] + rects[:, 2]) * 0.5
    cy = (rects[:, 1] + rects[:, 3]) * 0.5
    fine = 1 << 15
    ix = np.clip((cx * fine).astype(np.int64), 0, fine - 1)
    iy = np.clip((cy * fine).astype(np.int64), 0, fine - 1)
    codes = geometry.morton_encode_np(ix.astype(np.uint32), iy.astype(np.uint32))
    order = np.argsort(codes, kind="stable")
    rects, amps, doc_idx = rects[order], amps[order], doc_idx[order]
    T = len(rects)

    # Tile grid: toe-print IDs intersecting each tile, compressed to m intervals.
    tile_starts = np.full((grid * grid, m_intervals), INVALID, dtype=np.int32)
    tile_ends = np.full((grid * grid, m_intervals), INVALID, dtype=np.int32)

    # enumerate (tile, toeprint) pairs
    x0, y0, x1, y1 = geometry.rect_cell_bounds_np(rects, grid)
    tile_lists: dict[int, list[int]] = {}
    for t in range(T):
        for ty in range(y0[t], y1[t] + 1):
            base = ty * grid
            for tx in range(x0[t], x1[t] + 1):
                tile_lists.setdefault(base + tx, []).append(t)

    for tile, ids in tile_lists.items():
        ivs = _coalesce_to_m(np.asarray(ids, dtype=np.int64), m_intervals)
        for j, (s, e) in enumerate(ivs):
            tile_starts[tile, j] = s
            tile_ends[tile, j] = e

    # doc-major mirrors
    mbr = np.stack([footprint_mbr_np(doc_rects[i]) for i in range(N)], axis=0)
    area = np.maximum(doc_rects[:, :, 2] - doc_rects[:, :, 0], 0) * np.maximum(
        doc_rects[:, :, 3] - doc_rects[:, :, 1], 0
    )
    mass = (area * doc_amps).sum(axis=1).astype(np.float32)

    mode = normalize_compress(compress)
    ft = np.float16 if mode != "none" else np.float32
    if mode == "int8":
        tp_amps_store, tp_amp_scale = quantize_amps_np(amps)
        dec_amps = tp_amps_store.astype(np.float32) * np.repeat(
            tp_amp_scale, SCALE_BLOCK
        )[: len(tp_amps_store)]
    else:
        tp_amps_store = amps.astype(ft)
        tp_amp_scale = np.zeros((0,), np.float32)
        dec_amps = tp_amps_store.astype(np.float32)
    # block-max metadata is computed from the values the query path will
    # actually score (post-cast / dequantized), so the bounds stay safe
    # under lossy compression
    blk_mbr, blk_max_amp, blk_max_mass = block_metadata_np(
        rects.astype(ft).astype(np.float32),
        dec_amps,
        block_size,
    )
    return SpatialIndex(
        tp_rects=jnp.asarray(rects.astype(ft)),
        tp_amps=jnp.asarray(tp_amps_store),
        tp_doc_ids=jnp.asarray(doc_idx.astype(_id_dtype(N, mode))),
        tp_amp_scale=jnp.asarray(tp_amp_scale),
        tile_starts=jnp.asarray(tile_starts),
        tile_ends=jnp.asarray(tile_ends),
        doc_rects=jnp.asarray(doc_rects.astype(ft)),
        doc_amps=jnp.asarray(doc_amps.astype(ft)),
        doc_mbr=jnp.asarray(mbr.astype(ft)),
        doc_mass=jnp.asarray(mass.astype(ft)),
        blk_mbr=jnp.asarray(blk_mbr),
        blk_max_amp=jnp.asarray(blk_max_amp),
        blk_max_mass=jnp.asarray(blk_max_mass),
        grid=grid,
        n_docs=N,
        block_size=block_size,
    )


def block_metadata_np(
    rects: np.ndarray,  # f32[T, 4] Morton-ordered toe-print rects
    amps: np.ndarray,  # f32[T]
    block_size: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-block (MBR, max amp, max amp·area) over the Morton-ordered store.

    Block ``b`` covers toe prints ``[b*block_size, (b+1)*block_size)``; the
    tail block may be short.  Returns arrays of length ``ceil(T/bs)`` (at
    least 1; a degenerate all-empty block when the store is empty).
    """
    if block_size not in (128, 256, 512, 1024):
        # must divide the kernel's 1024-toe-print VMEM tile into whole
        # 128-lane rows, so a tile's per-block skip masks are row-aligned
        raise ValueError(f"block_size {block_size} must be 128/256/512/1024")
    T = rects.shape[0]
    nb = max((T + block_size - 1) // block_size, 1)
    pad = nb * block_size - T
    # pad with empty rects / zero amps: they cannot raise any block max
    big = np.float32(np.inf)
    r = np.concatenate(
        [rects, np.tile([big, big, -big, -big], (pad, 1)).astype(np.float32)]
    ).reshape(nb, block_size, 4)
    a = np.concatenate([amps, np.zeros((pad,), np.float32)]).reshape(nb, block_size)
    mbr = np.stack(
        [
            r[:, :, 0].min(axis=1),
            r[:, :, 1].min(axis=1),
            r[:, :, 2].max(axis=1),
            r[:, :, 3].max(axis=1),
        ],
        axis=1,
    ).astype(np.float32)
    # fully-padded blocks: make the MBR a plain empty rect (finite)
    empty = ~np.isfinite(mbr).all(axis=1)
    mbr[empty] = geometry.EMPTY_RECT
    area = np.maximum(r[:, :, 2] - r[:, :, 0], 0) * np.maximum(
        r[:, :, 3] - r[:, :, 1], 0
    )
    area = np.where(np.isfinite(area), area, 0.0)
    return (
        mbr,
        a.max(axis=1).astype(np.float32),
        (a * area).max(axis=1).astype(np.float32),
    )


def _coalesce_to_m(ids: np.ndarray, m: int) -> list[tuple[int, int]]:
    """Cover sorted toe-print IDs with ≤ m [start, end) intervals.

    Greedy-optimal: cut at the m−1 largest gaps (minimizes covered slack).
    """
    if len(ids) == 0:
        return []
    ids = np.unique(ids)
    if len(ids) == 1:
        return [(int(ids[0]), int(ids[0]) + 1)]
    gaps = np.diff(ids)
    n_cuts = min(m - 1, len(gaps))
    if n_cuts > 0:
        cut_pos = np.argsort(-gaps, kind="stable")[:n_cuts]
        # only cut where the gap is > 1 (else no benefit)
        cut_pos = cut_pos[gaps[cut_pos] > 1]
        cut_pos = np.sort(cut_pos)
    else:
        cut_pos = np.array([], dtype=np.int64)
    bounds = np.concatenate([[-1], cut_pos, [len(ids) - 1]])
    out = []
    for i in range(len(bounds) - 1):
        s = int(ids[bounds[i] + 1])
        e = int(ids[bounds[i + 1]]) + 1
        out.append((s, e))
    return out


# ---------------------------------------------------------------------------
# Query-time primitives (jit-safe)
# ---------------------------------------------------------------------------

def gather_query_intervals(
    index: SpatialIndex,
    query_rects: jax.Array,  # f32[Qr, 4]
    max_tiles: int,
) -> tuple[jax.Array, jax.Array]:
    """Intervals of every tile touched by the query footprint.

    Returns (starts i32[Qr*max_tiles*m], ends …) with INVALID padding.
    """
    Qr = query_rects.shape[0]

    def per_rect(r):
        tiles, valid = geometry.enumerate_rect_tiles(r, index.grid, max_tiles)
        s = index.tile_starts[tiles]  # [max_tiles, m]
        e = index.tile_ends[tiles]
        s = jnp.where(valid[:, None], s, INVALID)
        e = jnp.where(valid[:, None], e, INVALID)
        return s.reshape(-1), e.reshape(-1)

    starts, ends = jax.vmap(per_rect)(query_rects)
    return starts.reshape(-1), ends.reshape(-1)


def coalesce_k_sweeps(
    starts: jax.Array,  # i32[I] with INVALID padding
    ends: jax.Array,
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """Coalesce intervals into ≤ k sweeps minimizing fetched volume.

    Sort intervals by start; a sweep boundary is placed at the k−1 largest
    *positive* gaps between consecutive intervals (gap = next.start −
    running_max_end).  Closed-form, no data-dependent shapes.

    Returns (sweep_starts i32[k], sweep_ends i32[k]); empty sweeps have
    start == end == INVALID.
    """
    I = starts.shape[0]
    order = jnp.argsort(starts)
    s = starts[order]
    e = ends[order]
    valid = s != INVALID
    # running max of interval ends (prefix), to handle containment/overlap
    e_filled = jnp.where(valid, e, jnp.int32(-1))
    run_end = jax.lax.cummax(e_filled)
    prev_end = jnp.concatenate([jnp.zeros((1,), jnp.int32), run_end[:-1]])
    gap = jnp.where(valid, s - prev_end, jnp.int32(-1))
    gap = gap.at[0].set(jnp.where(valid[0], 0, -1))
    # first valid interval must always open a sweep; force its gap huge
    first_valid = jnp.argmax(valid)  # 0 if none valid
    gap = gap.at[first_valid].set(
        jnp.where(valid.any(), jnp.int32(2**30), gap[first_valid])
    )
    gap = jnp.where(jnp.arange(I) == first_valid, gap, jnp.where(gap > 0, gap, -1))

    # choose k cut positions = k largest positive gaps (first_valid included)
    top_gap, top_idx = jax.lax.top_k(gap, min(k, I))
    is_cut = jnp.zeros((I,), dtype=bool).at[top_idx].set(top_gap > 0)

    # sweep id per interval = cumsum of cuts − 1
    sweep_id = jnp.cumsum(is_cut.astype(jnp.int32)) - 1
    sweep_id = jnp.where(valid, sweep_id, k)  # invalid → bucket k (dropped)

    big = jnp.int32(2**30)
    sweep_starts = jnp.full((k + 1,), big, jnp.int32).at[sweep_id].min(
        jnp.where(valid, s, big)
    )[:k]
    sweep_ends = jnp.full((k + 1,), jnp.int32(-1), jnp.int32).at[sweep_id].max(
        jnp.where(valid, e, jnp.int32(-1))
    )[:k]
    empty = sweep_ends < sweep_starts
    sweep_starts = jnp.where(empty, INVALID, sweep_starts)
    sweep_ends = jnp.where(empty, INVALID, sweep_ends)
    return sweep_starts, sweep_ends


def split_sweeps_to_budget(
    sweep_starts: jax.Array,  # i32[k]
    sweep_ends: jax.Array,
    k: int,
    budget: int,
) -> tuple[jax.Array, jax.Array]:
    """Re-chunk coalesced runs into ≤ k sweeps of length ≤ budget.

    A run longer than ``budget`` would otherwise be tail-truncated by
    ``fetch_sweeps``; here each run r is split into ceil(len_r/budget)
    consecutive chunks and the first k chunks across runs are kept (total
    fetch stays ≤ k·budget — the fixed I/O budget).
    """
    lens = jnp.where(sweep_starts != INVALID, sweep_ends - sweep_starts, 0)
    chunks = (lens + budget - 1) // budget  # per-run chunk count
    cum = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(chunks).astype(jnp.int32)]
    )
    j = jnp.arange(k, dtype=jnp.int32)
    run = jnp.clip(jnp.searchsorted(cum, j, side="right") - 1, 0, k - 1)
    within = j - cum[run]
    valid = j < cum[-1]
    s = jnp.where(sweep_starts[run] == INVALID, 0, sweep_starts[run]) + within * budget
    e = jnp.minimum(s + budget, sweep_ends[run])
    s = jnp.where(valid, s, INVALID)
    e = jnp.where(valid, e, INVALID)
    return s, e


def fetch_sweeps(
    index: SpatialIndex,
    sweep_starts: jax.Array,  # i32[k]
    sweep_ends: jax.Array,
    sweep_budget: int,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fetch toe prints of ≤ k sweeps as contiguous dynamic slices.

    Each sweep fetches exactly ``sweep_budget`` consecutive toe prints
    starting at its start (entries past the sweep end are masked).  This is
    the HBM analogue of the paper's "k highly efficient [disk] scans".

    Returns (rects f32[k*B,4], amps f32[k*B], doc_ids i32[k*B], valid bool[k*B]).
    """
    k = sweep_starts.shape[0]
    T = index.n_toeprints

    def fetch_one(s, e):
        start = jnp.clip(jnp.where(s == INVALID, 0, s), 0, max(T - sweep_budget, 0))
        r = jax.lax.dynamic_slice(index.tp_rects, (start, 0), (sweep_budget, 4))
        a = jax.lax.dynamic_slice(index.tp_amps, (start,), (sweep_budget,))
        d = jax.lax.dynamic_slice(index.tp_doc_ids, (start,), (sweep_budget,))
        pos = start + jnp.arange(sweep_budget, dtype=jnp.int32)
        # decode: same astype-then-multiply order the kernels use, so the
        # dequantized values bit-match the in-kernel decode
        a = a.astype(jnp.float32)
        if index.tp_amp_scale.shape[0]:
            a = a * index.tp_amp_scale[pos // SCALE_BLOCK]
        ok = (s != INVALID) & (pos >= s) & (pos < e)
        return r.astype(jnp.float32), a, d.astype(jnp.int32), ok

    rects, amps, docs, ok = jax.vmap(fetch_one)(sweep_starts, sweep_ends)
    return (
        rects.reshape(k * sweep_budget, 4),
        amps.reshape(-1),
        docs.reshape(-1),
        ok.reshape(-1),
    )


def fetch_sweep_ids(
    index: SpatialIndex,
    sweep_starts: jax.Array,  # i32[k]
    sweep_ends: jax.Array,
    sweep_budget: int,
) -> tuple[jax.Array, jax.Array]:
    """Doc-id-only sweep fetch (pairs with the fused sweep_score kernel,
    which produces the scores without materializing the geometry)."""
    k = sweep_starts.shape[0]
    T = index.n_toeprints

    def fetch_one(s, e):
        start = jnp.clip(jnp.where(s == INVALID, 0, s), 0, max(T - sweep_budget, 0))
        d = jax.lax.dynamic_slice(index.tp_doc_ids, (start,), (sweep_budget,))
        pos = start + jnp.arange(sweep_budget, dtype=jnp.int32)
        # re-window to [s, s+budget) convention used by the fused kernel
        shift = jnp.where(s == INVALID, 0, s) - start
        idx = jnp.clip(
            shift + jnp.arange(sweep_budget, dtype=jnp.int32), 0, sweep_budget - 1
        )
        return d[idx].astype(jnp.int32)

    docs = jax.vmap(fetch_one)(sweep_starts, sweep_ends)
    return docs.reshape(k * sweep_budget)


def tile_candidate_toeprints(
    index: SpatialIndex,
    query_rects: jax.Array,  # f32[Qr, 4]
    max_tiles: int,
    max_candidates: int,
    max_runs: int = 64,
) -> tuple[jax.Array, jax.Array]:
    """GEO-FIRST candidate generation: individual toe-print IDs from tiles.

    Merges the query's tile intervals into ≤ ``max_runs`` disjoint runs, then
    enumerates individual toe-print IDs (cumsum expansion) up to the
    ``max_candidates`` budget.  Models the R*-tree candidate lookup — each
    candidate toe print is then fetched *individually* (random access).

    Returns (tp_ids i32[max_candidates], valid bool[max_candidates]).
    """
    starts, ends = gather_query_intervals(index, query_rects, max_tiles)
    s, e = coalesce_k_sweeps(starts, ends, max_runs)  # disjoint runs
    lens = jnp.where(s != INVALID, e - s, 0)
    offs = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(lens).astype(jnp.int32)]
    )
    j = jnp.arange(max_candidates, dtype=jnp.int32)
    run = jnp.clip(jnp.searchsorted(offs, j, side="right") - 1, 0, max_runs - 1)
    ok = j < offs[-1]
    ids = jnp.where(s[run] == INVALID, 0, s[run]) + (j - offs[run])
    return jnp.where(ok, ids, 0), ok

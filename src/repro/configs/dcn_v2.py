"""dcn-v2 [arXiv:2008.13535; paper]

n_dense=13 n_sparse=26 embed_dim=16 n_cross_layers=3 mlp=1024-1024-512,
cross interaction; Criteo-scale per-field vocabularies.
"""
from repro.configs.base import ArchSpec, RECSYS_SHAPES, register
from repro.data.recsys import CRITEO_VOCABS
from repro.models.recsys import DCNv2Config

CONFIG = DCNv2Config(
    name="dcn-v2",
    n_dense=13, n_sparse=26, embed_dim=16, n_cross_layers=3,
    mlp_dims=(1024, 1024, 512), vocab_sizes=CRITEO_VOCABS,
)

SMOKE = DCNv2Config(
    name="dcn-v2-smoke",
    n_dense=4, n_sparse=6, embed_dim=8, n_cross_layers=2, mlp_dims=(32, 16),
    vocab_sizes=(50, 100, 200, 50, 30, 70),
)


@register("dcn-v2")
def make() -> ArchSpec:
    return ArchSpec(
        name="dcn-v2", family="recsys", config=CONFIG, smoke_config=SMOKE,
        shapes=RECSYS_SHAPES, source="arXiv:2008.13535",
    )

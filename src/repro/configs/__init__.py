from repro.configs.base import ArchSpec, ShapeSpec, get_arch, list_archs

__all__ = ["ArchSpec", "ShapeSpec", "get_arch", "list_archs"]

"""geoweb — the paper's own system at production scale.

64M-document web corpus (national-domain crawl scale, paper §III) sharded
over the mesh's doc axes; three serve cells, one per paper algorithm
(§IV A/B/C).  These cells are IN ADDITION to the 40 assigned-architecture
cells — they are the reproduction target itself.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ArchSpec, ShapeSpec, register
from repro.core.algorithms import QueryBudgets
from repro.core.ranking import RankWeights


@dataclass(frozen=True)
class GeoWebConfig:
    name: str = "geoweb"
    n_docs: int = 67_108_864  # 2^26 (global)
    n_terms: int = 1_048_576
    avg_postings_per_doc: int = 128
    max_rects: int = 2  # toe prints per doc (avg; doc-major mirror uses R=4)
    doc_major_rects: int = 4
    grid: int = 1024  # the paper's 1024x1024 tile domain
    m_intervals: int = 2
    query_batch: int = 4096  # global queries per serve step
    d_terms: int = 4
    q_rects: int = 2
    budgets: QueryBudgets = QueryBudgets(
        max_candidates=4096, max_tiles=256, k_sweeps=8, sweep_budget=16384,
        top_k=10, early_termination=True,
    )
    weights: RankWeights = RankWeights()
    # lossy-compressed (f16) footprint + impact data — the paper's own
    # future-work proposal; EXPERIMENTS.md §Perf geoweb iteration 1
    compress: bool = True


CONFIG = GeoWebConfig()

SMOKE = GeoWebConfig(
    name="geoweb-smoke",
    n_docs=512, n_terms=128, avg_postings_per_doc=16, grid=32,
    query_batch=8,
    budgets=QueryBudgets(
        max_candidates=256, max_tiles=64, k_sweeps=4, sweep_budget=256, top_k=10
    ),
)

SHAPES = (
    ShapeSpec("serve_ksweep", "geo_serve", dict(algorithm="k_sweep")),
    ShapeSpec("serve_textfirst", "geo_serve", dict(algorithm="text_first")),
    ShapeSpec("serve_geofirst", "geo_serve", dict(algorithm="geo_first")),
)


@register("geoweb")
def make() -> ArchSpec:
    return ArchSpec(
        name="geoweb", family="geoweb", config=CONFIG, smoke_config=SMOKE,
        shapes=SHAPES, source="the paper (CS.IR 2010)",
    )

"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

24L d_model=1024 16H (GQA kv=8) d_ff=512/expert vocab=49155, MoE 32e top-8.
"""
from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="granite-moe-1b-a400m",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=512,
    vocab=49155, n_experts=32, top_k=8, rope_theta=10000.0,
)

SMOKE = TransformerConfig(
    name="granite-moe-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
    vocab=512, n_experts=4, top_k=2, attn_chunk=16,
)


@register("granite-moe-1b-a400m")
def make() -> ArchSpec:
    return ArchSpec(
        name="granite-moe-1b-a400m", family="lm", config=CONFIG, smoke_config=SMOKE,
        shapes=lm_shapes(full_attention=True),
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )

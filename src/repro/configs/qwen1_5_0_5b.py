"""qwen1.5-0.5b [hf:Qwen/Qwen1.5-0.5B; hf]

24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936, QKV bias (dense).
"""
from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen1.5-0.5b",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=2816,
    vocab=151936, qkv_bias=True, rope_theta=1_000_000.0,
)

SMOKE = TransformerConfig(
    name="qwen1.5-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    qkv_bias=True, attn_chunk=16,
)


@register("qwen1.5-0.5b")
def make() -> ArchSpec:
    return ArchSpec(
        name="qwen1.5-0.5b", family="lm", config=CONFIG, smoke_config=SMOKE,
        shapes=lm_shapes(full_attention=True), source="hf:Qwen/Qwen1.5-0.5B",
    )

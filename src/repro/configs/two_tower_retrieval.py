"""two-tower-retrieval [RecSys'19 (YouTube); unverified]

embed_dim=256 tower_mlp=1024-512-256 interaction=dot, sampled-softmax
retrieval.  Item corpus 1M (retrieval_cand scores all of it).
"""
from repro.configs.base import ArchSpec, RECSYS_SHAPES, register
from repro.models.recsys import TwoTowerConfig

CONFIG = TwoTowerConfig(
    name="two-tower-retrieval",
    embed_dim=256, tower_dims=(1024, 512, 256),
    n_users=10_000_000, n_items=1_000_000,
    n_user_fields=4, n_item_fields=3, field_vocab=100_000,
    hist_len=20, feat_dim=64,
)

SMOKE = TwoTowerConfig(
    name="two-tower-smoke",
    embed_dim=16, tower_dims=(32, 16), n_users=1000, n_items=1000,
    n_user_fields=2, n_item_fields=2, field_vocab=50, hist_len=5, feat_dim=8,
)


@register("two-tower-retrieval")
def make() -> ArchSpec:
    return ArchSpec(
        name="two-tower-retrieval", family="recsys", config=CONFIG,
        smoke_config=SMOKE, shapes=RECSYS_SHAPES, source="RecSys'19 (YouTube)",
    )

"""Import every architecture module so the registry is populated."""
import repro.configs.granite_moe_1b_a400m  # noqa: F401
import repro.configs.olmoe_1b_7b  # noqa: F401
import repro.configs.smollm_135m  # noqa: F401
import repro.configs.qwen1_5_0_5b  # noqa: F401
import repro.configs.qwen2_5_14b  # noqa: F401
import repro.configs.egnn  # noqa: F401
import repro.configs.two_tower_retrieval  # noqa: F401
import repro.configs.dcn_v2  # noqa: F401
import repro.configs.autoint  # noqa: F401
import repro.configs.bst  # noqa: F401
import repro.configs.geoweb  # noqa: F401

"""smollm-135m [hf:HuggingFaceTB/SmolLM-135M; hf]

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152 (llama-arch small, dense).
"""
from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="smollm-135m",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_ff=1536, vocab=49152,
    tie_embeddings=True,
)

SMOKE = TransformerConfig(
    name="smollm-smoke",
    n_layers=2, d_model=48, n_heads=3, n_kv_heads=1, d_ff=96, vocab=512,
    attn_chunk=16,
)


@register("smollm-135m")
def make() -> ArchSpec:
    return ArchSpec(
        name="smollm-135m", family="lm", config=CONFIG, smoke_config=SMOKE,
        shapes=lm_shapes(full_attention=True), source="hf:HuggingFaceTB/SmolLM-135M",
    )

"""bst — Behavior Sequence Transformer (Alibaba) [arXiv:1905.06874; paper]

embed_dim=32 seq_len=20 n_blocks=1 n_heads=8 mlp=1024-512-256.
"""
from repro.configs.base import ArchSpec, RECSYS_SHAPES, register
from repro.models.recsys import BSTConfig

CONFIG = BSTConfig(
    name="bst",
    embed_dim=32, seq_len=20, n_blocks=1, n_heads=8, mlp_dims=(1024, 512, 256),
    n_items=10_000_000, n_other_fields=4, field_vocab=1_000_000,
)

SMOKE = BSTConfig(
    name="bst-smoke",
    embed_dim=16, seq_len=5, n_blocks=1, n_heads=2, mlp_dims=(32, 16),
    n_items=500, n_other_fields=2, field_vocab=50,
)


@register("bst")
def make() -> ArchSpec:
    return ArchSpec(
        name="bst", family="recsys", config=CONFIG, smoke_config=SMOKE,
        shapes=RECSYS_SHAPES, source="arXiv:1905.06874",
    )

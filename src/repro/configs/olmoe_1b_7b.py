"""olmoe-1b-7b [arXiv:2409.02060; hf]

16L d_model=2048 16H (GQA kv=16) d_ff=1024/expert vocab=50304, MoE 64e top-8.
"""
from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="olmoe-1b-7b",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1024,
    vocab=50304, n_experts=64, top_k=8, qk_norm=True,
)

SMOKE = TransformerConfig(
    name="olmoe-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
    vocab=512, n_experts=8, top_k=2, qk_norm=True, attn_chunk=16,
)


@register("olmoe-1b-7b")
def make() -> ArchSpec:
    return ArchSpec(
        name="olmoe-1b-7b", family="lm", config=CONFIG, smoke_config=SMOKE,
        shapes=lm_shapes(full_attention=True), source="arXiv:2409.02060",
    )

"""Architecture/shape registry: every assigned (arch × input-shape) cell.

Each ``configs/<id>.py`` defines ``make() -> ArchSpec`` with the exact
published configuration, a reduced smoke configuration (same family), and
its assigned shape set.  ``launch/steps.py`` turns (arch, shape) into a
(jit-able step fn, input ShapeDtypeStructs) pair for the dry-run; tests use
the smoke configs with real arrays.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # lm_train | lm_prefill | lm_decode | gnn_full | gnn_minibatch |
    #            gnn_molecule | recsys_train | recsys_serve | recsys_retrieval |
    #            geo_serve
    params: dict
    skip: str | None = None  # reason if this cell is inapplicable (DESIGN.md)
    variant_of: str | None = None  # beyond-paper variant rows


@dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str  # lm | gnn | recsys | geoweb
    config: Any
    smoke_config: Any
    shapes: tuple[ShapeSpec, ...]
    source: str = ""

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.name} has no shape {name}")


# ---------------------------------------------------------------------------
# shared LM shape set (assigned to every LM arch)
# ---------------------------------------------------------------------------

def lm_shapes(full_attention: bool, decode_batch: int = 128) -> tuple[ShapeSpec, ...]:
    shapes = [
        ShapeSpec("train_4k", "lm_train", dict(seq_len=4096, global_batch=256)),
        ShapeSpec("prefill_32k", "lm_prefill", dict(seq_len=32768, global_batch=32)),
        ShapeSpec("decode_32k", "lm_decode", dict(seq_len=32768, global_batch=decode_batch)),
    ]
    if full_attention:
        shapes.append(
            ShapeSpec(
                "long_500k", "lm_decode", dict(seq_len=524288, global_batch=1),
                skip="pure full-attention arch: 500k-token full-attention serving "
                     "is out of published scope (DESIGN.md §6); see the "
                     "long_500k_sliding beyond-paper variant",
            )
        )
        shapes.append(
            ShapeSpec(
                "long_500k_sliding", "lm_decode",
                dict(seq_len=524288, global_batch=1, attn_window=8192),
                variant_of="long_500k",
            )
        )
    else:
        shapes.append(
            ShapeSpec("long_500k", "lm_decode", dict(seq_len=524288, global_batch=1))
        )
    return tuple(shapes)


RECSYS_SHAPES = (
    ShapeSpec("train_batch", "recsys_train", dict(batch=65536)),
    ShapeSpec("serve_p99", "recsys_serve", dict(batch=512)),
    ShapeSpec("serve_bulk", "recsys_serve", dict(batch=262144)),
    ShapeSpec("retrieval_cand", "recsys_retrieval", dict(batch=1, n_candidates=1_000_000)),
)


_REGISTRY: dict[str, Any] = {}


def register(name: str):
    def deco(make):
        _REGISTRY[name] = make
        return make

    return deco


def get_arch(name: str) -> ArchSpec:
    import repro.configs.all_archs  # noqa: F401  (populates registry)

    return _REGISTRY[name]()


def list_archs() -> list[str]:
    import repro.configs.all_archs  # noqa: F401

    return sorted(_REGISTRY.keys())

"""qwen2.5-14b [hf:Qwen/Qwen2.5-14B; hf]

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064, QKV bias (dense).
"""
from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen2.5-14b",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=13824,
    vocab=152064, qkv_bias=True, rope_theta=1_000_000.0,
)

SMOKE = TransformerConfig(
    name="qwen2.5-smoke",
    n_layers=2, d_model=80, n_heads=5, n_kv_heads=1, d_ff=192, vocab=512,
    qkv_bias=True, attn_chunk=16,
)


@register("qwen2.5-14b")
def make() -> ArchSpec:
    return ArchSpec(
        name="qwen2.5-14b", family="lm", config=CONFIG, smoke_config=SMOKE,
        shapes=lm_shapes(full_attention=True, decode_batch=128),
        source="hf:Qwen/Qwen2.5-14B",
    )

"""egnn [arXiv:2102.09844; paper]

n_layers=4 d_hidden=64 equivariance=E(n).  Shape set: full_graph_sm (Cora),
minibatch_lg (Reddit-scale sampled), ogb_products (full-batch 2.4M nodes),
molecule (batched small graphs).
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, ShapeSpec, register
from repro.models.egnn import EGNNConfig

# bf16 message compute (f32 params/loss): halves HBM traffic and collective
# bytes on the 62M-edge full-batch cells (EXPERIMENTS.md §Perf egnn it. 1)
CONFIG = EGNNConfig(name="egnn", n_layers=4, d_hidden=64, d_feat=1433, n_classes=7,
                    compute_dtype=jnp.bfloat16)

SMOKE = EGNNConfig(name="egnn-smoke", n_layers=2, d_hidden=16, d_feat=8, n_classes=4)

SHAPES = (
    ShapeSpec("full_graph_sm", "gnn_full",
              dict(n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7)),
    ShapeSpec("minibatch_lg", "gnn_minibatch",
              dict(n_nodes=232_965, n_edges=114_615_892, batch_nodes=1024,
                   fanouts=(15, 10), d_feat=602, n_classes=41)),
    ShapeSpec("ogb_products", "gnn_full",
              dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100, n_classes=47)),
    ShapeSpec("molecule", "gnn_molecule",
              dict(n_nodes=30, n_edges=64, batch=128, d_feat=11)),
)


@register("egnn")
def make() -> ArchSpec:
    return ArchSpec(
        name="egnn", family="gnn", config=CONFIG, smoke_config=SMOKE,
        shapes=SHAPES, source="arXiv:2102.09844",
    )

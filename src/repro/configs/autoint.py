"""autoint [arXiv:1810.11921; paper]

n_sparse=39 embed_dim=16 n_attn_layers=3 n_heads=2 d_attn=32 (self-attn
feature interaction), Avazu-style mixed vocabularies.
"""
from repro.configs.base import ArchSpec, RECSYS_SHAPES, register
from repro.data.recsys import avazu_like_vocabs
from repro.models.recsys import AutoIntConfig

CONFIG = AutoIntConfig(
    name="autoint",
    n_sparse=39, embed_dim=16, n_attn_layers=3, n_heads=2, d_attn=32,
    vocab_sizes=avazu_like_vocabs(39),
)

SMOKE = AutoIntConfig(
    name="autoint-smoke",
    n_sparse=5, embed_dim=8, n_attn_layers=2, n_heads=2, d_attn=8,
    vocab_sizes=(50, 100, 200, 50, 30),
)


@register("autoint")
def make() -> ArchSpec:
    return ArchSpec(
        name="autoint", family="recsys", config=CONFIG, smoke_config=SMOKE,
        shapes=RECSYS_SHAPES, source="arXiv:1810.11921",
    )

"""Planner audit log: predicted vs measured cost per planned query.

The cost-based planner picks an algorithm per query from predicted
``n_probes`` / ``bytes_postings`` / ``bytes_spatial``.  Those predictions
are only as good as their calibration — and calibration is only as good
as the evidence.  This module makes the evidence a first-class artifact:
for every planned cache miss the server records

* the query's :class:`~repro.core.planner.QueryFeatures` (as a dict),
* every candidate plan's predicted counters + total cost,
* the chosen plan label,

and after the batch executes, the per-row **measured** counters from the
executor's stats are joined back onto the record.  The result is a JSONL
file where each line is one planned query with prediction and ground
truth side by side, plus :meth:`PlannerAudit.error_summary` — mean
relative prediction error per ``(algo, counter)`` — which is exactly the
signal :meth:`~repro.core.planner.CostModel.calibrate` consumes.

Audit records reference queries by the server's ``qid`` (coalesced
followers share the leader's record; only the leader is planned).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

# counters present in both predictions and executor stats
COST_KEYS = ("n_probes", "bytes_postings", "bytes_spatial")


@dataclass
class AuditRecord:
    qid: int
    idx: int  # trace position
    features: dict
    candidates: dict  # label -> {algorithm, n_probes, bytes_*, cost, ...}
    chosen: str
    t_plan: float  # virtual/wall arrival-clock time of planning
    measured: dict | None = None  # joined post-execution

    def errors(self) -> dict[str, float] | None:
        """Per-counter relative error |pred - meas| / max(meas, 1)."""
        if self.measured is None:
            return None
        pred = self.candidates[self.chosen]
        out = {}
        for k in COST_KEYS:
            if k in pred and k in self.measured:
                m = float(self.measured[k])
                out[k] = abs(float(pred[k]) - m) / max(m, 1.0)
        return out


@dataclass
class PlannerAudit:
    """Accumulates audit records; joined lazily as batches complete."""

    records: list[AuditRecord] = field(default_factory=list)
    _by_qid: dict[int, AuditRecord] = field(default_factory=dict)

    def record(
        self,
        qid: int,
        idx: int,
        features: dict,
        candidates: dict,
        chosen: str,
        t_plan: float,
    ) -> None:
        rec = AuditRecord(qid, idx, features, candidates, chosen, t_plan)
        self.records.append(rec)
        self._by_qid[qid] = rec

    def join(self, qid: int, measured: dict) -> None:
        """Attach post-execution measured counters to a planned query."""
        rec = self._by_qid.get(qid)
        if rec is not None:
            rec.measured = measured

    # ------------------------------------------------------------------
    @property
    def joined(self) -> list[AuditRecord]:
        return [r for r in self.records if r.measured is not None]

    def error_summary(self) -> dict[tuple[str, str], float]:
        """Mean relative prediction error per (chosen algo, counter)."""
        sums: dict[tuple[str, str], float] = {}
        counts: dict[tuple[str, str], int] = {}
        for rec in self.joined:
            algo = rec.candidates[rec.chosen].get("algorithm", rec.chosen)
            for k, e in (rec.errors() or {}).items():
                key = (algo, k)
                sums[key] = sums.get(key, 0.0) + e
                counts[key] = counts.get(key, 0) + 1
        return {k: sums[k] / counts[k] for k in sums}

    def to_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for rec in self.records:
                f.write(
                    json.dumps(
                        {
                            "qid": rec.qid,
                            "idx": rec.idx,
                            "t_plan_s": rec.t_plan,
                            "features": rec.features,
                            "candidates": rec.candidates,
                            "chosen": rec.chosen,
                            "measured": rec.measured,
                            "errors": rec.errors(),
                        }
                    )
                    + "\n"
                )

"""Validator for exported ``trace_event`` JSON (CI gate).

Checks the structural invariants a trace viewer relies on:

* top level is ``{"traceEvents": [...]}`` and every event is an object
  with ``name``/``ph``/``pid``/``tid``/``ts``;
* ``X`` (complete) events carry ``dur >= 0`` and appear in
  non-decreasing ``ts`` order per ``(pid, tid)`` track;
* ``b``/``e`` (async) events pair up per ``(pid, cat, id)`` with
  LIFO nesting — every ``e`` closes the most recent open ``b`` of the
  same name, and nothing is left open at the end;
* all async ids referenced by ``e`` events resolve to an open span.

Usage::

    python -m repro.obs.validate trace.json

Exits 0 on a valid trace, 1 with one line per violation otherwise.
"""
from __future__ import annotations

import json
import sys


def validate_trace(trace: dict) -> list[str]:
    """Return a list of violation messages (empty = valid)."""
    errors: list[str] = []
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["top-level 'traceEvents' missing or not a list"]

    last_x_ts: dict[tuple, float] = {}
    open_async: dict[tuple, list[str]] = {}

    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        missing = [k for k in ("name", "ph", "pid", "tid", "ts") if k not in ev]
        if missing:
            errors.append(f"event {i}: missing fields {missing}")
            continue
        ph = ev["ph"]
        track = (ev["pid"], ev["tid"])
        if ph == "X":
            dur = ev.get("dur")
            if dur is None or dur < 0:
                errors.append(f"event {i} ({ev['name']}): X needs dur >= 0")
            ts = ev["ts"]
            if ts < last_x_ts.get(track, float("-inf")):
                errors.append(
                    f"event {i} ({ev['name']}): ts {ts} not monotone on "
                    f"track {track}"
                )
            last_x_ts[track] = ts
        elif ph in ("b", "e"):
            if "id" not in ev:
                errors.append(f"event {i} ({ev['name']}): async without id")
                continue
            key = (ev["pid"], ev.get("cat", ""), ev["id"])
            stack = open_async.setdefault(key, [])
            if ph == "b":
                stack.append(ev["name"])
            elif not stack:
                errors.append(
                    f"event {i} ({ev['name']}): 'e' with no open span for "
                    f"id {ev['id']}"
                )
            elif stack[-1] != ev["name"]:
                errors.append(
                    f"event {i}: 'e' for {ev['name']!r} but innermost open "
                    f"span is {stack[-1]!r} (bad nesting, id {ev['id']})"
                )
            else:
                stack.pop()
        elif ph != "M":
            errors.append(f"event {i} ({ev['name']}): unknown ph {ph!r}")

    for (pid, cat, sid), stack in open_async.items():
        if stack:
            errors.append(
                f"async id {sid} (pid {pid}, cat {cat!r}): unclosed spans "
                f"{stack}"
            )
    return errors


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 1:
        print("usage: python -m repro.obs.validate TRACE.json", file=sys.stderr)
        return 2
    with open(argv[0]) as f:
        trace = json.load(f)
    errors = validate_trace(trace)
    for e in errors:
        print(f"trace-invalid: {e}", file=sys.stderr)
    if not errors:
        n = len(trace["traceEvents"])
        print(f"trace ok: {n} events")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())

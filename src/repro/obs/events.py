"""Structured JSONL event log for the serving pipeline.

Low-frequency discrete events that spans and metrics don't capture well —
*why* a batch flushed, *which* cache entries were evicted, *who* coalesced
onto whom.  One JSON object per line, each carrying the event time ``t``
(virtual-clock seconds in open-loop, wall seconds in closed-loop — the
serving clock), the event name ``ev``, and event-specific fields:

    flush      reason=fill|deadline|drain, plan, n_real, shape
    dispatch   worker, plan, n_real
    complete   worker, plan, n_real, service_s
    evict      n (entries evicted by this insert)
    coalesce   qid (leader), idx (follower trace position)
    expire     n (coalesce windows closed past their reuse horizon)

Events are buffered in memory and written once at the end of the run;
the serving hot path only ever pays an ``append``.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field


@dataclass
class EventLog:
    events: list[dict] = field(default_factory=list)

    def emit(self, t: float, ev: str, **fields) -> None:
        self.events.append({"t": t, "ev": ev, **fields})

    def __len__(self) -> int:
        return len(self.events)

    def to_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for e in self.events:
                f.write(json.dumps(e) + "\n")

"""Metrics registry: counters, gauges, log-bucketed histograms.

The paper argues per-query cost accounting is what makes a geo engine's
algorithm choices defensible; this module is the serving stack's ledger
for exactly that.  Every pipeline stage (server, batcher, cache, pending
table, executors, planner) publishes into one :class:`MetricsRegistry`
under a stable dotted naming scheme:

    server.queries_total            counter   one per served query
    server.cache_hits_total         counter
    server.cache_misses_total       counter
    server.coalesced_total          counter   misses served by a twin
    server.latency_ms               histogram end-to-end latency
    server.batch_wait_ms            histogram arrival -> bucket flush
    server.queue_wait_ms            histogram flush -> worker pickup
    server.service_ms               histogram batch execution share
    batcher.flush_total{reason=}    counter   fill | deadline | drain
    batcher.batch_real_queries      histogram real rows per flushed batch
    batcher.pad_slots / real_slots  gauge     cumulative padding ledger
    cache.evictions_total           counter
    pending.expired_total           counter   coalesce windows closed
    executor.batches_total{plan=}   counter
    executor.<stat>_total{plan=}    counter   bytes_*, n_probes, seeks, ...
    executor.text_blocks_skipped_total{plan=}
                                    counter   driver posting blocks whose
                                              bytes never streamed (pruned
                                              TEXT-FIRST θ-skips; pair
                                              with text_blocks_total for
                                              the skip rate)
    executor.shards_touched{plan=}  histogram shard fan-out per routed query
                                              (footprint routing only;
                                              broadcast never emits it)
    engine.compiled_fns_total       counter   plan x shape jit programs
    planner.tp_span_probe           counter   block MBRs tested per query
                                              (bbox-grid candidates only)

Histograms are **log-bucketed**: bucket ``i`` covers
``[lo * growth^(i-1), lo * growth^i)`` so a fixed number of buckets spans
microseconds to minutes, and :meth:`Histogram.quantile` reconstructs any
percentile to within one bucket width of the exact order statistic — tight
enough that the serving report's ``percentile_ms`` and the histogram
export agree to the bucket (asserted in ``tests/test_telemetry.py``).

Exports: :meth:`MetricsRegistry.to_prometheus` (text exposition format)
and :meth:`MetricsRegistry.to_json` (one dict per metric, histograms with
explicit bucket bounds + reconstructed p50/p99).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field


def _label_key(labels: dict | None) -> tuple:
    return tuple(sorted(labels.items())) if labels else ()


def _label_str(key: tuple) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


@dataclass
class Counter:
    value: float = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount


@dataclass
class Gauge:
    value: float = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


@dataclass
class Histogram:
    """Log-bucketed histogram with exact-to-one-bucket quantiles.

    ``lo`` is the smallest resolvable value (everything at or below it
    lands in bucket 0); bucket widths grow geometrically by ``growth``.
    The defaults resolve 0.1 us to ~20 min when observing milliseconds,
    with ~19% relative bucket width (growth = 2^0.25).
    """

    lo: float = 1e-4
    growth: float = 2.0 ** 0.25
    counts: dict[int, int] = field(default_factory=dict)
    n: int = 0
    sum: float = 0.0

    def observe(self, value: float) -> None:
        i = self._index(value)
        self.counts[i] = self.counts.get(i, 0) + 1
        self.n += 1
        self.sum += value

    def _index(self, value: float) -> int:
        if value <= self.lo:
            return 0
        return int(math.log(value / self.lo) / math.log(self.growth)) + 1

    def bucket_bounds(self, i: int) -> tuple[float, float]:
        """``[lo_edge, hi_edge)`` of bucket ``i`` (bucket 0 is ``[0, lo)``)."""
        if i <= 0:
            return (0.0, self.lo)
        return (self.lo * self.growth ** (i - 1), self.lo * self.growth ** i)

    def quantile(self, p: float) -> float:
        """Percentile ``p`` in [0, 100], reconstructed from the buckets.

        Returns the geometric midpoint of the bucket holding the
        ``p``-th order statistic — within one bucket width of the exact
        (numpy linear-interpolated) percentile by construction.
        """
        if self.n == 0:
            return float("nan")
        target = p / 100.0 * (self.n - 1)
        cum = 0
        for i in sorted(self.counts):
            cum += self.counts[i]
            if cum > target:
                lo, hi = self.bucket_bounds(i)
                return math.sqrt(lo * hi) if lo > 0 else hi / 2.0
        lo, hi = self.bucket_bounds(max(self.counts))
        return math.sqrt(lo * hi) if lo > 0 else hi / 2.0

    def same_or_adjacent_bucket(self, value: float, other: float) -> bool:
        """True when two values fall in the same or neighboring buckets —
        the histogram-reconstruction accuracy contract."""
        return abs(self._index(value) - self._index(other)) <= 1


class MetricsRegistry:
    """Name + label-keyed store of counters / gauges / histograms."""

    def __init__(self) -> None:
        self._counters: dict[tuple, Counter] = {}
        self._gauges: dict[tuple, Gauge] = {}
        self._histograms: dict[tuple, Histogram] = {}

    # ------------------------------------------------------------------
    def counter(self, name: str, labels: dict | None = None) -> Counter:
        key = (name, _label_key(labels))
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = Counter()
        return c

    def gauge(self, name: str, labels: dict | None = None) -> Gauge:
        key = (name, _label_key(labels))
        g = self._gauges.get(key)
        if g is None:
            g = self._gauges[key] = Gauge()
        return g

    def histogram(self, name: str, labels: dict | None = None) -> Histogram:
        key = (name, _label_key(labels))
        h = self._histograms.get(key)
        if h is None:
            h = self._histograms[key] = Histogram()
        return h

    # convenience single-call forms (the serving hot path uses these)
    def inc(self, name: str, amount: float = 1.0, **labels) -> None:
        self.counter(name, labels or None).inc(amount)

    def set(self, name: str, value: float, **labels) -> None:
        self.gauge(name, labels or None).set(value)

    def observe(self, name: str, value: float, **labels) -> None:
        self.histogram(name, labels or None).observe(value)

    # ------------------------------------------------------------------
    # export
    # ------------------------------------------------------------------
    @staticmethod
    def _prom_name(name: str) -> str:
        return name.replace(".", "_").replace("-", "_")

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (counters, gauges, histograms
        with cumulative ``_bucket{le=}`` series)."""
        lines: list[str] = []
        typed: set[str] = set()

        def header(name: str, kind: str) -> None:
            if name not in typed:
                typed.add(name)
                lines.append(f"# TYPE {name} {kind}")

        for (name, lk), c in sorted(self._counters.items()):
            pn = self._prom_name(name)
            header(pn, "counter")
            lines.append(f"{pn}{_label_str(lk)} {c.value:g}")
        for (name, lk), g in sorted(self._gauges.items()):
            pn = self._prom_name(name)
            header(pn, "gauge")
            lines.append(f"{pn}{_label_str(lk)} {g.value:g}")
        for (name, lk), h in sorted(self._histograms.items()):
            pn = self._prom_name(name)
            header(pn, "histogram")
            cum = 0
            for i in sorted(h.counts):
                cum += h.counts[i]
                le = h.bucket_bounds(i)[1]
                lk_le = lk + (("le", f"{le:g}"),)
                lines.append(f"{pn}_bucket{_label_str(lk_le)} {cum}")
            lk_inf = lk + (("le", "+Inf"),)
            lines.append(f"{pn}_bucket{_label_str(lk_inf)} {h.n}")
            lines.append(f"{pn}_sum{_label_str(lk)} {h.sum:g}")
            lines.append(f"{pn}_count{_label_str(lk)} {h.n}")
        return "\n".join(lines) + "\n"

    def to_json(self) -> dict:
        """One JSON-serializable dict per metric; histograms carry explicit
        bucket bounds plus reconstructed p50/p99."""
        out: dict[str, dict] = {"counters": {}, "gauges": {}, "histograms": {}}
        for (name, lk), c in sorted(self._counters.items()):
            out["counters"][name + _label_str(lk)] = c.value
        for (name, lk), g in sorted(self._gauges.items()):
            out["gauges"][name + _label_str(lk)] = g.value
        for (name, lk), h in sorted(self._histograms.items()):
            out["histograms"][name + _label_str(lk)] = {
                "count": h.n,
                "sum": h.sum,
                "p50": h.quantile(50),
                "p99": h.quantile(99),
                "buckets": [
                    [*h.bucket_bounds(i), h.counts[i]] for i in sorted(h.counts)
                ],
            }
        return out

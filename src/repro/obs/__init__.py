"""Observability for the serving pipeline: metrics, spans, audit, events.

Everything hangs off one :class:`Telemetry` handle.  A server built with
``telemetry=None`` (the default) pays **zero** overhead — every hook in
the hot path is guarded by a single truthiness check and the telemetry
branches never run.  A server built with ``Telemetry()`` records:

* :class:`~repro.obs.metrics.MetricsRegistry` — counters / gauges /
  log-bucketed histograms from every pipeline stage (``.metrics``);
* :class:`~repro.obs.tracing.SpanRecorder` — per-query, per-batch and
  per-shard spans, exportable as Chrome/Perfetto trace JSON (``.tracer``);
* :class:`~repro.obs.audit.PlannerAudit` — predicted vs measured cost
  per planned query (``.audit``, only populated under ``algorithm=auto``);
* :class:`~repro.obs.events.EventLog` — flush/dispatch/complete/evict/
  coalesce/expire JSONL events (``.events``).

Each component can be disabled individually (pass ``None``); the handle
is falsy only when *all* components are off.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .audit import COST_KEYS, AuditRecord, PlannerAudit
from .events import EventLog
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .tracing import BatchSpan, ExecSpan, QuerySpan, SpanRecorder
from .validate import validate_trace

__all__ = [
    "Telemetry",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "SpanRecorder",
    "QuerySpan",
    "BatchSpan",
    "ExecSpan",
    "PlannerAudit",
    "AuditRecord",
    "COST_KEYS",
    "EventLog",
    "validate_trace",
]


def _default_metrics():
    return MetricsRegistry()


def _default_tracer():
    return SpanRecorder()


def _default_audit():
    return PlannerAudit()


def _default_events():
    return EventLog()


@dataclass
class Telemetry:
    """Bundle of all telemetry sinks; pass to ``GeoServer(telemetry=...)``."""

    metrics: MetricsRegistry | None = field(default_factory=_default_metrics)
    tracer: SpanRecorder | None = field(default_factory=_default_tracer)
    audit: PlannerAudit | None = field(default_factory=_default_audit)
    events: EventLog | None = field(default_factory=_default_events)

    def __bool__(self) -> bool:
        return (
            self.metrics is not None
            or self.tracer is not None
            or self.audit is not None
            or self.events is not None
        )

"""Per-query span tracing, exported as Chrome/Perfetto ``trace_event`` JSON.

Every query served by :class:`~repro.serving.server.GeoServer` records one
**query span** — arrival to completion — decomposed into the same three
contiguous stage spans the serving report measures:

    query ............................. [arrival, done)
      batch_wait ...................... [arrival, flush)      (miss only)
      queue_wait ...................... [flush, worker start)
      service ......................... [start, done)
      lookup .......................... [arrival, done)       (cache hit)

Stage boundaries are reconstructed from the *exact* batch-wait /
queue-wait / service values the report records, so the span sums equal the
report's latency decomposition to the bit (property-tested in
``tests/test_telemetry.py``).  Timestamps are **virtual-clock** seconds in
open-loop replay and wall-clock seconds in closed-loop replay — the same
clock the report itself uses.

Two additional span families share the file:

* **batch spans** — one per executed batch on its worker's track
  (``worker 0..N-1``); per-worker timelines are sequential, so each track
  is monotone (validated by :mod:`repro.obs.validate`).
* **executor spans** — wall-clock spans measured *inside* the executors
  (per-shard spans of :class:`~repro.serving.executor.ShardedExecutor`'s
  sequential scatter-gather loop, the mesh step, the single-device engine
  call).  They live in a separate trace process ("executors (wall clock)")
  because open-loop virtual time and host wall time are different clock
  domains; mixing them on one track would be a lie.

Export targets the ``trace_event`` JSON array format (Chrome's
``chrome://tracing`` and Perfetto's https://ui.perfetto.dev both open it
directly): query spans are async events (``ph: b/e`` keyed by a unique
id), batch/executor spans are complete events (``ph: X``), and metadata
events name the processes and threads.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field

# trace process ids: virtual-clock serving timeline vs wall-clock executors
PID_SERVING = 1
PID_EXECUTOR = 2
TID_QUERIES = 1
TID_WORKER0 = 10  # worker w -> tid TID_WORKER0 + w


@dataclass
class QuerySpan:
    """One served query: arrival time + exact stage durations (seconds)."""

    qid: int  # server query id (-1 for cache hits: never enqueued)
    idx: int  # trace position
    kind: str  # "hit" | "executed" | "coalesced"
    label: str | None  # plan label (None = fixed-algorithm serving)
    t0: float  # arrival (virtual or wall seconds)
    latency: float  # end-to-end, as recorded (bit-identical to the report)
    batch_wait: float
    queue_wait: float
    service: float
    args: dict | None = None

    @property
    def total(self) -> float:
        return self.latency

    def boundaries(self) -> tuple[float, float, float, float]:
        """Contiguous stage edges: (arrival, flush, start, done)."""
        b1 = self.t0 + self.batch_wait
        b2 = b1 + self.queue_wait
        return self.t0, b1, b2, b2 + self.service


@dataclass
class BatchSpan:
    worker: int
    flush_t: float
    start_t: float
    done_t: float
    label: str | None
    n_real: int
    shape: tuple  # (batch, d_terms, q_rects)


@dataclass
class ExecSpan:
    track: str  # e.g. "shard 3", "engine", "mesh step"
    name: str
    t0: float  # wall seconds relative to recorder start
    t1: float
    args: dict | None = None


@dataclass
class SpanRecorder:
    """Accumulates query / batch / executor spans for one or more runs."""

    queries: list[QuerySpan] = field(default_factory=list)
    batches: list[BatchSpan] = field(default_factory=list)
    exec_spans: list[ExecSpan] = field(default_factory=list)

    def __post_init__(self) -> None:
        self._wall_t0 = time.perf_counter()
        # per-qid args staged before the query's span is recorded (the
        # server learns fingerprint/plan timings at enqueue, stage
        # durations only at completion)
        self._pending_args: dict[int, dict] = {}

    # ------------------------------------------------------------------
    def wall_now(self) -> float:
        """Wall-clock seconds since recorder creation (executor spans)."""
        return time.perf_counter() - self._wall_t0

    def annotate(self, qid: int, **args) -> None:
        """Attach args to a not-yet-completed query (by server qid)."""
        self._pending_args.setdefault(qid, {}).update(args)

    def query(
        self,
        qid: int,
        idx: int,
        kind: str,
        label: str | None,
        t0: float,
        latency: float,
        batch_wait: float,
        queue_wait: float,
        service: float,
    ) -> None:
        self.queries.append(
            QuerySpan(
                qid, idx, kind, label, t0, latency,
                batch_wait, queue_wait, service,
                args=self._pending_args.pop(qid, None),
            )
        )

    def batch(
        self,
        worker: int,
        flush_t: float,
        start_t: float,
        done_t: float,
        label: str | None,
        n_real: int,
        shape: tuple,
    ) -> None:
        self.batches.append(
            BatchSpan(worker, flush_t, start_t, done_t, label, n_real, shape)
        )

    def span(self, track: str, name: str, t0: float, t1: float, args=None) -> None:
        self.exec_spans.append(ExecSpan(track, name, t0, t1, args))

    # ------------------------------------------------------------------
    # export
    # ------------------------------------------------------------------
    def to_trace_events(self) -> dict:
        """The Chrome/Perfetto ``trace_event`` JSON object."""
        us = 1e6
        ev: list[dict] = [
            _meta("process_name", PID_SERVING, 0, "serving (virtual clock)"),
            _meta("thread_name", PID_SERVING, TID_QUERIES, "queries"),
        ]
        workers = sorted({b.worker for b in self.batches})
        for w in workers:
            ev.append(
                _meta("thread_name", PID_SERVING, TID_WORKER0 + w, f"worker {w}")
            )
        if self.exec_spans:
            ev.append(
                _meta("process_name", PID_EXECUTOR, 0, "executors (wall clock)")
            )
        exec_tids: dict[str, int] = {}
        for s in self.exec_spans:
            if s.track not in exec_tids:
                tid = len(exec_tids) + 1
                exec_tids[s.track] = tid
                ev.append(_meta("thread_name", PID_EXECUTOR, tid, s.track))

        for span_id, q in enumerate(self.queries):
            t_arr, t_flush, t_start, t_done = q.boundaries()
            args = {"idx": q.idx, "kind": q.kind}
            if q.label is not None:
                args["plan"] = q.label
            if q.args:
                args.update(q.args)
            base = {"cat": "query", "id": span_id, "pid": PID_SERVING,
                    "tid": TID_QUERIES}
            ev.append(
                {"name": "query", "ph": "b", "ts": t_arr * us, "args": args,
                 **base}
            )
            stages = (
                [("lookup", t_arr, t_done)]
                if q.kind == "hit"
                else [
                    ("batch_wait", t_arr, t_flush),
                    ("queue_wait", t_flush, t_start),
                    ("service", t_start, t_done),
                ]
            )
            for name, s0, s1 in stages:
                ev.append({"name": name, "ph": "b", "ts": s0 * us, **base})
                ev.append({"name": name, "ph": "e", "ts": s1 * us, **base})
            ev.append({"name": "query", "ph": "e", "ts": t_done * us, **base})

        for b in self.batches:
            name = f"batch[{b.label}]" if b.label else "batch"
            ev.append(
                {
                    "name": name, "ph": "X", "pid": PID_SERVING,
                    "tid": TID_WORKER0 + b.worker,
                    "ts": b.start_t * us, "dur": (b.done_t - b.start_t) * us,
                    "args": {
                        "flush_t_s": b.flush_t, "n_real": b.n_real,
                        "shape": list(b.shape),
                    },
                }
            )
        for s in self.exec_spans:
            ev.append(
                {
                    "name": s.name, "ph": "X", "pid": PID_EXECUTOR,
                    "tid": exec_tids[s.track],
                    "ts": s.t0 * us, "dur": (s.t1 - s.t0) * us,
                    **({"args": s.args} if s.args else {}),
                }
            )
        return {"traceEvents": ev, "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_trace_events(), f)

    # ------------------------------------------------------------------
    # report cross-checks (the serving report derives from these spans)
    # ------------------------------------------------------------------
    def stage_sums(self) -> tuple[list[float], list[float], list[float], list[float]]:
        """Per-query (total, batch_wait, queue_wait, service) in record
        order — must equal the serving report's four lists exactly."""
        return (
            [q.total for q in self.queries],
            [q.batch_wait for q in self.queries],
            [q.queue_wait for q in self.queries],
            [q.service for q in self.queries],
        )


def _meta(name: str, pid: int, tid: int, value: str) -> dict:
    return {
        "name": name, "ph": "M", "pid": pid, "tid": tid, "ts": 0,
        "args": {"name": value},
    }

from repro.corpus.synth import (
    SynthCorpus,
    TraceQuery,
    make_corpus,
    make_query_trace,
    make_uniform_trace,
    make_zipf_trace,
)

__all__ = [
    "SynthCorpus",
    "TraceQuery",
    "make_corpus",
    "make_query_trace",
    "make_uniform_trace",
    "make_zipf_trace",
]

from repro.corpus.synth import (
    ARRIVAL_KINDS,
    SynthCorpus,
    TraceQuery,
    make_arrivals,
    make_corpus,
    make_mixture_trace,
    make_query_trace,
    make_uniform_trace,
    make_zipf_trace,
    pad_trace_batch,
    stamp_arrivals,
    term_document_frequencies,
)

__all__ = [
    "ARRIVAL_KINDS",
    "SynthCorpus",
    "TraceQuery",
    "make_arrivals",
    "make_corpus",
    "make_mixture_trace",
    "make_query_trace",
    "make_uniform_trace",
    "make_zipf_trace",
    "pad_trace_batch",
    "stamp_arrivals",
    "term_document_frequencies",
]

from repro.corpus.synth import SynthCorpus, make_corpus, make_query_trace

__all__ = ["SynthCorpus", "make_corpus", "make_query_trace"]

"""Synthetic geo web corpus + query traces.

Models the workload of the paper's evaluation (a national-domain crawl with
extracted footprints, plus a realistic geographic query trace):

* **Places**: ``n_cities`` city centers in the unit square with power-law
  populations; each city has a radius ~ sqrt(population).
* **Documents**: term ids drawn from a Zipf distribution over ``n_terms``;
  each document is "about" 1–3 places — its footprint is 1..R rectangles
  around those places (complete-address-style small rects with high
  amplitude, town-name-style larger rects with low amplitude — paper fig. 1
  split footprints).  A fraction of documents is non-geographic (empty
  footprint never happens here: the paper's engine only indexes docs with
  footprints; non-geo docs get a country-wide low-amplitude rect).
* **Queries**: ``d`` terms from the same Zipf head + a footprint around a
  random city with town/city/region extent.
* **Traces** (``make_zipf_trace``): a *stream* of variable-width queries
  with Zipf-skewed repetition over a finite pool of distinct searches and
  geographic hot-spot locality — the workload shape the serving layer's
  cache and batcher are designed for.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from repro.core.algorithms import QueryBatch
import jax.numpy as jnp


@dataclass
class SynthCorpus:
    doc_terms: list[np.ndarray]
    doc_rects: np.ndarray  # [N, R, 4]
    doc_amps: np.ndarray  # [N, R]
    pagerank: np.ndarray  # [N]
    n_terms: int
    cities: np.ndarray  # [C, 3]: x, y, radius


def make_corpus(
    n_docs: int = 2000,
    n_terms: int = 500,
    n_cities: int = 32,
    max_rects: int = 4,
    doc_len: int = 32,
    zipf_a: float = 1.3,
    seed: int = 0,
) -> SynthCorpus:
    rng = np.random.default_rng(seed)
    # cities: power-law sizes
    cx = rng.uniform(0.05, 0.95, n_cities)
    cy = rng.uniform(0.05, 0.95, n_cities)
    pop = rng.zipf(1.5, n_cities).astype(np.float64)
    pop = pop / pop.max()
    radius = 0.01 + 0.06 * np.sqrt(pop)
    cities = np.stack([cx, cy, radius], axis=1).astype(np.float32)
    city_p = pop / pop.sum()

    # documents
    doc_terms = []
    rects = np.zeros((n_docs, max_rects, 4), dtype=np.float32)
    rects[:, :, 0] = 1.0  # empty-rect padding (x1 < x0)
    rects[:, :, 1] = 1.0
    amps = np.zeros((n_docs, max_rects), dtype=np.float32)
    for i in range(n_docs):
        terms = np.minimum(rng.zipf(zipf_a, doc_len) - 1, n_terms - 1)
        doc_terms.append(terms.astype(np.int32))
        n_places = rng.integers(1, max_rects + 1)
        chosen = rng.choice(n_cities, size=n_places, p=city_p, replace=True)
        for j, c in enumerate(chosen):
            x, y, r = cities[c]
            # address-style small rect (high amp) or town-style larger (low amp)
            if rng.random() < 0.5:
                w = r * rng.uniform(0.05, 0.2)
                amp = rng.uniform(0.7, 1.0)
            else:
                w = r * rng.uniform(0.5, 1.5)
                amp = rng.uniform(0.2, 0.6)
            px = np.clip(x + rng.normal(0, r / 2), 0.001, 0.999)
            py = np.clip(y + rng.normal(0, r / 2), 0.001, 0.999)
            x0, x1 = np.clip(px - w, 0, 1), np.clip(px + w, 0, 1)
            y0, y1 = np.clip(py - w, 0, 1), np.clip(py + w, 0, 1)
            if x1 <= x0 or y1 <= y0:
                continue
            rects[i, j] = (x0, y0, x1, y1)
            amps[i, j] = amp

    pagerank = rng.pareto(2.0, n_docs).astype(np.float32)
    pagerank = pagerank / max(pagerank.max(), 1e-9)
    return SynthCorpus(doc_terms, rects, amps, pagerank, n_terms, cities)


def make_query_trace(
    corpus: SynthCorpus,
    n_queries: int = 64,
    d_terms: int = 4,
    q_rects: int = 2,
    zipf_a: float = 1.3,
    seed: int = 1,
    from_docs: bool = True,
) -> QueryBatch:
    """Query trace: terms + footprints around cities.

    ``from_docs=True`` samples query terms from a random document (queries
    correlate with content, every conjunction has ≥ 1 match — realistic
    trace); otherwise draws independent Zipf terms.  Extents mix town
    (~0.3·r), city (~1·r) and region (~3·r) scales, matching the paper's
    town/city/region query classes.
    """
    rng = np.random.default_rng(seed)
    n_cities = len(corpus.cities)
    terms = np.full((n_queries, d_terms), -1, dtype=np.int32)
    rects = np.zeros((n_queries, q_rects, 4), dtype=np.float32)
    rects[:, :, 0] = 1.0
    rects[:, :, 1] = 1.0
    amps = np.zeros((n_queries, q_rects), dtype=np.float32)
    scales = np.array([0.3, 1.0, 3.0])
    for i in range(n_queries):
        nt = rng.integers(1, d_terms + 1)
        if from_docs:
            doc = corpus.doc_terms[rng.integers(0, len(corpus.doc_terms))]
            t = np.unique(rng.choice(doc, size=min(nt, len(doc)), replace=False))
        else:
            t = np.unique(np.minimum(rng.zipf(zipf_a, nt) - 1, corpus.n_terms - 1))
        terms[i, : len(t)] = t
        c = rng.integers(0, n_cities)
        x, y, r = corpus.cities[c]
        nr = rng.integers(1, q_rects + 1)
        for j in range(nr):
            w = r * scales[rng.integers(0, 3)] * rng.uniform(0.5, 1.0)
            px = np.clip(x + rng.normal(0, r / 4), 0.001, 0.999)
            py = np.clip(y + rng.normal(0, r / 4), 0.001, 0.999)
            x0, x1 = np.clip(px - w, 0, 1), np.clip(px + w, 0, 1)
            y0, y1 = np.clip(py - w, 0, 1), np.clip(py + w, 0, 1)
            if x1 <= x0 or y1 <= y0:
                continue
            rects[i, j] = (x0, y0, x1, y1)
            amps[i, j] = 1.0
    return QueryBatch(
        terms=jnp.asarray(terms), rects=jnp.asarray(rects), amps=jnp.asarray(amps)
    )


@dataclass
class TraceQuery:
    """One un-padded query in a serving trace (variable widths).

    ``arrival_s`` stamps when the query enters the system (seconds from
    trace start).  Closed-loop replay ignores it; open-loop replay
    (:meth:`repro.serving.server.GeoServer.run_trace` with
    ``arrival != "closed"``) releases queries at these times regardless of
    server progress, which is what makes tail latency under load visible.
    """

    terms: np.ndarray  # i32[d], no padding
    rects: np.ndarray  # f32[r, 4]
    amps: np.ndarray  # f32[r]
    arrival_s: float = 0.0


def _one_query(
    rng, corpus: SynthCorpus, city: int, d_terms: int, q_rects: int,
    scales: tuple = (0.3, 1.0, 3.0),
):
    """Sample one variable-width query about ``city`` (terms from a doc)."""
    nt = int(rng.integers(1, d_terms + 1))
    doc = corpus.doc_terms[rng.integers(0, len(corpus.doc_terms))]
    terms = np.unique(rng.choice(doc, size=min(nt, len(doc)), replace=False))
    x, y, r = corpus.cities[city]
    scales = np.asarray(scales)
    rects, amps = [], []
    for _ in range(int(rng.integers(1, q_rects + 1))):
        w = r * scales[rng.integers(0, len(scales))] * rng.uniform(0.5, 1.0)
        px = np.clip(x + rng.normal(0, r / 4), 0.001, 0.999)
        py = np.clip(y + rng.normal(0, r / 4), 0.001, 0.999)
        x0, x1 = np.clip(px - w, 0, 1), np.clip(px + w, 0, 1)
        y0, y1 = np.clip(py - w, 0, 1), np.clip(py + w, 0, 1)
        if x1 <= x0 or y1 <= y0:
            continue
        rects.append((x0, y0, x1, y1))
        amps.append(1.0)
    if not rects:  # degenerate draw: whole-city rect
        rects, amps = [(x - r, y - r, x + r, y + r)], [1.0]
    return TraceQuery(
        terms=terms.astype(np.int32),
        rects=np.asarray(rects, dtype=np.float32),
        amps=np.asarray(amps, dtype=np.float32),
    )


def make_zipf_trace(
    corpus: SynthCorpus,
    n_queries: int = 2048,
    pool_size: int = 256,
    zipf_a: float = 1.1,
    hot_frac: float = 0.8,
    n_hot_cities: int = 4,
    d_terms: int = 4,
    q_rects: int = 2,
    seed: int = 1,
    scales: tuple = (0.3, 1.0, 3.0),
) -> list[TraceQuery]:
    """Skewed serving trace: Zipf repetition + geographic hot spots.

    A pool of ``pool_size`` distinct queries is built first; ``hot_frac``
    of them are about one of the ``n_hot_cities`` largest cities (the
    paper's observation that geographic query load concentrates on big
    population centers).  The trace then samples the pool with Zipf(``a``)
    rank skew, so head queries repeat heavily — the regime where a result
    cache pays for itself — while the tail keeps the batcher honest.

    ``scales`` sets the footprint-extent mix in city radii; the default
    matches the paper's town (0.3·r) / city (1·r) / region (3·r) query
    classes, and ``scales=(1.0,)`` pins a city-sized workload (the
    footprint-routing benches).
    """
    rng = np.random.default_rng(seed)
    hot = np.argsort(-corpus.cities[:, 2])[:n_hot_cities]
    pool = []
    for _ in range(pool_size):
        if rng.random() < hot_frac:
            city = int(hot[rng.integers(0, len(hot))])
        else:
            city = int(rng.integers(0, len(corpus.cities)))
        pool.append(_one_query(rng, corpus, city, d_terms, q_rects, scales))
    # Zipf over pool ranks (rejection-free: clip the unbounded tail)
    ranks = np.minimum(rng.zipf(zipf_a, n_queries) - 1, pool_size - 1)
    return [pool[r] for r in ranks]


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

ARRIVAL_KINDS = ("closed", "poisson", "bursty", "diurnal")


def make_arrivals(
    kind: str,
    n: int,
    rate_qps: float = 200.0,
    seed: int = 0,
    burst_factor: float = 4.0,
    on_frac: float = 0.1,
    diurnal_period_s: float = 60.0,
    diurnal_depth: float = 0.8,
) -> np.ndarray:
    """Arrival-time stamps (seconds, non-decreasing, f64[n]) for a stream.

    * ``closed``  — all zeros; the replay loop ignores them (next query is
      released when the previous one finishes — PR 1 behavior).
    * ``poisson`` — open-loop Poisson process at ``rate_qps``: i.i.d.
      exponential inter-arrivals, the memoryless baseline load model.
    * ``bursty``  — two-state MMPP (on/off Markov-modulated Poisson): an ON
      state firing at ``burst_factor × rate_qps`` for ~``on_frac`` of the
      time, and an OFF state at the complementary rate so the *mean* rate
      stays ``rate_qps``.  Dwell times in each state are exponential with
      mean ``diurnal_period_s / 10`` (bursts are short relative to the
      diurnal swing).  This is the flash-crowd regime where deadline-based
      flushing earns its keep.
    * ``diurnal`` — inhomogeneous Poisson with a sinusoidal rate profile
      ``rate_qps · (1 + diurnal_depth · sin(2πt / diurnal_period_s))``,
      generated by thinning; models the day/night swing of a geoportal.

    ``burst_factor · on_frac`` must be < 1 so the OFF rate stays positive.
    """
    if kind not in ARRIVAL_KINDS:
        raise ValueError(f"unknown arrival kind {kind!r}; want one of {ARRIVAL_KINDS}")
    if kind == "closed":
        return np.zeros(n, dtype=np.float64)
    if rate_qps <= 0:
        raise ValueError("rate_qps must be > 0 for open-loop arrivals")
    rng = np.random.default_rng(seed)
    if kind == "poisson":
        return np.cumsum(rng.exponential(1.0 / rate_qps, n))
    if kind == "bursty":
        if not 0.0 < on_frac < 1.0:
            raise ValueError("on_frac must be in (0, 1)")
        if burst_factor * on_frac >= 1.0:
            raise ValueError("burst_factor * on_frac must be < 1 (mean-rate budget)")
        rate_on = burst_factor * rate_qps
        rate_off = (1.0 - burst_factor * on_frac) * rate_qps / (1.0 - on_frac)
        mean_dwell = diurnal_period_s / 10.0
        out = np.empty(n, dtype=np.float64)
        t, i, on = 0.0, 0, False
        state_end = t + rng.exponential(mean_dwell * (1.0 - on_frac))
        while i < n:
            rate = rate_on if on else rate_off
            nxt = t + rng.exponential(1.0 / rate)
            if nxt >= state_end:
                # no arrival before the state switch; restart the clock in
                # the new state (exponential dwell ⇒ memoryless, so this is
                # an exact simulation, not an approximation)
                t, on = state_end, not on
                state_end = t + rng.exponential(
                    mean_dwell * (on_frac if on else 1.0 - on_frac)
                )
                continue
            t = nxt
            out[i] = t
            i += 1
        return out
    # diurnal: thinning against the peak rate
    rate_max = rate_qps * (1.0 + diurnal_depth)
    out = np.empty(n, dtype=np.float64)
    t, i = 0.0, 0
    while i < n:
        t += rng.exponential(1.0 / rate_max)
        rate_t = rate_qps * (
            1.0 + diurnal_depth * np.sin(2.0 * np.pi * t / diurnal_period_s)
        )
        if rng.random() * rate_max < rate_t:
            out[i] = t
            i += 1
    return out


def stamp_arrivals(
    trace: list[TraceQuery],
    kind: str = "poisson",
    rate_qps: float = 200.0,
    seed: int = 0,
    **kw,
) -> list[TraceQuery]:
    """Return a copy of ``trace`` with ``arrival_s`` stamped by ``kind``."""
    times = make_arrivals(kind, len(trace), rate_qps=rate_qps, seed=seed, **kw)
    return [replace(q, arrival_s=float(t)) for q, t in zip(trace, times)]


def term_document_frequencies(corpus: SynthCorpus) -> np.ndarray:
    """Per-term document frequency (docs containing the term), f64[n_terms]."""
    df = np.zeros((corpus.n_terms,), dtype=np.float64)
    for terms in corpus.doc_terms:
        np.add.at(df, np.unique(terms), 1.0)
    return df


def make_mixture_trace(
    corpus: SynthCorpus,
    n_queries: int = 2048,
    rare_frac: float = 0.5,
    rare_df_max: int = 4,
    hot_quantile: float = 0.92,
    seed: int = 1,
) -> list[TraceQuery]:
    """Bimodal term-selectivity × footprint-area workload (planner stressor).

    Two query populations, mixed ``rare_frac`` / ``1 - rare_frac``:

    * **rare + huge** — one very rare term (df ≤ ``rare_df_max``) over a
      country-sized footprint.  The inverted index pins the answer set to a
      handful of docs while the spatial structures see almost the whole
      toe-print store: TEXT-FIRST territory, and catastrophic for GEO-FIRST
      / K-SWEEP (they stream/enumerate nearly everything).
    * **hot + tiny** — 2–3 of the collection's hottest terms (df above the
      ``hot_quantile``) over a city-block footprint centered on a real
      document's footprint (so the conjunction has a co-located match).
      Anchor documents are drawn from the *sparse* tail of the geographic
      density distribution — where the tile grid's intervals are tight —
      so the spatial index pins the candidates to a few toe prints while
      every posting list is huge: GEO-FIRST territory, and wasteful for
      TEXT-FIRST (its driver list is long regardless of the footprint).

    No single fixed algorithm is close to per-query selection on this
    workload — the cost-based planner's acceptance trace
    (``benchmarks/run.py::planner_mixture_*``).
    """
    rng = np.random.default_rng(seed)
    df = term_document_frequencies(corpus)
    rare_terms = np.nonzero((df >= 1) & (df <= rare_df_max))[0]
    if len(rare_terms) == 0:  # tiny corpora: fall back to the rarest decile
        order = np.argsort(df + np.where(df < 1, np.inf, 0.0))
        rare_terms = order[: max(corpus.n_terms // 10, 1)]
    hot_cut = np.quantile(df[df > 0], hot_quantile)
    hot_set = set(np.nonzero(df >= max(hot_cut, 2))[0].tolist())
    # geographic crowding per cell: how many footprint rects INTERSECT each
    # cell of a coarse grid (2D difference trick + cumsum = integral image).
    # Hot+tiny queries anchor on doc rects in the emptiest cells — exactly
    # where the tile grid's intervals are tight and a spatial-first plan
    # touches a handful of toe prints.
    G = 64
    N, R, _ = corpus.doc_rects.shape
    rects_flat = corpus.doc_rects.reshape(-1, 4)
    valid_flat = rects_flat[:, 2] > rects_flat[:, 0]
    vx0 = np.clip((rects_flat[:, 0] * G).astype(np.int64), 0, G - 1)
    vy0 = np.clip((rects_flat[:, 1] * G).astype(np.int64), 0, G - 1)
    vx1 = np.clip((rects_flat[:, 2] * G).astype(np.int64), 0, G - 1)
    vy1 = np.clip((rects_flat[:, 3] * G).astype(np.int64), 0, G - 1)
    diff = np.zeros((G + 1, G + 1))
    w = valid_flat.astype(np.float64)
    np.add.at(diff, (vy0, vx0), w)
    np.add.at(diff, (vy1 + 1, vx0), -w)
    np.add.at(diff, (vy0, vx1 + 1), -w)
    np.add.at(diff, (vy1 + 1, vx1 + 1), w)
    crowd = diff.cumsum(axis=0).cumsum(axis=1)[:G, :G]  # [iy, ix]
    # per doc: its least-crowded valid rect (anchor) and that crowding
    cx = ((rects_flat[:, 0] + rects_flat[:, 2]) * 0.5 * G).astype(np.int64)
    cy = ((rects_flat[:, 1] + rects_flat[:, 3]) * 0.5 * G).astype(np.int64)
    rect_crowd = np.where(
        valid_flat,
        crowd[np.clip(cy, 0, G - 1), np.clip(cx, 0, G - 1)],
        np.inf,
    ).reshape(N, R)
    anchor_rect = rect_crowd.argmin(axis=1)
    anchor_crowd = rect_crowd.min(axis=1)
    finite = np.isfinite(anchor_crowd)
    cut = np.quantile(anchor_crowd[finite], 0.15) if finite.any() else np.inf
    quiet_docs = np.nonzero(finite & (anchor_crowd <= cut))[0]
    if len(quiet_docs) == 0:
        quiet_docs = np.nonzero(finite)[0]
    out = []
    for _ in range(n_queries):
        if rng.random() < rare_frac:
            # rare + huge: one rare term, near-domain-wide footprint
            t = np.array([rare_terms[rng.integers(0, len(rare_terms))]], np.int32)
            w = rng.uniform(0.25, 0.45)
            qx, qy = rng.uniform(0.35, 0.65, 2)
            rect = (
                max(qx - w, 0.0), max(qy - w, 0.0),
                min(qx + w, 1.0), min(qy + w, 1.0),
            )
        else:
            # hot + tiny: the doc's hottest terms, city-block footprint at
            # the doc's least-crowded footprint rect (guaranteed overlap,
            # tight tile intervals)
            while True:
                d_i = int(quiet_docs[rng.integers(0, len(quiet_docs))])
                cand = np.unique(corpus.doc_terms[d_i])
                hot = cand[np.isin(cand, list(hot_set))] if hot_set else cand
                if len(hot) == 0:  # fall back to the doc's highest-df terms
                    hot = cand[np.argsort(-df[cand])][:3]
                if len(hot):
                    break
            nt = int(rng.integers(2, 4))
            t = np.sort(rng.choice(hot, size=min(nt, len(hot)), replace=False))
            r0 = corpus.doc_rects[d_i, anchor_rect[d_i]]
            qx = float((r0[0] + r0[2]) * 0.5)
            qy = float((r0[1] + r0[3]) * 0.5)
            w = rng.uniform(0.002, 0.006)
            rect = (
                max(qx - w, 0.0), max(qy - w, 0.0),
                min(qx + w, 1.0), min(qy + w, 1.0),
            )
        out.append(
            TraceQuery(
                terms=t.astype(np.int32),
                rects=np.asarray([rect], dtype=np.float32),
                amps=np.ones((1,), dtype=np.float32),
            )
        )
    return out


def make_uniform_trace(
    corpus: SynthCorpus,
    n_queries: int = 2048,
    d_terms: int = 4,
    q_rects: int = 2,
    seed: int = 1,
) -> list[TraceQuery]:
    """Adversarial trace for the cache: every query distinct, no locality."""
    rng = np.random.default_rng(seed)
    return [
        _one_query(
            rng, corpus, int(rng.integers(0, len(corpus.cities))), d_terms, q_rects
        )
        for _ in range(n_queries)
    ]


def pad_trace_batch(
    trace: list[TraceQuery],
    max_terms: int = 8,
    max_rects: int = 4,
) -> QueryBatch:
    """Pad a serving trace into one fixed-shape :class:`QueryBatch`.

    The core-algorithm analogue of the serving batcher's padding — lets
    benchmarks and tests drive ``GeoSearchEngine.query`` directly with the
    same zipf/uniform traces the serving layer replays."""
    B = len(trace)
    terms = np.full((B, max_terms), -1, dtype=np.int32)
    rects = np.tile(
        np.array([1.0, 1.0, 0.0, 0.0], np.float32), (B, max_rects, 1)
    )
    amps = np.zeros((B, max_rects), dtype=np.float32)
    for i, q in enumerate(trace):
        t = q.terms[:max_terms]
        terms[i, : len(t)] = t
        r = q.rects[:max_rects]
        rects[i, : len(r)] = r
        amps[i, : len(r)] = q.amps[: len(r)]
    return QueryBatch(
        terms=jnp.asarray(terms), rects=jnp.asarray(rects), amps=jnp.asarray(amps)
    )

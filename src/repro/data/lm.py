"""Deterministic synthetic LM token pipeline.

Keyed by (seed, step) so that a restarted job replays identical batches —
the property checkpoint-resume tests assert (DESIGN.md §5 fault tolerance).
A light Markov structure makes the loss meaningfully decreasable (unlike
uniform noise) so the end-to-end training example shows learning.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class LMDataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_clusters: int = 64  # markov structure


def lm_batch(cfg: LMDataConfig, step: int) -> dict:
    """Batch for ``step`` — pure function of (cfg.seed, step)."""
    key = jax.random.fold_in(jax.random.key(cfg.seed), step)
    k1, k2 = jax.random.split(key)
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab
    # cluster walk: each position's cluster = prev cluster + small step
    steps = jax.random.randint(k1, (B, S), -1, 2)
    clusters = jnp.cumsum(steps, axis=1) % cfg.n_clusters
    within = jax.random.randint(k2, (B, S), 0, max(V // cfg.n_clusters, 1))
    tokens = (clusters * (V // cfg.n_clusters) + within) % V
    tokens = tokens.astype(jnp.int32)
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.full((B, 1), -1, jnp.int32)], axis=1
    )
    return {"tokens": tokens, "labels": labels}


def lm_input_specs(cfg: LMDataConfig) -> dict:
    return {
        "tokens": jax.ShapeDtypeStruct((cfg.global_batch, cfg.seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((cfg.global_batch, cfg.seq_len), jnp.int32),
    }

"""Graph data: synthetic power-law graphs, a REAL neighbor sampler
(fanout-based, GraphSAGE-style), and batched small molecules.

The ``minibatch_lg`` shape (Reddit-scale: 233k nodes / 115M edges, fanout
15-10, batch_nodes=1024) requires genuine sampled-subgraph training — the
sampler below builds a CSR adjacency once and then draws per-step padded
subgraphs (numpy host-side, like a real input pipeline worker).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp


@dataclass
class CSRGraph:
    indptr: np.ndarray  # i64[N+1]
    indices: np.ndarray  # i32[nnz]
    feats: np.ndarray  # f32[N, F]
    coords: np.ndarray  # f32[N, C]
    labels: np.ndarray  # i32[N]

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1


def make_powerlaw_graph(
    n_nodes: int,
    n_edges: int,
    d_feat: int,
    n_classes: int = 8,
    coord_dim: int = 3,
    seed: int = 0,
) -> CSRGraph:
    """Hub-biased random graph (degree ~ power law), CSR adjacency."""
    rng = np.random.default_rng(seed)
    # hub bias: endpoint sampled with prob ∝ zipf rank weight
    w = 1.0 / np.arange(1, n_nodes + 1) ** 0.75
    w /= w.sum()
    src = rng.choice(n_nodes, size=n_edges, p=w).astype(np.int32)
    dst = rng.integers(0, n_nodes, size=n_edges).astype(np.int32)
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    feats = rng.normal(0, 1, (n_nodes, d_feat)).astype(np.float32)
    coords = rng.uniform(0, 1, (n_nodes, coord_dim)).astype(np.float32)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    return CSRGraph(indptr, dst, feats, coords, labels)


def pad_edges(n_edges: int, multiple: int = 512) -> int:
    """Edges padded so the edge dim shards over a full 512-chip mesh."""
    return (n_edges + multiple - 1) // multiple * multiple


def full_graph_batch(g: CSRGraph, edge_multiple: int = 512) -> dict:
    """Full-batch training input (edge list from CSR, padded+masked)."""
    n = g.n_nodes
    senders = g.indices.astype(np.int32)
    receivers = np.repeat(
        np.arange(n, dtype=np.int32), np.diff(g.indptr).astype(np.int64)
    )
    E = len(senders)
    Ep = pad_edges(E, edge_multiple)
    mask = np.zeros((Ep,), bool)
    mask[:E] = True
    s_pad = np.zeros((Ep,), np.int32); s_pad[:E] = senders
    r_pad = np.zeros((Ep,), np.int32); r_pad[:E] = receivers
    return {
        "feats": jnp.asarray(g.feats),
        "coords": jnp.asarray(g.coords),
        "senders": jnp.asarray(s_pad),
        "receivers": jnp.asarray(r_pad),
        "edge_mask": jnp.asarray(mask),
        "labels": jnp.asarray(g.labels),
    }


@dataclass
class SampledShape:
    """Static shape of a fanout-sampled subgraph."""

    batch_nodes: int
    fanouts: tuple[int, ...]

    @property
    def max_nodes(self) -> int:
        n, tot = self.batch_nodes, self.batch_nodes
        for f in self.fanouts:
            n = n * f
            tot += n
        return tot

    @property
    def max_edges(self) -> int:
        n, tot = self.batch_nodes, 0
        for f in self.fanouts:
            tot += n * f
            n = n * f
        return tot


def sample_subgraph(g: CSRGraph, shape: SampledShape, seed: int, step: int) -> dict:
    """Fanout neighbor sampling (GraphSAGE): returns padded local-id arrays.

    Seeds = batch_nodes random labeled nodes; for each hop, ``fanout``
    uniform neighbors per frontier node.  Node 0..n_sub-1 are relabeled
    locally; padding rows carry mask 0.
    """
    rng = np.random.default_rng((seed * 1_000_003 + step) % (2**63))
    seeds = rng.integers(0, g.n_nodes, shape.batch_nodes).astype(np.int32)
    nodes = [seeds]
    edges_s, edges_r = [], []
    local = {int(v): i for i, v in enumerate(seeds)}
    frontier = seeds
    for f in shape.fanouts:
        new = []
        for v in frontier:
            lo, hi = g.indptr[v], g.indptr[v + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = rng.integers(lo, hi, min(f, 64))
            for t in take[:f]:
                u = int(g.indices[t])
                if u not in local:
                    local[u] = len(local)
                    new.append(u)
                edges_s.append(local[u])
                edges_r.append(local[int(v)])
        frontier = np.array(new, dtype=np.int32) if new else np.array([], np.int32)
        nodes.append(frontier)

    n_sub = len(local)
    ids = np.fromiter(local.keys(), dtype=np.int64, count=n_sub)
    N, E = shape.max_nodes, shape.max_edges
    feats = np.zeros((N, g.feats.shape[1]), np.float32)
    coords = np.zeros((N, g.coords.shape[1]), np.float32)
    labels = np.full((N,), -1, np.int32)
    feats[:n_sub] = g.feats[ids]
    coords[:n_sub] = g.coords[ids]
    labels[: shape.batch_nodes] = g.labels[ids[: shape.batch_nodes]]
    senders = np.zeros((E,), np.int32)
    receivers = np.zeros((E,), np.int32)
    mask = np.zeros((E,), bool)
    ne = min(len(edges_s), E)
    senders[:ne] = edges_s[:ne]
    receivers[:ne] = edges_r[:ne]
    mask[:ne] = True
    return {
        "feats": jnp.asarray(feats),
        "coords": jnp.asarray(coords),
        "senders": jnp.asarray(senders),
        "receivers": jnp.asarray(receivers),
        "edge_mask": jnp.asarray(mask),
        "labels": jnp.asarray(labels),
    }


def molecule_batch(
    n_graphs: int, nodes_per: int, edges_per: int, d_feat: int, seed: int, step: int = 0
) -> dict:
    """Batch of small molecules as one block-diagonal graph + graph_ids."""
    rng = np.random.default_rng((seed * 7_919 + step) % (2**63))
    N, E = n_graphs * nodes_per, n_graphs * edges_per
    feats = rng.normal(0, 1, (N, d_feat)).astype(np.float32)
    coords = rng.normal(0, 1, (N, 3)).astype(np.float32)
    offs = np.repeat(np.arange(n_graphs) * nodes_per, edges_per)
    s = rng.integers(0, nodes_per, E).astype(np.int32) + offs
    r = rng.integers(0, nodes_per, E).astype(np.int32) + offs
    graph_ids = np.repeat(np.arange(n_graphs, dtype=np.int32), nodes_per)
    # synthetic regression target: mean pairwise distance proxy
    targets = coords.reshape(n_graphs, nodes_per, 3).std(axis=(1, 2)).astype(np.float32)
    return {
        "feats": jnp.asarray(feats),
        "coords": jnp.asarray(coords),
        "senders": jnp.asarray(s),
        "receivers": jnp.asarray(r),
        "edge_mask": jnp.ones((E,), bool),
        "graph_ids": jnp.asarray(graph_ids),
        "targets": jnp.asarray(targets),
    }

"""Synthetic recsys batches (Criteo/Avazu/Alibaba-style), deterministic in
(seed, step) like the LM pipeline."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _key(seed: int, step: int) -> jax.Array:
    return jax.random.fold_in(jax.random.key(seed), step)


def ctr_batch(
    batch: int,
    n_dense: int,
    vocab_sizes: tuple[int, ...],
    seed: int = 0,
    step: int = 0,
) -> dict:
    key = _key(seed, step)
    kd, ks, kl = jax.random.split(key, 3)
    vs = jnp.asarray(vocab_sizes, jnp.int32)
    # zipf-ish skew: square a uniform to concentrate mass at low ids
    u = jax.random.uniform(ks, (batch, len(vocab_sizes)))
    sparse = (u * u * vs[None, :]).astype(jnp.int32)
    out = {
        "sparse": sparse,
        "label": (jax.random.uniform(kl, (batch,)) < 0.25).astype(jnp.float32),
    }
    if n_dense > 0:
        out["dense"] = jax.random.normal(kd, (batch, n_dense), jnp.float32)
    return out


def ctr_input_specs(batch: int, n_dense: int, n_sparse: int) -> dict:
    out = {
        "sparse": jax.ShapeDtypeStruct((batch, n_sparse), jnp.int32),
        "label": jax.ShapeDtypeStruct((batch,), jnp.float32),
    }
    if n_dense > 0:
        out["dense"] = jax.ShapeDtypeStruct((batch, n_dense), jnp.float32)
    return out


def bst_batch(
    batch: int, n_items: int, seq_len: int, n_other: int, field_vocab: int,
    seed: int = 0, step: int = 0,
) -> dict:
    key = _key(seed, step)
    kh, kt, ko, kl = jax.random.split(key, 4)
    return {
        "history": jax.random.randint(kh, (batch, seq_len), 0, n_items, jnp.int32),
        "target": jax.random.randint(kt, (batch,), 0, n_items, jnp.int32),
        "other": jax.random.randint(ko, (batch, n_other), 0, field_vocab, jnp.int32),
        "label": (jax.random.uniform(kl, (batch,)) < 0.25).astype(jnp.float32),
    }


def bst_input_specs(batch: int, seq_len: int, n_other: int) -> dict:
    return {
        "history": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
        "target": jax.ShapeDtypeStruct((batch,), jnp.int32),
        "other": jax.ShapeDtypeStruct((batch, n_other), jnp.int32),
        "label": jax.ShapeDtypeStruct((batch,), jnp.float32),
    }


def two_tower_batch(
    batch: int, n_users: int, n_items: int, n_user_fields: int, n_item_fields: int,
    field_vocab: int, hist_len: int, seed: int = 0, step: int = 0,
) -> dict:
    key = _key(seed, step)
    ku, kf, kh, kt, ki, kq = jax.random.split(key, 6)
    return {
        "user_id": jax.random.randint(ku, (batch,), 0, n_users, jnp.int32),
        "user_fields": jax.random.randint(kf, (batch, n_user_fields), 0, field_vocab, jnp.int32),
        "history": jax.random.randint(kh, (batch, hist_len), -1, n_items, jnp.int32),
        "target": jax.random.randint(kt, (batch,), 0, n_items, jnp.int32),
        "item_fields": jax.random.randint(ki, (batch, n_item_fields), 0, field_vocab, jnp.int32),
        "logq": jnp.log(jax.random.uniform(kq, (batch,), minval=1e-6, maxval=1e-3)),
    }


def two_tower_input_specs(batch, n_user_fields, n_item_fields, hist_len) -> dict:
    return {
        "user_id": jax.ShapeDtypeStruct((batch,), jnp.int32),
        "user_fields": jax.ShapeDtypeStruct((batch, n_user_fields), jnp.int32),
        "history": jax.ShapeDtypeStruct((batch, hist_len), jnp.int32),
        "target": jax.ShapeDtypeStruct((batch,), jnp.int32),
        "item_fields": jax.ShapeDtypeStruct((batch, n_item_fields), jnp.int32),
        "logq": jax.ShapeDtypeStruct((batch,), jnp.float32),
    }


# classic Criteo-Kaggle per-field vocabulary sizes (26 categorical fields)
CRITEO_VOCABS = (
    1460, 583, 10_131_227, 2_202_608, 305, 24, 12_517, 633, 3, 93_145, 5_683,
    8_351_593, 3_194, 27, 14_992, 5_461_306, 10, 5_652, 2_173, 4, 7_046_547,
    18, 15, 286_181, 105, 142_572,
)


def avazu_like_vocabs(n_fields: int = 39, seed: int = 3) -> tuple[int, ...]:
    """Mixed small/large vocabularies for AutoInt's 39 fields."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_fields):
        r = rng.random()
        if r < 0.5:
            out.append(int(rng.integers(4, 1000)))
        elif r < 0.85:
            out.append(int(rng.integers(1000, 100_000)))
        else:
            out.append(int(rng.integers(100_000, 3_000_000)))
    return tuple(out)

"""Cluster-level fault tolerance: heartbeats, straggler watchdog, elastic
re-meshing.  (Launcher-side logic; in-container it is exercised by tests via
simulated hosts.)

On a real multi-host deployment each host process runs ``Heartbeat`` next to
the training loop; the (replicated) ``Watchdog`` on the coordinator
periodically scans heartbeat files:

* missing/stale heartbeat  → host declared dead → job restarts on the
  surviving hosts with a *shrunk* ``data`` axis (`plan_elastic_mesh`), and
  state restores through the resharding checkpoint loader (checkpoint.py) —
  no index/model rebuild.
* slow heartbeat (straggler) → logged; after ``straggler_patience`` scans
  the host is treated as dead (pre-emptive eviction), the standard
  mitigation when one of thousands of nodes runs at 10% speed.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass


@dataclass
class Heartbeat:
    directory: str
    host_id: int

    def beat(self, step: int, step_time_s: float):
        os.makedirs(self.directory, exist_ok=True)
        path = os.path.join(self.directory, f"host_{self.host_id}.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(
                {"host": self.host_id, "step": step, "t": time.time(),
                 "step_time_s": step_time_s},
                f,
            )
        os.replace(tmp, path)


@dataclass
class WatchdogConfig:
    timeout_s: float = 300.0
    straggler_factor: float = 3.0  # step_time > factor × median → straggler
    straggler_patience: int = 3


class Watchdog:
    def __init__(self, directory: str, cfg: WatchdogConfig = WatchdogConfig()):
        self.directory = directory
        self.cfg = cfg
        self.strikes: dict[int, int] = {}

    def scan(self, now: float | None = None) -> dict:
        """Returns {'alive': [...], 'dead': [...], 'stragglers': [...]}."""
        now = time.time() if now is None else now
        beats = []
        if os.path.isdir(self.directory):
            for name in os.listdir(self.directory):
                if name.startswith("host_") and name.endswith(".json"):
                    try:
                        with open(os.path.join(self.directory, name)) as f:
                            beats.append(json.load(f))
                    except Exception:
                        pass
        alive, dead, stragglers = [], [], []
        times = sorted(b["step_time_s"] for b in beats) or [0.0]
        median = times[len(times) // 2]
        for b in beats:
            if now - b["t"] > self.cfg.timeout_s:
                dead.append(b["host"])
                continue
            if median > 0 and b["step_time_s"] > self.cfg.straggler_factor * median:
                self.strikes[b["host"]] = self.strikes.get(b["host"], 0) + 1
                if self.strikes[b["host"]] >= self.cfg.straggler_patience:
                    dead.append(b["host"])  # evict persistent straggler
                else:
                    stragglers.append(b["host"])
                    alive.append(b["host"])
            else:
                self.strikes.pop(b["host"], None)
                alive.append(b["host"])
        return {"alive": sorted(alive), "dead": sorted(dead), "stragglers": sorted(stragglers)}


def plan_elastic_mesh(
    n_alive_hosts: int,
    chips_per_host: int,
    model_parallel: int,
    pods: int = 1,
) -> tuple[int, ...]:
    """Largest (pod, data, model) mesh fitting the surviving hosts.

    ``model`` is fixed (set by the architecture's memory footprint); the
    ``data`` axis shrinks to the largest size the chips support.  Returns the
    mesh shape; the caller re-lowers and restores via the resharding loader.
    """
    total = n_alive_hosts * chips_per_host
    per_pod = total // pods
    data = max(per_pod // model_parallel, 1)
    if pods > 1:
        return (pods, data, model_parallel)
    return (data, model_parallel)

"""AdamW with global-norm clipping, cosine/linear schedules, grad
accumulation, and optional ZeRO-1-style optimizer-state sharding.

Built from scratch (no optax in this environment) — the optimizer is part of
the substrate.  State is a pytree mirroring params; with
``zero1=True`` the first-moment/second-moment trees carry an extra
sharding constraint over the ``data`` axis (rules key "zero1"), which under
SPMD shards optimizer memory ZeRO-1 style while keeping the update local.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.sharding.specs import shard


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | linear | constant
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    zero1: bool = False  # shard m/v over the data axis (ZeRO-1)


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        decay = jnp.maximum(
            1.0 - (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
            0.0,
        )
    else:  # cosine
        frac = jnp.clip(
            (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
            0.0,
            1.0,
        )
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * decay


def init_opt_state(cfg: OptimizerConfig, params) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def zero1_sharding(mesh, spec, shape):
    """ZeRO-1 moment sharding: the param's own spec + the ``data`` axis on
    the first free dim it divides (so moments shard over data×model while
    params stay replicated across data)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    used = {
        a
        for e in spec
        for a in (e if isinstance(e, tuple) else (e,))
        if a is not None
    }
    if "data" in used or "data" not in mesh.axis_names:
        return NamedSharding(mesh, spec)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, (e, dim) in enumerate(zip(entries, shape)):
        if e is None and dim % mesh.shape["data"] == 0:
            entries[i] = "data"
            return NamedSharding(mesh, P(*entries))
    return NamedSharding(mesh, spec)


def _constrain_tree(tree, shardings):
    if shardings is None:
        return tree
    return jax.tree.map(
        lambda x, s: x if s is None else jax.lax.with_sharding_constraint(x, s),
        tree,
        shardings,
    )


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(cfg: OptimizerConfig, grads, params, state, moment_shardings=None):
    """One AdamW step. Returns (new_params, new_state, metrics).

    ``moment_shardings``: optional pytree (same structure as params) of
    NamedShardings for m/v — the ZeRO-1 layout from ``zero1_sharding``.
    """
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, p, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_p = jax.tree.leaves(params)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(g, p, m, v) for g, p, m, v in zip(flat_g, flat_p, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    if cfg.zero1 and moment_shardings is not None:
        new_m = _constrain_tree(new_m, moment_shardings)
        new_v = _constrain_tree(new_v, moment_shardings)
    return (
        new_p,
        {"step": step, "m": new_m, "v": new_v},
        {"grad_norm": gnorm, "lr": lr},
    )

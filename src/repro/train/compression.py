"""int8 gradient compression with error feedback (distributed-optimization
trick, DESIGN.md §8.5).

Under data parallelism the gradient all-reduce moves ``4·n_params`` bytes
per step per link.  Quantizing to int8 with a per-tensor absmax scale cuts
that 4×; the quantization error is fed back into the next step's gradient
(error-feedback/EF-SGD, Karimireddy et al. 2019) so convergence is
preserved.  In SPMD the all-reduce itself is inserted by XLA — we quantize
*before* the psum boundary by expressing the step inside shard_map in
``train/loop.py`` when compression is on; in plain-pjit mode this module
still provides the quantize/dequantize pair used by tests and benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor absmax int8 quantization. Returns (q int8, scale f32)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, error_buf):
    """Quantize grads+error_feedback; returns (q_tree, scales, new_error)."""

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return q, s, corrected - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_buf)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    q = jax.tree.unflatten(treedef, [o[0] for o in out])
    s = jax.tree.unflatten(treedef, [o[1] for o in out])
    err = jax.tree.unflatten(treedef, [o[2] for o in out])
    return q, s, err


def decompress_tree(q_tree, s_tree):
    return jax.tree.map(dequantize_int8, q_tree, s_tree)


def psum_compressed(grads, error_buf, axis_names):
    """All-reduce int8-quantized gradients inside shard_map.

    int8 summands can overflow int8 — accumulate the psum in int32 (XLA sends
    int8 on the wire only if the reduce dtype is int8, so we trade: send
    int32? No — we keep int8 on the wire by psumming int8 as int32 *after*
    local scaling to keep each shard's contribution within range, then
    renormalizing by the axis size).
    """
    n = 1
    for ax in axis_names:
        # jax.lax.axis_size only exists in newer jax; psum(1) is equivalent
        n *= jax.lax.psum(1, ax)

    q, s, err = compress_tree(grads, error_buf)

    def reduce_one(qi, si):
        # max scale across shards so all contributions share one grid
        s_max = si
        for ax in axis_names:
            s_max = jax.lax.pmax(s_max, ax)
        # requantize local values to the common grid (int8 wire format)
        v = dequantize_int8(qi, si)
        q8 = jnp.clip(jnp.round(v / s_max), -127, 127).astype(jnp.int8)
        acc = q8.astype(jnp.int32)
        for ax in axis_names:
            acc = jax.lax.psum(acc, ax)
        return acc.astype(jnp.float32) * s_max / n

    mean_g = jax.tree.map(reduce_one, q, s)
    return mean_g, err

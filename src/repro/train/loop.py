"""Train-step factory + fault-tolerant training loop.

``make_train_step(loss_fn, opt_cfg, ...)`` builds the jit-able
``train_step(params, opt_state, batch) -> (params, opt_state, metrics)``
with optional gradient accumulation (microbatching) and int8-compressed
gradient all-reduce (shard_map path).

``run(...)`` is the driver used by launch/train.py and the examples: it
checkpoints every N steps (atomic, async), and on failure (including
injected ``--simulate-failure``) restores the latest valid checkpoint and
replays — the data pipeline being keyed by (seed, step) makes the replay
bit-identical.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.train import checkpoint as ckpt_lib
from repro.train.optimizer import OptimizerConfig, adamw_update


def make_train_step(
    loss_fn: Callable,  # (params, batch) -> (loss, metrics)
    opt_cfg: OptimizerConfig,
    microbatches: int = 1,
    donate: bool = True,
    jit: bool = True,
    moment_shardings=None,
):
    """Standard SPMD train step (XLA inserts gradient reductions).

    ``jit=False`` returns the raw python step (dry-run lowers it itself with
    explicit donate/in_shardings)."""

    def step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:
            # gradient accumulation over leading-dim splits of the batch
            def micro(i, carry):
                acc, loss_sum = carry
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // microbatches), x.shape[0] // microbatches
                    ),
                    batch,
                )
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, loss_sum + l

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, loss_sum = jax.lax.fori_loop(
                0, microbatches, micro, (zeros, jnp.float32(0.0))
            )
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = {}
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, grads, params, opt_state, moment_shardings
        )
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    if not jit:
        return step
    if donate:
        return jax.jit(step, donate_argnums=(0, 1))
    return jax.jit(step)


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    ckpt_keep: int = 3
    ckpt_async: bool = True
    log_every: int = 10
    simulate_failure_at: int | None = None  # fault-injection for tests


def run(
    loop_cfg: LoopConfig,
    train_step,
    init_state: Callable[[], tuple],  # () -> (params, opt_state)
    batch_fn: Callable[[int], Any],  # step -> batch (deterministic)
    log: Callable[[str], None] = print,
):
    """Fault-tolerant loop. Returns (params, opt_state, history)."""
    params, opt_state = init_state()
    start = 0
    if loop_cfg.ckpt_dir:
        latest = ckpt_lib.latest_checkpoint(loop_cfg.ckpt_dir)
        if latest is not None and ckpt_lib.verify_checkpoint(loop_cfg.ckpt_dir, latest):
            log(f"[restore] resuming from step {latest}")
            params, opt_state = ckpt_lib.restore_checkpoint(
                loop_cfg.ckpt_dir, latest, (params, opt_state)
            )
            start = latest

    history = []
    pending = None
    step = start
    failed_once = False
    while step < loop_cfg.total_steps:
        try:
            if loop_cfg.simulate_failure_at is not None and step == loop_cfg.simulate_failure_at and not failed_once:
                failed_once = True
                raise RuntimeError(f"injected failure at step {step}")
            batch = batch_fn(step)
            t0 = time.perf_counter()
            params, opt_state, metrics = train_step(params, opt_state, batch)
            if step % loop_cfg.log_every == 0:
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                history.append((step, loss))
                log(f"step {step:5d}  loss {loss:.4f}  ({dt*1e3:.0f} ms)")
            step += 1
            if loop_cfg.ckpt_dir and step % loop_cfg.ckpt_every == 0:
                if pending is not None:
                    pending.join()
                pending = ckpt_lib.save_checkpoint(
                    loop_cfg.ckpt_dir, step, (params, opt_state),
                    async_=loop_cfg.ckpt_async, keep=loop_cfg.ckpt_keep,
                )
        except Exception as e:  # fault path: restore + replay
            log(f"[fault] {e!r}")
            if not loop_cfg.ckpt_dir:
                raise
            if pending is not None:
                pending.join()
                pending = None
            latest = ckpt_lib.latest_checkpoint(loop_cfg.ckpt_dir)
            if latest is None:
                log("[fault] no checkpoint — restarting from scratch")
                params, opt_state = init_state()
                step = 0
            else:
                log(f"[fault] restoring step {latest}")
                params, opt_state = ckpt_lib.restore_checkpoint(
                    loop_cfg.ckpt_dir, latest, (params, opt_state)
                )
                step = latest
    if pending is not None:
        pending.join()
    return params, opt_state, history

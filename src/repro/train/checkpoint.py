"""Checkpointing: atomic, async, keep-K, resharding restore.

Layout on disk:

    <dir>/step_<N>/
        manifest.json       {step, tree structure, shapes, dtypes, mesh shape}
        arr_<i>.npy         one file per leaf (numpy format)
    <dir>/step_<N>.tmp/     (writer workspace — renamed atomically on success)

Restore is *resharding*: arrays are loaded as host numpy and ``device_put``
with whatever sharding the (possibly different) current mesh prescribes —
a job restarted on a smaller/larger mesh resumes from the same checkpoint
(elastic scaling, DESIGN.md §5).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str, step: int, state, *, async_: bool = False,
                    keep: int = 3) -> threading.Thread | None:
    """Write state atomically; optionally in a background thread."""
    state_host = jax.tree.map(np.asarray, jax.device_get(state))

    def write():
        os.makedirs(directory, exist_ok=True)
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        paths, leaves, _ = _flatten_with_paths(state_host)
        manifest = {"step": step, "leaves": []}
        for i, (p, a) in enumerate(zip(paths, leaves)):
            a = np.asarray(a)
            np.save(os.path.join(tmp, f"arr_{i}.npy"), a)
            manifest["leaves"].append(
                {"path": p, "shape": list(a.shape), "dtype": str(a.dtype), "file": f"arr_{i}.npy"}
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        _gc(directory, keep)

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _gc(directory: str, keep: int):
    steps = sorted(list_checkpoints(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)


def list_checkpoints(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                out.append(int(name[5:]))
    return sorted(out)


def latest_checkpoint(directory: str) -> int | None:
    steps = list_checkpoints(directory)
    return steps[-1] if steps else None


def restore_checkpoint(directory: str, step: int, like, shardings=None):
    """Load ``step`` into the structure of ``like``.

    ``shardings``: optional pytree of jax.sharding.Sharding (same structure)
    — arrays are device_put with them (resharding restore). Without it,
    arrays are placed uncommitted (single device / donated into jit).
    """
    final = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _flatten_with_paths(like)
    by_path = {l["path"]: l for l in manifest["leaves"]}
    out = []
    shard_leaves = (
        jax.tree.flatten(shardings, is_leaf=lambda x: x is None)[0]
        if shardings is not None
        else [None] * len(leaves)
    )
    for p, leaf, sh in zip(paths, leaves, shard_leaves):
        meta = by_path[p]
        a = np.load(os.path.join(final, meta["file"]))
        want_shape = tuple(leaf.shape)
        assert tuple(a.shape) == want_shape, (p, a.shape, want_shape)
        if sh is not None:
            out.append(jax.device_put(a, sh))
        else:
            out.append(jax.device_put(a))
    return jax.tree.unflatten(treedef, out)


def verify_checkpoint(directory: str, step: int) -> bool:
    """Integrity check used by the restart manager before trusting a ckpt."""
    final = os.path.join(directory, f"step_{step:08d}")
    try:
        with open(os.path.join(final, "manifest.json")) as f:
            manifest = json.load(f)
        for l in manifest["leaves"]:
            fp = os.path.join(final, l["file"])
            if not os.path.exists(fp):
                return False
        return True
    except Exception:
        return False

"""RecSys architectures: two-tower retrieval, DCN-v2, AutoInt, BST.

JAX has no native ``nn.EmbeddingBag`` — the lookup substrate here IS part of
the system (taxonomy §B.6): ``jnp.take`` + masked reduction for fixed-hot
fields, ``jnp.take`` + ``jax.ops.segment_sum`` for ragged bags.  Embedding
tables are row-sharded over the ``model`` mesh axis ("rows" logical axis);
under SPMD a sharded-table gather lowers to the standard
partial-gather + all-reduce pattern.

All four models share a batch dict convention:
    dense    f32[B, n_dense]            (dcn only)
    sparse   i32[B, n_fields]           single-hot categorical ids
    history  i32[B, hist_len]           (bst, two-tower user history)
    target   i32[B]                     target item (bst)
    label    f32[B]                     CTR label / implicit positive
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef, init_params, param_count
from repro.sharding.specs import shard


# ---------------------------------------------------------------------------
# EmbeddingBag substrate
# ---------------------------------------------------------------------------

def _pad_vocab(v: int) -> int:
    """Row counts padded to a multiple of 256 so tables shard evenly over
    the model axis (ids never reference padding rows)."""
    return (v + 255) // 256 * 256


def embedding_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Single-hot lookup: table [V, D], ids i32[...] → [..., D]."""
    return jnp.take(table, ids, axis=0)


def embedding_bag(
    table: jax.Array,
    ids: jax.Array,  # i32[..., H] multi-hot, −1 padded
    mode: str = "sum",
) -> jax.Array:
    """Fixed-width EmbeddingBag: masked take + reduce over the hot dim."""
    mask = (ids >= 0).astype(table.dtype)[..., None]
    emb = jnp.take(table, jnp.maximum(ids, 0), axis=0) * mask
    if mode == "sum":
        return emb.sum(axis=-2)
    if mode == "mean":
        return emb.sum(axis=-2) / jnp.maximum(mask.sum(axis=-2), 1.0)
    if mode == "max":
        neg = jnp.where(mask > 0, emb, -jnp.inf)
        return jnp.where(jnp.isfinite(neg.max(axis=-2)), neg.max(axis=-2), 0.0)
    raise ValueError(mode)


def embedding_bag_ragged(
    table: jax.Array,
    flat_ids: jax.Array,  # i32[T] concatenated bags
    segment_ids: jax.Array,  # i32[T] bag index per id
    num_bags: int,
    weights: jax.Array | None = None,
    mode: str = "sum",
) -> jax.Array:
    """CSR-style ragged EmbeddingBag: take + segment_sum (torch parity)."""
    emb = jnp.take(table, jnp.maximum(flat_ids, 0), axis=0)
    valid = (flat_ids >= 0).astype(table.dtype)
    w = valid if weights is None else weights * valid
    emb = emb * w[:, None]
    tot = jax.ops.segment_sum(emb, segment_ids, num_segments=num_bags)
    if mode == "sum":
        return tot
    cnt = jax.ops.segment_sum(w, segment_ids, num_segments=num_bags)
    return tot / jnp.maximum(cnt, 1.0)[:, None]


def _mlp_defs(name: str, dims: list[int], pd) -> dict:
    out = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        out[f"{name}_w{i}"] = ParamDef((a, b), (None, "ffn") if i == 0 else (None, None), pd)
        out[f"{name}_b{i}"] = ParamDef((b,), (None,), pd, "zeros")
    return out


def _mlp_apply(p: dict, name: str, x: jax.Array, n: int, act=jax.nn.relu, last_act=True):
    for i in range(n):
        x = x @ p[f"{name}_w{i}"].astype(x.dtype) + p[f"{name}_b{i}"].astype(x.dtype)
        if i < n - 1 or last_act:
            x = act(x)
    return x


def _bce(logit: jax.Array, label: jax.Array):
    loss = jnp.mean(
        jnp.maximum(logit, 0) - logit * label + jnp.log1p(jnp.exp(-jnp.abs(logit)))
    )
    return loss


# ---------------------------------------------------------------------------
# Two-tower retrieval (Yi et al., RecSys'19)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two_tower"
    embed_dim: int = 256
    tower_dims: tuple[int, ...] = (1024, 512, 256)
    n_users: int = 1_000_000
    n_items: int = 1_000_000
    n_user_fields: int = 4  # user categorical context fields
    n_item_fields: int = 3
    field_vocab: int = 100_000
    hist_len: int = 20
    feat_dim: int = 64  # per-feature embedding dim
    temperature: float = 0.05
    compute_dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    def param_defs(self) -> dict:
        pd = self.param_dtype
        D = self.feat_dim
        user_in = D * (1 + self.n_user_fields + 1)  # id + fields + history pool
        item_in = D * (1 + self.n_item_fields)
        defs = {
            "user_id": ParamDef((_pad_vocab(self.n_users), D), ("rows", None), pd, "embed"),
            "item_id": ParamDef((_pad_vocab(self.n_items), D), ("rows", None), pd, "embed"),
            "user_fields": ParamDef(
                (self.n_user_fields, _pad_vocab(self.field_vocab), D), (None, "rows", None), pd, "embed"
            ),
            "item_fields": ParamDef(
                (self.n_item_fields, _pad_vocab(self.field_vocab), D), (None, "rows", None), pd, "embed"
            ),
        }
        udims = [user_in, *self.tower_dims, self.embed_dim]
        idims = [item_in, *self.tower_dims, self.embed_dim]
        defs.update(_mlp_defs("user", udims, pd))
        defs.update(_mlp_defs("item", idims, pd))
        return defs

    @property
    def n_tower_layers(self) -> int:
        return len(self.tower_dims) + 1

    def init(self, key):
        return init_params(self.param_defs(), key)

    def n_params(self) -> int:
        return param_count(self.param_defs())


def two_tower_user(cfg: TwoTowerConfig, p: dict, batch: dict) -> jax.Array:
    uid = embedding_lookup(p["user_id"], batch["user_id"])  # [B, D]
    uf = jax.vmap(
        lambda t, ids: embedding_lookup(t, ids), in_axes=(0, 1), out_axes=1
    )(p["user_fields"], batch["user_fields"])  # [B, F, D]
    hist = embedding_bag(p["item_id"], batch["history"], mode="mean")  # [B, D]
    x = jnp.concatenate([uid, uf.reshape(uid.shape[0], -1), hist], axis=-1)
    x = shard(x, "batch", None)
    u = _mlp_apply(p, "user", x, cfg.n_tower_layers, last_act=False)
    return u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-6)


def two_tower_item(cfg: TwoTowerConfig, p: dict, item_id, item_fields) -> jax.Array:
    iid = embedding_lookup(p["item_id"], item_id)
    itf = jax.vmap(
        lambda t, ids: embedding_lookup(t, ids), in_axes=(0, 1), out_axes=1
    )(p["item_fields"], item_fields)
    x = jnp.concatenate([iid, itf.reshape(iid.shape[0], -1)], axis=-1)
    x = shard(x, "candidates", None)
    v = _mlp_apply(p, "item", x, cfg.n_tower_layers, last_act=False)
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)


def two_tower_loss(cfg: TwoTowerConfig, params: dict, batch: dict):
    """In-batch sampled softmax with logQ correction (batch["logq"] [B])."""
    u = two_tower_user(cfg, params, batch)  # [B, E]
    v = two_tower_item(cfg, params, batch["target"], batch["item_fields"])  # [B, E]
    logits = (u @ v.T) / cfg.temperature  # [B, B]
    logits = logits - batch["logq"][None, :]  # logQ correction
    labels = jnp.arange(u.shape[0])
    lse = jax.nn.logsumexp(logits, axis=-1)
    nll = jnp.mean(lse - jnp.take_along_axis(logits, labels[:, None], 1)[:, 0])
    return nll, {"nll": nll}


def two_tower_score_candidates(
    cfg: TwoTowerConfig,
    params: dict,
    batch: dict,  # one/few users
    cand_ids: jax.Array,  # i32[Nc]
    cand_fields: jax.Array,  # i32[Nc, n_item_fields]
    top_k: int = 100,
    geo: dict | None = None,  # optional geo-constrained retrieval (paper tie-in)
):
    """Score candidates for retrieval; optionally blend a geographic score
    computed with the paper's geo_score kernel (DESIGN.md §6, two-tower row).

    geo = {cand_rects [Nc,R,4], cand_amps [Nc,R], q_rects [Q,4], q_amps [Q],
           weight float}
    """
    u = two_tower_user(cfg, params, batch)  # [B, E]
    v = two_tower_item(cfg, params, cand_ids, cand_fields)  # [Nc, E]
    scores = u @ v.T  # [B, Nc]
    if geo is not None:
        from repro.kernels.geo_score.ops import geo_score_docs

        g = geo_score_docs(
            geo["cand_rects"], geo["cand_amps"], geo["q_rects"], geo["q_amps"]
        )  # [Nc]
        scores = scores + geo["weight"] * g[None, :]
        scores = jnp.where(g[None, :] > 0, scores, -jnp.inf)
    return jax.lax.top_k(scores, top_k)


# ---------------------------------------------------------------------------
# DCN-v2 (arXiv:2008.13535)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DCNv2Config:
    name: str = "dcn_v2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 16
    n_cross_layers: int = 3
    mlp_dims: tuple[int, ...] = (1024, 1024, 512)
    vocab_sizes: tuple[int, ...] = ()  # len == n_sparse
    compute_dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    @property
    def d_input(self) -> int:
        return self.n_dense + self.n_sparse * self.embed_dim

    def param_defs(self) -> dict:
        pd = self.param_dtype
        vs = self.vocab_sizes or tuple([100_000] * self.n_sparse)
        defs = {
            f"table_{i}": ParamDef((_pad_vocab(v), self.embed_dim), ("rows", None), pd, "embed")
            for i, v in enumerate(vs)
        }
        d = self.d_input
        for l in range(self.n_cross_layers):
            defs[f"cross_w{l}"] = ParamDef((d, d), (None, None), pd)
            defs[f"cross_b{l}"] = ParamDef((d,), (None,), pd, "zeros")
        defs.update(_mlp_defs("deep", [d, *self.mlp_dims], pd))
        defs["logit_w"] = ParamDef((d + self.mlp_dims[-1], 1), (None, None), pd)
        defs["logit_b"] = ParamDef((1,), (None,), pd, "zeros")
        return defs

    def init(self, key):
        return init_params(self.param_defs(), key)

    def n_params(self) -> int:
        return param_count(self.param_defs())


def dcn_v2_forward(cfg: DCNv2Config, p: dict, batch: dict) -> jax.Array:
    B = batch["sparse"].shape[0]
    embs = [
        embedding_lookup(p[f"table_{i}"], batch["sparse"][:, i])
        for i in range(cfg.n_sparse)
    ]
    x0 = jnp.concatenate([batch["dense"].astype(cfg.compute_dtype), *embs], axis=-1)
    x0 = shard(x0, "batch", None)
    # cross network: x_{l+1} = x0 ⊙ (W x_l + b) + x_l
    x = x0
    for l in range(cfg.n_cross_layers):
        x = x0 * (x @ p[f"cross_w{l}"].astype(x.dtype) + p[f"cross_b{l}"].astype(x.dtype)) + x
    deep = _mlp_apply(p, "deep", x0, len(cfg.mlp_dims))
    out = jnp.concatenate([x, deep], axis=-1)
    logit = out @ p["logit_w"].astype(x.dtype) + p["logit_b"].astype(x.dtype)
    return logit[:, 0]


def dcn_v2_loss(cfg: DCNv2Config, params: dict, batch: dict):
    logit = dcn_v2_forward(cfg, params, batch)
    loss = _bce(logit, batch["label"])
    return loss, {"bce": loss}


# ---------------------------------------------------------------------------
# AutoInt (arXiv:1810.11921)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AutoIntConfig:
    name: str = "autoint"
    n_sparse: int = 39
    embed_dim: int = 16
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32
    vocab_sizes: tuple[int, ...] = ()
    compute_dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    def param_defs(self) -> dict:
        pd = self.param_dtype
        vs = self.vocab_sizes or tuple([100_000] * self.n_sparse)
        defs = {
            f"table_{i}": ParamDef((_pad_vocab(v), self.embed_dim), ("rows", None), pd, "embed")
            for i, v in enumerate(vs)
        }
        d_in = self.embed_dim
        for l in range(self.n_attn_layers):
            defs[f"attn{l}_wq"] = ParamDef((d_in, self.n_heads, self.d_attn), (None, "heads", None), pd)
            defs[f"attn{l}_wk"] = ParamDef((d_in, self.n_heads, self.d_attn), (None, "heads", None), pd)
            defs[f"attn{l}_wv"] = ParamDef((d_in, self.n_heads, self.d_attn), (None, "heads", None), pd)
            defs[f"attn{l}_wres"] = ParamDef((d_in, self.n_heads * self.d_attn), (None, None), pd)
            d_in = self.n_heads * self.d_attn
        defs["logit_w"] = ParamDef((self.n_sparse * d_in, 1), (None, None), pd)
        defs["logit_b"] = ParamDef((1,), (None,), pd, "zeros")
        return defs

    def init(self, key):
        return init_params(self.param_defs(), key)

    def n_params(self) -> int:
        return param_count(self.param_defs())


def autoint_forward(cfg: AutoIntConfig, p: dict, batch: dict) -> jax.Array:
    B = batch["sparse"].shape[0]
    embs = jnp.stack(
        [
            embedding_lookup(p[f"table_{i}"], batch["sparse"][:, i])
            for i in range(cfg.n_sparse)
        ],
        axis=1,
    )  # [B, F, D]
    x = shard(embs.astype(cfg.compute_dtype), "batch", None, None)
    for l in range(cfg.n_attn_layers):
        q = jnp.einsum("bfd,dha->bfha", x, p[f"attn{l}_wq"].astype(x.dtype))
        k = jnp.einsum("bfd,dha->bfha", x, p[f"attn{l}_wk"].astype(x.dtype))
        v = jnp.einsum("bfd,dha->bfha", x, p[f"attn{l}_wv"].astype(x.dtype))
        s = jnp.einsum("bfha,bgha->bhfg", q, k) / jnp.sqrt(jnp.float32(cfg.d_attn)).astype(x.dtype)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhfg,bgha->bfha", a, v)
        o = o.reshape(B, cfg.n_sparse, cfg.n_heads * cfg.d_attn)
        x = jax.nn.relu(o + jnp.einsum("bfd,de->bfe", x, p[f"attn{l}_wres"].astype(x.dtype)))
    flat = x.reshape(B, -1)
    logit = flat @ p["logit_w"].astype(x.dtype) + p["logit_b"].astype(x.dtype)
    return logit[:, 0]


def autoint_loss(cfg: AutoIntConfig, params: dict, batch: dict):
    logit = autoint_forward(cfg, params, batch)
    loss = _bce(logit, batch["label"])
    return loss, {"bce": loss}


# ---------------------------------------------------------------------------
# BST — Behavior Sequence Transformer (arXiv:1905.06874)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BSTConfig:
    name: str = "bst"
    embed_dim: int = 32
    seq_len: int = 20
    n_blocks: int = 1
    n_heads: int = 8
    mlp_dims: tuple[int, ...] = (1024, 512, 256)
    n_items: int = 1_000_000
    n_other_fields: int = 4
    field_vocab: int = 100_000
    compute_dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    @property
    def d_head(self) -> int:
        return self.embed_dim // self.n_heads

    def param_defs(self) -> dict:
        pd = self.param_dtype
        D = self.embed_dim
        defs = {
            "item_emb": ParamDef((_pad_vocab(self.n_items), D), ("rows", None), pd, "embed"),
            "pos_emb": ParamDef((self.seq_len + 1, D), (None, None), pd, "embed"),
            "other_fields": ParamDef(
                (self.n_other_fields, _pad_vocab(self.field_vocab), D), (None, "rows", None), pd, "embed"
            ),
        }
        for b in range(self.n_blocks):
            defs[f"blk{b}_wq"] = ParamDef((D, self.n_heads, self.d_head), (None, "heads", None), pd)
            defs[f"blk{b}_wk"] = ParamDef((D, self.n_heads, self.d_head), (None, "heads", None), pd)
            defs[f"blk{b}_wv"] = ParamDef((D, self.n_heads, self.d_head), (None, "heads", None), pd)
            defs[f"blk{b}_wo"] = ParamDef((self.n_heads * self.d_head, D), (None, None), pd)
            defs[f"blk{b}_ln1"] = ParamDef((D,), (None,), pd, "ones")
            defs[f"blk{b}_ln2"] = ParamDef((D,), (None,), pd, "ones")
            defs[f"blk{b}_ff1"] = ParamDef((D, 4 * D), (None, None), pd)
            defs[f"blk{b}_ff1b"] = ParamDef((4 * D,), (None,), pd, "zeros")
            defs[f"blk{b}_ff2"] = ParamDef((4 * D, D), (None, None), pd)
            defs[f"blk{b}_ff2b"] = ParamDef((D,), (None,), pd, "zeros")
        d_in = (self.seq_len + 1) * D + self.n_other_fields * D
        defs.update(_mlp_defs("mlp", [d_in, *self.mlp_dims], pd))
        defs["logit_w"] = ParamDef((self.mlp_dims[-1], 1), (None, None), pd)
        defs["logit_b"] = ParamDef((1,), (None,), pd, "zeros")
        return defs

    def init(self, key):
        return init_params(self.param_defs(), key)

    def n_params(self) -> int:
        return param_count(self.param_defs())


def bst_forward(cfg: BSTConfig, p: dict, batch: dict) -> jax.Array:
    B = batch["target"].shape[0]
    D = cfg.embed_dim
    seq = jnp.concatenate(
        [batch["history"], batch["target"][:, None]], axis=1
    )  # [B, S+1] target appended (BST)
    x = embedding_lookup(p["item_emb"], jnp.maximum(seq, 0))
    x = x * (seq >= 0).astype(x.dtype)[..., None]
    x = x + p["pos_emb"].astype(x.dtype)[None, :, :]
    x = shard(x, "batch", None, None)
    from repro.models.layers import rms_norm

    for b in range(cfg.n_blocks):
        y = rms_norm(x, p[f"blk{b}_ln1"])
        q = jnp.einsum("bsd,dha->bsha", y, p[f"blk{b}_wq"].astype(x.dtype))
        k = jnp.einsum("bsd,dha->bsha", y, p[f"blk{b}_wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dha->bsha", y, p[f"blk{b}_wv"].astype(x.dtype))
        s = jnp.einsum("bsha,btha->bhst", q, k) / jnp.sqrt(
            jnp.float32(cfg.d_head)
        ).astype(x.dtype)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhst,btha->bsha", a, v).reshape(B, cfg.seq_len + 1, -1)
        x = x + jnp.einsum("bse,ed->bsd", o, p[f"blk{b}_wo"].astype(x.dtype))
        y = rms_norm(x, p[f"blk{b}_ln2"])
        h = jax.nn.relu(y @ p[f"blk{b}_ff1"].astype(x.dtype) + p[f"blk{b}_ff1b"].astype(x.dtype))
        x = x + h @ p[f"blk{b}_ff2"].astype(x.dtype) + p[f"blk{b}_ff2b"].astype(x.dtype)

    other = jax.vmap(
        lambda t, ids: embedding_lookup(t, ids), in_axes=(0, 1), out_axes=1
    )(p["other_fields"], batch["other"])  # [B, F, D]
    flat = jnp.concatenate([x.reshape(B, -1), other.reshape(B, -1)], axis=-1)
    h = _mlp_apply(p, "mlp", flat, len(cfg.mlp_dims), act=jax.nn.leaky_relu)
    logit = h @ p["logit_w"].astype(x.dtype) + p["logit_b"].astype(x.dtype)
    return logit[:, 0]


def bst_loss(cfg: BSTConfig, params: dict, batch: dict):
    logit = bst_forward(cfg, params, batch)
    loss = _bce(logit, batch["label"])
    return loss, {"bce": loss}

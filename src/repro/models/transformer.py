"""Decoder-only LM: dense or MoE FFN, GQA, RoPE, scan-over-layers.

Covers the five assigned LM architectures (granite-moe, olmoe, smollm,
qwen1.5-0.5b, qwen2.5-14b).  Layer parameters are stacked on a leading
``layers`` dim and the body is a ``lax.scan`` — HLO size and compile time
are independent of depth (essential for 48-layer × 512-device dry runs).
Remat (``jax.checkpoint``) wraps the scanned body; policy configurable.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models.params import ParamDef, init_params, param_count
from repro.sharding.specs import shard


@dataclass(frozen=True)
class TransformerConfig:
    name: str = "lm"
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 256
    vocab: int = 1024
    # MoE (n_experts == 0 → dense)
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    norm_topk_probs: bool = True
    aux_loss_weight: float = 0.01
    # attention
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    attn_window: int | None = None  # sliding-window (beyond-paper long_500k)
    attn_chunk: int = 512
    tie_embeddings: bool = False
    # scan_unroll=True unrolls the layer loop: needed by the dry-run because
    # HLO cost analysis counts a while-loop body once (not × trip count)
    scan_unroll: bool = False
    # numerics
    compute_dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    z_loss: float = 1e-4
    remat: str = "full"  # none | full | dots

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 (Megatron-style) so the vocab
        dim shards evenly; padded logit columns are masked in _unembed."""
        return (self.vocab + 255) // 256 * 256

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_defs(self) -> dict:
        D, H, KVH, Dh, F, V, E = (
            self.d_model,
            self.n_heads,
            self.n_kv_heads,
            self.d_head,
            self.d_ff,
            self.vocab,
            self.n_experts,
        )
        Lyr = self.n_layers
        pd = self.param_dtype
        layer: dict = {
            "ln1": ParamDef((Lyr, D), ("layers", "embed"), pd, "ones"),
            "ln2": ParamDef((Lyr, D), ("layers", "embed"), pd, "ones"),
            "attn": {
                "wq": ParamDef((Lyr, D, H * Dh), ("layers", "embed", "qkv_out"), pd),
                "wk": ParamDef((Lyr, D, KVH * Dh), ("layers", "embed", "kv_out"), pd),
                "wv": ParamDef((Lyr, D, KVH * Dh), ("layers", "embed", "kv_out"), pd),
                "wo": ParamDef((Lyr, H * Dh, D), ("layers", "qkv_out", "embed"), pd),
            },
        }
        if self.qkv_bias:
            layer["attn"]["bq"] = ParamDef((Lyr, H * Dh), ("layers", "qkv_out"), pd, "zeros")
            layer["attn"]["bk"] = ParamDef((Lyr, KVH * Dh), ("layers", "kv_out"), pd, "zeros")
            layer["attn"]["bv"] = ParamDef((Lyr, KVH * Dh), ("layers", "kv_out"), pd, "zeros")
        if self.qk_norm:
            layer["attn"]["q_norm"] = ParamDef((Lyr, Dh), ("layers", None), pd, "ones")
            layer["attn"]["k_norm"] = ParamDef((Lyr, Dh), ("layers", None), pd, "ones")
        if self.is_moe:
            layer["moe"] = {
                "router": ParamDef((Lyr, D, E), ("layers", "embed", "experts"), pd),
                "wi_gate": ParamDef((Lyr, E, D, F), ("layers", "experts", "embed", "expert_ffn"), pd),
                "wi_up": ParamDef((Lyr, E, D, F), ("layers", "experts", "embed", "expert_ffn"), pd),
                "wo": ParamDef((Lyr, E, F, D), ("layers", "experts", "expert_ffn", "embed"), pd),
            }
        else:
            layer["mlp"] = {
                "wi_gate": ParamDef((Lyr, D, F), ("layers", "embed", "ffn"), pd),
                "wi_up": ParamDef((Lyr, D, F), ("layers", "embed", "ffn"), pd),
                "wo": ParamDef((Lyr, F, D), ("layers", "ffn", "embed"), pd),
            }
        Vp = self.padded_vocab
        out = {
            "embed": ParamDef((Vp, D), ("vocab", "embed"), pd, "embed"),
            "ln_f": ParamDef((D,), ("embed",), pd, "ones"),
            "layers": layer,
        }
        if not self.tie_embeddings:
            out["unembed"] = ParamDef((D, Vp), ("embed", "vocab"), pd)
        return out

    def init(self, key: jax.Array) -> dict:
        return init_params(self.param_defs(), key)

    def n_params(self) -> int:
        return param_count(self.param_defs())

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        total = self.n_params()
        if not self.is_moe:
            return total
        expert_p = 3 * self.d_model * self.d_ff * self.n_layers * self.n_experts
        return int(total - expert_p * (1 - self.top_k / self.n_experts))


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _unembed(cfg: TransformerConfig, params: dict, x: jax.Array) -> jax.Array:
    w = (
        params["embed"].T if cfg.tie_embeddings else params["unembed"]
    ).astype(cfg.compute_dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=jnp.float32)
    if cfg.padded_vocab != cfg.vocab:  # mask padding columns
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(col < cfg.vocab, logits, -1e9)
    return logits


def _layer_body(cfg: TransformerConfig, x, lp, positions):
    h, _ = L.attention_block(L.rms_norm(x, lp["ln1"]), lp["attn"], cfg, positions)
    x = x + h
    y = L.rms_norm(x, lp["ln2"])
    if cfg.is_moe:
        f, aux = moe_lib.moe_ffn(y, lp["moe"], cfg)
    else:
        f, aux = L.swiglu(y, lp["mlp"]), jnp.float32(0.0)
    return x + f, aux


def forward(cfg: TransformerConfig, params: dict, tokens: jax.Array):
    """tokens i32[B, S] → (logits f32[B, S, V], aux_loss)."""
    B, S = tokens.shape
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    x = shard(x, "batch", "seq", "embed")
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    def body(x, lp):
        out, aux = _layer_body(cfg, x, lp, positions)
        return out, aux

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    elif cfg.remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    x, auxs = jax.lax.scan(body, x, params["layers"], unroll=cfg.scan_unroll)
    x = L.rms_norm(x, params["ln_f"])
    # Loss region: release the seq shard (the model axis belongs to vocab
    # here — otherwise logits materialize with the FULL vocab per device).
    x = shard(x, "batch", None, "embed")
    logits = shard(_unembed(cfg, params, x), "batch", None, "vocab")
    return logits, auxs.sum()


def loss_fn(cfg: TransformerConfig, params: dict, batch: dict):
    """batch: tokens i32[B, S], labels i32[B, S] (−1 = ignore)."""
    logits, aux = forward(cfg, params, batch["tokens"])
    labels = batch["labels"]
    mask = labels >= 0
    lse = jax.nn.logsumexp(logits, axis=-1)
    # label log-prob via masked reduction (NOT take_along_axis: a gather over
    # the model-sharded vocab dim would force an all-gather of the logits)
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    ll = jnp.sum(
        jnp.where(col == jnp.maximum(labels, 0)[..., None], logits, 0.0), axis=-1
    )
    nll = (lse - ll) * mask
    n = jnp.maximum(mask.sum(), 1)
    loss = nll.sum() / n
    zl = cfg.z_loss * ((lse * mask) ** 2).sum() / n
    total = loss + zl + cfg.aux_loss_weight * aux
    return total, {"nll": loss, "z_loss": zl, "aux": aux, "tokens": n}


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------

def make_cache(cfg: TransformerConfig, batch: int, max_len: int) -> dict:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {
        "k": jnp.zeros(shape, cfg.compute_dtype),
        "v": jnp.zeros(shape, cfg.compute_dtype),
    }


def cache_defs(cfg: TransformerConfig, batch: int, max_len: int) -> dict:
    """ParamDef-style tree for dry-run cache ShapeDtypeStructs."""
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    # kv_seq/head_dim are fallback shards: they engage exactly when batch or
    # kv_heads cannot divide the mesh axes (long-context b=1, GQA kv<model).
    logical = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    return {
        "k": ParamDef(shape, logical, cfg.compute_dtype, "zeros"),
        "v": ParamDef(shape, logical, cfg.compute_dtype, "zeros"),
    }


def prefill(cfg: TransformerConfig, params: dict, tokens: jax.Array, cache: dict):
    """Fill the cache with the prompt; returns (logits_last, cache)."""
    B, S = tokens.shape
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    x = shard(x, "batch", "seq", "embed")
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    def body(x, lp):
        h, (k, v) = L.attention_block(
            L.rms_norm(x, lp["ln1"]), lp["attn"], cfg, positions
        )
        x = x + h
        y = L.rms_norm(x, lp["ln2"])
        if cfg.is_moe:
            f, _ = moe_lib.moe_ffn(y, lp["moe"], cfg)
        else:
            f = L.swiglu(y, lp["mlp"])
        return x + f, (k, v)

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"], unroll=cfg.scan_unroll)
    x = L.rms_norm(x, params["ln_f"])
    logits = _unembed(cfg, params, x[:, -1:, :])
    S_max = cache["k"].shape[2]
    pad = S_max - S
    ks = jnp.pad(ks.astype(cache["k"].dtype), ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    vs = jnp.pad(vs.astype(cache["v"].dtype), ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return logits[:, 0], {"k": ks, "v": vs}


def decode_step(
    cfg: TransformerConfig,
    params: dict,
    cache: dict,
    tokens: jax.Array,  # i32[B] last generated token
    pos: jax.Array,  # scalar i32: write position (= current length)
):
    """One token of batched decode. Returns (logits f32[B, V], new cache)."""
    B = tokens.shape[0]
    x = params["embed"].astype(cfg.compute_dtype)[tokens][:, None, :]  # [B,1,D]
    x = shard(x, "batch", None, "embed")
    positions = jnp.full((B, 1), pos, jnp.int32)

    def body(x, xs):
        lp, kc, vc = xs
        h, (k_new, v_new) = L.attention_block(
            L.rms_norm(x, lp["ln1"]),
            lp["attn"],
            cfg,
            positions,
            k_cache=kc,
            v_cache=vc,
            cache_pos=pos,
            kv_valid_len=pos + 1,
        )
        x = x + h
        y = L.rms_norm(x, lp["ln2"])
        if cfg.is_moe:
            f, _ = moe_lib.moe_ffn(y, lp["moe"], cfg)
        else:
            f = L.swiglu(y, lp["mlp"])
        return x + f, (k_new, v_new)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]), unroll=cfg.scan_unroll
    )
    x = L.rms_norm(x, params["ln_f"])
    logits = _unembed(cfg, params, x)
    return logits[:, 0], {"k": ks, "v": vs}

"""Shared transformer layers: RMSNorm, RoPE, GQA flash attention, SwiGLU.

All functions are mesh-agnostic; sharding is expressed through logical-axis
constraints (sharding/specs.shard) that resolve against whatever mesh is in
context (or no-op on a single device).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.sharding.specs import get_context, shard


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding. x [..., S, H, Dh], positions [..., S] (int32)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def flash_attention(
    q: jax.Array,  # [B, Sq, H, Dh]
    k: jax.Array,  # [B, Skv, KVH, Dh]
    v: jax.Array,  # [B, Skv, KVH, Dh]
    *,
    causal: bool = True,
    q_offset: jax.Array | int = 0,
    kv_valid_len: jax.Array | None = None,  # [B] or scalar; mask k_pos >= len
    window: int | None = None,  # sliding-window attention (beyond-paper)
    chunk: int = 512,
) -> jax.Array:
    """Chunked-KV attention with running softmax (flash-style, pure JAX).

    Never materializes the [Sq, Skv] score matrix; memory is
    O(Sq * chunk) per head. GQA via head grouping. Scores accumulate in f32.
    """
    B, Sq, H, Dh = q.shape
    _, Skv, KVH, _ = k.shape
    assert H % KVH == 0, (H, KVH)
    G = H // KVH
    scale = Dh**-0.5
    qg = q.reshape(B, Sq, KVH, G, Dh)
    chunk = min(chunk, Skv)
    assert Skv % chunk == 0, (Skv, chunk)
    n_chunks = Skv // chunk
    q_pos = jnp.asarray(q_offset) + jnp.arange(Sq, dtype=jnp.int32)  # [Sq]

    kc = k.reshape(B, n_chunks, chunk, KVH, Dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KVH, Dh).transpose(1, 0, 2, 3, 4)
    idxs = jnp.arange(n_chunks, dtype=jnp.int32)

    def step(carry, xs):
        m, l, acc = carry
        ci, kb, vb = xs  # kb/vb: [B, chunk, KVH, Dh]
        k_pos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)  # [chunk]
        s = jnp.einsum(
            "bqhgd,bkhd->bqhgk", qg, kb, preferred_element_type=jnp.float32
        ) * scale  # [B, Sq, KVH, G, chunk]
        mask = jnp.ones((Sq, chunk), dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        if kv_valid_len is not None:
            vl = jnp.broadcast_to(jnp.asarray(kv_valid_len), (B,))
            ok = (k_pos[None, :] < vl[:, None])[:, None, None, None, :]  # [B,1,1,1,chunk]
            s = jnp.where(ok, s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isneginf(s), 0.0, p)
        corr = jnp.exp(jnp.where(jnp.isneginf(m), m_safe * 0 - jnp.inf, m - m_safe))
        corr = jnp.where(jnp.isneginf(m), 0.0, corr)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(vb.dtype), vb)
        acc = acc * corr[..., None] + pv.astype(jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, Sq, KVH, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, KVH, G), jnp.float32)
    acc0 = jnp.zeros((B, Sq, KVH, G, Dh), jnp.float32)
    # checkpoint the chunk step: without it the scan's VJP stacks the per-
    # chunk score/prob residuals — i.e. the full [Sq, Skv] attention matrix
    # — and the flash formulation loses its memory advantage in backward.
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(step), (m0, l0, acc0), (idxs, kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


def attention_block(
    x: jax.Array,  # [B, S, D]
    p: dict,
    cfg,
    positions: jax.Array,
    *,
    k_cache: jax.Array | None = None,
    v_cache: jax.Array | None = None,
    cache_pos: jax.Array | int | None = None,
    kv_valid_len: jax.Array | None = None,
):
    """GQA attention with optional KV cache (decode).

    Returns (out [B, S, D], (k, v) new cache entries or full k/v).
    """
    B, S, D = x.shape
    H, KVH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    # Projections carry flattened (H·Dh) output dims so tensor parallelism
    # never depends on head-count divisibility (DESIGN.md §5).
    q = jnp.einsum("bsd,dz->bsz", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dz->bsz", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dz->bsz", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    # Attention-internal sharding is ADAPTIVE (EXPERIMENTS.md §Perf):
    # * heads divide the model axis → head-parallel attention (Megatron-SP
    #   boundary: seq-sharded outside, head-sharded inside). On olmoe this
    #   removed the 2.7 GB/layer f32 full-seq gathers inside flash.
    # * heads do NOT divide (qwen2.5: 40 q-heads, 8 kv-heads on model=16) →
    #   sequence-parallel attention (heads replicated, q seq-sharded);
    #   forcing head sharding there made XLA reshard mid-attention
    #   (~1.2 TB/device all-reduce — refuted).
    ctx = get_context()
    model_sz = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape)).get("model", 1) if ctx.mesh else 1
    head_parallel = (H % model_sz == 0) and (KVH % model_sz == 0)
    if head_parallel:
        q = shard(q, "batch", None, "qkv_out").reshape(B, S, H, Dh)
        k = shard(k, "batch", None, "kv_out").reshape(B, S, KVH, Dh)
        v = shard(v, "batch", None, "kv_out").reshape(B, S, KVH, Dh)
    else:
        q = shard(q, "batch", "seq", None).reshape(B, S, H, Dh)
        k = shard(k, "batch", "seq", None).reshape(B, S, KVH, Dh)
        v = shard(v, "batch", "seq", None).reshape(B, S, KVH, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if k_cache is not None:
        # decode: insert new kv at cache_pos, attend over the cache
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), cache_pos, axis=1
        )
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), cache_pos, axis=1
        )
        out = flash_attention(
            q,
            k_cache.astype(q.dtype),
            v_cache.astype(q.dtype),
            causal=False,
            kv_valid_len=(
                kv_valid_len if kv_valid_len is not None else cache_pos + S
            ),
            window=cfg.attn_window,
            chunk=cfg.attn_chunk,
        )
        new_kv = (k_cache, v_cache)
    else:
        out = flash_attention(
            q, k, v, causal=True, window=cfg.attn_window, chunk=cfg.attn_chunk
        )
        new_kv = (k, v)
    out = jnp.einsum("bsz,zd->bsd", out.reshape(B, S, H * Dh), p["wo"].astype(x.dtype))
    return shard(out, "batch", "seq", "embed"), new_kv


def swiglu(x: jax.Array, p: dict) -> jax.Array:
    gate = jnp.einsum("bsd,df->bsf", x, p["wi_gate"].astype(x.dtype))
    up = jnp.einsum("bsd,df->bsf", x, p["wi_up"].astype(x.dtype))
    h = jax.nn.silu(gate) * up
    # NOTE: keep the hidden activations seq-sharded ("seq" wins the model
    # axis). The Megatron-style alternative — h sharded on ffn with full
    # seq — was tried and REFUTED on qwen2.5-14b: XLA resharded gradients
    # with ~1.4 TB/device of all-reduce (EXPERIMENTS.md §Perf).
    h = shard(h, "batch", "seq", "ffn")
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))

"""E(n)-Equivariant Graph Neural Network (EGNN, arXiv:2102.09844).

Message passing over an explicit edge index with ``jax.ops.segment_sum`` —
the JAX-native scatter substrate (no SpMM needed for EGNN's scalar-distance
messages).  Kernel regime per the taxonomy: cheap equivariant (no spherical
harmonics).

Layer l:
    m_ij      = φ_e(h_i, h_j, ||x_i − x_j||², e_ij)
    x_i^{l+1} = x_i + C · Σ_j (x_i − x_j) · φ_x(m_ij)          (coord update)
    h_i^{l+1} = φ_h(h_i, Σ_j m_ij)                              (feature update)

Distribution (ogb_products scale: 62M edges): edges are sharded over every
mesh axis; nodes are replicated.  The segment-sum over a sharded edge dim
lowers to per-shard partial sums + an all-reduce — the canonical
graph-parallel pattern.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef, init_params, param_count
from repro.sharding.specs import shard


@dataclass(frozen=True)
class EGNNConfig:
    name: str = "egnn"
    n_layers: int = 4
    d_hidden: int = 64
    d_feat: int = 16  # input node-feature dim
    coord_dim: int = 3
    n_classes: int = 8  # node classification head (0 → graph regression)
    coord_agg: str = "mean"
    scan_unroll: bool = False
    compute_dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    def param_defs(self) -> dict:
        H, Fin, Lyr = self.d_hidden, self.d_feat, self.n_layers
        pd = self.param_dtype
        # φ_e: (h_i, h_j, dist²) → m ; φ_x: m → scalar ; φ_h: (h_i, Σm) → h
        layer = {
            "edge_w1": ParamDef((Lyr, 2 * H + 1, H), ("layers", None, None), pd),
            "edge_b1": ParamDef((Lyr, H), ("layers", None), pd, "zeros"),
            "edge_w2": ParamDef((Lyr, H, H), ("layers", None, None), pd),
            "edge_b2": ParamDef((Lyr, H), ("layers", None), pd, "zeros"),
            "coord_w1": ParamDef((Lyr, H, H), ("layers", None, None), pd),
            "coord_b1": ParamDef((Lyr, H), ("layers", None), pd, "zeros"),
            "coord_w2": ParamDef((Lyr, H, 1), ("layers", None, None), pd, "normal", 0.001),
            "node_w1": ParamDef((Lyr, 2 * H, H), ("layers", None, None), pd),
            "node_b1": ParamDef((Lyr, H), ("layers", None), pd, "zeros"),
            "node_w2": ParamDef((Lyr, H, H), ("layers", None, None), pd),
            "node_b2": ParamDef((Lyr, H), ("layers", None), pd, "zeros"),
        }
        defs = {
            "encode": ParamDef((Fin, H), (None, None), pd),
            "layers": layer,
        }
        if self.n_classes > 0:
            defs["head"] = ParamDef((H, self.n_classes), (None, None), pd)
        else:
            defs["head"] = ParamDef((H, 1), (None, None), pd)
        return defs

    def init(self, key: jax.Array) -> dict:
        return init_params(self.param_defs(), key)

    def n_params(self) -> int:
        return param_count(self.param_defs())


def _mlp2(x, w1, b1, w2, b2, act=jax.nn.silu):
    w1, b1, w2, b2 = (t.astype(x.dtype) for t in (w1, b1, w2, b2))
    return act(x @ w1 + b1) @ w2 + b2


def egnn_layer(cfg: EGNNConfig, lp: dict, h, x, senders, receivers, edge_mask):
    """One EGNN layer.  h [N,H], x [N,C], edges i32[E], edge_mask bool[E]."""
    N = h.shape[0]
    hi = h[receivers]  # [E, H]
    hj = h[senders]
    xi = x[receivers]  # [E, C]
    xj = x[senders]
    diff = xi - xj
    dist2 = jnp.sum(diff * diff, axis=-1, keepdims=True)  # [E,1]
    m_in = jnp.concatenate([hi, hj, dist2], axis=-1)
    m_in = shard(m_in, "edges", None)
    m = _mlp2(m_in, lp["edge_w1"], lp["edge_b1"], lp["edge_w2"], lp["edge_b2"])
    m = jax.nn.silu(m) * edge_mask[:, None]
    m = shard(m, "edges", None)

    # coordinate update (E(n) equivariant)
    cw = jax.nn.silu(
        m @ lp["coord_w1"].astype(m.dtype) + lp["coord_b1"].astype(m.dtype)
    ) @ lp["coord_w2"].astype(m.dtype)  # [E,1]
    upd = diff * cw * edge_mask[:, None]
    num = jax.ops.segment_sum(upd, receivers, num_segments=N)
    if cfg.coord_agg == "mean":
        deg = jax.ops.segment_sum(
            edge_mask.astype(jnp.float32), receivers, num_segments=N
        )
        num = num / jnp.maximum(deg, 1.0).astype(num.dtype)[:, None]
    x_new = x + num.astype(x.dtype)

    # feature update
    agg = jax.ops.segment_sum(m, receivers, num_segments=N)  # [N,H]
    h_new = h + _mlp2(
        jnp.concatenate([h, agg], axis=-1),
        lp["node_w1"], lp["node_b1"], lp["node_w2"], lp["node_b2"],
    )
    return h_new, x_new


def forward(cfg: EGNNConfig, params: dict, batch: dict):
    """batch: feats f32[N,Fin], coords f32[N,C], senders/receivers i32[E],
    edge_mask bool[E].  Returns (node_out [N, n_classes] or graph scalar)."""
    h = batch["feats"].astype(cfg.compute_dtype) @ params["encode"].astype(
        cfg.compute_dtype
    )
    x = batch["coords"].astype(cfg.compute_dtype)
    senders, receivers = batch["senders"], batch["receivers"]
    edge_mask = batch["edge_mask"].astype(cfg.compute_dtype)

    def body(carry, lp):
        h, x = carry
        h, x = egnn_layer(cfg, lp, h, x, senders, receivers, edge_mask)
        return (h, x), None

    (h, x), _ = jax.lax.scan(body, (h, x), params["layers"], unroll=cfg.scan_unroll)
    return (h @ params["head"].astype(h.dtype)).astype(jnp.float32), x


# ---------------------------------------------------------------------------
# Explicitly-sharded full-graph training (shard_map)
# ---------------------------------------------------------------------------
#
# Auto-SPMD on the replicated-node formulation materializes f32 full-node
# gathers in backward (observed 10+ GB/device on ogb_products, plus
# "involuntary full rematerialization" partitioner warnings).  This path
# shards the NODE state row-wise over every mesh axis and makes the
# communication pattern explicit per layer:
#     all_gather(h, x)            — senders may live on any shard
#     local messages + local segment_sum into a full-N partial buffer
#     psum_scatter(partials)      — reduce-scatter back to node shards
# i.e. AG + RS per tensor per layer instead of AR + backward re-gathers.

def make_sharded_loss(cfg: EGNNConfig, mesh):
    """Returns loss(params, batch) running under shard_map on ``mesh``.

    batch node arrays must be padded to a multiple of the total device count
    (``pad_nodes``), edge arrays likewise (senders/receivers use GLOBAL ids).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)

    def body(params, batch):
        feats, coords = batch["feats"], batch["coords"]  # [N/P, ...] local
        senders, receivers = batch["senders"], batch["receivers"]  # global ids
        edge_mask = batch["edge_mask"].astype(cfg.compute_dtype)
        N_loc = feats.shape[0]
        P_tot = 1
        for a in axes:
            # jax.lax.axis_size only exists in newer jax; psum(1) is equivalent
            P_tot *= jax.lax.psum(1, a)
        N = N_loc * P_tot

        h = feats.astype(cfg.compute_dtype) @ params["encode"].astype(cfg.compute_dtype)
        x = coords.astype(cfg.compute_dtype)

        def layer(carry, lp):
            h, x = carry
            h_full = jax.lax.all_gather(h, axes, tiled=True)  # [N, H]
            x_full = jax.lax.all_gather(x, axes, tiled=True)
            hi, hj = h_full[receivers], h_full[senders]
            xi, xj = x_full[receivers], x_full[senders]
            diff = xi - xj
            dist2 = jnp.sum(diff * diff, axis=-1, keepdims=True)
            m = _mlp2(
                jnp.concatenate([hi, hj, dist2], axis=-1),
                lp["edge_w1"], lp["edge_b1"], lp["edge_w2"], lp["edge_b2"],
            )
            m = jax.nn.silu(m) * edge_mask[:, None]
            cw = jax.nn.silu(
                m @ lp["coord_w1"].astype(m.dtype) + lp["coord_b1"].astype(m.dtype)
            ) @ lp["coord_w2"].astype(m.dtype)
            upd = diff * cw * edge_mask[:, None]
            # local partial sums over the FULL node range, then reduce-scatter
            upd_p = jax.ops.segment_sum(upd, receivers, num_segments=N)
            agg_p = jax.ops.segment_sum(m, receivers, num_segments=N)
            # degree stays f32: hub degrees (>256) are not exact in bf16
            deg_p = jax.ops.segment_sum(
                edge_mask.astype(jnp.float32), receivers, num_segments=N
            )
            upd_l = jax.lax.psum_scatter(upd_p, axes, scatter_dimension=0, tiled=True)
            agg_l = jax.lax.psum_scatter(agg_p, axes, scatter_dimension=0, tiled=True)
            deg_l = jax.lax.psum_scatter(deg_p, axes, scatter_dimension=0, tiled=True)
            if cfg.coord_agg == "mean":
                upd_l = upd_l / jnp.maximum(deg_l, 1.0)[:, None]
            x = x + upd_l.astype(x.dtype)
            h = h + _mlp2(
                jnp.concatenate([h, agg_l.astype(h.dtype)], axis=-1),
                lp["node_w1"], lp["node_b1"], lp["node_w2"], lp["node_b2"],
            )
            return (h, x), None

        (h, x), _ = jax.lax.scan(
            jax.checkpoint(layer), (h, x), params["layers"], unroll=cfg.scan_unroll
        )
        out = (h @ params["head"].astype(h.dtype)).astype(jnp.float32)
        labels = batch["labels"]
        mask = labels >= 0
        lse = jax.nn.logsumexp(out, axis=-1)
        ll = jnp.sum(
            jnp.where(
                jax.lax.broadcasted_iota(jnp.int32, out.shape, 1)
                == jnp.maximum(labels, 0)[:, None],
                out, 0.0,
            ),
            axis=-1,
        )
        nll_sum = jax.lax.psum(((lse - ll) * mask).sum(), axes)
        n = jax.lax.psum(mask.sum(), axes)
        acc = jax.lax.psum(((out.argmax(-1) == labels) & mask).sum(), axes)
        loss = nll_sum / jnp.maximum(n, 1)
        return loss, {"nll": loss, "acc": acc / jnp.maximum(n, 1)}

    node = P(axes)
    edge = P(axes)
    in_specs = (
        P(),  # params replicated
        {
            "feats": node, "coords": node, "labels": node,
            "senders": edge, "receivers": edge, "edge_mask": edge,
        },
    )
    return shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=(P(), P()), check_rep=False
    )


def pad_nodes(n: int, multiple: int = 512) -> int:
    return (n + multiple - 1) // multiple * multiple


def loss_fn(cfg: EGNNConfig, params: dict, batch: dict):
    """Node classification (labels i32[N], −1 ignored) or graph regression
    (graph_ids i32[N] + targets f32[G])."""
    out, _ = forward(cfg, params, batch)
    if cfg.n_classes > 0:
        labels = batch["labels"]
        mask = labels >= 0
        lse = jax.nn.logsumexp(out, axis=-1)
        ll = jnp.take_along_axis(out, jnp.maximum(labels, 0)[:, None], axis=1)[:, 0]
        n = jnp.maximum(mask.sum(), 1)
        loss = ((lse - ll) * mask).sum() / n
        acc = ((out.argmax(-1) == labels) & mask).sum() / n
        return loss, {"nll": loss, "acc": acc}
    # graph regression: mean-pool nodes per graph
    G = batch["targets"].shape[0]
    pooled = jax.ops.segment_sum(out[:, 0], batch["graph_ids"], num_segments=G)
    counts = jax.ops.segment_sum(
        jnp.ones_like(out[:, 0]), batch["graph_ids"], num_segments=G
    )
    pred = pooled / jnp.maximum(counts, 1.0)
    loss = jnp.mean((pred - batch["targets"]) ** 2)
    return loss, {"mse": loss}

"""Mixture-of-Experts FFN with top-k routing and GROUPED sort-based dispatch.

Expert-parallel design (DESIGN.md §5): the expert dimension is sharded over
the ``model`` mesh axis; dispatch groups are the batch dimension, which is
sharded over ``data`` — so all routing bookkeeping (sort, position-in-expert,
gather, combine scatter) stays LOCAL to a data shard, and the only
cross-device movement is the expert all-to-all on the [B, E, C, D] dispatch
tensor at the expert-parallel boundary.

(History: a first implementation dispatched over the GLOBAL flattened token
axis; its gather/scatter crossed data shards and XLA materialized ~300 GB of
all-reduce per device per step on olmoe train_4k.  The grouped form below
removed >90% of that — see EXPERIMENTS.md §Perf, olmoe iteration 1.)

Per group g (one sequence):
1. router logits → top-k experts per token, normalized weights;
2. flatten (token, k) assignments, sort by expert id (S·K local sort);
3. position-in-expert via sorted-run arithmetic; drop beyond capacity
   C = ceil(factor · S · K / E);
4. [E, C] slot→token maps, gather tokens → [E, C, D], batched expert FFN,
   scatter-add back weighted by router probs.

Aux load-balance loss (Switch-style) is computed globally.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.specs import shard


def _dispatch_group(xs, top_e, top_p, E: int, C: int):
    """Per-group dispatch. xs [S, D]; top_e/top_p [S, K] → xe [E, C, D] + maps."""
    S, K = top_e.shape
    flat_e = top_e.reshape(S * K)
    flat_t = jnp.repeat(jnp.arange(S, dtype=jnp.int32), K)
    flat_w = top_p.reshape(S * K).astype(jnp.float32)
    order = jnp.argsort(flat_e)
    e_s, t_s, w_s = flat_e[order], flat_t[order], flat_w[order]
    first = jnp.searchsorted(e_s, e_s, side="left")
    pos = jnp.arange(S * K, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = pos < C
    slot = jnp.where(keep, e_s * C + pos, E * C)  # overflow bucket
    slot_token = jnp.full((E * C + 1,), 0, jnp.int32).at[slot].set(t_s)
    slot_used = jnp.zeros((E * C + 1,), bool).at[slot].set(keep)
    slot_w = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, w_s, 0.0)
    )
    slot_token = slot_token[: E * C]
    slot_used = slot_used[: E * C]
    slot_w = slot_w[: E * C]
    xe = jnp.where(slot_used[:, None], xs[slot_token], 0).reshape(E, C, -1)
    return xe, slot_token, slot_used, slot_w


def _combine_group(ye, slot_token, slot_used, slot_w, S: int):
    """Per-group combine: ye [E, C, D] → out [S, D]."""
    EC, D = ye.shape[0] * ye.shape[1], ye.shape[2]
    yflat = ye.reshape(EC, D) * slot_w[:, None].astype(ye.dtype)
    return jnp.zeros((S, D), ye.dtype).at[slot_token].add(
        jnp.where(slot_used[:, None], yflat, 0)
    )


def moe_ffn(x: jax.Array, p: dict, cfg) -> tuple[jax.Array, jax.Array]:
    """x [B, S, D] → (out [B, S, D], aux_loss scalar)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k

    # single explicit SP all-gather (bf16) reused by router AND dispatch —
    # without it XLA re-gathers x separately (and in f32) for each consumer
    x = shard(x, "batch", None, "embed")
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype)).astype(
        jnp.float32
    )
    probs = shard(jax.nn.softmax(logits, axis=-1), "batch", None, None)
    top_p, top_e = jax.lax.top_k(probs, K)  # [B, S, K]
    if cfg.norm_topk_probs:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance aux loss (Switch): E · Σ_e f_e · p_e  (global) ----
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (B * S * K)
    aux = E * jnp.sum(me * ce)

    # ---- grouped dispatch (group = sequence, local to its data shard) ----
    # (A finer grouping — chunks aligned with the sequence-parallel shard,
    # intending an expert all-to-all — was tried and REFUTED: XLA reshards
    # the 5-D dispatch tensor with all-gathers, tripling collective bytes.
    # See EXPERIMENTS.md §Perf olmoe iteration 2.)
    C = max(int(cfg.capacity_factor * S * K / E + 0.5), 1)
    xe, slot_token, slot_used, slot_w = jax.vmap(
        lambda xs, te, tp: _dispatch_group(xs, te, tp, E, C)
    )(x, top_e, top_p)  # xe [B, E, C, D]
    # expert-parallel boundary: B stays on data, E shards over model
    xe = shard(xe, "batch", "experts", None, "embed")

    gate = jnp.einsum("becd,edf->becf", xe, p["wi_gate"].astype(x.dtype))
    up = jnp.einsum("becd,edf->becf", xe, p["wi_up"].astype(x.dtype))
    h = jax.nn.silu(gate) * up
    h = shard(h, "batch", "experts", None, "expert_ffn")
    ye = jnp.einsum("becf,efd->becd", h, p["wo"].astype(x.dtype))
    ye = shard(ye, "batch", "experts", None, "embed")

    out = jax.vmap(lambda y, st, su, sw: _combine_group(y, st, su, sw, S))(
        ye, slot_token, slot_used, slot_w
    )
    out = shard(out, "batch", "seq", "embed")
    return out, aux


def moe_ffn_ref(x: jax.Array, p: dict, cfg) -> jax.Array:
    """Dense reference (computes every expert for every token) — oracle for
    tests; must match moe_ffn when capacity_factor is large enough that no
    token is dropped."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    xt = x.reshape(B * S, D)
    logits = jnp.einsum("td,de->te", xt, p["router"].astype(x.dtype)).astype(
        jnp.float32
    )
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)
    if cfg.norm_topk_probs:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    gate = jnp.einsum("td,edf->tef", xt, p["wi_gate"].astype(x.dtype))
    up = jnp.einsum("td,edf->tef", xt, p["wi_up"].astype(x.dtype))
    h = jax.nn.silu(gate) * up
    ye = jnp.einsum("tef,efd->ted", h, p["wo"].astype(x.dtype))  # [T, E, D]
    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.float32) * top_p[..., None]
    weights = onehot.sum(axis=1)  # [T, E]
    out = jnp.einsum("ted,te->td", ye.astype(jnp.float32), weights)
    return out.reshape(B, S, D).astype(x.dtype)

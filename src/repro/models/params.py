"""Parameter definition utility: one source of truth for shape, dtype,
logical sharding axes, and initializer of every parameter.

Models declare a nested dict of ``ParamDef``; from it we derive
  * ``init_params``  — real arrays (smoke tests / real training),
  * ``param_shapes`` — ShapeDtypeStructs, optionally with NamedShardings
                       attached (dry-run lowering without allocation),
  * ``param_specs``  — PartitionSpec pytree for jit in_shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.specs import logical_spec, named_sharding


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    dtype: Any = jnp.float32
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float | None = None  # override fan-in scale

    def initializer(self, key: jax.Array) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "embed":
            return (
                jax.random.normal(key, self.shape, jnp.float32) * (self.scale or 0.02)
            ).astype(self.dtype)
        # fan-in scaled normal
        fan_in = self.shape[-2] if len(self.shape) >= 2 else max(self.shape[-1], 1)
        scale = self.scale if self.scale is not None else 1.0 / np.sqrt(fan_in)
        return (jax.random.normal(key, self.shape, jnp.float32) * scale).astype(
            self.dtype
        )


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(defs: dict, key: jax.Array) -> dict:
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(
        treedef, [d.initializer(k) for d, k in zip(leaves, keys)]
    )


def param_shapes(defs: dict, mesh=None, rules=None) -> dict:
    """ShapeDtypeStruct tree; attaches NamedShardings when mesh is given."""

    def one(d: ParamDef):
        if mesh is None:
            return jax.ShapeDtypeStruct(d.shape, d.dtype)
        return jax.ShapeDtypeStruct(
            d.shape, d.dtype,
            sharding=named_sharding(mesh, d.logical, rules, shape=d.shape),
        )

    return jax.tree_util.tree_map(one, defs, is_leaf=_is_def)


def param_specs(defs: dict, mesh, rules=None) -> dict:
    return jax.tree_util.tree_map(
        lambda d: logical_spec(d.logical, mesh.axis_names, rules, d.shape, mesh),
        defs,
        is_leaf=_is_def,
    )


def param_count(defs: dict) -> int:
    leaves = jax.tree_util.tree_leaves(defs, is_leaf=_is_def)
    return int(sum(np.prod(d.shape) for d in leaves))

"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; tests and benches must keep seeing 1 device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips (data, model).
    Multi-pod: 2×16×16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes
    )


def make_host_mesh(
    shape: tuple[int, ...] | None = None, axes: tuple[str, ...] | None = None
):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1) if n > 1 else (1, 1)
        axes = ("data", "model")
    return jax.make_mesh(
        shape, axes
    )

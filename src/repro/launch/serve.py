"""Serving launcher: trace-driven serving through ``repro.serving``.

Builds a synthetic corpus + indexes, generates a serving trace (Zipf-skewed
with geographic hot spots, or adversarially uniform), optionally stamps it
with an open-loop arrival process, then drives it through the production
serving stack —

    trace → fingerprint → result cache → deadline/shape-bucketed batcher
          → (sharded) executor → scatter-gather top-k merge

— reporting QPS, p50/p99 latency, cache hit rate, padding overhead, number
of compiled batch shapes, recall@k vs the exact oracle, and the paper's
per-stage byte counters.

Replay discipline (``--arrival``):

* ``closed`` (default) — next query released when the previous finishes;
  wall-clock timing, the PR 1 baseline.
* ``poisson`` / ``bursty`` / ``diurnal`` — open-loop replay: queries enter
  at stamped arrival times (``--rate-qps`` mean rate) whether or not the
  server has kept up, batches flush on fill **or** on the oldest query's
  ``--max-wait-ms`` deadline, flushed batches drain through a FIFO
  dispatch queue onto ``--workers`` parallel executor slots, and the
  report decomposes each query's latency into batch-wait / queue-wait /
  service p50+p99 plus the fraction of queries meeting the ``--slo-ms``
  budget.  ``--coalesce`` lets a duplicate query arriving while its twin
  is queued or executing subscribe to the in-flight result instead of
  re-executing (reported in the ``coalesced`` counter).

``--prune`` switches the engines to their block-max pruned pipelines
(``--fused`` runs them as Pallas kernels; interpret mode on CPU).
K-SWEEP: whole sweep blocks whose precomputed upper bound cannot beat the
running top-C threshold are skipped before scoring.  TEXT-FIRST: the
driver term's 128-posting blocks are tested against a partial
top-``max_candidates`` impact threshold and skipped before their bytes
stream (probe→score→select in ``kernels/text_probe``).  Both shrink the
inverted-index probes and the streamed bytes in the reported counters.
``--layout impact`` stores posting lists in descending-impact segments
(:mod:`repro.core.text_index`): the pruned traversal's block bounds
become monotone per term, so one failed bound cuts the whole tail of the
term — same results as ``--layout docid``, strictly fewer blocks
streamed (watch the ``text block skip rate`` report line).

Sharded serving (``--shards N``) is configured by two grouped flags:
``--partition {hash,morton,region}`` picks the document
:class:`~repro.core.distributed.Partitioner` (resolved from the string
exactly once, here at the CLI boundary), and ``--routing
{broadcast,footprint}`` picks the scatter discipline — ``broadcast``
sends every batch to all shards (the paper's O(S) baseline), while
``footprint`` consults each shard's coverage grid and skips shards no
query footprint touches, bit-identically.  The report then carries a
per-plan ``routing:`` fan-out line (mean shards-touched per query).

Telemetry (``--trace-out/--metrics-out/--audit-out/--events-out``): any of
these flags builds the server with a :class:`repro.obs.Telemetry` handle
and exports, post-run, a Chrome/Perfetto ``trace_event`` JSON of every
query/batch/executor span (open it at https://ui.perfetto.dev), a metrics
snapshot (Prometheus text for ``.prom``/``.txt`` paths, JSON otherwise),
the planner audit JSONL (predicted vs measured cost per planned query;
``--algorithm auto`` only), and the flush/dispatch/complete/evict/coalesce
event JSONL.  Without the flags the server runs telemetry-free (zero
overhead).

``--algorithm auto`` turns on the cost-based planner
(:mod:`repro.core.planner`): every miss is routed to the cheapest of
text-first / geo-first / K-SWEEP from its posting-list lengths and
footprint coverage, batcher buckets become plan-homogeneous (one compile
per plan × shape), and the report breaks query counts, latency
percentiles and byte counters down per plan.  ``--trace mixture``
generates the bimodal workload (rare terms × huge footprints alongside
hot terms × tiny footprints) where no fixed algorithm competes with
per-query selection.

Examples::

    python -m repro.launch.serve --trace zipf --cache landlord --batcher bucketed
    python -m repro.launch.serve --trace zipf --arrival poisson \\
        --rate-qps 200 --max-wait-ms 5 --slo-ms 50 --workers 4 --coalesce
    python -m repro.launch.serve --trace zipf --prune --fused --cache none
    python -m repro.launch.serve --trace zipf --shards 8 \\
        --partition region --routing footprint --cache none
    python -m repro.launch.serve --trace mixture --algorithm auto \\
        --grid 128 --m-intervals 8 --cache none
"""
from __future__ import annotations

import argparse

from repro.core import GeoSearchEngine, QueryBudgets
from repro.core.distributed import resolve_partitioner
from repro.corpus import (
    ARRIVAL_KINDS,
    make_corpus,
    make_mixture_trace,
    make_uniform_trace,
    make_zipf_trace,
    stamp_arrivals,
)
from repro.serving import (
    DeadlineBatcher,
    GeoServer,
    SingleDeviceExecutor,
    make_cache,
    make_executor,
)


def build_telemetry(args):
    """A :class:`repro.obs.Telemetry` handle, or None when no export path
    was requested (the server then runs the telemetry-free code path)."""
    if not (args.trace_out or args.metrics_out or args.audit_out or args.events_out):
        return None
    from repro.obs import Telemetry

    return Telemetry()


def export_telemetry(tel, args) -> None:
    import json

    if args.trace_out:
        tel.tracer.write(args.trace_out)
        print(f"trace ({len(tel.tracer.queries)} query spans) → {args.trace_out}")
    if args.metrics_out:
        if args.metrics_out.endswith((".prom", ".txt")):
            with open(args.metrics_out, "w") as f:
                f.write(tel.metrics.to_prometheus())
        else:
            with open(args.metrics_out, "w") as f:
                json.dump(tel.metrics.to_json(), f, indent=2)
        print(f"metrics → {args.metrics_out}")
    if args.audit_out:
        tel.audit.to_jsonl(args.audit_out)
        errs = tel.audit.error_summary()
        joined = len(tel.audit.joined)
        print(f"planner audit ({joined} joined records) → {args.audit_out}")
        for (algo, counter), e in sorted(errs.items()):
            print(f"  pred-error {algo}/{counter}: {e:.3f}")
    if args.events_out:
        tel.events.to_jsonl(args.events_out)
        print(f"events ({len(tel.events)}) → {args.events_out}")


def build_stack(args, corpus):
    budgets = QueryBudgets(
        max_candidates=2048, max_tiles=args.max_tiles, k_sweeps=8,
        sweep_budget=max(args.n_docs // 8, 256), top_k=args.top_k,
        prune=args.prune,
    )
    sharded = args.shards > 1
    # the one place a partition *string* becomes a Partitioner instance
    executor = make_executor(
        "sharded" if sharded else "single",
        corpus,
        algorithm=args.algorithm,
        budgets=budgets,
        partitioner=resolve_partitioner(args.partition) if sharded else None,
        routing=args.routing if sharded else "broadcast",
        n_shards=args.shards,
        grid=args.grid,
        m_intervals=args.m_intervals,
        fused=args.fused,
        use_pallas=args.use_pallas,
        compress=args.compress,
        layout=args.layout,
    )

    cache = make_cache(args.cache, args.cache_capacity, max_bytes=args.cache_max_bytes)
    max_wait_s = args.max_wait_ms * 1e-3
    if args.batcher == "bucketed":
        batcher = DeadlineBatcher(
            max_batch=args.batch, max_terms=8, max_rects=4, max_wait_s=max_wait_s
        )
    else:  # "fixed": one shape only — full padding, the pre-serving baseline
        batcher = DeadlineBatcher(
            max_batch=args.batch, max_terms=8, max_rects=4,
            term_buckets=[8], rect_buckets=[4], batch_sizes=[args.batch],
            max_wait_s=max_wait_s,
        )
    server = GeoServer(
        executor, cache=cache, batcher=batcher,
        n_workers=args.workers, coalesce=args.coalesce,
        telemetry=build_telemetry(args),
    )
    return server, budgets


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n-docs", type=int, default=20000)
    ap.add_argument("--n-terms", type=int, default=2000)
    ap.add_argument("--grid", type=int, default=64)
    ap.add_argument(
        "--m-intervals", type=int, default=2,
        help="toe-print intervals per tile (higher = tighter "
        "spatial candidate streams; single-device only)",
    )
    ap.add_argument(
        "--max-tiles", type=int, default=256,
        help="per-rect tile enumeration budget",
    )
    ap.add_argument("--queries", type=int, default=2048)
    ap.add_argument("--batch", type=int, default=32, help="max micro-batch size")
    ap.add_argument("--top-k", type=int, default=10)
    ap.add_argument("--trace", default="zipf", choices=["zipf", "uniform", "mixture"])
    ap.add_argument(
        "--pool-size", type=int, default=256,
        help="distinct queries in the zipf trace pool",
    )
    ap.add_argument("--cache", default="landlord", choices=["none", "lru", "landlord"])
    ap.add_argument("--cache-capacity", type=int, default=512)
    ap.add_argument(
        "--cache-max-bytes", type=float, default=None,
        help="landlord result-payload byte budget (size-aware admission)",
    )
    ap.add_argument("--batcher", default="bucketed", choices=["bucketed", "fixed"])
    ap.add_argument(
        "--arrival", default="closed", choices=list(ARRIVAL_KINDS),
        help="closed-loop replay, or an open-loop arrival process "
        "(poisson | bursty MMPP on/off | diurnal sinusoid)",
    )
    ap.add_argument(
        "--rate-qps", type=float, default=200.0,
        help="mean offered load for open-loop arrivals",
    )
    ap.add_argument(
        "--max-wait-ms", type=float, default=None,
        help="deadline before a non-full bucket flushes anyway "
        "(0 = flush every query immediately; inf = count-only; "
        "default: inf closed-loop, 5 ms open-loop)",
    )
    ap.add_argument(
        "--slo-ms", type=float, default=None,
        help="latency budget; report the fraction of queries under it",
    )
    ap.add_argument(
        "--workers", type=int, default=1,
        help="parallel executor slots draining the dispatch queue "
        "(open-loop replay only; 1 = single busy server)",
    )
    ap.add_argument(
        "--coalesce", action="store_true",
        help="subscribe duplicate queries to in-flight twin batches "
        "instead of re-executing them",
    )
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument(
        "--partition", default="morton",
        choices=["hash", "morton", "region", "geo"],
        metavar="{hash,morton,region}",  # "geo" = legacy alias for morton
        help="document partitioner for --shards > 1 (hash = round-robin "
        "baseline; morton = Z-order range split; region = recursive "
        "median KD split)",
    )
    ap.add_argument(
        "--routing", default="broadcast", choices=["broadcast", "footprint"],
        help="scatter discipline for --shards > 1: broadcast every batch "
        "to all shards, or skip shards whose coverage grid no query "
        "footprint touches (bit-identical results, fewer shards visited)",
    )
    ap.add_argument(
        "--algorithm", default="k_sweep",
        choices=["text_first", "geo_first", "k_sweep", "auto"],
        help="fixed query algorithm, or 'auto' for per-query "
        "cost-based plan selection",
    )
    ap.add_argument(
        "--use-pallas", action="store_true",
        help="score with the Pallas geo_score kernel (interpret on CPU)",
    )
    ap.add_argument(
        "--prune", action="store_true",
        help="block-max pruning: K-SWEEP skips sweep blocks and "
        "TEXT-FIRST skips driver posting blocks whose upper bound "
        "cannot beat the running top-C threshold "
        "(fewer index probes + bytes streamed)",
    )
    ap.add_argument(
        "--layout", default="docid", choices=["docid", "impact"],
        help="posting order: docid (ascending doc ids) or impact "
        "(descending-impact segments — monotone block bounds let the "
        "pruned TEXT-FIRST traversal cut a term's whole tail after the "
        "first failed bound; identical results)",
    )
    ap.add_argument(
        "--fused", action="store_true",
        help="run K-SWEEP through the fused Pallas sweep kernel and, "
        "with --prune, TEXT-FIRST through the fused text-probe kernel "
        "(in-kernel probe→score→select; interpret mode on CPU)",
    )
    ap.add_argument(
        "--compress", default="none", choices=["none", "f16", "int8"],
        help="compressed index storage: bit-packed posting deltas plus "
        "f16 (or int8 + per-block scale) toe-print stores, decoded "
        "inside the sweep kernels — the byte counters report the "
        "compressed sizes that actually stream",
    )
    ap.add_argument(
        "--no-recall", action="store_true",
        help="skip the oracle recall check (slow on big corpora)",
    )
    ap.add_argument(
        "--trace-out", default=None, metavar="PATH",
        help="write per-query/batch/executor spans as Chrome/Perfetto "
        "trace_event JSON",
    )
    ap.add_argument(
        "--metrics-out", default=None, metavar="PATH",
        help="write the metrics registry snapshot (.prom/.txt = "
        "Prometheus text format, otherwise JSON)",
    )
    ap.add_argument(
        "--audit-out", default=None, metavar="PATH",
        help="write the planner audit JSONL (predicted vs measured cost "
        "per planned query; --algorithm auto only)",
    )
    ap.add_argument(
        "--events-out", default=None, metavar="PATH",
        help="write flush/dispatch/complete/evict/coalesce events as JSONL",
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.arrival == "closed" and args.workers > 1:
        # fail before the (minutes-long) corpus + index build does
        ap.error(
            "--workers > 1 requires an open-loop --arrival "
            "(poisson | bursty | diurnal)"
        )
    if args.routing == "footprint" and args.shards <= 1:
        ap.error("--routing footprint requires --shards > 1")
    if args.max_wait_ms is None:
        # closed-loop: count-only batching (PR 1); open-loop: a live server
        # would never hold a half-full bucket for seconds
        args.max_wait_ms = float("inf") if args.arrival == "closed" else 5.0

    print(f"building corpus: {args.n_docs} docs, {args.n_terms} terms …")
    corpus = make_corpus(args.n_docs, args.n_terms, seed=args.seed)
    server, budgets = build_stack(args, corpus)

    if args.trace == "zipf":
        trace = make_zipf_trace(
            corpus, n_queries=args.queries, pool_size=args.pool_size,
            seed=args.seed + 1,
        )
    elif args.trace == "mixture":
        trace = make_mixture_trace(corpus, n_queries=args.queries, seed=args.seed + 1)
    else:
        trace = make_uniform_trace(corpus, n_queries=args.queries, seed=args.seed + 1)
    if args.arrival != "closed":
        trace = stamp_arrivals(
            trace, args.arrival, rate_qps=args.rate_qps, seed=args.seed + 3
        )

    print(
        f"serving {len(trace)} queries: trace={args.trace} arrival={args.arrival} "
        f"rate_qps={args.rate_qps:g} max_wait_ms={args.max_wait_ms:g} "
        f"cache={args.cache} batcher={args.batcher} shards={args.shards} "
        f"partition={args.partition} routing={args.routing} "
        f"workers={args.workers} coalesce={args.coalesce} "
        f"algo={args.algorithm} prune={args.prune} fused={args.fused} "
        f"layout={args.layout} …"
    )
    report = server.run_trace(trace, arrival=args.arrival, slo_ms=args.slo_ms)
    print(report.summary())
    if server.telemetry:
        export_telemetry(server.telemetry, args)

    if not args.no_recall:
        from repro.corpus import make_query_trace

        eng = (
            server.executor.engine
            if isinstance(server.executor, SingleDeviceExecutor)
            else GeoSearchEngine.build(
                corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
                pagerank=corpus.pagerank, grid=args.grid,
                m_intervals=args.m_intervals, budgets=budgets,
                compress=args.compress, layout=args.layout,
            )
        )
        if args.trace == "mixture":
            from repro.corpus import pad_trace_batch

            probe = pad_trace_batch(trace[: min(64, len(trace))])
        else:
            probe = make_query_trace(
                corpus, n_queries=min(64, args.queries), seed=args.seed + 2
            )
        kw = (
            {"fused": True}
            if args.fused
            and (
                args.algorithm in ("k_sweep", "auto")
                or (args.algorithm == "text_first" and args.prune)
            )
            else {}
        )
        rec = eng.recall_at_k(probe, args.algorithm, **kw)
        print(f"recall@{budgets.top_k} vs oracle = {rec:.3f}")


if __name__ == "__main__":
    main()

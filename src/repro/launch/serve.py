"""Serving launcher for the geo search engine (the paper's workload).

Builds a synthetic corpus + indexes, then serves batched query traffic
through the selected algorithm, reporting QPS, latency, recall@10 vs the
exact oracle, and the per-stage byte counters the paper optimizes.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import GeoSearchEngine, QueryBudgets
from repro.corpus import make_corpus, make_query_trace


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=20000)
    ap.add_argument("--n-terms", type=int, default=2000)
    ap.add_argument("--grid", type=int, default=64)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--algorithm", default="k_sweep",
                    choices=["text_first", "geo_first", "k_sweep", "all"])
    ap.add_argument("--use-pallas", action="store_true",
                    help="score with the Pallas geo_score kernel (interpret on CPU)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print(f"building corpus: {args.n_docs} docs, {args.n_terms} terms …")
    corpus = make_corpus(args.n_docs, args.n_terms, seed=args.seed)
    budgets = QueryBudgets(
        max_candidates=2048, max_tiles=256, k_sweeps=8,
        sweep_budget=max(args.n_docs // 8, 256), top_k=10,
    )
    eng = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=args.grid, budgets=budgets,
    )
    trace = make_query_trace(corpus, n_queries=args.queries, seed=args.seed + 1)

    algos = ["text_first", "geo_first", "k_sweep"] if args.algorithm == "all" else [args.algorithm]
    kw = {}
    if args.use_pallas:
        from repro.kernels.geo_score.ops import geo_score_toeprints
        kw = {"tp_scorer": geo_score_toeprints}

    import jax
    for algo in algos:
        akw = kw if algo == "k_sweep" else {}
        # batched serving loop
        n_batches = args.queries // args.batch
        # warmup/compile
        sub = jax.tree.map(lambda x: x[: args.batch], trace)
        eng.query(sub, algo, **akw)
        t0 = time.perf_counter()
        stats_acc: dict[str, float] = {}
        for i in range(n_batches):
            sub = jax.tree.map(lambda x: x[i * args.batch : (i + 1) * args.batch], trace)
            res = eng.query(sub, algo, **akw)
            for k, v in res.stats.items():
                stats_acc[k] = stats_acc.get(k, 0.0) + float(np.asarray(v).sum())
        jax.block_until_ready(res.scores)
        dt = time.perf_counter() - t0
        qps = n_batches * args.batch / dt
        recall = eng.recall_at_k(jax.tree.map(lambda x: x[: args.batch], trace), algo)
        per_q = {k: v / (n_batches * args.batch) for k, v in stats_acc.items()}
        print(
            f"{algo:12s} qps={qps:8.1f}  ms/query={1e3/qps:6.3f}  recall@10={recall:.3f}  "
            + "  ".join(f"{k}={v:,.0f}" for k, v in sorted(per_q.items()))
        )


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes, prove memory fit, extract roofline terms.

MUST be run as a module: ``PYTHONPATH=src python -m repro.launch.dryrun``
(the XLA_FLAGS line above precedes every jax import — jax locks the device
count at first init).

Methodology (DESIGN.md §7): per cell we compile
  A. the production program (scan-over-layers)    → memory_analysis / fit
  B. a 1-layer unrolled measurement variant        → cost & collective bytes
  C. a 2-layer unrolled measurement variant        → per-layer slope
and extrapolate  cost = B + (L−1)·(C−B).  XLA's HloCostAnalysis counts a
while-loop body ONCE (not × trip count), so the scanned program A
undercounts FLOPs for deep models; the B/C pair measures the exact
per-layer increment from compiled HLO instead.  Measurement variants set
``attn_chunk = seq_len`` so the flash-attention inner scan also has exactly
one (fully counted) iteration.  Non-scanned families (recsys, geoweb) and
the fully-unrolled EGNN use a single program.

Outputs one JSON record per cell to ``--out`` (incremental, crash-safe).
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import get_arch, list_archs  # noqa: E402
from repro.launch import roofline as rf  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_cell  # noqa: E402
from repro.sharding.specs import use_sharding  # noqa: E402


def _compile(spec, shape, mesh, lm_overrides=None):
    with use_sharding(mesh):
        cell = build_cell(spec, shape, mesh, lm_overrides=lm_overrides)
        with mesh:
            if hasattr(cell.fn, "lower"):  # already-jit fn (geoweb shard_map)
                lowered = cell.fn.lower(*cell.args)
            else:
                lowered = jax.jit(cell.fn, donate_argnums=cell.donate).lower(*cell.args)
            return cell, lowered.compile()


def _cost(compiled):
    ca = compiled.cost_analysis() or {}
    coll = rf.collective_bytes(compiled.as_text())
    return (
        float(ca.get("flops", 0.0)),
        float(ca.get("bytes accessed", 0.0)),
        coll,
    )


def run_cell(spec, shape, mesh, mesh_name: str):
    t0 = time.time()
    # --- program A: production program — memory fit proof ---
    cell, compiled_A = _compile(spec, shape, mesh)
    mem = compiled_A.memory_analysis()
    t_a = time.time() - t0

    # --- cost measurement ---
    if spec.family == "lm":
        L = spec.config.n_layers
        seq = shape.params["seq_len"]
        over = dict(scan_unroll=True, attn_chunk=seq)
        _, c1 = _compile(spec, shape, mesh, lm_overrides={**over, "n_layers": 1})
        f1, b1, coll1 = _cost(c1)
        del c1
        _, c2 = _compile(spec, shape, mesh, lm_overrides={**over, "n_layers": 2})
        f2, b2, coll2 = _cost(c2)
        del c2
        flops = f1 + (L - 1) * (f2 - f1)
        bytes_ = b1 + (L - 1) * (b2 - b1)
        coll = {
            k: coll1.get(k, 0) + (L - 1) * (coll2.get(k, 0) - coll1.get(k, 0))
            for k in set(coll1) | set(coll2)
        }
        method = "L-extrapolated(1,2 unrolled)"
    else:
        flops, bytes_, coll = _cost(compiled_A)
        method = "direct"
    t_all = time.time() - t0

    r = rf.Roofline(
        arch=spec.name, shape=shape.name, mesh=mesh_name, n_devices=mesh.size,
        flops_per_dev=flops, bytes_per_dev=bytes_,
        coll_bytes_per_dev=float(sum(coll.values())), coll_breakdown=coll,
        model_flops=cell.model_flops,
        mem_per_dev_bytes=float(
            mem.temp_size_in_bytes + mem.argument_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes
        ),
        note=cell.note,
    )
    row = r.row()
    row["method"] = method
    row["t_compile_s"] = round(t_all, 1)
    row["memory_analysis"] = {
        "argument_size_in_bytes": mem.argument_size_in_bytes,
        "output_size_in_bytes": mem.output_size_in_bytes,
        "temp_size_in_bytes": mem.temp_size_in_bytes,
        "alias_size_in_bytes": mem.alias_size_in_bytes,
    }
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch (default: all)")
    ap.add_argument("--shape", default=None, help="single shape (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="dryrun_results.jsonl")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    done = set()
    if args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if "error" not in r:
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass

    archs = [args.arch] if args.arch else list_archs()
    n_ok = n_skip = n_fail = 0
    with open(args.out, "a") as out:
        for name in archs:
            spec = get_arch(name)
            for shape in spec.shapes:
                if args.shape and shape.name != args.shape:
                    continue
                for mesh_name, mesh in meshes:
                    key = (spec.name, shape.name, mesh_name)
                    if key in done:
                        continue
                    if shape.skip:
                        print(
                            f"SKIP  {spec.name} × {shape.name} × {mesh_name}: "
                            f"{shape.skip}",
                            flush=True,
                        )
                        out.write(json.dumps({
                            "arch": spec.name, "shape": shape.name,
                            "mesh": mesh_name, "skipped": shape.skip,
                        }) + "\n")
                        out.flush()
                        n_skip += 1
                        continue
                    try:
                        row = run_cell(spec, shape, mesh, mesh_name)
                        out.write(json.dumps(row) + "\n")
                        out.flush()
                        n_ok += 1
                        print(
                            f"OK    {spec.name} × {shape.name} × {mesh_name}: "
                            f"hbm={row['hbm_per_dev_GB']:.2f}GB "
                            f"t_comp={row['t_compute_s']:.2e}s "
                            f"t_mem={row['t_memory_s']:.2e}s "
                            f"t_coll={row['t_collective_s']:.2e}s "
                            f"dom={row['bottleneck']} "
                            f"frac={row['roofline_fraction']:.3f} "
                            f"(compile {row['t_compile_s']}s)",
                            flush=True,
                        )
                    except Exception as e:
                        n_fail += 1
                        print(f"FAIL  {spec.name} × {shape.name} × {mesh_name}: {e}",
                              flush=True)
                        traceback.print_exc()
                        out.write(json.dumps({
                            "arch": spec.name, "shape": shape.name,
                            "mesh": mesh_name, "error": str(e)[:500],
                        }) + "\n")
                        out.flush()
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

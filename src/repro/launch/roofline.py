"""Roofline analysis from a compiled dry-run artifact.

Three terms per (arch × shape × mesh), all in seconds (v5e constants):

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

``cost_analysis()`` on an SPMD-partitioned module reports *per-device*
FLOPs/bytes (verified empirically in tests).  Collective bytes are parsed
from the compiled HLO text: the sum of output-shape bytes of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
op.  ``MODEL_FLOPS / (HLO_FLOPs × n_devices)`` measures how much compiled
compute is useful (remat & padding waste show up here).
"""
from __future__ import annotations

import re
from dataclasses import dataclass

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s per ICI link (conservative single-link figure)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# matches e.g. "f32[128,512]{1,0}" or "bf16[4096]" or "(f32[8], s32[8])"
_SHAPE_RE = re.compile(r"(pred|[sufc]\d+|bf16)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"^\s*(?:%\S+\s*=\s*)?"  # optional "%name = "
    r"(\(?[a-z0-9\[\],{}/ ()]*\)?)\s*"  # output shape(s)
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.IGNORECASE,
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims.strip():
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-type payload bytes (per device) from HLO text.

    all-gather / all-reduce: output size ≈ payload.  reduce-scatter outputs
    the already-scattered (small) shard — scale by the replica-group size to
    recover the per-device input payload.
    """
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.match(line)
        if not m:
            continue
        shapes, kind = m.group(1), m.group(2).lower()
        # avoid double counting async pairs: skip "-done" ops
        if "-done(" in line:
            continue
        b = _shape_bytes(shapes)
        if kind == "reduce-scatter":
            g = _GROUPS_RE.search(line)
            if g:
                b *= int(g.group(2))
            elif "replica_groups={{" in line:
                first = line.split("replica_groups={{", 1)[1].split("}", 1)[0]
                b *= first.count(",") + 1
        out[kind] = out.get(kind, 0) + b
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    coll_breakdown: dict[str, int]
    model_flops: float  # analytic useful flops, GLOBAL
    mem_per_dev_bytes: float  # from memory_analysis (peak/temp+args)
    note: str = ""

    @property
    def t_compute(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_dev / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_dev / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flop_ratio(self) -> float:
        total = self.flops_per_dev * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-FLOPs MFU bound implied by the dominant term:
        (model_flops / n_dev / peak) / max(term)."""
        t_useful = self.model_flops / self.n_devices / PEAK_FLOPS
        t_dom = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / t_dom if t_dom > 0 else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "devices": self.n_devices,
            "flops/dev": self.flops_per_dev,
            "bytes/dev": self.bytes_per_dev,
            "coll_bytes/dev": self.coll_bytes_per_dev,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flop_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.roofline_fraction,
            "hbm_per_dev_GB": self.mem_per_dev_bytes / 1e9,
            "collectives": self.coll_breakdown,
            "note": self.note,
        }


def analyze(
    compiled,
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    n_devices: int,
    model_flops: float,
    note: str = "",
) -> Roofline:
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    mem = (
        ma.temp_size_in_bytes + ma.argument_size_in_bytes + ma.output_size_in_bytes
        - ma.alias_size_in_bytes
    )
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        flops_per_dev=float(ca.get("flops", 0.0)),
        bytes_per_dev=float(ca.get("bytes accessed", 0.0)),
        coll_bytes_per_dev=float(sum(coll.values())),
        coll_breakdown=coll,
        model_flops=model_flops,
        mem_per_dev_bytes=float(mem),
        note=note,
    )

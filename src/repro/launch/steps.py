"""Cell builders: (arch × shape × mesh) → (step_fn, input ShapeDtypeStructs).

``build_cell`` returns a ``Cell`` whose ``fn`` is ready for
``jax.jit(fn, ...).lower(*cell.args)``:

* ``lm_train``        train_step(params, opt_state, batch)   [donate 0,1]
* ``lm_prefill``      prefill(params, tokens, cache)
* ``lm_decode``       decode_step(params, cache, tokens, pos) [donate 1]
* ``gnn_*``           train_step(params, opt_state, graph)
* ``recsys_train``    train_step(params, opt_state, batch)
* ``recsys_serve``    forward(params, batch)
* ``recsys_retrieval`` candidate scoring (top-k)
* ``geo_serve``       distributed engine serve step (shard_map)

Every input carries a NamedSharding resolved from the logical axes — the
dry-run's in_shardings ARE the production sharding config.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import ArchSpec, ShapeSpec
from repro.models import egnn as egnn_lib
from repro.models import recsys as rec_lib
from repro.models import transformer as tf_lib
from repro.models.params import param_shapes
from repro.sharding.specs import named_sharding
from repro.train.loop import make_train_step
from repro.train.optimizer import OptimizerConfig


@dataclass
class Cell:
    arch: str
    shape: str
    fn: Callable
    args: tuple
    donate: tuple[int, ...] = ()
    # analytic "useful" flops for this step (MODEL_FLOPS of §Roofline), global
    model_flops: float = 0.0
    note: str = ""


def _sds(shape, dtype, mesh, logical):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=named_sharding(mesh, logical, shape=shape)
    )


def _moment_shardings(pshapes, mesh):
    from repro.train.optimizer import zero1_sharding

    if mesh is None:
        return None
    return jax.tree.map(
        lambda s: zero1_sharding(mesh, s.sharding.spec, s.shape), pshapes
    )


def _opt_shapes(pshapes, mesh=None):
    """Optimizer-state ShapeDtypeStructs; moments carry ZeRO-1 shardings."""
    ms = _moment_shardings(pshapes, mesh)
    if ms is None:
        moments = pshapes
    else:
        moments = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            pshapes, ms,
        )
    return {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "m": moments,
        "v": moments,
    }


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_flops(cfg, n_tokens: int, kind: str, kv_len: int = 0, batch: int = 1) -> float:
    n_active = cfg.n_active_params()
    if kind == "train":
        return 6.0 * n_active * n_tokens
    if kind == "prefill":
        return 2.0 * n_active * n_tokens
    # decode: one token per sequence + attention over the cache
    attn = 2.0 * 2.0 * batch * cfg.n_heads * cfg.d_head * kv_len
    return 2.0 * n_active * n_tokens + attn * cfg.n_layers


def build_lm_cell(
    spec: ArchSpec, shape: ShapeSpec, mesh, opt_cfg=None, overrides: dict | None = None
) -> Cell:
    cfg = spec.config
    if "attn_window" in shape.params:
        cfg = dataclasses.replace(cfg, attn_window=shape.params["attn_window"])
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    p = shape.params
    pshapes = param_shapes(cfg.param_defs(), mesh)

    if shape.kind == "lm_train":
        B, S = p["global_batch"], p["seq_len"]
        opt_cfg = opt_cfg or OptimizerConfig(zero1=True)
        step = make_train_step(
            lambda prm, b: tf_lib.loss_fn(cfg, prm, b), opt_cfg, jit=False,
            moment_shardings=_moment_shardings(pshapes, mesh),
        )
        batch = {
            "tokens": _sds((B, S), jnp.int32, mesh, ("batch", None)),
            "labels": _sds((B, S), jnp.int32, mesh, ("batch", None)),
        }
        return Cell(
            spec.name, shape.name, step,
            (pshapes, _opt_shapes(pshapes, mesh), batch), donate=(0, 1),
            model_flops=_lm_flops(cfg, B * S, "train"),
        )

    if shape.kind == "lm_prefill":
        B, S = p["global_batch"], p["seq_len"]
        cache = param_shapes(tf_lib.cache_defs(cfg, B, S), mesh)

        def fn(params, tokens, cache):
            return tf_lib.prefill(cfg, params, tokens, cache)

        tokens = _sds((B, S), jnp.int32, mesh, ("batch", None))
        return Cell(
            spec.name, shape.name, fn, (pshapes, tokens, cache), donate=(2,),
            model_flops=_lm_flops(cfg, B * S, "prefill"),
        )

    if shape.kind == "lm_decode":
        B, S = p["global_batch"], p["seq_len"]
        cache = param_shapes(tf_lib.cache_defs(cfg, B, S), mesh)

        def fn(params, cache, tokens, pos):
            return tf_lib.decode_step(cfg, params, cache, tokens, pos)

        tokens = _sds((B,), jnp.int32, mesh, ("batch",))
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        return Cell(
            spec.name, shape.name, fn, (pshapes, cache, tokens, pos), donate=(1,),
            model_flops=_lm_flops(cfg, B, "decode", kv_len=S, batch=B),
        )
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _egnn_flops(cfg, n_edges: int, n_nodes: int, train: bool = True) -> float:
    H = cfg.d_hidden
    per_edge = 2 * ((2 * H + 1) * H + H * H) + 2 * (H * H + H)  # φ_e + φ_x
    per_node = 2 * (2 * H * H + H * H)  # φ_h
    fwd = cfg.n_layers * (per_edge * n_edges + per_node * n_nodes)
    return (3.0 if train else 1.0) * fwd


def build_gnn_cell(spec: ArchSpec, shape: ShapeSpec, mesh, opt_cfg=None) -> Cell:
    p = shape.params
    if shape.kind == "gnn_molecule":
        cfg = dataclasses.replace(spec.config, d_feat=p["d_feat"], n_classes=0)
    else:
        cfg = dataclasses.replace(
            spec.config, d_feat=p["d_feat"], n_classes=p.get("n_classes", 8)
        )
    cfg = dataclasses.replace(cfg, scan_unroll=True)
    pshapes = param_shapes(cfg.param_defs(), mesh)
    opt_cfg = opt_cfg or OptimizerConfig(zero1=True)
    step = make_train_step(
        lambda prm, b: egnn_lib.loss_fn(cfg, prm, b), opt_cfg, jit=False,
        moment_shardings=_moment_shardings(pshapes, mesh),
    )

    from repro.data.graph import pad_edges
    from repro.models.egnn import make_sharded_loss, pad_nodes

    if shape.kind == "gnn_full":
        # full-graph cells use the explicitly-sharded (shard_map) path:
        # node state sharded row-wise, AG + reduce-scatter per layer
        N, E = pad_nodes(p["n_nodes"]), pad_edges(p["n_edges"])
        if mesh is not None:
            step = make_train_step(
                make_sharded_loss(cfg, mesh), opt_cfg, jit=False,
                moment_shardings=_moment_shardings(pshapes, mesh),
            )
        batch = {
            "feats": _sds((N, cfg.d_feat), jnp.float32, mesh, ("nodes", None)),
            "coords": _sds((N, cfg.coord_dim), jnp.float32, mesh, ("nodes", None)),
            "senders": _sds((E,), jnp.int32, mesh, ("edges",)),
            "receivers": _sds((E,), jnp.int32, mesh, ("edges",)),
            "edge_mask": _sds((E,), jnp.bool_, mesh, ("edges",)),
            "labels": _sds((N,), jnp.int32, mesh, ("nodes",)),
        }
        mf = _egnn_flops(cfg, E, N)
    elif shape.kind == "gnn_minibatch":
        from repro.data.graph import SampledShape

        ss = SampledShape(p["batch_nodes"], tuple(p["fanouts"]))
        N, E = ss.max_nodes, pad_edges(ss.max_edges)
        batch = {
            "feats": _sds((N, cfg.d_feat), jnp.float32, mesh, (None, None)),
            "coords": _sds((N, cfg.coord_dim), jnp.float32, mesh, (None, None)),
            "senders": _sds((E,), jnp.int32, mesh, ("edges",)),
            "receivers": _sds((E,), jnp.int32, mesh, ("edges",)),
            "edge_mask": _sds((E,), jnp.bool_, mesh, ("edges",)),
            "labels": _sds((N,), jnp.int32, mesh, (None,)),
        }
        mf = _egnn_flops(cfg, E, N)
    elif shape.kind == "gnn_molecule":
        G, npg, epg = p["batch"], p["n_nodes"], p["n_edges"]
        N, E = G * npg, pad_edges(G * epg)
        batch = {
            "feats": _sds((N, cfg.d_feat), jnp.float32, mesh, (None, None)),
            "coords": _sds((N, 3), jnp.float32, mesh, (None, None)),
            "senders": _sds((E,), jnp.int32, mesh, ("edges",)),
            "receivers": _sds((E,), jnp.int32, mesh, ("edges",)),
            "edge_mask": _sds((E,), jnp.bool_, mesh, ("edges",)),
            "graph_ids": _sds((N,), jnp.int32, mesh, (None,)),
            "targets": _sds((G,), jnp.float32, mesh, (None,)),
        }
        mf = _egnn_flops(cfg, E, N)
    else:
        raise ValueError(shape.kind)
    return Cell(
        spec.name, shape.name, step,
        (pshapes, _opt_shapes(pshapes, mesh), batch), donate=(0, 1), model_flops=mf,
    )


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _recsys_batch_specs(cfg, B: int, mesh) -> dict:
    name = type(cfg).__name__
    if name == "DCNv2Config":
        return {
            "dense": _sds((B, cfg.n_dense), jnp.float32, mesh, ("batch", None)),
            "sparse": _sds((B, cfg.n_sparse), jnp.int32, mesh, ("batch", None)),
            "label": _sds((B,), jnp.float32, mesh, ("batch",)),
        }
    if name == "AutoIntConfig":
        return {
            "sparse": _sds((B, cfg.n_sparse), jnp.int32, mesh, ("batch", None)),
            "label": _sds((B,), jnp.float32, mesh, ("batch",)),
        }
    if name == "BSTConfig":
        return {
            "history": _sds((B, cfg.seq_len), jnp.int32, mesh, ("batch", None)),
            "target": _sds((B,), jnp.int32, mesh, ("batch",)),
            "other": _sds((B, cfg.n_other_fields), jnp.int32, mesh, ("batch", None)),
            "label": _sds((B,), jnp.float32, mesh, ("batch",)),
        }
    if name == "TwoTowerConfig":
        return {
            "user_id": _sds((B,), jnp.int32, mesh, ("batch",)),
            "user_fields": _sds(
                (B, cfg.n_user_fields), jnp.int32, mesh, ("batch", None)
            ),
            "history": _sds((B, cfg.hist_len), jnp.int32, mesh, ("batch", None)),
            "target": _sds((B,), jnp.int32, mesh, ("batch",)),
            "item_fields": _sds(
                (B, cfg.n_item_fields), jnp.int32, mesh, ("batch", None)
            ),
            "logq": _sds((B,), jnp.float32, mesh, ("batch",)),
        }
    raise ValueError(name)


def _recsys_fns(cfg):
    name = type(cfg).__name__
    if name == "DCNv2Config":
        return partial(rec_lib.dcn_v2_loss, cfg), partial(rec_lib.dcn_v2_forward, cfg)
    if name == "AutoIntConfig":
        return partial(rec_lib.autoint_loss, cfg), partial(rec_lib.autoint_forward, cfg)
    if name == "BSTConfig":
        return partial(rec_lib.bst_loss, cfg), partial(rec_lib.bst_forward, cfg)
    if name == "TwoTowerConfig":
        return partial(rec_lib.two_tower_loss, cfg), None
    raise ValueError(name)


def _recsys_flops(cfg, B: int, train: bool) -> float:
    """Dense-compute FLOPs (embedding lookups are bandwidth, not FLOPs)."""
    name = type(cfg).__name__
    if name == "DCNv2Config":
        d = cfg.d_input
        per = cfg.n_cross_layers * 2 * d * d
        dims = [d, *cfg.mlp_dims]
        per += sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
        per += 2 * (d + cfg.mlp_dims[-1])
    elif name == "AutoIntConfig":
        F, D = cfg.n_sparse, cfg.embed_dim
        per, d_in = 0, D
        for _ in range(cfg.n_attn_layers):
            d_out = cfg.n_heads * cfg.d_attn
            per += F * (3 * 2 * d_in * d_out + 2 * d_in * d_out)
            per += 2 * F * F * d_out * 2
            d_in = d_out
        per += 2 * F * d_in
    elif name == "BSTConfig":
        D, S = cfg.embed_dim, cfg.seq_len + 1
        per = cfg.n_blocks * (
            4 * 2 * S * D * D + 2 * 2 * S * S * D + 2 * 2 * S * D * 4 * D
        )
        d_in = S * D + cfg.n_other_fields * D
        dims = [d_in, *cfg.mlp_dims, 1]
        per += sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
    elif name == "TwoTowerConfig":
        D = cfg.feat_dim
        u_in = D * (1 + cfg.n_user_fields + 1)
        i_in = D * (1 + cfg.n_item_fields)
        u_per = _tower_flops([u_in, *cfg.tower_dims, cfg.embed_dim])
        i_per = _tower_flops([i_in, *cfg.tower_dims, cfg.embed_dim])
        if train:  # both towers + in-batch [B,B] logits
            return 3.0 * ((u_per + i_per) * B + 2 * cfg.embed_dim * B * B)
        return u_per * B  # serve = user-embedding computation
    else:
        raise ValueError(name)
    return (3.0 if train else 1.0) * per * B


def _tower_flops(dims: list[int]) -> float:
    return sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))


def _two_tower_retrieval_flops(cfg, B: int, Nc: int) -> float:
    D = cfg.feat_dim
    u_in = D * (1 + cfg.n_user_fields + 1)
    i_in = D * (1 + cfg.n_item_fields)
    return (
        _tower_flops([u_in, *cfg.tower_dims, cfg.embed_dim]) * B
        + _tower_flops([i_in, *cfg.tower_dims, cfg.embed_dim]) * Nc
        + 2.0 * cfg.embed_dim * B * Nc  # scoring dot
    )


def build_recsys_cell(spec: ArchSpec, shape: ShapeSpec, mesh, opt_cfg=None) -> Cell:
    cfg = spec.config
    p = shape.params
    pshapes = param_shapes(cfg.param_defs(), mesh)
    loss, fwd = _recsys_fns(cfg)

    if shape.kind == "recsys_train":
        B = p["batch"]
        opt_cfg = opt_cfg or OptimizerConfig(zero1=True)
        step = make_train_step(
            lambda prm, b: loss(prm, b), opt_cfg, jit=False,
            moment_shardings=_moment_shardings(pshapes, mesh),
        )
        batch = _recsys_batch_specs(cfg, B, mesh)
        return Cell(
            spec.name, shape.name, step,
            (pshapes, _opt_shapes(pshapes, mesh), batch), donate=(0, 1),
            model_flops=_recsys_flops(cfg, B, True),
        )

    if shape.kind == "recsys_serve":
        B = p["batch"]
        if fwd is None:  # two-tower: serve = user-embedding computation
            def fn(prm, batch):
                return rec_lib.two_tower_user(cfg, prm, batch)
        else:
            def fn(prm, batch):
                return fwd(prm, batch)
        batch = _recsys_batch_specs(cfg, B, mesh)
        batch.pop("label", None)
        return Cell(
            spec.name, shape.name, fn, (pshapes, batch),
            model_flops=_recsys_flops(cfg, B, False),
        )

    if shape.kind == "recsys_retrieval":
        Nc = p["n_candidates"]
        B = p["batch"]
        if type(cfg).__name__ == "TwoTowerConfig":
            def fn(prm, batch, cand_ids, cand_fields):
                return rec_lib.two_tower_score_candidates(
                    cfg, prm, batch, cand_ids, cand_fields, top_k=100
                )

            batch = _recsys_batch_specs(cfg, B, mesh)
            batch.pop("label", None)
            cand_ids = _sds((Nc,), jnp.int32, mesh, ("candidates",))
            cand_fields = _sds(
                (Nc, cfg.n_item_fields), jnp.int32, mesh, ("candidates", None)
            )
            return Cell(
                spec.name, shape.name, fn, (pshapes, batch, cand_ids, cand_fields),
                model_flops=_two_tower_retrieval_flops(cfg, B, Nc),
            )
        # CTR models: retrieval scoring = candidate-major forward batch
        batch = _recsys_batch_specs(cfg, Nc, mesh)
        batch.pop("label", None)

        def fn(prm, batch):
            scores = fwd(prm, batch)
            return jax.lax.top_k(scores, 100)

        return Cell(
            spec.name, shape.name, fn, (pshapes, batch),
            model_flops=_recsys_flops(cfg, Nc, False),
            note="candidate-major scoring (1 user context broadcast into rows)",
        )
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# geoweb cells (the paper's system)
# ---------------------------------------------------------------------------

I32_SAFE_MAX = 2**30  # see _check_i32_addressable below


def _check_i32_addressable(name: str, value: int, n_shards: int) -> int:
    """Guard the engine's int32 index arithmetic at production scale.

    Every posting/toe-print position in the query pipeline is int32 (CSR
    offsets, binary-search bounds, sweep starts).  At the paper's full
    scale (2^26 docs × 128 postings = 2^33 global postings) a shard's
    store only stays addressable because the mesh provides enough doc
    shards; with too few shards the offsets' top entries and the search
    positions silently wrap negative.  The bound is 2^30 — not 2^31−1 —
    so intermediate index *sums* (e.g. ``start + budget``, the bisection
    bounds) keep headroom too.  Fails loudly at cell-construction time
    with the minimum shard count instead of lowering a program that
    would return garbage.
    """
    if value > I32_SAFE_MAX:
        need = -(-value * n_shards // I32_SAFE_MAX)
        raise ValueError(
            f"geoweb cell: per-shard {name} = {value:,} exceeds the int32-"
            f"addressable bound 2^30; shard the docs over >= {need} devices "
            f"(mesh provides {n_shards}) or shrink the config"
        )
    return value


def build_geoweb_cell(spec: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    from repro.core import algorithms as alg
    from repro.core.distributed import COVERAGE_GRID, make_serve_fn, ShardedGeoIndex

    cfg = spec.config
    if mesh is None:
        raise ValueError("geoweb cells need a mesh")
    doc_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    q_axis = "model"
    S = int(np.prod([mesh.shape[a] for a in doc_axes]))
    N = cfg.n_docs // S  # docs per shard
    Tt = _check_i32_addressable(
        "toe prints", N * cfg.max_rects, S
    )  # toe prints per shard
    Pp = _check_i32_addressable("postings", N * cfg.avg_postings_per_doc, S)
    G2 = cfg.grid * cfg.grid
    R = cfg.doc_major_rects
    M = cfg.n_terms

    def sh(shape_, dtype, logical):
        return _sds(shape_, dtype, mesh, logical)

    from repro.core.spatial_index import SCALE_BLOCK, normalize_compress
    from repro.core.text_index import POSTING_BLOCK

    mode = normalize_compress(getattr(cfg, "compress", False))
    ft = jnp.float16 if mode != "none" else jnp.float32
    at = jnp.int8 if mode == "int8" else ft  # amp store dtype
    it = jnp.int16 if (mode != "none" and N <= 2**15 - 1) else jnp.int32
    # compressed posting store widths: one block per POSTING_BLOCK postings,
    # delta width bounded by the per-shard doc-id range
    NBp = max(-(-Pp // POSTING_BLOCK), 1) if mode != "none" else 0
    # logical 128-posting framing exists in BOTH layouts (block-max text
    # pruning metadata rides on it)
    NBt = max(-(-Pp // POSTING_BLOCK), 1)
    d_bits = max(int(N - 1).bit_length(), 1) if N > 1 else 1
    Wp = NBp * (POSTING_BLOCK * d_bits // 32)
    Pp_store = 0 if mode != "none" else Pp  # raw doc-id column
    SBn = max(-(-Tt // SCALE_BLOCK), 1) if mode == "int8" else 0
    # block-max metadata columns (always f32; see core/spatial_index.py)
    block_size = getattr(cfg, "block_size", 128)
    NB = max((Tt + block_size - 1) // block_size, 1)
    lead = ("docs",)  # leading shard dim over doc axes
    idx = ShardedGeoIndex(
        postings=sh((S, Pp_store), jnp.int32, lead + (None,)),
        impacts=sh((S, Pp), ft, lead + (None,)),
        offsets=sh((S, M + 1), jnp.int32, lead + (None,)),
        post_packed=sh((S, Wp), jnp.uint32, lead + (None,)),
        blk_first=sh((S, NBp), jnp.int32, lead + (None,)),
        blk_bits=sh((S, NBp), jnp.int32, lead + (None,)),
        blk_word_off=sh((S, NBp), jnp.int32, lead + (None,)),
        blk_n_exc=sh((S, NBp), jnp.int32, lead + (None,)),
        blk_len=sh((S, NBt), jnp.int32, lead + (None,)),
        blk_pos=sh((S, NBt), jnp.int32, lead + (None,)),
        blk_max_impact=sh((S, NBt), jnp.float32, lead + (None,)),
        blk_term_off=sh((S, M + 1), jnp.int32, lead + (None,)),
        # docID layout: the impact-segment CSR is degenerate (see
        # core/text_index.py build_text_index_np)
        seg_term_off=sh((S, M + 1), jnp.int32, lead + (None,)),
        seg_pos=sh((S, 1), jnp.int32, lead + (None,)),
        seg_len=sh((S, 1), jnp.int32, lead + (None,)),
        tp_rects=sh((S, Tt, 4), ft, lead + (None, None)),
        tp_amps=sh((S, Tt), at, lead + (None,)),
        tp_doc_ids=sh((S, Tt), it, lead + (None,)),
        tp_amp_scale=sh((S, SBn), jnp.float32, lead + (None,)),
        tile_starts=sh((S, G2, cfg.m_intervals), jnp.int32, lead + (None, None)),
        tile_ends=sh((S, G2, cfg.m_intervals), jnp.int32, lead + (None, None)),
        doc_rects=sh((S, N, R, 4), ft, lead + (None, None, None)),
        doc_amps=sh((S, N, R), ft, lead + (None, None)),
        doc_mbr=sh((S, N, 4), ft, lead + (None, None)),
        doc_mass=sh((S, N), ft, lead + (None,)),
        blk_mbr=sh((S, NB, 4), jnp.float32, lead + (None, None)),
        blk_max_amp=sh((S, NB), jnp.float32, lead + (None,)),
        blk_max_mass=sh((S, NB), jnp.float32, lead + (None,)),
        pagerank=sh((S, N), jnp.float32, lead + (None,)),
        doc_offset=sh((S, N), jnp.int32, lead + (None,)),
        coverage_sat=sh(
            (S, COVERAGE_GRID + 1, COVERAGE_GRID + 1),
            jnp.float32,
            lead + (None, None),
        ),
        grid=cfg.grid,
        n_terms=M,
        block_size=block_size,
        coverage_grid=COVERAGE_GRID,
        # synthetic hot-term bound: a term may touch every shard doc
        max_term_blocks=max(-(-N // POSTING_BLOCK), 1),
    )
    B, d, Qr = cfg.query_batch, cfg.d_terms, cfg.q_rects
    query = alg.QueryBatch(
        terms=sh((B, d), jnp.int32, ("queries", None)),
        rects=sh((B, Qr, 4), jnp.float32, ("queries", None, None)),
        amps=sh((B, Qr), jnp.float32, ("queries", None)),
    )
    serve = make_serve_fn(
        mesh, cfg.budgets, cfg.weights, doc_axes=doc_axes, query_axis=q_axis,
        algorithm=shape.params["algorithm"], grid=cfg.grid, n_terms=M,
        max_term_blocks=idx.max_term_blocks,
    )
    # geo-score flops: ~14 flops per (toeprint, query-rect) pair per query
    kb = cfg.budgets
    mf = float(B) * kb.k_sweeps * kb.sweep_budget * Qr * 14
    return Cell(spec.name, shape.name, serve, (idx, query), model_flops=mf)


def build_cell(
    spec: ArchSpec,
    shape: ShapeSpec,
    mesh,
    opt_cfg=None,
    lm_overrides: dict | None = None,
) -> Cell:
    if spec.family == "lm":
        return build_lm_cell(spec, shape, mesh, opt_cfg, lm_overrides)
    if spec.family == "gnn":
        return build_gnn_cell(spec, shape, mesh, opt_cfg)
    if spec.family == "recsys":
        return build_recsys_cell(spec, shape, mesh, opt_cfg)
    if spec.family == "geoweb":
        return build_geoweb_cell(spec, shape, mesh)
    raise ValueError(spec.family)

"""Training launcher: ``PYTHONPATH=src python -m repro.launch.train --arch <id>``.

Runs real training steps on the host's devices (reduced config by default —
this container is a single CPU; pass ``--full`` on a real cluster), with
checkpointing, fault injection, and deterministic data.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_arch
from repro.data.graph import full_graph_batch, make_powerlaw_graph
from repro.data.lm import LMDataConfig, lm_batch
from repro.data.recsys import bst_batch, ctr_batch, two_tower_batch
from repro.launch.mesh import make_host_mesh
from repro.models import egnn as egnn_lib
from repro.models import recsys as rec_lib
from repro.models import transformer as tf_lib
from repro.sharding.specs import use_sharding
from repro.train.loop import LoopConfig, make_train_step, run
from repro.train.optimizer import OptimizerConfig, init_opt_state


def loss_and_batch_fns(spec, cfg, batch_size: int, seq_len: int, seed: int):
    if spec.family == "lm":
        dc = LMDataConfig(
            vocab=cfg.vocab, seq_len=seq_len, global_batch=batch_size, seed=seed
        )
        return (
            lambda p, b: tf_lib.loss_fn(cfg, p, b),
            lambda step: lm_batch(dc, step),
        )
    if spec.family == "gnn":
        g = make_powerlaw_graph(
            512, 2048, cfg.d_feat, n_classes=max(cfg.n_classes, 1), seed=seed
        )
        batch = full_graph_batch(g, edge_multiple=8)
        return (lambda p, b: egnn_lib.loss_fn(cfg, p, b), lambda step: batch)
    if spec.family == "recsys":
        name = type(cfg).__name__
        if name == "DCNv2Config":
            return (
                lambda p, b: rec_lib.dcn_v2_loss(cfg, p, b),
                lambda step: ctr_batch(
                    batch_size, cfg.n_dense, cfg.vocab_sizes, seed, step
                ),
            )
        if name == "AutoIntConfig":
            return (
                lambda p, b: rec_lib.autoint_loss(cfg, p, b),
                lambda step: ctr_batch(batch_size, 0, cfg.vocab_sizes, seed, step),
            )
        if name == "BSTConfig":
            return (
                lambda p, b: rec_lib.bst_loss(cfg, p, b),
                lambda step: bst_batch(
                    batch_size, cfg.n_items, cfg.seq_len,
                    cfg.n_other_fields, cfg.field_vocab, seed, step,
                ),
            )
        if name == "TwoTowerConfig":
            return (
                lambda p, b: rec_lib.two_tower_loss(cfg, p, b),
                lambda step: two_tower_batch(
                    batch_size, cfg.n_users, cfg.n_items,
                    cfg.n_user_fields, cfg.n_item_fields,
                    cfg.field_vocab, cfg.hist_len, seed, step,
                ),
            )
    raise ValueError(spec.family)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true", help="use the full published config")
    ap.add_argument("--simulate-failure", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    if spec.family == "geoweb":
        raise SystemExit("geoweb is a serving system: use repro.launch.serve")
    cfg = spec.config if args.full else spec.smoke_config

    mesh = make_host_mesh() if len(jax.devices()) > 1 else None
    opt = OptimizerConfig(
        lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
        total_steps=args.steps,
    )
    loss_fn, batch_fn = loss_and_batch_fns(
        spec, cfg, args.batch_size, args.seq_len, args.seed
    )

    with use_sharding(mesh):
        step_fn = make_train_step(loss_fn, opt, microbatches=args.microbatches)

        def init_state():
            params = cfg.init(jax.random.key(args.seed))
            return params, init_opt_state(opt, params)

        loop = LoopConfig(
            total_steps=args.steps, ckpt_every=args.ckpt_every,
            ckpt_dir=args.ckpt_dir, log_every=max(args.steps // 20, 1),
            simulate_failure_at=args.simulate_failure,
        )
        run(loop, step_fn, init_state, batch_fn)


if __name__ == "__main__":
    main()

"""Logical-axis sharding rules → PartitionSpecs (MaxText-style, minimal).

Models annotate every parameter and activation with *logical* dimension
names ("batch", "heads", "ffn", "vocab", "experts", …).  A ``ShardingRules``
table maps logical names to candidate mesh axes; ``logical_spec`` resolves
them against a concrete mesh (skipping axes the mesh doesn't have, never
using one mesh axis twice in a spec).  This keeps every model definition
mesh-agnostic: the same code lowers on ``(data, model)``,
``(pod, data, model)``, or a single CPU device (no mesh → no constraint).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default logical-axis → mesh-axes table. Tuple values are *joined* mesh axes
# (e.g. batch is sharded over pod AND data).
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "embed": (),  # d_model replicated by default
    "seq": ("model",),  # sequence parallelism: saved activations shard over model
    "docs": ("pod", "data"),  # geo engine: document shards
    "queries": ("model",),  # geo engine: query replicas
    "edges": ("pod", "data", "model"),  # GNN: edge partitioning
    "nodes": ("pod", "data", "model"),  # GNN node-sharded state (shard_map path)
    "rows": ("model",),  # recsys embedding-table rows
    "candidates": ("pod", "data"),  # retrieval candidate sharding
    "layers": (),
    "expert_ffn": (),
    "stage": ("pod",),  # pipeline stages (optional PP)
    "zero1_dim0": ("data",),  # ZeRO-1 optimizer-moment sharding
    "qkv_out": ("model",),  # flattened H*Dh projection output (TP column)
    "kv_out": ("model",),  # flattened KVH*Dh projection output
    "head_dim": ("model",),  # per-head feature dim (KV-cache fallback shard)
    "kv_seq": ("pod", "data"),  # KV-cache sequence dim (long-context decode)
}


@dataclass
class ShardingContext:
    mesh: Mesh | None = None
    rules: dict[str, tuple[str, ...]] = field(default_factory=lambda: dict(DEFAULT_RULES))


_CTX = threading.local()


def get_context() -> ShardingContext:
    if not hasattr(_CTX, "ctx"):
        _CTX.ctx = ShardingContext()
    return _CTX.ctx


class use_sharding:
    """Context manager installing (mesh, rules) for model code."""

    def __init__(self, mesh: Mesh | None, rules: dict | None = None):
        self.new = ShardingContext(mesh, dict(rules or DEFAULT_RULES))

    def __enter__(self):
        self.prev = get_context()
        _CTX.ctx = self.new
        return self.new

    def __exit__(self, *exc):
        _CTX.ctx = self.prev
        return False


def logical_spec(
    dims: tuple[str | None, ...],
    mesh_axis_names: tuple[str, ...],
    rules: dict[str, tuple[str, ...]] | None = None,
    shape: tuple[int, ...] | None = None,
    mesh: Mesh | None = None,
) -> P:
    """Resolve logical dim names to a PartitionSpec for a mesh.

    Shape-aware: a candidate mesh axis is only taken if the cumulative shard
    product still divides the dimension (jit in_shardings require exact
    division; an indivisible axis is dropped and stays available for later
    dims — e.g. a KV cache whose 8 kv-heads can't split over model=16 falls
    through to head_dim 128, which can).
    """
    rules = rules or get_context().rules
    sizes = dict(zip(mesh.axis_names, mesh.shape.values() if hasattr(mesh.shape, "values") else mesh.shape)) if mesh is not None else {}
    used: set[str] = set()
    out = []
    for i, d in enumerate(dims):
        if d is None:
            out.append(None)
            continue
        dim_size = shape[i] if shape is not None else None
        axes = []
        prod = 1
        for a in rules.get(d, ()):
            if a not in mesh_axis_names or a in used:
                continue
            a_size = sizes.get(a)
            if dim_size is not None and a_size is not None:
                if dim_size % (prod * a_size) != 0:
                    continue
                prod *= a_size
            axes.append(a)
        used.update(axes)
        if len(axes) == 0:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def shard(x: jax.Array, *dims: str | None) -> jax.Array:
    """Apply a logical sharding constraint (no-op without a mesh context)."""
    ctx = get_context()
    if ctx.mesh is None:
        return x
    spec = logical_spec(
        tuple(dims), ctx.mesh.axis_names, ctx.rules, tuple(x.shape), ctx.mesh
    )
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def named_sharding(
    mesh: Mesh,
    dims: tuple[str | None, ...],
    rules=None,
    shape: tuple[int, ...] | None = None,
) -> NamedSharding:
    return NamedSharding(
        mesh, logical_spec(dims, mesh.axis_names, rules, shape, mesh)
    )

"""Serving-stack benchmark: cache policy × batcher × sharding × arrival sweeps.

Prints the same ``name,us_per_call,derived`` CSV rows as ``benchmarks.run``
but for the serving layer (``repro.serving``):

* ``serve_cache_*``     — zipf trace through none / lru / landlord caches:
                          QPS, p50/p99 latency, hit rate.
* ``serve_batcher_*``   — bucketed vs fixed-shape batching: padding overhead
                          and number of compiled shapes.
* ``serve_shards_*``    — doc-sharded scatter-gather execution.
* ``serve_routing_*``   — footprint routing vs broadcast over the same
                          region-partitioned S=8 engines on a city-scale
                          zipf trace; the ``_fanout`` row reports
                          ``shards_touched_mean`` (≪ S) and the
                          bit-identity check (``identical=1``).
* ``serve_algo_ksweep_pruned`` — the block-max pruned K-SWEEP engine
                          (``budgets.prune``) behind the same serving
                          stack: fewer inverted-index probes and streamed
                          bytes per executed batch.
* ``serve_compress_int8`` — the int8-compressed posting/toe-print store
                          behind the same stack on the same zipf trace;
                          the ``_io`` row reports the streamed
                          postings+spatial byte ratio vs the uncompressed
                          engine (gated ≥ 2× in ``compare_baseline``).
* ``serve_algo_textprune`` — the block-max pruned TEXT-FIRST engine
                          (fused probe→score→select kernel) on a planted
                          impact-bimodal hot-pair trace; the
                          ``serve_text_prune_io`` row reports probe and
                          postings-byte ratios plus recall@10 vs the
                          unpruned covering-budget twin (gated ≥ 2× at
                          recall ≥ 0.99 in ``compare_baseline``).
* ``serve_text_prune_natural`` — the impact-ordered posting layout
                          (``layout="impact"``) vs the docID-ordered one
                          on a *plain* zipf trace with no planted
                          bimodality, both pruned+fused:
                          ``layout_bytes_x`` is the docID-pruned ÷
                          impact-pruned streamed-posting-byte ratio and
                          results are bit-identical (pruned selection is
                          order-invariant); gated ≥ 1.5× with
                          ``recall_vs_docid ≥ 0.99`` and blocks actually
                          skipped in ``compare_baseline``.
* ``serve_algo_auto``   — the cost-based per-query planner (``--algo
                          auto``) on the bimodal mixture trace: plan-
                          homogeneous buckets, one compile per plan×shape;
                          the ``_plans`` row prints the per-plan query mix.
* ``serving_arrival_*`` — open-loop replay (Poisson + bursty MMPP arrivals)
                          across ``max_wait_ms`` deadlines: the throughput
                          vs tail-latency tradeoff of deadline-based batch
                          flush, with batch-wait / queue-wait / service p99
                          and SLO attainment per row.
* ``serving_workers_*`` — the multi-worker dispatch queue × in-flight
                          coalescing sweep on the Zipf trace (duplicates
                          common): workers ∈ {1,2,4} × coalesce on/off;
                          more workers cut queue-wait, coalescing cuts
                          re-executed duplicates (``coalesced`` column).

All single-device rows share one engine so jit compiles amortize across
configurations (the engine's compiled-function cache is keyed per shape,
exactly as a long-running server would hold it).

``--smoke`` shrinks corpus/trace/bucket-lattice so the whole file finishes
in well under a minute on CPU — it is part of ``scripts/check.sh``'s
pre-merge gate.  ``--json PATH`` additionally dumps every row's parsed
derived fields for the baseline-regression comparison
(``benchmarks.compare_baseline``).

Usage: ``PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] [--json out.json]``
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core import GeoSearchEngine, QueryBudgets
from repro.core.distributed import MortonPartitioner, RegionRangePartitioner
from repro.corpus import make_corpus, make_uniform_trace, make_zipf_trace, stamp_arrivals
from repro.serving import (
    DeadlineBatcher,
    GeoServer,
    ShardedExecutor,
    SingleDeviceExecutor,
    make_cache,
)

ROWS: dict[str, dict] = {}  # name -> parsed row (for --json / baseline compare)


def make_textprune_corpus(n_docs: int, n_short: int = 1024, seed: int = 9):
    """Zipf corpus with a planted impact-bimodal hot term pair (ISSUE 9).

    Two extra terms appear in EVERY document, so their docID-ordered
    posting lists span the whole corpus: the first ``n_short`` docs repeat
    them 16× inside short (len-64) documents (high impact), the rest
    mention them once inside long (len-130) documents (low impact).  The
    driver list's first posting blocks therefore hold exactly the
    high-impact docs, so a block-max pruned traversal fills its θ buffer
    from the first tile and deterministically skips every later block,
    while an unpruned traversal needs ``max_candidates ≥ df = n_docs`` to
    return the same top-k.  Footprints are identical and pagerank constant,
    so text strictly decides the ranking and recall vs the unpruned twin
    is exact.  Also used by ``benchmarks.run`` for the core rows.
    """
    assert n_docs > n_short
    n_terms_base = 400
    base = make_corpus(n_docs, n_terms_base, seed=seed)
    hot = np.array([n_terms_base, n_terms_base + 1], dtype=np.int32)
    rng = np.random.default_rng(seed + 1)
    doc_terms = []
    for d, terms in enumerate(base.doc_terms):
        terms = np.asarray(terms, dtype=np.int32)[:32]
        if d < n_short:
            doc_terms.append(np.concatenate([terms, np.repeat(hot, 16)]))
        else:
            fill = rng.integers(0, n_terms_base, size=96).astype(np.int32)
            doc_terms.append(np.concatenate([terms, hot, fill]))
    rects = np.tile(
        np.array([[[0.05, 0.05, 0.95, 0.95]]], np.float32), (n_docs, 1, 1)
    )
    amps = np.ones((n_docs, 1), np.float32)
    return doc_terms, rects, amps, n_terms_base + 2, hot


def textprune_trace(hot: np.ndarray, n_queries: int) -> list:
    """Hot-pair conjunction queries for :func:`make_textprune_corpus`."""
    from repro.corpus import TraceQuery

    return [
        TraceQuery(
            terms=hot.copy(),
            rects=np.array([[0.2, 0.2, 0.8, 0.8]], np.float32),
            amps=np.ones((1,), np.float32),
        )
        for _ in range(n_queries)
    ]


def _row(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)
    rec: dict = {"us_per_call": us}
    for part in derived.split(";"):
        if not part:
            continue
        k, _, v = part.partition("=")
        try:
            rec[k] = float(v)
        except ValueError:
            rec[k] = v
    ROWS[name] = rec


def report_row(name: str, rep) -> None:
    """Shared derived-column format for serving rows (also used by run.py)."""
    derived = (
        f"qps={rep.qps:.0f};p50_ms={rep.percentile_ms(50):.3f};"
        f"p99_ms={rep.percentile_ms(99):.3f};hit_rate={rep.hit_rate:.3f};"
        f"padding={rep.padding_overhead:.3f};shapes={rep.n_compiled_shapes}"
    )
    if rep.arrival != "closed":
        derived += (
            f";bw_p99_ms={rep.stage_percentile_ms('batch_wait', 99):.3f}"
            f";qw_p99_ms={rep.stage_percentile_ms('queue_wait', 99):.3f}"
            f";svc_p99_ms={rep.stage_percentile_ms('service', 99):.3f}"
            f";workers={rep.n_workers};coalesced={rep.coalesced}"
        )
        if rep.slo_ms is not None:
            derived += f";slo={rep.slo_attainment:.3f}"
    _row(name, 1e6 / rep.qps if rep.qps else 0.0, derived)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes; finishes < 60 s on CPU (pre-merge gate)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write all rows as JSON (baseline comparison input)")
    args = ap.parse_args()
    smoke = args.smoke
    n_docs = 1200 if smoke else 20000
    n_q = 384 if smoke else 4096
    max_batch = 16 if smoke else 32
    # smoke: a coarse bucket lattice → few compiles; full: the real lattice
    buckets = dict(
        term_buckets=[4, 8] if smoke else [],
        rect_buckets=[2, 4] if smoke else [],
    )

    def batcher(kind="bucketed", max_wait_s=float("inf")):
        if kind == "fixed":
            return DeadlineBatcher(
                max_batch=max_batch, max_terms=8, max_rects=4,
                term_buckets=[8], rect_buckets=[4], batch_sizes=[max_batch],
                max_wait_s=max_wait_s,
            )
        return DeadlineBatcher(
            max_batch=max_batch, max_terms=8, max_rects=4,
            term_buckets=list(buckets["term_buckets"]),
            rect_buckets=list(buckets["rect_buckets"]),
            max_wait_s=max_wait_s,
        )

    print("name,us_per_call,derived")
    corpus = make_corpus(n_docs, 400 if smoke else 2000, seed=0)
    budgets = QueryBudgets(
        max_candidates=1024, max_tiles=256, k_sweeps=8,
        sweep_budget=max(n_docs // 8, 256), top_k=10,
    )
    eng = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=32, budgets=budgets,
    )
    single = SingleDeviceExecutor(eng)
    zipf = make_zipf_trace(corpus, n_queries=n_q, pool_size=max(n_q // 8, 32), seed=1)
    uni = make_uniform_trace(corpus, n_queries=n_q // 2, seed=1)

    for cache in ["none", "lru", "landlord"]:
        server = GeoServer(single, cache=make_cache(cache, 512), batcher=batcher())
        report_row(f"serve_cache_{cache}_zipf", server.run_trace(zipf))
    server = GeoServer(single, cache=make_cache("landlord", 512), batcher=batcher())
    report_row("serve_cache_landlord_uniform", server.run_trace(uni))

    for kind in ["bucketed", "fixed"]:
        server = GeoServer(single, cache=None, batcher=batcher(kind))
        report_row(f"serve_batcher_{kind}", server.run_trace(zipf))

    # block-max pruned K-SWEEP behind the same stack (shares the corpus;
    # its own engine since `prune` is a static budget).  No cache, so every
    # query actually executes the pruned pipeline.
    from dataclasses import replace as _replace

    eng_pruned = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=32,
        budgets=_replace(budgets, prune=True),
    )
    server = GeoServer(
        SingleDeviceExecutor(eng_pruned), cache=None, batcher=batcher()
    )
    rep = server.run_trace(zipf)
    probes = rep.stats.get("n_probes", 0)
    saved = rep.stats.get("probes_saved", 0)
    skipped = rep.stats.get("blocks_skipped", 0)
    report_row("serve_algo_ksweep_pruned", rep)
    _row(
        "serve_algo_ksweep_pruned_io", 0.0,
        f"n_probes={probes:.0f};probes_saved={saved:.0f};"
        f"blocks_skipped={skipped:.0f}",
    )

    # cost-based planner behind the same stack on the bimodal mixture
    # trace: per-query plan selection, plan-homogeneous buckets, per-plan
    # report attribution.  No cache, so every query exercises its plan.
    from repro.corpus import make_mixture_trace

    eng_auto = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=64, m_intervals=8, budgets=budgets,
    )
    mixture = make_mixture_trace(
        corpus, n_queries=n_q // 4 if smoke else n_q // 2, seed=5
    )
    server = GeoServer(
        SingleDeviceExecutor(eng_auto, "auto"), cache=None, batcher=batcher()
    )
    rep = server.run_trace(mixture)
    report_row("serve_algo_auto", rep)
    _row(
        "serve_algo_auto_plans", 0.0,
        ";".join(
            f"{label}={n}" for label, n in sorted(rep.plan_queries.items())
        )
        + f";n_plans={len(rep.plan_queries)}",
    )

    # compressed stores behind the same stack: int8 posting + toe-print
    # compression end to end through server → executor → engine.  No cache,
    # so every query streams the compressed store; the `_io` row reports
    # the postings+spatial byte ratio vs the uncompressed engine on the
    # identical trace (the ISSUE 8 serving-layer gate).
    eng_comp = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=32, budgets=budgets, compress="int8",
    )
    server = GeoServer(
        SingleDeviceExecutor(eng_comp), cache=None, batcher=batcher()
    )
    rep_c = server.run_trace(zipf)
    rep_u = GeoServer(single, cache=None, batcher=batcher()).run_trace(zipf)
    bytes_c = rep_c.stats.get("bytes_postings", 0.0) + rep_c.stats.get(
        "bytes_spatial", 0.0
    )
    bytes_u = rep_u.stats.get("bytes_postings", 0.0) + rep_u.stats.get(
        "bytes_spatial", 0.0
    )
    report_row("serve_compress_int8", rep_c)
    _row(
        "serve_compress_int8_io", 0.0,
        f"bytes_compressed={bytes_c:.0f};bytes_uncompressed={bytes_u:.0f};"
        f"bytes_x={bytes_u / max(bytes_c, 1e-9):.2f}",
    )

    # block-max pruned TEXT-FIRST behind the same stack (ISSUE 9): the
    # planted impact-bimodal hot pair means the fused probe kernel fills θ
    # from the driver's first tile and skips every later block, while the
    # unpruned twin needs max_candidates >= df for the same answers.  No
    # cache, so every query streams postings; the `_io` row is gated in
    # compare_baseline (probe and postings-byte ratios must stay >= 2× at
    # recall@10 >= 0.99).
    from repro.core.ranking import topk_recall_np

    tp_docs, tp_rects, tp_amps, tp_nt, tp_hot = make_textprune_corpus(
        3072 if smoke else 8192
    )
    tp_trace = textprune_trace(tp_hot, n_q // 4)
    eng_tp_un = GeoSearchEngine.build(
        tp_docs, tp_rects, tp_amps, tp_nt, grid=32,
        budgets=_replace(budgets, max_candidates=len(tp_docs)),
    )
    eng_tp_pr = GeoSearchEngine(
        index=eng_tp_un.index,
        budgets=_replace(budgets, prune=True),
        weights=eng_tp_un.weights,
    )
    rep_tp_un = GeoServer(
        SingleDeviceExecutor(eng_tp_un, "text_first"),
        cache=None, batcher=batcher("fixed"),
    ).run_trace(tp_trace, collect_results=True)
    rep_tp_pr = GeoServer(
        SingleDeviceExecutor(eng_tp_pr, "text_first", fused=True),
        cache=None, batcher=batcher("fixed"),
    ).run_trace(tp_trace, collect_results=True)
    rec_tp = topk_recall_np(
        np.stack([r.ids for r in rep_tp_un.results]),
        np.stack([r.ids for r in rep_tp_pr.results]),
    )
    un_probes = rep_tp_un.stats.get("n_probes", 0.0)
    pr_probes = rep_tp_pr.stats.get("n_probes", 0.0)
    un_bytes = rep_tp_un.stats.get("bytes_postings", 0.0)
    pr_bytes = rep_tp_pr.stats.get("bytes_postings", 0.0)
    report_row("serve_algo_textprune", rep_tp_pr)
    _row(
        "serve_text_prune_io", 0.0,
        f"n_probes_unpruned={un_probes:.0f};n_probes_pruned={pr_probes:.0f};"
        f"probes_x={un_probes / max(pr_probes, 1e-9):.2f};"
        f"bytes_unpruned={un_bytes:.0f};bytes_pruned={pr_bytes:.0f};"
        f"bytes_x={un_bytes / max(pr_bytes, 1e-9):.2f};"
        f"recall_vs_unpruned={rec_tp:.3f};"
        f"blocks_skipped={rep_tp_pr.stats.get('text_blocks_skipped', 0.0):.0f};"
        f"blocks_total={rep_tp_pr.stats.get('text_blocks_total', 0.0):.0f}",
    )

    # natural-trace layout row (ISSUE 10): a *plain* zipf trace — no
    # planted impact bimodality — over the impact-ordered posting layout
    # vs the docID-ordered one, both pruned+fused.  Pruned selection is
    # the global top-max_candidates by optimistic score, so the two
    # layouts return bit-identical ids/scores; the win is purely I/O
    # (monotone blk_max_impact → one failed θ bound cuts a term's whole
    # tail).  Sizes are pinned (not smoke-scaled): the gate margin in
    # compare_baseline was calibrated at this operating point and the
    # whole block runs in a few seconds on CPU.
    nat_corpus = make_corpus(4096, 400, seed=0)
    nat_trace = make_zipf_trace(
        nat_corpus, n_queries=96, pool_size=48, seed=1, d_terms=2
    )
    nat_budgets = _replace(budgets, max_candidates=512, sweep_budget=512)

    def nat_engine(layout, prune):
        eng_full = GeoSearchEngine.build(
            nat_corpus.doc_terms, nat_corpus.doc_rects, nat_corpus.doc_amps,
            nat_corpus.n_terms, pagerank=nat_corpus.pagerank, grid=32,
            budgets=_replace(nat_budgets, max_candidates=4096),
            layout=layout,
        )
        if not prune:
            return eng_full  # covering budget: the recall anchor
        return GeoSearchEngine(
            index=eng_full.index,
            budgets=_replace(nat_budgets, prune=True),
            weights=eng_full.weights,
        )

    def nat_run(eng):
        return GeoServer(
            SingleDeviceExecutor(eng, "text_first", fused=eng.budgets.prune),
            cache=None, batcher=batcher("fixed"),
        ).run_trace(nat_trace, collect_results=True)

    rep_nat_cov = nat_run(nat_engine("docid", prune=False))
    rep_nat_d = nat_run(nat_engine("docid", prune=True))
    rep_nat_i = nat_run(nat_engine("impact", prune=True))
    ids_d = np.stack([r.ids for r in rep_nat_d.results])
    ids_i = np.stack([r.ids for r in rep_nat_i.results])
    sc_d = np.stack([r.scores for r in rep_nat_d.results])
    sc_i = np.stack([r.scores for r in rep_nat_i.results])
    nat_identical = bool((ids_d == ids_i).all() and (sc_d == sc_i).all())
    rec_nat_docid = topk_recall_np(ids_d, ids_i)
    rec_nat_cov = topk_recall_np(
        np.stack([r.ids for r in rep_nat_cov.results]), ids_i
    )
    cov_probes = rep_nat_cov.stats.get("n_probes", 0.0)
    i_probes = rep_nat_i.stats.get("n_probes", 0.0)
    cov_bytes = rep_nat_cov.stats.get("bytes_postings", 0.0)
    d_bytes = rep_nat_d.stats.get("bytes_postings", 0.0)
    i_bytes = rep_nat_i.stats.get("bytes_postings", 0.0)
    _row(
        "serve_text_prune_natural", 0.0,
        f"probes_x={cov_probes / max(i_probes, 1e-9):.2f};"
        f"bytes_x={cov_bytes / max(i_bytes, 1e-9):.2f};"
        f"layout_bytes_x={d_bytes / max(i_bytes, 1e-9):.2f};"
        f"recall_vs_docid={rec_nat_docid:.3f};"
        f"recall_vs_unpruned={rec_nat_cov:.3f};"
        f"identical_to_docid={int(nat_identical)};"
        f"blocks_skipped={rep_nat_i.stats.get('text_blocks_skipped', 0.0):.0f};"
        f"blocks_total={rep_nat_i.stats.get('text_blocks_total', 0.0):.0f}",
    )

    # open-loop arrival sweep: deadline (max_wait_ms) trades padding +
    # throughput against tail latency; no cache so every query batches.
    # smoke keeps the offered load well under capacity: near saturation,
    # queue-wait amplifies machine noise nonlinearly and the CI baseline
    # comparison would flap
    rate = 120.0 if smoke else 800.0
    arr_trace = stamp_arrivals(zipf, "poisson", rate_qps=rate, seed=2)
    for wait_ms in [0.0, 2.0, 8.0, float("inf")]:
        tag = "inf" if wait_ms == float("inf") else f"{wait_ms:g}"
        server = GeoServer(
            single, cache=None, batcher=batcher(max_wait_s=wait_ms * 1e-3)
        )
        rep = server.run_trace(arr_trace, arrival="poisson", slo_ms=50.0)
        report_row(f"serving_arrival_poisson_w{tag}", rep)
    burst_trace = stamp_arrivals(zipf, "bursty", rate_qps=rate, seed=3)
    server = GeoServer(single, cache=None, batcher=batcher(max_wait_s=8e-3))
    rep = server.run_trace(burst_trace, arrival="bursty", slo_ms=50.0)
    report_row("serving_arrival_bursty_w8", rep)

    # multi-worker dispatch × in-flight coalescing on the Zipf trace (the
    # duplicate-heavy workload): workers drain the dispatch queue in
    # parallel, coalescing subscribes in-flight duplicates to their twin
    # batch.  No cache, so every repeat either re-executes or coalesces —
    # the `coalesced` column measures the path directly (a cache would
    # absorb the repeats and leave nothing to gate).
    worker_sweep = (
        [(1, False), (2, True)]
        if smoke
        else [(w, c) for w in (1, 2, 4) for c in (False, True)]
    )
    workers_trace = stamp_arrivals(zipf, "poisson", rate_qps=rate, seed=4)
    for n_workers, coal in worker_sweep:
        server = GeoServer(
            single, cache=None,
            batcher=batcher(max_wait_s=2e-3),
            n_workers=n_workers, coalesce=coal,
        )
        rep = server.run_trace(workers_trace, arrival="poisson", slo_ms=50.0)
        tag = "on" if coal else "off"
        report_row(f"serving_workers_{n_workers}_coalesce_{tag}", rep)

    # telemetry overhead: the identical closed-loop zipf run with the obs
    # stack off vs fully on (metrics + spans + events).  No cache, so every
    # query pays the per-query recording path.  Best-of-3 per side so one
    # background hiccup cannot fake an overhead regression; the qps_ratio
    # row is gated in compare_baseline (must stay >= its floor).
    from repro.obs import Telemetry

    def _best_run(make_telemetry):
        best = None
        for _ in range(3):
            server = GeoServer(
                single, cache=None, batcher=batcher(),
                telemetry=make_telemetry() if make_telemetry else None,
            )
            rep = server.run_trace(zipf)
            if best is None or rep.qps > best.qps:
                best = rep
        return best

    rep_off = _best_run(None)
    rep_on = _best_run(Telemetry)
    single.engine.metrics = None  # detach from the shared engine
    report_row("serve_telemetry_off", rep_off)
    report_row("serve_telemetry_on", rep_on)
    ratio = rep_on.qps / rep_off.qps if rep_off.qps else 0.0
    _row("serve_telemetry_overhead", 0.0, f"qps_ratio={ratio:.3f}")

    sharded = ShardedExecutor.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, n_shards=2 if smoke else 4,
        partitioner=MortonPartitioner(), grid=32, budgets=budgets,
    )
    # fixed shape for the sharded row: per-shard engines each compile fresh,
    # so keep the smoke-mode compile count at one shape per shard
    server = GeoServer(sharded, cache=None, batcher=batcher("fixed"))
    report_row(f"serve_shards_{sharded.n_shards}", server.run_trace(zipf))

    # footprint routing vs broadcast at S=8 over the SAME region-partitioned
    # engines (the broadcast twin shares them, so per-shard compiles happen
    # once) on a city-scale zipf trace — the fan-out the tentpole claims:
    # shards_touched_mean ≪ S with bit-identical results.  The routing rows
    # use a single-place corpus (max_rects=1): multi-place docs smear every
    # shard's coverage across the map, which is broadcast's regime, not
    # routing's — single-toe-print pages are where partitioned serving pays.
    # Seed 17 gives a geographically spread city-size draw (top city ~16%
    # of population, 8 cities above 5%); seeds where one mega-city wins the
    # zipf draw put every shard inside that city, the degenerate anti-case
    # for any spatial partitioner.
    S_route = 8
    route_corpus = make_corpus(
        n_docs, 400 if smoke else 2000, max_rects=1, seed=17
    )
    routed = ShardedExecutor.build(
        route_corpus.doc_terms, route_corpus.doc_rects,
        route_corpus.doc_amps, route_corpus.n_terms,
        pagerank=route_corpus.pagerank, n_shards=S_route,
        partitioner=RegionRangePartitioner(), grid=32, budgets=budgets,
        routing="footprint",
    )
    twin = ShardedExecutor(
        routed.engines, routed.global_ids, routed.algorithm,
        routing="broadcast",
    )
    city = make_zipf_trace(
        route_corpus, n_queries=n_q // 4, pool_size=max(n_q // 16, 16),
        seed=6, scales=(1.0,),
    )
    rep_bc = GeoServer(twin, cache=None, batcher=batcher("fixed")).run_trace(
        city, collect_results=True
    )
    rep_fp = GeoServer(routed, cache=None, batcher=batcher("fixed")).run_trace(
        city, collect_results=True
    )
    identical = all(
        np.array_equal(a.ids, b.ids)
        and a.scores.tobytes() == b.scores.tobytes()
        for a, b in zip(rep_bc.results, rep_fp.results)
    )
    label = routed.algorithm
    report_row("serve_routing_broadcast", rep_bc)
    report_row("serve_routing_footprint", rep_fp)
    _row(
        "serve_routing_footprint_fanout", 0.0,
        f"shards_touched_mean={rep_fp.routing_mean(label):.3f};"
        f"shards_total={S_route};identical={int(identical)}",
    )

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"smoke": smoke, "rows": ROWS}, f, indent=2, sort_keys=True)
        print(f"wrote {len(ROWS)} rows to {args.json}")


if __name__ == "__main__":
    main()

"""Serving-stack benchmark: cache policy × batcher × sharding sweeps.

Prints the same ``name,us_per_call,derived`` CSV rows as ``benchmarks.run``
but for the serving layer (``repro.serving``):

* ``serve_cache_*``   — zipf trace through none / lru / landlord caches:
                        QPS, p50/p99 latency, hit rate.
* ``serve_batcher_*`` — bucketed vs fixed-shape batching: padding overhead
                        and number of compiled shapes.
* ``serve_shards_*``  — doc-sharded scatter-gather execution.

All single-device rows share one engine so jit compiles amortize across
configurations (the engine's compiled-function cache is keyed per shape,
exactly as a long-running server would hold it).

``--smoke`` shrinks corpus/trace/bucket-lattice so the whole file finishes
in well under a minute on CPU — it is part of ``scripts/check.sh``'s
pre-merge gate.

Usage: ``PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]``
"""
from __future__ import annotations

import argparse

from repro.core import GeoSearchEngine, QueryBudgets
from repro.corpus import make_corpus, make_uniform_trace, make_zipf_trace
from repro.serving import (
    GeoServer,
    ShapeBucketedBatcher,
    ShardedExecutor,
    SingleDeviceExecutor,
    make_cache,
)


def _row(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)


def report_row(name: str, rep) -> None:
    """Shared derived-column format for serving rows (also used by run.py)."""
    _row(
        name,
        1e6 / rep.qps if rep.qps else 0.0,
        f"qps={rep.qps:.0f};p50_ms={rep.percentile_ms(50):.3f};"
        f"p99_ms={rep.percentile_ms(99):.3f};hit_rate={rep.hit_rate:.3f};"
        f"padding={rep.padding_overhead:.3f};shapes={rep.n_compiled_shapes}",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes; finishes < 60 s on CPU (pre-merge gate)")
    args = ap.parse_args()
    smoke = args.smoke
    n_docs = 1200 if smoke else 20000
    n_q = 384 if smoke else 4096
    max_batch = 16 if smoke else 32
    # smoke: a coarse bucket lattice → few compiles; full: the real lattice
    buckets = dict(
        term_buckets=[4, 8] if smoke else [],
        rect_buckets=[2, 4] if smoke else [],
    )

    def batcher(kind="bucketed"):
        if kind == "fixed":
            return ShapeBucketedBatcher(
                max_batch=max_batch, max_terms=8, max_rects=4,
                term_buckets=[8], rect_buckets=[4], batch_sizes=[max_batch],
            )
        return ShapeBucketedBatcher(
            max_batch=max_batch, max_terms=8, max_rects=4,
            term_buckets=list(buckets["term_buckets"]),
            rect_buckets=list(buckets["rect_buckets"]),
        )

    print("name,us_per_call,derived")
    corpus = make_corpus(n_docs, 400 if smoke else 2000, seed=0)
    budgets = QueryBudgets(
        max_candidates=1024, max_tiles=256, k_sweeps=8,
        sweep_budget=max(n_docs // 8, 256), top_k=10,
    )
    eng = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=32, budgets=budgets,
    )
    single = SingleDeviceExecutor(eng)
    zipf = make_zipf_trace(corpus, n_queries=n_q, pool_size=max(n_q // 8, 32), seed=1)
    uni = make_uniform_trace(corpus, n_queries=n_q // 2, seed=1)

    for cache in ["none", "lru", "landlord"]:
        server = GeoServer(single, cache=make_cache(cache, 512), batcher=batcher())
        report_row(f"serve_cache_{cache}_zipf", server.run_trace(zipf))
    server = GeoServer(single, cache=make_cache("landlord", 512), batcher=batcher())
    report_row("serve_cache_landlord_uniform", server.run_trace(uni))

    for kind in ["bucketed", "fixed"]:
        server = GeoServer(single, cache=None, batcher=batcher(kind))
        report_row(f"serve_batcher_{kind}", server.run_trace(zipf))

    sharded = ShardedExecutor.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, n_shards=2 if smoke else 4, partition="geo",
        grid=32, budgets=budgets,
    )
    # fixed shape for the sharded row: per-shard engines each compile fresh,
    # so keep the smoke-mode compile count at one shape per shard
    server = GeoServer(sharded, cache=None, batcher=batcher("fixed"))
    report_row(f"serve_shards_{sharded.n_shards}", server.run_trace(zipf))


if __name__ == "__main__":
    main()

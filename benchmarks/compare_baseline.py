"""Benchmark-regression gate: compare a serve_bench JSON dump to a baseline.

Reads two files produced by ``benchmarks.serve_bench --json`` (the checked-in
``benchmarks/baseline_smoke.json`` and a fresh run) and fails when serving
performance regressed beyond noise:

* **p99 latency** — fail when ``current > p99_factor × baseline + slack_ms``
  *and* ``current > min_fail_ms``.  The additive slack absorbs proportional
  CPU-runner jitter; the absolute floor absorbs one-off scheduler hiccups
  (a single 150 ms stall inside a 3 s open-loop trace cascades through
  queue-wait and can 8× a 20 ms p99 without any code regression — while a
  genuine "batcher stopped batching" regression lands in the hundreds of
  ms to seconds and clears the floor easily).
* **QPS** — fail when ``current < qps_factor × baseline``.
* **Routing fan-out** — the ``serve_routing_footprint_fanout`` row carries
  ``shards_touched_mean``, ``shards_total`` and ``identical`` (1 iff the
  footprint-routed results were bitwise equal to the broadcast twin's);
  fail when the current run's mean fan-out exceeds ``fanout_factor`` ×
  shards (default 0.5 — footprint routing must reach ≤ S/2 shards per
  query on the city trace) or when ``identical`` is 0.  Like the
  telemetry gate this is absolute on the fresh run, not relative to the
  baseline: the routing contract does not drift with machine noise.
* **Compression ratio** — the ``serve_compress_int8_io`` row carries
  ``bytes_compressed`` / ``bytes_uncompressed`` (postings + spatial
  streamed on the identical zipf trace); fail when the compressed run
  streams more than ``bytes_factor`` × the uncompressed bytes (default
  0.5 — the compressed store must halve streamed bytes).  Absolute on the
  fresh run: the storage layout does not drift with machine noise.
* **Text-prune I/O** — the ``serve_text_prune_io`` row carries
  ``probes_x`` / ``bytes_x`` (unpruned ÷ pruned probes and streamed
  postings bytes on the planted hot-pair trace) and
  ``recall_vs_unpruned``; fail when either ratio drops below
  ``textprune_factor`` (default 2.0) or recall@10 drops below 0.99.
  Absolute on the fresh run: the skip construction is deterministic and
  does not drift with machine noise.
* **Natural-trace layout** — the ``serve_text_prune_natural`` row carries
  ``probes_x`` / ``bytes_x`` (unpruned-covering ÷ impact-pruned) and
  ``layout_bytes_x`` (docID-pruned ÷ impact-pruned streamed posting
  bytes) on a *plain* zipf trace with no planted bimodality, plus
  ``recall_vs_docid`` and ``blocks_skipped``; fail when any ratio drops
  below ``natural_factor`` (default 1.5), when ``recall_vs_docid`` drops
  below 0.99 (pruned selection is order-invariant, so the layouts must
  agree bit-for-bit), or when no blocks were skipped.  Absolute on the
  fresh run, like the other layout gates.
* **Telemetry overhead** — the ``serve_telemetry_overhead`` row carries
  ``qps_ratio`` (telemetry-on QPS / telemetry-off QPS, best-of-3 each);
  fail when the *current* run's ratio drops below ``overhead_floor``
  (default 0.95 — i.e. the full obs stack must cost <5% QPS).  This is an
  absolute gate on the fresh run, not a baseline comparison: the ratio is
  already self-normalised.

Rows present in the baseline but missing from the current run fail too (a
silently dropped benchmark is how gates rot).  Rows present in the new run
but absent from the old baseline only *warn* — never fail — so adding
benchmark rows and regenerating the baseline are not order-sensitive:
a fresh run with extra rows passes against the old baseline, and the
warning tells you to regenerate to start gating them::

    PYTHONPATH=src python -m benchmarks.serve_bench --smoke --json benchmarks/baseline_smoke.json

Thresholds follow the CI gate spec (2× p99, 0.5× QPS) and are deliberately
tolerant: this catches "the batcher stopped batching", not 10% drift.

Usage::

    PYTHONPATH=src python -m benchmarks.compare_baseline \\
        benchmarks/baseline_smoke.json /tmp/serve_smoke.json
"""
from __future__ import annotations

import argparse
import json
import sys


def load_rows(path: str) -> dict[str, dict]:
    with open(path) as f:
        payload = json.load(f)
    return payload["rows"] if "rows" in payload else payload


def compare(
    baseline: dict[str, dict],
    current: dict[str, dict],
    p99_factor: float = 2.0,
    qps_factor: float = 0.5,
    slack_ms: float = 25.0,
    min_fail_ms: float = 250.0,
    overhead_floor: float = 0.95,
    fanout_factor: float = 0.5,
    bytes_factor: float = 0.5,
    textprune_factor: float = 2.0,
    natural_factor: float = 1.5,
) -> tuple[list[str], list[str]]:
    """Return ``(failures, warnings)`` — the gate passes iff no failures.

    Warnings cover rows present in ``current`` but absent from
    ``baseline`` (new benchmarks are ungated until the baseline is
    regenerated); they never fail the gate.
    """
    failures: list[str] = []
    warnings: list[str] = [
        f"{name}: new row not in baseline (ungated; regenerate the baseline "
        f"to start gating it)"
        for name in sorted(set(current) - set(baseline))
    ]
    for name, base in sorted(baseline.items()):
        cur = current.get(name)
        if cur is None:
            failures.append(f"{name}: present in baseline but missing from current run")
            continue
        b_p99, c_p99 = base.get("p99_ms"), cur.get("p99_ms")
        if b_p99 is not None and c_p99 is not None:
            limit = max(p99_factor * b_p99 + slack_ms, min_fail_ms)
            if c_p99 > limit:
                failures.append(
                    f"{name}: p99_ms {c_p99:.3f} > limit {limit:.3f} "
                    f"(max of {p99_factor}x baseline {b_p99:.3f} + {slack_ms}ms "
                    f"slack, {min_fail_ms}ms floor)"
                )
        b_qps, c_qps = base.get("qps"), cur.get("qps")
        if b_qps is not None and c_qps is not None:
            floor = qps_factor * b_qps
            if c_qps < floor:
                failures.append(
                    f"{name}: qps {c_qps:.0f} < floor {floor:.0f} "
                    f"({qps_factor}x baseline {b_qps:.0f})"
                )
    fanout = current.get("serve_routing_footprint_fanout")
    if fanout is not None:
        mean = fanout.get("shards_touched_mean")
        total = fanout.get("shards_total")
        if mean is not None and total:
            limit = fanout_factor * total
            if mean > limit:
                failures.append(
                    f"serve_routing_footprint_fanout: shards_touched_mean "
                    f"{mean:.3f} > {limit:.1f} ({fanout_factor}x "
                    f"{total:.0f} shards — footprint routing stopped pruning)"
                )
        if fanout.get("identical") == 0:
            failures.append(
                "serve_routing_footprint_fanout: footprint-routed results "
                "diverged bitwise from the broadcast twin"
            )
    comp = current.get("serve_compress_int8_io")
    if comp is not None:
        b_c = comp.get("bytes_compressed")
        b_u = comp.get("bytes_uncompressed")
        if b_c is not None and b_u:
            if b_c > bytes_factor * b_u:
                failures.append(
                    f"serve_compress_int8_io: bytes_compressed {b_c:.0f} > "
                    f"{bytes_factor}x uncompressed {b_u:.0f} (the compressed "
                    f"store stopped halving streamed bytes)"
                )
    tp = current.get("serve_text_prune_io")
    if tp is not None:
        for key in ("probes_x", "bytes_x"):
            val = tp.get(key)
            if val is not None and val < textprune_factor:
                failures.append(
                    f"serve_text_prune_io: {key} {val:.2f} < "
                    f"{textprune_factor} (block-max pruning stopped cutting "
                    f"text traversal I/O)"
                )
        rec = tp.get("recall_vs_unpruned")
        if rec is not None and rec < 0.99:
            failures.append(
                f"serve_text_prune_io: recall_vs_unpruned {rec:.3f} < 0.99 "
                f"(pruned text_first diverged from the unpruned top-k)"
            )
    nat = current.get("serve_text_prune_natural")
    if nat is not None:
        for key in ("probes_x", "bytes_x", "layout_bytes_x"):
            val = nat.get(key)
            if val is not None and val < natural_factor:
                failures.append(
                    f"serve_text_prune_natural: {key} {val:.2f} < "
                    f"{natural_factor} (the impact-ordered layout stopped "
                    f"cutting I/O on the natural trace)"
                )
        rec = nat.get("recall_vs_docid")
        if rec is not None and rec < 0.99:
            failures.append(
                f"serve_text_prune_natural: recall_vs_docid {rec:.3f} < 0.99 "
                f"(impact-pruned text_first diverged from the docID-pruned "
                f"twin — pruned selection must be order-invariant)"
            )
        if not nat.get("blocks_skipped"):
            failures.append(
                "serve_text_prune_natural: blocks_skipped = 0 (the monotone "
                "blk_max_impact tail cut never fired on the natural trace)"
            )
    ratio = current.get("serve_telemetry_overhead", {}).get("qps_ratio")
    if ratio is not None and ratio < overhead_floor:
        failures.append(
            f"serve_telemetry_overhead: qps_ratio {ratio:.3f} < floor "
            f"{overhead_floor} (telemetry-on must keep >= "
            f"{overhead_floor:.0%} of telemetry-off QPS)"
        )
    return failures, warnings


def main() -> None:
    ap = argparse.ArgumentParser(description="serve_bench baseline-regression gate")
    ap.add_argument("baseline", help="checked-in baseline JSON")
    ap.add_argument("current", help="fresh serve_bench --json output")
    ap.add_argument("--p99-factor", type=float, default=2.0)
    ap.add_argument("--qps-factor", type=float, default=0.5)
    ap.add_argument("--slack-ms", type=float, default=25.0)
    ap.add_argument("--min-fail-ms", type=float, default=250.0,
                    help="p99 below this never fails (one-off stall immunity)")
    ap.add_argument("--overhead-floor", type=float, default=0.95,
                    help="min telemetry-on/off QPS ratio (obs overhead gate)")
    ap.add_argument("--fanout-factor", type=float, default=0.5,
                    help="max mean shards-touched as a fraction of shards "
                         "(footprint-routing prune gate)")
    ap.add_argument("--bytes-factor", type=float, default=0.5,
                    help="max compressed/uncompressed streamed-bytes ratio "
                         "(compressed-store gate)")
    ap.add_argument("--textprune-factor", type=float, default=2.0,
                    help="min unpruned/pruned probes and postings-bytes "
                         "ratios (block-max text-pruning gate)")
    ap.add_argument("--natural-factor", type=float, default=1.5,
                    help="min probes/bytes/layout-bytes ratios on the "
                         "natural (unplanted) zipf trace (impact-ordered "
                         "posting-layout gate)")
    args = ap.parse_args()

    baseline = load_rows(args.baseline)
    current = load_rows(args.current)
    failures, warnings = compare(
        baseline, current,
        p99_factor=args.p99_factor, qps_factor=args.qps_factor,
        slack_ms=args.slack_ms, min_fail_ms=args.min_fail_ms,
        overhead_floor=args.overhead_floor, fanout_factor=args.fanout_factor,
        bytes_factor=args.bytes_factor, textprune_factor=args.textprune_factor,
        natural_factor=args.natural_factor,
    )
    for name in sorted(set(baseline) & set(current)):
        b, c = baseline[name], current[name]
        print(
            f"{name}: p99_ms {b.get('p99_ms', float('nan')):.3f} -> "
            f"{c.get('p99_ms', float('nan')):.3f}  "
            f"qps {b.get('qps', float('nan')):.0f} -> {c.get('qps', float('nan')):.0f}"
        )
    for w in warnings:
        print(f"WARNING: {w}")
    if failures:
        print("\nREGRESSIONS:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        sys.exit(1)
    print(f"\nbaseline gate OK ({len(baseline)} rows, no regressions)")


if __name__ == "__main__":
    main()

"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

* ``table1_*``     — the paper's Table 1 (processing-time comparison of the
                     proposed K-SWEEP pipeline vs the old/baseline pipeline)
                     measured as wall-clock per query on the CPU-hosted
                     engine, plus recall and modeled I/O bytes.
* ``core_ksweep_{unpruned,pruned,pruned_fused}`` — block-max pruned
                     K-SWEEP (sweep→score→select with adaptive threshold
                     feedback; the ``pruned`` row runs the jnp oracle, the
                     ``pruned_fused`` row the Pallas kernel — interpret
                     mode on CPU, so its wall clock is a correctness
                     smoke, not kernel speed) vs the unpruned reference
                     on a padded zipf trace: recall, n_probes,
                     postings/spatial bytes, blocks skipped; the
                     ``_gain`` row prints the ratios.
* ``core_textprune_{unpruned,pruned,gain}`` — block-max pruned TEXT-FIRST
                     (impact-ordered posting skipping with in-kernel DMA
                     elision) vs the unpruned traversal that needs
                     ``max_candidates ≥ df`` for the same answers, on the
                     planted impact-bimodal hot-pair corpus: recall vs the
                     unpruned top-k, probes, streamed postings bytes,
                     text blocks skipped (acceptance: ≥ 2× drop in both
                     ``n_probes`` and ``bytes_postings`` at recall@10
                     ≥ 0.99, the ``meets_2x`` column).
* ``core_layout_{docid,impact,gain}`` — impact-ordered posting layout
                     (``layout="impact"``: descending quantized-impact
                     segments, so ``blk_max_impact`` is monotone per term
                     and one failed θ bound cuts the whole tail) vs the
                     docID-ordered layout, both under the pruned fused
                     TEXT-FIRST walk on a *natural* zipf trace with no
                     planted bimodality; the ``_gain`` row reports
                     ``layout_bytes_x`` (docID-pruned ÷ impact-pruned
                     streamed posting bytes), the probes/bytes ratios vs
                     the unpruned covering run, and the bit-identity flag
                     (pruned selection is order-invariant).
* ``core_compress_{f16,int8,gain}`` — compressed posting (delta +
                     bit-packed) and toe-print (f16 / int8 + per-block
                     scale) stores vs the uncompressed layout on the same
                     zipf trace: recall vs the uncompressed engine and the
                     streamed postings+spatial byte ratio (acceptance:
                     ≥ 2× drop at recall@10 ≥ 0.99, the ``meets_2x``
                     column).
* ``planner_mixture_{auto,text_first,geo_first,ksweep}`` — the cost-based
                     per-query planner (``core/planner.py``) against every
                     fixed algorithm on the bimodal term-selectivity ×
                     footprint-area mixture trace; the ``_gain`` row prints
                     the probes+posting-bytes ratio vs the best fixed
                     algorithm and the per-plan mix (acceptance: ≥ 1.3× at
                     recall@10 ≥ 0.95).
* ``fig_k_sweep``  — sensitivity of fetched volume to k (paper §IV.C).
* ``fig_scale``    — throughput vs corpus size (the scalability axis the
                     paper's abstract claims).
* ``geo_partition``— hash vs Morton vs region-range document partitioning
                     (paper §Conclusions future work).
* ``kernel_*``     — Pallas kernels vs jnp oracles (CPU interpret: check
                     only; derived column reports modeled VMEM bytes/call).
* ``serving_*``    — the production serving stack (repro.serving): zipf
                     trace through cache + shape-bucketed batcher, QPS,
                     p50/p99 latency, hit rate, padding overhead; the
                     ``serving_arrival_*`` rows replay the same trace
                     open-loop (Poisson arrivals) across deadline settings
                     and the ``serving_workers_*`` rows sweep the worker
                     pool × in-flight coalescing; the
                     ``serving_routing_*`` rows compare footprint routing
                     against broadcast at S=8 on city-sized footprints
                     (mean shards-touched, recall@10, bit-identity).  The
                     full sweep lives in ``benchmarks.serve_bench``.

Usage: ``PYTHONPATH=src python -m benchmarks.run [--quick]``
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps, out


def _row(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)


# --- access-cost models --------------------------------------------------
# 2010 disk (the paper's own regime): one seek 8 ms, 100 MB/s sequential.
SEEK_S, DISK_BW = 8e-3, 100e6
# TPU v5e HBM (this system's regime): streams at ~90% of 819 GB/s; random
# small gathers at ~15% effective (transaction granularity waste).
HBM_BW, EFF_SEQ, EFF_RAND = 819e9, 0.9, 0.15


def _cost_models(stats: dict) -> tuple[float, float]:
    seeks = float(np.asarray(stats["seeks"]).mean())
    b_seq = float(np.asarray(stats["bytes_seq"]).mean())
    b_rand = float(np.asarray(stats["bytes_random"]).mean())
    t_disk = seeks * SEEK_S + (b_seq + b_rand) / DISK_BW
    t_hbm = b_seq / (HBM_BW * EFF_SEQ) + b_rand / (HBM_BW * EFF_RAND)
    return t_disk, t_hbm


def bench_table1(quick: bool) -> None:
    """Paper Table 1: old (text-first) vs proposed (k-sweep) processing.

    Three time columns per algorithm:
      us_per_call        — measured wall clock on the CPU-hosted engine
      t_disk2010_ms      — the paper's own cost regime (seek + 100MB/s),
                           applied to the MEASURED per-query operation counts
      t_hbm_v5e_us       — TPU-HBM regime (stream vs gather efficiency)
    The paper's 1.91× (0.65 s → 0.34 s) claim is checked in the disk model.
    """
    from repro.core import GeoSearchEngine, QueryBudgets
    from repro.corpus import make_corpus, make_query_trace

    n_docs = 4000 if quick else 20000
    corpus = make_corpus(n_docs, 1500, seed=0)
    # full-recall budgets: Table 1 compares I/O models at equal quality;
    # k_sweeps×sweep_budget covers the store, max_candidates the longest list
    budgets = QueryBudgets(
        max_candidates=n_docs, max_tiles=2048, k_sweeps=16,
        sweep_budget=max(n_docs // 4, 512), top_k=10,
    )
    eng = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=64, budgets=budgets,
    )
    B = 64
    trace = make_query_trace(corpus, n_queries=B, seed=1)
    disk, hbm, wall = {}, {}, {}
    for algo in ["text_first", "geo_first", "k_sweep"]:
        dt, res = _time(lambda a=algo: eng.query(trace, a))
        rec = eng.recall_at_k(trace, algo)
        t_disk, t_hbm = _cost_models(res.stats)
        disk[algo], hbm[algo], wall[algo] = t_disk, t_hbm, dt / B
        _row(
            f"table1_{algo}", dt / B * 1e6,
            f"recall@10={rec:.3f};t_disk2010_ms={t_disk*1e3:.1f};"
            f"t_hbm_v5e_us={t_hbm*1e6:.2f};n_docs={n_docs}",
        )
    _row(
        "table1_speedup_ksweep_vs_textfirst", 0.0,
        f"disk2010={disk['text_first']/disk['k_sweep']:.2f}x;"
        f"hbm_v5e={hbm['text_first']/hbm['k_sweep']:.2f}x;"
        f"wall_cpu={wall['text_first']/wall['k_sweep']:.2f}x;"
        f"paper=1.91x (0.65s->0.34s)",
    )


def bench_block_prune(quick: bool) -> None:
    """Block-max pruned K-SWEEP vs the unpruned reference (zipf trace).

    The PR 4 acceptance row: pruning must cut ``n_probes`` and
    ``bytes_postings`` ≥ 2× at recall@10 ≥ 0.95 vs the unpruned path,
    with ``blocks_skipped > 0``.
    """
    from dataclasses import replace

    from repro.core import GeoSearchEngine, QueryBudgets
    from repro.corpus import make_corpus, make_zipf_trace, pad_trace_batch

    n_docs = 1200 if quick else 12000
    corpus = make_corpus(n_docs, 400 if quick else 1500, seed=9)
    budgets = QueryBudgets(
        max_candidates=1024 if quick else 4096, max_tiles=256, k_sweeps=8,
        sweep_budget=max(n_docs // 8, 256), top_k=10,
    )
    eng = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=32 if quick else 64, budgets=budgets,
    )
    B = 64
    trace = pad_trace_batch(
        make_zipf_trace(corpus, n_queries=B, pool_size=48, seed=10)
    )
    dt_u, un = _time(lambda: eng.query(trace, "k_sweep"))
    rec_u = eng.recall_at_k(trace, "k_sweep")
    # fresh engine sharing the built index: `prune` is a static budget, and
    # a new instance gets its own compiled-fn cache (eng.budgets keeps the
    # sweep-budget clamp GeoSearchEngine.build applied)
    eng_p = GeoSearchEngine(
        index=eng.index, budgets=replace(eng.budgets, prune=True),
        weights=eng.weights,
    )
    dt_p, pr = _time(lambda: eng_p.query(trace, "k_sweep"))
    rec_p = eng_p.recall_at_k(trace, "k_sweep")
    dt_f, prf = _time(lambda: eng_p.query(trace, "k_sweep", fused=True))
    fused_same = bool((np.asarray(prf.ids) == np.asarray(pr.ids)).all())

    def mean(r, key):
        return float(np.asarray(r.stats[key], np.float64).mean())

    # recall of the pruned top-k against the unpruned top-k
    from repro.core.ranking import topk_recall_np

    rec_vs_un = topk_recall_np(un.ids, pr.ids)
    _row(
        "core_ksweep_unpruned", dt_u / B * 1e6,
        f"recall@10={rec_u:.3f};n_probes={mean(un, 'n_probes'):.0f};"
        f"bytes_postings={mean(un, 'bytes_postings'):.0f};"
        f"bytes_spatial={mean(un, 'bytes_spatial'):.0f};n_docs={n_docs}",
    )
    _row(
        "core_ksweep_pruned", dt_p / B * 1e6,
        f"recall@10={rec_p:.3f};n_probes={mean(pr, 'n_probes'):.0f};"
        f"bytes_postings={mean(pr, 'bytes_postings'):.0f};"
        f"bytes_spatial={mean(pr, 'bytes_spatial'):.0f};"
        f"blocks_skipped={mean(pr, 'blocks_skipped'):.1f};"
        f"blocks_total={mean(pr, 'blocks_total'):.1f};"
        f"probes_saved={mean(pr, 'probes_saved'):.0f}",
    )
    _row(
        "core_ksweep_pruned_fused", dt_f / B * 1e6,
        f"ids_match_ref_path={int(fused_same)};"
        f"blocks_skipped={mean(prf, 'blocks_skipped'):.1f};"
        f"interpret_mode={int(jax.default_backend() != 'tpu')}",
    )
    _row(
        "core_ksweep_prune_gain", 0.0,
        f"recall_vs_unpruned={rec_vs_un:.3f};"
        f"n_probes_x={mean(un, 'n_probes') / max(mean(pr, 'n_probes'), 1):.2f};"
        f"bytes_postings_x="
        f"{mean(un, 'bytes_postings') / max(mean(pr, 'bytes_postings'), 1):.2f};"
        f"bytes_spatial_x="
        f"{mean(un, 'bytes_spatial') / max(mean(pr, 'bytes_spatial'), 1):.2f}",
    )


def bench_text_prune(quick: bool) -> None:
    """Block-max pruned TEXT-FIRST vs the unpruned traversal (ISSUE 9).

    The acceptance rows: on the planted impact-bimodal hot-pair corpus
    (``benchmarks.serve_bench.make_textprune_corpus``) the pruned
    ``text_first`` path must cut ``n_probes`` and ``bytes_postings`` ≥ 2×
    at recall@10 ≥ 0.99 vs the unpruned path run at a covering candidate
    budget (``max_candidates ≥ df``), with text blocks actually skipped.
    """
    from dataclasses import replace

    from repro.core import GeoSearchEngine, QueryBudgets
    from repro.core.ranking import topk_recall_np
    from repro.corpus import pad_trace_batch

    from benchmarks.serve_bench import make_textprune_corpus, textprune_trace

    n_docs = 3072 if quick else 8192
    docs, rects, amps, n_terms, hot = make_textprune_corpus(n_docs)
    budgets = QueryBudgets(
        max_candidates=n_docs, max_tiles=256, k_sweeps=8,
        sweep_budget=max(n_docs // 8, 256), top_k=10,
    )
    eng_un = GeoSearchEngine.build(
        docs, rects, amps, n_terms, grid=32, budgets=budgets
    )
    # pruned twin shares the built index but walks the driver list with the
    # fused probe→score→select kernel at a small θ-buffer budget; `prune`
    # and `max_candidates` are static budgets, so a fresh engine instance
    # gets its own compiled-fn cache
    eng_pr = GeoSearchEngine(
        index=eng_un.index,
        budgets=replace(eng_un.budgets, max_candidates=1024, prune=True),
        weights=eng_un.weights,
    )
    B = 64
    trace = pad_trace_batch(textprune_trace(hot, B))
    dt_u, un = _time(lambda: eng_un.query(trace, "text_first"))
    dt_p, pr = _time(lambda: eng_pr.query(trace, "text_first"))
    dt_f, prf = _time(lambda: eng_pr.query(trace, "text_first", fused=True))
    fused_same = bool((np.asarray(prf.ids) == np.asarray(pr.ids)).all())
    rec_vs_un = topk_recall_np(un.ids, pr.ids)

    def mean(r, key):
        return float(np.asarray(r.stats[key], np.float64).mean())

    probes_x = mean(un, "n_probes") / max(mean(pr, "n_probes"), 1)
    bytes_x = mean(un, "bytes_postings") / max(mean(pr, "bytes_postings"), 1)
    _row(
        "core_textprune_unpruned", dt_u / B * 1e6,
        f"n_probes={mean(un, 'n_probes'):.0f};"
        f"bytes_postings={mean(un, 'bytes_postings'):.0f};"
        f"blocks_skipped={mean(un, 'text_blocks_skipped'):.1f};"
        f"n_docs={n_docs}",
    )
    _row(
        "core_textprune_pruned", dt_p / B * 1e6,
        f"n_probes={mean(pr, 'n_probes'):.0f};"
        f"bytes_postings={mean(pr, 'bytes_postings'):.0f};"
        f"blocks_skipped={mean(pr, 'text_blocks_skipped'):.1f};"
        f"blocks_total={mean(pr, 'text_blocks_total'):.1f};"
        f"probes_saved={mean(pr, 'probes_saved'):.0f};"
        f"ids_match_ref_path={int(fused_same)};"
        f"interpret_mode={int(jax.default_backend() != 'tpu')}",
    )
    meets = int(probes_x >= 2.0 and bytes_x >= 2.0 and rec_vs_un >= 0.99)
    _row(
        "core_textprune_gain", 0.0,
        f"recall_vs_unpruned={rec_vs_un:.3f};n_probes_x={probes_x:.2f};"
        f"bytes_postings_x={bytes_x:.2f};meets_2x={meets}",
    )


def bench_layout(quick: bool) -> None:
    """Impact-ordered vs docID-ordered posting layout on a natural trace.

    The ISSUE 10 acceptance rows: on a *plain* zipf trace (no planted
    impact bimodality) the pruned TEXT-FIRST walk over the
    ``layout="impact"`` index must stream fewer posting bytes than the
    same pruned walk over the docID-ordered index — the monotone
    ``blk_max_impact`` envelope lets one failed bound cut a term's whole
    tail — while returning **bit-identical** ids and scores (pruned
    selection is the global top-``max_candidates`` by optimistic score,
    which is order-invariant).  The unpruned covering run (docID layout,
    ``max_candidates = n_docs``) anchors the recall and the overall
    probes/bytes ratios.
    """
    from dataclasses import replace

    from repro.core import GeoSearchEngine, QueryBudgets
    from repro.core.ranking import topk_recall_np
    from repro.corpus import make_corpus, make_zipf_trace, pad_trace_batch

    n_docs = 1536 if quick else 4096
    corpus = make_corpus(n_docs, 200 if quick else 400, seed=0)
    budgets = QueryBudgets(
        max_candidates=n_docs, max_tiles=256, k_sweeps=8,
        sweep_budget=max(n_docs // 8, 256), top_k=10,
    )
    B = 48 if quick else 96
    trace = pad_trace_batch(
        make_zipf_trace(corpus, n_queries=B, pool_size=48, seed=1, d_terms=2)
    )
    mc = 512  # pruned θ-buffer budget; the covering twin uses n_docs

    def build(layout):
        return GeoSearchEngine.build(
            corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
            pagerank=corpus.pagerank, grid=32, budgets=budgets, layout=layout,
        )

    def mean(r, key):
        return float(np.asarray(r.stats[key], np.float64).mean())

    eng_cov = build("docid")  # unpruned covering anchor
    eng_d = GeoSearchEngine(
        index=eng_cov.index,
        budgets=replace(budgets, max_candidates=mc, prune=True),
        weights=eng_cov.weights,
    )
    eng_i = build("impact")
    eng_i = GeoSearchEngine(
        index=eng_i.index,
        budgets=replace(budgets, max_candidates=mc, prune=True),
        weights=eng_i.weights,
    )
    dt_c, cov = _time(lambda: eng_cov.query(trace, "text_first"))
    dt_d, prd = _time(lambda: eng_d.query(trace, "text_first", fused=True))
    dt_i, pri = _time(lambda: eng_i.query(trace, "text_first", fused=True))
    identical = bool(
        (np.asarray(prd.ids) == np.asarray(pri.ids)).all()
        and (np.asarray(prd.scores) == np.asarray(pri.scores)).all()
    )
    rec_cov = topk_recall_np(cov.ids, pri.ids)
    probes_x = mean(cov, "n_probes") / max(mean(pri, "n_probes"), 1)
    bytes_x = mean(cov, "bytes_postings") / max(mean(pri, "bytes_postings"), 1)
    layout_x = mean(prd, "bytes_postings") / max(mean(pri, "bytes_postings"), 1)
    _row(
        "core_layout_docid", dt_d / B * 1e6,
        f"n_probes={mean(prd, 'n_probes'):.0f};"
        f"bytes_postings={mean(prd, 'bytes_postings'):.0f};"
        f"blocks_skipped={mean(prd, 'text_blocks_skipped'):.1f};"
        f"blocks_total={mean(prd, 'text_blocks_total'):.1f};"
        f"n_docs={n_docs}",
    )
    _row(
        "core_layout_impact", dt_i / B * 1e6,
        f"n_probes={mean(pri, 'n_probes'):.0f};"
        f"bytes_postings={mean(pri, 'bytes_postings'):.0f};"
        f"blocks_skipped={mean(pri, 'text_blocks_skipped'):.1f};"
        f"blocks_total={mean(pri, 'text_blocks_total'):.1f};"
        f"posting_bytes_per_entry={eng_i.index.text.posting_bytes:.2f};"
        f"interpret_mode={int(jax.default_backend() != 'tpu')}",
    )
    _row(
        "core_layout_gain", dt_c / B * 1e6,
        f"identical_to_docid={int(identical)};"
        f"recall_vs_covering={rec_cov:.3f};"
        f"n_probes_x={probes_x:.2f};bytes_postings_x={bytes_x:.2f};"
        f"layout_bytes_x={layout_x:.2f}",
    )


def bench_compress(quick: bool) -> None:
    """Compressed posting/toe-print stores vs the uncompressed layout.

    The ISSUE 8 acceptance rows: on the zipf smoke trace the compressed
    store must stream ≤ 0.5× the postings+spatial bytes of the
    uncompressed layout at recall@10 ≥ 0.99 vs it (``meets_2x`` column).
    """
    from repro.core import GeoSearchEngine, QueryBudgets
    from repro.core.ranking import topk_recall_np
    from repro.corpus import make_corpus, make_zipf_trace, pad_trace_batch

    n_docs = 1200 if quick else 12000
    corpus = make_corpus(n_docs, 400 if quick else 1500, seed=9)
    budgets = QueryBudgets(
        max_candidates=1024 if quick else 4096, max_tiles=256, k_sweeps=8,
        sweep_budget=max(n_docs // 8, 256), top_k=10,
    )
    B = 64
    trace = pad_trace_batch(
        make_zipf_trace(corpus, n_queries=B, pool_size=48, seed=10)
    )

    def build(mode):
        return GeoSearchEngine.build(
            corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
            pagerank=corpus.pagerank, grid=32 if quick else 64, budgets=budgets,
            compress=mode,
        )

    def mean(r, key):
        return float(np.asarray(r.stats[key], np.float64).mean())

    eng_u = build("none")
    dt_u, un = _time(lambda: eng_u.query(trace, "k_sweep"))
    bytes_u = mean(un, "bytes_postings") + mean(un, "bytes_spatial")
    rows = {}
    for mode in ["f16", "int8"]:
        eng_c = build(mode)
        dt_c, co = _time(lambda e=eng_c: e.query(trace, "k_sweep"))
        bytes_c = mean(co, "bytes_postings") + mean(co, "bytes_spatial")
        rec = topk_recall_np(un.ids, co.ids)
        rows[mode] = (bytes_c, rec)
        _row(
            f"core_compress_{mode}", dt_c / B * 1e6,
            f"recall_vs_uncompressed={rec:.3f};"
            f"bytes_postings={mean(co, 'bytes_postings'):.0f};"
            f"bytes_spatial={mean(co, 'bytes_spatial'):.0f};"
            f"posting_bytes_per_entry={eng_c.index.text.posting_bytes:.2f};"
            f"tp_bytes_per_entry={eng_c.index.spatial.tp_bytes:.2f};"
            f"n_docs={n_docs}",
        )
    meets = all(
        bytes_u >= 2.0 * b and rec >= 0.99 for b, rec in rows.values()
    )
    _row(
        "core_compress_gain", dt_u / B * 1e6,
        f"bytes_x_f16={bytes_u / max(rows['f16'][0], 1e-9):.2f};"
        f"bytes_x_int8={bytes_u / max(rows['int8'][0], 1e-9):.2f};"
        f"bytes_postings_uncompressed={mean(un, 'bytes_postings'):.0f};"
        f"bytes_spatial_uncompressed={mean(un, 'bytes_spatial'):.0f};"
        f"meets_2x={int(meets)}",
    )


def bench_planner(quick: bool) -> None:
    """Cost-based planner vs every fixed algorithm on the mixture trace.

    The ISSUE 5 acceptance rows: on the bimodal term-selectivity ×
    footprint-area workload, ``--algo auto`` must spend ≥ 1.3× fewer
    probes + posting bytes than the best single fixed algorithm at
    recall@10 ≥ 0.95 vs the exact oracle (``meets_1p3x`` column).
    """
    from repro.core import GeoSearchEngine, QueryBudgets
    from repro.corpus import make_corpus, make_mixture_trace, pad_trace_batch

    n_docs = 2500 if quick else 8000
    corpus = make_corpus(n_docs, 1000 if quick else 1500, seed=9)
    budgets = QueryBudgets(
        max_candidates=2048, max_tiles=1024, k_sweeps=8,
        sweep_budget=max(n_docs // 8, 256), top_k=10,
    )
    eng = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=128, m_intervals=8, budgets=budgets,
    )
    B = 96 if quick else 192
    batch = pad_trace_batch(make_mixture_trace(corpus, n_queries=B, seed=10))

    def mean(res, key):
        return float(np.asarray(res.stats[key], np.float64).mean())

    # one exact-oracle run serves all four recall columns
    from repro.core.ranking import topk_recall_np

    want_ids = np.asarray(eng.oracle(batch).ids)
    costs, recalls = {}, {}
    for algo in ["text_first", "geo_first", "k_sweep", "auto"]:
        dt, res = _time(lambda a=algo: eng.query(batch, a))
        costs[algo] = mean(res, "n_probes") + mean(res, "bytes_postings")
        recalls[algo] = topk_recall_np(want_ids, res.ids)
        tag = "ksweep" if algo == "k_sweep" else algo
        _row(
            f"planner_mixture_{tag}", dt / B * 1e6,
            f"recall@10={recalls[algo]:.3f};"
            f"probes_plus_postbytes={costs[algo]:.0f};"
            f"n_probes={mean(res, 'n_probes'):.0f};"
            f"bytes_postings={mean(res, 'bytes_postings'):.0f};n_docs={n_docs}",
        )
    mix = {}
    for p in eng.planner.plan_rows(batch):
        mix[p.label] = mix.get(p.label, 0) + 1
    best_fixed = min(costs[a] for a in ["text_first", "geo_first", "k_sweep"])
    gain = best_fixed / max(costs["auto"], 1e-9)
    _row(
        "planner_mixture_gain", 0.0,
        f"gain_vs_best_fixed={gain:.2f}x;"
        f"meets_1p3x={int(gain >= 1.3 and recalls['auto'] >= 0.95)};"
        f"plan_mix={'/'.join(f'{k}:{v}' for k, v in sorted(mix.items()))}",
    )

    # planner audit: how well do the cost model's per-query counter
    # predictions match what the executors actually measure?  One row per
    # algorithm, mean relative error per counter before vs after
    # CostModel.calibrate on this same mixture batch (the serving-time
    # audit log computes the identical quantity online; see
    # repro.obs.audit.PlannerAudit.error_summary).
    from repro.core.planner import COST_KEYS

    planner = eng.planner
    model = planner.model
    terms = np.asarray(batch.terms)
    rects = np.asarray(batch.rects)
    amps = np.asarray(batch.amps)
    feats = [model.features(terms[b], rects[b], amps[b]) for b in range(B)]

    def _audit_errors() -> dict:
        errs = {}
        for plan in planner.candidates:
            res = eng.query(batch, plan=plan)
            pred = [model.estimate(plan, f) for f in feats]
            for k in COST_KEYS:
                meas = np.asarray(res.stats[k], np.float64).reshape(B, -1).sum(axis=1)
                p = np.array([e[k] for e in pred])
                errs[(plan.algorithm, k)] = float(
                    (np.abs(p - meas) / np.maximum(meas, 1.0)).mean()
                )
        return errs

    before = _audit_errors()
    model.calibrate(eng, batch, planner.candidates)
    after = _audit_errors()
    for plan in planner.candidates:
        algo = plan.algorithm
        derived = ";".join(
            f"{k}_err={before[(algo, k)]:.3f};{k}_err_cal={after[(algo, k)]:.3f}"
            for k in COST_KEYS
        )
        _row(f"planner_audit_{algo}", 0.0, derived)


def bench_k_sensitivity(quick: bool) -> None:
    from repro.core import GeoSearchEngine, QueryBudgets
    from repro.corpus import make_corpus, make_query_trace

    n_docs = 4000 if quick else 12000
    corpus = make_corpus(n_docs, 800, seed=2)
    for k in [1, 2, 4, 8, 16]:
        budgets = QueryBudgets(
            max_candidates=2048, max_tiles=2048, k_sweeps=k,
            sweep_budget=max(n_docs // 3, 256), top_k=10,
        )
        eng = GeoSearchEngine.build(
            corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
            pagerank=corpus.pagerank, grid=64, budgets=budgets,
        )
        trace = make_query_trace(corpus, n_queries=32, seed=3)
        dt, res = _time(lambda: eng.query(trace, "k_sweep"))
        slack = float(np.asarray(res.stats["sweep_slack"]).mean())
        rec = eng.recall_at_k(trace, "k_sweep")
        _row(f"fig_k_sweep_k{k}", dt / 32 * 1e6,
             f"recall={rec:.3f};mean_slack_toeprints={slack:,.0f}")


def bench_scale(quick: bool) -> None:
    from repro.core import GeoSearchEngine, QueryBudgets
    from repro.corpus import make_corpus, make_query_trace

    sizes = [1000, 4000] if quick else [1000, 4000, 16000, 64000]
    for n in sizes:
        corpus = make_corpus(n, 1000, seed=4)
        budgets = QueryBudgets(
            max_candidates=2048, max_tiles=256, k_sweeps=8,
            sweep_budget=max(n // 8, 256), top_k=10,
        )
        eng = GeoSearchEngine.build(
            corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
            pagerank=corpus.pagerank, grid=64, budgets=budgets,
        )
        trace = make_query_trace(corpus, n_queries=32, seed=5)
        dt, _ = _time(lambda: eng.query(trace, "k_sweep"))
        _row(f"fig_scale_n{n}", dt / 32 * 1e6, f"docs={n}")


def bench_geo_partition(quick: bool) -> None:
    """Geographic vs hash partitioning: per-shard structure tightness."""
    from repro.core.distributed import shard_corpus_np
    from repro.corpus import make_corpus

    from repro.core.distributed import (
        HashPartitioner, MortonPartitioner, RegionRangePartitioner,
    )

    n_docs, S = (2048, 4) if quick else (8192, 8)
    corpus = make_corpus(n_docs, 500, seed=6)
    rng = np.random.default_rng(0)
    # city-sized probe queries
    probes = []
    for _ in range(100):
        c = corpus.cities[rng.integers(0, len(corpus.cities))]
        w = float(c[2])
        probes.append([c[0] - w, c[1] - w, c[0] + w, c[1] + w])
    probes = np.array(probes, np.float32)
    parts = [
        ("hash", HashPartitioner()),
        ("morton", MortonPartitioner()),
        ("region", RegionRangePartitioner()),
    ]
    for part, partitioner in parts:
        sh = shard_corpus_np(
            corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.pagerank,
            corpus.n_terms, n_shards=S, partitioner=partitioner, grid=32,
        )
        # per-shard toe-print MBR -> how many shards must a query visit?
        rects = np.asarray(sh.tp_rects)  # [S, T, 4]
        amps = np.asarray(sh.tp_amps)
        fanouts = []
        mbrs = []
        for si in range(S):
            v = amps[si] > 0
            r = rects[si][v]
            mbrs.append([r[:, 0].min(), r[:, 1].min(), r[:, 2].max(), r[:, 3].max()])
        mbrs = np.array(mbrs)
        for q in probes:
            inter = (
                (np.maximum(mbrs[:, 0], q[0]) < np.minimum(mbrs[:, 2], q[2]))
                & (np.maximum(mbrs[:, 1], q[1]) < np.minimum(mbrs[:, 3], q[3]))
            )
            fanouts.append(inter.sum())
        occ = np.asarray(sh.tile_starts) != np.int32(2**31 - 1)
        _row(f"geo_partition_{part}", 0.0,
             f"mean_query_shard_fanout={np.mean(fanouts):.2f}_of_{S};"
             f"tile_occupancy={occ.any(axis=2).mean():.3f}")


def bench_kernels(quick: bool) -> None:
    from repro.kernels.geo_score.ops import geo_score_toeprints
    from repro.kernels.geo_score.ref import geo_score_toeprints_ref
    from repro.kernels.bitmap_filter.ops import bitmap_and_popcount
    from repro.kernels.bitmap_filter.ref import bitmap_and_popcount_ref

    rng = np.random.default_rng(0)
    T = 4096 if quick else 65536
    lo = rng.uniform(0, 0.9, (T, 2)).astype(np.float32)
    rects = jnp.asarray(np.concatenate([lo, lo + 0.05], axis=1))
    amps = jnp.asarray(rng.uniform(0, 1, T).astype(np.float32))
    qr = jnp.asarray(np.array([[0.2, 0.2, 0.6, 0.6], [0.5, 0.5, 0.9, 0.9]], np.float32))
    qa = jnp.ones((2,))
    got = geo_score_toeprints(rects, amps, qr, qa)
    want = geo_score_toeprints_ref(rects, amps, qr, qa)
    err = float(jnp.abs(got - want).max())
    dt_ref, _ = _time(jax.jit(geo_score_toeprints_ref), rects, amps, qr, qa)
    _row("kernel_geo_score", dt_ref * 1e6,
         f"max_err_vs_ref={err:.2e};T={T};vmem_bytes_per_block={8*128*6*4}")

    # fused sweep fetch+score kernel vs its oracle
    from repro.kernels.sweep_score.ops import sweep_score
    from repro.kernels.sweep_score.ref import sweep_score_ref

    ss = jnp.asarray(np.sort(rng.integers(0, T - 2048, 8)).astype(np.int32))
    ee = jnp.asarray(np.minimum(np.asarray(ss) + 1500, T).astype(np.int32))
    fs, fv = sweep_score(rects, amps, ss, ee, qr, qa, 2048)
    ws, wv = sweep_score_ref(rects, amps, ss, ee, qr, qa, 2048)
    errf = float(jnp.abs(fs - ws).max())
    dt_ref, _ = _time(jax.jit(lambda *a: sweep_score_ref(*a, 2048)), rects, amps, ss, ee, qr, qa)
    _row("kernel_sweep_score_fused", dt_ref * 1e6,
         f"max_err_vs_ref={errf:.2e};k=8;budget=2048;fused_fetch_and_score=1")

    W = 8192 if quick else 262144
    bm = jnp.asarray(rng.integers(0, 2**32, (4, W), dtype=np.uint32))
    ga, gc = bitmap_and_popcount(bm)
    wa, wc = bitmap_and_popcount_ref(bm)
    ok = bool((ga == wa).all() and (gc == wc).all())
    dt_ref, _ = _time(jax.jit(bitmap_and_popcount_ref), bm)
    _row("kernel_bitmap_filter", dt_ref * 1e6,
         f"exact_match={ok};W={W};vmem_bytes_per_block={8*128*(4+2)*4}")


def bench_distributed(quick: bool) -> None:
    """Single-process multi-device serve (requires >1 device; noted on 1)."""
    if len(jax.devices()) < 2:
        _row("distributed_serve", 0.0,
             "skipped=single_device_container;see tests/test_distributed.py")
        return
    from repro.core import QueryBudgets
    from repro.core.distributed import (
        MortonPartitioner, make_serve_fn, shard_corpus_np,
    )
    from repro.corpus import make_corpus, make_query_trace

    corpus = make_corpus(2048, 500, seed=7)
    budgets = QueryBudgets(max_candidates=512, max_tiles=64, k_sweeps=4,
                           sweep_budget=256, top_k=10)
    n = len(jax.devices())
    mesh = jax.make_mesh((n, 1), ("data", "model"))
    sharded = shard_corpus_np(corpus.doc_terms, corpus.doc_rects, corpus.doc_amps,
                              corpus.pagerank, corpus.n_terms, n,
                              MortonPartitioner(), grid=32)
    serve = make_serve_fn(mesh, budgets, doc_axes=("data",), grid=32,
                          n_terms=corpus.n_terms)
    trace = make_query_trace(corpus, n_queries=32, seed=8)
    with mesh:
        dt, _ = _time(lambda: serve(sharded, trace))
    _row("distributed_serve", dt / 32 * 1e6, f"devices={n}")


def bench_serving(quick: bool) -> None:
    """End-to-end serving stack on a Zipf trace (cache × batcher × arrival)."""
    from repro.core import GeoSearchEngine, QueryBudgets
    from repro.corpus import make_corpus, make_zipf_trace, stamp_arrivals
    from repro.serving import (
        DeadlineBatcher, GeoServer, SingleDeviceExecutor, make_cache,
    )

    n_docs = 2000 if quick else 12000
    n_q = 512 if quick else 2048
    corpus = make_corpus(n_docs, 500 if quick else 1500, seed=9)
    budgets = QueryBudgets(
        max_candidates=1024, max_tiles=256, k_sweeps=8,
        sweep_budget=max(n_docs // 8, 256), top_k=10,
    )
    eng = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=32, budgets=budgets,
    )
    trace = make_zipf_trace(corpus, n_queries=n_q, pool_size=max(n_q // 8, 32), seed=10)
    from benchmarks.serve_bench import report_row

    for cache in ["none", "landlord"]:
        server = GeoServer(
            SingleDeviceExecutor(eng),
            cache=make_cache(cache, 512),
            batcher=DeadlineBatcher(max_batch=32, max_terms=8, max_rects=4),
        )
        report_row(f"serving_zipf_{cache}", server.run_trace(trace))

    # open-loop arrival replay: deadline flush vs tail latency at fixed load
    rate = 400.0 if quick else 800.0
    arr = stamp_arrivals(trace, "poisson", rate_qps=rate, seed=11)
    for wait_ms in [2.0, float("inf")]:
        tag = "inf" if wait_ms == float("inf") else f"{wait_ms:g}"
        server = GeoServer(
            SingleDeviceExecutor(eng), cache=None,
            batcher=DeadlineBatcher(
                max_batch=32, max_terms=8, max_rects=4, max_wait_s=wait_ms * 1e-3
            ),
        )
        rep = server.run_trace(arr, arrival="poisson", slo_ms=50.0)
        report_row(f"serving_arrival_poisson_w{tag}", rep)

    # worker pool × in-flight coalescing at the same offered load; no cache
    # (the Zipf trace repeats queries, so with nothing absorbing repeats
    # every duplicate either re-executes or coalesces — the `coalesced`
    # column measures the path directly)
    sweep = [(1, True), (2, True)] if quick else [
        (w, c) for w in (1, 2, 4) for c in (False, True)
    ]
    for n_workers, coal in sweep:
        server = GeoServer(
            SingleDeviceExecutor(eng), cache=None,
            batcher=DeadlineBatcher(
                max_batch=32, max_terms=8, max_rects=4, max_wait_s=2e-3
            ),
            n_workers=n_workers, coalesce=coal,
        )
        rep = server.run_trace(arr, arrival="poisson", slo_ms=50.0)
        tag = "on" if coal else "off"
        report_row(f"serving_workers_{n_workers}_coalesce_{tag}", rep)


def bench_routing(quick: bool) -> None:
    """Footprint routing vs broadcast at S=8: fan-out, recall, bit-identity.

    The tentpole claim in one sweep — on a city-footprint zipf trace over
    region-partitioned shards, footprint routing must (a) touch a mean of
    ≪ S shards per query, (b) keep recall@k vs the exact oracle at 1.0
    under generous budgets, and (c) return bit-identical ids *and* scores
    to the hash-partition broadcast baseline.
    """
    from repro.core import GeoSearchEngine, QueryBudgets
    from repro.core.distributed import HashPartitioner, RegionRangePartitioner
    from repro.corpus import make_corpus, make_zipf_trace
    from repro.serving import GeoServer, ShapeBucketedBatcher, make_executor

    n_docs, S = (2048, 8) if quick else (8192, 8)
    n_q = 256 if quick else 1024
    # single-place docs: multi-place corpora smear shard coverage across
    # the map (every shard touches every city), which defeats routing by
    # construction — single-toe-print pages are the workload it targets.
    # Seed 17's zipf city-size draw spreads population over ~8 cities;
    # single-mega-city draws are the degenerate anti-case (all shards
    # subdivide the one city, so every city query touches all of them).
    corpus = make_corpus(n_docs, 500, max_rects=1, seed=17)
    budgets = QueryBudgets(
        max_candidates=2048, max_tiles=256, k_sweeps=8,
        sweep_budget=max(n_docs // 4, 512), top_k=10,
    )
    kw = dict(algorithm="k_sweep", budgets=budgets, grid=32, n_shards=S)
    broadcast = make_executor(
        "sharded", corpus, partitioner=HashPartitioner(),
        routing="broadcast", **kw,
    )
    routed = make_executor(
        "sharded", corpus, partitioner=RegionRangePartitioner(),
        routing="footprint", **kw,
    )
    trace = make_zipf_trace(
        corpus, n_queries=n_q, pool_size=max(n_q // 8, 32), seed=14,
        scales=(1.0,),  # city-sized footprints
    )

    def serve(executor):
        server = GeoServer(
            executor, cache=None,
            batcher=ShapeBucketedBatcher(
                max_batch=16, max_terms=8, max_rects=4,
                term_buckets=[8], rect_buckets=[4], batch_sizes=[16],
            ),
        )
        return server.run_trace(trace, collect_results=True)

    rep_bc = serve(broadcast)
    rep_fp = serve(routed)
    identical = all(
        np.array_equal(a.ids, b.ids)
        and a.scores.tobytes() == b.scores.tobytes()
        for a, b in zip(rep_bc.results, rep_fp.results)
    )
    # recall@k vs the exact oracle on the distinct pool head
    from repro.corpus import pad_trace_batch

    seen, distinct = set(), []
    for q in trace:
        key = id(q)
        if key not in seen:
            seen.add(key)
            distinct.append(q)
    probe = pad_trace_batch(distinct[:64])
    eng = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=32, budgets=budgets,
    )
    want = np.asarray(eng.oracle(probe).ids)
    got = np.asarray(routed.run(probe).ids)
    hits = tot = 0
    for b in range(want.shape[0]):
        w = set(want[b][want[b] >= 0])
        hits += len(w & set(got[b][got[b] >= 0]))
        tot += len(w)
    recall = hits / max(tot, 1)
    from benchmarks.serve_bench import report_row

    report_row("serving_routing_broadcast", rep_bc)
    report_row("serving_routing_footprint", rep_fp)
    mean_touched = rep_fp.routing_mean(routed.algorithm)
    _row(
        "serving_routing_footprint_fanout", 0.0,
        f"shards_touched_mean={mean_touched:.3f};shards_total={S};"
        f"identical={int(identical)};recall_at_10={recall:.3f}",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    bench_table1(args.quick)
    bench_block_prune(args.quick)
    bench_text_prune(args.quick)
    bench_layout(args.quick)
    bench_compress(args.quick)
    bench_planner(args.quick)
    bench_k_sensitivity(args.quick)
    bench_scale(args.quick)
    bench_geo_partition(args.quick)
    bench_kernels(args.quick)
    bench_distributed(args.quick)
    bench_serving(args.quick)
    bench_routing(args.quick)


if __name__ == "__main__":
    main()

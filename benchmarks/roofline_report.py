"""Render the dry-run results (dryrun_results.jsonl) into the EXPERIMENTS.md
roofline tables.

    PYTHONPATH=src python -m benchmarks.roofline_report [--in dryrun_results.jsonl]
"""
from __future__ import annotations

import argparse
import json


def load(path: str):
    seen, skips = {}, {}
    for line in open(path):
        r = json.loads(line)
        key = (r["arch"], r["shape"], r["mesh"])
        if "skipped" in r:
            skips[key] = r
        elif "error" not in r:
            seen[key] = r
    return seen, skips


def fmt_e(x: float) -> str:
    return f"{x:.2e}"


def render(seen: dict, skips: dict, mesh: str) -> str:
    out = []
    out.append(
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) | "
        "bottleneck | MODEL_FLOPS | useful/HLO | roofline frac | HBM GB/dev |"
    )
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    keys = sorted(set(list(seen) + list(skips)))
    for arch, shape, m in keys:
        if m != mesh:
            continue
        if (arch, shape, m) in skips:
            r = skips[(arch, shape, m)]
            out.append(f"| {arch} | {shape} | — | — | — | SKIPPED | — | — | — | — |")
            continue
        r = seen[(arch, shape, m)]
        out.append(
            f"| {arch} | {shape} | {fmt_e(r['t_compute_s'])} | "
            f"{fmt_e(r['t_memory_s'])} | {fmt_e(r['t_collective_s'])} | "
            f"**{r['bottleneck']}** | {fmt_e(r['model_flops'])} | "
            f"{r['useful_flop_ratio']:.3f} | {r['roofline_fraction']:.3f} | "
            f"{r['hbm_per_dev_GB']:.2f} |"
        )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="dryrun_results.jsonl")
    ap.add_argument("--mesh", default="single_pod_16x16")
    args = ap.parse_args()
    seen, skips = load(args.inp)
    print(render(seen, skips, args.mesh))


if __name__ == "__main__":
    main()

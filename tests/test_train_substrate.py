"""Training substrate: optimizer math, checkpoint roundtrip/resume,
fault injection, compression numerics, watchdog, data determinism."""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt
from repro.train.compression import compress_tree, decompress_tree, quantize_int8, dequantize_int8
from repro.train.fault import Heartbeat, Watchdog, WatchdogConfig, plan_elastic_mesh
from repro.train.loop import LoopConfig, make_train_step, run
from repro.train.optimizer import OptimizerConfig, adamw_update, init_opt_state, lr_at


class TestOptimizer:
    def test_adamw_decreases_quadratic(self):
        cfg = OptimizerConfig(lr=0.1, warmup_steps=1, total_steps=200, weight_decay=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = init_opt_state(cfg, params)
        for _ in range(100):
            grads = {"w": 2 * params["w"]}
            params, state, m = adamw_update(cfg, grads, params, state)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_grad_clipping(self):
        cfg = OptimizerConfig(lr=1e-3, clip_norm=1.0, warmup_steps=1)
        params = {"w": jnp.zeros(4)}
        state = init_opt_state(cfg, params)
        _, _, m = adamw_update(cfg, {"w": jnp.full((4,), 100.0)}, params, state)
        assert float(m["grad_norm"]) == pytest.approx(200.0, rel=1e-3)

    def test_schedule_shapes(self):
        cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100, schedule="cosine")
        assert float(lr_at(cfg, jnp.int32(0))) == 0.0
        assert float(lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
        assert float(lr_at(cfg, jnp.int32(100))) == pytest.approx(0.0, abs=1e-5)

    def test_microbatch_accumulation_matches_full(self):
        """grad accumulation over 4 microbatches == full-batch step."""
        def loss_fn(p, b):
            pred = b["x"] @ p["w"]
            return jnp.mean((pred - b["y"]) ** 2), {}

        rng = np.random.default_rng(0)
        params = {"w": jnp.asarray(rng.normal(0, 1, (8,)).astype(np.float32))}
        batch = {
            "x": jnp.asarray(rng.normal(0, 1, (16, 8)).astype(np.float32)),
            "y": jnp.asarray(rng.normal(0, 1, (16,)).astype(np.float32)),
        }
        opt = OptimizerConfig(lr=1e-2, warmup_steps=1)
        s1 = make_train_step(loss_fn, opt, microbatches=1, donate=False)
        s4 = make_train_step(loss_fn, opt, microbatches=4, donate=False)
        st = init_opt_state(opt, params)
        p1, _, m1 = s1(params, st, batch)
        p4, _, m4 = s4(params, init_opt_state(opt, params), batch)
        # microbatch mean-of-means == full mean here (equal sizes)
        np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p4["w"]), rtol=2e-5, atol=2e-6)


class TestCheckpoint:
    def test_roundtrip(self):
        state = {
            "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b": jnp.ones((2,), jnp.int32)},
        }
        with tempfile.TemporaryDirectory() as d:
            ckpt.save_checkpoint(d, 7, state)
            assert ckpt.list_checkpoints(d) == [7]
            got = ckpt.restore_checkpoint(d, 7, state)
            for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_keep_k_gc(self):
        state = {"a": jnp.zeros(3)}
        with tempfile.TemporaryDirectory() as d:
            for s in [10, 20, 30, 40]:
                ckpt.save_checkpoint(d, s, state, keep=2)
            assert ckpt.list_checkpoints(d) == [30, 40]

    def test_async_save(self):
        state = {"a": jnp.ones((128, 128))}
        with tempfile.TemporaryDirectory() as d:
            t = ckpt.save_checkpoint(d, 1, state, async_=True)
            t.join()
            assert ckpt.verify_checkpoint(d, 1)

    def test_verify_detects_missing_file(self):
        state = {"a": jnp.zeros(3), "b": jnp.ones(4)}
        with tempfile.TemporaryDirectory() as d:
            ckpt.save_checkpoint(d, 5, state)
            os.remove(os.path.join(d, "step_00000005", "arr_1.npy"))
            assert not ckpt.verify_checkpoint(d, 5)

    def test_resume_replay_bit_identical(self):
        """Loop resumed from a checkpoint replays identical losses
        (deterministic (seed, step)-keyed data)."""
        from repro.data.lm import LMDataConfig, lm_batch
        from repro.models.transformer import TransformerConfig, loss_fn

        cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=1,
                                d_ff=64, vocab=128, attn_chunk=8,
                                compute_dtype=jnp.float32)
        opt = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=20)
        dc = LMDataConfig(vocab=128, seq_len=16, global_batch=4)
        step_fn = make_train_step(lambda p, b: loss_fn(cfg, p, b), opt)

        def init_state():
            p = cfg.init(jax.random.key(0))
            return p, init_opt_state(opt, p)

        with tempfile.TemporaryDirectory() as d:
            lc = LoopConfig(total_steps=12, ckpt_every=4, ckpt_dir=d,
                            log_every=1, ckpt_async=False)
            _, _, hist1 = run(lc, step_fn, init_state, lambda s: lm_batch(dc, s),
                              log=lambda s: None)
            # crash at step 9 and restart
            lc2 = LoopConfig(total_steps=12, ckpt_every=4, ckpt_dir=d,
                             log_every=1, simulate_failure_at=9, ckpt_async=False)
        with tempfile.TemporaryDirectory() as d2:
            lc_a = LoopConfig(total_steps=12, ckpt_every=4, ckpt_dir=d2,
                              log_every=1, simulate_failure_at=9, ckpt_async=False)
            _, _, hist2 = run(lc_a, step_fn, init_state, lambda s: lm_batch(dc, s),
                              log=lambda s: None)
        h1 = dict(hist1)
        h2 = dict(hist2)
        for s in h1:
            assert h1[s] == pytest.approx(h2[s], rel=1e-6), (s, h1[s], h2[s])


class TestCompression:
    def test_quantize_roundtrip_error(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(0, 0.1, (1000,)).astype(np.float32))
        q, s = quantize_int8(x)
        err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x)).max()
        assert err <= float(s) / 2 + 1e-9

    def test_error_feedback_accumulates(self):
        """With error feedback, the mean of many compressed steps converges
        to the true gradient (bias-free compression)."""
        rng = np.random.default_rng(1)
        g = {"w": jnp.asarray(rng.normal(0, 1, (256,)).astype(np.float32))}
        err = {"w": jnp.zeros((256,), jnp.float32)}
        acc = np.zeros((256,), np.float32)
        n = 50
        for _ in range(n):
            q, s, err = compress_tree(g, err)
            acc += np.asarray(decompress_tree(q, s)["w"])
        np.testing.assert_allclose(acc / n, np.asarray(g["w"]), atol=2e-3)


class TestFault:
    def test_watchdog_detects_dead_and_straggler(self):
        with tempfile.TemporaryDirectory() as d:
            now = time.time()
            for h in range(4):
                Heartbeat(d, h).beat(step=100, step_time_s=1.0)
            Heartbeat(d, 4).beat(step=80, step_time_s=10.0)  # straggler
            wd = Watchdog(d, WatchdogConfig(timeout_s=300, straggler_factor=3.0,
                                            straggler_patience=2))
            r1 = wd.scan(now)
            assert r1["stragglers"] == [4]
            r2 = wd.scan(now)  # second strike → evicted
            assert 4 in r2["dead"]
            # stale heartbeat → dead
            r3 = wd.scan(now + 1000)
            assert set(r3["dead"]) >= {0, 1, 2, 3}

    def test_elastic_mesh_plan(self):
        assert plan_elastic_mesh(64, 4, model_parallel=16) == (16, 16)
        assert plan_elastic_mesh(60, 4, model_parallel=16) == (15, 16)
        assert plan_elastic_mesh(64, 8, model_parallel=16, pods=2) == (2, 16, 16)

    def test_restore_on_different_topology(self):
        """Resharding restore: save arrays, restore with explicit shardings
        onto the (single-device) 'new mesh' — shapes and values survive."""
        state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        with tempfile.TemporaryDirectory() as d:
            ckpt.save_checkpoint(d, 3, state)
            sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
            got = ckpt.restore_checkpoint(d, 3, state, shardings={"w": sh})
            np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(state["w"]))


class TestDataDeterminism:
    def test_lm_batches_deterministic(self):
        from repro.data.lm import LMDataConfig, lm_batch

        dc = LMDataConfig(vocab=100, seq_len=8, global_batch=2, seed=3)
        a = lm_batch(dc, 17)
        b = lm_batch(dc, 17)
        np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
        c = lm_batch(dc, 18)
        assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))

    def test_sampler_deterministic(self):
        from repro.data.graph import SampledShape, make_powerlaw_graph, sample_subgraph

        g = make_powerlaw_graph(100, 500, 4, seed=0)
        sh = SampledShape(8, (3, 2))
        a = sample_subgraph(g, sh, seed=1, step=5)
        b = sample_subgraph(g, sh, seed=1, step=5)
        np.testing.assert_array_equal(np.asarray(a["senders"]), np.asarray(b["senders"]))

    def test_sampler_respects_fanout_and_locality(self):
        from repro.data.graph import SampledShape, make_powerlaw_graph, sample_subgraph

        g = make_powerlaw_graph(200, 2000, 4, seed=2)
        sh = SampledShape(4, (5, 3))
        sub = sample_subgraph(g, sh, seed=0, step=0)
        ne = int(np.asarray(sub["edge_mask"]).sum())
        assert 0 < ne <= sh.max_edges
        s = np.asarray(sub["senders"])[np.asarray(sub["edge_mask"])]
        r = np.asarray(sub["receivers"])[np.asarray(sub["edge_mask"])]
        assert s.max() < sh.max_nodes and r.max() < sh.max_nodes

"""Footprint-routing equivalence suite.

The routing contract: a footprint-routed executor may *skip* shards whose
coverage grid no query footprint touches, and must remain **bit-identical**
to the broadcast baseline — `require_geo` ranking scores a doc −inf when
its geo score is 0, so an unreachable shard can only contribute empty
lists, and the shard builders construct impacts from partition-independent
global statistics so per-doc scores do not depend on the shard layout.
"""
import jax
import numpy as np
import pytest

from repro.core import GeoSearchEngine, QueryBudgets
from repro.core.algorithms import QueryBatch
from repro.core.distributed import (
    HashPartitioner,
    MortonPartitioner,
    RegionRangePartitioner,
    resolve_partitioner,
)
from repro.corpus import make_corpus, make_query_trace
from repro.serving import ShardedExecutor, make_executor


def _budgets(top_k: int = 10) -> QueryBudgets:
    # generous: every path is exact, so disagreement = routing bug
    return QueryBudgets(
        max_candidates=1024, max_tiles=256, k_sweeps=4,
        sweep_budget=1024, top_k=top_k,
    )


def _bit_identical(a, b) -> None:
    a_ids, b_ids = np.asarray(a.ids), np.asarray(b.ids)
    a_sc, b_sc = np.asarray(a.scores), np.asarray(b.scores)
    assert np.array_equal(a_ids, b_ids)
    assert a_sc.tobytes() == b_sc.tobytes()  # bitwise, -inf included


def _query_batch(rects: np.ndarray, amps: np.ndarray) -> QueryBatch:
    b = rects.shape[0]
    return QueryBatch(
        terms=np.zeros((b, 1), dtype=np.int32),
        rects=rects.astype(np.float32),
        amps=amps.astype(np.float32),
    )


# ---------------------------------------------------------------------------
# bit-identity: region-routed ≡ hash-broadcast at S ∈ {1, 4, 8}
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 4, 8])
def test_region_footprint_bit_identical_to_hash_broadcast(n_shards):
    corpus = make_corpus(n_docs=256, n_terms=60, seed=7)
    budgets = _budgets()
    kw = dict(algorithm="k_sweep", budgets=budgets, grid=16, n_shards=n_shards)
    broadcast = make_executor(
        "sharded", corpus, partitioner=HashPartitioner(),
        routing="broadcast", **kw,
    )
    routed = make_executor(
        "sharded", corpus, partitioner=RegionRangePartitioner(),
        routing="footprint", **kw,
    )
    batch = make_query_trace(corpus, n_queries=16, seed=8)
    want = broadcast.run(batch)
    got = routed.run(batch)
    _bit_identical(want, got)
    touched = got.stats["shards_touched"]
    assert touched.shape == (16,)
    # 0 is legal: a footprint overlapping no doc toe-print scores −inf
    # everywhere, so the row is servable without visiting any shard
    assert np.all(touched >= 0) and np.all(touched <= n_shards)
    assert float(got.stats["shards_visited"]) <= n_shards
    # broadcast never emits routing stats (key-set stability)
    assert "shards_touched" not in want.stats


def test_footprint_matches_single_device_bitwise():
    corpus = make_corpus(n_docs=256, n_terms=60, seed=7)
    budgets = _budgets()
    single = make_executor("single", corpus, budgets=budgets, grid=16)
    routed = make_executor(
        "sharded", corpus, partitioner=RegionRangePartitioner(),
        routing="footprint", budgets=budgets, grid=16, n_shards=4,
    )
    batch = make_query_trace(corpus, n_queries=16, seed=9)
    _bit_identical(single.run(batch), routed.run(batch))


# ---------------------------------------------------------------------------
# routing decision properties
# ---------------------------------------------------------------------------

def test_shards_touched_monotone_in_footprint_area():
    corpus = make_corpus(n_docs=256, n_terms=60, seed=3)
    ex = make_executor(
        "sharded", corpus, partitioner=RegionRangePartitioner(),
        routing="footprint", budgets=_budgets(), grid=16, n_shards=8,
    )
    widths = [0.01, 0.05, 0.1, 0.2, 0.4, 0.6]
    rects = np.zeros((len(widths), 1, 4), dtype=np.float32)
    for i, w in enumerate(widths):
        rects[i, 0] = [0.5 - w, 0.5 - w, 0.5 + w, 0.5 + w]
    amps = np.ones((len(widths), 1), dtype=np.float32)
    _, touched = ex.route_batch(_query_batch(rects, amps))
    assert np.all(np.diff(touched) >= 0), touched
    assert touched[-1] == 8  # a footprint over everything touches everything


def test_zero_coverage_shard_contributes_zero_bytes_host():
    """A query reaching only part of the corpus must not stream bytes from
    the skipped shards, while staying bit-identical to broadcast."""
    corpus = make_corpus(n_docs=256, n_terms=60, seed=5)
    routed = make_executor(
        "sharded", corpus, partitioner=RegionRangePartitioner(),
        routing="footprint", budgets=_budgets(), grid=16, n_shards=4,
    )
    # broadcast twin over the *same* engines: byte deltas are routing-only
    broadcast = ShardedExecutor(
        routed.engines, routed.global_ids, "k_sweep", routing="broadcast"
    )
    # scan tiny footprints over a lattice and keep one that reaches a
    # strict subset of shards — region partitioning must leave *some*
    # location whose coverage misses at least one KD cell
    centers = np.linspace(0.05, 0.95, 12)
    cand = np.zeros((len(centers) ** 2, 1, 4), dtype=np.float32)
    for i, cx in enumerate(centers):
        for j, cy in enumerate(centers):
            cand[i * len(centers) + j, 0] = [
                cx - 0.01, cy - 0.01, cx + 0.01, cy + 0.01,
            ]
    amps = np.ones((len(cand), 1), dtype=np.float32)
    _, cand_touched = routed.route_batch(_query_batch(cand, amps))
    partial = np.flatnonzero((cand_touched >= 1) & (cand_touched < 4))
    assert partial.size, "region partitioner produced no partial coverage"
    batch = _query_batch(cand[partial[:1]], amps[:1])
    got = routed.run(batch)
    want = broadcast.run(batch)
    _bit_identical(want, got)
    visited = float(got.stats["shards_visited"])
    assert 1 <= visited < 4  # reaches its own shard, not every KD cell
    for key, v in want.stats.items():
        if key.startswith("bytes_"):
            total = float(np.asarray(v, np.float64).sum())
            routed_total = float(
                np.asarray(got.stats[key], np.float64).sum()
            )
            # the zero-coverage shards contributed exactly zero bytes to
            # the broadcast totals — skipping them changes nothing
            assert routed_total == total, key
    # what routing *does* save: each skipped shard's fixed seek overhead
    assert float(np.asarray(got.stats["seeks"]).sum()) < float(
        np.asarray(want.stats["seeks"]).sum()
    )


def test_out_of_coverage_query_visits_nothing_host():
    corpus = make_corpus(n_docs=128, n_terms=40, seed=2)
    ex = make_executor(
        "sharded", corpus, partitioner=RegionRangePartitioner(),
        routing="footprint", budgets=_budgets(top_k=5), grid=16, n_shards=4,
    )
    # valid footprint (x1 > x0, amp > 0) entirely outside the corpus extent
    rects = np.array([[[5.0, 5.0, 6.0, 6.0]]], dtype=np.float32)
    res = ex.run(_query_batch(rects, np.ones((1, 1), dtype=np.float32)))
    assert float(res.stats["shards_visited"]) == 0
    assert np.all(np.asarray(res.ids) == -1)
    assert np.all(np.isneginf(np.asarray(res.scores)))
    # no engine ran: only routing stats exist, zero bytes anywhere
    assert not any(k.startswith("bytes_") for k in res.stats)


def test_mesh_routing_counters_match_host():
    """The jit'd mesh masking reports the same routing + byte counters as
    the host skip loop, and an out-of-coverage query leaves every mesh
    counter provably zero."""
    from jax.sharding import Mesh

    corpus = make_corpus(n_docs=192, n_terms=64, seed=11)
    budgets = QueryBudgets(
        max_candidates=256, max_tiles=64, k_sweeps=4, sweep_budget=128,
        top_k=5,
    )
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    kw = dict(
        partitioner=HashPartitioner(), routing="footprint",
        budgets=budgets, grid=16,
    )
    meshx = make_executor("mesh", corpus, mesh=mesh, **kw)
    host = make_executor("sharded", corpus, n_shards=1, **kw)
    batch = make_query_trace(corpus, n_queries=8, seed=12)
    got, want = meshx.run(batch), host.run(batch)
    _bit_identical(want, got)
    assert set(got.stats) == set(want.stats)
    for k in want.stats:
        np.testing.assert_allclose(
            np.asarray(got.stats[k], np.float64).sum(),
            np.asarray(want.stats[k], np.float64).sum(),
            rtol=1e-6, err_msg=k,
        )
    # an unreachable footprint: the masked step's counters are all zero
    rects = np.array([[[5.0, 5.0, 6.0, 6.0]]], dtype=np.float32)
    far = meshx.run(_query_batch(rects, np.ones((1, 1), dtype=np.float32)))
    assert np.all(np.asarray(far.ids) == -1)
    for k, v in far.stats.items():
        assert float(np.asarray(v, np.float64).sum()) == 0, k


# ---------------------------------------------------------------------------
# Partitioner API round-trips
# ---------------------------------------------------------------------------

def test_partitioner_round_trips_through_make_executor():
    corpus = make_corpus(n_docs=64, n_terms=30, seed=1)
    for part in (HashPartitioner(), MortonPartitioner(), RegionRangePartitioner()):
        ex = make_executor(
            "sharded", corpus, partitioner=part, n_shards=2,
            budgets=_budgets(top_k=3), grid=16,
        )
        assert ex.n_shards == 2
        # every doc lands in exactly one shard
        all_ids = np.concatenate(ex.global_ids)
        assert sorted(all_ids.tolist()) == list(range(64))


def test_raw_partition_strings_rejected():
    corpus = make_corpus(n_docs=64, n_terms=30, seed=1)
    with pytest.raises(TypeError, match="Partitioner"):
        make_executor("sharded", corpus, partitioner="hash", n_shards=2)
    with pytest.raises(TypeError, match="Partitioner"):
        ShardedExecutor.build(
            corpus.doc_terms, corpus.doc_rects, corpus.doc_amps,
            corpus.n_terms, pagerank=corpus.pagerank, n_shards=2,
            partitioner="geo",
        )
    # the deprecated partition= kwarg fails loudly, not silently
    with pytest.raises(TypeError, match="Partitioner API"):
        ShardedExecutor.build(
            corpus.doc_terms, corpus.doc_rects, corpus.doc_amps,
            corpus.n_terms, pagerank=corpus.pagerank, n_shards=2,
            partition="hash",
        )


def test_make_executor_validation():
    corpus = make_corpus(n_docs=64, n_terms=30, seed=1)
    with pytest.raises(ValueError, match="kind"):
        make_executor("cluster", corpus)
    with pytest.raises(ValueError, match="routing"):
        make_executor("sharded", corpus, n_shards=2, routing="multicast")
    with pytest.raises(ValueError, match="sharded"):
        make_executor("single", corpus, partitioner=HashPartitioner())
    with pytest.raises(ValueError, match="mesh"):
        make_executor("mesh", corpus)


def test_resolve_partitioner_aliases():
    assert isinstance(resolve_partitioner(None), MortonPartitioner)
    assert isinstance(resolve_partitioner("geo"), MortonPartitioner)
    assert isinstance(resolve_partitioner("hash"), HashPartitioner)
    assert isinstance(resolve_partitioner("morton"), MortonPartitioner)
    assert isinstance(resolve_partitioner("region"), RegionRangePartitioner)
    part = RegionRangePartitioner()
    assert resolve_partitioner(part) is part
    with pytest.raises(ValueError, match="unknown partitioner"):
        resolve_partitioner("voronoi")

"""Deadline-batcher + open-loop arrival replay tests (virtual clock).

Everything here is deterministic: the batcher is driven with explicit
``now`` values, and the serve loop runs with an injected ``service_time``
model over a dummy executor, so no wall-clock or XLA timing leaks in.
"""
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.corpus import (
    make_arrivals,
    make_corpus,
    make_zipf_trace,
    stamp_arrivals,
)
from repro.serving import (
    DeadlineBatcher,
    GeoServer,
    LandlordCache,
    LRUCache,
    ShapeBucketedBatcher,
)
from repro.serving.batcher import PendingQuery


def _query(qid: int, d: int = 3, r: int = 1) -> PendingQuery:
    lo = np.full((r, 2), 0.1, np.float32)
    return PendingQuery(
        qid,
        np.arange(d, dtype=np.int32),
        np.concatenate([lo, lo + 0.1], axis=1),
        np.ones((r,), np.float32),
    )


class DummyExecutor:
    """Fixed results, one byte-counter; lets serve-loop tests skip jax."""

    top_k = 5

    def run(self, batch):
        B = int(batch.terms.shape[0])
        return alg.TopKResult(
            ids=np.zeros((B, 5), np.int32),
            scores=np.zeros((B, 5), np.float32),
            stats={"bytes_seq": np.ones(B)},
        )


# ---------------------------------------------------------------------------
# DeadlineBatcher (virtual clock)
# ---------------------------------------------------------------------------

def test_flush_on_deadline():
    b = DeadlineBatcher(max_batch=4, max_terms=8, max_rects=4, max_wait_s=0.01)
    assert b.add(_query(0), now=0.0) == []
    assert b.next_deadline() == pytest.approx(0.01)
    assert b.due(0.009) == []  # not ripe yet
    out = b.due(0.01)
    assert len(out) == 1 and out[0].qids == [0]
    assert b.next_deadline() is None  # nothing pending


def test_flush_on_full_wins_over_deadline():
    """A bucket that fills flushes immediately; its deadline timer dies."""
    b = DeadlineBatcher(max_batch=2, max_terms=8, max_rects=4, max_wait_s=10.0)
    assert b.add(_query(0), now=0.0) == []
    out = b.add(_query(1), now=1.0)  # fills → flush now, long before t=10
    assert len(out) == 1 and out[0].qids == [0, 1]
    assert b.next_deadline() is None
    assert b.due(100.0) == []


def test_due_returns_batches_in_deadline_order():
    b = DeadlineBatcher(max_batch=8, max_terms=8, max_rects=4, max_wait_s=0.01)
    b.add(_query(0, d=2, r=1), now=0.000)  # bucket (2,1) → deadline 0.010
    b.add(_query(1, d=7, r=3), now=0.004)  # bucket (8,4) → deadline 0.014
    # oldest-per-bucket rules: a second query doesn't reset bucket 1's timer
    b.add(_query(2, d=2, r=1), now=0.008)
    out = b.due(1.0)
    assert [raw.qids for raw in out] == [[0, 2], [1]]


def test_zero_wait_flushes_every_query_alone():
    b = DeadlineBatcher(max_batch=8, max_terms=8, max_rects=4, max_wait_s=0.0)
    b.add(_query(0), now=0.5)
    assert b.next_deadline() == pytest.approx(0.5)  # due the instant it lands
    out = b.due(0.5)
    assert len(out) == 1 and out[0].n_real == 1 and out[0].shape.batch == 1


def test_infinite_wait_reproduces_count_only_batcher():
    """max_wait=inf must be bit-identical to PR 1's ShapeBucketedBatcher."""
    rng = np.random.default_rng(0)
    queries = [
        _query(i, d=int(rng.integers(1, 9)), r=int(rng.integers(1, 5)))
        for i in range(200)
    ]
    count_only = ShapeBucketedBatcher(max_batch=8, max_terms=8, max_rects=4)
    deadline = DeadlineBatcher(max_batch=8, max_terms=8, max_rects=4)
    assert deadline.max_wait_s == float("inf")
    got, want = [], []
    for i, q in enumerate(queries):
        want.extend(count_only.add(q))
        assert deadline.next_deadline() is None
        got.extend(deadline.add(q, now=i * 0.001))
    want.extend(count_only.flush())
    got.extend(deadline.flush())
    assert [raw.qids for raw in got] == [raw.qids for raw in want]
    assert [raw.shape for raw in got] == [raw.shape for raw in want]
    assert deadline.pad_slots == count_only.pad_slots
    assert deadline.pad_elements == count_only.pad_elements


def test_clone_empty_preserves_deadline_config():
    b = DeadlineBatcher(max_batch=4, max_terms=8, max_rects=4, max_wait_s=0.25)
    b.add(_query(0), now=0.0)
    c = b.clone_empty()
    assert type(c) is DeadlineBatcher and c.max_wait_s == 0.25
    assert c.next_deadline() is None and c.real_slots == 0


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

def test_arrivals_closed_is_all_zero():
    assert (make_arrivals("closed", 100) == 0).all()


@pytest.mark.parametrize("kind", ["poisson", "bursty", "diurnal"])
def test_arrivals_are_sorted_and_roughly_at_rate(kind):
    t = make_arrivals(kind, 8000, rate_qps=200.0, seed=7, diurnal_period_s=2.0)
    assert t.shape == (8000,)
    assert (np.diff(t) >= 0).all()
    # mean rate within a loose factor (bursty/diurnal have heavy variance)
    achieved = len(t) / t[-1]
    assert 0.5 * 200.0 < achieved < 2.0 * 200.0, achieved


@pytest.mark.parametrize("kind", ["poisson", "bursty", "diurnal"])
def test_arrivals_deterministic_per_seed(kind):
    """Fixed seed → identical stamps across calls (guards the virtual-clock
    serving tests against nondeterministic traces); different seed differs."""
    a = make_arrivals(kind, 500, rate_qps=300.0, seed=42)
    b = make_arrivals(kind, 500, rate_qps=300.0, seed=42)
    np.testing.assert_array_equal(a, b)
    c = make_arrivals(kind, 500, rate_qps=300.0, seed=43)
    assert not np.array_equal(a, c)


def test_stamp_arrivals_deterministic_per_seed():
    corpus = make_corpus(n_docs=80, n_terms=40, seed=0)
    trace = make_zipf_trace(corpus, n_queries=40, pool_size=8, seed=1)
    s1 = stamp_arrivals(trace, "poisson", rate_qps=150.0, seed=9)
    s2 = stamp_arrivals(trace, "poisson", rate_qps=150.0, seed=9)
    assert [q.arrival_s for q in s1] == [q.arrival_s for q in s2]


def test_arrivals_validation():
    with pytest.raises(ValueError):
        make_arrivals("weibull", 10)
    with pytest.raises(ValueError):
        make_arrivals("poisson", 10, rate_qps=0.0)
    with pytest.raises(ValueError):
        make_arrivals("bursty", 10, burst_factor=20.0, on_frac=0.5)


def test_stamp_arrivals_preserves_queries():
    corpus = make_corpus(n_docs=100, n_terms=50, seed=0)
    trace = make_zipf_trace(corpus, n_queries=50, pool_size=8, seed=1)
    stamped = stamp_arrivals(trace, "poisson", rate_qps=100.0, seed=2)
    assert len(stamped) == len(trace)
    assert all(s.arrival_s >= 0 for s in stamped)
    assert all(
        np.array_equal(s.terms, q.terms) and np.array_equal(s.rects, q.rects)
        for s, q in zip(stamped, trace)
    )
    assert all(q.arrival_s == 0.0 for q in trace)  # originals untouched


# ---------------------------------------------------------------------------
# open-loop replay (virtual clock through the whole serve loop)
# ---------------------------------------------------------------------------

def _stamped_trace(n=300, rate=500.0):
    corpus = make_corpus(n_docs=200, n_terms=100, seed=0)
    trace = make_zipf_trace(corpus, n_queries=n, pool_size=32, seed=1)
    return stamp_arrivals(trace, "poisson", rate_qps=rate, seed=2)


def _open_server(max_wait_s, cache=None):
    return GeoServer(
        DummyExecutor(),
        cache=cache,
        batcher=DeadlineBatcher(
            max_batch=8, max_terms=8, max_rects=4, max_wait_s=max_wait_s
        ),
    )


def test_open_loop_latency_decomposition_sums_exactly():
    trace = _stamped_trace()
    srv = _open_server(5e-3, cache=LRUCache(64))
    rep = srv.run_trace(
        trace, warmup=False, arrival="poisson", slo_ms=50.0,
        service_time=lambda raw: 2e-3,
    )
    assert rep.n_queries == len(trace)
    assert len(rep.latencies_s) == len(trace)
    total = (
        np.asarray(rep.batch_wait_s)
        + np.asarray(rep.queue_wait_s)
        + np.asarray(rep.service_s)
    )
    np.testing.assert_allclose(np.asarray(rep.latencies_s), total, rtol=0, atol=1e-12)
    # every component is a real delay, never negative
    assert min(rep.batch_wait_s) >= 0
    assert min(rep.queue_wait_s) >= 0
    assert min(rep.service_s) >= 0
    assert 0.0 <= rep.slo_attainment <= 1.0


def test_open_loop_is_deterministic_under_virtual_clock():
    trace = _stamped_trace()
    reps = [
        _open_server(5e-3, cache=LRUCache(64)).run_trace(
            trace, warmup=False, arrival="poisson", slo_ms=50.0,
            service_time=lambda raw: 2e-3,
        )
        for _ in range(2)
    ]
    assert reps[0].latencies_s == reps[1].latencies_s
    assert reps[0].batch_wait_s == reps[1].batch_wait_s
    assert reps[0].n_batches == reps[1].n_batches


def test_open_loop_deadline_bounds_batch_wait():
    """No query waits in its bucket longer than max_wait (plus fill flushes)."""
    trace = _stamped_trace()
    rep = _open_server(3e-3).run_trace(
        trace, warmup=False, arrival="poisson", service_time=lambda raw: 1e-3
    )
    assert max(rep.batch_wait_s) <= 3e-3 + 1e-12
    # and a slower deadline trades longer batch-waits for fewer batches
    rep_slow = _open_server(50e-3).run_trace(
        trace, warmup=False, arrival="poisson", service_time=lambda raw: 1e-3
    )
    assert rep_slow.n_batches < rep.n_batches
    assert max(rep_slow.batch_wait_s) > 3e-3


def test_open_loop_requires_deadline_batcher():
    srv = GeoServer(
        DummyExecutor(),
        batcher=ShapeBucketedBatcher(max_batch=8, max_terms=8, max_rects=4),
    )
    with pytest.raises(ValueError, match="DeadlineBatcher"):
        srv.run_trace(_stamped_trace(n=4), warmup=False, arrival="poisson")


def test_open_loop_cache_fill_waits_for_virtual_completion():
    """A duplicate arriving while its twin is in flight misses; after the
    twin's virtual completion it hits."""
    import dataclasses

    corpus = make_corpus(n_docs=100, n_terms=50, seed=0)
    base = make_zipf_trace(corpus, n_queries=1, pool_size=1, seed=1)[0]
    trace = [
        dataclasses.replace(base, arrival_s=t) for t in (0.0, 0.001, 1.0)
    ]
    srv = _open_server(0.0, cache=LRUCache(16))  # zero wait: flush singletons
    rep = srv.run_trace(
        trace, warmup=False, arrival="poisson", service_time=lambda raw: 0.01
    )
    # q0 misses; q1 arrives at 1ms < q0's completion at 10ms → must miss too;
    # q2 arrives at 1s, long after completion → hits
    assert rep.cache_misses == 2
    assert rep.cache_hits == 1


def test_closed_inf_wait_matches_pr1_count_only_server():
    """Acceptance: --arrival closed --max-wait-ms inf reproduces PR 1 metrics."""
    corpus = make_corpus(n_docs=200, n_terms=100, seed=0)
    trace = make_zipf_trace(corpus, n_queries=250, pool_size=32, seed=1)
    old = GeoServer(
        DummyExecutor(),
        cache=LRUCache(64),
        batcher=ShapeBucketedBatcher(max_batch=8, max_terms=8, max_rects=4),
    ).run_trace(trace, warmup=False)
    new = GeoServer(
        DummyExecutor(),
        cache=LRUCache(64),
        batcher=DeadlineBatcher(max_batch=8, max_terms=8, max_rects=4),
    ).run_trace(trace, warmup=False, arrival="closed")
    assert new.hit_rate == old.hit_rate
    assert new.cache_hits == old.cache_hits
    assert new.pad_slots == old.pad_slots
    assert new.real_slots == old.real_slots
    assert new.padding_overhead == old.padding_overhead
    assert new.element_padding_overhead == old.element_padding_overhead
    assert new.shapes_used == old.shapes_used
    assert new.n_batches == old.n_batches


# ---------------------------------------------------------------------------
# Landlord size-aware admission
# ---------------------------------------------------------------------------

def test_landlord_byte_budget_evicts_below_count_capacity():
    c = LandlordCache(capacity=100, max_bytes=100.0)
    c.put("a", 1, cost=1.0, size=40.0)
    c.put("b", 2, cost=1.0, size=40.0)
    assert c.bytes_used == pytest.approx(80.0)
    c.put("c", 3, cost=1.0, size=40.0)  # 120 bytes > budget → evict to fit
    assert len(c) == 2 and c.bytes_used <= 100.0
    assert c.evictions == 1


def test_landlord_oversized_entry_rejected():
    c = LandlordCache(capacity=100, max_bytes=50.0)
    c.put("small", 1, cost=1.0, size=10.0)
    c.put("huge", 2, cost=100.0, size=500.0)  # larger than the whole budget
    assert "huge" not in c and "small" in c
    assert c.rejected == 1 and c.evictions == 0


def test_landlord_byte_budget_prefers_high_credit_density():
    """cost/size credit: a cheap-per-byte giant goes before pricey smalls."""
    c = LandlordCache(capacity=100, max_bytes=100.0)
    c.put("giant", 0, cost=1.0, size=90.0)  # credit 1/90
    c.put("small1", 1, cost=1.0, size=5.0)  # credit 1/5
    c.put("small2", 2, cost=1.0, size=50.0)  # over budget → evict giant
    assert "giant" not in c
    assert "small1" in c and "small2" in c


def test_landlord_fresh_clone_copies_budget():
    c = LandlordCache(capacity=7, max_bytes=123.0)
    c.put("a", 1)
    d = c.fresh_clone()
    assert d.capacity == 7 and d.max_bytes == 123.0 and len(d) == 0


def test_landlord_byte_accounting_exact_after_eviction_storms():
    """Property: ``bytes_used`` equals the integer sum of resident entry
    sizes after ANY interleaving of admissions, replacements, renewals and
    byte-pressure eviction storms.

    The accounting used to run on floats and reset itself to zero whenever
    the cache drained ("clear any float residue") — masking drift instead
    of preventing it.  Sizes are now whole bytes and the invariant is
    exact equality, not approx.
    """
    rng = np.random.default_rng(42)
    budget = 4096
    c = LandlordCache(capacity=64, max_bytes=budget)
    for i in range(3000):
        key = int(rng.integers(0, 160))
        op = rng.random()
        if op < 0.25:
            c.get(key)  # renewals must not perturb accounting
        else:
            # sizes up to ~budget/2 force frequent multi-entry storms;
            # occasional oversized entries exercise the rejection path
            size = int(rng.integers(1, budget // 2 if op < 0.9 else 2 * budget))
            c.put(key, i, cost=float(rng.random() * 10 + 1e-3), size=size)
        assert isinstance(c.bytes_used, int)
        assert c.bytes_used == sum(e[2] for e in c._data.values())
        assert c.bytes_used <= budget
    assert c.evictions > 100  # the storms actually happened


def test_serve_loop_fills_cache_with_payload_sizes():
    """The server passes result payload bytes as the Landlord entry size."""
    trace = _stamped_trace(n=100)
    cache = LandlordCache(capacity=1000)
    _open_server(5e-3, cache=cache).run_trace(
        trace, warmup=False, arrival="poisson", service_time=lambda raw: 1e-3
    )
    # DummyExecutor rows: 5 i32 ids + 5 f32 scores = 40 bytes per entry
    assert len(cache) > 0
    assert cache.bytes_used == pytest.approx(40.0 * len(cache))

"""Block-max pruned K-SWEEP: kernel/oracle equality, safety vs the
unpruned reference path, recall floors across the prune × fused grid,
streamed-vs-scored byte accounting, and the serving-layer threading."""
from dataclasses import replace

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GeoSearchEngine, QueryBudgets
from repro.core.distributed import HashPartitioner
from repro.core.spatial_index import block_metadata_np
from repro.corpus import make_corpus, make_uniform_trace, make_zipf_trace, pad_trace_batch
from repro.kernels.sweep_score.ops import sweep_score, sweep_score_pruned
from repro.kernels.sweep_score.ref import sweep_score_pruned_ref

INVALID = 2**31 - 1


def _store(rng, T):
    lo = rng.uniform(0, 0.9, (T, 2)).astype(np.float32)
    wh = rng.uniform(0.01, 0.08, (T, 2)).astype(np.float32)
    rects = np.concatenate([lo, lo + wh], axis=1).astype(np.float32)
    amps = rng.uniform(0, 1, T).astype(np.float32)
    return rects, amps


def _sweeps(rng, T, budget, k):
    ss = np.sort(rng.integers(0, T, k)).astype(np.int32)
    ee = np.minimum(ss + rng.integers(1, budget + 500, k), T).astype(np.int32)
    if k > 1:
        ss[k // 2] = INVALID
        ee[k // 2] = INVALID
    return ss, ee


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,budget,k,C,bs,floor", [
    (1024, 1024, 1, 256, 128, 0.0),
    (5000, 2048, 4, 1024, 256, 0.0),
    (5000, 2048, 4, 1024, 128, 0.05),
    (33000, 1024, 8, 4096, 512, 0.0),
    (2048, 2048, 3, 512, 1024, 0.01),
])
def test_pruned_kernel_matches_ref(T, budget, k, C, bs, floor):
    """The Pallas pruned kernel and the jnp oracle agree on scores AND on
    every per-block skip decision (same θ trajectory)."""
    rng = np.random.default_rng(T + budget + k + bs)
    rects, amps = _store(rng, T)
    bm, ba, bmass = block_metadata_np(rects, amps, bs)
    qr = jnp.asarray(
        np.array([[0.2, 0.2, 0.6, 0.6], [0.5, 0.5, 0.9, 0.9]], np.float32)
    )
    qa = jnp.ones((2,))
    ss, ee = _sweeps(rng, T, budget, k)
    args = (
        jnp.asarray(rects), jnp.asarray(amps),
        jnp.asarray(bm), jnp.asarray(ba), jnp.asarray(bmass),
        jnp.asarray(ss), jnp.asarray(ee), qr, qa,
    )
    got = sweep_score_pruned(*args, budget, C, bs, floor)
    want = sweep_score_pruned_ref(*args, budget, C, bs, floor)
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))  # valid
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))  # streamed
    assert int(got[3]) == int(want[3]) and int(got[4]) == int(want[4])
    np.testing.assert_allclose(
        np.asarray(got[0]), np.asarray(want[0]), rtol=1e-6, atol=1e-7
    )


def test_pruned_kernel_safety_property():
    """θ never overshoots: every candidate the exact top-C_eff selection
    would keep survives pruning with its unpruned score."""
    rng = np.random.default_rng(42)
    T, budget, k, C, bs = 8000, 2048, 6, 512, 128
    rects, amps = _store(rng, T)
    bm, ba, bmass = block_metadata_np(rects, amps, bs)
    qr = jnp.asarray(np.array([[0.3, 0.3, 0.7, 0.7]], np.float32))
    qa = jnp.ones((1,))
    for trial in range(5):
        ss, ee = _sweeps(np.random.default_rng(trial), T, budget, k)
        ps, pv, streamed, b_scored, b_active = sweep_score_pruned(
            jnp.asarray(rects), jnp.asarray(amps),
            jnp.asarray(bm), jnp.asarray(ba), jnp.asarray(bmass),
            jnp.asarray(ss), jnp.asarray(ee), qr, qa, budget, C, bs,
        )
        us, uv = sweep_score(
            jnp.asarray(rects), jnp.asarray(amps),
            jnp.asarray(ss), jnp.asarray(ee), qr, qa, budget,
        )
        us, uv = np.asarray(us).ravel(), np.asarray(uv).ravel()
        kept = (np.asarray(pv) & np.asarray(streamed)).ravel()
        c_eff = max(1, -(-C // 1024)) * 1024
        pos_scores = np.sort(us[uv & (us > 0)])[::-1]
        theta_cap = pos_scores[c_eff - 1] if len(pos_scores) >= c_eff else 0.0
        must_keep = uv & (us > theta_cap)
        assert (kept[must_keep]).all(), "pruning dropped a top-C candidate"
        # kept scores are the unpruned scores
        np.testing.assert_allclose(
            np.asarray(ps).ravel()[kept], us[kept], rtol=1e-6, atol=1e-7
        )
        assert int(b_scored) <= int(b_active)


# ---------------------------------------------------------------------------
# end-to-end safety + recall (prune × fused grid)
# ---------------------------------------------------------------------------

def _engine(corpus, C, sweep_budget, grid=32, **bud_kw):
    budgets = QueryBudgets(
        max_candidates=C, max_tiles=256, k_sweeps=8,
        sweep_budget=sweep_budget, top_k=10, **bud_kw,
    )
    return GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=grid, budgets=budgets,
    )


def _with_budgets(eng, **kw):
    """Fresh engine sharing the built index (its own compiled-fn cache)."""
    return GeoSearchEngine(
        index=eng.index, budgets=replace(eng.budgets, **kw), weights=eng.weights
    )


def _recall_vs(a, b):
    ai, bi = np.asarray(a.ids), np.asarray(b.ids)
    va = ai >= 0
    found = (
        (ai[:, :, None] == bi[:, None, :]) & va[:, :, None] & (bi[:, None, :] >= 0)
    ).any(-1)
    return found.sum() / max(va.sum(), 1)


@pytest.mark.parametrize("trace_kind", ["zipf", "uniform"])
def test_prune_safety_same_topk_as_unpruned(trace_kind):
    """With exact block bounds and the candidate buffer strictly larger
    than the whole window (C > k·budget, so θ provably stays 0 and only
    zero-bound blocks are skipped), pruned K-SWEEP returns EXACTLY the
    unpruned path's top-k on seeded zipf + uniform corpora.

    No carve-out: the historical ~1e-10 cumsum-residue leak (a doc with
    exactly zero footprint overlap slipping through ``require_geo`` on the
    unpruned path) is dead — candidate aggregation is a cumsum-free dedupe
    (``algorithms._sorted_dedupe``) and the final geo score is recomputed
    exactly from each doc's own footprint rows, so a zero-overlap doc
    scores exactly 0.0 on every path and the ``require_geo`` gate is
    exact (see ``ranking.combine_scores``)."""
    corpus = make_corpus(n_docs=900, n_terms=300, seed=17)
    if trace_kind == "zipf":
        trace = pad_trace_batch(
            make_zipf_trace(corpus, n_queries=48, pool_size=32, seed=18)
        )
    else:
        trace = pad_trace_batch(make_uniform_trace(corpus, n_queries=48, seed=18))
    eng = _engine(corpus, C=2 * 8 * 256, sweep_budget=256)
    un = eng.query(trace, "k_sweep")
    eng_p = _with_budgets(eng, prune=True)
    pr = eng_p.query(trace, "k_sweep")
    prf = eng_p.query(trace, "k_sweep", fused=True)
    np.testing.assert_array_equal(np.asarray(pr.ids), np.asarray(prf.ids))
    # pruned == unpruned, exactly — ids AND scores
    np.testing.assert_array_equal(np.asarray(un.ids), np.asarray(pr.ids))
    np.testing.assert_array_equal(np.asarray(un.scores), np.asarray(pr.scores))


@pytest.mark.parametrize("prune", [False, True])
@pytest.mark.parametrize("fused", [False, True])
def test_prune_recall_floor_vs_oracle(prune, fused):
    """recall@10 ≥ 0.95 vs the exact oracle across the prune × fused grid."""
    corpus = make_corpus(n_docs=600, n_terms=150, seed=3)
    eng = _engine(corpus, C=1024, sweep_budget=512, prune=prune)
    trace = pad_trace_batch(make_zipf_trace(corpus, n_queries=32, pool_size=32, seed=4))
    rec = eng.recall_at_k(trace, "k_sweep", fused=fused)
    assert rec >= 0.95, f"prune={prune} fused={fused} recall {rec}"


def test_prune_budget_degradation_graceful():
    """Tiny budgets with pruning must not crash or return invalid docs."""
    corpus = make_corpus(n_docs=300, n_terms=80, seed=5)
    budgets = QueryBudgets(
        max_candidates=16, max_tiles=8, k_sweeps=1, sweep_budget=32, top_k=5,
        prune=True, prune_eps=1e-3,
    )
    eng = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=16, budgets=budgets,
    )
    trace = pad_trace_batch(make_zipf_trace(corpus, n_queries=8, pool_size=8, seed=2))
    for fused in [False, True]:
        ids = np.asarray(eng.query(trace, "k_sweep", fused=fused).ids)
        assert ((ids >= -1) & (ids < 300)).all()


# ---------------------------------------------------------------------------
# stats: streamed vs scored accounting, probe savings (acceptance numbers)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_engine_and_trace():
    corpus = make_corpus(n_docs=1200, n_terms=400, seed=9)
    trace = pad_trace_batch(make_zipf_trace(corpus, n_queries=64, pool_size=48, seed=10))
    return corpus, trace


def test_pruned_stats_reduce_probes_and_bytes(smoke_engine_and_trace):
    """The acceptance bar: on the zipf smoke trace, pruning cuts n_probes
    and bytes_postings ≥ 2× at recall@10 ≥ 0.95 vs the unpruned path, with
    blocks actually skipped and bytes_spatial counting only streamed blocks."""
    corpus, trace = smoke_engine_and_trace
    eng = _engine(corpus, C=1024, sweep_budget=256)
    un = eng.query(trace, "k_sweep")
    pr = _with_budgets(eng, prune=True).query(trace, "k_sweep")

    def tot(r, k):
        return float(np.asarray(r.stats[k], np.float64).sum())

    assert _recall_vs(un, pr) >= 0.95
    assert tot(un, "n_probes") >= 2.0 * tot(pr, "n_probes")
    assert tot(un, "bytes_postings") >= 2.0 * tot(pr, "bytes_postings")
    assert tot(pr, "blocks_skipped") > 0
    assert tot(pr, "bytes_spatial") < tot(un, "bytes_spatial")
    assert tot(pr, "probes_saved") > 0
    # unpruned path reports no skips and charges the full streams
    assert tot(un, "blocks_skipped") == 0
    assert tot(un, "probes_saved") == 0


def test_early_termination_reports_streamed_vs_scored(smoke_engine_and_trace):
    """The lossy early-termination path still streams the full sweep budget
    (bytes_spatial unchanged) but now reports the scored subset and the
    probes it saved separately."""
    corpus, trace = smoke_engine_and_trace
    eng = _engine(corpus, C=256, sweep_budget=256)
    un = eng.query(trace, "k_sweep")
    et = _with_budgets(eng, early_termination=True).query(trace, "k_sweep")

    def tot(r, k):
        return float(np.asarray(r.stats[k], np.float64).sum())

    # ET pays the full stream...
    assert tot(et, "bytes_spatial") == tot(un, "bytes_spatial")
    # ...but aggregates (and probes) only the selected subset
    assert tot(et, "bytes_scored") < tot(un, "bytes_scored")
    assert tot(et, "bytes_scored") < tot(et, "bytes_spatial")
    assert tot(et, "probes_saved") > 0
    assert tot(et, "n_probes") < tot(un, "n_probes")
    # the unpruned reference aggregates everything it fetched
    assert tot(un, "bytes_scored") == float(
        np.asarray(un.stats["candidates"], np.float64).sum() * 24
    )


def test_prune_eps_floor_monotone(smoke_engine_and_trace):
    """Raising prune_eps only increases savings (probes monotone down)."""
    corpus, trace = smoke_engine_and_trace
    probes = []
    for eps in [0.0, 3e-3, 3e-2]:
        eng = _engine(corpus, C=1024, sweep_budget=256, prune=True, prune_eps=eps)
        res = eng.query(trace, "k_sweep")
        probes.append(float(np.asarray(res.stats["n_probes"], np.float64).sum()))
    assert probes[0] >= probes[1] >= probes[2]


# ---------------------------------------------------------------------------
# serving-layer threading
# ---------------------------------------------------------------------------

def test_sharded_executor_prune_matches_single():
    """A pruned ShardedExecutor(S=1, hash) reproduces the single-device
    pruned engine and reports the new counter keys."""
    from repro.serving import ShardedExecutor, SingleDeviceExecutor

    corpus = make_corpus(n_docs=400, n_terms=100, seed=11)
    budgets = QueryBudgets(
        max_candidates=512, max_tiles=64, k_sweeps=4, sweep_budget=128,
        top_k=5, prune=True,
    )
    eng = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=16, budgets=budgets,
    )
    single = SingleDeviceExecutor(eng, fused=True)
    sharded = ShardedExecutor.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, n_shards=1, partitioner=HashPartitioner(),
        grid=16, budgets=budgets, fused=True,
    )
    trace = pad_trace_batch(make_zipf_trace(corpus, n_queries=16, pool_size=8, seed=12))
    a = single.run(trace)
    b = sharded.run(trace)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    for key in ["blocks_skipped", "blocks_total", "probes_saved", "bytes_scored"]:
        np.testing.assert_allclose(
            float(np.asarray(a.stats[key], np.float64).sum()),
            float(np.asarray(b.stats[key], np.float64).sum()),
            rtol=1e-6, err_msg=key,
        )


def test_mesh_executor_prune_fused_matches_single():
    """The SPMD mesh executor runs the pruned fused kernel inside its
    shard_map step and agrees with the single-device engine; its in-step
    measured counters (psum over doc axes) match the host measurement
    exactly — including the pruning savings counters."""
    import jax
    from jax.sharding import Mesh

    from repro.serving import MeshExecutor, SingleDeviceExecutor

    corpus = make_corpus(n_docs=256, n_terms=64, seed=11)
    budgets = QueryBudgets(
        max_candidates=256, max_tiles=64, k_sweeps=4, sweep_budget=128,
        top_k=5, prune=True,
    )
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    meshx = MeshExecutor.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, mesh=mesh, partitioner=HashPartitioner(),
        grid=16, budgets=budgets, fused=True,
    )
    eng = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=16, budgets=budgets,
    )
    single = SingleDeviceExecutor(eng, fused=True)
    batch = pad_trace_batch(make_zipf_trace(corpus, n_queries=8, pool_size=8, seed=12))
    a = single.run(batch)
    b = meshx.run(batch)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    assert set(b.stats) == set(a.stats)
    # measured inside the step: every counter agrees exactly with the
    # host-side measurement (single shard, hash partition)
    for key in a.stats:
        np.testing.assert_allclose(
            float(np.asarray(b.stats[key], np.float64).sum()),
            float(np.asarray(a.stats[key], np.float64).sum()),
            rtol=1e-6, err_msg=key,
        )


# ---------------------------------------------------------------------------
# optional hypothesis fuzz
# ---------------------------------------------------------------------------

def test_pruned_safety_fuzz():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        bs=st.sampled_from([128, 256, 512]),
        C=st.sampled_from([128, 700, 2048]),
        floor=st.floats(0.0, 0.05),
    )
    def prop(seed, bs, C, floor):
        rng = np.random.default_rng(seed)
        T = int(rng.integers(1200, 6000))
        budget = int(rng.choice([512, 1024, 2000]))
        k = int(rng.integers(1, 5))
        rects, amps = _store(rng, T)
        bm, ba, bmass = block_metadata_np(rects, amps, bs)
        ss, ee = _sweeps(rng, T, budget, k)
        qr = jnp.asarray(rng.uniform(0, 0.7, (2, 2)).astype(np.float32))
        qr = jnp.concatenate([qr, qr + 0.3], axis=1)
        qa = jnp.ones((2,))
        ps, pv, streamed, _, _ = sweep_score_pruned(
            jnp.asarray(rects), jnp.asarray(amps),
            jnp.asarray(bm), jnp.asarray(ba), jnp.asarray(bmass),
            jnp.asarray(ss), jnp.asarray(ee), qr, qa, budget, C, bs, floor,
        )
        us, uv = sweep_score(
            jnp.asarray(rects), jnp.asarray(amps),
            jnp.asarray(ss), jnp.asarray(ee), qr, qa, budget,
        )
        us, uv = np.asarray(us).ravel(), np.asarray(uv).ravel()
        kept = (np.asarray(pv) & np.asarray(streamed)).ravel()
        c_eff = max(1, -(-C // 1024)) * 1024
        pos = np.sort(us[uv & (us > 0)])[::-1]
        theta_cap = max(pos[c_eff - 1] if len(pos) >= c_eff else 0.0, floor)
        must_keep = uv & (us > theta_cap)
        assert kept[must_keep].all()
        np.testing.assert_allclose(
            np.asarray(ps).ravel()[kept], us[kept], rtol=1e-6, atol=1e-7
        )

    prop()

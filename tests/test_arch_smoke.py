"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + finite values.
(The FULL configs are exercised via the dry-run only.)"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.data.graph import full_graph_batch, make_powerlaw_graph, molecule_batch
from repro.data.lm import LMDataConfig, lm_batch
from repro.data.recsys import bst_batch, ctr_batch, two_tower_batch
from repro.models import egnn as egnn_lib
from repro.models import recsys as rec_lib
from repro.models import transformer as tf_lib
from repro.train.loop import make_train_step
from repro.train.optimizer import OptimizerConfig, init_opt_state

LM_ARCHS = ["granite-moe-1b-a400m", "olmoe-1b-7b", "smollm-135m", "qwen1.5-0.5b", "qwen2.5-14b"]
REC_ARCHS = ["two-tower-retrieval", "dcn-v2", "autoint", "bst"]


def _train_one(loss_fn, params, batch):
    opt = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=4)
    step = make_train_step(loss_fn, opt)
    state = init_opt_state(opt, params)
    # host copies: params/state are donated into the step
    before = [np.asarray(x).copy() for x in jax.tree.leaves(params)]
    p2, s2, m = step(params, state, batch)
    assert np.isfinite(float(m["loss"]))
    # params actually changed
    delta = max(
        float(np.abs(a.astype(np.float32) - np.asarray(b, np.float32)).max())
        for a, b in zip(before, jax.tree.leaves(p2))
    )
    assert delta > 0
    return float(m["loss"])


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch):
    spec = get_arch(arch)
    cfg = dataclasses.replace(spec.smoke_config, compute_dtype=jnp.float32)
    params = cfg.init(jax.random.key(0))
    dc = LMDataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2)
    batch = lm_batch(dc, 0)
    logits, aux = jax.jit(lambda p, t: tf_lib.forward(cfg, p, t))(params, batch["tokens"])
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    if cfg.is_moe:
        assert float(aux) > 0  # load-balance loss active
    _train_one(lambda p, b: tf_lib.loss_fn(cfg, p, b), params, batch)


@pytest.mark.parametrize("arch", LM_ARCHS[:2] + ["smollm-135m"])
def test_lm_decode_smoke(arch):
    spec = get_arch(arch)
    cfg = dataclasses.replace(spec.smoke_config, compute_dtype=jnp.float32)
    params = cfg.init(jax.random.key(0))
    cache = tf_lib.make_cache(cfg, 2, 16)
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab)
    logits, cache = jax.jit(lambda p, t, c: tf_lib.prefill(cfg, p, t, c))(params, toks, cache)
    assert logits.shape == (2, cfg.padded_vocab)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = jax.jit(lambda p, c, t, pos: tf_lib.decode_step(cfg, p, c, t, pos))(
        params, cache, nxt, jnp.int32(8)
    )
    assert logits2.shape == (2, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits2).all())


def test_lm_sliding_window_variant():
    spec = get_arch("smollm-135m")
    cfg = dataclasses.replace(
        spec.smoke_config, compute_dtype=jnp.float32, attn_window=8, attn_chunk=8
    )
    params = cfg.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab)
    logits, _ = jax.jit(lambda p, t: tf_lib.forward(cfg, p, t))(params, toks)
    assert bool(jnp.isfinite(logits).all())


class TestEGNN:
    def test_full_graph(self):
        spec = get_arch("egnn")
        cfg = spec.smoke_config
        params = cfg.init(jax.random.key(0))
        g = make_powerlaw_graph(128, 512, cfg.d_feat, n_classes=cfg.n_classes, seed=0)
        batch = full_graph_batch(g, edge_multiple=8)
        _train_one(lambda p, b: egnn_lib.loss_fn(cfg, p, b), params, batch)

    def test_minibatch_sampled(self):
        from repro.data.graph import SampledShape, sample_subgraph

        spec = get_arch("egnn")
        cfg = spec.smoke_config
        params = cfg.init(jax.random.key(0))
        g = make_powerlaw_graph(512, 4096, cfg.d_feat, n_classes=cfg.n_classes, seed=1)
        sub = sample_subgraph(g, SampledShape(16, (4, 3)), seed=0, step=0)
        loss, m = jax.jit(lambda p, b: egnn_lib.loss_fn(cfg, p, b))(params, sub)
        assert np.isfinite(float(loss))

    def test_molecule(self):
        spec = get_arch("egnn")
        cfg = dataclasses.replace(spec.smoke_config, n_classes=0)
        params = cfg.init(jax.random.key(0))
        batch = molecule_batch(8, 10, 16, cfg.d_feat, seed=0)
        _train_one(lambda p, b: egnn_lib.loss_fn(cfg, p, b), params, batch)

    def test_equivariance(self):
        spec = get_arch("egnn")
        cfg = spec.smoke_config
        params = cfg.init(jax.random.key(0))
        g = make_powerlaw_graph(64, 256, cfg.d_feat, n_classes=cfg.n_classes, seed=2)
        batch = full_graph_batch(g, edge_multiple=8)
        h1, x1 = jax.jit(lambda p, b: egnn_lib.forward(cfg, p, b))(params, batch)
        th = 1.1
        R = jnp.array(
            [[np.cos(th), -np.sin(th), 0], [np.sin(th), np.cos(th), 0], [0, 0, 1.0]],
            jnp.float32,
        )
        t = jnp.array([0.5, -1.0, 2.0], jnp.float32)
        b2 = dict(batch)
        b2["coords"] = batch["coords"] @ R.T + t
        h2, x2 = jax.jit(lambda p, b: egnn_lib.forward(cfg, p, b))(params, b2)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-4)
        np.testing.assert_allclose(
            np.asarray(x1 @ R.T + t), np.asarray(x2), atol=2e-4
        )


@pytest.mark.parametrize("arch", REC_ARCHS)
def test_recsys_smoke(arch):
    spec = get_arch(arch)
    cfg = spec.smoke_config
    params = cfg.init(jax.random.key(0))
    B = 16
    name = type(cfg).__name__
    if name == "DCNv2Config":
        batch = ctr_batch(B, cfg.n_dense, cfg.vocab_sizes, 0, 0)
        loss_fn = lambda p, b: rec_lib.dcn_v2_loss(cfg, p, b)
        fwd = rec_lib.dcn_v2_forward
    elif name == "AutoIntConfig":
        batch = ctr_batch(B, 0, cfg.vocab_sizes, 0, 0)
        loss_fn = lambda p, b: rec_lib.autoint_loss(cfg, p, b)
        fwd = rec_lib.autoint_forward
    elif name == "BSTConfig":
        batch = bst_batch(B, cfg.n_items, cfg.seq_len, cfg.n_other_fields, cfg.field_vocab, 0, 0)
        loss_fn = lambda p, b: rec_lib.bst_loss(cfg, p, b)
        fwd = rec_lib.bst_forward
    else:
        batch = two_tower_batch(B, cfg.n_users, cfg.n_items, cfg.n_user_fields,
                                cfg.n_item_fields, cfg.field_vocab, cfg.hist_len, 0, 0)
        loss_fn = lambda p, b: rec_lib.two_tower_loss(cfg, p, b)
        fwd = None
    if fwd is not None:
        logit = jax.jit(lambda p, b: fwd(cfg, p, b))(params, {k: v for k, v in batch.items() if k != "label"} | {"label": batch["label"]})
        assert logit.shape == (B,)
        assert bool(jnp.isfinite(logit).all())
    _train_one(loss_fn, params, batch)


def test_two_tower_retrieval_topk():
    spec = get_arch("two-tower-retrieval")
    cfg = spec.smoke_config
    params = cfg.init(jax.random.key(0))
    batch = two_tower_batch(4, cfg.n_users, cfg.n_items, cfg.n_user_fields,
                            cfg.n_item_fields, cfg.field_vocab, cfg.hist_len, 0, 0)
    Nc = 256
    cand = jnp.arange(Nc, dtype=jnp.int32)
    cf = jnp.zeros((Nc, cfg.n_item_fields), jnp.int32)
    scores, idx = rec_lib.two_tower_score_candidates(cfg, params, batch, cand, cf, top_k=10)
    assert scores.shape == (4, 10) and idx.shape == (4, 10)
    assert (np.diff(np.asarray(scores), axis=1) <= 1e-6).all()


def test_geoweb_cell_lowers_and_guards_i32_overflow():
    """The geoweb serve cell traces on a smoke config, and the production
    config passes the int32-addressability guard on the production meshes
    — while a too-small mesh fails loudly instead of silently wrapping
    posting positions (the pre-existing production-scale overflow)."""
    from jax.sharding import Mesh

    from repro.launch.steps import I32_SAFE_MAX, build_cell

    spec = get_arch("geoweb")
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    smoke = dataclasses.replace(spec, config=spec.smoke_config)
    cell = build_cell(smoke, spec.shapes[0], mesh)
    assert cell.fn.lower(*cell.args) is not None  # full pipeline traces
    assert cell.model_flops > 0
    # production config: per-shard posting stores fit int32 index math on
    # both production meshes (16 and 32 doc shards)
    cfg = spec.config
    for S in (16, 32):
        assert cfg.n_docs // S * cfg.avg_postings_per_doc <= I32_SAFE_MAX
    # a single-shard mesh would overflow: the guard must trip at build
    with pytest.raises(ValueError, match="int32"):
        build_cell(spec, spec.shapes[0], mesh)


def test_registry_has_all_assigned():
    want = {
        "granite-moe-1b-a400m", "olmoe-1b-7b", "smollm-135m", "qwen1.5-0.5b",
        "qwen2.5-14b", "egnn", "two-tower-retrieval", "dcn-v2", "autoint",
        "bst", "geoweb",
    }
    assert want <= set(list_archs())


def test_assigned_cell_count():
    """40 assigned cells: 5 LM × 4 (3 run + 1 documented skip) + 4 GNN + 16 recsys."""
    n_run, n_skip, n_variant = 0, 0, 0
    for a in list_archs():
        spec = get_arch(a)
        if spec.family == "geoweb":
            continue
        for s in spec.shapes:
            if s.variant_of:
                n_variant += 1
            elif s.skip:
                n_skip += 1
            else:
                n_run += 1
    assert n_run + n_skip == 40, (n_run, n_skip)
    assert n_skip == 5  # long_500k × 5 full-attention LMs
    assert n_variant == 5  # sliding-window beyond-paper rows

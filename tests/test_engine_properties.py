"""Engine-level property tests (hypothesis): system invariants that must
hold for ANY corpus/query drawn from the generator."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import GeoSearchEngine, QueryBudgets
from repro.corpus import make_corpus, make_query_trace


def _engine(n_docs, seed, early=False):
    corpus = make_corpus(n_docs=n_docs, n_terms=60, seed=seed)
    eng = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=16,
        budgets=QueryBudgets(
            max_candidates=n_docs * 4, max_tiles=256, k_sweeps=4,
            sweep_budget=n_docs * 2, top_k=5, early_termination=early,
        ),
    )
    return corpus, eng


@settings(max_examples=8, deadline=None)
@given(st.integers(50, 150), st.integers(0, 10_000))
def test_full_budget_ksweep_matches_oracle(n_docs, seed):
    """With budgets ≥ corpus size, K-SWEEP is EXACT (recall 1.0)."""
    corpus, eng = _engine(n_docs, seed)
    trace = make_query_trace(corpus, n_queries=6, seed=seed + 1)
    assert eng.recall_at_k(trace, "k_sweep") == 1.0


@settings(max_examples=6, deadline=None)
@given(st.integers(50, 120), st.integers(0, 10_000))
def test_algorithms_agree_under_full_budgets(n_docs, seed):
    """All three algorithms return identical top-k when nothing truncates."""
    corpus, eng = _engine(n_docs, seed)
    trace = make_query_trace(corpus, n_queries=6, seed=seed + 2)
    ids = {}
    for algo in ["text_first", "geo_first", "k_sweep"]:
        ids[algo] = np.asarray(eng.query(trace, algo).ids)
    # compare as sets per query (ties may reorder equal scores)
    for b in range(6):
        sets = [set(x for x in ids[a][b] if x >= 0) for a in ids]
        assert sets[0] == sets[1] == sets[2], (b, sets)


@settings(max_examples=5, deadline=None)
@given(st.integers(60, 120), st.integers(0, 10_000))
def test_early_termination_only_loses_recall(n_docs, seed):
    """Early termination must only DROP results, never invent them: every
    returned doc must also be valid under the exact semantics."""
    corpus, eng = _engine(n_docs, seed, early=True)
    trace = make_query_trace(corpus, n_queries=4, seed=seed + 3)
    got = np.asarray(eng.query(trace, "k_sweep").ids)
    want = np.asarray(eng.oracle(trace, k=n_docs).ids)  # all valid results
    for b in range(4):
        valid = set(x for x in want[b] if x >= 0)
        returned = set(x for x in got[b] if x >= 0)
        assert returned <= valid, (b, returned - valid)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_compressed_index_subset_property(seed):
    """f16 index results are a subset of valid results (never invalid docs)."""
    corpus = make_corpus(n_docs=100, n_terms=60, seed=seed)
    budgets = QueryBudgets(max_candidates=400, max_tiles=256, k_sweeps=4,
                           sweep_budget=200, top_k=5)
    kw = dict(pagerank=corpus.pagerank, grid=16, budgets=budgets)
    eng32 = GeoSearchEngine.build(corpus.doc_terms, corpus.doc_rects,
                                  corpus.doc_amps, corpus.n_terms, **kw)
    eng16 = GeoSearchEngine.build(corpus.doc_terms, corpus.doc_rects,
                                  corpus.doc_amps, corpus.n_terms,
                                  compress=True, **kw)
    trace = make_query_trace(corpus, n_queries=4, seed=seed + 4)
    want = np.asarray(eng32.oracle(trace, k=100).ids)
    got = np.asarray(eng16.query(trace, "k_sweep").ids)
    for b in range(4):
        valid = set(x for x in want[b] if x >= 0)
        returned = set(x for x in got[b] if x >= 0)
        assert returned <= valid

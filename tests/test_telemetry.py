"""Telemetry property tests: spans, metrics, audit, validation (ISSUE 6).

The central contract: telemetry is an *observer*.  With a
:class:`~repro.obs.Telemetry` handle attached, the serving report is
bit-identical to a telemetry-off run, and every exported artifact is
internally consistent with that report:

(a) the tracer's per-query stage spans — in record order — equal the
    report's ``latencies_s`` / ``batch_wait_s`` / ``queue_wait_s`` /
    ``service_s`` lists **exactly** (``==``, not allclose), across the
    full workers x coalesce x deadline x arrival grid of
    ``tests/test_multiworker_serving.py``;
(b) the exported Chrome/Perfetto trace is well-formed: spans well-nested
    (LIFO b/e pairing per id), per-track X events non-overlapping and
    monotone, stage boundaries contiguous and ordered;
(c) the metrics histograms reconstruct p50/p99 to within one log bucket
    of the report's exact ``percentile_ms``;
(d) the planner audit joins measured counters for every executed plan
    and its relative-error summary is finite.
"""
import dataclasses
import math

import pytest

from repro.obs import (
    EventLog,
    Histogram,
    MetricsRegistry,
    PlannerAudit,
    SpanRecorder,
    Telemetry,
    validate_trace,
)
from repro.serving import DeadlineBatcher, GeoServer, LRUCache

from test_multiworker_serving import (
    RowExecutor,
    _check_decomposition,
    _pool_query,
    _random_trace,
    _service,
)


def _tel_server(workers=1, coalesce=False, max_wait_s=2e-3, cache=None,
                max_batch=8):
    tel = Telemetry()
    srv = GeoServer(
        RowExecutor(),
        cache=cache,
        batcher=DeadlineBatcher(
            max_batch=max_batch, max_terms=8, max_rects=4, max_wait_s=max_wait_s
        ),
        n_workers=workers,
        coalesce=coalesce,
        telemetry=tel,
    )
    return srv, tel


# ---------------------------------------------------------------------------
# (a) span sums == report decomposition, exactly, across the full grid
# ---------------------------------------------------------------------------

def _check_spans(rep, tel, n: int) -> None:
    tot, bw, qw, svc = tel.tracer.stage_sums()
    # exact equality: the tracer records the *same floats* the report does
    assert tot == rep.latencies_s
    assert bw == rep.batch_wait_s
    assert qw == rep.queue_wait_s
    assert svc == rep.service_s
    # stage boundaries are contiguous and ordered for every query
    for q in tel.tracer.queries:
        t_arr, t_flush, t_start, t_done = q.boundaries()
        assert t_arr <= t_flush <= t_start <= t_done
        if q.kind == "hit":
            assert q.batch_wait == q.queue_wait == 0.0
    # the exported trace is well-formed (nesting, monotone tracks, pairing)
    assert validate_trace(tel.tracer.to_trace_events()) == []
    # metrics agree with the report's counts
    reg = tel.metrics
    assert reg.counter("server.queries_total").value == n
    assert reg.histogram("server.latency_ms").n == n
    assert reg.counter("server.cache_hits_total").value == rep.cache_hits
    assert reg.counter("server.cache_misses_total").value == rep.cache_misses
    assert reg.counter("server.coalesced_total").value == rep.coalesced
    flushes = sum(
        reg.counter("batcher.flush_total", {"reason": r}).value
        for r in ("fill", "deadline", "drain")
    )
    assert flushes == rep.n_batches
    # batch spans: one per executed batch, sequential per worker
    assert len(tel.tracer.batches) == rep.n_batches
    by_worker: dict[int, float] = {}
    for b in tel.tracer.batches:
        assert b.flush_t <= b.start_t <= b.done_t
        assert b.start_t >= by_worker.get(b.worker, 0.0)
        by_worker[b.worker] = b.done_t


def test_spans_match_report_across_grid():
    for seed in range(4):
        kind = ("poisson", "bursty")[seed % 2]
        with_cache = seed % 3 == 0
        for workers in (1, 2, 4):
            for coalesce in (False, True):
                for wait in (0.0, 2e-3, float("inf")):
                    trace = _random_trace(seed, kind=kind)
                    cache = LRUCache(64) if with_cache else None
                    srv, tel = _tel_server(workers, coalesce, wait, cache)
                    rep = srv.run_trace(
                        trace, warmup=False, arrival=kind,
                        service_time=_service,
                    )
                    _check_decomposition(rep, len(trace))
                    _check_spans(rep, tel, len(trace))


def test_telemetry_is_pure_observer():
    """Attaching telemetry changes no serving outcome, bit for bit."""
    trace = _random_trace(7, n=250, pool=16, rate=1500.0)
    plain = GeoServer(
        RowExecutor(), cache=LRUCache(64),
        batcher=DeadlineBatcher(max_batch=8, max_terms=8, max_rects=4,
                                max_wait_s=2e-3),
        n_workers=2, coalesce=True,
    )
    rep0 = plain.run_trace(
        trace, warmup=False, arrival="poisson", service_time=_service
    )
    srv, _ = _tel_server(workers=2, coalesce=True, cache=LRUCache(64))
    rep1 = srv.run_trace(
        trace, warmup=False, arrival="poisson", service_time=_service
    )
    assert rep0.latencies_s == rep1.latencies_s
    assert rep0.batch_wait_s == rep1.batch_wait_s
    assert rep0.queue_wait_s == rep1.queue_wait_s
    assert rep0.service_s == rep1.service_s
    assert rep0.n_batches == rep1.n_batches
    assert rep0.cache_hits == rep1.cache_hits
    assert rep0.coalesced == rep1.coalesced


def test_closed_loop_spans_and_events():
    qs = [_pool_query(i, d=3, r=1) for i in range(6)]
    trace = qs + [dataclasses.replace(qs[0])]
    srv, tel = _tel_server(coalesce=True, max_wait_s=float("inf"),
                           max_batch=4, cache=LRUCache(16))
    rep = srv.run_trace(trace, warmup=False)
    _check_spans(rep, tel, len(trace))
    evs = {e["ev"] for e in tel.events.events}
    assert {"flush", "dispatch", "complete"} <= evs
    assert len(tel.events) > 0


# ---------------------------------------------------------------------------
# (c) histogram percentiles within one bucket of the exact report values
# ---------------------------------------------------------------------------

def test_histogram_percentiles_within_one_bucket_of_report():
    trace = _random_trace(11, n=400, rate=900.0)
    srv, tel = _tel_server(workers=2, max_wait_s=1e-3)
    rep = srv.run_trace(
        trace, warmup=False, arrival="poisson", service_time=_service
    )
    pairs = [
        ("server.latency_ms", rep.percentile_ms),
        ("server.batch_wait_ms", lambda p: rep.stage_percentile_ms("batch_wait", p)),
        ("server.queue_wait_ms", lambda p: rep.stage_percentile_ms("queue_wait", p)),
        ("server.service_ms", lambda p: rep.stage_percentile_ms("service", p)),
    ]
    for name, exact_ms in pairs:
        h = tel.metrics.histogram(name)
        assert h.n == len(trace)
        for p in (50, 90, 99):
            assert h.same_or_adjacent_bucket(h.quantile(p), exact_ms(p)), (
                name, p, h.quantile(p), exact_ms(p),
            )


def test_histogram_quantile_basics():
    h = Histogram()
    for v in [1.0, 2.0, 4.0, 8.0, 100.0]:
        h.observe(v)
    assert h.n == 5 and h.sum == 115.0
    assert h.same_or_adjacent_bucket(h.quantile(50), 4.0)
    assert h.same_or_adjacent_bucket(h.quantile(100), 100.0)
    assert math.isnan(Histogram().quantile(50))
    # bucket edges partition [lo, inf): index of an edge == right bucket
    for i in range(1, 40):
        lo, hi = h.bucket_bounds(i)
        assert h._index(lo * 1.0000001) == i
        assert lo < hi


def test_metrics_exports():
    reg = MetricsRegistry()
    reg.inc("server.queries_total", 3)
    reg.inc("batcher.flush_total", reason="fill")
    reg.set("batcher.pad_slots", 7)
    for v in (1.0, 2.0, 3.0):
        reg.observe("server.latency_ms", v)
    prom = reg.to_prometheus()
    assert "# TYPE server_queries_total counter" in prom
    assert "server_queries_total 3" in prom
    assert 'batcher_flush_total{reason="fill"} 1' in prom
    assert "batcher_pad_slots 7" in prom
    assert "server_latency_ms_count 3" in prom
    assert 'le="+Inf"' in prom
    js = reg.to_json()
    assert js["counters"]["server.queries_total"] == 3
    h = js["histograms"]["server.latency_ms"]
    assert h["count"] == 3 and h["sum"] == 6.0
    assert sum(b[2] for b in h["buckets"]) == 3


# ---------------------------------------------------------------------------
# (d) planner audit on a real auto engine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def auto_engine():
    from repro.core import GeoSearchEngine, QueryBudgets
    from repro.corpus import make_corpus

    corpus = make_corpus(600, 300, seed=5)
    budgets = QueryBudgets(
        max_candidates=512, max_tiles=256, k_sweeps=4,
        sweep_budget=256, top_k=5,
    )
    eng = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=32, m_intervals=4, budgets=budgets,
    )
    return corpus, eng


def test_audit_joins_every_executed_plan(auto_engine):
    from repro.corpus import make_zipf_trace, stamp_arrivals
    from repro.serving import SingleDeviceExecutor

    corpus, eng = auto_engine
    trace = stamp_arrivals(
        make_zipf_trace(corpus, n_queries=24, pool_size=12, seed=3),
        "poisson", rate_qps=800.0, seed=4,
    )
    tel = Telemetry()
    srv = GeoServer(
        SingleDeviceExecutor(eng, "auto"),
        cache=None,
        batcher=DeadlineBatcher(max_batch=8, max_terms=16, max_rects=4,
                                max_wait_s=2e-3),
        telemetry=tel,
    )
    srv.run_trace(trace, warmup=False, arrival="poisson")
    audit = tel.audit
    assert len(audit.records) > 0
    assert len(audit.joined) == len(audit.records)  # every plan joined
    for rec in audit.records:
        assert rec.chosen in rec.candidates
        assert rec.measured is not None
        errs = rec.errors()
        assert set(errs) == {"n_probes", "bytes_postings", "bytes_spatial"}
        assert all(e >= 0 and math.isfinite(e) for e in errs.values())
    summary = audit.error_summary()
    assert summary and all(math.isfinite(v) for v in summary.values())
    # the engine-side metrics got populated through the same handle
    assert tel.metrics.counter("planner.tp_span_probe").value > 0
    assert tel.metrics.counter("engine.compiled_fns_total").value > 0


# ---------------------------------------------------------------------------
# (b) trace validation: malformed traces are rejected
# ---------------------------------------------------------------------------

def test_validate_trace_rejects_malformed():
    ok = {"traceEvents": [
        {"name": "q", "ph": "b", "pid": 1, "tid": 1, "ts": 0, "cat": "c",
         "id": 1},
        {"name": "q", "ph": "e", "pid": 1, "tid": 1, "ts": 5, "cat": "c",
         "id": 1},
        {"name": "x", "ph": "X", "pid": 1, "tid": 2, "ts": 0, "dur": 3},
    ]}
    assert validate_trace(ok) == []
    assert validate_trace({"nope": []})  # missing traceEvents
    # unclosed async span
    assert validate_trace({"traceEvents": [
        {"name": "q", "ph": "b", "pid": 1, "tid": 1, "ts": 0, "cat": "c",
         "id": 1},
    ]})
    # mismatched b/e name
    assert validate_trace({"traceEvents": [
        {"name": "a", "ph": "b", "pid": 1, "tid": 1, "ts": 0, "cat": "c",
         "id": 1},
        {"name": "b", "ph": "e", "pid": 1, "tid": 1, "ts": 1, "cat": "c",
         "id": 1},
    ]})
    # negative dur
    assert validate_trace({"traceEvents": [
        {"name": "x", "ph": "X", "pid": 1, "tid": 1, "ts": 0, "dur": -1},
    ]})
    # non-monotone X events on one track
    assert validate_trace({"traceEvents": [
        {"name": "x", "ph": "X", "pid": 1, "tid": 1, "ts": 10, "dur": 1},
        {"name": "y", "ph": "X", "pid": 1, "tid": 1, "ts": 0, "dur": 1},
    ]})


def test_span_recorder_trace_round_trip(tmp_path):
    rec = SpanRecorder()
    rec.annotate(5, plan_algo="k_sweep")
    rec.query(5, 0, "executed", "ksweep", 0.0, 1e-3, 4e-4, 1e-4, 5e-4)
    rec.query(-1, 1, "hit", None, 2e-3, 1e-6, 0.0, 0.0, 1e-6)
    rec.batch(0, 4e-4, 5e-4, 1e-3, "ksweep", 1, (8, 8, 4))
    rec.span("shard 0", "query[ksweep]", 0.001, 0.002, {"rows": 8})
    trace = rec.to_trace_events()
    assert validate_trace(trace) == []
    names = {e["name"] for e in trace["traceEvents"]}
    assert {"query", "batch_wait", "queue_wait", "service", "lookup",
            "batch[ksweep]", "query[ksweep]"} <= names
    # staged args landed on the query span
    q = next(e for e in trace["traceEvents"]
             if e["name"] == "query" and e["ph"] == "b")
    assert q["args"]["plan_algo"] == "k_sweep"
    p = tmp_path / "trace.json"
    rec.write(str(p))
    import json

    assert validate_trace(json.loads(p.read_text())) == []


# ---------------------------------------------------------------------------
# satellite 2: empty-stage percentiles are NaN, not 0.0
# ---------------------------------------------------------------------------

def test_empty_percentiles_are_nan_and_summary_omits_them():
    srv = GeoServer(RowExecutor(), cache=LRUCache(4),
                    batcher=DeadlineBatcher(max_batch=4, max_terms=8,
                                            max_rects=4, max_wait_s=0.0))
    q = _pool_query(0, d=3, r=1)
    rep = srv.run_trace([q, dataclasses.replace(q)], warmup=False)
    assert rep.cache_hits == 1
    fresh = type(rep)()
    assert math.isnan(fresh.stage_percentile_ms("batch_wait", 99))
    assert math.isnan(fresh.plan_percentile_ms("ksweep", 99))
    assert math.isnan(rep.plan_percentile_ms("no_such_plan", 50))
    # summary never renders a NaN
    assert "nan" not in fresh.summary().lower()
    assert "nan" not in rep.summary().lower()


# ---------------------------------------------------------------------------
# event log + audit unit behavior
# ---------------------------------------------------------------------------

def test_event_log_and_audit_units(tmp_path):
    log = EventLog()
    log.emit(0.1, "flush", reason="fill", n_real=4)
    log.emit(0.2, "evict", n=2)
    assert len(log) == 2
    p = tmp_path / "events.jsonl"
    log.to_jsonl(str(p))
    lines = p.read_text().splitlines()
    assert len(lines) == 2 and '"ev": "flush"' in lines[0]

    audit = PlannerAudit()
    audit.record(
        qid=1, idx=0, features={"df_min": 3.0},
        candidates={"ksweep": {"algorithm": "k_sweep", "n_probes": 10.0,
                               "bytes_postings": 100.0,
                               "bytes_spatial": 50.0, "cost": 1.0}},
        chosen="ksweep", t_plan=0.0,
    )
    assert audit.joined == []
    audit.join(1, {"n_probes": 20.0, "bytes_postings": 100.0,
                   "bytes_spatial": 0.0})
    assert len(audit.joined) == 1
    errs = audit.records[0].errors()
    assert errs["n_probes"] == pytest.approx(0.5)
    assert errs["bytes_postings"] == 0.0
    assert errs["bytes_spatial"] == pytest.approx(50.0)  # denom floor 1
    summary = audit.error_summary()
    assert summary[("k_sweep", "n_probes")] == pytest.approx(0.5)
    out = tmp_path / "audit.jsonl"
    audit.to_jsonl(str(out))
    assert len(out.read_text().splitlines()) == 1

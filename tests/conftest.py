import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def random_rects(rng, n):
    """n random well-formed rects in the unit square, (n, 4) f32."""
    lo = rng.uniform(0, 0.9, (n, 2))
    hi = lo + rng.uniform(0.01, 0.1, (n, 2))
    return np.concatenate([lo, np.minimum(hi, 1.0)], axis=1).astype(np.float32)

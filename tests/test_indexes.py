"""Text & spatial index construction + query-time primitive tests."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import spatial_index as S
from repro.core import text_index as T


def small_index():
    docs = [
        np.array([0, 1, 1, 2], np.int32),
        np.array([1, 3], np.int32),
        np.array([0, 2, 2, 2], np.int32),
        np.array([3, 3, 1], np.int32),
    ]
    return T.build_text_index_np(docs, n_terms=4, n_bitmap_terms=2), docs


class TestTextIndex:
    def test_postings_sorted_and_complete(self):
        idx, docs = small_index()
        offs = np.asarray(idx.offsets)
        posts = np.asarray(idx.postings)
        for w in range(4):
            sl = posts[offs[w] : offs[w + 1]]
            assert (np.diff(sl) > 0).all()  # strictly ascending (unique docs)
            want = sorted(d for d, terms in enumerate(docs) if w in terms)
            assert list(sl) == want

    def test_probe_membership(self):
        idx, docs = small_index()
        for w in range(4):
            member, imp = T.probe_term(idx, jnp.int32(w), jnp.arange(4, dtype=jnp.int32))
            for d in range(4):
                want = w in docs[d]
                assert bool(member[d]) == want, (w, d)
                if want:
                    assert float(imp[d]) > 0

    def test_conjunction_equals_brute_force(self):
        idx, docs = small_index()
        terms = jnp.array([1, 2, -1, -1], jnp.int32)
        cand, valid, score = T.conjunction_candidates(idx, terms, 16)
        got = sorted(int(c) for c, v in zip(cand, valid) if v)
        want = sorted(d for d, t in enumerate(docs) if 1 in t and 2 in t)
        assert got == want

    def test_conjunction_empty_query(self):
        idx, _ = small_index()
        terms = jnp.array([-1, -1, -1, -1], jnp.int32)
        _, valid, _ = T.conjunction_candidates(idx, terms, 16)
        assert not bool(valid.any())

    def test_impacts_quantize(self):
        # one compression entry point: quantization happens inside the
        # builder (pre-metadata, so blk_max_impact bounds the stored values)
        docs = small_index()[1]
        q = T.build_text_index_np(
            docs, n_terms=4, n_bitmap_terms=2, impact_dtype=jnp.float16
        )
        idx, _ = small_index()
        assert q.impacts.dtype == jnp.float16
        np.testing.assert_allclose(
            np.asarray(q.impacts, np.float32), np.asarray(idx.impacts), rtol=2e-3
        )
        # deprecated shim still works and keeps the pruning bounds fresh:
        # quantize-after-build lands on the same stored values AND the same
        # refreshed block-max metadata as the builder's impact_dtype path
        s = T.quantize_impacts(idx, jnp.float16)
        assert s.impacts.dtype == jnp.float16
        np.testing.assert_array_equal(
            np.asarray(s.impacts), np.asarray(q.impacts)
        )
        np.testing.assert_array_equal(
            np.asarray(s.blk_max_impact), np.asarray(q.blk_max_impact)
        )

    def test_bitmaps_match_postings(self):
        idx, docs = small_index()
        bm = np.asarray(idx.bitmaps)
        ids = np.asarray(idx.bitmap_term_ids)
        for row, w in enumerate(ids):
            for d in range(4):
                bit = (bm[row, d // 32] >> (d % 32)) & 1
                assert bool(bit) == (w in docs[d])

    @settings(max_examples=20, deadline=None)
    @given(st.integers(2, 40), st.integers(2, 8), st.integers(1, 97))
    def test_property_conjunction_random(self, n_docs, n_terms, seed):
        rng = np.random.default_rng(seed)
        docs = [
            rng.integers(0, n_terms, rng.integers(1, 10)).astype(np.int32)
            for _ in range(n_docs)
        ]
        idx = T.build_text_index_np(docs, n_terms)
        t = rng.integers(0, n_terms, 2)
        terms = jnp.array([t[0], t[1], -1, -1], jnp.int32)
        cand, valid, _ = T.conjunction_candidates(idx, terms, n_docs + 8)
        got = sorted(set(int(c) for c, v in zip(cand, valid) if v))
        want = sorted(
            d for d, dt in enumerate(docs) if t[0] in dt and t[1] in dt
        )
        assert got == want


def small_spatial(n=40, seed=0, grid=8, m=2):
    rng = np.random.default_rng(seed)
    R = 3
    rects = np.zeros((n, R, 4), np.float32)
    rects[:, :, 0] = 1.0
    rects[:, :, 1] = 1.0
    amps = np.zeros((n, R), np.float32)
    for i in range(n):
        k = rng.integers(1, R + 1)
        for j in range(k):
            lo = rng.uniform(0, 0.85, 2)
            hi = lo + rng.uniform(0.02, 0.15, 2)
            rects[i, j] = [lo[0], lo[1], min(hi[0], 1), min(hi[1], 1)]
            amps[i, j] = rng.uniform(0.2, 1.0)
    return S.build_spatial_index_np(rects, amps, grid=grid, m_intervals=m), rects, amps


class TestSpatialIndex:
    def test_morton_sorted(self):
        idx, _, _ = small_spatial()
        from repro.core import geometry as G

        cx = np.asarray((idx.tp_rects[:, 0] + idx.tp_rects[:, 2]) / 2)
        cy = np.asarray((idx.tp_rects[:, 1] + idx.tp_rects[:, 3]) / 2)
        fine = 1 << 15
        codes = G.morton_encode_np(
            np.clip(cx * fine, 0, fine - 1).astype(np.uint32),
            np.clip(cy * fine, 0, fine - 1).astype(np.uint32),
        )
        assert (np.diff(codes) >= 0).all()

    def test_tile_intervals_cover_all_toeprints(self):
        """Every toe print must be covered by the intervals of every tile it
        intersects (completeness of the grid structure)."""
        idx, _, _ = small_spatial()
        from repro.core import geometry as G

        starts = np.asarray(idx.tile_starts)
        ends = np.asarray(idx.tile_ends)
        rects = np.asarray(idx.tp_rects)
        grid = idx.grid
        eps = 0.5 / grid * 1e-3
        for t in range(idx.n_toeprints):
            x0, y0, x1, y1 = rects[t]
            tx0 = int(np.clip(np.floor(x0 * grid), 0, grid - 1))
            ty0 = int(np.clip(np.floor(y0 * grid), 0, grid - 1))
            tx1 = int(np.clip(np.floor((x1 - eps) * grid), 0, grid - 1))
            ty1 = int(np.clip(np.floor((y1 - eps) * grid), 0, grid - 1))
            for ty in range(ty0, ty1 + 1):
                for tx in range(tx0, tx1 + 1):
                    tile = ty * grid + tx
                    covered = any(
                        starts[tile, j] <= t < ends[tile, j]
                        for j in range(idx.m_intervals)
                        if starts[tile, j] != S.INVALID
                    )
                    assert covered, (t, tile)

    def test_coalesce_k_sweeps_covers_intervals(self):
        starts = jnp.array([5, 100, 7, S.INVALID, 102], jnp.int32)
        ends = jnp.array([9, 105, 12, S.INVALID, 110], jnp.int32)
        s, e = S.coalesce_k_sweeps(starts, ends, k=2)
        s, e = np.asarray(s), np.asarray(e)
        # two sweeps: [5,12) and [100,110)
        got = sorted((int(a), int(b)) for a, b in zip(s, e) if a != S.INVALID)
        assert got == [(5, 12), (100, 110)]

    def test_coalesce_k1_single_sweep(self):
        starts = jnp.array([5, 100, 7], jnp.int32)
        ends = jnp.array([9, 105, 12], jnp.int32)
        s, e = S.coalesce_k_sweeps(starts, ends, k=1)
        got = [(int(a), int(b)) for a, b in zip(np.asarray(s), np.asarray(e)) if a != S.INVALID]
        assert got == [(5, 105)]

    def test_coalesce_all_invalid(self):
        starts = jnp.full((4,), S.INVALID, jnp.int32)
        s, e = S.coalesce_k_sweeps(starts, starts, k=3)
        assert (np.asarray(s) == S.INVALID).all()

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 500), st.integers(1, 30)), min_size=1, max_size=12),
           st.integers(1, 6))
    def test_property_sweeps_cover_and_disjoint(self, ivs, k):
        starts = jnp.array([a for a, _ in ivs], jnp.int32)
        ends = jnp.array([a + w for a, w in ivs], jnp.int32)
        s, e = S.coalesce_k_sweeps(starts, ends, k)
        s, e = np.asarray(s), np.asarray(e)
        sw = sorted((a, b) for a, b in zip(s, e) if a != S.INVALID)
        assert len(sw) <= k
        # coverage: every interval point set within some sweep
        for a, w in ivs:
            assert any(sa <= a and a + w <= sb for sa, sb in sw), (a, w, sw)
        # disjoint & sorted
        for (a1, b1), (a2, b2) in zip(sw, sw[1:]):
            assert b1 <= a2

    def test_fetch_sweeps_masks(self):
        idx, _, _ = small_spatial()
        s = jnp.array([0, S.INVALID], jnp.int32)
        e = jnp.array([5, S.INVALID], jnp.int32)
        rects, amps, docs, ok = S.fetch_sweeps(idx, s, e, sweep_budget=8)
        assert int(ok.sum()) == 5
        assert ok.shape == (16,)

"""Multi-device tests (8 fake CPU devices via a subprocess — the main test
process must keep seeing 1 device)."""
import json
import os
import subprocess
import sys
import textwrap


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_subprocess(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_distributed_serve_matches_oracle():
    r = run_in_subprocess(textwrap.dedent("""
        import json, numpy as np, jax
        from repro.corpus import make_corpus, make_query_trace
        from repro.core import GeoSearchEngine, QueryBudgets
        from repro.core.distributed import (
            MortonPartitioner, shard_corpus_np, make_serve_fn,
        )

        corpus = make_corpus(n_docs=512, n_terms=100, seed=0)
        budgets = QueryBudgets(max_candidates=512, max_tiles=256, k_sweeps=4,
                               sweep_budget=256, top_k=10)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        sharded = shard_corpus_np(corpus.doc_terms, corpus.doc_rects,
                                  corpus.doc_amps, corpus.pagerank,
                                  corpus.n_terms, 4, MortonPartitioner(),
                                  grid=32)
        serve = make_serve_fn(mesh, budgets, doc_axes=("data",), grid=32,
                              n_terms=corpus.n_terms)
        q = make_query_trace(corpus, n_queries=16, seed=1)
        with mesh:
            ids, scores = serve(sharded, q)
        eng = GeoSearchEngine.build(corpus.doc_terms, corpus.doc_rects,
                                    corpus.doc_amps, corpus.n_terms,
                                    pagerank=corpus.pagerank, grid=32,
                                    budgets=budgets)
        want = eng.oracle(q)
        w = np.asarray(want.ids); g = np.asarray(ids)
        hits = sum(len(set(w[b][w[b]>=0]) & set(g[b][g[b]>=0])) for b in range(16))
        tot = int(sum((w[b]>=0).sum() for b in range(16)))
        print(json.dumps({"recall": hits/max(tot,1), "shape": list(g.shape)}))
    """))
    assert r["recall"] >= 0.9
    assert r["shape"] == [16, 10]


def test_distributed_lm_train_step_matches_single_device():
    """SPMD data+tensor-parallel train step must be numerically close to the
    single-device step (same init, same batch)."""
    r = run_in_subprocess(textwrap.dedent("""
        import json, numpy as np, jax, jax.numpy as jnp
        from repro.models.transformer import TransformerConfig, loss_fn
        from repro.train.loop import make_train_step
        from repro.train.optimizer import OptimizerConfig, init_opt_state
        from repro.sharding.specs import use_sharding

        cfg = TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                                d_ff=128, vocab=256, attn_chunk=16,
                                compute_dtype=jnp.float32)
        opt = OptimizerConfig(lr=1e-3, warmup_steps=1)
        params = cfg.init(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (8, 32), 0, 256)
        batch = {"tokens": toks, "labels": toks}

        # single device
        step1 = make_train_step(lambda p, b: loss_fn(cfg, p, b), opt, donate=False)
        p1, _, m1 = step1(params, init_opt_state(opt, params), batch)

        # 4x2 mesh
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with use_sharding(mesh), mesh:
            stepN = make_train_step(lambda p, b: loss_fn(cfg, p, b), opt, donate=False)
            pN, _, mN = stepN(params, init_opt_state(opt, params), batch)
        dl = abs(float(m1["loss"]) - float(mN["loss"]))
        dw = max(float(jnp.abs(a - b).max())
                 for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(pN)))
        print(json.dumps({"dloss": dl, "dparams": dw}))
    """))
    assert r["dloss"] < 1e-4
    assert r["dparams"] < 1e-4


def test_compressed_psum_matches_mean():
    """int8 compressed gradient all-reduce ≈ exact mean across shards."""
    r = run_in_subprocess(textwrap.dedent("""
        import json, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.train.compression import psum_compressed

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(0, 1, (8, 512)).astype(np.float32))

        def body(g):
            g = g[0]
            mean, err = psum_compressed({"g": g}, {"g": jnp.zeros_like(g)}, ("data",))
            return mean["g"][None], err["g"][None]

        f = shard_map(body, mesh=mesh, in_specs=P("data"),
                      out_specs=(P("data"), P("data")), check_rep=False)
        with mesh:
            mean, err = f(g)
        want = np.asarray(g).mean(axis=0)
        got = np.asarray(mean)[0]
        rel = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
        print(json.dumps({"rel_err": float(rel)}))
    """))
    assert r["rel_err"] < 0.05  # int8 grid error, corrected over steps by EF


def test_zero1_moment_sharding():
    """ZeRO-1: optimizer moments are sharded over data; params replicated."""
    r = run_in_subprocess(textwrap.dedent("""
        import json, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train.optimizer import OptimizerConfig, zero1_sharding

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        spec = P(None, "model")
        sh = zero1_sharding(mesh, spec, (64, 32))
        print(json.dumps({"spec": str(sh.spec)}))
    """))
    assert "data" in r["spec"] and "model" in r["spec"]


def test_mesh_executor_serving_stack():
    """The serving stack (cache + batcher) over the shard_map MeshExecutor:
    with full budgets, mesh-served results must match the exact oracle."""
    r = run_in_subprocess(textwrap.dedent("""
        import json, numpy as np, jax
        from repro.corpus import make_corpus, make_query_trace, make_zipf_trace
        from repro.core import GeoSearchEngine, QueryBudgets
        from repro.serving import (
            GeoServer, LRUCache, MeshExecutor, ShapeBucketedBatcher,
        )

        corpus = make_corpus(n_docs=512, n_terms=100, seed=0)
        budgets = QueryBudgets(max_candidates=1024, max_tiles=2048, k_sweeps=8,
                               sweep_budget=1024, top_k=10)
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        from repro.core.distributed import MortonPartitioner
        mx = MeshExecutor.build(
            corpus.doc_terms, corpus.doc_rects, corpus.doc_amps,
            corpus.n_terms, pagerank=corpus.pagerank, mesh=mesh,
            partitioner=MortonPartitioner(), grid=32, budgets=budgets)
        eng = GeoSearchEngine.build(
            corpus.doc_terms, corpus.doc_rects, corpus.doc_amps,
            corpus.n_terms, pagerank=corpus.pagerank, grid=32,
            budgets=budgets)
        q = make_query_trace(corpus, n_queries=16, seed=1)
        got = mx.run(q)
        want = eng.oracle(q)  # exact ground truth
        g, w = np.asarray(got.ids), np.asarray(want.ids)
        hits = sum(len(set(w[b][w[b]>=0]) & set(g[b][g[b]>=0]))
                   for b in range(16))
        tot = int(sum((w[b]>=0).sum() for b in range(16)))

        # and the full serve loop on top of the mesh executor
        server = GeoServer(mx, cache=LRUCache(256),
                           batcher=ShapeBucketedBatcher(max_batch=8))
        rep = server.run_trace(make_zipf_trace(corpus, n_queries=64,
                                               pool_size=16, seed=2))
        print(json.dumps({"recall": hits/max(tot,1),
                          "served": rep.n_queries,
                          "hit_rate": rep.hit_rate}))
    """))
    assert r["recall"] >= 0.99
    assert r["served"] == 64
    assert r["hit_rate"] >= 0.30

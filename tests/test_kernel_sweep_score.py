"""Fused sweep-fetch+score Pallas kernel vs oracle (shape/dtype sweeps)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.sweep_score.ops import sweep_score
from repro.kernels.sweep_score.ref import sweep_score_ref

INVALID = 2**31 - 1


def _store(rng, T):
    lo = rng.uniform(0, 0.9, (T, 2)).astype(np.float32)
    rects = jnp.asarray(np.concatenate([lo, lo + 0.05], axis=1))
    amps = jnp.asarray(rng.uniform(0, 1, T).astype(np.float32))
    return rects, amps


@pytest.mark.parametrize("T,budget,k", [
    (1024, 1024, 1), (5000, 2048, 4), (33000, 1024, 8), (2048, 2048, 3),
])
def test_sweep_score_matches_ref(T, budget, k):
    rng = np.random.default_rng(T + budget + k)
    rects, amps = _store(rng, T)
    qr = jnp.asarray(np.array([[0.2, 0.2, 0.6, 0.6], [0.5, 0.5, 0.9, 0.9]], np.float32))
    qa = jnp.ones((2,))
    ss = np.sort(rng.integers(0, T, k)).astype(np.int32)
    ee = np.minimum(ss + rng.integers(1, budget + 500, k), T).astype(np.int32)
    if k > 1:
        ss[k // 2] = INVALID
        ee[k // 2] = INVALID
    got_s, got_v = sweep_score(rects, amps, jnp.asarray(ss), jnp.asarray(ee), qr, qa, budget)
    want_s, want_v = sweep_score_ref(rects, amps, jnp.asarray(ss), jnp.asarray(ee), qr, qa, budget)
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s), rtol=1e-6, atol=1e-7)


def test_sweep_score_f16_store():
    """Kernel accepts the lossy-compressed (f16) store."""
    rng = np.random.default_rng(7)
    rects, amps = _store(rng, 4096)
    rects16, amps16 = rects.astype(jnp.float16), amps.astype(jnp.float16)
    qr = jnp.asarray(np.array([[0.1, 0.1, 0.7, 0.7]], np.float32))
    qa = jnp.ones((1,))
    ss = jnp.asarray(np.array([100], np.int32))
    ee = jnp.asarray(np.array([3100], np.int32))
    got_s, _ = sweep_score(rects16, amps16, ss, ee, qr, qa, 3072)
    want_s, _ = sweep_score_ref(rects, amps, ss, ee, qr, qa, 3072)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s), atol=2e-3)


def test_sweep_score_all_invalid():
    rng = np.random.default_rng(9)
    rects, amps = _store(rng, 2048)
    qr = jnp.asarray(np.array([[0.0, 0.0, 1.0, 1.0]], np.float32))
    qa = jnp.ones((1,))
    ss = jnp.full((4,), INVALID, jnp.int32)
    got_s, got_v = sweep_score(rects, amps, ss, ss, qr, qa, 1024)
    assert not bool(got_v.any())
    assert float(jnp.abs(got_s).max()) == 0.0


def test_k_sweep_fused_path_equals_reference():
    """k_sweep(fused=True) — the Pallas fused kernel in the real pipeline —
    returns identical results to the fetch-then-score path."""
    from repro.corpus import make_corpus, make_query_trace
    from repro.core import GeoSearchEngine, QueryBudgets

    corpus = make_corpus(n_docs=400, n_terms=100, seed=0)
    eng = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=32,
        budgets=QueryBudgets(max_candidates=512, max_tiles=256, k_sweeps=4,
                             sweep_budget=512, top_k=10),
    )
    q = make_query_trace(corpus, n_queries=8, seed=1)
    a = eng.query(q, "k_sweep")
    b = eng.query(q, "k_sweep", fused=True)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_allclose(np.asarray(a.scores), np.asarray(b.scores),
                               rtol=1e-5, atol=1e-6)

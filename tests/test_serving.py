"""Tests for the serving subsystem: fingerprints, caches, batcher,
sharded scatter-gather equivalence, and the end-to-end serve loop."""
import numpy as np
import pytest

from repro.corpus import make_corpus, make_zipf_trace
from repro.core import GeoSearchEngine, QueryBudgets
from repro.core.distributed import (
    HashPartitioner,
    MortonPartitioner,
    RegionRangePartitioner,
)
from repro.serving import (
    GeoServer,
    LandlordCache,
    LRUCache,
    MeshExecutor,
    ShapeBucketedBatcher,
    ShardedExecutor,
    SingleDeviceExecutor,
    query_fingerprint,
)
from repro.serving.batcher import PendingQuery


# ---------------------------------------------------------------------------
# fingerprint
# ---------------------------------------------------------------------------

def test_fingerprint_normalizes_term_order_and_padding():
    r = np.array([[0.1, 0.1, 0.3, 0.3]], np.float32)
    a = np.ones((1,), np.float32)
    k1 = query_fingerprint(np.array([5, 2, 9, -1]), r, a)
    k2 = query_fingerprint(np.array([9, 5, 2]), r, a)
    assert k1 == k2


def test_fingerprint_distinguishes_tiny_distant_rects():
    """Sub-lattice-cell rects must not be dropped: same terms + tiny
    footprints in different places are different searches."""
    a = np.ones((1,), np.float32)
    t = np.array([7])
    r1 = np.array([[0.095, 0.095, 0.098, 0.098]], np.float32)
    r2 = np.array([[0.907, 0.907, 0.910, 0.910]], np.float32)
    assert query_fingerprint(t, r1, a) != query_fingerprint(t, r2, a)


def test_fingerprint_quantizes_nearby_rects():
    a = np.ones((1,), np.float32)
    t = np.array([1, 2])
    base = np.array([[0.1, 0.1, 0.3, 0.3]], np.float32)
    nearby = base + 1e-4  # far below one lattice cell at quant=128
    far = base + 0.1
    assert query_fingerprint(t, base, a) == query_fingerprint(t, nearby, a)
    assert query_fingerprint(t, base, a) != query_fingerprint(t, far, a)
    assert query_fingerprint(np.array([1, 3]), base, a) != query_fingerprint(t, base, a)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def test_lru_eviction_order():
    c = LRUCache(capacity=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1  # refresh a → b is now LRU
    c.put("c", 3)
    assert "b" not in c and "a" in c and "c" in c
    assert c.get("b") is None
    assert c.evictions == 1
    assert c.hits == 1 and c.misses == 1


def test_landlord_keeps_expensive_entries():
    c = LandlordCache(capacity=2)
    c.put("cheap", 1, cost=1.0)
    c.put("pricey", 2, cost=10.0)
    c.put("new", 3, cost=1.0)  # cache full → evict min credit = "cheap"
    assert "cheap" not in c and "pricey" in c and "new" in c
    # rent was charged: "new" has credit 1 vs pricey's remaining 9
    c.put("new2", 4, cost=1.0)
    assert "new" not in c and "pricey" in c
    assert c.evictions == 2


def test_landlord_hit_renews_credit():
    c = LandlordCache(capacity=2)
    c.put("a", 1, cost=2.0)
    c.put("b", 2, cost=3.0)
    assert c.get("a") == 1  # a's credit restored after rent
    c.put("c", 3, cost=1.0)  # evicts b? a expiry=clock+2 > b expiry=3 …
    # After a's renewal at clock 0: a expires at 2 … b at 3. Hmm — renewal
    # restores *full* credit, so a=2, b=3 → a is still min. Landlord is
    # cost-aware, not recency-aware: b's larger cost wins.
    assert "b" in c and "c" in c and "a" not in c


def test_lru_vs_landlord_policy_difference():
    """Same access pattern, different survivor: the policies genuinely differ."""
    lru, ll = LRUCache(2), LandlordCache(2)
    for c in (lru, ll):
        c.put("expensive_old", 1, cost=100.0)
        c.put("cheap_mid", 2, cost=1.0)
        c.put("cheap_new", 3, cost=1.0)
    assert "expensive_old" not in lru  # LRU evicts the oldest
    assert "expensive_old" in ll  # Landlord keeps the pricey one


# ---------------------------------------------------------------------------
# batcher
# ---------------------------------------------------------------------------

def _random_queries(rng, n, max_terms=8, max_rects=4):
    out = []
    for qid in range(n):
        d = int(rng.integers(1, max_terms + 1))
        r = int(rng.integers(1, max_rects + 1))
        lo = rng.uniform(0, 0.8, (r, 2)).astype(np.float32)
        rects = np.concatenate([lo, lo + 0.1], axis=1).astype(np.float32)
        out.append(
            PendingQuery(
                qid,
                rng.integers(0, 100, d).astype(np.int32),
                rects,
                np.ones((r,), np.float32),
            )
        )
    return out


def test_batcher_shapes_are_registered_and_no_query_dropped():
    rng = np.random.default_rng(0)
    b = ShapeBucketedBatcher(max_batch=8, max_terms=8, max_rects=4)
    registered = b.registered_shapes
    queries = _random_queries(rng, 100)
    batches = []
    for q in queries:
        batches.extend(b.add(q))
    batches.extend(b.flush())
    seen = []
    for raw in batches:
        assert raw.shape in registered
        assert raw.terms.shape == (raw.shape.batch, raw.shape.d_terms)
        assert raw.rects.shape == (raw.shape.batch, raw.shape.q_rects, 4)
        assert raw.n_real <= raw.shape.batch
        for row, qid in enumerate(raw.qids):
            q = queries[qid]
            assert np.array_equal(raw.terms[row, : len(q.terms)], q.terms)
            # padding is inert: −1 terms, empty rects
            assert (raw.terms[row, len(q.terms):] == -1).all()
        seen.extend(raw.qids)
    assert sorted(seen) == [q.qid for q in queries]  # exactly once each
    assert b.real_slots == len(queries)


def test_batcher_bounded_shape_count():
    rng = np.random.default_rng(1)
    b = ShapeBucketedBatcher(max_batch=8, max_terms=8, max_rects=4)
    for q in _random_queries(rng, 500):
        b.add(q)
    b.flush()
    assert len(b.emitted_shapes) <= len(b.registered_shapes)
    assert b.padding_overhead < 1.0


# ---------------------------------------------------------------------------
# sharded scatter-gather vs single device
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "partitioner",
    [HashPartitioner(), MortonPartitioner(), RegionRangePartitioner()],
    ids=["hash", "morton", "region"],
)
def test_sharded_executor_matches_single_device(partitioner):
    corpus = make_corpus(n_docs=256, n_terms=80, seed=3)
    # generous budgets: both paths are exact → results must agree
    budgets = QueryBudgets(
        max_candidates=1024, max_tiles=256, k_sweeps=4,
        sweep_budget=1024, top_k=5,
    )
    eng = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=16, budgets=budgets,
    )
    single = SingleDeviceExecutor(eng)
    sharded = ShardedExecutor.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, n_shards=4, partitioner=partitioner,
        grid=16, budgets=budgets,
    )
    from repro.corpus import make_query_trace

    batch = make_query_trace(corpus, n_queries=16, seed=4)
    want = single.run(batch)
    got = sharded.run(batch)
    w_ids, w_sc = np.asarray(want.ids), np.asarray(want.scores)
    g_ids, g_sc = np.asarray(got.ids), np.asarray(got.scores)
    for b in range(w_ids.shape[0]):
        # order-insensitive: sort both top-k lists by (-score, id)
        wo = np.lexsort((w_ids[b], -w_sc[b]))
        go = np.lexsort((g_ids[b], -g_sc[b]))
        assert np.array_equal(w_ids[b][wo], g_ids[b][go])
        np.testing.assert_allclose(
            np.where(np.isfinite(w_sc[b][wo]), w_sc[b][wo], 0.0),
            np.where(np.isfinite(g_sc[b][go]), g_sc[b][go], 0.0),
            rtol=1e-4, atol=1e-5,
        )


def test_sharded_overlap_matches_sequential_dispatch():
    """Overlapped per-shard dispatch (submit all shards, one sync at the
    gather) is a pure scheduling change: ids, scores, and every per-stage
    counter are bit-identical to the strictly sequential reference loop
    (``overlap=False``, which blocks on each shard before the next)."""
    from repro.corpus import make_query_trace

    corpus = make_corpus(n_docs=320, n_terms=80, seed=7)
    budgets = QueryBudgets(
        max_candidates=512, max_tiles=64, k_sweeps=4, sweep_budget=128, top_k=5
    )
    kw = dict(
        pagerank=corpus.pagerank, n_shards=4, partitioner=MortonPartitioner(),
        grid=16, budgets=budgets, routing="footprint",
    )
    ov = ShardedExecutor.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        overlap=True, **kw,
    )
    sq = ShardedExecutor.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        overlap=False, **kw,
    )
    assert ov.overlap and not sq.overlap
    batch = make_query_trace(corpus, n_queries=16, seed=8)
    a, b = ov.run(batch), sq.run(batch)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))
    assert set(a.stats) == set(b.stats)
    for key in a.stats:
        np.testing.assert_array_equal(
            np.asarray(a.stats[key]), np.asarray(b.stats[key]), err_msg=key
        )


# ---------------------------------------------------------------------------
# executor byte counters (single vs sharded measured, mesh modeled)
# ---------------------------------------------------------------------------

def test_executor_byte_counters_nonzero_and_consistent():
    """All three executors report the same per-stage counter keys on the
    same batch; bytes are non-zero; the sharded(S=1, hash) measurement
    matches single-device, and the MeshExecutor's counters — now *measured
    inside the shard_map step* (psum over the doc axes), not a host-side
    capacity model — match the single-device measurement at S=1."""
    import jax
    from jax.sharding import Mesh

    from repro.corpus import make_query_trace

    corpus = make_corpus(n_docs=192, n_terms=64, seed=11)
    budgets = QueryBudgets(
        max_candidates=256, max_tiles=64, k_sweeps=4, sweep_budget=128, top_k=5
    )
    eng = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=16, budgets=budgets,
    )
    single = SingleDeviceExecutor(eng)
    # hash partition with one shard keeps the doc order identical to the
    # single-device engine, so measured counters must agree exactly
    sharded = ShardedExecutor.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, n_shards=1, partitioner=HashPartitioner(),
        grid=16, budgets=budgets,
    )
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    meshx = MeshExecutor.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, mesh=mesh, partitioner=HashPartitioner(),
        grid=16, budgets=budgets,
    )
    batch = make_query_trace(corpus, n_queries=8, seed=12)
    sums = {}
    for name, ex in [("single", single), ("sharded", sharded), ("mesh", meshx)]:
        res = ex.run(batch)
        assert res.stats, f"{name}: empty stats dict"
        sums[name] = {
            k: float(np.asarray(v, np.float64).sum()) for k, v in res.stats.items()
        }
    assert set(sums["mesh"]) == set(sums["sharded"]) == set(sums["single"])
    for name in sums:
        for k, v in sums[name].items():
            if k.startswith("bytes_"):
                assert v > 0, f"{name}: {k} is zero"
    for k in sums["single"]:
        np.testing.assert_allclose(
            sums["sharded"][k], sums["single"][k], rtol=1e-6, err_msg=k
        )
        # measured inside the step: exact agreement with the host path
        np.testing.assert_allclose(
            sums["mesh"][k], sums["single"][k], rtol=1e-6, err_msg=k
        )
    # the counters also flow into a serving report through the server
    server = GeoServer(
        meshx, cache=None,
        batcher=ShapeBucketedBatcher(
            max_batch=8, max_terms=8, max_rects=4,
            term_buckets=[8], rect_buckets=[4], batch_sizes=[8],
        ),
    )
    rep = server.run_trace(
        make_zipf_trace(corpus, n_queries=16, pool_size=8, seed=21)
    )
    assert any(k.startswith("bytes_") and v > 0 for k, v in rep.stats.items())


# ---------------------------------------------------------------------------
# end-to-end serve loop
# ---------------------------------------------------------------------------

def _small_server(cache):
    corpus = make_corpus(n_docs=400, n_terms=100, seed=5)
    budgets = QueryBudgets(
        max_candidates=512, max_tiles=64, k_sweeps=4, sweep_budget=256, top_k=5
    )
    eng = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=16, budgets=budgets,
    )
    batcher = ShapeBucketedBatcher(max_batch=8, max_terms=8, max_rects=4)
    return corpus, GeoServer(SingleDeviceExecutor(eng), cache=cache, batcher=batcher)


def test_serve_loop_accounts_every_query():
    corpus, server = _small_server(LRUCache(128))
    trace = make_zipf_trace(corpus, n_queries=200, pool_size=32, seed=6)
    rep = server.run_trace(trace)
    assert rep.n_queries == 200
    assert rep.cache_hits + rep.cache_misses == 200
    assert len(rep.latencies_s) == 200
    assert rep.qps > 0
    assert 0.0 <= rep.padding_overhead < 1.0
    assert rep.stats  # byte counters flowed through


def test_serve_report_is_per_run():
    """Metrics are per run_trace call, not cumulative batcher state."""
    corpus, server = _small_server(LRUCache(128))
    trace = make_zipf_trace(corpus, n_queries=100, pool_size=16, seed=8)
    r1 = server.run_trace(trace)
    r2 = server.run_trace(trace)  # warmed cache: mostly hits now
    for r in (r1, r2):
        assert r.n_queries == 100
        assert r.real_slots == r.cache_misses  # this run's executed queries only
    assert r2.hit_rate > r1.hit_rate
    assert r2.n_batches <= r1.n_batches


def test_serve_loop_zipf_hit_rate():
    """Acceptance: >= 30% hit rate on the Zipf trace (both policies)."""
    for cache in (LRUCache(256), LandlordCache(256)):
        corpus, server = _small_server(cache)
        trace = make_zipf_trace(corpus, n_queries=300, pool_size=64, seed=7)
        rep = server.run_trace(trace)
        assert rep.hit_rate >= 0.30, f"{type(cache).__name__}: {rep.hit_rate}"

"""Compressed posting/toe-print stores: bit-exact round-trips, kernel ≡
ref on compressed inputs across the prune × fused grid, recall floors vs
the uncompressed oracle, and the ≥ 2× byte-accounting drop the compressed
layout is supposed to buy."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GeoSearchEngine, QueryBudgets
from repro.core.spatial_index import (
    SCALE_BLOCK,
    block_metadata_np,
    build_spatial_index_np,
    quantize_amps_np,
)
from repro.core.text_index import (
    POSTING_BLOCK,
    build_text_index_np,
    decode_posting_blocks,
    probe_term,
)
from repro.corpus import make_corpus, make_zipf_trace, pad_trace_batch
from repro.kernels.sweep_score.ops import sweep_score, sweep_score_pruned
from repro.kernels.sweep_score.ref import sweep_score_pruned_ref, sweep_score_ref

INVALID = 2**31 - 1


# ---------------------------------------------------------------------------
# posting store: delta + bit-packed round-trip
# ---------------------------------------------------------------------------

def _decode_all_terms(idx):
    """Decode every term's packed blocks back to flat doc-id lists."""
    bto = np.asarray(idx.blk_term_off)
    blk_len = np.asarray(idx.blk_len)
    out = []
    for t in range(idx.n_terms):
        ids = []
        for b in range(int(bto[t]), int(bto[t + 1])):
            dec = np.asarray(decode_posting_blocks(idx, jnp.int32(b)))
            ids.append(dec[: int(blk_len[b])])
        out.append(np.concatenate(ids) if ids else np.zeros((0,), np.int64))
    return out


def test_posting_roundtrip_edge_cases():
    """Empty terms, single-posting lists, a maximal delta gap, and a list
    spanning multiple 128-posting blocks all decode back exactly."""
    N = 300  # docs; term 3 spans 3 blocks (300 > 2·128)
    doc_terms = []
    for d in range(N):
        t = [3]  # term 3: every doc (multi-block list)
        if d == 0:
            t += [1, 2]  # term 1: single posting; term 2 gets doc 0
        if d == N - 1:
            t += [2]  # term 2: {0, N-1} — the maximal delta gap
        doc_terms.append(np.asarray(t, np.int32))
    # term 0 stays empty
    comp = build_text_index_np(doc_terms, n_terms=4, compress=True)
    raw = build_text_index_np(doc_terms, n_terms=4, compress=False)
    assert comp.is_compressed and not raw.is_compressed
    assert comp.postings.shape[0] == 0  # packed words are the store
    offs = np.asarray(raw.offsets)
    decoded = _decode_all_terms(comp)
    for t in range(4):
        want = np.asarray(raw.postings)[offs[t] : offs[t + 1]]
        np.testing.assert_array_equal(decoded[t], want)
    # impacts stay CSR-addressed at full length in both layouts
    np.testing.assert_array_equal(np.asarray(comp.impacts), np.asarray(raw.impacts))
    # compressed store is strictly smaller per posting
    assert comp.posting_bytes < raw.posting_bytes


def test_posting_roundtrip_random_corpus():
    corpus = make_corpus(n_docs=500, n_terms=120, seed=21)
    comp = build_text_index_np(corpus.doc_terms, corpus.n_terms, compress=True)
    raw = build_text_index_np(corpus.doc_terms, corpus.n_terms, compress=False)
    offs = np.asarray(raw.offsets)
    decoded = _decode_all_terms(comp)
    for t in range(corpus.n_terms):
        np.testing.assert_array_equal(
            decoded[t], np.asarray(raw.postings)[offs[t] : offs[t + 1]]
        )


def test_probe_term_matches_uncompressed():
    """The packed probe (block-head bisection + one-block decode) agrees
    with the CSR binary search on membership AND impacts."""
    corpus = make_corpus(n_docs=400, n_terms=90, seed=22)
    comp = build_text_index_np(corpus.doc_terms, corpus.n_terms, compress=True)
    raw = build_text_index_np(corpus.doc_terms, corpus.n_terms, compress=False)
    rng = np.random.default_rng(23)
    doc_ids = jnp.asarray(rng.integers(0, 400, (256,)).astype(np.int32))
    for t in [0, 1, 17, 89]:
        m_c, i_c = probe_term(comp, jnp.int32(t), doc_ids)
        m_r, i_r = probe_term(raw, jnp.int32(t), doc_ids)
        np.testing.assert_array_equal(np.asarray(m_c), np.asarray(m_r))
        np.testing.assert_array_equal(np.asarray(i_c), np.asarray(i_r))


# ---------------------------------------------------------------------------
# amplitude store: int8 quantization round-trip
# ---------------------------------------------------------------------------

def test_quantize_amps_roundtrip_properties():
    """Negative amps, an all-zero block, and a ragged tail: decode error is
    bounded by scale/2, signs survive, zero blocks decode to exact zeros."""
    rng = np.random.default_rng(31)
    T = 2 * SCALE_BLOCK + 37  # ragged tail block
    amps = rng.uniform(-2.0, 2.0, T).astype(np.float32)
    amps[SCALE_BLOCK : 2 * SCALE_BLOCK] = 0.0  # all-zero block
    q, scale = quantize_amps_np(amps)
    assert q.dtype == np.int8 and scale.dtype == np.float32
    assert q.shape == (T,) and scale.shape == (3,)
    dec = q.astype(np.float32) * np.repeat(scale, SCALE_BLOCK)[:T]
    err = np.abs(dec - amps)
    bound = np.repeat(scale, SCALE_BLOCK)[:T] * 0.5 + 1e-7
    assert (err <= bound).all()
    # zero block: exact zeros with the sentinel scale
    assert scale[1] == 1.0 and (dec[SCALE_BLOCK : 2 * SCALE_BLOCK] == 0.0).all()
    # signs preserved wherever the quantized value is nonzero
    nz = q != 0
    assert (np.sign(dec[nz]) == np.sign(amps[nz])).all()


def test_quantize_amps_single_element():
    q, scale = quantize_amps_np(np.asarray([-0.75], np.float32))
    assert q.shape == (1,) and scale.shape == (1,)
    assert q[0] == -127 and np.isclose(q[0] * scale[0], -0.75)


@pytest.mark.parametrize("mode", ["none", "f16", "int8"])
def test_text_block_max_impact_bounds_decoded_values(mode):
    """``blk_max_impact`` is computed from the *stored* (post-quantization)
    impact values, so it upper-bounds — exactly equals the max of — every
    decoded impact in its block across compress modes, including all-zero
    blocks (idf-zero term), empty terms, and ragged tail blocks."""
    rng = np.random.default_rng(44)
    n_terms = 60
    docs = [
        rng.integers(0, 50, size=int(rng.integers(1, 40))).astype(np.int32)
        for _ in range(300)
    ]
    docs.append(np.full((200,), 3, np.int32))  # hot ragged multi-block term
    idf = np.log(1.0 + len(docs) / np.maximum(
        np.bincount(np.concatenate(docs), minlength=n_terms), 1.0
    ))
    idf[7] = 0.0  # term 7's blocks store all-zero impacts
    idx = build_text_index_np(
        docs, n_terms, idf=idf,
        compress=(mode != "none"),
        impact_dtype=(np.float16 if mode != "none" else None),
    )
    blk_pos = np.asarray(idx.blk_pos)
    blk_len = np.asarray(idx.blk_len)
    imp = np.asarray(idx.impacts).astype(np.float32)
    bmi = np.asarray(idx.blk_max_impact)
    bto = np.asarray(idx.blk_term_off)
    assert (bto[1:] >= bto[:-1]).all()  # empty terms → zero blocks
    assert blk_pos.shape[0] > 0
    saw_ragged = saw_zero = False
    for b in range(blk_pos.shape[0]):
        vals = imp[blk_pos[b] : blk_pos[b] + blk_len[b]]
        want = float(vals.max()) if len(vals) else 0.0
        assert bmi[b] == np.float32(want), b
        assert (vals <= bmi[b]).all(), b
        saw_ragged |= 0 < blk_len[b] < POSTING_BLOCK
        saw_zero |= len(vals) > 0 and want == 0.0
    assert saw_ragged and saw_zero


def test_spatial_block_metadata_from_decoded_values():
    """int8 build computes block-max bounds from the dequantized amps (not
    the raw f32 inputs), so pruning bounds stay safe under quantization."""
    rng = np.random.default_rng(33)
    T = 600
    lo = rng.uniform(0, 0.9, (T, 2)).astype(np.float32)
    rects = np.concatenate([lo, lo + 0.05], axis=1).astype(np.float32)
    amps = rng.uniform(0, 1, T).astype(np.float32)
    doc_rects = rects[:, None, :]
    doc_amps = amps[:, None]
    idx = build_spatial_index_np(doc_rects, doc_amps, grid=16, compress="int8")
    sc = np.asarray(idx.tp_amp_scale)
    dec = np.asarray(idx.tp_amps).astype(np.float32) * np.repeat(sc, SCALE_BLOCK)[:T]
    _, want_amp, want_mass = block_metadata_np(
        np.asarray(idx.tp_rects).astype(np.float32), dec, idx.block_size
    )
    np.testing.assert_array_equal(np.asarray(idx.blk_max_amp), want_amp)
    np.testing.assert_array_equal(np.asarray(idx.blk_max_mass), want_mass)
    # doc-id column narrows to i16 when the corpus fits
    assert np.asarray(idx.tp_doc_ids).dtype == np.int16
    assert idx.tp_bytes < 12.0  # < f16's 12 B/toe-print


# ---------------------------------------------------------------------------
# kernel ≡ ref on compressed inputs (prune × fused grid)
# ---------------------------------------------------------------------------

def _compressed_store(rng, T, mode):
    lo = rng.uniform(0, 0.9, (T, 2)).astype(np.float32)
    wh = rng.uniform(0.01, 0.08, (T, 2)).astype(np.float32)
    rects = np.concatenate([lo, lo + wh], axis=1).astype(np.float16)
    amps = rng.uniform(-0.2, 1.0, T).astype(np.float32)
    if mode == "int8":
        store, scale = quantize_amps_np(amps)
        dec = store.astype(np.float32) * np.repeat(scale, SCALE_BLOCK)[:T]
    else:
        store, scale = amps.astype(np.float16), None
        dec = store.astype(np.float32)
    return rects, store, scale, dec


@pytest.mark.parametrize("mode", ["f16", "int8"])
def test_kernel_matches_ref_on_compressed_store(mode):
    """In-kernel decode of the compressed planes bit-matches the jnp
    reference that dequantizes with the same astype-then-multiply order."""
    rng = np.random.default_rng(41 if mode == "f16" else 43)
    T, budget, k = 5000, 2048, 4
    rects, store, scale, _ = _compressed_store(rng, T, mode)
    ss = np.sort(rng.integers(0, T, k)).astype(np.int32)
    ee = np.minimum(ss + rng.integers(1, budget + 500, k), T).astype(np.int32)
    ss[k // 2] = INVALID
    ee[k // 2] = INVALID
    qr = jnp.asarray(np.array([[0.2, 0.2, 0.6, 0.6], [0.5, 0.5, 0.9, 0.9]], np.float32))
    qa = jnp.ones((2,))
    sc = None if scale is None else jnp.asarray(scale)
    args = (jnp.asarray(rects), jnp.asarray(store), jnp.asarray(ss), jnp.asarray(ee), qr, qa)
    got = sweep_score(*args, budget, tp_amp_scale=sc)
    want = sweep_score_ref(*args, budget, tp_amp_scale=sc)
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))


@pytest.mark.parametrize("mode", ["f16", "int8"])
@pytest.mark.parametrize("bs,C,floor", [(128, 1024, 0.0), (256, 512, 0.02)])
def test_pruned_kernel_matches_ref_on_compressed_store(mode, bs, C, floor):
    """The manual-DMA pruned kernel decodes compressed blocks identically
    to the oracle — scores, valid, streamed, and both block counters."""
    rng = np.random.default_rng(1000 + bs + (1 if mode == "int8" else 0))
    T, budget, k = 5000, 2048, 4
    rects, store, scale, dec = _compressed_store(rng, T, mode)
    bm, ba, bmass = block_metadata_np(rects.astype(np.float32), dec, bs)
    ss = np.sort(rng.integers(0, T, k)).astype(np.int32)
    ee = np.minimum(ss + rng.integers(1, budget + 500, k), T).astype(np.int32)
    qr = jnp.asarray(np.array([[0.2, 0.2, 0.6, 0.6], [0.5, 0.5, 0.9, 0.9]], np.float32))
    qa = jnp.ones((2,))
    sc = None if scale is None else jnp.asarray(scale)
    args = (
        jnp.asarray(rects), jnp.asarray(store),
        jnp.asarray(bm), jnp.asarray(ba), jnp.asarray(bmass),
        jnp.asarray(ss), jnp.asarray(ee), qr, qa,
    )
    got = sweep_score_pruned(*args, budget, C, bs, floor, tp_amp_scale=sc)
    want = sweep_score_pruned_ref(*args, budget, C, bs, floor, tp_amp_scale=sc)
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))  # valid
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))  # streamed
    assert int(got[3]) == int(want[3]) and int(got[4]) == int(want[4])
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))


# ---------------------------------------------------------------------------
# end-to-end: recall floors and the byte-accounting drop
# ---------------------------------------------------------------------------

def _recall_vs(a, b):
    ai, bi = np.asarray(a.ids), np.asarray(b.ids)
    va = ai >= 0
    found = (
        (ai[:, :, None] == bi[:, None, :]) & va[:, :, None] & (bi[:, None, :] >= 0)
    ).any(-1)
    return found.sum() / max(va.sum(), 1)


@pytest.fixture(scope="module")
def smoke_corpus_and_trace():
    corpus = make_corpus(n_docs=1200, n_terms=400, seed=9)
    trace = pad_trace_batch(make_zipf_trace(corpus, n_queries=64, pool_size=48, seed=10))
    return corpus, trace


def _engine(corpus, compress, **bud_kw):
    budgets = QueryBudgets(
        max_candidates=1024, max_tiles=256, k_sweeps=8, sweep_budget=256,
        top_k=10, **bud_kw,
    )
    return GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=32, budgets=budgets, compress=compress,
    )


@pytest.mark.parametrize("mode", ["f16", "int8"])
@pytest.mark.parametrize("fused", [False, True])
def test_compressed_recall_vs_uncompressed_oracle(
    smoke_corpus_and_trace, mode, fused
):
    """recall@10 ≥ 0.99 vs the uncompressed engine at both precisions."""
    corpus, trace = smoke_corpus_and_trace
    un = _engine(corpus, "none").query(trace, "k_sweep", fused=fused)
    co = _engine(corpus, mode).query(trace, "k_sweep", fused=fused)
    assert _recall_vs(un, co) >= 0.99


def test_compressed_bytes_drop_2x_at_recall_floor(smoke_corpus_and_trace):
    """The acceptance bar: on the zipf smoke trace the compressed store
    streams ≤ half the bytes (postings + spatial) at recall@10 ≥ 0.99."""
    corpus, trace = smoke_corpus_and_trace
    un = _engine(corpus, "none").query(trace, "k_sweep")

    def tot(r):
        return float(np.asarray(r.stats["bytes_postings"], np.float64).sum()) + float(
            np.asarray(r.stats["bytes_spatial"], np.float64).sum()
        )

    for mode in ["f16", "int8"]:
        co = _engine(corpus, mode).query(trace, "k_sweep")
        assert _recall_vs(un, co) >= 0.99, mode
        assert tot(co) <= 0.5 * tot(un), f"{mode}: {tot(co)} vs {tot(un)}"


def test_compressed_prune_skips_blocks_and_bytes(smoke_corpus_and_trace):
    """Pruning composes with compression: skipped blocks charge no spatial
    bytes on the compressed store either, and the pruned compressed run
    streams fewer bytes than BOTH the unpruned compressed and the pruned
    uncompressed runs."""
    corpus, trace = smoke_corpus_and_trace

    def tot(r, k):
        return float(np.asarray(r.stats[k], np.float64).sum())

    un_c = _engine(corpus, "int8").query(trace, "k_sweep")
    pr_c = _engine(corpus, "int8", prune=True).query(trace, "k_sweep")
    pr_u = _engine(corpus, "none", prune=True).query(trace, "k_sweep")
    assert tot(pr_c, "blocks_skipped") > 0
    assert tot(pr_c, "bytes_spatial") < tot(un_c, "bytes_spatial")
    assert tot(pr_c, "bytes_spatial") < tot(pr_u, "bytes_spatial")
    assert tot(pr_c, "bytes_postings") < tot(pr_u, "bytes_postings")
    assert _recall_vs(pr_u, pr_c) >= 0.99

"""Block-max pruned TEXT-FIRST: kernel/ref bit-match across compression
modes, select-stage safety, prune=False bit-identity, recall floors on
the prune × fused grid, deterministic block skipping with probe/byte
accounting, and the serving-layer threading."""
from dataclasses import replace

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GeoSearchEngine, QueryBudgets
from repro.core import text_index as T
from repro.core.distributed import HashPartitioner
from repro.core.engine import GeoIndex
from repro.corpus import TraceQuery, make_corpus, make_query_trace, pad_trace_batch
from repro.kernels.text_probe.ops import (
    impact_planes,
    text_probe_pruned,
    window_size,
)
from repro.kernels.text_probe.ref import text_probe_pruned_ref


# ---------------------------------------------------------------------------
# corpora: a natural zipf corpus and a bimodal hot-term corpus whose
# driver posting list provably triggers θ-adaptive block skipping
# ---------------------------------------------------------------------------

def _hot_corpus(n_docs=2560, n_short=1024, n_terms=64, seed=0):
    """Terms 0 and 1 appear in EVERY doc; docs < ``n_short`` are 2-term
    docs (impact idf/√2) and the rest are 64-term docs (impact idf/8).

    Postings are docID-ordered, so the driver list's first 8 blocks (one
    kernel tile, 1024 postings) hold only high-impact postings: after the
    first tile the running θ provably exceeds every later block's bound
    and the remaining blocks are skipped — deterministically."""
    rng = np.random.default_rng(seed)
    docs = []
    for d in range(n_docs):
        if d < n_short:
            docs.append(np.array([0, 1], np.int32))
        else:
            fill = rng.integers(2, n_terms, size=62).astype(np.int32)
            docs.append(np.concatenate([np.array([0, 1], np.int32), fill]))
    rects = np.tile(
        np.array([[0.1, 0.1, 0.9, 0.9]], np.float32), (n_docs, 1, 1)
    )
    amps = np.ones((n_docs, 1), np.float32)
    return docs, rects, amps, n_terms


def _hot_trace(n_queries=8):
    q = TraceQuery(
        terms=np.array([0, 1], np.int32),
        rects=np.array([[0.2, 0.2, 0.8, 0.8]], np.float32),
        amps=np.ones((1,), np.float32),
    )
    return pad_trace_batch([q] * n_queries)


def _hot_engine(C, seed=0, **bud_kw):
    docs, rects, amps, n_terms = _hot_corpus(seed=seed)
    budgets = QueryBudgets(
        max_candidates=C, max_tiles=64, k_sweeps=4, sweep_budget=256,
        top_k=10, **bud_kw,
    )
    return GeoSearchEngine.build(
        docs, rects, amps, n_terms, pagerank=np.zeros(len(docs), np.float32),
        grid=16, budgets=budgets,
    )


def _engine(corpus, C, grid=32, **bud_kw):
    budgets = QueryBudgets(
        max_candidates=C, max_tiles=256, k_sweeps=4, sweep_budget=1024,
        top_k=10, **bud_kw,
    )
    return GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=grid, budgets=budgets,
    )


def _with_budgets(eng, **kw):
    """Fresh engine sharing the built index (its own compiled-fn cache)."""
    return GeoSearchEngine(
        index=eng.index, budgets=replace(eng.budgets, **kw), weights=eng.weights
    )


def _recall_vs(a, b):
    ai, bi = np.asarray(a.ids), np.asarray(b.ids)
    va = ai >= 0
    found = (
        (ai[:, :, None] == bi[:, None, :]) & va[:, :, None] & (bi[:, None, :] >= 0)
    ).any(-1)
    return found.sum() / max(va.sum(), 1)


# ---------------------------------------------------------------------------
# kernel vs ref: bit-match across stored dtypes × posting compression
# ---------------------------------------------------------------------------

def _probe_args(text, t0, w_text, rest_ub):
    plane = impact_planes(text.impacts, text.blk_pos, text.blk_len)
    b0 = text.blk_term_off[t0]
    nb = text.blk_term_off[t0 + 1] - b0
    return plane, text.blk_max_impact, text.blk_len, jnp.int32(b0), nb


@pytest.mark.parametrize("compress", [False, True])
@pytest.mark.parametrize("impact_dtype", [None, jnp.float16])
@pytest.mark.parametrize("C,floor_frac", [(256, 0.0), (2048, 0.0), (256, 0.4)])
def test_pruned_kernel_matches_ref(compress, impact_dtype, C, floor_frac):
    """The Pallas probe kernel and the jnp reference agree bit-for-bit on
    scores, masks, AND the per-block skip counters — on f32 and f16
    stored impacts, compressed and uncompressed posting layouts, and a
    multi-tile (max_term_blocks > 8) driver list."""
    docs, _, _, n_terms = _hot_corpus(n_docs=2560)
    text = T.build_text_index_np(
        docs, n_terms, compress=compress, impact_dtype=impact_dtype
    )
    assert text.max_term_blocks > 8  # multi-tile window, ragged tail
    w_text = jnp.float32(1.0)
    for t0, rest_ub in [(0, 0.7), (1, 0.0), (5, 1.3)]:
        plane, bmi, blens, b0, nb = _probe_args(text, t0, w_text, rest_ub)
        tmax = float(np.asarray(text.blk_max_impact).max())
        floor = jnp.float32(floor_frac * (tmax + rest_ub))
        args = (plane, bmi, blens, b0, nb, w_text, jnp.float32(rest_ub), floor)
        kw = dict(max_candidates=C, max_term_blocks=text.max_term_blocks)
        got = text_probe_pruned(*args, **kw)
        want = text_probe_pruned_ref(*args, **kw)
        for g, w, name in zip(got, want, ["opt", "valid", "streamed",
                                          "blocks_scored", "blocks_active"]):
            np.testing.assert_array_equal(
                np.asarray(g), np.asarray(w), err_msg=f"t0={t0} {name}"
            )


def test_kernel_select_safety_property():
    """θ never overshoots: any valid driver posting whose optimistic score
    beats max(C_eff-th largest optimistic, floor) must be streamed."""
    docs, _, _, n_terms = _hot_corpus(n_docs=2560, seed=3)
    text = T.build_text_index_np(docs, n_terms)
    w_text, rest_ub = 1.0, 0.35
    plane, bmi, blens, b0, nb = _probe_args(text, 0, jnp.float32(w_text), rest_ub)
    for C, floor in [(256, 0.0), (256, 0.5), (1024, 0.0), (4096, 0.0)]:
        opt, valid, streamed, b_scored, b_active = text_probe_pruned(
            plane, bmi, blens, b0, nb, jnp.float32(w_text),
            jnp.float32(rest_ub), jnp.float32(floor),
            max_candidates=C, max_term_blocks=text.max_term_blocks,
        )
        valid = np.asarray(valid)
        streamed = np.asarray(streamed)
        # true optimistic score of every window position (skipped or not)
        n_win = window_size(text.max_term_blocks)
        rows = np.clip(int(b0) + np.arange(n_win), 0, bmi.shape[0] - 1)
        imp = np.asarray(plane, np.float32)[rows]
        true_opt = (w_text * imp + rest_ub).reshape(-1)
        c_eff = max(1, -(-C // 1024)) * 1024
        pos = np.sort(true_opt[valid])[::-1]
        theta_cap = pos[c_eff - 1] if len(pos) >= c_eff else 0.0
        must_keep = valid & (true_opt > max(theta_cap, floor))
        assert streamed[must_keep].all(), (C, floor)
        # streamed scores are exact (not bounds)
        kept = valid & streamed
        np.testing.assert_allclose(
            np.asarray(opt)[kept], true_opt[kept], rtol=1e-6, atol=1e-7
        )
        assert int(b_scored) <= int(b_active)


# ---------------------------------------------------------------------------
# prune=False bit-identity: the unpruned path never reads block-max
# metadata, so zeroing it cannot change ids, scores, or stats
# ---------------------------------------------------------------------------

def test_prune_false_ignores_block_metadata():
    corpus = make_corpus(n_docs=500, n_terms=120, seed=3)
    eng = _engine(corpus, C=512)
    trace = make_query_trace(corpus, n_queries=16, seed=7)
    a = eng.query(trace, "text_first")
    zeroed = replace(
        eng.index.text, blk_max_impact=jnp.zeros_like(eng.index.text.blk_max_impact)
    )
    eng2 = GeoSearchEngine(
        index=GeoIndex(
            text=zeroed, spatial=eng.index.spatial, pagerank=eng.index.pagerank
        ),
        budgets=eng.budgets, weights=eng.weights,
    )
    b = eng2.query(trace, "text_first")
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))
    assert set(a.stats) == set(b.stats)
    for k in a.stats:
        np.testing.assert_array_equal(
            np.asarray(a.stats[k]), np.asarray(b.stats[k]), err_msg=k
        )
    # the unpruned path reports the new counters as zeros/constants
    assert float(np.asarray(a.stats["text_blocks_skipped"]).sum()) == 0
    assert float(np.asarray(a.stats["probes_saved"]).sum()) == 0


def test_pruned_matches_unpruned_when_covering():
    """With the candidate budget covering every driver list (C ≥ max df)
    and no floor, θ stays at 0, no block is skipped, and the pruned path
    returns EXACTLY the unpruned top-k — ids AND scores, ref and fused."""
    corpus = make_corpus(n_docs=400, n_terms=100, seed=11)
    eng = _engine(corpus, C=1024)
    trace = make_query_trace(corpus, n_queries=24, seed=12)
    un = eng.query(trace, "text_first")
    eng_p = _with_budgets(eng, prune=True)
    pr = eng_p.query(trace, "text_first")
    prf = eng_p.query(trace, "text_first", fused=True)
    np.testing.assert_array_equal(np.asarray(pr.ids), np.asarray(prf.ids))
    np.testing.assert_array_equal(np.asarray(pr.scores), np.asarray(prf.scores))
    np.testing.assert_array_equal(np.asarray(un.ids), np.asarray(pr.ids))
    np.testing.assert_array_equal(np.asarray(un.scores), np.asarray(pr.scores))


@pytest.mark.parametrize("prune", [False, True])
@pytest.mark.parametrize("fused", [False, True])
def test_prune_recall_floor_vs_oracle(prune, fused):
    """recall@10 ≥ 0.95 vs the exact oracle across the prune × fused grid."""
    corpus = make_corpus(n_docs=600, n_terms=150, seed=3)
    eng = _engine(corpus, C=512, prune=prune)
    trace = make_query_trace(corpus, n_queries=24, seed=4)
    rec = eng.recall_at_k(trace, "text_first", fused=fused)
    assert rec >= 0.95, f"prune={prune} fused={fused} recall {rec}"


def test_prune_budget_degradation_graceful():
    """Tiny budgets with pruning must not crash or return invalid docs."""
    corpus = make_corpus(n_docs=300, n_terms=80, seed=5)
    eng = _engine(
        corpus, C=16, grid=16, prune=True, prune_eps=1e-3,
    )
    trace = make_query_trace(corpus, n_queries=8, seed=2)
    for fused in [False, True]:
        ids = np.asarray(eng.query(trace, "text_first", fused=fused).ids)
        assert ((ids >= -1) & (ids < 300)).all()


# ---------------------------------------------------------------------------
# stats: deterministic skipping, probe/byte savings (acceptance numbers)
# ---------------------------------------------------------------------------

def test_pruned_stats_skip_blocks_and_cut_io():
    """On the bimodal hot-term corpus the pruned traversal skips every
    post-first-tile block, and cuts n_probes AND bytes_postings ≥ 2× vs
    an unpruned traversal that needs C ≥ df for the same answers —
    at recall@10 ≥ 0.99."""
    trace = _hot_trace(8)
    un = _hot_engine(C=4096).query(trace, "text_first")
    eng_p = _hot_engine(C=256, prune=True)
    pr = eng_p.query(trace, "text_first")
    prf = eng_p.query(trace, "text_first", fused=True)

    def tot(r, k):
        return float(np.asarray(r.stats[k], np.float64).sum())

    np.testing.assert_array_equal(np.asarray(pr.ids), np.asarray(prf.ids))
    for k in pr.stats:
        np.testing.assert_array_equal(
            np.asarray(pr.stats[k]), np.asarray(prf.stats[k]), err_msg=k
        )
    assert _recall_vs(un, pr) >= 0.99
    assert tot(pr, "text_blocks_skipped") > 0
    assert tot(pr, "text_blocks_skipped") < tot(pr, "text_blocks_total")
    assert tot(pr, "probes_saved") > 0
    assert tot(un, "n_probes") >= 2.0 * tot(pr, "n_probes")
    assert tot(un, "bytes_postings") >= 2.0 * tot(pr, "bytes_postings")
    # unpruned path reports no skips and no savings
    assert tot(un, "text_blocks_skipped") == 0
    assert tot(un, "probes_saved") == 0


def test_prune_eps_floor_monotone():
    """Raising prune_eps only increases savings (probes monotone down)."""
    trace = _hot_trace(4)
    probes = []
    for eps in [0.0, 1e-2, 0.5]:
        eng = _hot_engine(C=256, prune=True, prune_eps=eps)
        res = eng.query(trace, "text_first")
        probes.append(float(np.asarray(res.stats["n_probes"], np.float64).sum()))
    assert probes[0] >= probes[1] >= probes[2]


# ---------------------------------------------------------------------------
# serving-layer threading
# ---------------------------------------------------------------------------

def test_sharded_executor_text_prune_matches_single():
    """A pruned TEXT-FIRST ShardedExecutor(S=1, hash) reproduces the
    single-device pruned engine and reports the new counter keys."""
    from repro.serving import ShardedExecutor, SingleDeviceExecutor

    corpus = make_corpus(n_docs=400, n_terms=100, seed=11)
    budgets = QueryBudgets(
        max_candidates=512, max_tiles=64, k_sweeps=4, sweep_budget=128,
        top_k=5, prune=True,
    )
    eng = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=16, budgets=budgets,
    )
    single = SingleDeviceExecutor(eng, "text_first", fused=True)
    sharded = ShardedExecutor.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, n_shards=1, partitioner=HashPartitioner(),
        grid=16, budgets=budgets, algorithm="text_first", fused=True,
    )
    trace = make_query_trace(corpus, n_queries=16, seed=12)
    a = single.run(trace)
    b = sharded.run(trace)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    for key in ["text_blocks_skipped", "text_blocks_total", "probes_saved",
                "n_probes", "bytes_postings"]:
        np.testing.assert_allclose(
            float(np.asarray(a.stats[key], np.float64).sum()),
            float(np.asarray(b.stats[key], np.float64).sum()),
            rtol=1e-6, err_msg=key,
        )


def test_composition_auto_prune_compress_routing_smoke(tmp_path):
    """prune × compress × routing × workers, composed: one open-loop serve
    with ``--algorithm auto --prune --compress int8 --routing footprint
    --workers 2`` holds the recall floor vs the exact oracle, all four
    telemetry exports validate, and per-plan audit counters are populated."""
    import json
    import math

    from repro.core import ranking
    from repro.core.distributed import RegionRangePartitioner
    from repro.corpus import make_zipf_trace, stamp_arrivals
    from repro.obs import Telemetry, validate_trace
    from repro.serving import DeadlineBatcher, GeoServer
    from repro.serving.factory import make_executor

    corpus = make_corpus(n_docs=500, n_terms=120, seed=19)
    budgets = QueryBudgets(
        max_candidates=512, max_tiles=64, k_sweeps=4, sweep_budget=256,
        top_k=10, prune=True,
    )
    tel = Telemetry()
    ex = make_executor(
        "sharded", corpus, algorithm="auto", budgets=budgets,
        partitioner=RegionRangePartitioner(), routing="footprint",
        n_shards=2, grid=16, fused=True, compress="int8", telemetry=tel,
    )
    srv = GeoServer(
        ex, cache=None,
        batcher=DeadlineBatcher(
            max_batch=8, max_terms=8, max_rects=4, max_wait_s=2e-3
        ),
        n_workers=2, telemetry=tel,
    )
    trace = stamp_arrivals(
        make_zipf_trace(corpus, n_queries=48, pool_size=24, seed=20),
        "poisson", rate_qps=500.0, seed=21,
    )
    rep = srv.run_trace(trace, warmup=False, arrival="poisson")
    assert rep.n_queries == 48
    assert rep.stats and any(
        k.startswith("bytes_") and float(np.asarray(v, np.float64).sum()) > 0
        for k, v in rep.stats.items()
    )
    # recall@10 vs the exact (uncompressed, unpruned) oracle
    batch = pad_trace_batch(trace)
    oracle_eng = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=16, budgets=budgets,
    )
    rec = ranking.topk_recall_np(
        np.asarray(oracle_eng.oracle(batch).ids), np.asarray(ex.run(batch).ids)
    )
    assert rec >= 0.9, rec
    # all four telemetry exports validate
    assert validate_trace(tel.tracer.to_trace_events()) == []
    js = tel.metrics.to_json()
    assert js["counters"]["server.queries_total"] >= 48
    assert "server_queries_total" in tel.metrics.to_prometheus()
    assert len(tel.events) > 0
    tel.events.to_jsonl(str(tmp_path / "events.jsonl"))
    tel.audit.to_jsonl(str(tmp_path / "audit.jsonl"))
    assert (tmp_path / "audit.jsonl").exists()
    assert json.loads((tmp_path / "events.jsonl").read_text().splitlines()[0])
    # per-plan counters: every executed plan joined with measured stats
    assert len(tel.audit.records) > 0
    assert len(tel.audit.joined) == len(tel.audit.records)
    for r in tel.audit.records:
        assert r.measured is not None
        errs = r.errors()
        assert all(e >= 0 and math.isfinite(e) for e in errs.values())
    summary = tel.audit.error_summary()
    assert summary and all(math.isfinite(v) for v in summary.values())


def test_mesh_executor_text_prune_fused_matches_single():
    """The SPMD mesh executor runs the pruned text-probe kernel inside its
    shard_map step and agrees with the single-device engine — including
    the pruning savings counters."""
    import jax
    from jax.sharding import Mesh

    from repro.serving import MeshExecutor, SingleDeviceExecutor

    corpus = make_corpus(n_docs=256, n_terms=64, seed=11)
    budgets = QueryBudgets(
        max_candidates=256, max_tiles=64, k_sweeps=4, sweep_budget=128,
        top_k=5, prune=True,
    )
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    meshx = MeshExecutor.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, mesh=mesh, partitioner=HashPartitioner(),
        grid=16, budgets=budgets, algorithm="text_first", fused=True,
    )
    eng = GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=16, budgets=budgets,
    )
    single = SingleDeviceExecutor(eng, "text_first", fused=True)
    batch = make_query_trace(corpus, n_queries=8, seed=12)
    a = single.run(batch)
    b = meshx.run(batch)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    assert set(b.stats) == set(a.stats)
    for key in a.stats:
        np.testing.assert_allclose(
            float(np.asarray(b.stats[key], np.float64).sum()),
            float(np.asarray(a.stats[key], np.float64).sum()),
            rtol=1e-6, err_msg=key,
        )

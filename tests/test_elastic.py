"""Elastic scaling: train on one mesh, lose devices, resume on a smaller
mesh from the same checkpoint (resharding restore) — the DESIGN.md §5
fault-tolerance story end-to-end, on 8 fake devices in a subprocess."""
import json
import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_subprocess(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_elastic_resume_on_smaller_mesh():
    r = run_in_subprocess(textwrap.dedent("""
        import json, tempfile, numpy as np, jax, jax.numpy as jnp
        from repro.models.transformer import TransformerConfig, loss_fn
        from repro.train.loop import make_train_step
        from repro.train.optimizer import OptimizerConfig, init_opt_state
        from repro.train import checkpoint as ckpt
        from repro.train.fault import plan_elastic_mesh
        from repro.sharding.specs import use_sharding, named_sharding
        from repro.data.lm import LMDataConfig, lm_batch
        from repro.models.params import param_shapes

        cfg = TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                                d_ff=128, vocab=256, attn_chunk=16,
                                compute_dtype=jnp.float32)
        opt = OptimizerConfig(lr=1e-3, warmup_steps=2)
        dc = LMDataConfig(vocab=256, seq_len=32, global_batch=8)

        # phase 1: 8 devices as (data=4, model=2)
        mesh1 = jax.make_mesh((4, 2), ("data", "model"))
        losses = []
        with tempfile.TemporaryDirectory() as d:
            with use_sharding(mesh1), mesh1:
                step = make_train_step(lambda p, b: loss_fn(cfg, p, b), opt, donate=False)
                params = cfg.init(jax.random.key(0))
                state = init_opt_state(opt, params)
                for s in range(4):
                    params, state, m = step(params, state, lm_batch(dc, s))
                    losses.append(float(m["loss"]))
                ckpt.save_checkpoint(d, 4, (params, state))

            # phase 2: "half the hosts died" -> plan a (2, 2) mesh on 4 devices
            shape = plan_elastic_mesh(n_alive_hosts=1, chips_per_host=4, model_parallel=2)
            assert shape == (2, 2), shape
            devs = np.array(jax.devices()[:4]).reshape(2, 2)
            mesh2 = jax.sharding.Mesh(devs, ("data", "model"))
            with use_sharding(mesh2), mesh2:
                # resharding restore: device_put with the NEW mesh's shardings
                pshapes = param_shapes(cfg.param_defs(), mesh2)
                pshard = jax.tree.map(lambda s: s.sharding, pshapes)
                like = (params, state)
                shardings = (pshard, {"step": None, "m": pshard, "v": pshard})
                params2, state2 = ckpt.restore_checkpoint(d, 4, like, shardings)
                step2 = make_train_step(lambda p, b: loss_fn(cfg, p, b), opt, donate=False)
                for s in range(4, 6):
                    params2, state2, m = step2(params2, state2, lm_batch(dc, s))
                    losses.append(float(m["loss"]))
        print(json.dumps({"losses": losses}))
    """))
    losses = r["losses"]
    assert len(losses) == 6
    assert all(np.isfinite(l) for l in losses) if (np := __import__("numpy")) else True
    # training continued sensibly after the elastic restart
    assert losses[-1] < losses[0] + 0.5

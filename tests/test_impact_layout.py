"""Impact-ordered posting layout: bit-identity to the docID layout across
the full compress × prune × fused grid, monotone suffix-max envelopes +
segment CSR invariants, PForDelta exception-framing round-trip edge
cases, and the layout's end-to-end byte/skip win on a natural zipf trace."""
from types import SimpleNamespace

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GeoSearchEngine, QueryBudgets
from repro.core.text_index import (
    PFOR_HIGH_BITS,
    POSTING_BLOCK,
    build_text_index_np,
    decode_posting_blocks,
    impact_levels_np,
    pack_postings_np,
)
from repro.corpus import make_corpus, make_zipf_trace, pad_trace_batch


def _engine(corpus, layout, compress="none", prune=False, mc=512):
    budgets = QueryBudgets(
        max_candidates=mc, max_tiles=128, k_sweeps=4, sweep_budget=512,
        top_k=10, prune=prune,
    )
    return GeoSearchEngine.build(
        corpus.doc_terms, corpus.doc_rects, corpus.doc_amps, corpus.n_terms,
        pagerank=corpus.pagerank, grid=32, budgets=budgets,
        compress=compress, layout=layout,
    )


@pytest.fixture(scope="module")
def zipf_corpus_and_batch():
    corpus = make_corpus(1536, 160, seed=11)
    trace = make_zipf_trace(corpus, n_queries=48, pool_size=24, seed=12)
    return corpus, pad_trace_batch(trace)


# ---------------------------------------------------------------------------
# the core property: the impact layout is a pure storage reordering — ids
# AND scores are bit-identical to the docID layout on every pipeline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("compress", ["none", "f16", "int8"])
@pytest.mark.parametrize(
    "prune,fused", [(False, False), (True, False), (True, True)]
)
def test_impact_equals_docid_text_first(
    zipf_corpus_and_batch, compress, prune, fused
):
    """Pruned selection is order-invariant at any budget (the θ rule only
    ever discards candidates the top-C select stage would drop), so
    pruned runs must agree bit-for-bit.  The *unpruned* traversal
    truncates the driver's CSR walk at ``max_candidates`` — under the
    impact layout that keeps the highest-impact postings instead of the
    lowest docIDs, a different (better) candidate subset — so the
    unpruned case is compared at covering budgets, where both layouts
    stream every driver posting."""
    corpus, batch = zipf_corpus_and_batch
    mc = 512 if prune else len(corpus.doc_terms)
    out = {}
    for layout in ("docid", "impact"):
        eng = _engine(corpus, layout, compress=compress, prune=prune, mc=mc)
        kw = {"fused": True} if fused else {}
        out[layout] = eng.query(batch, "text_first", **kw)
    np.testing.assert_array_equal(
        np.asarray(out["docid"].ids), np.asarray(out["impact"].ids)
    )
    np.testing.assert_array_equal(
        np.asarray(out["docid"].scores), np.asarray(out["impact"].scores)
    )


def test_impact_layout_other_algorithms_identical(zipf_corpus_and_batch):
    """geo_first and k_sweep probe postings through the same segment-aware
    path — the layout must be invisible to them too."""
    corpus, batch = zipf_corpus_and_batch
    for algorithm in ("geo_first", "k_sweep"):
        out = {}
        for layout in ("docid", "impact"):
            eng = _engine(corpus, layout, compress="f16")
            out[layout] = eng.query(batch, algorithm)
        np.testing.assert_array_equal(
            np.asarray(out["docid"].ids), np.asarray(out["impact"].ids)
        )
        np.testing.assert_array_equal(
            np.asarray(out["docid"].scores), np.asarray(out["impact"].scores)
        )


def test_impact_prune_skips_more_blocks_on_natural_trace(zipf_corpus_and_batch):
    """The layout's purpose: on a plain zipf trace (no planted bimodality)
    the monotone bounds + early-exit cut turn θ-pruning into actual
    skipped blocks and fewer streamed posting bytes, at identical
    results (checked above).  2-term queries keep the min-df driver hot
    — the regime where docID-ordered pruning has nothing to skip."""
    corpus, _ = zipf_corpus_and_batch
    batch = pad_trace_batch(
        make_zipf_trace(
            corpus, n_queries=48, pool_size=24, seed=12, d_terms=2
        )
    )
    stats = {}
    for layout in ("docid", "impact"):
        eng = _engine(corpus, layout, compress="f16", prune=True, mc=256)
        r = eng.query(batch, "text_first", fused=True)
        stats[layout] = {
            k: float(np.asarray(v).sum()) for k, v in r.stats.items()
        }
    assert stats["impact"]["text_blocks_skipped"] > 0
    assert (
        stats["impact"]["text_blocks_skipped"]
        >= stats["docid"]["text_blocks_skipped"]
    )
    assert (
        stats["impact"]["bytes_postings"] < stats["docid"]["bytes_postings"]
    )


# ---------------------------------------------------------------------------
# layout invariants: monotone envelope + segment CSR structure
# ---------------------------------------------------------------------------

def test_blk_max_impact_monotone_per_term(zipf_corpus_and_batch):
    corpus, _ = zipf_corpus_and_batch
    idx = build_text_index_np(corpus.doc_terms, corpus.n_terms, layout="impact")
    bto = np.asarray(idx.blk_term_off)
    env = np.asarray(idx.blk_max_impact)
    for t in range(idx.n_terms):
        run = env[bto[t] : bto[t + 1]]
        assert np.all(np.diff(run) <= 0), f"term {t} envelope not monotone"


def test_segment_csr_structure(zipf_corpus_and_batch):
    """Segments tile each term's CSR slice exactly; docIDs ascend within a
    segment; quantized impact levels strictly descend across segments."""
    corpus, _ = zipf_corpus_and_batch
    idx = build_text_index_np(corpus.doc_terms, corpus.n_terms, layout="impact")
    raw = build_text_index_np(corpus.doc_terms, corpus.n_terms, layout="docid")
    offs = np.asarray(idx.offsets)
    sto = np.asarray(idx.seg_term_off)
    spos, slen = np.asarray(idx.seg_pos), np.asarray(idx.seg_len)
    post, imp = np.asarray(idx.postings), np.asarray(idx.impacts)
    lvl = impact_levels_np(imp)
    for t in range(idx.n_terms):
        segs = range(int(sto[t]), int(sto[t + 1]))
        assert sum(int(slen[s]) for s in segs) == int(offs[t + 1] - offs[t])
        cursor = int(offs[t])
        prev_lvl = -1
        for s in segs:
            a, n = int(spos[s]), int(slen[s])
            assert a == cursor  # segments tile the slice contiguously
            cursor += n
            ids = post[a : a + n]
            assert np.all(np.diff(ids) > 0)  # docID-ascending, duplicate-free
            levels = lvl[a : a + n]
            assert np.all(levels == levels[0])  # one level per segment
            assert levels[0] > prev_lvl  # strictly descending impact
            prev_lvl = levels[0]
    # a reordering, not a reweighting: the multiset of (doc, impact)
    # pairs per term is exactly the docID layout's
    roffs = np.asarray(raw.offsets)
    for t in range(idx.n_terms):
        a, b = int(offs[t]), int(offs[t + 1])
        got = sorted(zip(post[a:b].tolist(), imp[a:b].tolist()))
        want = sorted(
            zip(
                np.asarray(raw.postings)[roffs[t] : roffs[t + 1]].tolist(),
                np.asarray(raw.impacts)[roffs[t] : roffs[t + 1]].tolist(),
            )
        )
        assert got == want


def test_impact_layout_pays_segment_bytes(zipf_corpus_and_batch):
    """posting_bytes charges the packed words + 20 B/block + 8 B/segment
    honestly; the impact layout's extra framing (blocks restart at every
    segment boundary, plus the segment prefixes) makes it strictly
    costlier per posting than the docID layout."""
    corpus, _ = zipf_corpus_and_batch
    doc = build_text_index_np(corpus.doc_terms, corpus.n_terms, compress=True)
    imp = build_text_index_np(
        corpus.doc_terms, corpus.n_terms, compress=True, layout="impact"
    )
    for idx in (doc, imp):
        seg = 8 * idx.seg_pos.shape[0] if idx.layout == "impact" else 0
        want = (
            4 * idx.post_packed.shape[0] + 20 * idx.blk_first.shape[0] + seg
        ) / max(idx.n_postings, 1) + idx.impacts.dtype.itemsize
        assert idx.posting_bytes == pytest.approx(want, rel=1e-9)
    assert imp.posting_bytes > doc.posting_bytes
    assert imp.blk_first.shape[0] >= doc.blk_first.shape[0]


# ---------------------------------------------------------------------------
# PForDelta exception framing: round-trip edge cases (pack_postings_np
# driven directly, so delta gaps far beyond any test corpus are cheap)
# ---------------------------------------------------------------------------

def _pack(plists):
    """Pack a list of per-term sorted posting arrays; return a decode
    handle (`decode_posting_blocks` only touches the packed columns)."""
    offsets = np.zeros((len(plists) + 1,), np.int64)
    offsets[1:] = np.cumsum([len(p) for p in plists])
    postings = (
        np.concatenate(plists).astype(np.int64)
        if offsets[-1]
        else np.zeros((0,), np.int64)
    )
    cols = pack_postings_np(postings, offsets)
    return SimpleNamespace(**{k: jnp.asarray(v) for k, v in cols.items()})


def _decode_term(idx, t):
    bto = np.asarray(idx.blk_term_off)
    blk_len = np.asarray(idx.blk_len)
    ids = [
        np.asarray(decode_posting_blocks(idx, jnp.int32(b)))[: int(blk_len[b])]
        for b in range(int(bto[t]), int(bto[t + 1]))
    ]
    return np.concatenate(ids) if ids else np.zeros((0,), np.int64)


def test_pfor_zero_exception_block():
    """Uniform small deltas: the width argmin lands on the plain framing
    (no exception words) and decodes exactly."""
    plist = np.arange(0, 2 * POSTING_BLOCK * 3, 3, dtype=np.int64)
    idx = _pack([plist])
    assert int(np.asarray(idx.blk_n_exc).sum()) == 0
    np.testing.assert_array_equal(_decode_term(idx, 0), plist)


def test_pfor_exception_heavy_block():
    """Half tiny deltas, half huge: patching the outliers (one exception
    word each) beats widening the whole block, so the argmin framing
    carries many exceptions — and still decodes exactly."""
    deltas = np.ones(POSTING_BLOCK, np.int64)
    deltas[1::2] = 1 << 20  # 64 outliers, interleaved
    plist = np.cumsum(deltas) - 1
    idx = _pack([plist])
    n_exc = int(np.asarray(idx.blk_n_exc)[0])
    assert n_exc == POSTING_BLOCK // 2
    # exception framing must beat the no-exception alternative:
    # 64 patch words + a narrow base < 128 postings at 21 bits
    words_noexc = -(-POSTING_BLOCK * 21 // 32)
    bits = int(np.asarray(idx.blk_bits)[0])
    assert -(-POSTING_BLOCK * bits // 32) + n_exc < words_noexc
    np.testing.assert_array_equal(_decode_term(idx, 0), plist)


def test_pfor_single_posting_and_max_gap():
    """A single-posting term, and terms whose one delta is a maximal
    doc-id gap — wider than PFOR_HIGH_BITS, so the base width's floor
    (bits ≥ bit_length − PFOR_HIGH_BITS) must keep every exception's
    high bits inside one patch field."""
    big = (1 << (PFOR_HIGH_BITS + 4)) + 5
    plists = [
        np.asarray([7], np.int64),  # single posting
        np.asarray([0, big], np.int64),  # maximal delta gap
        # the gap hidden among tiny deltas: forces an exception whose
        # high bits exercise the width floor
        np.concatenate(
            [np.arange(64, dtype=np.int64), np.asarray([big], np.int64)]
        ),
    ]
    idx = _pack(plists)
    for t, want in enumerate(plists):
        np.testing.assert_array_equal(_decode_term(idx, t), want)


def test_pfor_ragged_tail_block():
    """A list whose last block is part-full: tail-trimmed base words plus
    exceptions decode exactly, and padding lanes never leak."""
    rng = np.random.default_rng(41)
    n = 2 * POSTING_BLOCK + 37  # ragged tail
    deltas = rng.integers(1, 4, size=n).astype(np.int64)
    deltas[n - 5] = 1 << 18  # an outlier inside the ragged tail
    plist = np.cumsum(deltas) - 1
    idx = _pack([plist])
    assert int(np.asarray(idx.blk_n_exc).sum()) >= 1
    np.testing.assert_array_equal(_decode_term(idx, 0), plist)


def test_pfor_roundtrip_random_impact_layout():
    """Random corpus under layout="impact": segment-local delta streams
    (docIDs restart ascending at each segment) round-trip exactly."""
    corpus = make_corpus(n_docs=700, n_terms=90, seed=42)
    comp = build_text_index_np(
        corpus.doc_terms, corpus.n_terms, compress=True, layout="impact"
    )
    raw = build_text_index_np(
        corpus.doc_terms, corpus.n_terms, compress=False, layout="impact"
    )
    offs = np.asarray(raw.offsets)
    for t in range(corpus.n_terms):
        np.testing.assert_array_equal(
            _decode_term(comp, t),
            np.asarray(raw.postings)[offs[t] : offs[t + 1]],
        )

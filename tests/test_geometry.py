"""Unit + property tests for rectangle/Morton geometry."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import geometry as G

coord = st.floats(0.0, 1.0, width=32, allow_nan=False)


def make_rect(x0, y0, w, h):
    return jnp.array([x0, y0, min(x0 + w, 1.0), min(y0 + h, 1.0)], jnp.float32)


class TestIntersection:
    def test_disjoint(self):
        a = jnp.array([0.0, 0.0, 0.2, 0.2])
        b = jnp.array([0.5, 0.5, 0.9, 0.9])
        assert float(G.rect_intersection_area(a, b)) == 0.0

    def test_contained(self):
        a = jnp.array([0.0, 0.0, 1.0, 1.0])
        b = jnp.array([0.2, 0.2, 0.4, 0.4])
        np.testing.assert_allclose(
            float(G.rect_intersection_area(a, b)), 0.04, rtol=1e-5
        )

    def test_empty_rect_zero(self):
        a = jnp.asarray(G.EMPTY_RECT)
        b = jnp.array([0.0, 0.0, 1.0, 1.0])
        assert float(G.rect_intersection_area(a, b)) == 0.0
        assert float(G.rect_area(a)) == 0.0

    @settings(max_examples=100, deadline=None)
    @given(coord, coord, coord, coord, coord, coord, coord, coord)
    def test_symmetry_and_bounds(self, x0, y0, w0, h0, x1, y1, w1, h1):
        a = make_rect(x0, y0, w0 * 0.3, h0 * 0.3)
        b = make_rect(x1, y1, w1 * 0.3, h1 * 0.3)
        iab = float(G.rect_intersection_area(a, b))
        iba = float(G.rect_intersection_area(b, a))
        assert iab == pytest.approx(iba, rel=1e-6)
        assert iab <= float(G.rect_area(a)) + 1e-6
        assert iab <= float(G.rect_area(b)) + 1e-6
        assert iab >= 0.0

    @settings(max_examples=50, deadline=None)
    @given(coord, coord, coord, coord)
    def test_self_intersection_is_area(self, x0, y0, w, h):
        a = make_rect(x0, y0, w * 0.5, h * 0.5)
        np.testing.assert_allclose(
            float(G.rect_intersection_area(a, a)), float(G.rect_area(a)), rtol=1e-5
        )


class TestMorton:
    def test_matches_numpy(self):
        rng = np.random.default_rng(0)
        ix = rng.integers(0, 1024, 100).astype(np.uint32)
        iy = rng.integers(0, 1024, 100).astype(np.uint32)
        got = np.asarray(G.morton_encode(jnp.asarray(ix.astype(np.int32)), jnp.asarray(iy.astype(np.int32))))
        want = G.morton_encode_np(ix, iy)
        np.testing.assert_array_equal(got, want.astype(np.int32))

    def test_bijective_on_grid(self):
        g = 64
        xs, ys = np.meshgrid(np.arange(g), np.arange(g))
        codes = G.morton_encode_np(xs.ravel().astype(np.uint32), ys.ravel().astype(np.uint32))
        assert len(np.unique(codes)) == g * g

    def test_locality(self):
        # adjacent cells differ by small code distance on average vs random
        g = 256
        rng = np.random.default_rng(1)
        x = rng.integers(0, g - 1, 1000).astype(np.uint32)
        y = rng.integers(0, g - 1, 1000).astype(np.uint32)
        d_adj = np.abs(
            G.morton_encode_np(x, y) - G.morton_encode_np(x + 1, y)
        ).mean()
        x2 = rng.integers(0, g, 1000).astype(np.uint32)
        y2 = rng.integers(0, g, 1000).astype(np.uint32)
        d_rand = np.abs(G.morton_encode_np(x, y) - G.morton_encode_np(x2, y2)).mean()
        assert d_adj < d_rand / 10


class TestTiles:
    def test_enumerate_covers_rect(self):
        r = jnp.array([0.26, 0.26, 0.52, 0.40], jnp.float32)
        tiles, valid = G.enumerate_rect_tiles(r, grid=8, max_tiles=64)
        got = sorted(set(int(t) for t, v in zip(tiles, valid) if v))
        # covered cells: x in [2..4], y in [2..3] (inclusive of boundary rule)
        want = sorted({ty * 8 + tx for tx in (2, 3, 4) for ty in (2, 3)})
        assert got == want

    def test_empty_rect_no_tiles(self):
        tiles, valid = G.enumerate_rect_tiles(
            jnp.asarray(G.EMPTY_RECT), grid=8, max_tiles=16
        )
        assert not bool(valid.any())

    @settings(max_examples=50, deadline=None)
    @given(coord, coord, coord, coord)
    def test_point_in_rect_tile_enumerated(self, x0, y0, w, h):
        r = make_rect(x0 * 0.8, y0 * 0.8, max(w * 0.1, 1e-3), max(h * 0.1, 1e-3))
        grid = 16
        tiles, valid = G.enumerate_rect_tiles(r, grid=grid, max_tiles=grid * grid)
        cx, cy = (r[0] + r[2]) / 2, (r[1] + r[3]) / 2
        ix, iy = G.point_to_cell(cx, cy, grid)
        center_tile = int(iy) * grid + int(ix)
        enumerated = set(int(t) for t, v in zip(tiles, valid) if v)
        assert center_tile in enumerated

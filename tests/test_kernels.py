"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU; identical code targets TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bitmap_filter.ops import bitmap_and_popcount
from repro.kernels.bitmap_filter.ref import bitmap_and_popcount_ref
from repro.kernels.geo_score.ops import geo_score_docs, geo_score_toeprints
from repro.kernels.geo_score.ref import geo_score_toeprints_ref


def _rects(rng, n):
    lo = rng.uniform(0, 0.9, (n, 2)).astype(np.float32)
    hi = lo + rng.uniform(0.005, 0.2, (n, 2)).astype(np.float32)
    return np.concatenate([lo, np.minimum(hi, 1.0)], axis=1)


@pytest.mark.parametrize("T", [1, 7, 128, 1024, 1025, 4096, 10000])
@pytest.mark.parametrize("Q", [1, 2, 8])
def test_geo_score_shape_sweep(T, Q):
    rng = np.random.default_rng(T * 31 + Q)
    r = jnp.asarray(_rects(rng, T))
    a = jnp.asarray(rng.uniform(0, 1, T).astype(np.float32))
    qr = jnp.asarray(_rects(rng, Q))
    qa = jnp.asarray(rng.uniform(0, 1, Q).astype(np.float32))
    got = geo_score_toeprints(r, a, qr, qa)
    want = geo_score_toeprints_ref(r, a, qr, qa)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float16, jnp.bfloat16])
def test_geo_score_dtype_sweep(dtype):
    rng = np.random.default_rng(9)
    r = jnp.asarray(_rects(rng, 512)).astype(dtype)
    a = jnp.asarray(rng.uniform(0, 1, 512).astype(np.float32)).astype(dtype)
    qr = jnp.asarray(_rects(rng, 4)).astype(dtype)
    qa = jnp.ones((4,), dtype)
    got = geo_score_toeprints(r, a, qr, qa)
    want = geo_score_toeprints_ref(
        r.astype(jnp.float32), a.astype(jnp.float32),
        qr.astype(jnp.float32), qa.astype(jnp.float32),
    )
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_geo_score_empty_rect_padding():
    rng = np.random.default_rng(1)
    r = np.asarray(_rects(rng, 8))
    r[3] = [1.0, 1.0, 0.0, 0.0]  # empty
    a = np.ones((8,), np.float32)
    got = geo_score_toeprints(
        jnp.asarray(r), jnp.asarray(a),
        jnp.asarray([[0.0, 0.0, 1.0, 1.0]], dtype=jnp.float32), jnp.ones((1,)),
    )
    assert float(got[3]) == 0.0


def test_geo_score_docs_matches_footprint_module():
    from repro.core.footprint import geo_score as fp_score

    rng = np.random.default_rng(2)
    C, R, Q = 33, 3, 2
    rects = jnp.asarray(_rects(rng, C * R).reshape(C, R, 4))
    amps = jnp.asarray(rng.uniform(0, 1, (C, R)).astype(np.float32))
    qr = jnp.asarray(_rects(rng, Q))
    qa = jnp.ones((Q,))
    got = geo_score_docs(rects, amps, qr, qa)
    want = fp_score(rects, amps, qr, qa)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


@pytest.mark.parametrize("d", [1, 2, 3, 4])
@pytest.mark.parametrize("W", [1, 31, 32, 1024, 1025, 8192])
def test_bitmap_shape_sweep(d, W):
    rng = np.random.default_rng(d * 131 + W)
    bm = jnp.asarray(rng.integers(0, 2**32, (d, W), dtype=np.uint32))
    g_and, g_cnt = bitmap_and_popcount(bm)
    w_and, w_cnt = bitmap_and_popcount_ref(bm)
    np.testing.assert_array_equal(np.asarray(g_and), np.asarray(w_and))
    np.testing.assert_array_equal(np.asarray(g_cnt), np.asarray(w_cnt))


def test_bitmap_known_values():
    bm = jnp.asarray(np.array([[0b1010, 0xFFFFFFFF], [0b0110, 0xFFFF0000]], np.uint32))
    anded, cnt = bitmap_and_popcount(bm)
    assert int(anded[0]) == 0b0010 and int(cnt[0]) == 1
    assert int(anded[1]) == 0xFFFF0000 and int(cnt[1]) == 16


def test_bitmap_conjunction_against_index():
    """Bitmap AND+popcount equals the brute-force conjunction count."""
    from repro.core.text_index import build_text_index_np

    rng = np.random.default_rng(4)
    docs = [rng.integers(0, 6, rng.integers(1, 8)).astype(np.int32) for _ in range(200)]
    idx = build_text_index_np(docs, 6, n_bitmap_terms=6)
    ids = np.asarray(idx.bitmap_term_ids)
    row = {int(w): i for i, w in enumerate(ids)}
    t0, t1 = 0, 1
    bm = jnp.asarray(np.asarray(idx.bitmaps)[[row[t0], row[t1]]])
    _, cnt = bitmap_and_popcount(bm)
    want = sum(1 for d in docs if t0 in d and t1 in d)
    assert int(cnt.sum()) == want

"""Property tests for multi-worker open-loop serving + in-flight coalescing.

The open-loop replay is a discrete-event simulation, so every invariant
here runs on a **virtual clock** with an injected ``service_time`` model —
no wall-clock or XLA timing leaks in, and every check is deterministic.

hypothesis is not a baked-in dependency of this container, so the
properties are checked as *seeded loops* over many randomized
configurations (traces, arrival processes, worker counts, deadlines,
caches); when hypothesis is installed an extra fuzz variant drives the
same checker with drawn parameters.

Invariants (ISSUE 3):

(a) batch-wait + queue-wait + service == total latency for every query,
    under any workers × coalesce × deadline × arrival-process mix;
(b) ``n_workers=1, coalesce=False`` reproduces PR 2's single-busy-server
    open-loop timeline bit-identically (recurrence + default-config
    equality);
(c) work conservation — no worker idles while the dispatch queue holds a
    flushed batch;
(d) coalesced duplicates return the same doc IDs/scores as their executed
    twin and never increase the executed-batch count.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.corpus import make_arrivals
from repro.corpus.synth import TraceQuery
from repro.serving import DeadlineBatcher, GeoServer, LRUCache


class RowExecutor:
    """Deterministic per-row results, no jax: each output row is a pure
    function of that row's own (unpadded) query content, so identical
    queries must produce identical ids/scores however they are batched."""

    top_k = 4

    def run(self, batch):
        terms = np.asarray(batch.terms)
        B = terms.shape[0]
        base = terms.max(axis=1).astype(np.int64)  # padding rows → -1
        ids = (base[:, None] * 16 + np.arange(self.top_k)).astype(np.int32)
        tsum = np.where(terms >= 0, terms, 0).sum(axis=1).astype(np.float32)
        scores = tsum[:, None] - np.arange(self.top_k, dtype=np.float32)
        return alg.TopKResult(
            ids=ids, scores=scores, stats={"bytes_seq": np.ones(B)}
        )


def _pool_query(i: int, d: int, r: int) -> TraceQuery:
    # disjoint term ranges per pool slot → distinct fingerprints
    terms = np.arange(i * 8, i * 8 + d, dtype=np.int32)
    lo = np.full((r, 2), 0.1 + 0.01 * (i % 50), np.float32)
    rects = np.concatenate([lo, lo + 0.05], axis=1)
    return TraceQuery(terms, rects, np.ones((r,), np.float32))


def _random_trace(seed, n=200, pool=24, kind="poisson", rate=400.0):
    """Duplicate-heavy stamped trace; ``pool=None`` → all queries distinct."""
    rng = np.random.default_rng(seed)
    size = n if pool is None else pool
    pool_qs = [
        _pool_query(i, int(rng.integers(1, 8)), int(rng.integers(1, 4)))
        for i in range(size)
    ]
    picks = np.arange(n) if pool is None else rng.integers(0, pool, n)
    times = make_arrivals(kind, n, rate_qps=rate, seed=seed + 1)
    return [
        dataclasses.replace(pool_qs[p], arrival_s=float(t))
        for p, t in zip(picks, times)
    ]


def _service(raw) -> float:
    """Injected virtual batch duration: deterministic function of the batch."""
    return (1 + (raw.n_real % 3)) * 1.7e-3


def _server(workers=1, coalesce=False, max_wait_s=2e-3, cache=None, max_batch=8):
    return GeoServer(
        RowExecutor(),
        cache=cache,
        batcher=DeadlineBatcher(
            max_batch=max_batch, max_terms=8, max_rects=4, max_wait_s=max_wait_s
        ),
        n_workers=workers,
        coalesce=coalesce,
    )


def _check_decomposition(rep, n: int) -> None:
    assert rep.n_queries == n
    assert len(rep.latencies_s) == n
    assert rep.cache_hits + rep.cache_misses == n
    assert rep.coalesced <= rep.cache_misses
    total = (
        np.asarray(rep.batch_wait_s)
        + np.asarray(rep.queue_wait_s)
        + np.asarray(rep.service_s)
    )
    np.testing.assert_allclose(
        np.asarray(rep.latencies_s), total, rtol=0, atol=1e-12
    )
    # every component is a real delay, never negative
    assert min(rep.batch_wait_s) >= 0
    assert min(rep.queue_wait_s) >= 0
    assert min(rep.service_s) >= 0


def _run_and_check(seed, workers, coalesce, wait, kind, with_cache) -> None:
    trace = _random_trace(seed, kind=kind)
    cache = LRUCache(64) if with_cache else None
    srv = _server(workers, coalesce, wait, cache)
    rep = srv.run_trace(trace, warmup=False, arrival=kind, service_time=_service)
    _check_decomposition(rep, len(trace))


# ---------------------------------------------------------------------------
# (a) exact latency decomposition under every configuration
# ---------------------------------------------------------------------------

def test_decomposition_sums_exactly_across_configs():
    for seed in range(6):
        kind = ("poisson", "bursty")[seed % 2]
        with_cache = seed % 3 == 0
        for workers in (1, 2, 4):
            for coalesce in (False, True):
                for wait in (0.0, 2e-3, float("inf")):
                    _run_and_check(seed, workers, coalesce, wait, kind, with_cache)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        workers=st.integers(1, 5),
        coalesce=st.booleans(),
        wait=st.sampled_from([0.0, 1e-3, 5e-3, float("inf")]),
        kind=st.sampled_from(["poisson", "bursty", "diurnal"]),
        with_cache=st.booleans(),
    )
    def test_decomposition_sums_exactly_fuzzed(
        seed, workers, coalesce, wait, kind, with_cache
    ):
        _run_and_check(seed, workers, coalesce, wait, kind, with_cache)
except ImportError:  # seeded loops above cover the property
    pass


# ---------------------------------------------------------------------------
# (b) n_workers=1, coalesce=False ≡ PR 2 single-busy-server timeline
# ---------------------------------------------------------------------------

def test_single_worker_follows_busy_server_recurrence():
    """PR 2 semantics: one executor timeline — batch j starts at
    max(flush_j, done_{j-1}), exactly (float-equal, not approximately)."""
    for seed in range(4):
        trace = _random_trace(seed, n=300, rate=800.0)
        srv = _server(workers=1, coalesce=False, cache=LRUCache(64))
        rep = srv.run_trace(
            trace, warmup=False, arrival="poisson", service_time=_service
        )
        free = 0.0
        for ev in rep.batch_events:
            assert ev.worker == 0
            assert ev.start_t == max(ev.flush_t, free)
            free = ev.done_t
        _check_decomposition(rep, len(trace))


def test_default_server_is_single_worker_no_coalesce():
    """A server built without the new knobs reproduces the explicit
    (n_workers=1, coalesce=False) run bit-identically."""
    trace = _random_trace(3, n=250)
    batcher = dict(max_batch=8, max_terms=8, max_rects=4, max_wait_s=2e-3)
    old_style = GeoServer(
        RowExecutor(), cache=LRUCache(64), batcher=DeadlineBatcher(**batcher)
    )
    explicit = GeoServer(
        RowExecutor(), cache=LRUCache(64), batcher=DeadlineBatcher(**batcher),
        n_workers=1, coalesce=False,
    )
    reps = [
        s.run_trace(trace, warmup=False, arrival="poisson", service_time=_service)
        for s in (old_style, explicit)
    ]
    assert reps[0].latencies_s == reps[1].latencies_s
    assert reps[0].batch_wait_s == reps[1].batch_wait_s
    assert reps[0].queue_wait_s == reps[1].queue_wait_s
    assert reps[0].service_s == reps[1].service_s
    assert reps[0].n_batches == reps[1].n_batches
    assert reps[0].cache_hits == reps[1].cache_hits
    assert reps[0].coalesced == reps[1].coalesced == 0


# ---------------------------------------------------------------------------
# (c) work conservation across the worker pool
# ---------------------------------------------------------------------------

def test_no_worker_idles_while_dispatch_queue_nonempty():
    """FIFO dispatch: every batch starts the instant both it (flush) and
    the earliest-free worker are ready, on that earliest-free worker."""
    for seed in range(4):
        for workers in (2, 3, 4):
            trace = _random_trace(seed, n=300, rate=900.0)
            srv = _server(workers, coalesce=seed % 2 == 1, max_wait_s=1e-3)
            rep = srv.run_trace(
                trace, warmup=False, arrival="poisson", service_time=_service
            )
            free = [0.0] * workers
            for ev in rep.batch_events:
                assert free[ev.worker] == min(free)  # earliest-free slot
                assert ev.start_t == max(ev.flush_t, min(free))
                free[ev.worker] = ev.done_t
            assert len(rep.batch_events) == rep.n_batches


def test_more_workers_cut_queue_wait_at_same_load():
    """Acceptance: at a load that saturates one worker, a pool drains the
    dispatch queue — p99 queue-wait drops (virtual clock, deterministic)."""
    trace = _random_trace(0, n=400, pool=None, rate=1000.0)  # all distinct
    reps = {}
    for workers in (1, 4):
        srv = _server(workers, max_wait_s=1e-3)
        reps[workers] = srv.run_trace(
            trace, warmup=False, arrival="poisson",
            service_time=lambda raw: 3e-3,
        )
    qw1 = reps[1].stage_percentile_ms("queue_wait", 99)
    qw4 = reps[4].stage_percentile_ms("queue_wait", 99)
    assert qw4 < 0.5 * qw1, (qw1, qw4)
    assert reps[4].percentile_ms(99) < reps[1].percentile_ms(99)
    # same batches were executed either way — only the timeline changed
    assert reps[4].n_batches == reps[1].n_batches


# ---------------------------------------------------------------------------
# (d) coalescing: twin results, never more executed batches
# ---------------------------------------------------------------------------

def test_coalesced_duplicates_return_twin_results():
    for seed in range(4):
        # high rate → many duplicates arrive while their twin is in flight
        trace = _random_trace(seed, n=250, pool=12, rate=2000.0)
        plain = _server(2, coalesce=False, cache=LRUCache(256))
        rep0 = plain.run_trace(
            trace, warmup=False, arrival="poisson",
            service_time=_service, collect_results=True,
        )
        srv = _server(2, coalesce=True, cache=LRUCache(256))
        rep1 = srv.run_trace(
            trace, warmup=False, arrival="poisson",
            service_time=_service, collect_results=True,
        )
        assert rep1.coalesced > 0
        # coalescing removes work; it can never add executed batches
        assert rep1.n_batches <= rep0.n_batches
        assert rep1.real_slots + rep1.coalesced + rep1.cache_hits == len(trace)
        _check_decomposition(rep1, len(trace))
        # every query got a result, and identical queries — whether
        # executed, cache-hit, or coalesced — got identical ids/scores
        assert all(r is not None for r in rep1.results)
        for rep in (rep0, rep1):
            by_query = {}
            for q, res in zip(trace, rep.results):
                by_query.setdefault(q.terms.tobytes(), []).append(res)
            for group in by_query.values():
                for r in group[1:]:
                    np.testing.assert_array_equal(group[0].ids, r.ids)
                    np.testing.assert_array_equal(group[0].scores, r.scores)
        # and the two runs agree query-by-query
        for r0, r1 in zip(rep0.results, rep1.results):
            np.testing.assert_array_equal(r0.ids, r1.ids)
            np.testing.assert_array_equal(r0.scores, r1.scores)


def test_coalesce_without_cache_still_dedupes_in_flight():
    trace = _random_trace(1, n=200, pool=8, rate=2000.0)
    rep_off = _server(1, coalesce=False).run_trace(
        trace, warmup=False, arrival="poisson", service_time=_service
    )
    rep_on = _server(1, coalesce=True).run_trace(
        trace, warmup=False, arrival="poisson", service_time=_service
    )
    assert rep_on.coalesced > 0
    assert rep_on.real_slots < rep_off.real_slots  # fewer executed queries
    assert rep_on.n_batches <= rep_off.n_batches
    assert rep_on.cache_hits == rep_off.cache_hits == 0
    _check_decomposition(rep_on, len(trace))


# ---------------------------------------------------------------------------
# cache-fill visibility on the virtual timeline
# ---------------------------------------------------------------------------

def test_fast_batch_fill_visible_behind_slow_earlier_batch():
    """With overlapping workers, completion order != dispatch order: a fast
    batch's cache fill must become visible at its own done time even while
    an earlier-dispatched slow batch is still running."""
    slow, fast = _pool_query(0, d=3, r=1), _pool_query(1, d=3, r=1)
    trace = [
        dataclasses.replace(slow, arrival_s=0.0),  # service 100ms → done 0.1
        dataclasses.replace(fast, arrival_s=0.001),  # service 1ms → done ~2ms
        dataclasses.replace(fast, arrival_s=0.050),  # must HIT the cache
    ]
    srv = _server(workers=2, max_wait_s=0.0, cache=LRUCache(16))
    rep = srv.run_trace(
        trace, warmup=False, arrival="poisson",
        service_time=lambda raw: 0.1 if raw.terms[0, 0] == 0 else 1e-3,
    )
    assert rep.cache_hits == 1
    assert rep.n_batches == 2
    _check_decomposition(rep, len(trace))


def test_deadline_batch_fill_visible_to_triggering_arrival():
    """A duplicate whose arrival lazily fires the twin's deadline flush —
    with the twin's completion long past — must hit the cache, as on a
    live server where that batch really finished on the wall clock."""
    q = _pool_query(2, d=3, r=1)
    trace = [
        dataclasses.replace(q, arrival_s=0.0),  # flush at 5ms, done at 7ms
        dataclasses.replace(q, arrival_s=0.020),  # arrives well after 7ms
    ]
    srv = _server(workers=1, max_wait_s=5e-3, cache=LRUCache(16))
    rep = srv.run_trace(
        trace, warmup=False, arrival="poisson", service_time=lambda raw: 2e-3
    )
    assert rep.cache_hits == 1
    assert rep.n_batches == 1
    _check_decomposition(rep, len(trace))


# ---------------------------------------------------------------------------
# closed-loop edges of the new knobs
# ---------------------------------------------------------------------------

def test_closed_loop_coalesces_within_batcher_window():
    q = _pool_query(0, d=3, r=1)
    fillers = [_pool_query(i, d=3, r=1) for i in range(1, 4)]
    trace = [q, dataclasses.replace(q)] + fillers  # dup while twin batched
    srv = _server(1, coalesce=True, max_wait_s=float("inf"), max_batch=4)
    rep = srv.run_trace(trace, warmup=False, collect_results=True)
    assert rep.coalesced == 1
    assert rep.real_slots == 4  # the duplicate never re-executed
    np.testing.assert_array_equal(rep.results[0].ids, rep.results[1].ids)
    np.testing.assert_array_equal(rep.results[0].scores, rep.results[1].scores)
    _check_decomposition(rep, len(trace))


def test_closed_loop_rejects_worker_pool():
    srv = _server(workers=2)
    with pytest.raises(ValueError, match="open-loop"):
        srv.run_trace([_pool_query(0, 2, 1)], warmup=False, arrival="closed")
    with pytest.raises(ValueError, match="n_workers"):
        GeoServer(RowExecutor(), n_workers=0)


# ---------------------------------------------------------------------------
# CI tooling: the baseline gate tolerates new rows (warn, don't fail)
# ---------------------------------------------------------------------------

def test_compare_baseline_new_rows_warn_not_fail():
    from benchmarks.compare_baseline import compare

    base = {"a": {"p99_ms": 10.0, "qps": 100.0}}
    cur = {
        "a": {"p99_ms": 11.0, "qps": 99.0},
        "serving_workers_2_coalesce_on": {"p99_ms": 500.0, "qps": 1.0},
    }
    failures, warnings = compare(base, cur)
    assert failures == []
    assert len(warnings) == 1
    assert "serving_workers_2_coalesce_on" in warnings[0]


def test_compare_baseline_dropped_and_regressed_rows_fail():
    from benchmarks.compare_baseline import compare

    base = {
        "a": {"p99_ms": 200.0, "qps": 100.0},
        "b": {"p99_ms": 10.0, "qps": 100.0},
    }
    cur = {"a": {"p99_ms": 2000.0, "qps": 10.0}}
    failures, warnings = compare(base, cur)
    assert warnings == []
    assert len(failures) == 3  # a: p99 blowout, a: qps floor, b: dropped
    assert any("missing" in f for f in failures)
